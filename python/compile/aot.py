"""AOT compilation: lower the L2 graphs to HLO text artifacts.

Run once at build time (`make artifacts`); the Rust runtime loads the
text with `HloModuleProto::from_text_file` and compiles it on the PJRT
CPU client. HLO **text** is the interchange format (not
`.serialize()`): jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 rejects; the text parser reassigns ids.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Page-count variants for the policy step — keep in sync with
# rust/src/runtime/mod.rs::ARTIFACT_SIZES.
HOTNESS_SIZES = [4096, 16384, 65536, 262144]
# Batch size for the latency model artifact.
LATENCY_BATCH = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see /opt/xla-example)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_policy_step(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    lowered = jax.jit(model.policy_step).lower(spec, spec, spec, spec)
    return to_hlo_text(lowered)


def lower_latency_model(batch: int) -> str:
    spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    lowered = jax.jit(model.latency_estimate).lower(spec, spec, spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--sizes", default=",".join(map(str, HOTNESS_SIZES)),
                    help="comma-separated policy-step page counts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"hotness_step": [], "latency_model": []}

    for n in [int(s) for s in args.sizes.split(",") if s]:
        text = lower_policy_step(n)
        path = os.path.join(args.out_dir, f"hotness_step_{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["hotness_step"].append({"pages": n, "file": os.path.basename(path),
                                         "chars": len(text)})
        print(f"wrote {path} ({len(text)} chars)")

    text = lower_latency_model(LATENCY_BATCH)
    path = os.path.join(args.out_dir, f"latency_model_{LATENCY_BATCH}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["latency_model"].append({"batch": LATENCY_BATCH,
                                      "file": os.path.basename(path),
                                      "chars": len(text)})
    print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
