"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps shapes and values
(hypothesis) asserting the Pallas kernels (interpret=True) match these
references exactly, and the Rust `NativeHotnessEngine` mirrors the same
math so the whole three-layer stack agrees.
"""

import jax.numpy as jnp

# Policy constants — keep in sync with rust/src/hmmu/policy/hotness.rs.
HOTNESS_DECAY = 0.5
WRITE_WEIGHT = 2.0
NEG_INF = -1.0e30


def hotness_step_ref(reads, writes, prev, in_dram):
    """Reference policy step.

    hotness' = DECAY*prev + reads + WRITE_WEIGHT*writes
    promote  = hotness' where NVM-resident else -inf
    demote   = -hotness' where DRAM-resident else -inf
    """
    hot = HOTNESS_DECAY * prev + (reads + WRITE_WEIGHT * writes)
    dram = in_dram != 0.0
    promote = jnp.where(dram, NEG_INF, hot)
    demote = jnp.where(dram, -hot, NEG_INF)
    return hot, promote, demote


def latency_model_ref(is_nvm, is_write, queue_depth, *, dram_rt_ns=32.0,
                      pcie_rtt_ns=510.0, nvm_read_stall_ns=50.0,
                      nvm_write_stall_ns=225.0, service_ns=18.0):
    """Reference batched request-latency estimate (§III-F calibration).

    latency = PCIe RTT + DRAM round trip
            + NVM stall (read or write) when the request targets NVM
            + queue_depth * per-request service time
    """
    nvm_stall = is_nvm * (
        is_write * nvm_write_stall_ns + (1.0 - is_write) * nvm_read_stall_ns
    )
    return pcie_rtt_ns + dram_rt_ns + nvm_stall + queue_depth * service_ns
