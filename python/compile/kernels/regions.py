"""Layer-1 Pallas kernel: region access aggregation for pattern
recognition.

The paper's §III-A lists three policy aspects users implement in fabric:
"the memory access pattern recognition, data placement policy, and data
migration policy". This kernel is the *recognition* stage: it reduces
per-page epoch counters into per-region aggregates (region = contiguous
group of `pages_per_region` pages) so the policy can classify regions as
streaming (uniform, read-heavy), hot-spot (skewed), or write-bursty —
at region granularity instead of page granularity.

Outputs per region: total reads, total writes, max page hotness (a
skew/peak indicator which, together with the total, distinguishes a hot
spot from a uniform stream).

TPU shape: grid over regions; each step reduces one `pages_per_region`
block from VMEM with `jnp.sum`/`jnp.max` (VPU reductions).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import HOTNESS_DECAY, WRITE_WEIGHT

# Pages aggregated per region (4 KiB pages -> 1 MiB regions).
PAGES_PER_REGION = 256


def _region_kernel(reads_ref, writes_ref, prev_ref,
                   sum_reads_ref, sum_writes_ref, max_hot_ref):
    reads = reads_ref[...]
    writes = writes_ref[...]
    prev = prev_ref[...]
    hot = HOTNESS_DECAY * prev + (reads + WRITE_WEIGHT * writes)
    sum_reads_ref[...] = jnp.sum(reads)[None]
    sum_writes_ref[...] = jnp.sum(writes)[None]
    max_hot_ref[...] = jnp.max(hot)[None]


@functools.partial(jax.jit, static_argnames=("pages_per_region",))
def region_stats(reads, writes, prev, *, pages_per_region=PAGES_PER_REGION):
    """Aggregate f32[N] page counters into f32[N/R] region stats."""
    n = reads.shape[0]
    assert n % pages_per_region == 0, (
        f"page count {n} not a multiple of region size {pages_per_region}")
    regions = n // pages_per_region
    in_spec = pl.BlockSpec((pages_per_region,), lambda i: (i,))
    out_spec = pl.BlockSpec((1,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((regions,), jnp.float32)
    return pl.pallas_call(
        _region_kernel,
        grid=(regions,),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=(out_spec, out_spec, out_spec),
        out_shape=(out, out, out),
        interpret=True,
    )(reads, writes, prev)


def classify_regions(sum_reads, sum_writes, max_hot, *,
                     write_burst_ratio=2.0, skew_ratio=0.25):
    """Classify each region (plain jnp; runs inside the L2 graph).

    Returns an i32 class per region:
      0 = cold        (negligible traffic)
      1 = streaming   (traffic spread evenly, read-dominated)
      2 = hot-spot    (one page dominates: max_hot > skew_ratio * total)
      3 = write-burst (writes dominate reads)
    """
    total = sum_reads + sum_writes
    eps = 1e-6
    is_cold = total < 1.0
    is_burst = sum_writes > write_burst_ratio * (sum_reads + eps)
    is_spot = max_hot > skew_ratio * (total + eps)
    return jnp.where(
        is_cold, 0,
        jnp.where(is_burst, 3, jnp.where(is_spot, 2, 1))
    ).astype(jnp.int32)
