"""Layer-1 Pallas kernel: the epoch policy step.

The HMMU accumulates per-page read/write counters during an epoch; at the
boundary this kernel computes decayed hotness and migration scores for
every page in one dense pass.

TPU shape (DESIGN.md §Hardware-Adaptation): the page array is tiled
through VMEM in `BLOCK`-page blocks; per block the math is a fused
elementwise FMA + two selects — pure VPU work with all operands resident
(4 input streams + 3 output streams x BLOCK x 4B = 28 KiB at BLOCK=1024,
comfortably inside VMEM). interpret=True everywhere here: the CPU PJRT
client cannot execute Mosaic custom-calls; on a real TPU the same
pallas_call lowers natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import HOTNESS_DECAY, NEG_INF, WRITE_WEIGHT

# Pages per VMEM block.
BLOCK = 1024


def _hotness_kernel(reads_ref, writes_ref, prev_ref, in_dram_ref,
                    hot_ref, promote_ref, demote_ref):
    """One block: fused hotness update + masked scores."""
    reads = reads_ref[...]
    writes = writes_ref[...]
    prev = prev_ref[...]
    in_dram = in_dram_ref[...]

    hot = HOTNESS_DECAY * prev + (reads + WRITE_WEIGHT * writes)
    dram = in_dram != 0.0
    hot_ref[...] = hot
    promote_ref[...] = jnp.where(dram, NEG_INF, hot)
    demote_ref[...] = jnp.where(dram, -hot, NEG_INF)


@functools.partial(jax.jit, static_argnames=("block",))
def hotness_step(reads, writes, prev, in_dram, *, block=BLOCK):
    """Pallas policy step over f32[N] page arrays (N % block == 0)."""
    n = reads.shape[0]
    assert n % block == 0, f"page count {n} not a multiple of block {block}"
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _hotness_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=(spec, spec, spec),
        out_shape=(out, out, out),
        interpret=True,  # CPU PJRT cannot run Mosaic; see module docstring
    )(reads, writes, prev, in_dram)
