"""Layer-1 Pallas kernel: batched request-latency composition.

Implements the paper's §III-F "arbitrary latency cycles" calibration as a
batched estimator: given per-request device/type/queue-depth vectors and
the measured DRAM round trip, produce per-request latency estimates. The
`hymem calibrate` CLI uses the AOT artifact of this kernel to print the
stall-cycle table for every Table I technology.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _latency_kernel(is_nvm_ref, is_write_ref, qd_ref, out_ref, *,
                    dram_rt_ns, pcie_rtt_ns, nvm_read_stall_ns,
                    nvm_write_stall_ns, service_ns):
    is_nvm = is_nvm_ref[...]
    is_write = is_write_ref[...]
    qd = qd_ref[...]
    nvm_stall = is_nvm * (
        is_write * nvm_write_stall_ns + (1.0 - is_write) * nvm_read_stall_ns
    )
    out_ref[...] = pcie_rtt_ns + dram_rt_ns + nvm_stall + qd * service_ns


@functools.partial(
    jax.jit,
    static_argnames=(
        "block", "dram_rt_ns", "pcie_rtt_ns", "nvm_read_stall_ns",
        "nvm_write_stall_ns", "service_ns",
    ),
)
def latency_model(is_nvm, is_write, queue_depth, *, block=BLOCK,
                  dram_rt_ns=32.0, pcie_rtt_ns=510.0,
                  nvm_read_stall_ns=50.0, nvm_write_stall_ns=225.0,
                  service_ns=18.0):
    """Pallas latency estimator over f32[B] request vectors."""
    n = is_nvm.shape[0]
    assert n % block == 0, f"batch {n} not a multiple of block {block}"
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    kernel = functools.partial(
        _latency_kernel,
        dram_rt_ns=dram_rt_ns,
        pcie_rtt_ns=pcie_rtt_ns,
        nvm_read_stall_ns=nvm_read_stall_ns,
        nvm_write_stall_ns=nvm_write_stall_ns,
        service_ns=service_ns,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(is_nvm, is_write, queue_depth)
