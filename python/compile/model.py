"""Layer-2 JAX model: the HMMU policy step graph.

Wraps the Layer-1 Pallas kernels into the exact computation the Rust
coordinator executes each epoch, and is the function `aot.py` lowers to
HLO text. Returns tuples so the Rust side can `to_tuple()` the result.
"""

from .kernels.hotness import hotness_step
from .kernels.latency import latency_model


def policy_step(reads, writes, prev, in_dram):
    """Epoch policy step: (hotness, promote_score, demote_score).

    Inputs are f32[N] page arrays; N is fixed per AOT variant (the Rust
    runtime pads to the next variant size). The heavy lifting is the
    Pallas kernel; this graph exists so future L2 additions (e.g.
    cross-epoch smoothing, per-region aggregation) compose before AOT.
    """
    hot, promote, demote = hotness_step(reads, writes, prev, in_dram)
    return (hot, promote, demote)


def latency_estimate(is_nvm, is_write, queue_depth, **params):
    """Batched latency estimate (§III-F calibration graph)."""
    return (latency_model(is_nvm, is_write, queue_depth, **params),)
