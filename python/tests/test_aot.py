"""AOT pipeline tests: lowering to HLO text, artifact structure, and
round-trip executability on the CPU backend."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import hotness_step_ref


class TestLowering:
    def test_policy_step_lowers_to_hlo_text(self):
        text = aot.lower_policy_step(4096)
        assert "HloModule" in text
        assert "f32[4096]" in text
        # return_tuple=True -> root is a 3-tuple.
        assert "(f32[4096]" in text

    def test_latency_model_lowers(self):
        text = aot.lower_latency_model(1024)
        assert "HloModule" in text
        assert "f32[1024]" in text

    def test_all_variants_lower(self):
        for n in aot.HOTNESS_SIZES:
            text = aot.lower_policy_step(n)
            assert f"f32[{n}]" in text

    def test_no_custom_calls_in_hlo(self):
        """interpret=True must lower to plain HLO ops the CPU client can
        run — a Mosaic custom-call here would break the Rust runtime."""
        text = aot.lower_policy_step(4096)
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


class TestArtifactGeneration:
    def test_main_writes_artifacts(self, tmp_path):
        out = tmp_path / "artifacts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
             "--sizes", "4096"],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert (out / "hotness_step_4096.hlo.txt").exists()
        assert (out / "latency_model_1024.hlo.txt").exists()
        manifest = json.loads((out / "manifest.json").read_text())
        assert manifest["hotness_step"][0]["pages"] == 4096

    def test_hlo_text_parses_back(self):
        """Round-trip: the emitted text must parse back into an HloModule
        — the same parser the Rust runtime invokes via
        `HloModuleProto::from_text_file`. (Full execute-and-compare runs
        in the Rust integration test `xla_policy_cross_check`.)"""
        from jax._src.lib import xla_client as xc

        n = 4096
        text = aot.lower_policy_step(n)
        module = xc._xla.hlo_module_from_text(text)
        rendered = module.to_string()
        assert "f32[4096]" in rendered

    def test_lowered_output_matches_ref_semantics(self):
        """Execute the jitted (pre-AOT) graph and compare against ref —
        the computation being serialized is the computation we tested."""
        import jax

        n = 4096
        rng = np.random.default_rng(5)
        args = [
            rng.integers(0, 50, n).astype(np.float32),
            rng.integers(0, 50, n).astype(np.float32),
            rng.random(n).astype(np.float32),
            (rng.random(n) < 0.5).astype(np.float32),
        ]
        got = jax.jit(model.policy_step)(*args)
        want = hotness_step_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


class TestManifestConsistency:
    def test_sizes_match_rust_runtime(self):
        """HOTNESS_SIZES must mirror rust/src/runtime/mod.rs::ARTIFACT_SIZES."""
        rust_src = os.path.join(
            os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
            "rust", "src", "runtime", "mod.rs",
        )
        with open(rust_src) as f:
            content = f.read()
        for n in aot.HOTNESS_SIZES:
            assert str(n) in content, f"size {n} missing from Rust ARTIFACT_SIZES"
