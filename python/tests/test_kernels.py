"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; exact equality is expected
because interpret mode executes the same f32 ops in the same order.
"""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.hotness import hotness_step
from compile.kernels.latency import latency_model
from compile.kernels.ref import (HOTNESS_DECAY, NEG_INF, WRITE_WEIGHT,
                                 hotness_step_ref, latency_model_ref)

RNG = np.random.default_rng(42)


def _page_arrays(n, seed=0):
    rng = np.random.default_rng(seed)
    reads = rng.integers(0, 1000, n).astype(np.float32)
    writes = rng.integers(0, 500, n).astype(np.float32)
    prev = (rng.random(n) * 1e4).astype(np.float32)
    in_dram = (rng.random(n) < 0.3).astype(np.float32)
    return reads, writes, prev, in_dram


class TestHotnessKernel:
    def test_matches_ref_basic(self):
        arrs = _page_arrays(4096)
        got = hotness_step(*arrs)
        want = hotness_step_ref(*arrs)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_constants_match_rust(self):
        # Guard against drift vs rust/src/hmmu/policy/hotness.rs.
        assert HOTNESS_DECAY == 0.5
        assert WRITE_WEIGHT == 2.0
        assert NEG_INF == -1.0e30

    def test_known_values(self):
        reads = jnp.array([3.0] + [0.0] * 1023, dtype=jnp.float32)
        writes = jnp.array([1.0] + [0.0] * 1023, dtype=jnp.float32)
        prev = jnp.array([4.0] + [0.0] * 1023, dtype=jnp.float32)
        in_dram = jnp.zeros(1024, dtype=jnp.float32)
        hot, promote, demote = hotness_step(reads, writes, prev, in_dram)
        # 0.5*4 + 3 + 2*1 = 7 (mirrors the Rust unit test).
        assert float(hot[0]) == 7.0
        assert float(promote[0]) == 7.0
        assert float(demote[0]) == np.float32(NEG_INF)

    def test_dram_pages_masked(self):
        n = 2048
        reads, writes, prev, _ = _page_arrays(n, seed=1)
        in_dram = np.ones(n, dtype=np.float32)
        hot, promote, demote = hotness_step(reads, writes, prev, in_dram)
        assert np.all(np.asarray(promote) == np.float32(NEG_INF))
        np.testing.assert_array_equal(np.asarray(demote), -np.asarray(hot))

    @settings(max_examples=40, deadline=None)
    @given(
        nblocks=st.integers(min_value=1, max_value=16),
        block=st.sampled_from([8, 64, 128, 1024]),
        seed=st.integers(min_value=0, max_value=2**31),
        scale=st.sampled_from([1.0, 1e3, 1e6, 1e-3]),
    )
    def test_hypothesis_shapes_and_ranges(self, nblocks, block, seed, scale):
        n = nblocks * block
        rng = np.random.default_rng(seed)
        reads = (rng.random(n) * scale).astype(np.float32)
        writes = (rng.random(n) * scale).astype(np.float32)
        prev = (rng.random(n) * scale).astype(np.float32)
        in_dram = (rng.random(n) < 0.5).astype(np.float32)
        got = hotness_step(reads, writes, prev, in_dram, block=block)
        want = hotness_step_ref(reads, writes, prev, in_dram)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=0, atol=0)

    def test_rejects_non_multiple_of_block(self):
        with pytest.raises(AssertionError):
            hotness_step(
                jnp.zeros(1000), jnp.zeros(1000), jnp.zeros(1000), jnp.zeros(1000)
            )

    def test_zero_epoch_decays_only(self):
        n = 1024
        z = jnp.zeros(n, dtype=jnp.float32)
        prev = jnp.full(n, 64.0, dtype=jnp.float32)
        hot, _, _ = hotness_step(z, z, prev, z)
        assert np.all(np.asarray(hot) == 32.0)


class TestLatencyKernel:
    def test_matches_ref(self):
        n = 1024
        rng = np.random.default_rng(7)
        is_nvm = (rng.random(n) < 0.5).astype(np.float32)
        is_write = (rng.random(n) < 0.4).astype(np.float32)
        qd = rng.integers(0, 32, n).astype(np.float32)
        got = latency_model(is_nvm, is_write, qd)
        want = latency_model_ref(is_nvm, is_write, qd)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_nvm_write_is_slowest(self):
        z = np.zeros(256, dtype=np.float32)
        o = np.ones(256, dtype=np.float32)
        dram_read = np.asarray(latency_model(z, z, z))[0]
        nvm_read = np.asarray(latency_model(o, z, z))[0]
        nvm_write = np.asarray(latency_model(o, o, z))[0]
        assert dram_read < nvm_read < nvm_write

    def test_queue_depth_adds_service(self):
        z = np.zeros(256, dtype=np.float32)
        qd = np.full(256, 10.0, dtype=np.float32)
        base = np.asarray(latency_model(z, z, z))[0]
        queued = np.asarray(latency_model(z, z, qd))[0]
        assert queued == pytest.approx(base + 180.0)

    @settings(max_examples=25, deadline=None)
    @given(
        nblocks=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, nblocks, seed):
        n = nblocks * 256
        rng = np.random.default_rng(seed)
        is_nvm = (rng.random(n) < 0.5).astype(np.float32)
        is_write = (rng.random(n) < 0.5).astype(np.float32)
        qd = rng.integers(0, 64, n).astype(np.float32)
        got = latency_model(is_nvm, is_write, qd)
        want = latency_model_ref(is_nvm, is_write, qd)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_custom_params_flow_through(self):
        z = np.zeros(256, dtype=np.float32)
        o = np.ones(256, dtype=np.float32)
        got = latency_model(o, z, z, dram_rt_ns=10.0, pcie_rtt_ns=0.0,
                            nvm_read_stall_ns=90.0, service_ns=0.0)
        assert np.asarray(got)[0] == pytest.approx(100.0)
