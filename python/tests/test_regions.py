"""Region-aggregation kernel vs a pure-numpy oracle, plus classifier
semantics (the paper's §III-A 'memory access pattern recognition')."""

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from compile.kernels.ref import HOTNESS_DECAY, WRITE_WEIGHT
from compile.kernels.regions import classify_regions, region_stats


def oracle(reads, writes, prev, r):
    n = len(reads)
    regions = n // r
    sr = reads.reshape(regions, r).sum(axis=1)
    sw = writes.reshape(regions, r).sum(axis=1)
    hot = HOTNESS_DECAY * prev + (reads + WRITE_WEIGHT * writes)
    mh = hot.reshape(regions, r).max(axis=1)
    return sr, sw, mh


class TestRegionStats:
    def test_matches_oracle(self):
        rng = np.random.default_rng(1)
        n, r = 4096, 256
        reads = rng.integers(0, 100, n).astype(np.float32)
        writes = rng.integers(0, 100, n).astype(np.float32)
        prev = rng.random(n).astype(np.float32) * 100
        got = region_stats(reads, writes, prev)
        want = oracle(reads, writes, prev, r)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), w, rtol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        regions=st.integers(min_value=1, max_value=8),
        r=st.sampled_from([8, 64, 256]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, regions, r, seed):
        rng = np.random.default_rng(seed)
        n = regions * r
        reads = (rng.random(n) * 50).astype(np.float32)
        writes = (rng.random(n) * 50).astype(np.float32)
        prev = (rng.random(n) * 10).astype(np.float32)
        got = region_stats(reads, writes, prev, pages_per_region=r)
        want = oracle(reads, writes, prev, r)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), w, rtol=1e-4)

    def test_output_shapes(self):
        z = np.zeros(2048, dtype=np.float32)
        sr, sw, mh = region_stats(z, z, z)
        assert sr.shape == (8,)
        assert sw.shape == (8,)
        assert mh.shape == (8,)


class TestClassifier:
    def test_classes(self):
        # region 0: cold; 1: streaming; 2: hot-spot; 3: write-burst
        sum_reads = np.array([0.0, 100.0, 100.0, 10.0], dtype=np.float32)
        sum_writes = np.array([0.0, 10.0, 10.0, 100.0], dtype=np.float32)
        max_hot = np.array([0.0, 2.0, 90.0, 5.0], dtype=np.float32)
        cls = np.asarray(classify_regions(sum_reads, sum_writes, max_hot))
        assert list(cls) == [0, 1, 2, 3]

    def test_uniform_stream_not_hotspot(self):
        # 256 pages each read ~4x: max_hot ~ 4 << 0.25 * total.
        n, r = 1024, 256
        reads = np.full(n, 4.0, dtype=np.float32)
        z = np.zeros(n, dtype=np.float32)
        sr, sw, mh = region_stats(reads, z, z, pages_per_region=r)
        cls = np.asarray(classify_regions(sr, sw, mh))
        assert np.all(cls == 1)
