"""L2 model tests: graph shapes, dtypes, composability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels.ref import hotness_step_ref


class TestPolicyStep:
    def test_output_arity_and_shapes(self):
        n = 4096
        z = jnp.zeros(n, dtype=jnp.float32)
        out = model.policy_step(z, z, z, z)
        assert len(out) == 3
        for o in out:
            assert o.shape == (n,)
            assert o.dtype == jnp.float32

    def test_jit_matches_eager(self):
        n = 2048
        rng = np.random.default_rng(3)
        args = [
            rng.random(n).astype(np.float32) * 100,
            rng.random(n).astype(np.float32) * 50,
            rng.random(n).astype(np.float32) * 10,
            (rng.random(n) < 0.5).astype(np.float32),
        ]
        eager = model.policy_step(*args)
        jitted = jax.jit(model.policy_step)(*args)
        for e, j in zip(eager, jitted):
            np.testing.assert_array_equal(np.asarray(e), np.asarray(j))

    def test_matches_reference_end_to_end(self):
        n = 8192
        rng = np.random.default_rng(11)
        args = [
            rng.integers(0, 100, n).astype(np.float32),
            rng.integers(0, 100, n).astype(np.float32),
            rng.random(n).astype(np.float32) * 1e3,
            (rng.random(n) < 0.25).astype(np.float32),
        ]
        got = model.policy_step(*args)
        want = hotness_step_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_top_candidate_selection_semantics(self):
        """The Rust coordinator picks argmax(promote) and argmax(demote);
        verify those semantics survive the graph."""
        n = 1024
        reads = np.zeros(n, dtype=np.float32)
        reads[7] = 500.0   # hottest page, NVM-resident
        reads[3] = 100.0   # warm DRAM page
        in_dram = np.zeros(n, dtype=np.float32)
        in_dram[3] = 1.0
        in_dram[5] = 1.0   # cold DRAM page -> demotion victim
        z = np.zeros(n, dtype=np.float32)
        hot, promote, demote = model.policy_step(reads, z, z, in_dram)
        assert int(np.argmax(np.asarray(promote))) == 7
        # Demote scores: only DRAM pages participate; coldest wins.
        d = np.asarray(demote)
        assert int(np.argmax(d)) == 5


class TestLatencyEstimate:
    def test_tuple_output(self):
        n = 1024
        z = jnp.zeros(n, dtype=jnp.float32)
        out = model.latency_estimate(z, z, z)
        assert isinstance(out, tuple) and len(out) == 1
        assert out[0].shape == (n,)
