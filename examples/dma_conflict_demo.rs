//! DMA conflict walkthrough — the paper's §III-C + §III-D mechanisms on
//! display: a page swap is started, and memory requests race it at
//! different offsets and times; the demo prints which device each request
//! was routed to and why, plus the Fig 3 tag-matching scenario.
//!
//! ```bash
//! cargo run --release --example dma_conflict_demo
//! ```

use hymem::hmmu::dma::{DmaEngine, DmaRoute};
use hymem::hmmu::redirection::{Device, Mapping};
use hymem::hmmu::TagMatcher;

fn main() {
    println!("=== §III-D: DMA page swap with conflicting requests ===\n");
    let mut dma = DmaEngine::new(512, 4096, false);
    let map_nvm = Mapping {
        device: Device::Nvm,
        frame: 42,
    };
    let map_dram = Mapping {
        device: Device::Dram,
        frame: 7,
    };
    // Swap host page 100 (hot, in NVM) with host page 3 (cold, in DRAM).
    let done = dma.start_swap(100, map_nvm, 3, map_dram, 0, &mut |dev, _a, k, _b, at| {
        // NVM reads/writes slower than DRAM, per Table I.
        at + match (dev, k.is_write()) {
            (Device::Dram, false) => 30,
            (Device::Dram, true) => 35,
            (Device::Nvm, false) => 80,
            _ => 260,
        }
    });
    println!("swap(page 100 <-> page 3) started at t=0, completes at t={done}ns");
    println!("8 sub-blocks of 512B each (paper: 'data is transferred in units of 512B-block')\n");

    println!(
        "{:>6} {:>8} {:>22} {:>10}",
        "t(ns)", "offset", "route", "serviced-by"
    );
    for (t, offset) in [
        (0u64, 0u64),        // block 0 in flight
        (0, 3584),           // block 7 untouched
        (done / 2, 0),       // block 0 long committed
        (done / 2, 2048),    // middle of the swap
        (done / 2, 3584),    // tail still pending
        (done + 1, 3584),    // swap complete
    ] {
        let (route, swap) = dma.route(100, offset, t);
        let (label, dev) = match route {
            DmaRoute::NotInvolved => ("not involved".to_string(), "table".to_string()),
            DmaRoute::UseOriginal => (
                "ahead of progress -> original".to_string(),
                format!("{:?}", swap.unwrap().original(100).device),
            ),
            DmaRoute::UseDestination => (
                "behind progress -> destination".to_string(),
                format!("{:?}", swap.unwrap().destination(100).device),
            ),
            DmaRoute::Stall(until) => (
                format!("in-flight block, stall to {until}"),
                format!("{:?}", swap.unwrap().destination(100).device),
            ),
        };
        println!("{t:>6} {offset:>8} {label:>22} {dev:>10}");
    }

    println!("\n=== §III-C / Fig 3: memory consistency via tag matching ===\n");
    let mut tm = TagMatcher::new(8);
    let req0 = tm.issue(); // -> NVM, slow
    let req1 = tm.issue(); // -> DRAM, fast
    println!("req0 (tag {req0}) -> NVM,  media completes at t=300ns");
    println!("req1 (tag {req1}) -> DRAM, media completes at t=50ns (earlier!)");
    let r1 = tm.complete(req1, 50);
    println!("  at t=50:  DRAM data back; drained so far: {r1:?} (held — req0 is FIFO head)");
    let r0 = tm.complete(req0, 300);
    println!("  at t=300: NVM data back; drained: {r0:?}");
    println!(
        "  -> both responses released in request order; req1 waited {}ns for consistency",
        tm.reorder_wait_ns
    );

    println!("\n=== write-during-swap correctness ===\n");
    let mut dma2 = DmaEngine::new(512, 4096, false);
    let done2 = dma2.start_swap(100, map_nvm, 3, map_dram, 0, &mut |_d, _a, k, _b, at| {
        at + if k.is_write() { 40 } else { 30 }
    });
    let probe = done2 / 3;
    let (route, _) = dma2.route(100, 3584, probe);
    println!(
        "write to not-yet-copied block at t={probe}: routed {:?} — lands in the source \
         frame and will be carried over when its block is copied",
        route
    );
    let (route, _) = dma2.route(100, 0, probe);
    println!(
        "write to already-copied block at t={probe}:  routed {:?} — the copy in the \
         destination is the live one",
        route
    );
    println!("\n({} conflict stalls recorded by the engine)", dma2.conflict_stalls);
}
