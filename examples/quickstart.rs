//! Quickstart: run one SPEC-like workload on the emulation platform and
//! print the full report.
//!
//! ```bash
//! cargo run --release --example quickstart -- [workload] [ops]
//! ```

use hymem::config::SystemConfig;
use hymem::platform::{Platform, RunOpts};
use hymem::workload::spec;

fn main() -> hymem::util::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let wl_name = args.first().map(|s| s.as_str()).unwrap_or("505.mcf");
    let ops: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(500_000);

    let wl = spec::by_name(wl_name)
        .ok_or_else(|| hymem::anyhow!("unknown workload {wl_name}"))?;

    // Table II at 1/16 scale: 8 MiB DRAM + 64 MiB emulated 3D XPoint.
    let cfg = SystemConfig::default_scaled(16);
    println!("=== configuration ===\n{}\n", cfg.show());

    let report = Platform::new(cfg).run_opts(
        &wl,
        RunOpts {
            ops,
            flush_at_end: false,
        },
    )?;
    println!("=== run report ===\n{}", report.detail());
    println!(
        "\nFig 7 datapoint: {} slows down {:.2}x on the PCIe-attached \
         hybrid platform (paper geomean: 3.17x)",
        wl.name,
        report.slowdown()
    );
    Ok(())
}
