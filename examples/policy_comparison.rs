//! Policy comparison: the experiment the paper's platform was built to
//! enable — evaluate data placement/migration policies against each
//! other on the same workload.
//!
//! Compares static / first-touch / hotness-migration on slowdown, DRAM
//! service ratio, NVM wear and estimated dynamic energy.
//!
//! ```bash
//! cargo run --release --example policy_comparison -- [workload] [ops]
//! ```

use hymem::config::{PolicyKind, SystemConfig};
use hymem::platform::{Platform, RunOpts};
use hymem::workload::spec;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let wl_name = args.first().map(|s| s.as_str()).unwrap_or("520.omnetpp");
    let ops: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(800_000);
    let wl = spec::by_name(wl_name)
        .ok_or_else(|| anyhow::anyhow!("unknown workload {wl_name}"))?;

    println!("=== policy comparison on {} ({} mem-ops) ===\n", wl.name, ops);
    println!(
        "{:<12} {:>9} {:>10} {:>12} {:>10} {:>10} {:>9}",
        "policy", "slowdown", "dram-serv", "migrations", "nvm-wear", "energy", "p99(ns)"
    );

    for kind in [
        PolicyKind::Static,
        PolicyKind::FirstTouch,
        PolicyKind::Hotness,
        PolicyKind::WearAware,
    ] {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = kind;
        let r = Platform::new(cfg).run_opts(
            &wl,
            RunOpts {
                ops,
                flush_at_end: false,
            },
        )?;
        println!(
            "{:<12} {:>8.2}x {:>9.1}% {:>12} {:>10} {:>8.1}mJ {:>9}",
            kind.name(),
            r.slowdown(),
            r.counters.dram_service_ratio() * 100.0,
            r.counters.migrations,
            r.nvm_max_wear,
            r.counters.energy_estimate_mj(),
            r.counters.latency.percentile(99.0),
        );
    }

    println!(
        "\nExpected shape: hotness > first-touch > static on DRAM service \
         ratio for working sets larger than DRAM; migration trades DMA \
         traffic for locality; wear-aware trades a little locality for a \
         lower NVM max-wear (endurance, Table I)."
    );
    Ok(())
}
