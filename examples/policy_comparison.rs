//! Policy comparison: the experiment the paper's platform was built to
//! enable — evaluate data placement/migration policies against each
//! other on the same workload.
//!
//! Compares static / first-touch / hotness-migration / wear-aware on
//! slowdown, DRAM service ratio, NVM wear and estimated dynamic energy.
//! The four policy runs are independent scenarios, so they go through the
//! parallel sweep engine — one thread each, bit-identical to serial.
//!
//! ```bash
//! cargo run --release --example policy_comparison -- [workload] [ops]
//! ```

use hymem::config::{PolicyKind, SystemConfig};
use hymem::sweep::{run_sweep, Scenario};
use hymem::workload::spec;

fn main() -> hymem::util::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let wl_name = args.first().map(|s| s.as_str()).unwrap_or("520.omnetpp");
    let ops: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(800_000);
    let wl = spec::by_name(wl_name)
        .ok_or_else(|| hymem::anyhow!("unknown workload {wl_name}"))?;

    println!("=== policy comparison on {} ({} mem-ops) ===\n", wl.name, ops);
    println!(
        "{:<12} {:>9} {:>10} {:>12} {:>10} {:>10} {:>9}",
        "policy", "slowdown", "dram-serv", "migrations", "nvm-wear", "energy", "p99(ns)"
    );

    let policies = [
        PolicyKind::Static,
        PolicyKind::FirstTouch,
        PolicyKind::Hotness,
        PolicyKind::WearAware,
    ];
    let base = SystemConfig::default_scaled(16);
    let scenarios = Scenario::grid(&[wl], &policies, &base, ops);
    let report = run_sweep(&scenarios, policies.len())?;

    for r in &report.scenarios {
        println!(
            "{:<12} {:>8.2}x {:>9.1}% {:>12} {:>10} {:>8.1}mJ {:>9}",
            r.policy,
            r.slowdown,
            r.dram_service_ratio * 100.0,
            r.migrations,
            r.nvm_max_wear,
            r.energy_mj,
            r.latency_p99_ns,
        );
    }
    println!(
        "\n{} scenarios in {:.2}x less wall time than serial",
        report.scenarios.len(),
        report.parallel_speedup()
    );

    println!(
        "\nExpected shape: hotness > first-touch > static on DRAM service \
         ratio for working sets larger than DRAM; migration trades DMA \
         traffic for locality; wear-aware trades a little locality for a \
         lower NVM max-wear (endurance, Table I)."
    );
    Ok(())
}
