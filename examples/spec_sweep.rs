//! End-to-end driver: the full system on the full Table III workload set.
//!
//! This is the repository's E2E validation run (EXPERIMENTS.md): all
//! three layers compose — synthetic SPEC traces → A57 core + caches →
//! PCIe link → HMMU (hotness policy through the **AOT XLA artifact** when
//! present) → DRAM/NVM timing models — and the Fig 7 + Fig 8 data come
//! out the other side, with the gem5-like / champsim-like baselines
//! measured on a sample for the speedup headline.
//!
//! ```bash
//! make artifacts && cargo run --release --example spec_sweep
//! ```

use hymem::baselines::run_fig7_row;
use hymem::config::SystemConfig;
use hymem::platform::{Platform, RunOpts};
use hymem::runtime::XlaHotnessEngine;
use hymem::util::stats::geomean;
use hymem::util::units::fmt_bytes;
use hymem::workload::WORKLOADS;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ops: u64 = args
        .first()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let baseline_instr: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200_000);

    let cfg = SystemConfig::default_scaled(16);

    // Engine: the AOT XLA policy step if artifacts exist.
    let engine_label = match XlaHotnessEngine::load_default() {
        Ok(e) => {
            println!(
                "XLA policy engine loaded (variants: {:?})",
                e.variant_sizes()
            );
            "xla-aot"
        }
        Err(e) => {
            println!("XLA artifacts unavailable ({e}); using native engine");
            "native"
        }
    };

    println!("\n=== E2E sweep: 12 workloads, policy=hotness/{engine_label}, {ops} mem-ops each ===\n");

    let mut slowdowns = Vec::new();
    let mut fig8: Vec<(String, u64, u64)> = Vec::new();
    for wl in &WORKLOADS {
        let mut p = Platform::new(cfg.clone());
        if let Ok(e) = XlaHotnessEngine::load_default() {
            p = p.with_engine(Box::new(e));
        }
        let r = p.run_opts(
            wl,
            RunOpts {
                ops,
                flush_at_end: false,
            },
        )?;
        println!("{}", r.summary());
        slowdowns.push(r.slowdown());
        let (rb, wb) = r.fig8_scaled();
        fig8.push((wl.name.to_string(), rb, wb));
    }
    let geo = geomean(&slowdowns);
    println!("\nFig 7 (ours): geomean slowdown {geo:.2}x  (paper: 3.17x)");

    println!("\n=== Fig 8: memory request volume (scaled to paper size) ===");
    println!("(run lengths proportional to full-benchmark memory-op counts)");
    println!("{:<16} {:>12} {:>12}", "workload", "read", "write");
    fig8.clear();
    for (wl, wl_ops) in hymem::workload::proportional_ops(ops) {
        let r = Platform::new(cfg.clone()).run_opts(
            &wl,
            RunOpts {
                ops: wl_ops,
                // flush residual dirty lines so write-back volume is
                // counted, as a full-benchmark run would see (Fig 8 has
                // writes ~ reads).
                flush_at_end: true,
            },
        )?;
        let (rb, wb) = r.fig8_scaled();
        fig8.push((wl.name.to_string(), rb, wb));
    }
    for (name, rb, wb) in &fig8 {
        println!("{:<16} {:>12} {:>12}", name, fmt_bytes(*rb), fmt_bytes(*wb));
    }
    fig8.sort_by_key(|r| std::cmp::Reverse(r.1 + r.2));
    println!(
        "volume order: max={} min={} (paper: mcf max, imagick min)",
        fig8.first().unwrap().0,
        fig8.last().unwrap().0
    );

    // Baseline comparison on a representative subset (full set via
    // `hymem fig7` / the fig7 bench; they are slow by design).
    println!("\n=== baseline spot-check (sampled {baseline_instr} instructions) ===");
    let mut ours = Vec::new();
    let mut champ = Vec::new();
    let mut gem5 = Vec::new();
    for name in ["505.mcf", "538.imagick", "557.xz"] {
        let wl = hymem::workload::spec::by_name(name).unwrap();
        let row = run_fig7_row(&cfg, &wl, ops.min(200_000), baseline_instr)?;
        println!(
            "{:<16} ours {:>6.2}x   champsim-like {:>8.0}x   gem5-like {:>8.0}x",
            row.workload, row.ours, row.champsim, row.gem5
        );
        ours.push(row.ours);
        champ.push(row.champsim);
        gem5.push(row.gem5);
    }
    println!(
        "speedup vs gem5-like {:.0}x (paper 9280x), vs champsim-like {:.0}x (paper 2286x)",
        geomean(&gem5) / geomean(&ours),
        geomean(&champ) / geomean(&ours)
    );
    Ok(())
}
