//! End-to-end driver: the full system on the full Table III workload set.
//!
//! This is the repository's E2E validation run (EXPERIMENTS.md): all
//! three layers compose — synthetic SPEC traces → A57 core + caches →
//! PCIe link → HMMU (hotness policy; through the **AOT XLA artifact**
//! when built with `--features xla`) → DRAM/NVM timing models — and the
//! Fig 7 + Fig 8 data come out the other side, with the gem5-like /
//! champsim-like baselines measured on a sample for the speedup headline.
//!
//! The 12-workload sweep runs through the **parallel sweep engine**
//! (`hymem::sweep`): one scenario per workload, fanned across all cores,
//! bit-identical to a serial run, with the machine-readable report in
//! `BENCH_sweep.json`.
//!
//! ```bash
//! cargo run --release --example spec_sweep [-- ops [baseline_instr]]
//! ```

use hymem::baselines::run_fig7_row;
use hymem::config::SystemConfig;
use hymem::platform::{Platform, RunOpts};
use hymem::runtime::XlaHotnessEngine;
use hymem::sweep::{default_threads, run_sweep, Scenario};
use hymem::util::stats::geomean;
use hymem::util::units::{fmt_bytes, fmt_ns};
use hymem::workload::WORKLOADS;

fn main() -> hymem::util::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ops: u64 = args
        .first()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let baseline_instr: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200_000);

    let cfg = SystemConfig::default_scaled(16);

    // Sweep scenarios always run the native engine (it is bit-compatible
    // with the XLA artifact, so the numbers are identical); note artifact
    // availability for the reader without mislabeling the run.
    match XlaHotnessEngine::load_default() {
        Ok(e) => println!(
            "XLA policy engine available (variants: {:?}); sweep scenarios use the \
             bit-compatible native engine — run `hymem run` for the artifact path",
            e.variant_sizes()
        ),
        Err(e) => println!("XLA artifacts unavailable ({e}); using native engine"),
    }

    let threads = default_threads();
    println!(
        "\n=== E2E sweep: 12 workloads, policy=hotness/native, {ops} mem-ops each, \
         {threads} threads ===\n"
    );

    let scenarios: Vec<Scenario> = WORKLOADS
        .iter()
        .map(|wl| Scenario::new(format!("{}/hotness", wl.name), *wl, cfg.clone(), ops))
        .collect();
    let report = run_sweep(&scenarios, threads)?;
    println!("{}", report.summary());
    println!(
        "\nFig 7 (ours): geomean slowdown {:.2}x  (paper: 3.17x)",
        report.geomean_slowdown
    );
    println!(
        "sweep wall {} vs serial-equivalent {} => {:.2}x parallel speedup",
        fmt_ns(report.wall_ns),
        fmt_ns(report.serial_wall_ns),
        report.parallel_speedup()
    );
    report.write_json("BENCH_sweep.json")?;
    println!("wrote BENCH_sweep.json");

    println!("\n=== Fig 8: memory request volume (scaled to paper size) ===");
    println!("(run lengths proportional to full-benchmark memory-op counts)");
    println!("{:<16} {:>12} {:>12}", "workload", "read", "write");
    let mut fig8: Vec<(String, u64, u64)> = Vec::new();
    for (wl, wl_ops) in hymem::workload::proportional_ops(ops) {
        let r = Platform::new(cfg.clone()).run_opts(
            &wl,
            RunOpts {
                ops: wl_ops,
                // flush residual dirty lines so write-back volume is
                // counted, as a full-benchmark run would see (Fig 8 has
                // writes ~ reads).
                flush_at_end: true,
            },
        )?;
        let (rb, wb) = r.fig8_scaled();
        fig8.push((wl.name.to_string(), rb, wb));
    }
    for (name, rb, wb) in &fig8 {
        println!("{:<16} {:>12} {:>12}", name, fmt_bytes(*rb), fmt_bytes(*wb));
    }
    fig8.sort_by_key(|r| std::cmp::Reverse(r.1 + r.2));
    println!(
        "volume order: max={} min={} (paper: mcf max, imagick min)",
        fig8.first().unwrap().0,
        fig8.last().unwrap().0
    );

    // Baseline comparison on a representative subset (full set via
    // `hymem fig7` / the fig7 bench; they are slow by design).
    println!("\n=== baseline spot-check (sampled {baseline_instr} instructions) ===");
    let mut ours = Vec::new();
    let mut champ = Vec::new();
    let mut gem5 = Vec::new();
    for name in ["505.mcf", "538.imagick", "557.xz"] {
        let wl = hymem::workload::spec::by_name(name).unwrap();
        let row = run_fig7_row(&cfg, &wl, ops.min(200_000), baseline_instr)?;
        println!(
            "{:<16} ours {:>6.2}x   champsim-like {:>8.0}x   gem5-like {:>8.0}x",
            row.workload, row.ours, row.champsim, row.gem5
        );
        ours.push(row.ours);
        champ.push(row.champsim);
        gem5.push(row.gem5);
    }
    println!(
        "speedup vs gem5-like {:.0}x (paper 9280x), vs champsim-like {:.0}x (paper 2286x)",
        geomean(&gem5) / geomean(&ours),
        geomean(&champ) / geomean(&ours)
    );
    Ok(())
}
