//! Table I technology sweep — the platform's "arbitrary latency cycles"
//! flexibility (§III-F): swap the emulated NVM among FLASH / 3D XPoint /
//! DRAM / STT-RAM / MRAM and watch the application-level impact.
//!
//! ```bash
//! cargo run --release --example latency_sensitivity -- [workload] [ops]
//! ```

use hymem::config::{MemTech, SystemConfig, TechPreset};
use hymem::platform::{Platform, RunOpts};
use hymem::workload::spec;

fn main() -> hymem::util::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let wl_name = args.first().map(|s| s.as_str()).unwrap_or("505.mcf");
    let ops: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(400_000);
    let wl = spec::by_name(wl_name)
        .ok_or_else(|| hymem::anyhow!("unknown workload {wl_name}"))?;

    println!("=== NVM technology sensitivity: {} ===\n", wl.name);
    println!(
        "{:<12} {:>8} {:>8} {:>11} {:>11} {:>10} {:>12}",
        "tech", "rd(ns)", "wr(ns)", "rd-stall", "wr-stall", "slowdown", "p99-lat(ns)"
    );

    for tech in MemTech::ALL {
        let preset = TechPreset::of(tech);
        let cfg = SystemConfig::default_scaled(16).with_tech(tech);
        let (rs, ws) = (cfg.nvm.read_stall_ns, cfg.nvm.write_stall_ns);
        let r = Platform::new(cfg).run_opts(
            &wl,
            RunOpts {
                ops,
                flush_at_end: false,
            },
        )?;
        println!(
            "{:<12} {:>8} {:>8} {:>11} {:>11} {:>9.2}x {:>12}",
            tech.name(),
            preset.read_ns,
            preset.write_ns,
            rs,
            ws,
            r.slowdown(),
            r.counters.latency.percentile(99.0),
        );
    }

    println!(
        "\nExpected shape: FLASH is unusable as main memory; 3D XPoint \
         costs a moderate factor; STT-RAM/MRAM are DRAM-class (stalls \
         clamp at 0). This regenerates the Table I comparison as an \
         application-level experiment."
    );
    Ok(())
}
