//! Multi-programmed multicore run — the LS2085A has 8 A57 cores all
//! served by one PCIe link and one HMMU. This example runs a mixed
//! rate-style bundle and reports per-core times plus shared-resource
//! contention, then sweeps core count to show the link saturating.
//!
//! ```bash
//! cargo run --release --example multiprogram -- [ops-per-core]
//! ```

use hymem::config::SystemConfig;
use hymem::platform::{run_multicore, RunOpts};
use hymem::workload::spec;

fn main() -> hymem::util::error::Result<()> {
    let ops: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);
    let cfg = SystemConfig::default_scaled(16);
    let opts = RunOpts {
        ops,
        flush_at_end: false,
    };

    // A mixed bundle: two memory hogs, two compute-bound.
    let bundle = [
        spec::by_name("505.mcf").unwrap(),
        spec::by_name("557.xz").unwrap(),
        spec::by_name("538.imagick").unwrap(),
        spec::by_name("525.x264").unwrap(),
    ];
    println!("=== 4-core mixed bundle ({} mem-ops/core) ===\n", ops);
    let r = run_multicore(cfg.clone(), &bundle, opts, None)?;
    print!("{}", r.summary());
    println!(
        "  shared-resource pressure: {} PCIe credit stalls, {} HDR FIFO stalls\n",
        r.pcie_credit_stalls, r.fifo_full_stalls
    );

    // Scaling sweep: N copies of mcf hammering the shared HMMU.
    println!("=== scaling: N x 505.mcf through one HMMU ===\n");
    println!(
        "{:>6} {:>14} {:>16} {:>14} {:>12}",
        "cores", "makespan", "aggregate MIPS", "credit-stalls", "fifo-stalls"
    );
    let mcf = spec::by_name("505.mcf").unwrap();
    for n in [1usize, 2, 4, 8] {
        let wls = vec![mcf; n];
        let r = run_multicore(cfg.clone(), &wls, opts, None)?;
        println!(
            "{:>6} {:>11} ms {:>16.1} {:>14} {:>12}",
            n,
            r.makespan_ns / 1_000_000,
            r.aggregate_mips,
            r.pcie_credit_stalls,
            r.fifo_full_stalls
        );
    }
    println!(
        "\nExpected shape: aggregate MIPS grows sub-linearly as the shared \
         PCIe link and HMMU pipeline saturate — the contention the paper's \
         single-link platform would exhibit with all 8 cores active."
    );
    Ok(())
}
