#!/usr/bin/env python3
"""Perf gate over the BENCH_*.json snapshots.

Usage: check_bench_gate.py [BENCH_hot_path.json | BENCH_sweep_fork.json | ...]
       check_bench_gate.py --list-pairs   # dump the registry, tab-separated

Two kinds of gated pairs:

- Block-batched paths (BENCH_hot_path.json) must not be slower than their
  per-op counterparts. The tolerance absorbs run-to-run noise — wider when
  the snapshot came from the quick CI smoke (short budgets, shared
  runners; the JSON records `"quick": true`) — while a real regression,
  the block path losing its amortization, shows up far below either bar.
- The warm-state forked sweep (BENCH_sweep_fork.json) must be *strictly*
  faster than cold replay of the same 8-point grid: the fork skips ~3/4
  of the simulation volume, so any ratio <= 1.0 means the checkpoint
  engine stopped paying for itself.

Pairs whose rows are absent from the given file are skipped (each JSON
carries only its own suite), but a file matching no known pair fails, as
does a pair with only one row present. The trajectory itself is archived
per run as a CI artifact.
"""

import json
import sys

TOLERANCE = 0.95
QUICK_TOLERANCE = 0.85

# (baseline row, improved row, required ratio or None = noise tolerance)
PAIRS = [
    ("trace_gen/per-op (batch 4096)", "trace_gen/fill_block (batch 4096)", None),
    ("platform_step/per-op (batch 4096)", "platform_step/block (batch 4096)", None),
    ("hierarchy_access/per-op (batch 4096)", "hierarchy_access/block (batch 4096)", None),
    ("pcie_link/per-op (batch 4096)", "pcie_link/block (batch 4096)", None),
    ("hierarchy_flush/per-op (batch 4096)", "hierarchy_flush/block (batch 4096)", None),
    ("hmmu_accounting/per-op (batch 4096)", "hmmu_accounting/block (batch 4096)", None),
    # Fault layer default-off must stay free: the healthy path may not run
    # slower than the faulted one (off/on >= tolerance; off is normally
    # faster, so only a hook-cost regression can trip this).
    ("fault_check/on (batch 4096)", "fault_check/off (batch 4096)", None),
    # Row-buffer charging is opt-in: the legacy flat-stall path may not
    # run slower than the row-aware one (flat/rowbuf >= tolerance; flat
    # skips the row-buffer outcome bookkeeping, so only a regression on
    # the default path can trip this).
    ("tier_access/rowbuf (batch 4096)", "tier_access/flat (batch 4096)", None),
    # Strict: forked sweep must beat cold replay outright (ratio > 1.0).
    ("sweep/cold (8-point grid)", "sweep/forked (8-point grid)", 1.0),
    # Sharding must be free on the uncontended fast path: the sharded
    # table may not run slower than the 1-shard (monolithic) build on
    # the identical translate+swap churn.
    ("redirection/mono (translate+swap mix)", "redirection/sharded (translate+swap mix)", None),
    # Fanning a warm group's members across the pool may not lose to
    # forking them serially (it normally wins ~Nx on the tails; the
    # noise tolerance absorbs starved 1-2 vCPU runners).
    ("sweep_group/serial (6-member group)", "sweep_group/parallel (6-member group)", None),
]


def main() -> int:
    if "--list-pairs" in sys.argv[1:]:
        # Machine-readable pair registry (one "base<TAB>fast" per line);
        # consumed by the hymem-audit bench-pair rule.
        for base_name, fast_name, _required in PAIRS:
            print(f"{base_name}\t{fast_name}")
        return 0
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_hot_path.json"
    with open(path) as f:
        data = json.load(f)
    rows = {r["name"]: r for r in data["results"]}
    tolerance = QUICK_TOLERANCE if data.get("quick") else TOLERANCE

    failed = False
    checked = 0
    for base_name, fast_name, required in PAIRS:
        present = [n for n in (base_name, fast_name) if n in rows]
        if not present:
            continue  # pair belongs to another suite's JSON
        if len(present) == 1:
            print(f"FAIL: {path} has {present[0]!r} but not its pair row")
            failed = True
            continue
        base = rows[base_name].get("throughput_per_sec")
        fast = rows[fast_name].get("throughput_per_sec")
        if not base or not fast:
            print(f"FAIL: no throughput recorded for {base_name!r} / {fast_name!r}")
            failed = True
            continue
        bar = required if required is not None else tolerance
        strict = required is not None
        ratio = fast / base
        ok = ratio > bar if strict else ratio >= bar
        verdict = "ok" if ok else "REGRESSION"
        print(
            f"{verdict}: {fast_name} {fast:,.0f}/s vs "
            f"{base_name} {base:,.0f}/s (ratio = {ratio:.2f}x, "
            f"bar {'>' if strict else '>='} {bar}x)"
        )
        if not ok:
            failed = True
        checked += 1

    if checked == 0:
        print(f"FAIL: {path} matched no known bench pairs")
        failed = True
    if failed:
        print("bench gate failed")
        return 1
    print(f"bench gate passed ({checked} pairs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
