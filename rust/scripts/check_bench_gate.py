#!/usr/bin/env python3
"""Perf gate over BENCH_hot_path.json: the block-batched paths must not be
slower than their per-op counterparts.

Usage: check_bench_gate.py [BENCH_hot_path.json]

Compares the throughput of each (per-op, block) row pair and fails (exit 1)
if a block row falls below the tolerance x the per-op row. The tolerance
absorbs run-to-run noise — wider when the snapshot came from the quick CI
smoke (short budgets, shared runners; the JSON records `"quick": true`) —
while a real regression, the block path losing its amortization, shows up
far below either bar. The trajectory itself is archived per run as a CI
artifact.
"""

import json
import sys

TOLERANCE = 0.95
QUICK_TOLERANCE = 0.85

PAIRS = [
    ("trace_gen/per-op (batch 4096)", "trace_gen/fill_block (batch 4096)"),
    ("platform_step/per-op (batch 4096)", "platform_step/block (batch 4096)"),
    ("hierarchy_access/per-op (batch 4096)", "hierarchy_access/block (batch 4096)"),
    ("pcie_link/per-op (batch 4096)", "pcie_link/block (batch 4096)"),
    ("hierarchy_flush/per-op (batch 4096)", "hierarchy_flush/block (batch 4096)"),
]


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_hot_path.json"
    with open(path) as f:
        data = json.load(f)
    rows = {r["name"]: r for r in data["results"]}
    tolerance = QUICK_TOLERANCE if data.get("quick") else TOLERANCE

    failed = False
    for per_op_name, block_name in PAIRS:
        missing = [n for n in (per_op_name, block_name) if n not in rows]
        if missing:
            print(f"FAIL: missing bench rows: {missing}")
            failed = True
            continue
        per_op = rows[per_op_name].get("throughput_per_sec")
        block = rows[block_name].get("throughput_per_sec")
        if not per_op or not block:
            print(f"FAIL: no throughput recorded for {per_op_name!r} / {block_name!r}")
            failed = True
            continue
        ratio = block / per_op
        verdict = "ok" if ratio >= tolerance else "REGRESSION"
        print(
            f"{verdict}: {block_name} {block:,.0f}/s vs "
            f"{per_op_name} {per_op:,.0f}/s (block/per-op = {ratio:.2f}x)"
        )
        if ratio < tolerance:
            failed = True

    if failed:
        print(f"bench gate failed: block path slower than per-op (tolerance {tolerance}x)")
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
