//! Sharded vs monolithic redirection table on the single-threaded fast
//! path.
//!
//! The shard layer must be free when nobody contends: both rows drive
//! the identical translate-heavy churn (the per-access hot path, plus
//! cross-shard swaps at migration-ish frequency) through a 1-shard
//! (monolithic) and a `DEFAULT_SHARDS` table. The property battery
//! (`tests/redirection_shard_props.rs`) pins them bit-identical; this
//! pair pins the sharded side not-slower (scripts/check_bench_gate.py
//! on BENCH_redirection.json).

use hymem::hmmu::redirection::DEFAULT_SHARDS;
use hymem::hmmu::RedirectionTable;
use hymem::util::bench::BenchSuite;

/// 64K pages (256 MiB of 4 KiB pages), DRAM half the footprint so the
/// stack holds a realistic mix of fast- and slow-tier mappings.
const HOST_PAGES: u64 = 1 << 16;
const FRAMES: [u32; 2] = [1 << 15, 1 << 16];
/// Table ops per measured batch: 15 translates per swap, roughly the
/// migration rate a hotness epoch sustains against its access stream.
const BATCH: u64 = 160_000;

fn splitmix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn churn_row(suite: &mut BenchSuite, name: &str, nshards: usize) {
    let mut table = RedirectionTable::new_with_shards(HOST_PAGES, &FRAMES, 4096, nshards);
    table.identity_map();
    let mut seed = 0x5EED ^ nshards as u64;
    let mut sink = 0u64;
    suite.bench_items(name, BATCH, || {
        let mut ops = 0u64;
        while ops < BATCH {
            for _ in 0..15 {
                let addr = (splitmix(&mut seed) % HOST_PAGES) * 4096 + 128;
                if let Some((_, dev_addr)) = table.translate(addr) {
                    sink ^= dev_addr;
                }
            }
            let a = splitmix(&mut seed) % HOST_PAGES;
            let b = splitmix(&mut seed) % HOST_PAGES;
            if a != b {
                table.swap(a, b).unwrap();
            }
            ops += 16;
        }
        std::hint::black_box(sink);
        BATCH
    });
    table.check_invariants().expect("churn must preserve invariants");
}

fn main() {
    let mut suite = BenchSuite::new("redirection table: monolithic vs sharded fast path");
    suite.header();

    churn_row(&mut suite, "redirection/mono (translate+swap mix)", 1);
    churn_row(&mut suite, "redirection/sharded (translate+swap mix)", DEFAULT_SHARDS);

    suite
        .write_json("BENCH_redirection.json")
        .expect("writing BENCH_redirection.json");
    suite.finish();
}
