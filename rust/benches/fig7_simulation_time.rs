//! Fig 7 regeneration: simulation time normalized against native
//! execution, for all 12 Table III workloads × {ours, champsim-like,
//! gem5-like}, plus the geomean row and the headline speedup ratios.
//!
//! `cargo bench --bench fig7_simulation_time` (add `-- --quick` for a
//! fast pass).

use hymem::baselines::run_fig7_row;
use hymem::config::SystemConfig;
use hymem::util::bench::BenchSuite;
use hymem::util::stats::geomean;
use hymem::workload::WORKLOADS;

fn main() {
    let suite = BenchSuite::new("Fig 7: simulation slowdown vs native");
    suite.header();
    let (ops, binstr) = if suite.quick() {
        (60_000, 40_000)
    } else {
        (400_000, 250_000)
    };
    let cfg = SystemConfig::default_scaled(16);

    suite.report_row(&format!(
        "{:<16} {:>10} {:>14} {:>12}",
        "workload", "ours", "champsim-like", "gem5-like"
    ));
    let (mut ours, mut champ, mut gem5) = (Vec::new(), Vec::new(), Vec::new());
    for wl in &WORKLOADS {
        let row = run_fig7_row(&cfg, wl, ops, binstr).expect("fig7 row");
        suite.report_row(&format!(
            "{:<16} {:>9.2}x {:>13.0}x {:>11.0}x",
            row.workload, row.ours, row.champsim, row.gem5
        ));
        ours.push(row.ours);
        champ.push(row.champsim);
        gem5.push(row.gem5);
    }
    let (go, gc, gg) = (geomean(&ours), geomean(&champ), geomean(&gem5));
    suite.report_row(&format!(
        "{:<16} {:>9.2}x {:>13.0}x {:>11.0}x   paper: 3.17x / 7,241x / 29,398x",
        "geomean", go, gc, gg
    ));
    suite.report_row(&format!(
        "headline: speedup vs gem5-like {:.0}x (paper 9,280x); vs champsim-like {:.0}x (paper 2,286x)",
        gg / go,
        gc / go
    ));
    suite.report_row(&format!(
        "shape checks: ours single-digit geomean: {}; ordering gem5>champ>ours: {}",
        go < 10.0,
        gg > gc && gc > go
    ));

    // The paper's other alternative (§II): analytical modeling — instant
    // but inaccurate. Report its per-workload slowdown error vs the
    // platform simulation.
    suite.report_row("--- analytical model (paper §II: 'large impact on accuracy') ---");
    suite.report_row(&format!(
        "{:<16} {:>10} {:>12} {:>8}",
        "workload", "predicted", "simulated", "error"
    ));
    let model = hymem::baselines::AnalyticalModel::new(cfg.clone());
    for wl in &WORKLOADS {
        let r = hymem::platform::Platform::new(cfg.clone())
            .run_opts(
                wl,
                hymem::platform::RunOpts {
                    ops,
                    flush_at_end: false,
                },
            )
            .expect("run");
        let p = model.predict(wl, r.instructions);
        let err = (p.slowdown - r.slowdown()) / r.slowdown() * 100.0;
        suite.report_row(&format!(
            "{:<16} {:>9.2}x {:>11.2}x {:>+7.0}%",
            wl.name,
            p.slowdown,
            r.slowdown(),
            err
        ));
    }
    suite.finish();
}
