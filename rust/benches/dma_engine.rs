//! DMA engine benchmarks + the paper's design-parameter discussion
//! (§III-D: "the choice of these two primary design parameters, bit width
//! and buffer size"): sweep block size and buffering mode, reporting swap
//! latency and throughput.

use hymem::hmmu::dma::DmaEngine;
use hymem::hmmu::redirection::{Device, Mapping};
use hymem::util::bench::BenchSuite;

fn maps() -> (Mapping, Mapping) {
    (
        Mapping {
            device: Device::Nvm,
            frame: 5,
        },
        Mapping {
            device: Device::Dram,
            frame: 9,
        },
    )
}

fn main() {
    let suite = BenchSuite::new("DMA engine: block size x buffering sweep");
    suite.header();

    // Modeled swap latency per configuration (paper parameter study).
    suite.report_row(&format!(
        "{:<24} {:>14} {:>16}",
        "config", "swap latency", "modeled MB/s"
    ));
    for &block in &[128u64, 256, 512, 1024, 2048] {
        for pipelined in [false, true] {
            let mut dma = DmaEngine::new(block, 4096, pipelined);
            let (ma, mb) = maps();
            let done = dma.start_swap(1, ma, 2, mb, 0, &mut |_d, _a, k, _b, at| {
                // DRAM-ish read 30ns / write 40ns + per-block overhead.
                at + if k.is_write() { 40 } else { 30 }
            });
            let mbps = (2.0 * 4096.0) / (done as f64 / 1e9) / 1e6;
            suite.report_row(&format!(
                "{:<24} {:>11} ns {:>13.0} MB/s",
                format!("block={block}B pipelined={pipelined}"),
                done,
                mbps
            ));
        }
    }
    suite.report_row("paper default: 512B blocks; pipelined requires 2x block buffer (8KiB ok)");

    // Host-time throughput of the swap machinery.
    let mut host = BenchSuite::new("DMA engine: host-time throughput");
    host.header();
    {
        let mut dma = DmaEngine::new(512, 4096, true);
        let (ma, mb) = maps();
        let mut t = 0u64;
        let mut next_page = 0u64;
        host.bench_items("start_swap+drain (batch 100)", 100, || {
            for _ in 0..100 {
                let pa = next_page;
                let pb = next_page + 1;
                next_page += 2;
                t = dma.start_swap(pa, ma, pb, mb, t, &mut |_d, _a, _k, _b, at| at + 35);
                dma.drain_committed(t);
            }
            100
        });
        let mut dma2 = DmaEngine::new(512, 4096, true);
        let done = dma2.start_swap(1, ma, 2, mb, 0, &mut |_d, _a, _k, _b, at| at + 35);
        host.bench_items("route probe during swap (batch 10K)", 10_000, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                let (r, _) = dma2.route(1 + (i % 2), (i * 64) % 4096, (i * 7) % done);
                acc += matches!(r, hymem::hmmu::DmaRoute::UseDestination) as u64;
            }
            std::hint::black_box(acc);
            10_000
        });
    }
    host.finish();
    suite.finish();
}
