//! Warm-state fork vs cold replay: the sweep-level win the checkpoint
//! engine exists for.
//!
//! Both rows run the identical 8-point design grid (2 workloads × 2
//! policies × 2 NVM stall points, 2 warm groups) through the same
//! warm+morph code path and produce bit-identical modeled results
//! (`tests/checkpoint_fork.rs`); the only difference is who pays the
//! warm-up. Cold replay re-simulates the warm prefix for every scenario
//! (8 × warm + 8 × tail); the forked row pays it once per warm group
//! (2 × warm + 8 × tail). With warm 20K of a 24K-op run the forked
//! sweep does ~2.7× less simulation — CI gates forked strictly faster
//! than cold (scripts/check_bench_gate.py on BENCH_sweep_fork.json).

use hymem::config::{PolicyKind, SystemConfig};
use hymem::sweep::{run_sweep_forked, ForkOpts, Scenario};
use hymem::util::bench::BenchSuite;
use hymem::workload::spec;

const OPS: u64 = 24_000;
const WARM: u64 = 20_000;

fn grid() -> Vec<Scenario> {
    let mut base = SystemConfig::default_scaled(64);
    base.hmmu.epoch_requests = 2_000;
    let workloads = [
        spec::by_name("505.mcf").unwrap(),
        spec::by_name("557.xz").unwrap(),
    ];
    let policies = [PolicyKind::Static, PolicyKind::Hotness];
    let grid = Scenario::grid(&workloads, &policies, &base, OPS);
    Scenario::stall_grid(&grid, &[(50, 225), (400, 1_800)])
}

fn main() {
    let mut suite = BenchSuite::new("sweep: warm-state fork vs cold replay");
    suite.header();

    let scenarios = grid();
    assert_eq!(scenarios.len(), 8);
    // Items = modeled ops the *grid* represents (scenarios × ops), the
    // same for both rows — so the throughput ratio is exactly the
    // wall-clock ratio on identical logical work. Single worker thread:
    // the rows measure simulation volume, not scheduling.
    let grid_ops = scenarios.len() as u64 * OPS;

    let cold = ForkOpts {
        warmup_ops: WARM,
        checkpoint_dir: None,
        cold_replay: true,
    };
    suite.bench_items("sweep/cold (8-point grid)", grid_ops, || {
        let r = run_sweep_forked(&scenarios, 1, &cold).unwrap();
        assert_eq!(r.scenarios.len(), 8);
        grid_ops
    });

    let forked = ForkOpts {
        warmup_ops: WARM,
        checkpoint_dir: None,
        cold_replay: false,
    };
    suite.bench_items("sweep/forked (8-point grid)", grid_ops, || {
        let r = run_sweep_forked(&scenarios, 1, &forked).unwrap();
        assert_eq!(r.scenarios.len(), 8);
        grid_ops
    });

    // Intra-group fork parallelism: one warm group × 6 members (policy
    // and stall are fork axes, so a single workload is a single group).
    // Serial forks the members on one worker; parallel fans the same
    // members across 4 workers after the one shared warm-up. Results
    // are bit-identical (`tests/checkpoint_fork.rs`); the gate pins the
    // parallel row not-slower.
    let members = {
        let mut base = SystemConfig::default_scaled(64);
        base.hmmu.epoch_requests = 2_000;
        let grid = Scenario::grid(
            &[spec::by_name("505.mcf").unwrap()],
            &[PolicyKind::Static, PolicyKind::Hotness],
            &base,
            OPS,
        );
        Scenario::stall_grid(&grid, &[(50, 225), (200, 900), (400, 1_800)])
    };
    assert_eq!(members.len(), 6);
    let member_ops = members.len() as u64 * OPS;
    suite.bench_items("sweep_group/serial (6-member group)", member_ops, || {
        let r = run_sweep_forked(&members, 1, &forked).unwrap();
        assert_eq!(r.scenarios.len(), 6);
        member_ops
    });
    suite.bench_items("sweep_group/parallel (6-member group)", member_ops, || {
        let r = run_sweep_forked(&members, 4, &forked).unwrap();
        assert_eq!(r.scenarios.len(), 6);
        member_ops
    });

    suite
        .write_json("BENCH_sweep_fork.json")
        .expect("writing BENCH_sweep_fork.json");
    suite.finish();
}
