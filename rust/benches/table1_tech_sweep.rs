//! Table I regeneration: the §III-F "arbitrary latency cycles" mechanism
//! swept across every memory technology in Table I, reporting the derived
//! stall cycles and the application-level slowdown each produces.

use hymem::config::{MemTech, SystemConfig, TechPreset};
use hymem::mem::{AccessKind, DramDevice, MemDevice};
use hymem::platform::{Platform, RunOpts};
use hymem::sim::Clock;
use hymem::util::bench::BenchSuite;
use hymem::workload::spec;

fn main() {
    let suite = BenchSuite::new("Table I: technology presets & latency emulation");
    suite.header();
    let ops = if suite.quick() { 50_000 } else { 300_000 };

    // §III-F step 1: measured DRAM round trip in FPGA cycles.
    let base_cfg = SystemConfig::default_scaled(16);
    let mut dram = DramDevice::new(base_cfg.dram);
    let (rt, _) = dram.access(0, AccessKind::Read, 64, 0);
    let fpga = Clock::from_mhz(base_cfg.hmmu.fpga_freq_mhz);
    suite.report_row(&format!(
        "measured DRAM round trip: {rt} ns = {} FPGA cycles @ {} MHz",
        fpga.ns_to_cycles(rt),
        base_cfg.hmmu.fpga_freq_mhz
    ));
    suite.report_row(&format!(
        "{:<12} {:>9} {:>9} {:>12} {:>12} {:>10} {:>10}",
        "tech", "rd(ns)", "wr(ns)", "rd-stall(cy)", "wr-stall(cy)", "mcf", "imagick"
    ));

    for tech in MemTech::ALL {
        let p = TechPreset::of(tech);
        let mut slow = Vec::new();
        for wl_name in ["505.mcf", "538.imagick"] {
            let cfg = SystemConfig::default_scaled(16).with_tech(tech);
            let r = Platform::new(cfg)
                .run_opts(
                    &spec::by_name(wl_name).unwrap(),
                    RunOpts {
                        ops,
                        flush_at_end: false,
                    },
                )
                .expect("run");
            slow.push(r.slowdown());
        }
        suite.report_row(&format!(
            "{:<12} {:>9} {:>9} {:>12} {:>12} {:>9.2}x {:>9.2}x",
            tech.name(),
            p.read_ns,
            p.write_ns,
            fpga.ns_to_cycles(p.read_stall_ns(rt)),
            fpga.ns_to_cycles(p.write_stall_ns(rt)),
            slow[0],
            slow[1]
        ));
    }
    suite.report_row(
        "shape checks: FLASH unusable (huge slowdown); STT-RAM/MRAM ~ DRAM (0 stalls); \
         3D XPoint intermediate",
    );
    suite.finish();
}
