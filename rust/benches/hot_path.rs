//! Hot-path microbenchmarks: the HMMU request pipeline and its
//! components. The §Perf target (DESIGN.md) is ≥10 M modeled requests/s
//! through the full HMMU so the emulator is never the experiment
//! bottleneck.

use hymem::config::{PolicyKind, SystemConfig};
use hymem::cpu::{BlockOutcomes, CacheHierarchy, CoreModel, MemBackend};
use hymem::hmmu::policy::{HotnessEngine, HotnessPolicy, NativeHotnessEngine, PlacementPolicy};
use hymem::hmmu::{build_policy, Hmmu, TagMatcher};
use hymem::mem::AccessKind;
use hymem::pcie::{PcieLink, TlpColumn, TlpKind};
use hymem::platform::HmmuBackend;
use hymem::sim::Time;
use hymem::util::bench::BenchSuite;
use hymem::util::rng::Xoshiro256;
use hymem::workload::{spec, TraceBlock, TraceGenerator, TRACE_BLOCK_OPS};

fn main() {
    let mut suite = BenchSuite::new("hot path: HMMU pipeline components");
    suite.header();

    // Full HMMU request path (static policy: pure routing).
    {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Static;
        let mut hmmu = Hmmu::new(cfg.clone(), None);
        let mut rng = Xoshiro256::new(1);
        let total = cfg.total_mem_bytes();
        let mut t = 0u64;
        suite.bench_items("hmmu_access/static (batch 10K)", 10_000, || {
            for _ in 0..10_000 {
                let addr = rng.below(total) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                t = hmmu.access(addr, kind, 64, t + 20);
            }
            10_000
        });
    }

    // Full HMMU with hotness policy + migrations.
    {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 50_000;
        let mut hmmu = Hmmu::new(cfg.clone(), None);
        let mut rng = Xoshiro256::new(2);
        let total = cfg.total_mem_bytes();
        let mut t = 0u64;
        suite.bench_items("hmmu_access/hotness (batch 10K)", 10_000, || {
            for _ in 0..10_000 {
                let addr = (rng.zipf(total / 4096, 1.1)) * 4096 + rng.below(4096) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                t = hmmu.access(addr, kind, 64, t + 20);
            }
            10_000
        });
    }

    // Tag matcher alone.
    {
        let mut tm = TagMatcher::new(64);
        let mut rng = Xoshiro256::new(3);
        suite.bench_items("tag_matcher issue+complete (batch 10K)", 10_000, || {
            for i in 0..10_000u64 {
                if !tm.can_issue() {
                    continue;
                }
                let tag = tm.issue();
                let _ = tm.complete(tag, i * 10 + rng.below(200));
            }
            10_000
        });
    }

    // PCIe link send path.
    {
        let cfg = SystemConfig::default_scaled(16);
        let mut link = PcieLink::new(cfg.pcie);
        let mut t = 0u64;
        suite.bench_items("pcie send_to_device+host (batch 10K)", 10_000, || {
            for _ in 0..10_000 {
                t += 100;
                let a = link.send_to_device(0, t);
                let b = link.send_to_host(64, a + 50);
                link.hold_credit_until(b);
            }
            10_000
        });
    }

    // Per-op vs block: the PCIe link crossing. Both rows push the same
    // recorded traffic mix (60% MRd round trips, 40% posted MWr, monotone
    // issue times, fixed device service) through the link; the block row
    // crosses the whole column in one `send_block_to_device` pass
    // (coalescing off, so the work is bit-identical — the ratio isolates
    // the batching: one call per column, memoized serialization, heap
    // credit gate drained per batch). CI gates block ≥ per-op
    // (scripts/check_bench_gate.py).
    {
        let cfg = SystemConfig::default_scaled(16);
        let ops = TRACE_BLOCK_OPS as u64;
        let mut rng = Xoshiro256::new(6);
        let mut entries = Vec::with_capacity(TRACE_BLOCK_OPS);
        let mut col = TlpColumn::new();
        let mut t = 0u64;
        for _ in 0..TRACE_BLOCK_OPS {
            t += 20;
            let addr = rng.below(1 << 30) & !63;
            let kind = if rng.chance(0.6) {
                TlpKind::MRd
            } else {
                TlpKind::MWr
            };
            entries.push((kind, t));
            col.push(kind, addr, 64, t);
        }

        let mut link = PcieLink::new(cfg.pcie);
        suite.bench_items("pcie_link/per-op (batch 4096)", ops, || {
            for &(kind, at) in &entries {
                if kind == TlpKind::MRd {
                    let a = link.send_to_device(0, at);
                    let b = link.send_to_host(64, a + 180);
                    link.hold_credit_until(b);
                } else {
                    let a = link.send_to_device(64, at);
                    link.hold_credit_until(a + 120);
                }
            }
            ops
        });

        let mut link = PcieLink::new(cfg.pcie);
        let mut completions = Vec::new();
        suite.bench_items("pcie_link/block (batch 4096)", ops, || {
            link.send_block_to_device(
                &col,
                &mut |_l, j, arrive| {
                    arrive + if col.kind(j) == TlpKind::MRd { 180 } else { 120 }
                },
                &mut completions,
            );
            ops
        });
    }

    // Trace generation alone (must never dominate).
    {
        let wl = spec::by_name("505.mcf").unwrap();
        let mut gen = TraceGenerator::new(wl, 16, 42);
        suite.bench_items("trace_generator next (batch 10K)", 10_000, || {
            for _ in 0..10_000 {
                let _ = gen.next();
            }
            10_000
        });
    }

    // Per-op vs block: trace generation. The block path amortizes the
    // per-op iterator call into one `fill_block` per 4096 ops writing
    // straight into recycled struct-of-arrays buffers.
    {
        let wl = spec::by_name("505.mcf").unwrap();
        let mut gen = TraceGenerator::new(wl, 16, 42);
        let ops = TRACE_BLOCK_OPS as u64;
        suite.bench_items("trace_gen/per-op (batch 4096)", ops, || {
            for _ in 0..TRACE_BLOCK_OPS {
                let _ = gen.next();
            }
            ops
        });
        let mut gen = TraceGenerator::new(wl, 16, 42);
        let mut block = TraceBlock::new();
        suite.bench_items("trace_gen/fill_block (batch 4096)", ops, || {
            gen.fill_block(&mut block) as u64
        });
    }

    // Per-op vs block: the full platform inner loop (generator → core →
    // L1/L2 → PCIe+HMMU). This is the pipeline `Platform::run_opts` and
    // the sweep engine now drive in blocks; the per-op row is the old
    // iterator loop kept for the before/after delta.
    {
        let wl = spec::by_name("505.mcf").unwrap();
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Static;
        let ops = TRACE_BLOCK_OPS as u64;

        let mut backend = HmmuBackend::new(cfg.clone(), None);
        let mut core = CoreModel::new(cfg.cpu);
        let mut hier = CacheHierarchy::new(&cfg);
        let mut gen = TraceGenerator::new(wl, cfg.scale, 42);
        suite.bench_items("platform_step/per-op (batch 4096)", ops, || {
            for _ in 0..TRACE_BLOCK_OPS {
                let op = gen.next().unwrap();
                core.step(&op, &mut hier, &mut backend);
            }
            ops
        });

        let mut backend = HmmuBackend::new(cfg.clone(), None);
        let mut core = CoreModel::new(cfg.cpu);
        let mut hier = CacheHierarchy::new(&cfg);
        let mut gen = TraceGenerator::new(wl, cfg.scale, 42);
        let mut block = TraceBlock::new();
        suite.bench_items("platform_step/block (batch 4096)", ops, || {
            let n = gen.fill_block(&mut block) as u64;
            core.step_block(&block, &mut hier, &mut backend);
            n
        });
    }

    // Per-op vs block: the cache filter alone (TLB + L1 + L2 in front of
    // a fixed-latency backend, isolating the hierarchy's tag probes from
    // HMMU/PCIe modeling). `hierarchy_access/block` runs the multi-probe
    // `access_block` and drains the recorded backend traffic exactly as
    // `CoreModel::step_block` does, so both rows do identical modeling
    // work on identical op streams; the items/s ratio is the block-lookup
    // speedup. CI fails if the block row is slower than per-op
    // (scripts/check_bench_gate.py).
    {
        struct FixedBackend {
            latency: u64,
        }
        impl MemBackend for FixedBackend {
            fn access(&mut self, _a: u64, _k: AccessKind, _b: u64, now: Time) -> Time {
                now + self.latency
            }
        }

        let wl = spec::by_name("505.mcf").unwrap();
        let cfg = SystemConfig::default_scaled(16);
        let ops = TRACE_BLOCK_OPS as u64;

        let mut hier = CacheHierarchy::new(&cfg);
        let mut backend = FixedBackend { latency: 300 };
        let mut gen = TraceGenerator::new(wl, cfg.scale, 42);
        let mut block = TraceBlock::new();
        suite.bench_items("hierarchy_access/per-op (batch 4096)", ops, || {
            let n = gen.fill_block(&mut block) as u64;
            let mut t = 0u64;
            for op in block.iter() {
                let out = hier.access(op.addr, op.is_write, t, &mut backend);
                t += 20 + out.latency_ns / 8;
            }
            n
        });

        let mut hier = CacheHierarchy::new(&cfg);
        let mut backend = FixedBackend { latency: 300 };
        let mut gen = TraceGenerator::new(wl, cfg.scale, 42);
        let mut outcomes = BlockOutcomes::new();
        suite.bench_items("hierarchy_access/block (batch 4096)", ops, || {
            let n = gen.fill_block(&mut block) as u64;
            hier.access_block(&block, &mut outcomes);
            // Drain the recorded traffic through the same `issue` replay
            // `step_block` uses.
            let mut t = 0u64;
            let mut wr = 0usize;
            let mut rd = 0usize;
            for i in 0..outcomes.len() {
                let mut latency = outcomes.latency_ns(i);
                if let Some(done) = outcomes.issue(i, &mut wr, &mut rd, &mut backend, t) {
                    latency += done - t;
                }
                t += 20 + latency / 8;
            }
            n
        });
    }

    // SoA vs AoS tag layout (§Perf satellite): the multi-probe loop of
    // `Cache::access_block` scans way-major contiguous tag columns; the
    // AoS baseline below replicates the pre-SoA 24-byte line-struct
    // layout with the identical loop structure, so the throughput delta
    // isolates the layout. L2 geometry (16 ways) — the widest probe in
    // the stack, where the flat tag slice matters most. Results are
    // bit-identical by construction (same victim select, same order);
    // the unit/equivalence tests pin it.
    {
        #[derive(Clone, Copy, Default)]
        struct AosLine {
            tag: u64,
            valid: bool,
            dirty: bool,
            lru: u64,
        }
        struct AosCache {
            sets: usize,
            ways: usize,
            line_shift: u32,
            lines: Vec<AosLine>,
            tick: u64,
            hits: u64,
            misses: u64,
        }
        impl AosCache {
            fn access_block(&mut self, addrs: &[u64], flags: &[u8]) {
                let mut tick = self.tick;
                let mut hits = 0u64;
                let mut misses = 0u64;
                let set_mask = self.sets - 1;
                let set_shift = self.sets.trailing_zeros();
                'ops: for (&addr, &f) in addrs.iter().zip(flags) {
                    tick += 1;
                    let is_write = f & 1 != 0;
                    let line = addr >> self.line_shift;
                    let set = (line as usize) & set_mask;
                    let tag = line >> set_shift;
                    let base = set * self.ways;
                    for l in &mut self.lines[base..base + self.ways] {
                        if l.valid && l.tag == tag {
                            l.lru = tick;
                            l.dirty |= is_write;
                            hits += 1;
                            continue 'ops;
                        }
                    }
                    misses += 1;
                    let ways = &mut self.lines[base..base + self.ways];
                    let victim = ways
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, l)| if l.valid { l.lru } else { 0 })
                        .map(|(w, _)| w)
                        .unwrap();
                    ways[victim] = AosLine {
                        tag,
                        valid: true,
                        dirty: is_write,
                        lru: tick,
                    };
                }
                self.tick = tick;
                self.hits += hits;
                self.misses += misses;
            }
        }

        let cfg = SystemConfig::default_scaled(16);
        let ops = TRACE_BLOCK_OPS as u64;
        let wl = spec::by_name("505.mcf").unwrap();
        let mut gen = TraceGenerator::new(wl, cfg.scale, 7);
        let mut addrs = Vec::with_capacity(TRACE_BLOCK_OPS);
        let mut flags = Vec::with_capacity(TRACE_BLOCK_OPS);
        for i in 0..TRACE_BLOCK_OPS {
            let op = gen.next().unwrap();
            addrs.push(op.addr);
            flags.push((i % 3 == 0) as u8);
        }

        let sets = cfg.l2.sets() as usize;
        let mut aos = AosCache {
            sets,
            ways: cfg.l2.ways as usize,
            line_shift: cfg.l2.line_bytes.trailing_zeros(),
            lines: vec![AosLine::default(); sets * cfg.l2.ways as usize],
            tick: 0,
            hits: 0,
            misses: 0,
        };
        suite.bench_items("cache_tags/aos (L2 probe, batch 4096)", ops, || {
            aos.access_block(&addrs, &flags);
            ops
        });

        let mut soa = hymem::cpu::cache::Cache::new(cfg.l2);
        let mut misses = Vec::new();
        suite.bench_items("cache_tags/soa (L2 probe, batch 4096)", ops, || {
            misses.clear();
            soa.access_block(&addrs, &flags, 1, &mut misses);
            ops
        });
        // Keep the baseline observable (incl. the dirty bits) so the
        // optimizer cannot discard its state updates.
        assert!(aos.hits + aos.misses > 0);
        assert!(aos.lines.iter().any(|l| l.dirty), "stores must dirty lines");
    }

    // End-of-run flush: per-op vs column-ized drain (§Perf satellite).
    // Each iteration re-dirties 4096 L2 lines **directly** (cheap tag
    // ops via `fill_writeback`, no backend traffic — so the timed work
    // is dominated by the flush itself), then writes every dirty line
    // back through the real PCIe+HMMU backend: the per-op row replays
    // the pre-columnization flush loop, the block row is the production
    // `CacheHierarchy::flush` (one `issue_block_op` column through the
    // batched link crossing). CI gates block ≥ per-op
    // (scripts/check_bench_gate.py).
    {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Static;
        let ops = TRACE_BLOCK_OPS as u64;

        fn dirty(hier: &mut CacheHierarchy) {
            for i in 0..TRACE_BLOCK_OPS as u64 {
                // 4096 distinct lines across 1024 pages; fits the 1 MiB
                // L2 with no evictions.
                let addr = (i * 4096) % (1 << 22) + (i % 4) * 64;
                let _ = hier.l2.fill_writeback(addr);
            }
        }

        let mut backend = HmmuBackend::new(cfg.clone(), None);
        let mut hier = CacheHierarchy::new(&cfg);
        let mut t = 0u64;
        suite.bench_items("hierarchy_flush/per-op (batch 4096)", ops, || {
            dirty(&mut hier);
            t += 100_000;
            // The pre-columnization per-op flush loop.
            for wb in hier.l1d.flush() {
                if let Some(wb2) = hier.l2.fill_writeback(wb) {
                    hier.mem_writes += 1;
                    backend.access(wb2, AccessKind::Write, 64, t);
                }
            }
            for addr in hier.l2.flush() {
                hier.mem_writes += 1;
                backend.access(addr, AccessKind::Write, 64, t);
            }
            ops
        });

        let mut backend = HmmuBackend::new(cfg.clone(), None);
        let mut hier = CacheHierarchy::new(&cfg);
        let mut t = 0u64;
        suite.bench_items("hierarchy_flush/block (batch 4096)", ops, || {
            dirty(&mut hier);
            t += 100_000;
            hier.flush(t, &mut backend);
            ops
        });
    }

    // Per-op vs block: policy + per-tier accounting (§Perf satellite).
    // Identical zipf request streams through the full HMMU with the
    // hotness policy; the block row brackets each 4096-op batch with
    // `begin_block`/`end_block`, so record_access + record_tier_access
    // defer into the pending queue and drain in one tight loop per block
    // instead of interleaving policy-state touches with routing. Results
    // are bit-identical (every reader sits behind a flush point;
    // `tests/batch_equivalence.rs` pins the per-op vs block paths). CI
    // gates block ≥ per-op (scripts/check_bench_gate.py).
    {
        fn accounting_hmmu() -> (Hmmu, u64) {
            let mut cfg = SystemConfig::default_scaled(16);
            cfg.policy = PolicyKind::Hotness;
            cfg.hmmu.epoch_requests = 50_000;
            let total = cfg.total_mem_bytes();
            (Hmmu::new(cfg, None), total)
        }
        let ops = TRACE_BLOCK_OPS as u64;

        let (mut hmmu, total) = accounting_hmmu();
        let mut rng = Xoshiro256::new(8);
        let mut t = 0u64;
        suite.bench_items("hmmu_accounting/per-op (batch 4096)", ops, || {
            for _ in 0..TRACE_BLOCK_OPS {
                let addr = (rng.zipf(total / 4096, 1.1)) * 4096 + rng.below(4096) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                t = hmmu.access(addr, kind, 64, t + 20);
            }
            ops
        });

        let (mut hmmu, total) = accounting_hmmu();
        let mut rng = Xoshiro256::new(8);
        let mut t = 0u64;
        suite.bench_items("hmmu_accounting/block (batch 4096)", ops, || {
            hmmu.begin_block();
            for _ in 0..TRACE_BLOCK_OPS {
                let addr = (rng.zipf(total / 4096, 1.1)) * 4096 + rng.below(4096) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                t = hmmu.access(addr, kind, 64, t + 20);
            }
            hmmu.end_block();
            ops
        });
    }

    // Fault layer off vs on (robustness satellite): identical zipf
    // streams through the full HMMU; the `off` row is today's healthy
    // hot path (the fault hook reduces to one branch on a disabled
    // config), the `on` row pays the per-access RBER draw plus ECC
    // charging. CI gates off ≥ 0.95× on (scripts/check_bench_gate.py) so
    // the default-off hook stays free.
    {
        fn fault_hmmu(rber: f64) -> (Hmmu, u64) {
            let mut cfg = SystemConfig::default_scaled(16);
            cfg.policy = PolicyKind::Hotness;
            cfg.hmmu.epoch_requests = 50_000;
            cfg.fault.rber_base = rber;
            cfg.fault.uncorrectable_frac = 0.0; // ECC-corrected only: no retirement churn
            let total = cfg.total_mem_bytes();
            (Hmmu::new(cfg, None), total)
        }
        let ops = TRACE_BLOCK_OPS as u64;

        let (mut hmmu, total) = fault_hmmu(0.0);
        let mut rng = Xoshiro256::new(9);
        let mut t = 0u64;
        suite.bench_items("fault_check/off (batch 4096)", ops, || {
            for _ in 0..TRACE_BLOCK_OPS {
                let addr = (rng.zipf(total / 4096, 1.1)) * 4096 + rng.below(4096) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                t = hmmu.access(addr, kind, 64, t + 20);
            }
            ops
        });

        let (mut hmmu, total) = fault_hmmu(1e-4);
        let mut rng = Xoshiro256::new(9);
        let mut t = 0u64;
        suite.bench_items("fault_check/on (batch 4096)", ops, || {
            for _ in 0..TRACE_BLOCK_OPS {
                let addr = (rng.zipf(total / 4096, 1.1)) * 4096 + rng.below(4096) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                t = hmmu.access(addr, kind, 64, t + 20);
            }
            ops
        });
    }

    // Flat vs row-buffer-aware stall charging on one tier device (the
    // row-buffer satellite): identical zipf access streams through a
    // PCM-class `TierDevice` built flat and built row-aware. The rowbuf
    // row pays the per-access row-buffer outcome branch; the flat row is
    // the legacy default path and must not regress — CI gates
    // flat ≥ 0.95× rowbuf (scripts/check_bench_gate.py).
    {
        use hymem::config::{MemTech, TierSpec};
        use hymem::mem::{MemDevice, TierDevice};

        let cfg = SystemConfig::default_scaled(16);
        let ops = TRACE_BLOCK_OPS as u64;
        let spec = TierSpec::of(MemTech::Pcm, cfg.nvm.size_bytes, 28);
        let size = spec.size_bytes;

        let mut dev = TierDevice::build(&spec, cfg.dram, cfg.hmmu.page_bytes);
        let mut rng = Xoshiro256::new(10);
        let mut t = 0u64;
        suite.bench_items("tier_access/flat (batch 4096)", ops, || {
            for _ in 0..TRACE_BLOCK_OPS {
                let addr = (rng.zipf(size / 4096, 1.1)) * 4096 + rng.below(4096) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                let (done, _) = dev.access(addr, kind, 64, t + 20);
                t = done;
            }
            ops
        });
        assert!(dev.stats().reads > 0);

        let mut dev = TierDevice::build(&spec.with_row_buffer(), cfg.dram, cfg.hmmu.page_bytes);
        let mut rng = Xoshiro256::new(10);
        let mut t = 0u64;
        suite.bench_items("tier_access/rowbuf (batch 4096)", ops, || {
            for _ in 0..TRACE_BLOCK_OPS {
                let addr = (rng.zipf(size / 4096, 1.1)) * 4096 + rng.below(4096) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                let (done, _) = dev.access(addr, kind, 64, t + 20);
                t = done;
            }
            ops
        });
        assert!(dev.stats().row_hits + dev.stats().row_misses > 0);
    }

    // Tiled hotness step (the epoch-boundary dense pass; HOTNESS_TILE
    // chunks, auto-vectorized inner loop).
    {
        let pages = 16_384usize;
        let mut rng = Xoshiro256::new(5);
        let reads: Vec<f32> = (0..pages).map(|_| rng.below(64) as f32).collect();
        let writes: Vec<f32> = (0..pages).map(|_| rng.below(16) as f32).collect();
        let prev: Vec<f32> = (0..pages).map(|_| rng.below(512) as f32 / 4.0).collect();
        let in_dram: Vec<f32> = (0..pages).map(|_| rng.below(2) as f32).collect();
        let mut engine = NativeHotnessEngine;
        suite.bench_items("hotness_step/tiled (16K pages)", pages as u64, || {
            let out = engine.step(&reads, &writes, &prev, &in_dram);
            out.hotness.len() as u64
        });
    }

    // De-virtualization before/after: the old `Box<dyn PlacementPolicy>`
    // vtable dispatch vs the enum-dispatched `PolicyImpl` the HMMU now
    // uses on its per-request path (place + record_access).
    {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Hotness;
        let pages = cfg.total_pages();
        let mut boxed: Box<dyn PlacementPolicy> = Box::new(HotnessPolicy::new(
            pages,
            Box::new(NativeHotnessEngine),
        ));
        let mut rng = Xoshiro256::new(4);
        suite.bench_items("policy_dispatch/boxed-dyn (batch 10K)", 10_000, || {
            for i in 0..10_000u64 {
                boxed.record_access(rng.below(pages), i % 3 == 0);
            }
            10_000
        });

        let mut enumd = build_policy(&cfg, None);
        let mut rng = Xoshiro256::new(4);
        suite.bench_items("policy_dispatch/enum (batch 10K)", 10_000, || {
            for i in 0..10_000u64 {
                enumd.record_access(rng.below(pages), i % 3 == 0);
            }
            10_000
        });
    }

    // Machine-readable perf trajectory: CI archives this per PR, and the
    // before/after throughput comparison for hmmu_access/static and
    // hmmu_access/hotness reads straight out of it.
    suite
        .write_json("BENCH_hot_path.json")
        .expect("writing BENCH_hot_path.json");
    suite.finish();
}
