//! Hot-path microbenchmarks: the HMMU request pipeline and its
//! components. The §Perf target (DESIGN.md) is ≥10 M modeled requests/s
//! through the full HMMU so the emulator is never the experiment
//! bottleneck.

use hymem::config::{PolicyKind, SystemConfig};
use hymem::hmmu::policy::{HotnessPolicy, NativeHotnessEngine, PlacementPolicy};
use hymem::hmmu::{build_policy, Hmmu, TagMatcher};
use hymem::mem::AccessKind;
use hymem::pcie::PcieLink;
use hymem::util::bench::BenchSuite;
use hymem::util::rng::Xoshiro256;
use hymem::workload::{spec, TraceGenerator};

fn main() {
    let mut suite = BenchSuite::new("hot path: HMMU pipeline components");
    suite.header();

    // Full HMMU request path (static policy: pure routing).
    {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Static;
        let mut hmmu = Hmmu::new(cfg.clone(), None);
        let mut rng = Xoshiro256::new(1);
        let total = cfg.total_mem_bytes();
        let mut t = 0u64;
        suite.bench_items("hmmu_access/static (batch 10K)", 10_000, || {
            for _ in 0..10_000 {
                let addr = rng.below(total) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                t = hmmu.access(addr, kind, 64, t + 20);
            }
            10_000
        });
    }

    // Full HMMU with hotness policy + migrations.
    {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 50_000;
        let mut hmmu = Hmmu::new(cfg.clone(), None);
        let mut rng = Xoshiro256::new(2);
        let total = cfg.total_mem_bytes();
        let mut t = 0u64;
        suite.bench_items("hmmu_access/hotness (batch 10K)", 10_000, || {
            for _ in 0..10_000 {
                let addr = (rng.zipf(total / 4096, 1.1)) * 4096 + rng.below(4096) & !63;
                let kind = if rng.chance(0.3) {
                    AccessKind::Write
                } else {
                    AccessKind::Read
                };
                t = hmmu.access(addr, kind, 64, t + 20);
            }
            10_000
        });
    }

    // Tag matcher alone.
    {
        let mut tm = TagMatcher::new(64);
        let mut rng = Xoshiro256::new(3);
        suite.bench_items("tag_matcher issue+complete (batch 10K)", 10_000, || {
            for i in 0..10_000u64 {
                if !tm.can_issue() {
                    continue;
                }
                let tag = tm.issue();
                let _ = tm.complete(tag, i * 10 + rng.below(200));
            }
            10_000
        });
    }

    // PCIe link send path.
    {
        let cfg = SystemConfig::default_scaled(16);
        let mut link = PcieLink::new(cfg.pcie);
        let mut t = 0u64;
        suite.bench_items("pcie send_to_device+host (batch 10K)", 10_000, || {
            for _ in 0..10_000 {
                t += 100;
                let a = link.send_to_device(0, t);
                let b = link.send_to_host(64, a + 50);
                link.hold_credit_until(b);
            }
            10_000
        });
    }

    // Trace generation alone (must never dominate).
    {
        let wl = spec::by_name("505.mcf").unwrap();
        let mut gen = TraceGenerator::new(wl, 16, 42);
        suite.bench_items("trace_generator next (batch 10K)", 10_000, || {
            for _ in 0..10_000 {
                let _ = gen.next();
            }
            10_000
        });
    }

    // De-virtualization before/after: the old `Box<dyn PlacementPolicy>`
    // vtable dispatch vs the enum-dispatched `PolicyImpl` the HMMU now
    // uses on its per-request path (place + record_access).
    {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Hotness;
        let pages = cfg.total_pages();
        let mut boxed: Box<dyn PlacementPolicy> = Box::new(HotnessPolicy::new(
            pages,
            Box::new(NativeHotnessEngine),
        ));
        let mut rng = Xoshiro256::new(4);
        suite.bench_items("policy_dispatch/boxed-dyn (batch 10K)", 10_000, || {
            for i in 0..10_000u64 {
                boxed.record_access(rng.below(pages), i % 3 == 0);
            }
            10_000
        });

        let mut enumd = build_policy(&cfg, None);
        let mut rng = Xoshiro256::new(4);
        suite.bench_items("policy_dispatch/enum (batch 10K)", 10_000, || {
            for i in 0..10_000u64 {
                enumd.record_access(rng.below(pages), i % 3 == 0);
            }
            10_000
        });
    }

    // Machine-readable perf trajectory: CI archives this per PR, and the
    // before/after throughput comparison for hmmu_access/static and
    // hmmu_access/hotness reads straight out of it.
    suite
        .write_json("BENCH_hot_path.json")
        .expect("writing BENCH_hot_path.json");
    suite.finish();
}
