//! Fig 8 regeneration: memory request volume (bytes, read/write) seen by
//! the HMMU for each workload, scaled back to paper-size footprints.
//!
//! Paper anchors: 505.mcf max (2.83 TB R / 2.82 TB W), 538.imagick min
//! (4.47 GB R / 4.49 GB W). Absolute magnitudes differ (we run a trace
//! sample, not the full benchmark); the *ordering* and the read/write
//! balance are the reproduction targets.

use hymem::config::SystemConfig;
use hymem::platform::{Platform, RunOpts};
use hymem::util::bench::BenchSuite;
use hymem::util::units::fmt_bytes;


fn main() {
    let suite = BenchSuite::new("Fig 8: memory requests (bytes)");
    suite.header();
    let ops = if suite.quick() { 80_000 } else { 1_000_000 };
    let cfg = SystemConfig::default_scaled(16);

    suite.report_row(&format!(
        "{:<16} {:>14} {:>14} {:>8}",
        "workload", "read", "write", "rw-ratio"
    ));
    let mut rows: Vec<(String, u64, u64)> = Vec::new();
    for (wl, wl_ops) in hymem::workload::proportional_ops(ops) {
        let wl = &wl;
        let r = Platform::new(cfg.clone())
            .run_opts(
                wl,
                RunOpts {
                    ops: wl_ops,
                    // count residual dirty lines (full runs evict them)
                    flush_at_end: true,
                },
            )
            .expect("run");
        let (rb, wb) = r.fig8_scaled();
        suite.report_row(&format!(
            "{:<16} {:>14} {:>14} {:>8.2}",
            wl.name,
            fmt_bytes(rb),
            fmt_bytes(wb),
            rb as f64 / wb.max(1) as f64
        ));
        rows.push((wl.name.to_string(), rb, wb));
    }
    rows.sort_by_key(|r| std::cmp::Reverse(r.1 + r.2));
    suite.report_row(&format!(
        "ordering: max={} (paper: 505.mcf) ... min={} (paper: 538.imagick)",
        rows.first().unwrap().0,
        rows.last().unwrap().0
    ));
    let mcf_ok = rows.first().unwrap().0 == "505.mcf";
    let img_ok = rows.last().unwrap().0 == "538.imagick";
    suite.report_row(&format!("shape checks: mcf max: {mcf_ok}; imagick min: {img_ok}"));
    suite.finish();
}
