//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. Placement policy (static / first-touch / hotness / hints).
//! 2. Epoch length for the hotness policy.
//! 3. Migration cap per epoch.
//! 4. HDR FIFO depth (consistency backpressure).
//!
//! Each reports modeled slowdown + DRAM service ratio + migrations, so
//! the trade-offs the paper's platform exists to explore are visible.

use hymem::config::{PolicyKind, SystemConfig};
use hymem::platform::{Platform, RunOpts};
use hymem::util::bench::BenchSuite;
use hymem::workload::spec;

fn main() {
    let suite = BenchSuite::new("ablations: policy / epoch / migration cap / FIFO depth");
    suite.header();
    let ops = if suite.quick() { 60_000 } else { 400_000 };
    let wl = spec::by_name("531.deepsjeng").unwrap(); // skewed, DRAM-overflowing
    let opts = RunOpts {
        ops,
        flush_at_end: false,
    };

    // 1. Policies.
    suite.report_row("--- policy ablation (531.deepsjeng) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>10} {:>12} {:>10}",
        "policy", "slowdown", "dram-serv", "migrations", "energy(mJ)"
    ));
    for kind in [
        PolicyKind::Static,
        PolicyKind::FirstTouch,
        PolicyKind::Hotness,
        PolicyKind::Hints,
        PolicyKind::WearAware,
    ] {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = kind;
        let r = Platform::new(cfg).run_opts(&wl, opts).expect("run");
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>9.1}% {:>12} {:>10.1}",
            kind.name(),
            r.slowdown(),
            r.counters.dram_service_ratio() * 100.0,
            r.counters.migrations,
            r.counters.energy_estimate_mj()
        ));
    }

    // 1b. Wear comparison: hotness vs wear-aware on a write-heavy load.
    suite.report_row("--- NVM wear: hotness vs wear-aware (519.lbm, write-heavy) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>12} {:>12}",
        "policy", "slowdown", "nvm-max-wear", "nvm-writes"
    ));
    let lbm = spec::by_name("519.lbm").unwrap();
    for kind in [PolicyKind::Hotness, PolicyKind::WearAware] {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = kind;
        cfg.hmmu.epoch_requests = 8_000;
        let r = Platform::new(cfg).run_opts(&lbm, opts).expect("run");
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>12} {:>12}",
            kind.name(),
            r.slowdown(),
            r.nvm_max_wear,
            r.counters.nvm_writes
        ));
    }

    // 2. Epoch length.
    suite.report_row("--- epoch-length ablation (hotness) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>10} {:>12}",
        "epoch", "slowdown", "dram-serv", "migrations"
    ));
    for epoch in [1_000u64, 4_000, 16_000, 64_000] {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = epoch;
        let r = Platform::new(cfg).run_opts(&wl, opts).expect("run");
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>9.1}% {:>12}",
            epoch,
            r.slowdown(),
            r.counters.dram_service_ratio() * 100.0,
            r.counters.migrations
        ));
    }

    // 3. Migration cap.
    suite.report_row("--- migration-cap ablation (hotness, epoch=8000) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>10} {:>12} {:>14}",
        "cap", "slowdown", "dram-serv", "migrations", "dma-conflicts"
    ));
    for cap in [4u32, 16, 64, 256] {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 8_000;
        cfg.hmmu.migrations_per_epoch = cap;
        let r = Platform::new(cfg).run_opts(&wl, opts).expect("run");
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>9.1}% {:>12} {:>14}",
            cap,
            r.slowdown(),
            r.counters.dram_service_ratio() * 100.0,
            r.counters.migrations,
            r.counters.dma_conflict_stalls
        ));
    }

    // 4. HDR FIFO depth.
    suite.report_row("--- HDR FIFO depth ablation (505.mcf) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>14} {:>14}",
        "depth", "slowdown", "fifo-stalls", "reorder-wait"
    ));
    let mcf = spec::by_name("505.mcf").unwrap();
    for depth in [4u32, 16, 64, 256] {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Static;
        cfg.hmmu.hdr_fifo_depth = depth;
        let r = Platform::new(cfg).run_opts(&mcf, opts).expect("run");
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>14} {:>11} ns",
            depth,
            r.slowdown(),
            r.counters.fifo_full_stalls,
            r.counters.reorder_wait_ns
        ));
    }

    suite.finish();
}
