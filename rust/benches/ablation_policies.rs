//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. Placement policy (static / first-touch / hotness / hints / wear).
//! 2. NVM wear under hotness vs wear-aware on a write-heavy load.
//! 3. Epoch length for the hotness policy.
//! 4. Migration cap per epoch.
//! 5. HDR FIFO depth (consistency backpressure).
//!
//! Each reports modeled slowdown + DRAM service ratio + migrations, so
//! the trade-offs the paper's platform exists to explore are visible.
//!
//! All 19 ablation points are independent scenarios, so the whole bench
//! runs as **one parallel sweep** (`hymem::sweep`) — results are printed
//! grouped, and are bit-identical to running each point serially.

use hymem::config::{PolicyKind, SystemConfig};
use hymem::sweep::{default_threads, run_sweep, Scenario, ScenarioResult, SweepReport};
use hymem::util::bench::BenchSuite;
use hymem::util::units::fmt_ns;
use hymem::workload::spec;

fn find<'a>(report: &'a SweepReport, name: &str) -> &'a ScenarioResult {
    report
        .scenarios
        .iter()
        .find(|r| r.name == name)
        .unwrap_or_else(|| panic!("missing scenario {name}"))
}

fn main() {
    let suite = BenchSuite::new("ablations: policy / epoch / migration cap / FIFO depth");
    suite.header();
    let ops = if suite.quick() { 60_000 } else { 400_000 };
    let wl = spec::by_name("531.deepsjeng").unwrap(); // skewed, DRAM-overflowing
    let lbm = spec::by_name("519.lbm").unwrap(); // write-heavy
    let mcf = spec::by_name("505.mcf").unwrap();

    let mut scenarios: Vec<Scenario> = Vec::new();

    // 1. Policies on deepsjeng.
    let policy_kinds = [
        PolicyKind::Static,
        PolicyKind::FirstTouch,
        PolicyKind::Hotness,
        PolicyKind::Hints,
        PolicyKind::WearAware,
    ];
    for kind in policy_kinds {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = kind;
        scenarios.push(Scenario::new(format!("policy/{}", kind.name()), wl, cfg, ops));
    }

    // 2. Wear comparison on write-heavy lbm.
    for kind in [PolicyKind::Hotness, PolicyKind::WearAware] {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = kind;
        cfg.hmmu.epoch_requests = 8_000;
        scenarios.push(Scenario::new(format!("wear/{}", kind.name()), lbm, cfg, ops));
    }

    // 3. Epoch length (hotness).
    let epochs = [1_000u64, 4_000, 16_000, 64_000];
    for epoch in epochs {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = epoch;
        scenarios.push(Scenario::new(format!("epoch/{epoch}"), wl, cfg, ops));
    }

    // 4. Migration cap (hotness, epoch=8000).
    let caps = [4u32, 16, 64, 256];
    for cap in caps {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 8_000;
        cfg.hmmu.migrations_per_epoch = cap;
        scenarios.push(Scenario::new(format!("cap/{cap}"), wl, cfg, ops));
    }

    // 5. HDR FIFO depth (static, mcf).
    let depths = [4u32, 16, 64, 256];
    for depth in depths {
        let mut cfg = SystemConfig::default_scaled(16);
        cfg.policy = PolicyKind::Static;
        cfg.hmmu.hdr_fifo_depth = depth;
        scenarios.push(Scenario::new(format!("fifo/{depth}"), mcf, cfg, ops));
    }

    let threads = default_threads();
    suite.report_row(&format!(
        "running {} ablation scenarios on {} threads...",
        scenarios.len(),
        threads
    ));
    let report = run_sweep(&scenarios, threads).expect("ablation sweep");

    // 1. Policies.
    suite.report_row("--- policy ablation (531.deepsjeng) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>10} {:>12} {:>10}",
        "policy", "slowdown", "dram-serv", "migrations", "energy(mJ)"
    ));
    for kind in policy_kinds {
        let r = find(&report, &format!("policy/{}", kind.name()));
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>9.1}% {:>12} {:>10.1}",
            kind.name(),
            r.slowdown,
            r.dram_service_ratio * 100.0,
            r.migrations,
            r.energy_mj
        ));
    }

    // 2. Wear.
    suite.report_row("--- NVM wear: hotness vs wear-aware (519.lbm, write-heavy) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>12} {:>12}",
        "policy", "slowdown", "nvm-max-wear", "nvm-writes"
    ));
    for kind in [PolicyKind::Hotness, PolicyKind::WearAware] {
        let r = find(&report, &format!("wear/{}", kind.name()));
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>12} {:>12}",
            kind.name(),
            r.slowdown,
            r.nvm_max_wear,
            r.nvm_writes
        ));
    }

    // 3. Epoch length.
    suite.report_row("--- epoch-length ablation (hotness) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>10} {:>12}",
        "epoch", "slowdown", "dram-serv", "migrations"
    ));
    for epoch in epochs {
        let r = find(&report, &format!("epoch/{epoch}"));
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>9.1}% {:>12}",
            epoch,
            r.slowdown,
            r.dram_service_ratio * 100.0,
            r.migrations
        ));
    }

    // 4. Migration cap.
    suite.report_row("--- migration-cap ablation (hotness, epoch=8000) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>10} {:>12} {:>14}",
        "cap", "slowdown", "dram-serv", "migrations", "dma-conflicts"
    ));
    for cap in caps {
        let r = find(&report, &format!("cap/{cap}"));
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>9.1}% {:>12} {:>14}",
            cap,
            r.slowdown,
            r.dram_service_ratio * 100.0,
            r.migrations,
            r.dma_conflict_stalls
        ));
    }

    // 5. HDR FIFO depth.
    suite.report_row("--- HDR FIFO depth ablation (505.mcf) ---");
    suite.report_row(&format!(
        "{:<14} {:>10} {:>14} {:>14}",
        "depth", "slowdown", "fifo-stalls", "reorder-wait"
    ));
    for depth in depths {
        let r = find(&report, &format!("fifo/{depth}"));
        suite.report_row(&format!(
            "{:<14} {:>9.2}x {:>14} {:>11} ns",
            depth,
            r.slowdown,
            r.fifo_full_stalls,
            r.reorder_wait_ns
        ));
    }

    suite.report_row(&format!(
        "sweep wall {} vs serial-equivalent {} => {:.2}x parallel speedup",
        fmt_ns(report.wall_ns),
        fmt_ns(report.serial_wall_ns),
        report.parallel_speedup()
    ));
    suite.finish();
}
