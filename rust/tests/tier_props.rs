//! Property tests for the tier-generic migration substrate: any
//! (src, dst) tier pair must move exactly `page_bytes` in each
//! direction, per-tier wear may only increment on tiers that receive
//! writes, and the per-tier residency counters must always sum to the
//! mapped page count.

use hymem::config::{MemTech, PolicyKind, SystemConfig};
use hymem::hmmu::dma::DmaEngine;
use hymem::hmmu::redirection::{Mapping, RedirectionTable, TierId};
use hymem::hmmu::Hmmu;
use hymem::mem::AccessKind;
use hymem::platform::{Platform, RunOpts, WarmPlatform};
use hymem::sweep::{run_sweep, Scenario};
use hymem::util::prop::run_prop;
use hymem::workload::spec;

fn three_tier_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default_scaled(64)
        .with_tiers(&[MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D])
        .unwrap();
    cfg.policy = PolicyKind::Hotness;
    cfg.hmmu.epoch_requests = 1000;
    cfg
}

#[test]
fn prop_any_tier_pair_moves_page_bytes_each_way() {
    // Swap two pages mapped on arbitrary (src, dst) tier ranks: the DMA
    // engine must read exactly `page_bytes` from each side and write
    // exactly `page_bytes` to each side, whatever the pair.
    run_prop("tier-pair-bytes", |rng| {
        let page_bytes = 4096u64;
        let block = *[256u64, 512, 1024].get(rng.below(3) as usize).unwrap();
        let src = TierId(rng.below(4) as u8);
        let mut dst = TierId(rng.below(4) as u8);
        if dst == src {
            dst = TierId((src.0 + 1) % 4);
        }
        let ma = Mapping { device: src, frame: 7 };
        let mb = Mapping { device: dst, frame: 3 };
        let mut dma = DmaEngine::new(block, page_bytes, rng.chance(0.5));
        // Byte ledger: (tier, kind) -> bytes.
        let mut reads = [0u64; 4];
        let mut writes = [0u64; 4];
        dma.start_swap(10, ma, 20, mb, 0, &mut |d, _a, k, b, at| {
            if k.is_write() {
                writes[d.index()] += b;
            } else {
                reads[d.index()] += b;
            }
            at + 10
        });
        for t in 0..4usize {
            let expect = if t == src.index() || t == dst.index() {
                page_bytes
            } else {
                0
            };
            assert_eq!(reads[t], expect, "tier {t} read bytes (src {src:?} dst {dst:?})");
            assert_eq!(writes[t], expect, "tier {t} write bytes (src {src:?} dst {dst:?})");
        }
        assert_eq!(dma.bytes_moved, 2 * page_bytes);
    });
}

#[test]
fn prop_residency_sums_to_mapped_under_churn() {
    // Random place/swap churn over a three-tier table: per-tier resident
    // counts always sum to the mapped count, and every tier's O(1)
    // counter matches a full recount.
    run_prop("tier-residency-sum", |rng| {
        let frames = [
            8 + rng.below(16) as u32,
            8 + rng.below(16) as u32,
            16 + rng.below(32) as u32,
        ];
        let host = (frames.iter().map(|&f| f as u64).sum::<u64>()).min(40);
        let mut t = RedirectionTable::new(host, &frames, 4096);
        let mut placed: Vec<u64> = Vec::new();
        for page in 0..host {
            if rng.chance(0.8) {
                let pref = TierId(rng.below(3) as u8);
                t.place(page, pref).unwrap();
                placed.push(page);
            }
            assert_eq!(
                t.residency().iter().sum::<u64>(),
                t.mapped_pages(),
                "residency must sum to mapped after every place"
            );
        }
        for _ in 0..100 {
            if placed.len() < 2 {
                break;
            }
            let a = placed[rng.below(placed.len() as u64) as usize];
            let b = placed[rng.below(placed.len() as u64) as usize];
            if a != b {
                t.swap(a, b).unwrap();
            }
            assert_eq!(t.residency().iter().sum::<u64>(), t.mapped_pages());
        }
        for rank in 0..3u8 {
            assert_eq!(
                t.resident_pages(TierId(rank)),
                t.recount_resident(TierId(rank)),
                "rank {rank} counter drifted"
            );
        }
        t.check_invariants().unwrap();
    });
}

#[test]
fn wear_only_increments_on_write_target_tiers() {
    // Drive a read-only stream over a three-tier stack: pages spill into
    // every tier, but with no writes and no migrations (first-touch
    // never migrates) no tier may accrue wear. Then a write-heavy run
    // must wear exactly the wear-limited tiers that received writes.
    let mut cfg = three_tier_cfg();
    cfg.policy = PolicyKind::FirstTouch;
    let page_bytes = cfg.hmmu.page_bytes;
    let total = cfg.total_pages();

    let mut h = Hmmu::new(cfg.clone(), None);
    let mut t = 0;
    for p in 0..total.min(6000) {
        t = h.access(p * page_bytes, AccessKind::Read, 64, t + 20);
    }
    assert!(
        h.tier_residency()[2] > 0,
        "stream must spill into the deep tier"
    );
    assert_eq!(h.tier_wear(), vec![0, 0, 0], "reads must not wear any tier");

    let mut h = Hmmu::new(cfg, None);
    let mut t = 0;
    for p in 0..total.min(6000) {
        t = h.access(p * page_bytes, AccessKind::Write, 64, t + 20);
    }
    let wear = h.tier_wear();
    assert_eq!(wear[0], 0, "bare DRAM rank tracks no wear");
    assert!(wear[1] > 0 && wear[2] > 0, "written tiers must wear: {wear:?}");
    // The device write counters corroborate: wear appears exactly where
    // writes landed.
    for rank in 1..3u8 {
        let stats = h.tier_stats(TierId(rank));
        assert!(
            stats.writes > 0,
            "rank {rank} must have served writes to wear"
        );
    }
}

#[test]
fn migration_wear_lands_on_destination_tiers_only() {
    // Hotness scenario on three tiers with a read-only demand stream:
    // the only writes in the system are the DMA engine's cross-writes,
    // so any wear must be attributable to migration block writes, and
    // each migration's byte ledger stays 2 × page_bytes.
    let cfg = three_tier_cfg();
    let page_bytes = cfg.hmmu.page_bytes;
    let total = cfg.total_pages();
    let mut h = Hmmu::new(cfg, None);
    let mut t = 0;
    // Touch everything once (spill deep), then hammer a few deep pages
    // hot so they migrate upward.
    for p in 0..total.min(6000) {
        t = h.access(p * page_bytes, AccessKind::Read, 64, t + 20);
    }
    // Enough hot traffic to cross several epoch boundaries (epoch =
    // 1000 requests) after the warm-up stream.
    let hot_base = 5000u64;
    for _ in 0..300 {
        for p in hot_base..hot_base + 8 {
            t = h.access(p * page_bytes, AccessKind::Read, 64, t + 20);
        }
    }
    h.drain(t + 100_000_000);
    assert!(h.counters.migrations > 0, "scenario must migrate");
    assert_eq!(
        h.counters.migration_bytes,
        h.counters.migrations * 2 * page_bytes,
        "each swap moves both pages exactly once"
    );
    // Demand stream was read-only: every device write is DMA traffic,
    // and wear can only exist on tiers the DMA wrote to.
    for rank in 0..3u8 {
        let stats = h.tier_stats(TierId(rank));
        let wear = h.tier_max_wear(TierId(rank));
        if stats.writes == 0 {
            assert_eq!(wear, 0, "rank {rank} wore without receiving writes");
        }
        if rank == 0 {
            assert_eq!(wear, 0, "bare DRAM rank tracks no wear");
        }
    }
    h.table.check_invariants().unwrap();
}

// ---------------------------------------------------------------------
// Row-buffer battery: flat charging stays bit-identical with the row
// fields present, RBL sweeps are thread-count deterministic, the
// per-tier row counters mirror the device stats, and RBL policy state
// rides the warm checkpoint (fork == cold).
// ---------------------------------------------------------------------

/// 2 stacks × 2 policies on one workload, flat charging.
fn flat_grid(base: &SystemConfig) -> Vec<Scenario> {
    let wl = spec::by_name("505.mcf").unwrap();
    let mut out = Vec::new();
    for (tag, stack) in [
        ("2t", &[MemTech::Dram, MemTech::Xpoint3D][..]),
        ("3t", &[MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D][..]),
    ] {
        for policy in [PolicyKind::Static, PolicyKind::Hotness] {
            let mut cfg = base.clone().with_tiers(stack).unwrap();
            cfg.policy = policy;
            out.push(Scenario::new(format!("mcf/{tag}/{}", policy.name()), wl, cfg, 6_000));
        }
    }
    out
}

#[test]
fn flat_charging_bit_identical_with_inert_row_fields() {
    // The row-buffer stall point rides in every TierSpec but must be
    // dead weight until `row_aware` is set: scribbling garbage into the
    // row fields of a flat-charging config may not move a single bit of
    // the sweep fingerprint, across 2/3-tier stacks and both a static
    // and a migrating policy.
    let mut base = SystemConfig::default_scaled(64);
    base.hmmu.epoch_requests = 2_000;
    let pristine = run_sweep(&flat_grid(&base), 2).unwrap();

    let mut garbage = flat_grid(&base);
    for sc in &mut garbage {
        assert!(!sc.cfg.nvm.row_aware, "flat grid must stay flat");
        sc.cfg.nvm.row_hit_stall_ns = 999;
        sc.cfg.nvm.row_miss_stall_ns = 12_345;
        for t in &mut sc.cfg.extra_tiers {
            t.row_hit_stall_ns = 777;
            t.row_miss_stall_ns = 31_337;
        }
    }
    let scribbled = run_sweep(&garbage, 2).unwrap();
    assert_eq!(
        pristine.deterministic_fingerprint(),
        scribbled.deterministic_fingerprint(),
        "inert row fields leaked into flat-charging results"
    );
}

#[test]
fn rbl_sweep_deterministic_across_thread_counts() {
    // Row-aware charging + the RBL policy through the real sweep engine:
    // identical fingerprints at 1/2/4 threads, and the new per-tier
    // row-outcome columns must actually carry traffic.
    let grid = || -> Vec<Scenario> {
        let mut base = SystemConfig::default_scaled(64);
        base.hmmu.epoch_requests = 2_000;
        base.policy = PolicyKind::Rbl;
        let base = base.with_row_buffer();
        [spec::by_name("505.mcf").unwrap(), spec::by_name("557.xz").unwrap()]
            .into_iter()
            .map(|wl| Scenario::new(format!("{}/rbl", wl.name), wl, base.clone(), 8_000))
            .collect()
    };
    let serial = run_sweep(&grid(), 1).unwrap();
    let fp = serial.deterministic_fingerprint();
    for r in &serial.scenarios {
        let total: u64 = r.tier_row_hits.iter().sum::<u64>()
            + r.tier_row_misses.iter().sum::<u64>();
        assert!(total > 0, "{}: no row outcomes surfaced", r.name);
        assert_eq!(r.tier_row_hit_rate.len(), r.tier_row_hits.len(), "{}", r.name);
    }
    for threads in [2usize, 4] {
        let par = run_sweep(&grid(), threads).unwrap();
        assert_eq!(
            fp,
            par.deterministic_fingerprint(),
            "rbl sweep (threads={threads}) diverged from serial"
        );
    }
}

#[test]
fn row_counters_mirror_device_stats() {
    // The platform report's per-tier row vectors are a verbatim mirror
    // of the device stats — on a two-tier run, rank 0 is the DRAM
    // device and rank 1 the NVM device, both reported alongside.
    let mut cfg = SystemConfig::default_scaled(64).with_row_buffer();
    cfg.policy = PolicyKind::Rbl;
    cfg.hmmu.epoch_requests = 2_000;
    let wl = spec::by_name("505.mcf").unwrap();
    let r = Platform::new(cfg)
        .run_opts(
            &wl,
            RunOpts {
                ops: 20_000,
                flush_at_end: false,
            },
        )
        .unwrap();
    assert_eq!(r.counters.tier_row_hits, vec![r.dram_stats.row_hits, r.nvm_stats.row_hits]);
    assert_eq!(r.counters.tier_row_misses, vec![r.dram_stats.row_misses, r.nvm_stats.row_misses]);
    let total: u64 = r.counters.tier_row_hits.iter().sum::<u64>()
        + r.counters.tier_row_misses.iter().sum::<u64>();
    assert!(total > 0, "run must observe row outcomes");
    // The Hmmu-level mirror agrees with the per-tier device stats on a
    // deeper stack too.
    let cfg = three_tier_cfg().with_row_buffer();
    let page_bytes = cfg.hmmu.page_bytes;
    let total_pages = cfg.total_pages();
    let mut h = Hmmu::new(cfg, None);
    let mut t = 0;
    for p in 0..total_pages.min(6000) {
        t = h.access(p * page_bytes, AccessKind::Read, 64, t + 20);
    }
    h.drain(t + 100_000_000);
    h.sync_row_counters();
    for rank in 0..3u8 {
        let stats = h.tier_stats(TierId(rank));
        assert_eq!(h.counters.tier_row_hits[rank as usize], stats.row_hits);
        assert_eq!(h.counters.tier_row_misses[rank as usize], stats.row_misses);
    }
}

#[test]
fn rbl_state_rides_the_warm_checkpoint() {
    // RBL's per-page miss intensity is policy state: a serialized warm
    // checkpoint must resume bit-identically to the in-memory fork it
    // was saved from, so fork == cold holds for `--policies rbl` too.
    let mut cfg = SystemConfig::default_scaled(64).with_row_buffer();
    cfg.policy = PolicyKind::Rbl;
    cfg.hmmu.epoch_requests = 2_000;
    let wl = spec::by_name("505.mcf").unwrap();
    let opts = RunOpts {
        ops: 6_000,
        flush_at_end: false,
    };
    let mut warm = WarmPlatform::new(cfg.clone(), &wl, opts);
    warm.warm_up(3_000);
    let bytes = warm.save();
    let restored = WarmPlatform::load(&bytes, cfg, &wl, opts).unwrap();
    let a = warm.run_to_completion().unwrap();
    let b = restored.run_to_completion().unwrap();
    assert_eq!(a.platform_time_ns, b.platform_time_ns);
    assert_eq!(format!("{:#?}", a.counters), format!("{:#?}", b.counters));
    assert_eq!(a.tier_residency, b.tier_residency);
    assert_eq!(a.counters.tier_row_hits, b.counters.tier_row_hits);
    assert_eq!(a.counters.tier_row_misses, b.counters.tier_row_misses);
}
