//! End-to-end platform integration tests: invariants that must hold
//! across the full stack (trace → caches → PCIe → HMMU → devices),
//! property-swept over workloads, policies and scales.

use hymem::config::{PolicyKind, SystemConfig};
use hymem::platform::{Platform, RunOpts};
use hymem::util::prop::run_prop_n;
use hymem::workload::{spec, WORKLOADS};

fn opts(ops: u64) -> RunOpts {
    RunOpts {
        ops,
        flush_at_end: false,
    }
}

#[test]
fn all_workloads_run_under_all_policies() {
    for wl in &WORKLOADS {
        for kind in [
            PolicyKind::Static,
            PolicyKind::FirstTouch,
            PolicyKind::Hotness,
            PolicyKind::Hints,
        ] {
            let mut cfg = SystemConfig::default_scaled(64);
            cfg.policy = kind;
            cfg.hmmu.epoch_requests = 3000;
            let r = Platform::new(cfg).run_opts(wl, opts(12_000)).unwrap();
            assert!(
                r.platform_time_ns >= r.native_time_ns,
                "{} under {:?}: platform faster than native?",
                wl.name,
                kind
            );
            assert_eq!(r.mem_ops, 12_000);
        }
    }
}

#[test]
fn prop_conservation_of_requests() {
    // Every post-cache access must be accounted at the HMMU: host
    // reads = fills, and device routing partitions host requests.
    run_prop_n("request-conservation", 0xAB, 12, |rng| {
        let wl = WORKLOADS[rng.below(WORKLOADS.len() as u64) as usize];
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = if rng.chance(0.5) {
            PolicyKind::Hotness
        } else {
            PolicyKind::FirstTouch
        };
        cfg.seed = rng.next_u64();
        cfg.hmmu.epoch_requests = 2000 + rng.below(4000);
        let r = Platform::new(cfg).run_opts(&wl, opts(15_000)).unwrap();
        let c = &r.counters;
        assert_eq!(c.host_reads, r.memory_accesses, "{}", wl.name);
        // Host requests (reads+writes) = device requests (DMA traffic is
        // counted at the devices, not as host traffic).
        let host = c.host_reads + c.host_writes;
        let device = c.dram_reads() + c.dram_writes() + c.nvm_reads() + c.nvm_writes();
        assert_eq!(host, device, "{}: host {host} != device {device}", wl.name);
        // Page placement happened for every touched page.
        assert!(c.pages_placed_dram() + c.pages_placed_nvm() > 0);
    });
}

#[test]
fn prop_migration_bookkeeping_consistent() {
    run_prop_n("migration-bookkeeping", 0xCD, 8, |rng| {
        let wl = spec::by_name("520.omnetpp").unwrap();
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = PolicyKind::Hotness;
        cfg.seed = rng.next_u64();
        cfg.hmmu.epoch_requests = 1500;
        cfg.hmmu.migrations_per_epoch = 1 + rng.below(16) as u32;
        let r = Platform::new(cfg.clone()).run_opts(&wl, opts(20_000)).unwrap();
        // Migration byte accounting: 2 pages per swap.
        assert_eq!(
            r.counters.migration_bytes,
            r.counters.migrations * 2 * cfg.hmmu.page_bytes
        );
        // Migration cap respected per epoch (on average, can't exceed).
        assert!(
            r.counters.migrations
                <= r.counters.epochs * cfg.hmmu.migrations_per_epoch as u64,
            "migrations {} > epochs {} * cap {}",
            r.counters.migrations,
            r.counters.epochs,
            cfg.hmmu.migrations_per_epoch
        );
    });
}

#[test]
fn hotness_beats_first_touch_on_dram_service_for_skewed_overflow() {
    // A workload whose hot set overflows DRAM: migration should raise the
    // fraction of traffic served by DRAM vs frozen first-touch placement.
    let wl = spec::by_name("531.deepsjeng").unwrap(); // zipf random dominant
    let mut ft_cfg = SystemConfig::default_scaled(32);
    ft_cfg.policy = PolicyKind::FirstTouch;
    let mut hot_cfg = SystemConfig::default_scaled(32);
    hot_cfg.policy = PolicyKind::Hotness;
    hot_cfg.hmmu.epoch_requests = 4000;
    hot_cfg.hmmu.migrations_per_epoch = 64;

    let ops = opts(150_000);
    let ft = Platform::new(ft_cfg).run_opts(&wl, ops).unwrap();
    let hot = Platform::new(hot_cfg).run_opts(&wl, ops).unwrap();
    assert!(
        hot.counters.dram_service_ratio() > ft.counters.dram_service_ratio(),
        "hotness {:.3} should beat first-touch {:.3}",
        hot.counters.dram_service_ratio(),
        ft.counters.dram_service_ratio()
    );
}

#[test]
fn fig8_ordering_mcf_max_imagick_min() {
    // The Fig 8 calibration target on a fast subset.
    let cfg = SystemConfig::default_scaled(64);
    let names = ["505.mcf", "557.xz", "541.leela", "538.imagick"];
    let mut volumes = Vec::new();
    for n in names {
        let wl = spec::by_name(n).unwrap();
        let r = Platform::new(cfg.clone()).run_opts(&wl, opts(60_000)).unwrap();
        let (rb, wb) = r.counters.fig8_row();
        volumes.push((n, rb + wb));
    }
    let mcf = volumes[0].1;
    let imagick = volumes[3].1;
    for &(n, v) in &volumes[1..3] {
        assert!(mcf >= v, "mcf should be max, but {n} has {v} > {mcf}");
        assert!(imagick <= v, "imagick should be min, but {n} has {v} < {imagick}");
    }
}

#[test]
fn scale_one_paper_config_smoke() {
    // Full-size Table II config must at least run (short trace).
    let mut cfg = SystemConfig::paper();
    cfg.policy = PolicyKind::FirstTouch;
    let wl = spec::by_name("541.leela").unwrap();
    let r = Platform::new(cfg).run_opts(&wl, opts(5_000)).unwrap();
    assert_eq!(r.scale, 1);
    assert!(r.platform_time_ns > 0);
}

#[test]
fn seeds_change_traffic_but_not_structure() {
    let wl = spec::by_name("500.perlbench").unwrap();
    let mut a_cfg = SystemConfig::default_scaled(64);
    a_cfg.seed = 1;
    let mut b_cfg = SystemConfig::default_scaled(64);
    b_cfg.seed = 2;
    let a = Platform::new(a_cfg).run_opts(&wl, opts(20_000)).unwrap();
    let b = Platform::new(b_cfg).run_opts(&wl, opts(20_000)).unwrap();
    assert_ne!(a.platform_time_ns, b.platform_time_ns);
    // Same op count and same conservation invariants regardless of seed.
    assert_eq!(a.mem_ops, b.mem_ops);
}
