//! Golden counter snapshots: two small fixed scenarios rendered — full
//! `HmmuCounters` Debug plus the deterministic `RunReport` scalars —
//! and compared verbatim against checked-in golden files, so any future
//! fidelity drift (PR 3's write-back stat inflation is the motivating
//! example) fails loudly with a readable first-divergence diff instead
//! of silently shifting a figure.
//!
//! Blessing protocol: when a golden file is absent the test **seeds** it
//! (writes the current rendering into `tests/golden/`) and passes with a
//! note — commit the seeded file to pin the numbers. Set
//! `HYMEM_GOLDEN_STRICT=1` to turn absence into failure; CI runs the
//! suite a second time under that flag, so within one CI run the seeded
//! snapshot must at minimum reproduce itself (catching nondeterminism),
//! and once the files are committed any drift fails the first run.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use hymem::config::{PolicyKind, SystemConfig};
use hymem::platform::{Platform, RunOpts, RunReport};
use hymem::workload::spec;

fn render(r: &RunReport) -> String {
    let mut s = String::new();
    // Only deterministic, simulated-time fields: host wall clocks
    // (host_wall_ns / native_wall_ns) are excluded, and HmmuCounters'
    // Debug impl itself excludes policy_wall_ns.
    let _ = writeln!(s, "workload: {}", r.workload);
    let _ = writeln!(s, "policy: {}", r.policy);
    let _ = writeln!(s, "scale: {}", r.scale);
    let _ = writeln!(s, "instructions: {}", r.instructions);
    let _ = writeln!(s, "mem_ops: {}", r.mem_ops);
    let _ = writeln!(s, "memory_accesses: {}", r.memory_accesses);
    let _ = writeln!(s, "l1d_miss_rate: {:?}", r.l1d_miss_rate);
    let _ = writeln!(s, "l2_miss_rate: {:?}", r.l2_miss_rate);
    let _ = writeln!(s, "native_time_ns: {}", r.native_time_ns);
    let _ = writeln!(s, "platform_time_ns: {}", r.platform_time_ns);
    let _ = writeln!(s, "mem_stall_ns: {}", r.mem_stall_ns);
    let _ = writeln!(s, "nvm_max_wear: {}", r.nvm_max_wear);
    let _ = writeln!(s, "dram_residency: {:?}", r.dram_residency);
    let _ = writeln!(s, "pcie_tx_bytes: {}", r.pcie_tx_bytes);
    let _ = writeln!(s, "pcie_rx_bytes: {}", r.pcie_rx_bytes);
    let _ = writeln!(s, "pcie_credit_stalls: {}", r.pcie_credit_stalls);
    let _ = writeln!(s, "counters: {:#?}", r.counters);
    s
}

fn check_golden(name: &str, rendered: &str) {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden");
    let path = dir.join(format!("{name}.txt"));
    match fs::read_to_string(&path) {
        Ok(want) => {
            if want == rendered {
                return;
            }
            // Readable diff: first divergent line with context.
            let (mut line_no, mut got_line, mut want_line) = (0usize, "", "<missing>");
            for (i, pair) in rendered
                .lines()
                .map(Some)
                .chain(std::iter::repeat(None))
                .zip(want.lines().map(Some).chain(std::iter::repeat(None)))
                .enumerate()
            {
                match pair {
                    (None, None) => break,
                    (g, w) if g != w => {
                        line_no = i + 1;
                        got_line = g.unwrap_or("<missing>");
                        want_line = w.unwrap_or("<missing>");
                        break;
                    }
                    _ => {}
                }
            }
            panic!(
                "golden counter snapshot {name:?} drifted at line {line_no}:\n  \
                 golden: {want_line}\n  \
                 got:    {got_line}\n\
                 Full rendering:\n{rendered}\n\
                 If the change is an intended fidelity shift, delete \
                 {path:?} and re-run to re-seed (then commit it)."
            );
        }
        Err(_) => {
            // Strict only when explicitly =1 (so e.g. `=0` still seeds).
            if std::env::var("HYMEM_GOLDEN_STRICT").is_ok_and(|v| v == "1") {
                panic!(
                    "golden file {path:?} missing under HYMEM_GOLDEN_STRICT=1 \
                     (run the suite once without the flag to seed it, then \
                     commit the file)"
                );
            }
            fs::create_dir_all(&dir).expect("creating tests/golden");
            fs::write(&path, rendered).expect("seeding golden file");
            eprintln!(
                "NOTE: seeded golden counter snapshot {path:?}; commit it so \
                 future fidelity drift fails loudly"
            );
        }
    }
}

/// Scenario A: hotness policy with migrations inside the run (the same
/// shape `platform::tests::policies_execute_and_differ` pins as
/// migrating).
#[test]
fn golden_hotness_omnetpp() {
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::Hotness;
    cfg.hmmu.epoch_requests = 2_000;
    let wl = spec::by_name("520.omnetpp").unwrap();
    let r = Platform::new(cfg)
        .run_opts_serial(
            &wl,
            RunOpts {
                ops: 60_000,
                flush_at_end: false,
            },
        )
        .unwrap();
    assert!(r.counters.migrations > 0, "scenario must migrate");
    check_golden("hotness_omnetpp", &render(&r));
}

/// Scenario B: first-touch policy, write-heavy workload, end-of-run
/// flush (covers the write-back + flush counter surface).
#[test]
fn golden_first_touch_lbm_flush() {
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::FirstTouch;
    let wl = spec::by_name("519.lbm").unwrap();
    let r = Platform::new(cfg)
        .run_opts_serial(
            &wl,
            RunOpts {
                ops: 20_000,
                flush_at_end: true,
            },
        )
        .unwrap();
    assert!(r.counters.host_writes > 0, "scenario must write");
    check_golden("first_touch_lbm_flush", &render(&r));
}

/// The snapshot rendering itself must be reproducible within a process —
/// a second identical run renders byte-identically (this is what makes
/// the golden comparison meaningful, and it catches wall-clock or
/// iteration-order leaks into the counter surface immediately, without
/// waiting for a committed golden file).
#[test]
fn golden_rendering_is_deterministic() {
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::Hotness;
    cfg.hmmu.epoch_requests = 2_000;
    let wl = spec::by_name("505.mcf").unwrap();
    let opts = RunOpts {
        ops: 20_000,
        flush_at_end: false,
    };
    let a = Platform::new(cfg.clone()).run_opts_serial(&wl, opts).unwrap();
    let b = Platform::new(cfg).run_opts_serial(&wl, opts).unwrap();
    assert_eq!(render(&a), render(&b), "rendering must be deterministic");
}
