//! Link-fidelity property battery (the paper attributes the platform's
//! residual slowdown to "the latency of the PCIe links", so the link
//! model is the fidelity-critical boundary): seeded-random TLP streams
//! pin the invariants the rest of the stack leans on —
//!
//! - wire time is monotone per direction,
//! - the credit pool never exceeds `cfg.credits`,
//! - `credit_wait_ns` is consistent with the stall count,
//! - `tx_bytes` / `rx_bytes` equal the sum of `Tlp::wire_payload()` (plus
//!   headers) over the sent TLPs,
//! - and the block-batched crossing is **bit-identical** to the per-op
//!   crossing with coalescing off, across 3 seeds × 2 credit configs,
//!   while coalescing on changes only wire time / TLP counts.

use hymem::config::{PcieConfig, PolicyKind, SystemConfig};
use hymem::pcie::{PcieLink, Tlp, TlpColumn, TlpKind};
use hymem::sim::Time;
use hymem::util::rng::Xoshiro256;

fn pcie_cfg(credits: u32) -> PcieConfig {
    let mut c = SystemConfig::paper().pcie;
    c.credits = credits;
    c
}

/// Deterministic device-side service latency for entry `i` (the HMMU
/// stand-in: varied but replayable).
fn service_latency(i: usize) -> u64 {
    80 + ((i as u64).wrapping_mul(37) % 400)
}

/// A seeded-random recorded-traffic column: monotone issue times, ~40%
/// MRd round trips, runs of same-page writes (so coalescing, when on,
/// has adjacency to find), mixed payload sizes.
fn random_column(rng: &mut Xoshiro256, n: usize) -> TlpColumn {
    let mut col = TlpColumn::new();
    let mut t: Time = 0;
    let payloads = [16u32, 64, 64, 128];
    let mut i = 0;
    while i < n {
        t += rng.below(50);
        if rng.chance(0.4) {
            let addr = rng.below(1 << 30) & !63;
            col.push(TlpKind::MRd, addr, 64, t);
            i += 1;
        } else {
            // A run of 1-4 address-contiguous writes inside one 4 KiB
            // page at one time (what a write-combiner may merge).
            let page = rng.below(1 << 18) << 12;
            let run = 1 + rng.below(4) as usize;
            let mut offset = 0u64;
            for _ in 0..run.min(n - i) {
                let payload = payloads[rng.below(4) as usize];
                col.push(TlpKind::MWr, page + offset, payload, t);
                offset += payload as u64;
            }
            i += run.min(n - i);
        }
    }
    col
}

/// Reference executor: the column crossed one TLP at a time through the
/// per-op API, exactly as `HmmuBackend::access` sequences it.
fn cross_per_op(link: &mut PcieLink, col: &TlpColumn) -> Vec<Time> {
    let mut completions = Vec::new();
    for i in 0..col.len() {
        let at = col.issue_time(i);
        match col.kind(i) {
            TlpKind::MRd => {
                let a = link.send_to_device(0, at);
                let release = a + service_latency(i);
                let back = link.send_to_host(col.payload(i), release);
                link.hold_credit_until(back);
                completions.push(back);
            }
            _ => {
                let a = link.send_to_device(col.payload(i), at);
                let commit = a + service_latency(i);
                link.hold_credit_until(commit);
                completions.push(commit);
            }
        }
    }
    completions
}

#[test]
fn batch_bit_identical_to_per_op_across_seeds_and_credit_configs() {
    for seed in [1u64, 2, 3] {
        for credits in [4u32, 64] {
            let mut rng = Xoshiro256::new(seed);
            let col = random_column(&mut rng, 256);

            let mut per_op = PcieLink::new(pcie_cfg(credits));
            let ref_completions = cross_per_op(&mut per_op, &col);

            let mut blocked = PcieLink::new(pcie_cfg(credits));
            let mut completions = Vec::new();
            blocked.send_block_to_device(
                &col,
                &mut |_l, i, arrive| arrive + service_latency(i),
                &mut completions,
            );

            let label = format!("seed={seed} credits={credits}");
            assert_eq!(completions, ref_completions, "{label}: completion times");
            assert_eq!(blocked.tx_bytes(), per_op.tx_bytes(), "{label}: tx bytes");
            assert_eq!(blocked.rx_bytes(), per_op.rx_bytes(), "{label}: rx bytes");
            assert_eq!(blocked.tx_tlps(), per_op.tx_tlps(), "{label}: tx tlps");
            assert_eq!(blocked.rx_tlps(), per_op.rx_tlps(), "{label}: rx tlps");
            assert_eq!(
                blocked.credit_stalls, per_op.credit_stalls,
                "{label}: credit stalls"
            );
            assert_eq!(
                blocked.credit_wait_ns, per_op.credit_wait_ns,
                "{label}: credit wait"
            );
            assert_eq!(
                blocked.outstanding_credits(),
                per_op.outstanding_credits(),
                "{label}: outstanding credits"
            );
            // Probe: the very next TLP must behave identically on both
            // links (pins wire_free and residual credit state, not just
            // the counters).
            let t_probe = col.issue_time(col.len() - 1) + 1;
            assert_eq!(
                blocked.send_to_device(0, t_probe),
                per_op.send_to_device(0, t_probe),
                "{label}: post-batch probe"
            );
            // Sanity: the tight credit config actually exercised stalls.
            if credits == 4 {
                assert!(per_op.credit_stalls > 0, "{label}: no stall coverage");
            }
        }
    }
}

#[test]
fn wire_time_is_monotone_per_direction() {
    for seed in [11u64, 12, 13] {
        let mut rng = Xoshiro256::new(seed);
        let mut link = PcieLink::new(pcie_cfg(64));
        let mut t: Time = 0;
        let mut last_tx = 0;
        let mut last_rx = 0;
        for i in 0..500usize {
            t += rng.below(40);
            let payload = [0u32, 16, 64, 256][rng.below(4) as usize];
            let a = link.send_to_device(payload, t);
            assert!(a > last_tx, "seed={seed} op={i}: tx arrival regressed");
            last_tx = a;
            let b = link.send_to_host(payload, t);
            assert!(b > last_rx, "seed={seed} op={i}: rx arrival regressed");
            last_rx = b;
            link.hold_credit_until(a + 200);
        }
    }
}

#[test]
fn credit_pool_never_exceeds_config() {
    for &credits in &[4u32, 64] {
        let mut rng = Xoshiro256::new(99);
        let mut link = PcieLink::new(pcie_cfg(credits));
        let mut t: Time = 0;
        for _ in 0..2_000usize {
            t += rng.below(30);
            let a = link.send_to_device(64, t);
            assert!(
                link.outstanding_credits() <= credits as usize,
                "pool exceeded {credits} after send"
            );
            // Long-lived transactions keep the pool under pressure.
            link.hold_credit_until(a + 500 + rng.below(5_000));
            assert!(
                link.outstanding_credits() <= credits as usize,
                "pool exceeded {credits} after hold"
            );
        }
        assert!(link.credit_stalls > 0, "scenario must exercise the gate");
    }
}

#[test]
fn credit_wait_consistent_with_stall_count() {
    // No-pressure regime: zero stalls must mean zero accumulated wait.
    let mut relaxed = PcieLink::new(pcie_cfg(64));
    let mut t = 0;
    for _ in 0..500 {
        t += 1_000;
        let a = relaxed.send_to_device(64, t);
        relaxed.hold_credit_until(a + 10);
    }
    assert_eq!(relaxed.credit_stalls, 0);
    assert_eq!(relaxed.credit_wait_ns, 0);

    // Pressure regime: every stall waits at least 1 ns (the gate always
    // drains entries ≤ now before declaring a stall), so the accumulated
    // wait bounds the stall count from above.
    let mut tight = PcieLink::new(pcie_cfg(4));
    for i in 0..500u64 {
        let a = tight.send_to_device(64, i);
        tight.hold_credit_until(a + 10_000);
    }
    assert!(tight.credit_stalls > 0);
    assert!(
        tight.credit_wait_ns >= tight.credit_stalls,
        "wait {} < stalls {}",
        tight.credit_wait_ns,
        tight.credit_stalls
    );
}

#[test]
fn byte_counters_equal_wire_payload_sums() {
    for seed in [21u64, 22, 23] {
        let mut rng = Xoshiro256::new(seed);
        let mut link = PcieLink::new(pcie_cfg(64));
        let hdr = link.config().tlp_header_bytes as u64;
        let (mut want_tx, mut want_rx) = (0u64, 0u64);
        let (mut want_tx_tlps, mut want_rx_tlps) = (0u64, 0u64);
        let mut t = 0;
        for i in 0..400u64 {
            t += rng.below(60);
            let bytes = [16u32, 64, 256][rng.below(3) as usize];
            if rng.chance(0.5) {
                // Read round trip: MRd out (no payload on the wire),
                // CplD back carrying the data.
                let req = Tlp::read(i * 64, bytes, 0, 0);
                let cpl = req.completion();
                let a = link.send_to_device(req.wire_payload(), t);
                let b = link.send_to_host(cpl.wire_payload(), a + 100);
                link.hold_credit_until(b);
                want_tx += hdr + req.wire_payload() as u64;
                want_rx += hdr + cpl.wire_payload() as u64;
                want_tx_tlps += 1;
                want_rx_tlps += 1;
            } else {
                let req = Tlp::write(i * 64, bytes, 0, 0);
                let a = link.send_to_device(req.wire_payload(), t);
                link.hold_credit_until(a + 50);
                want_tx += hdr + req.wire_payload() as u64;
                want_tx_tlps += 1;
            }
        }
        assert_eq!(link.tx_bytes(), want_tx, "seed={seed}");
        assert_eq!(link.rx_bytes(), want_rx, "seed={seed}");
        assert_eq!(link.tx_tlps(), want_tx_tlps, "seed={seed}");
        assert_eq!(link.rx_tlps(), want_rx_tlps, "seed={seed}");
    }
}

#[test]
fn coalescing_changes_only_wire_accounting_never_service() {
    let mut rng = Xoshiro256::new(31);
    let col = random_column(&mut rng, 256);

    let mut off = PcieLink::new(pcie_cfg(64));
    let mut serviced_off: Vec<usize> = Vec::new();
    let mut completions_off = Vec::new();
    off.send_block_to_device(
        &col,
        &mut |_l, i, arrive| {
            serviced_off.push(i);
            arrive + service_latency(i)
        },
        &mut completions_off,
    );

    let mut on_cfg = pcie_cfg(64);
    on_cfg.coalesce_writes = true;
    let mut on = PcieLink::new(on_cfg);
    let mut serviced_on: Vec<usize> = Vec::new();
    let mut completions_on = Vec::new();
    on.send_block_to_device(
        &col,
        &mut |_l, i, arrive| {
            serviced_on.push(i);
            arrive + service_latency(i)
        },
        &mut completions_on,
    );

    // Device-side view is untouched: same requests, same order, one
    // completion per request.
    assert_eq!(serviced_on, serviced_off, "service sequence changed");
    assert_eq!(completions_on.len(), completions_off.len());
    // Wire accounting shrinks: merged TLPs save headers and TLP slots.
    assert!(on.coalesced_writes > 0, "column must offer adjacency");
    assert_eq!(on.tx_tlps() + on.coalesced_writes, off.tx_tlps());
    assert!(on.tx_bytes() < off.tx_bytes(), "headers must be saved");
    assert_eq!(
        off.tx_bytes() - on.tx_bytes(),
        on.coalesced_writes * on.config().tlp_header_bytes as u64,
        "exactly one header saved per merged TLP"
    );
    // Reads are never merged.
    assert_eq!(on.rx_tlps(), off.rx_tlps());
    assert_eq!(on.rx_bytes(), off.rx_bytes());
}

#[test]
fn coalescing_on_platform_preserves_state_and_device_counters() {
    // End-to-end: a write-heavy run under the static policy (routing is
    // address-based, so device counters are time-independent) with
    // coalescing on must reproduce the exact device-side state of the
    // coalescing-off run — only wire accounting may shrink.
    use hymem::platform::{Platform, RunOpts};
    use hymem::workload::spec;
    let opts = RunOpts {
        ops: 20_000,
        flush_at_end: false,
    };
    let wl = spec::by_name("519.lbm").unwrap();
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::Static;
    let off = Platform::new(cfg.clone()).run_opts_serial(&wl, opts).unwrap();
    cfg.pcie.coalesce_writes = true;
    let on = Platform::new(cfg).run_opts_serial(&wl, opts).unwrap();

    assert_eq!(on.counters.host_reads, off.counters.host_reads);
    assert_eq!(on.counters.host_writes, off.counters.host_writes);
    assert_eq!(on.counters.dram_reads(), off.counters.dram_reads());
    assert_eq!(on.counters.dram_writes(), off.counters.dram_writes());
    assert_eq!(on.counters.nvm_reads(), off.counters.nvm_reads());
    assert_eq!(on.counters.nvm_writes(), off.counters.nvm_writes());
    assert_eq!(on.counters.pages_placed_dram(), off.counters.pages_placed_dram());
    assert_eq!(on.counters.pages_placed_nvm(), off.counters.pages_placed_nvm());
    assert_eq!(on.counters.migrations, off.counters.migrations);
    assert!((on.dram_residency - off.dram_residency).abs() < f64::EPSILON);
    assert!(on.pcie_tx_bytes <= off.pcie_tx_bytes, "coalescing never adds wire bytes");
    assert!(on.counters.host_writes > 0, "mix must exercise posted writes");
}
