//! Property tests for the DMA page-swap engine (§III-D).
//!
//! "When a memory request is targeted at the page being swapped, we use
//! the swap progress indicator to decide where to redirect the memory
//! requests. ... We spent considerable time to design and verify the
//! logic design to ensure all possible cases are covered and processed
//! properly." — these sweeps are that verification for our model:
//! arbitrary probe times × offsets × block sizes must route to exactly
//! the device that holds the current copy of the data.

use hymem::hmmu::dma::{DmaEngine, DmaRoute};
use hymem::hmmu::redirection::{Device, Mapping};
use hymem::util::prop::run_prop;

fn maps() -> (Mapping, Mapping) {
    (
        Mapping {
            device: Device::Nvm,
            frame: 7,
        },
        Mapping {
            device: Device::Dram,
            frame: 3,
        },
    )
}

#[test]
fn prop_route_is_consistent_with_block_windows() {
    run_prop("dma-route-windows", |rng| {
        let block = *[128u64, 256, 512, 1024].get(rng.below(4) as usize).unwrap();
        let page = 4096u64;
        let pipelined = rng.chance(0.5);
        let mut dma = DmaEngine::new(block, page, pipelined);
        let (ma, mb) = maps();
        let start = rng.below(10_000);
        // Random per-access latencies for this episode.
        let lat_r = 20 + rng.below(60);
        let lat_w = 30 + rng.below(80);
        let done = dma.start_swap(
            10,
            ma,
            20,
            mb,
            start,
            &mut |_d, _a, k, _b, at| at + if k.is_write() { lat_w } else { lat_r },
        );
        assert!(done > start);

        // Probe random (page, offset, time) triples.
        for _ in 0..64 {
            let probe_page = if rng.chance(0.8) {
                if rng.chance(0.5) {
                    10
                } else {
                    20
                }
            } else {
                rng.below(100)
            };
            let offset = rng.below(page);
            let t = start + rng.below((done - start) * 2);
            let (route, swap) = dma.route(probe_page, offset, t);
            if probe_page != 10 && probe_page != 20 {
                assert_eq!(route, DmaRoute::NotInvolved);
                continue;
            }
            let s = swap.expect("swap record for involved page");
            match route {
                DmaRoute::NotInvolved => panic!("involved page not routed"),
                DmaRoute::UseOriginal => {
                    // Data not yet moved: the original frame holds it.
                    assert_eq!(s.original(probe_page), if probe_page == 10 { ma } else { mb });
                }
                DmaRoute::UseDestination => {
                    assert_eq!(
                        s.destination(probe_page),
                        if probe_page == 10 { mb } else { ma }
                    );
                }
                DmaRoute::Stall(until) => {
                    // Stall must end strictly after the probe and no
                    // later than the whole swap.
                    assert!(until > t, "stall {until} <= probe {t}");
                    assert!(until <= done);
                }
            }
        }
    });
}

#[test]
fn prop_progress_partitions_page_at_any_instant() {
    // At any time t, the page's blocks partition into
    // committed (dest) | in-flight (stall) | pending (orig),
    // in that order with at most one in-flight region boundary pair.
    run_prop("dma-progress-partition", |rng| {
        let mut dma = DmaEngine::new(512, 4096, rng.chance(0.5));
        let (ma, mb) = maps();
        let lat = 25 + rng.below(100);
        let done = dma.start_swap(1, ma, 2, mb, 0, &mut |_d, _a, _k, _b, at| at + lat);
        let t = rng.below(done + 10);
        let mut seen_states = Vec::new();
        for b in 0..8u64 {
            let (route, _) = dma.route(1, b * 512, t);
            seen_states.push(match route {
                DmaRoute::UseDestination => 0u8,
                DmaRoute::Stall(_) => 1,
                DmaRoute::UseOriginal => 2,
                DmaRoute::NotInvolved => panic!("page 1 is involved"),
            });
        }
        // States must be non-decreasing (committed prefix, then in-flight,
        // then pending) for sequential DMA; pipelined overlap allows
        // multiple in-flight blocks but still no committed-after-pending.
        for w in seen_states.windows(2) {
            assert!(
                w[0] <= w[1],
                "non-monotone swap progress: {seen_states:?} at t={t}"
            );
        }
    });
}

#[test]
fn prop_commit_exactly_once() {
    run_prop("dma-commit-once", |rng| {
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        let mut commits = 0;
        let n_swaps = 1 + rng.below(4);
        let mut t = 0;
        for i in 0..n_swaps {
            let pa = 100 + i * 2;
            let pb = 101 + i * 2;
            t = dma.start_swap(pa, ma, pb, mb, t + rng.below(100), &mut |_d, _a, _k, _b, at| {
                at + 10
            });
        }
        // Drain at random times, possibly before completion.
        let mut probe = 0;
        for _ in 0..10 {
            probe += rng.below(t + 100);
            commits += dma.drain_committed(probe).len();
        }
        commits += dma.drain_committed(t + 1).len();
        assert_eq!(commits as u64, n_swaps, "each swap commits exactly once");
        assert_eq!(dma.active_count(), 0);
    });
}

#[test]
fn prop_byte_accounting() {
    run_prop("dma-bytes", |rng| {
        let block = *[256u64, 512, 1024].get(rng.below(3) as usize).unwrap();
        let mut dma = DmaEngine::new(block, 4096, false);
        let (ma, mb) = maps();
        let n = 1 + rng.below(5);
        let mut t = 0;
        for i in 0..n {
            t = dma.start_swap(i * 2, ma, i * 2 + 1, mb, t, &mut |_d, _a, _k, _b, at| at + 5);
        }
        // A swap moves both pages: 2 * page_bytes per swap.
        assert_eq!(dma.bytes_moved, n * 2 * 4096);
        assert_eq!(dma.blocks_moved, n * (4096 / block));
    });
}
