//! The sweep engine's determinism contract: a parallel sweep is
//! bit-identical to a serial one — every modeled report field and counter,
//! across thread counts — because per-scenario seeds derive from scenario
//! index, never from thread identity or completion order.

use hymem::config::{PolicyKind, SystemConfig};
use hymem::sweep::{derive_seed, run_sweep, Scenario};
use hymem::workload::spec;

/// 8 mixed scenarios (4 workloads × 2 policies), small enough to run the
/// whole matrix three times in tier-1.
fn scenarios() -> Vec<Scenario> {
    let mut base = SystemConfig::default_scaled(64);
    base.hmmu.epoch_requests = 2_000;
    let workloads = [
        spec::by_name("505.mcf").unwrap(),
        spec::by_name("538.imagick").unwrap(),
        spec::by_name("557.xz").unwrap(),
        spec::by_name("531.deepsjeng").unwrap(),
    ];
    let policies = [PolicyKind::Static, PolicyKind::Hotness];
    let out = Scenario::grid(&workloads, &policies, &base, 8_000);
    assert_eq!(out.len(), 8);
    out
}

#[test]
fn parallel_sweep_identical_to_serial_across_thread_counts() {
    let serial = run_sweep(&scenarios(), 1).unwrap();
    assert_eq!(serial.threads, 1);
    let fp_serial = serial.deterministic_fingerprint();
    assert_eq!(fp_serial.lines().count(), 8);

    for threads in [2usize, 4] {
        let par = run_sweep(&scenarios(), threads).unwrap();
        assert_eq!(par.threads, threads);
        assert_eq!(
            fp_serial,
            par.deterministic_fingerprint(),
            "parallel sweep (threads={threads}) diverged from serial"
        );
        // True serial-vs-parallel wall ratio (threads=1 run above is the
        // uncontended baseline). Informational only: CI machines are too
        // noisy to hard-assert the <0.5x acceptance ratio here.
        eprintln!(
            "threads={threads}: wall {}ns vs serial wall {}ns ({:.2}x)",
            par.wall_ns,
            serial.wall_ns,
            serial.wall_ns as f64 / par.wall_ns.max(1) as f64
        );
    }
}

#[test]
fn repeated_sweep_is_reproducible() {
    // Same scenario list twice at the same thread count: identical too
    // (catches any hidden global state between runs).
    let a = run_sweep(&scenarios(), 4).unwrap();
    let b = run_sweep(&scenarios(), 4).unwrap();
    assert_eq!(a.deterministic_fingerprint(), b.deterministic_fingerprint());
}

#[test]
fn results_keep_scenario_order() {
    let names: Vec<String> = scenarios().iter().map(|s| s.name.clone()).collect();
    let r = run_sweep(&scenarios(), 4).unwrap();
    let got: Vec<String> = r.scenarios.iter().map(|s| s.name.clone()).collect();
    assert_eq!(names, got, "results must come back in scenario order");
}

#[test]
fn grid_scenarios_share_the_trace_replicates_do_not() {
    // Controlled comparison: every grid point reports the shared base
    // seed, so policy deltas on a workload are measured on the identical
    // trace — and identical traces show up as identical host-side request
    // volumes for the same workload across policies.
    let scs = scenarios();
    let r = run_sweep(&scs, 4).unwrap();
    for (sc, res) in scs.iter().zip(&r.scenarios) {
        assert_eq!(res.seed, sc.cfg.seed, "grid must not rewrite seeds");
    }
    let mcf: Vec<_> = r
        .scenarios
        .iter()
        .filter(|s| s.workload == "505.mcf")
        .collect();
    assert_eq!(mcf.len(), 2);
    // Same trace + same caches => identical post-cache request volumes;
    // only the timing/placement columns may differ between policies.
    assert_eq!(mcf[0].host_read_bytes, mcf[1].host_read_bytes);
    assert_eq!(mcf[0].host_write_bytes, mcf[1].host_write_bytes);

    // Error-bar path: replicates carry distinct index-derived seeds.
    let reps = Scenario::replicates(&scs[..1], 4);
    let rr = run_sweep(&reps, 4).unwrap();
    let mut seeds: Vec<u64> = rr.scenarios.iter().map(|s| s.seed).collect();
    for (k, s) in rr.scenarios.iter().enumerate() {
        assert_eq!(s.seed, derive_seed(scs[0].cfg.seed, k as u64));
    }
    seeds.sort_unstable();
    seeds.dedup();
    assert_eq!(seeds.len(), 4, "replicate seeds must be distinct");
}

#[test]
fn json_report_round_trips_key_fields() {
    let r = run_sweep(&scenarios()[..2], 2).unwrap();
    let js = r.to_json().pretty();
    assert!(js.contains("\"schema\": \"hymem/sweep/v1\""));
    for sc in &r.scenarios {
        assert!(js.contains(&format!("\"name\": \"{}\"", sc.name)));
        assert!(js.contains(&format!("\"platform_time_ns\": {}", sc.platform_time_ns)));
    }
}
