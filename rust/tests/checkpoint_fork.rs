//! Warm-state checkpoint/fork fidelity pins.
//!
//! The fork engine's contract has two halves:
//!
//! 1. **Fork == cold replay, bit-for-bit.** A forked sweep (warm-up paid
//!    once per group, state cloned per scenario) produces the *identical*
//!    modeled results — platform time, every counter, residency, the full
//!    scenario fingerprint — as cold-replay mode, which re-simulates the
//!    same warm-up + morph path per scenario. Across thread counts.
//! 2. **Serialized == in-memory.** A checkpoint that round-trips through
//!    the binary codec resumes bit-identically to the in-memory clone it
//!    was saved from — across tier-stack depths and every policy.

use hymem::config::{MemTech, PolicyKind, SystemConfig};
use hymem::platform::{RunOpts, WarmPlatform};
use hymem::sweep::{run_sweep_forked, ForkOpts, Scenario};
use hymem::workload::spec;

const OPS: u64 = 6_000;
const WARM: u64 = 3_000;

/// 2 workloads × 2 policies × 2 stall points on a 3-tier stack: 8
/// scenarios in 4 warm groups (grouping ignores the policy and stall
/// fork axes, keeps workload and topology).
fn grid_3tier() -> Vec<Scenario> {
    let mut base = SystemConfig::default_scaled(64);
    base.hmmu.epoch_requests = 2_000;
    let base = base
        .with_tiers(&[MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D])
        .unwrap();
    let workloads = [
        spec::by_name("505.mcf").unwrap(),
        spec::by_name("557.xz").unwrap(),
    ];
    let policies = [PolicyKind::Static, PolicyKind::Hotness];
    let grid = Scenario::grid(&workloads, &policies, &base, OPS);
    let grid = Scenario::stall_grid(&grid, &[(50, 225), (400, 1_800)]);
    assert_eq!(grid.len(), 8);
    grid
}

fn forked(warmup_ops: u64, cold_replay: bool) -> ForkOpts {
    ForkOpts {
        warmup_ops,
        checkpoint_dir: None,
        cold_replay,
    }
}

#[test]
fn forked_sweep_bit_identical_to_cold_replay_across_threads() {
    let grid = grid_3tier();
    let cold = run_sweep_forked(&grid, 1, &forked(WARM, true)).unwrap();
    let fp_cold = cold.deterministic_fingerprint();
    assert_eq!(fp_cold.lines().count(), 8);

    for threads in [1usize, 2, 4] {
        let fork = run_sweep_forked(&grid, threads, &forked(WARM, false)).unwrap();
        assert_eq!(
            fp_cold,
            fork.deterministic_fingerprint(),
            "forked sweep (threads={threads}) diverged from cold replay"
        );
        // Spot-check the headline fields beyond the fingerprint.
        for (c, f) in cold.scenarios.iter().zip(&fork.scenarios) {
            assert_eq!(c.platform_time_ns, f.platform_time_ns, "{}", c.name);
            assert_eq!(c.native_time_ns, f.native_time_ns, "{}", c.name);
            assert_eq!(c.tier_residency, f.tier_residency, "{}", c.name);
            assert_eq!(c.migrations, f.migrations, "{}", c.name);
        }
    }
}

#[test]
fn zero_warmup_forked_sweep_matches_classic_sweep() {
    // `--warmup-ops 0` must reduce to today's cold path exactly.
    let grid = grid_3tier();
    let classic = hymem::sweep::run_sweep(&grid, 2).unwrap();
    let forked0 = run_sweep_forked(&grid, 2, &forked(0, false)).unwrap();
    assert_eq!(
        classic.deterministic_fingerprint(),
        forked0.deterministic_fingerprint()
    );
}

#[test]
fn checkpoint_roundtrip_matches_in_memory_fork_across_stacks_and_policies() {
    let wl = spec::by_name("505.mcf").unwrap();
    let stacks: [&[MemTech]; 3] = [
        &[MemTech::Dram, MemTech::Xpoint3D],
        &[MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D],
        &[MemTech::Dram, MemTech::SttRam, MemTech::Pcm, MemTech::Xpoint3D],
    ];
    let policies = [
        PolicyKind::Static,
        PolicyKind::FirstTouch,
        PolicyKind::Hints,
        PolicyKind::Hotness,
        PolicyKind::WearAware,
        PolicyKind::Rbl,
    ];
    let opts = RunOpts {
        ops: OPS,
        flush_at_end: false,
    };
    for stack in stacks {
        for policy in policies {
            let mut cfg = SystemConfig::default_scaled(64);
            cfg.hmmu.epoch_requests = 2_000;
            cfg.policy = policy;
            let cfg = cfg.with_tiers(stack).unwrap();
            let label = format!("{}/{:?}", cfg.topology_label(), policy);

            let mut warm = WarmPlatform::new(cfg.clone(), &wl, opts);
            warm.warm_up(WARM);
            let bytes = warm.save();
            let restored = WarmPlatform::load(&bytes, cfg, &wl, opts).unwrap();
            assert_eq!(restored.warmed_ops(), warm.warmed_ops(), "{label}");

            let a = warm.run_to_completion().unwrap();
            let b = restored.run_to_completion().unwrap();
            assert_eq!(a.platform_time_ns, b.platform_time_ns, "{label}");
            assert_eq!(a.native_time_ns, b.native_time_ns, "{label}");
            assert_eq!(
                format!("{:#?}", a.counters),
                format!("{:#?}", b.counters),
                "{label}"
            );
            assert_eq!(a.tier_residency, b.tier_residency, "{label}");
            assert_eq!(a.tier_wear, b.tier_wear, "{label}");
            assert_eq!(a.nvm_max_wear, b.nvm_max_wear, "{label}");
        }
    }
}

#[test]
fn checkpoint_dir_cache_hit_is_bit_identical() {
    let grid = &grid_3tier()[..4]; // one workload, 2 policies × 2 stalls
    let dir = std::env::temp_dir().join(format!("hymem-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = ForkOpts {
        warmup_ops: WARM,
        checkpoint_dir: Some(dir.clone()),
        cold_replay: false,
    };
    // First run seeds the cache, second run resumes from it.
    let seeded = run_sweep_forked(grid, 2, &opts).unwrap();
    let ckpts = std::fs::read_dir(&dir).unwrap().count();
    assert!(ckpts >= 1, "no checkpoints cached in {}", dir.display());
    let cached = run_sweep_forked(grid, 2, &opts).unwrap();
    assert_eq!(
        seeded.deterministic_fingerprint(),
        cached.deterministic_fingerprint(),
        "cache-hit sweep diverged from cache-seeding sweep"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn multicore_rows_fork_warm_and_match_cold_replay_across_threads() {
    // cores > 1 rows warm and fork through `WarmMulticore` — no cold
    // fallback. The forked result must be bit-identical to cold-replay
    // mode (which replays the identical warm+morph path per scenario)
    // at every thread count, and the multicore warm engine itself is
    // pinned identical to `run_multicore` in its unit tests, so the
    // classic sweep agrees too.
    let mut base = SystemConfig::default_scaled(64);
    base.hmmu.epoch_requests = 2_000;
    let wl = spec::by_name("541.leela").unwrap();
    let policies = [PolicyKind::Static, PolicyKind::Hotness];
    let mut scenarios = Vec::new();
    for policy in policies {
        let mut cfg = base.clone();
        cfg.policy = policy;
        scenarios.push(
            Scenario::new(format!("leela/{policy:?}x2"), wl, cfg.clone(), 4_000).with_cores(2),
        );
        scenarios.push(Scenario::new(format!("leela/{policy:?}"), wl, cfg, 4_000));
    }
    let cold = run_sweep_forked(&scenarios, 1, &forked(2_000, true)).unwrap();
    let fp_cold = cold.deterministic_fingerprint();
    assert_eq!(fp_cold.lines().count(), 4);
    for threads in [1usize, 2, 4] {
        let fork = run_sweep_forked(&scenarios, threads, &forked(2_000, false)).unwrap();
        assert_eq!(
            fp_cold,
            fork.deterministic_fingerprint(),
            "multicore forked sweep (threads={threads}) diverged from cold replay"
        );
    }
    // The classic sweep agrees with the warm engine on the multicore
    // rows (full counter surface via the deterministic key).
    let classic = hymem::sweep::run_sweep(&scenarios, 2).unwrap();
    let fork = run_sweep_forked(&scenarios, 2, &forked(2_000, false)).unwrap();
    for (c, f) in classic.scenarios.iter().zip(&fork.scenarios) {
        if c.cores > 1 {
            assert_eq!(c.deterministic_key(), f.deterministic_key(), "{}", c.name);
        }
    }
}

#[test]
fn intra_group_fork_parallelism_is_deterministic() {
    // One warm group × many members: phase B fans the members (not the
    // groups) across the pool, so thread counts beyond the group count
    // must still produce the serial fork order bit-for-bit.
    let mut base = SystemConfig::default_scaled(64);
    base.hmmu.epoch_requests = 2_000;
    let wl = spec::by_name("505.mcf").unwrap();
    let policies = [PolicyKind::Static, PolicyKind::Hotness];
    let grid = Scenario::grid(&[wl], &policies, &base, OPS);
    let grid = Scenario::stall_grid(&grid, &[(50, 225), (200, 900), (400, 1_800)]);
    assert_eq!(grid.len(), 6, "six members, one warm group");
    let serial = run_sweep_forked(&grid, 1, &forked(WARM, false)).unwrap();
    for threads in [2usize, 4] {
        let par = run_sweep_forked(&grid, threads, &forked(WARM, false)).unwrap();
        assert_eq!(
            serial.deterministic_fingerprint(),
            par.deterministic_fingerprint(),
            "intra-group fork (threads={threads}) diverged from serial"
        );
    }
}
