//! Integration tests: the AOT XLA artifacts loaded through PJRT must be
//! bit-compatible with the native Rust engine, and the full platform must
//! run end-to-end through the XLA policy step.
//!
//! These tests are skipped (with a message) when `artifacts/` has not
//! been built — run `make artifacts` first. CI runs them via `make test`.

use hymem::config::{PolicyKind, SystemConfig};
use hymem::hmmu::policy::{HotnessEngine, NativeHotnessEngine};
use hymem::platform::{Platform, RunOpts};
use hymem::runtime::{default_artifact_dir, XlaHotnessEngine, XlaLatencyModel};
use hymem::util::rng::Xoshiro256;
use hymem::workload::spec;

fn artifacts_available() -> bool {
    XlaHotnessEngine::load_default().is_ok()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn xla_policy_cross_check_exact() {
    require_artifacts!();
    let mut xla = XlaHotnessEngine::load_default().unwrap();
    let mut native = NativeHotnessEngine;

    let mut rng = Xoshiro256::new(777);
    for &n in &[100usize, 4096, 5000, 16384, 20000] {
        let reads: Vec<f32> = (0..n).map(|_| rng.below(1000) as f32).collect();
        let writes: Vec<f32> = (0..n).map(|_| rng.below(500) as f32).collect();
        let prev: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 1e4).collect();
        let in_dram: Vec<f32> = (0..n).map(|_| (rng.chance(0.3)) as u8 as f32).collect();

        let a = xla.step(&reads, &writes, &prev, &in_dram);
        let b = native.step(&reads, &writes, &prev, &in_dram);
        assert_eq!(a.hotness.len(), n);
        // Exact equality: same f32 ops in the same order on both sides.
        assert_eq!(a.hotness, b.hotness, "hotness mismatch at n={n}");
        assert_eq!(a.promote_score, b.promote_score, "promote mismatch at n={n}");
        assert_eq!(a.demote_score, b.demote_score, "demote mismatch at n={n}");
    }
    assert!(xla.invocations >= 5);
}

#[test]
fn xla_engine_padding_is_invisible() {
    require_artifacts!();
    let mut xla = XlaHotnessEngine::load_default().unwrap();
    // 100 pages -> padded to 4096 internally; outputs truncated back.
    let out = xla.step(&[1.0; 100], &[0.0; 100], &[0.0; 100], &[0.0; 100]);
    assert_eq!(out.hotness.len(), 100);
    assert!(out.hotness.iter().all(|&h| h == 1.0));
}

#[test]
fn platform_runs_with_xla_engine_end_to_end() {
    require_artifacts!();
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::Hotness;
    cfg.hmmu.epoch_requests = 5_000;
    let engine = XlaHotnessEngine::load_default().unwrap();
    let wl = spec::by_name("520.omnetpp").unwrap();
    let r = Platform::new(cfg)
        .with_engine(Box::new(engine))
        .run_opts(
            &wl,
            RunOpts {
                ops: 40_000,
                flush_at_end: false,
            },
        )
        .unwrap();
    assert!(r.counters.epochs > 0, "policy epochs must have run");
    assert!(r.platform_time_ns > r.native_time_ns);
}

#[test]
fn xla_and_native_engines_produce_identical_platform_runs() {
    require_artifacts!();
    let wl = spec::by_name("505.mcf").unwrap();
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::Hotness;
    cfg.hmmu.epoch_requests = 4_000;
    let opts = RunOpts {
        ops: 30_000,
        flush_at_end: false,
    };

    let r_native = Platform::new(cfg.clone()).run_opts(&wl, opts).unwrap();
    let r_xla = Platform::new(cfg)
        .with_engine(Box::new(XlaHotnessEngine::load_default().unwrap()))
        .run_opts(&wl, opts)
        .unwrap();

    // Bit-compatible engines => identical simulated timelines & counters.
    assert_eq!(r_native.platform_time_ns, r_xla.platform_time_ns);
    assert_eq!(r_native.counters.migrations, r_xla.counters.migrations);
    assert_eq!(
        r_native.counters.host_read_bytes,
        r_xla.counters.host_read_bytes
    );
}

#[test]
fn latency_model_artifact_matches_formula() {
    require_artifacts!();
    let mut m = match XlaLatencyModel::load(&default_artifact_dir(), 1024) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP: latency artifact missing: {e}");
            return;
        }
    };
    let is_nvm: Vec<f32> = (0..1024).map(|i| (i % 2) as f32).collect();
    let is_write: Vec<f32> = (0..1024).map(|i| ((i / 2) % 2) as f32).collect();
    let qd: Vec<f32> = (0..1024).map(|i| (i % 8) as f32).collect();
    let out = m.estimate(&is_nvm, &is_write, &qd).unwrap();
    for i in 0..1024 {
        let expect = 510.0
            + 32.0
            + is_nvm[i] * (is_write[i] * 225.0 + (1.0 - is_write[i]) * 50.0)
            + qd[i] * 18.0;
        assert!(
            (out[i] - expect).abs() < 1e-3,
            "i={i}: got {} want {expect}",
            out[i]
        );
    }
}
