//! Fixture tests for the `hymem-audit` rule engine: each rule gets a
//! deliberately-broken source tree in a temp directory and must report
//! the right rule id at the right place; the exemption syntax must
//! silence it; and the real crate tree must come back clean (the same
//! invariant the CI `audit` job enforces).

use hymem::audit::{audit_tree, Finding};
use std::fs;
use std::path::{Path, PathBuf};

/// Materialize `files` (path relative to the fixture root → contents)
/// under a unique temp dir and return its root. `src/` always exists.
fn fixture(name: &str, files: &[(&str, &str)]) -> PathBuf {
    let dir = format!("hymem-audit-{}-{name}", std::process::id());
    let base = std::env::temp_dir().join(dir);
    let _ = fs::remove_dir_all(&base);
    fs::create_dir_all(base.join("src")).unwrap();
    for (rel, text) in files {
        let p = base.join(rel);
        fs::create_dir_all(p.parent().unwrap()).unwrap();
        fs::write(p, text).unwrap();
    }
    base
}

fn run(base: &Path) -> Vec<Finding> {
    let findings = audit_tree(&base.join("src")).unwrap();
    let _ = fs::remove_dir_all(base);
    findings
}

const BAD_CODEC: &str = r#"
pub struct Thing {
    pub a: u64,
    pub b: u64,
}

impl CodecState for Thing {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_u64(self.a);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.a = d.get_u64()?;
        Ok(())
    }
}
"#;

#[test]
fn codec_coverage_flags_uncovered_field() {
    let base = fixture("codec", &[("src/thing.rs", BAD_CODEC)]);
    let findings = run(&base);
    assert_eq!(findings.len(), 1, "{findings:?}");
    let f = &findings[0];
    assert_eq!(f.rule, "codec-coverage");
    assert_eq!(f.line, 4, "anchored to the `pub b` field line");
    assert!(f.message.contains("Thing.b"), "{}", f.message);
    assert!(f.message.contains("encode_state or decode_state"), "{}", f.message);
    // The file:line: [rule] message shape the CI log relies on.
    let shown = f.to_string();
    assert!(shown.contains("thing.rs:4: [codec-coverage]"), "{shown}");
}

#[test]
fn allow_comment_silences_a_finding() {
    let trailing = BAD_CODEC.replace(
        "    pub b: u64,",
        "    pub b: u64, // audit: allow(codec-coverage) — fixture",
    );
    let standalone = BAD_CODEC.replace(
        "    pub b: u64,",
        "    // audit: allow(codec-coverage) — fixture\n    pub b: u64,",
    );
    let wrong_rule = BAD_CODEC.replace(
        "    pub b: u64,",
        "    pub b: u64, // audit: allow(wall-clock) — wrong rule id",
    );
    let base = fixture("allow-trailing", &[("src/thing.rs", &trailing)]);
    assert!(run(&base).is_empty(), "same-line allow must silence");
    let base = fixture("allow-standalone", &[("src/thing.rs", &standalone)]);
    assert!(run(&base).is_empty(), "line-above allow must silence");
    let base = fixture("allow-wrong", &[("src/thing.rs", &wrong_rule)]);
    assert_eq!(run(&base).len(), 1, "an allow for another rule must not");
}

const UNSORTED: &str = r#"
pub struct Wear {
    map: HashMap<u64, u64>,
}

impl CodecState for Wear {
    fn encode_state(&self, e: &mut Encoder) {
        for (k, v) in &self.map {
            e.put_u64(*k);
            e.put_u64(*v);
        }
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.map.insert(d.get_u64()?, d.get_u64()?);
        Ok(())
    }
}
"#;

#[test]
fn unsorted_iter_flags_hash_encode_without_sort() {
    let base = fixture("unsorted", &[("src/wear.rs", UNSORTED)]);
    let findings = run(&base);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "unsorted-iter");
    assert!(findings[0].message.contains("Wear.map"), "{}", findings[0].message);

    // The mem/nvm.rs pattern — collect + sort before emitting — passes.
    let sorted = UNSORTED.replace(
        "        for (k, v) in &self.map {",
        "        let mut kv: Vec<_> = self.map.iter().collect();\n        \
         kv.sort();\n        for (k, v) in kv {",
    );
    let base = fixture("sorted", &[("src/wear.rs", &sorted)]);
    assert!(run(&base).is_empty());
}

const FLOAT_CAST: &str = r#"
pub struct P {
    x: f32,
}

impl CodecState for P {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_u32(self.x as u32);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.x = d.get_u32()? as f32;
        Ok(())
    }
}
"#;

#[test]
fn float_bits_flags_ad_hoc_cast_in_encode() {
    let base = fixture("float", &[("src/p.rs", FLOAT_CAST)]);
    let findings = run(&base);
    assert_eq!(findings.len(), 1, "{findings:?}");
    let f = &findings[0];
    assert_eq!(f.rule, "float-bits");
    assert_eq!(f.line, 8, "anchored to the casting encode line");
    assert!(f.message.contains("P.x"), "{}", f.message);

    let via_bits = FLOAT_CAST.replace(
        "        e.put_u32(self.x as u32);",
        "        e.put_u32(self.x.to_bits());",
    );
    let base = fixture("float-ok", &[("src/p.rs", &via_bits)]);
    assert!(run(&base).is_empty());
}

#[test]
fn wall_clock_flagged_outside_allowlist_only() {
    let clocky = "pub fn t() -> u64 {\n    let _w = std::time::Instant::now();\n    0\n}\n";
    let base = fixture(
        "wall",
        &[
            ("src/model.rs", clocky),
            // Allowlisted wholesale: the sweep driver reports wall time.
            ("src/sweep/driver.rs", clocky),
        ],
    );
    let findings = run(&base);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "wall-clock");
    assert!(findings[0].file.ends_with("model.rs"), "{}", findings[0].file);
    assert_eq!(findings[0].line, 2);
}

const MINI_COUNTERS: &str = r#"
pub struct HmmuCounters {
    pub good: u64,
    pub missing_one: u64,
}

impl std::fmt::Debug for HmmuCounters {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let HmmuCounters { good, missing_one } = self;
        write!(f, "{good} {missing_one}")
    }
}
"#;

const MINI_REPORT: &str = r#"
pub struct ScenarioResult {
    pub good: u64,
}

impl ScenarioResult {
    pub fn to_json(&self) -> u64 {
        self.good
    }

    pub fn deterministic_key(&self) -> u64 {
        self.good
    }
}
"#;

#[test]
fn counter_surface_flags_missing_report_columns() {
    let base = fixture(
        "counters",
        &[
            ("src/hmmu/counters.rs", MINI_COUNTERS),
            ("src/sweep/report.rs", MINI_REPORT),
        ],
    );
    let findings = run(&base);
    assert_eq!(findings.len(), 1, "{findings:?}");
    let f = &findings[0];
    assert_eq!(f.rule, "counter-surface");
    assert_eq!(f.line, 4, "anchored to the counter field");
    assert!(f.message.contains("missing_one"), "{}", f.message);
    assert!(f.message.contains("to_json"), "{}", f.message);
    assert!(f.message.contains("deterministic_key"), "{}", f.message);
    assert!(!f.message.contains("Debug"), "destructured in Debug: {}", f.message);
}

#[test]
fn bench_pair_requires_registered_block_partner() {
    let rows = "fn main() {\n    \
        suite.bench_items(\"foo/per-op (batch 64)\", 64, || 0);\n    \
        suite.bench_items(\"bar/per-op (batch 64)\", 64, || 0);\n}\n";
    let gate = "PAIRS = [\n    (\"foo/per-op (batch 64)\", \"foo/block (batch 64)\", None),\n]\n";
    let base = fixture(
        "bench",
        &[
            ("src/lib.rs", "// fixture\n"),
            ("benches/rows.rs", rows),
            ("scripts/check_bench_gate.py", gate),
        ],
    );
    let findings = run(&base);
    assert_eq!(findings.len(), 2, "{findings:?}");
    assert!(findings.iter().all(|f| f.rule == "bench-pair"));
    let msgs: Vec<&str> = findings.iter().map(|f| f.message.as_str()).collect();
    // `bar` is not registered at all; `foo`'s partner row exists in the
    // registry but no bench defines it.
    assert!(msgs.iter().any(|m| m.contains("bar/per-op") && m.contains("no pair registered")));
    assert!(msgs.iter().any(|m| m.contains("foo/block") && m.contains("no bench registers")));
}

/// The invariant the CI `audit` job enforces, pinned as a test so
/// `cargo test` catches drift without the extra binary run: the crate's
/// own tree (including `benches/` and the gate-pair registry) is clean.
#[test]
fn repo_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = audit_tree(&root).unwrap();
    let shown: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(findings.is_empty(), "{shown:#?}");
}
