//! The batched pipeline's contract: pulling whole `TraceBlock`s through
//! `fill_block` + `step_block` is **bit-identical** to the per-op
//! iterator loop — same trace, same counters, same report — across
//! workloads and policies, and the new multicore sweep scenarios stay
//! deterministic across sweep thread counts.

use hymem::config::{PolicyKind, SystemConfig};
use hymem::cpu::{CacheHierarchy, CoreModel};
use hymem::platform::{HmmuBackend, Platform, RunOpts};
use hymem::sweep::{run_sweep, Scenario};
use hymem::workload::{spec, TraceBlock, TraceGenerator, Workload};

const OPS: u64 = 30_000;

fn cfg_for(policy: PolicyKind) -> SystemConfig {
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = policy;
    // Small epochs so the hotness path migrates inside the run.
    cfg.hmmu.epoch_requests = 2_000;
    cfg
}

/// Reference per-op platform pass: the exact pre-batching inner loop
/// (iterator-driven `CoreModel::step`, per-op `CacheHierarchy::access`),
/// kept here as the ground truth the block pipeline — including the
/// block-batched hierarchy lookup — is pinned against.
fn run_per_op(cfg: &SystemConfig, wl: &Workload, ops: u64, flush: bool) -> (u64, String, f64) {
    let mut backend = HmmuBackend::new(cfg.clone(), None);
    let mut core = CoreModel::new(cfg.cpu);
    let mut hier = CacheHierarchy::new(cfg);
    let gen = TraceGenerator::new(*wl, cfg.scale, cfg.seed).take_ops(ops);
    for op in gen {
        core.step(&op, &mut hier, &mut backend);
    }
    if flush {
        let now = core.now();
        hier.flush(now, &mut backend);
    }
    let platform_time_ns = core.finish();
    backend.drain(platform_time_ns);
    (
        platform_time_ns,
        // The full counter block (incl. the latency histogram) rendered
        // via Debug: any drifting field shows up in the diff.
        format!("{:?}", backend.hmmu.counters),
        backend.hmmu.dram_residency(),
    )
}

#[test]
fn batched_platform_bit_identical_to_per_op() {
    let workloads = ["505.mcf", "538.imagick", "557.xz"];
    let policies = [PolicyKind::Static, PolicyKind::Hotness];
    for wl_name in workloads {
        for policy in policies {
            let cfg = cfg_for(policy);
            let wl = spec::by_name(wl_name).unwrap();
            let (ref_time, ref_counters, ref_residency) = run_per_op(&cfg, &wl, OPS, false);

            // The production path (Platform::run_opts_serial) drives the
            // block pipeline.
            let r = Platform::new(cfg)
                .run_opts_serial(
                    &wl,
                    RunOpts {
                        ops: OPS,
                        flush_at_end: false,
                    },
                )
                .unwrap();
            let label = format!("{wl_name}/{}", policy.name());
            assert_eq!(
                r.platform_time_ns, ref_time,
                "{label}: platform_time_ns diverged"
            );
            assert_eq!(
                format!("{:?}", r.counters),
                ref_counters,
                "{label}: HMMU counters diverged"
            );
            assert!(
                (r.dram_residency - ref_residency).abs() < f64::EPSILON,
                "{label}: residency diverged ({} vs {ref_residency})",
                r.dram_residency
            );
            // Sanity: the comparison exercised real traffic.
            assert!(r.memory_accesses > 0, "{label}: no memory traffic");
        }
    }
}

#[test]
fn block_generator_feeds_exact_op_budget() {
    // The tail block is shorter than TRACE_BLOCK_OPS; the budget must
    // come out exact (no over- or under-generation at block boundaries).
    let cfg = cfg_for(PolicyKind::Static);
    let wl = spec::by_name("519.lbm").unwrap();
    let r = Platform::new(cfg)
        .run_opts_serial(
            &wl,
            RunOpts {
                ops: 10_123,
                flush_at_end: false,
            },
        )
        .unwrap();
    assert_eq!(r.mem_ops, 10_123);
}

#[test]
fn per_op_reference_matches_concurrent_runner_too() {
    // run_opts (concurrent passes) and run_opts_serial share the block
    // pipeline; both must match the per-op reference.
    let cfg = cfg_for(PolicyKind::Hotness);
    let wl = spec::by_name("505.mcf").unwrap();
    let (ref_time, ref_counters, _) = run_per_op(&cfg, &wl, OPS, false);
    let r = Platform::new(cfg)
        .run_opts(
            &wl,
            RunOpts {
                ops: OPS,
                flush_at_end: false,
            },
        )
        .unwrap();
    assert_eq!(r.platform_time_ns, ref_time);
    assert_eq!(format!("{:?}", r.counters), ref_counters);
}

#[test]
fn host_managed_dma_block_path_bit_identical_to_per_op() {
    // The new link-fidelity scenario: migration DMA crosses PCIe
    // (`host_managed_dma`). The per-op reference and the block-batched
    // link crossing must interleave the DMA's link charges at the same
    // sequence points — every counter, including the new
    // pcie_dma_bytes / dma_link_stalls, stays bit-identical.
    let mut cfg = cfg_for(PolicyKind::Hotness);
    cfg.hmmu.host_managed_dma = true;
    let wl = spec::by_name("505.mcf").unwrap();
    let (ref_time, ref_counters, ref_residency) = run_per_op(&cfg, &wl, OPS, false);
    let r = Platform::new(cfg)
        .run_opts_serial(
            &wl,
            RunOpts {
                ops: OPS,
                flush_at_end: false,
            },
        )
        .unwrap();
    assert_eq!(r.platform_time_ns, ref_time, "host-managed: time diverged");
    assert_eq!(
        format!("{:?}", r.counters),
        ref_counters,
        "host-managed: counters diverged"
    );
    assert!((r.dram_residency - ref_residency).abs() < f64::EPSILON);
    assert!(r.counters.migrations > 0, "scenario must migrate");
    assert!(
        r.counters.pcie_dma_bytes > 0,
        "host-managed migration traffic must cross the link"
    );
}

#[test]
fn block_link_crossing_is_bit_identical_with_coalescing_off() {
    // Belt-and-braces at the platform level for the new PCIe block
    // crossing: the default config ships coalescing off, and the whole
    // per-op-vs-block battery above rides the block link path — this
    // pins that the default really is the bit-identical mode.
    let cfg = cfg_for(PolicyKind::Hotness);
    assert!(
        !cfg.pcie.coalesce_writes,
        "coalescing must default off (bit-identity contract)"
    );
}

#[test]
fn multicore_block_path_is_reproducible() {
    // The multicore scheduler consumes per-core blocks through a cursor;
    // the interleaving (and so every counter) must be a pure function of
    // the scenario.
    let cfg = cfg_for(PolicyKind::Hotness);
    let wls = [
        spec::by_name("505.mcf").unwrap(),
        spec::by_name("538.imagick").unwrap(),
        spec::by_name("557.xz").unwrap(),
    ];
    let opts = RunOpts {
        ops: 8_000,
        flush_at_end: false,
    };
    let a = hymem::platform::run_multicore(cfg.clone(), &wls, opts, None).unwrap();
    let b = hymem::platform::run_multicore(cfg, &wls, opts, None).unwrap();
    assert_eq!(a.makespan_ns, b.makespan_ns);
    assert_eq!(format!("{:?}", a.counters), format!("{:?}", b.counters));
    for (ca, cb) in a.cores.iter().zip(&b.cores) {
        assert_eq!(ca.time_ns, cb.time_ns);
        assert_eq!(ca.mem_ops, opts.ops, "every core runs its full budget");
        assert_eq!(ca.instructions, cb.instructions);
    }
}

#[test]
fn multicore_sweep_scenarios_deterministic_across_thread_counts() {
    // The new cores axis: single-core and 2-/4-core scenarios in one
    // sweep, fingerprint pinned at 1/2/4 sweep threads.
    let base = cfg_for(PolicyKind::Hotness);
    let wl = spec::by_name("505.mcf").unwrap();
    let xz = spec::by_name("557.xz").unwrap();
    let single = vec![
        Scenario::new("mcf/hotness", wl, base.clone(), 6_000),
        Scenario::new("xz/hotness", xz, base, 6_000),
    ];
    let scenarios = Scenario::cores_grid(&single, &[1, 2, 4]);
    assert_eq!(scenarios.len(), 6);
    assert_eq!(scenarios[2].cores, 4);

    let fp_serial = run_sweep(&scenarios, 1).unwrap().deterministic_fingerprint();
    assert_eq!(fp_serial.lines().count(), 6);
    assert!(fp_serial.contains("mcf/hotnessx4"));
    assert!(fp_serial.contains("cores=2"));
    for threads in [2usize, 4] {
        let fp = run_sweep(&scenarios, threads)
            .unwrap()
            .deterministic_fingerprint();
        assert_eq!(
            fp_serial, fp,
            "multicore sweep diverged at {threads} threads"
        );
    }
}

#[test]
fn flush_at_end_bit_identical_to_per_op() {
    // The end-of-run flush now writes dirty lines back at their real
    // addresses; both paths must feed the HMMU the same write stream.
    for policy in [PolicyKind::Static, PolicyKind::Hotness] {
        let cfg = cfg_for(policy);
        let wl = spec::by_name("519.lbm").unwrap(); // write-heavy: big dirty set
        let (ref_time, ref_counters, ref_residency) = run_per_op(&cfg, &wl, OPS, true);
        let r = Platform::new(cfg)
            .run_opts_serial(
                &wl,
                RunOpts {
                    ops: OPS,
                    flush_at_end: true,
                },
            )
            .unwrap();
        let label = format!("lbm+flush/{}", policy.name());
        assert_eq!(r.platform_time_ns, ref_time, "{label}: time diverged");
        assert_eq!(
            format!("{:?}", r.counters),
            ref_counters,
            "{label}: counters diverged"
        );
        assert!(
            (r.dram_residency - ref_residency).abs() < f64::EPSILON,
            "{label}: residency diverged"
        );
    }
}

#[test]
fn hierarchy_block_lookup_bit_identical_through_hmmu() {
    // The access_block contract at the full-counter level: the same
    // handcrafted mix as `step_block_bit_identical_to_per_op` (hits,
    // independent misses, dependent chains, stores), driven through the
    // real PCIe+HMMU backend per-op and block-batched, compared on core
    // stats, hierarchy stats and the whole HMMU counter block.
    use hymem::workload::TraceOp;
    let mut ops = Vec::new();
    for i in 0..2_000u64 {
        ops.push(TraceOp::load(3, (i % 7) * 64));
        ops.push(TraceOp::load(0, i * 4096));
        if i % 3 == 0 {
            ops.push(TraceOp::chained_load(1, i * 8192));
        }
        if i % 4 == 0 {
            ops.push(TraceOp::store(2, i * 4096 + 64));
        }
    }

    let cfg = cfg_for(PolicyKind::Hotness);

    let mut ref_backend = HmmuBackend::new(cfg.clone(), None);
    let mut ref_core = CoreModel::new(cfg.cpu);
    let mut ref_hier = CacheHierarchy::new(&cfg);
    for op in &ops {
        ref_core.step(op, &mut ref_hier, &mut ref_backend);
    }
    let ref_time = ref_core.finish();
    ref_backend.drain(ref_time);

    let mut backend = HmmuBackend::new(cfg.clone(), None);
    let mut core = CoreModel::new(cfg.cpu);
    let mut hier = CacheHierarchy::new(&cfg);
    // 384 is not a divisor of the op count: exercises the short tail.
    let mut block = TraceBlock::with_capacity(384);
    for chunk in ops.chunks(384) {
        block.clear();
        for op in chunk {
            block.push(*op);
        }
        core.step_block(&block, &mut hier, &mut backend);
    }
    let time = core.finish();
    backend.drain(time);

    assert_eq!(time, ref_time);
    assert_eq!(format!("{:?}", core.stats), format!("{:?}", ref_core.stats));
    assert_eq!(hier.l1d.hits, ref_hier.l1d.hits);
    assert_eq!(hier.l1d.misses, ref_hier.l1d.misses);
    assert_eq!(hier.l2.hits, ref_hier.l2.hits);
    assert_eq!(hier.l2.misses, ref_hier.l2.misses);
    assert_eq!(hier.l2.writebacks, ref_hier.l2.writebacks);
    assert_eq!(hier.mem_reads, ref_hier.mem_reads);
    assert_eq!(hier.mem_writes, ref_hier.mem_writes);
    assert_eq!(
        format!("{:?}", backend.hmmu.counters),
        format!("{:?}", ref_backend.hmmu.counters),
        "HMMU counters diverged between per-op and block hierarchy lookup"
    );
    assert!(
        backend.hmmu.counters.host_writes > 0,
        "mix must exercise posted write-backs"
    );
}

#[test]
fn multicore_parallel_generation_preserves_per_core_streams() {
    // The per-core producer threads must feed each core exactly the
    // stream a serial generator would: pin instruction counts against a
    // direct drain of the same-seed generator.
    let cfg = cfg_for(PolicyKind::Static);
    let wls = [
        spec::by_name("505.mcf").unwrap(),
        spec::by_name("519.lbm").unwrap(),
        spec::by_name("557.xz").unwrap(),
    ];
    let opts = RunOpts {
        ops: 9_000,
        flush_at_end: false,
    };
    let r = hymem::platform::run_multicore(cfg.clone(), &wls, opts, None).unwrap();
    for (i, wl) in wls.iter().enumerate() {
        // Same scale and seed derivation as `run_multicore`.
        let scale = cfg.scale * wls.len() as u64;
        let expected: u64 = TraceGenerator::new(*wl, scale, cfg.seed ^ (i as u64) << 32)
            .take_ops(opts.ops)
            .map(|op| op.instructions())
            .sum();
        assert_eq!(r.cores[i].mem_ops, opts.ops);
        assert_eq!(
            r.cores[i].instructions, expected,
            "core {i} stream diverged from serial generation"
        );
    }
}

#[test]
fn generator_block_stream_equals_iterator_stream() {
    // Belt-and-braces at the trace level (unit tests cover this per
    // module; this pins it for the shipped workload set end to end).
    for wl in ["505.mcf", "519.lbm", "538.imagick", "557.xz"] {
        let spec = spec::by_name(wl).unwrap();
        let per_op: Vec<_> = TraceGenerator::new(spec, 64, 0x5EED).take_ops(9_000).collect();
        let mut gen = TraceGenerator::new(spec, 64, 0x5EED).take_ops(9_000);
        let mut block = TraceBlock::with_capacity(1024);
        let mut batched = Vec::with_capacity(per_op.len());
        while gen.fill_block(&mut block) > 0 {
            batched.extend(block.iter());
        }
        assert_eq!(per_op, batched, "{wl}: generator streams diverged");
    }
}
