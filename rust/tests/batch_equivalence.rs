//! The batched pipeline's contract: pulling whole `TraceBlock`s through
//! `fill_block` + `step_block` is **bit-identical** to the per-op
//! iterator loop — same trace, same counters, same report — across
//! workloads and policies, and the new multicore sweep scenarios stay
//! deterministic across sweep thread counts.

use hymem::config::{PolicyKind, SystemConfig};
use hymem::cpu::{CacheHierarchy, CoreModel};
use hymem::platform::{HmmuBackend, Platform, RunOpts};
use hymem::sweep::{run_sweep, Scenario};
use hymem::workload::{spec, TraceBlock, TraceGenerator, Workload};

const OPS: u64 = 30_000;

fn cfg_for(policy: PolicyKind) -> SystemConfig {
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = policy;
    // Small epochs so the hotness path migrates inside the run.
    cfg.hmmu.epoch_requests = 2_000;
    cfg
}

/// Reference per-op platform pass: the exact pre-batching inner loop
/// (iterator-driven `CoreModel::step`), kept here as the ground truth the
/// block pipeline is pinned against.
fn run_per_op(cfg: &SystemConfig, wl: &Workload, ops: u64) -> (u64, String, f64) {
    let mut backend = HmmuBackend::new(cfg.clone(), None);
    let mut core = CoreModel::new(cfg.cpu);
    let mut hier = CacheHierarchy::new(cfg);
    let gen = TraceGenerator::new(*wl, cfg.scale, cfg.seed).take_ops(ops);
    for op in gen {
        core.step(&op, &mut hier, &mut backend);
    }
    let platform_time_ns = core.finish();
    backend.drain(platform_time_ns);
    (
        platform_time_ns,
        // The full counter block (incl. the latency histogram) rendered
        // via Debug: any drifting field shows up in the diff.
        format!("{:?}", backend.hmmu.counters),
        backend.hmmu.dram_residency(),
    )
}

#[test]
fn batched_platform_bit_identical_to_per_op() {
    let workloads = ["505.mcf", "538.imagick", "557.xz"];
    let policies = [PolicyKind::Static, PolicyKind::Hotness];
    for wl_name in workloads {
        for policy in policies {
            let cfg = cfg_for(policy);
            let wl = spec::by_name(wl_name).unwrap();
            let (ref_time, ref_counters, ref_residency) = run_per_op(&cfg, &wl, OPS);

            // The production path (Platform::run_opts_serial) drives the
            // block pipeline.
            let r = Platform::new(cfg)
                .run_opts_serial(
                    &wl,
                    RunOpts {
                        ops: OPS,
                        flush_at_end: false,
                    },
                )
                .unwrap();
            let label = format!("{wl_name}/{}", policy.name());
            assert_eq!(
                r.platform_time_ns, ref_time,
                "{label}: platform_time_ns diverged"
            );
            assert_eq!(
                format!("{:?}", r.counters),
                ref_counters,
                "{label}: HMMU counters diverged"
            );
            assert!(
                (r.dram_residency - ref_residency).abs() < f64::EPSILON,
                "{label}: residency diverged ({} vs {ref_residency})",
                r.dram_residency
            );
            // Sanity: the comparison exercised real traffic.
            assert!(r.memory_accesses > 0, "{label}: no memory traffic");
        }
    }
}

#[test]
fn block_generator_feeds_exact_op_budget() {
    // The tail block is shorter than TRACE_BLOCK_OPS; the budget must
    // come out exact (no over- or under-generation at block boundaries).
    let cfg = cfg_for(PolicyKind::Static);
    let wl = spec::by_name("519.lbm").unwrap();
    let r = Platform::new(cfg)
        .run_opts_serial(
            &wl,
            RunOpts {
                ops: 10_123,
                flush_at_end: false,
            },
        )
        .unwrap();
    assert_eq!(r.mem_ops, 10_123);
}

#[test]
fn per_op_reference_matches_concurrent_runner_too() {
    // run_opts (concurrent passes) and run_opts_serial share the block
    // pipeline; both must match the per-op reference.
    let cfg = cfg_for(PolicyKind::Hotness);
    let wl = spec::by_name("505.mcf").unwrap();
    let (ref_time, ref_counters, _) = run_per_op(&cfg, &wl, OPS);
    let r = Platform::new(cfg)
        .run_opts(
            &wl,
            RunOpts {
                ops: OPS,
                flush_at_end: false,
            },
        )
        .unwrap();
    assert_eq!(r.platform_time_ns, ref_time);
    assert_eq!(format!("{:?}", r.counters), ref_counters);
}

#[test]
fn multicore_block_path_is_reproducible() {
    // The multicore scheduler consumes per-core blocks through a cursor;
    // the interleaving (and so every counter) must be a pure function of
    // the scenario.
    let cfg = cfg_for(PolicyKind::Hotness);
    let wls = [
        spec::by_name("505.mcf").unwrap(),
        spec::by_name("538.imagick").unwrap(),
        spec::by_name("557.xz").unwrap(),
    ];
    let opts = RunOpts {
        ops: 8_000,
        flush_at_end: false,
    };
    let a = hymem::platform::run_multicore(cfg.clone(), &wls, opts, None).unwrap();
    let b = hymem::platform::run_multicore(cfg, &wls, opts, None).unwrap();
    assert_eq!(a.makespan_ns, b.makespan_ns);
    assert_eq!(format!("{:?}", a.counters), format!("{:?}", b.counters));
    for (ca, cb) in a.cores.iter().zip(&b.cores) {
        assert_eq!(ca.time_ns, cb.time_ns);
        assert_eq!(ca.mem_ops, opts.ops, "every core runs its full budget");
        assert_eq!(ca.instructions, cb.instructions);
    }
}

#[test]
fn multicore_sweep_scenarios_deterministic_across_thread_counts() {
    // The new cores axis: single-core and 2-/4-core scenarios in one
    // sweep, fingerprint pinned at 1/2/4 sweep threads.
    let base = cfg_for(PolicyKind::Hotness);
    let wl = spec::by_name("505.mcf").unwrap();
    let xz = spec::by_name("557.xz").unwrap();
    let single = vec![
        Scenario::new("mcf/hotness", wl, base.clone(), 6_000),
        Scenario::new("xz/hotness", xz, base, 6_000),
    ];
    let scenarios = Scenario::cores_grid(&single, &[1, 2, 4]);
    assert_eq!(scenarios.len(), 6);
    assert_eq!(scenarios[2].cores, 4);

    let fp_serial = run_sweep(&scenarios, 1).unwrap().deterministic_fingerprint();
    assert_eq!(fp_serial.lines().count(), 6);
    assert!(fp_serial.contains("mcf/hotnessx4"));
    assert!(fp_serial.contains("cores=2"));
    for threads in [2usize, 4] {
        let fp = run_sweep(&scenarios, threads)
            .unwrap()
            .deterministic_fingerprint();
        assert_eq!(
            fp_serial, fp,
            "multicore sweep diverged at {threads} threads"
        );
    }
}

#[test]
fn generator_block_stream_equals_iterator_stream() {
    // Belt-and-braces at the trace level (unit tests cover this per
    // module; this pins it for the shipped workload set end to end).
    for wl in ["505.mcf", "519.lbm", "538.imagick", "557.xz"] {
        let spec = spec::by_name(wl).unwrap();
        let per_op: Vec<_> = TraceGenerator::new(spec, 64, 0x5EED).take_ops(9_000).collect();
        let mut gen = TraceGenerator::new(spec, 64, 0x5EED).take_ops(9_000);
        let mut block = TraceBlock::with_capacity(1024);
        let mut batched = Vec::with_capacity(per_op.len());
        while gen.fill_block(&mut block) > 0 {
            batched.extend(block.iter());
        }
        assert_eq!(per_op, batched, "{wl}: generator streams diverged");
    }
}
