//! The tier-generic substrate's compatibility contract: the two-tier
//! default — now just a 2-entry tier stack — is **bit-identical** to the
//! pre-refactor DRAM/NVM pair (full `HmmuCounters` Debug, residency and
//! `platform_time_ns` pinned, Debug rendering keeping the legacy scalar
//! field names), and a three-or-more-tier scenario runs end to end
//! through the sweep with per-tier counters, energy and wear in the JSON
//! report and the topology in the scenario fingerprint.

use hymem::config::{MemTech, PolicyKind, SystemConfig};
use hymem::mem::{AccessKind, DramDevice, MemoryController, NvmDevice, TierDevice};
use hymem::platform::{Platform, RunOpts, RunReport};
use hymem::sim::Clock;
use hymem::sweep::{run_sweep, Scenario};
use hymem::util::rng::Xoshiro256;
use hymem::workload::spec;

const OPS: u64 = 30_000;

fn run(cfg: SystemConfig, wl: &str, flush: bool) -> RunReport {
    Platform::new(cfg)
        .run_opts_serial(
            &spec::by_name(wl).unwrap(),
            RunOpts {
                ops: OPS,
                flush_at_end: flush,
            },
        )
        .unwrap()
}

/// The substrate layer the refactor actually replaced: a two-tier
/// `MemoryController<TierDevice>` stack must produce completion times,
/// device stats and queue stalls **identical** to the legacy
/// `MemoryController<DramDevice>` / `MemoryController<NvmDevice>` pair
/// it superseded, on an interleaved seeded workload. (The pipeline
/// above the controllers is unchanged code, so this pins the pre/post
/// bit-identity claim at the layer that changed; the run-level
/// batteries in `batch_equivalence.rs` and the golden snapshots pin
/// the rest.)
#[test]
fn two_tier_stack_timing_matches_legacy_device_pair() {
    let cfg = SystemConfig::default_scaled(64);
    let specs = cfg.tier_specs();
    let mc_clock = Clock::from_mhz(1200.0);
    let page = cfg.hmmu.page_bytes;

    // Tier stack, exactly as Hmmu::new builds it.
    let mut tiers: Vec<MemoryController<TierDevice>> = specs
        .iter()
        .map(|s| {
            MemoryController::new(
                TierDevice::build(s, cfg.dram, page),
                mc_clock,
                4,
                cfg.dram.queue_depth,
            )
        })
        .collect();
    // Legacy pair, exactly as the pre-refactor Hmmu built it.
    let mut dram_mc =
        MemoryController::new(DramDevice::new(cfg.dram), mc_clock, 4, cfg.dram.queue_depth);
    let mut nvm_mc = MemoryController::new(
        NvmDevice::new(cfg.nvm, cfg.dram, page),
        mc_clock,
        4,
        cfg.dram.queue_depth,
    );

    let mut rng = Xoshiro256::new(0x7EE5);
    let mut t = 0u64;
    for i in 0..20_000u64 {
        let tier1 = rng.chance(0.6);
        let size = if tier1 { cfg.nvm.size_bytes } else { cfg.dram.size_bytes };
        let addr = rng.below(size) & !63;
        let kind = if rng.chance(0.3) { AccessKind::Write } else { AccessKind::Read };
        // Bursty arrivals so the bounded queues genuinely stall.
        t += if rng.chance(0.8) { 2 } else { rng.below(4000) };
        let got = tiers[usize::from(tier1)].issue(addr, kind, 64, t);
        let want = if tier1 {
            nvm_mc.issue(addr, kind, 64, t)
        } else {
            dram_mc.issue(addr, kind, 64, t)
        };
        assert_eq!(got, want, "op {i}: completion diverged");
    }
    assert!(
        tiers[0].stalls + tiers[1].stalls > 0,
        "workload must exercise the queue-stall path"
    );
    assert_eq!(tiers[0].stalls, dram_mc.stalls);
    assert_eq!(tiers[1].stalls, nvm_mc.stalls);
    assert_eq!(tiers[0].queue_wait_ns, dram_mc.queue_wait_ns);
    assert_eq!(tiers[1].queue_wait_ns, nvm_mc.queue_wait_ns);
    assert_eq!(
        format!("{:?}", tiers[0].device().stats()),
        format!("{:?}", dram_mc.device().stats())
    );
    assert_eq!(
        format!("{:?}", tiers[1].device().stats()),
        format!("{:?}", nvm_mc.device().stats())
    );
    assert_eq!(tiers[1].device().max_wear(), nvm_mc.device().max_wear());
}

/// The explicit `dram+xpoint` topology must be a pure identity over the
/// default config — same stall point, same stack, byte-identical run —
/// so the topology plumbing cannot perturb the two-tier default. (This
/// guards the `with_tiers` path, not pre/post-refactor drift — that is
/// the job of the device-pair pin above and the golden snapshots.)
#[test]
fn two_tier_default_bit_identical_to_explicit_topology() {
    for (policy, flush) in [
        (PolicyKind::Static, false),
        (PolicyKind::Hotness, false),
        (PolicyKind::FirstTouch, true),
        (PolicyKind::WearAware, false),
    ] {
        let mut base = SystemConfig::default_scaled(64);
        base.policy = policy;
        base.hmmu.epoch_requests = 2_000;
        let explicit = base
            .clone()
            .with_tiers(&[MemTech::Dram, MemTech::Xpoint3D])
            .unwrap();

        let a = run(base, "520.omnetpp", flush);
        let b = run(explicit, "520.omnetpp", flush);
        let label = format!("{policy:?}/flush={flush}");
        assert_eq!(
            a.platform_time_ns, b.platform_time_ns,
            "{label}: platform_time_ns diverged"
        );
        assert_eq!(
            format!("{:?}", a.counters),
            format!("{:?}", b.counters),
            "{label}: HmmuCounters Debug diverged"
        );
        assert!(
            (a.dram_residency - b.dram_residency).abs() < f64::EPSILON,
            "{label}: residency diverged"
        );
        assert_eq!(a.tier_residency, b.tier_residency, "{label}");
        assert_eq!(a.tier_wear, b.tier_wear, "{label}");
        assert_eq!(a.topology, "dram+xpoint");
        assert_eq!(
            format!("{:?}", a.energy.tiers),
            format!("{:?}", b.energy.tiers),
            "{label}: energy diverged"
        );
    }
}

/// The two-tier Debug surface keeps the legacy scalar field names (the
/// golden counter snapshots compare this rendering verbatim) and never
/// renders the per-tier vectors.
#[test]
fn two_tier_counter_debug_keeps_legacy_layout() {
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::Hotness;
    cfg.hmmu.epoch_requests = 2_000;
    let r = run(cfg, "520.omnetpp", false);
    let s = format!("{:?}", r.counters);
    for field in [
        "host_reads",
        "dram_reads",
        "dram_writes",
        "nvm_reads",
        "nvm_writes",
        "pages_placed_dram",
        "pages_placed_nvm",
        "migrations",
        "pcie_dma_bytes",
    ] {
        assert!(s.contains(field), "missing legacy field {field}: {s}");
    }
    assert!(
        !s.contains("tier_reads"),
        "two-tier Debug must not render tier vectors: {s}"
    );
    // The legacy scalars are views of the tier vectors.
    assert_eq!(r.counters.dram_reads(), r.counters.tier_reads[0]);
    assert_eq!(r.counters.nvm_writes(), r.counters.tier_writes[1]);
}

/// A three-tier demotion scenario (hot→DRAM, warm→PCM, cold→3D XPoint)
/// runs end to end through `hymem sweep`'s engine: migrations fire, the
/// per-tier counters/energy/wear columns are populated in the JSON
/// report, and the tier topology participates in the deterministic
/// fingerprint.
#[test]
fn three_tier_scenario_is_a_sweep_citizen() {
    let mut base = SystemConfig::default_scaled(64);
    base.policy = PolicyKind::Hotness;
    base.hmmu.epoch_requests = 2_000;
    let scenarios = Scenario::tier_grid(
        &[Scenario::new(
            "omnetpp/hotness",
            spec::by_name("520.omnetpp").unwrap(),
            base,
            60_000,
        )],
        &[vec![MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D]],
    )
    .unwrap();
    assert_eq!(scenarios[0].name, "omnetpp/hotness~dram+pcm+xpoint");
    assert_eq!(scenarios[0].cfg.tier_count(), 3);

    let report = run_sweep(&scenarios, 1).unwrap();
    let r = &report.scenarios[0];
    assert_eq!(r.topology, "dram+pcm+xpoint");
    assert!(r.migrations > 0, "three-tier scenario must migrate");
    assert_eq!(r.tier_reads.len(), 3);
    assert_eq!(r.tier_writes.len(), 3);
    assert_eq!(r.tier_residency.len(), 3);
    assert_eq!(r.tier_wear.len(), 3);
    assert_eq!(r.tier_energy_mj.len(), 3);
    assert!(
        r.tier_residency.iter().sum::<u64>() > 0,
        "residency must be populated"
    );
    assert!(r.tier_energy_mj.iter().all(|&e| e >= 0.0));

    // Topology is part of the fingerprint; JSON carries the per-tier
    // columns.
    let fp = report.deterministic_fingerprint();
    assert!(fp.contains("tiers=dram+pcm+xpoint"), "{fp}");
    assert!(fp.contains("tres="), "{fp}");
    let js = report.to_json().render();
    assert!(js.contains("\"topology\":\"dram+pcm+xpoint\""));
    for key in ["tier_reads", "tier_writes", "tier_residency", "tier_wear", "tier_energy_mj"] {
        assert!(js.contains(&format!("\"{key}\":[")), "missing {key} in JSON");
    }
}

/// Three-tier runs are deterministic and sweep-thread-independent like
/// every other scenario shape.
#[test]
fn three_tier_sweep_deterministic_across_thread_counts() {
    let mut base = SystemConfig::default_scaled(64);
    base.policy = PolicyKind::Hotness;
    base.hmmu.epoch_requests = 2_000;
    let two = Scenario::new(
        "mcf/hotness",
        spec::by_name("505.mcf").unwrap(),
        base.clone(),
        10_000,
    );
    let scenarios = Scenario::tier_grid(
        &[two],
        &[
            vec![MemTech::Dram, MemTech::Xpoint3D],
            vec![MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D],
            vec![MemTech::Dram, MemTech::Memristor, MemTech::Pcm, MemTech::Xpoint3D],
        ],
    )
    .unwrap();
    assert_eq!(scenarios.len(), 3);
    assert_eq!(scenarios[2].cfg.tier_count(), 4);
    let fp1 = run_sweep(&scenarios, 1).unwrap().deterministic_fingerprint();
    for threads in [2usize, 3] {
        let fp = run_sweep(&scenarios, threads)
            .unwrap()
            .deterministic_fingerprint();
        assert_eq!(fp1, fp, "tier sweep diverged at {threads} threads");
    }
}
