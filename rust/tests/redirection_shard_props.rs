//! Sharded redirection-table property pins.
//!
//! The sharded table's contract: **every shard count is bit-identical to
//! the monolithic table** (`nshards == 1`) — same placements, same
//! fallback order, same swap/retire outcomes, same counter surface —
//! under arbitrary churn, at the table level and end-to-end through the
//! HMMU with the fault layer retiring frames mid-run.

use hymem::config::{MemTech, PolicyKind, SystemConfig};
use hymem::cpu::{CacheHierarchy, CoreModel};
use hymem::hmmu::redirection::DEFAULT_SHARDS;
use hymem::hmmu::{Mapping, RedirectionTable, TierId};
use hymem::platform::HmmuBackend;
use hymem::workload::{spec, TraceGenerator};

/// Deterministic splitmix64 stream (no rand dependency).
fn mix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Full observable-surface equality against `tables[0]` (the monolithic
/// reference), plus the internal invariant check on every table.
fn assert_surfaces_equal(tables: &[RedirectionTable]) {
    let a = &tables[0];
    a.check_invariants().unwrap();
    for b in &tables[1..] {
        let n = b.shard_count();
        b.check_invariants().unwrap();
        assert_eq!(a.mapped_pages(), b.mapped_pages(), "{n} shards");
        assert_eq!(a.residency(), b.residency(), "{n} shards");
        for t in 0..a.tiers() {
            let tier = TierId(t as u8);
            assert_eq!(a.free_frames(tier), b.free_frames(tier), "{tier:?} ({n} shards)");
            assert_eq!(a.retired_frames(tier), b.retired_frames(tier), "{tier:?} ({n} shards)");
            assert_eq!(a.effective_frames(tier), b.effective_frames(tier), "{tier:?} ({n} shards)");
            assert_eq!(a.resident_pages(tier), b.resident_pages(tier), "{tier:?} ({n} shards)");
            assert_eq!(a.recount_resident(tier), b.recount_resident(tier), "{tier:?} ({n} shards)");
        }
        let ma: Vec<(u64, Mapping)> = a.iter_mapped().collect();
        let mb: Vec<(u64, Mapping)> = b.iter_mapped().collect();
        assert_eq!(ma, mb, "mapped surface diverged at {n} shards");
    }
}

/// Drive the identical place/swap/retire/lookup churn through every
/// table, asserting per-call result equality and (periodically) full
/// surface equality.
fn churn(tables: &mut [RedirectionTable], seed: u64, steps: u64) {
    let host_pages = tables[0].host_pages();
    let tiers = tables[0].tiers() as u64;
    let mut s = seed;
    for step in 0..steps {
        let a = mix(&mut s) % host_pages;
        let b = mix(&mut s) % host_pages;
        match mix(&mut s) % 10 {
            0..=4 => {
                if tables[0].lookup(a).is_none() {
                    let want = TierId((mix(&mut s) % tiers) as u8);
                    let got: Vec<Mapping> =
                        tables.iter_mut().map(|t| t.place(a, want).unwrap()).collect();
                    assert!(got.windows(2).all(|w| w[0] == w[1]), "place({a}) diverged: {got:?}");
                }
            }
            5..=6 => {
                if a != b && tables[0].lookup(a).is_some() && tables[0].lookup(b).is_some() {
                    for t in tables.iter_mut() {
                        t.swap(a, b).unwrap();
                    }
                }
            }
            7..=8 => {
                if tables[0].lookup(a).is_some() {
                    let got: Vec<Option<Mapping>> = tables
                        .iter_mut()
                        .map(|t| t.retire_and_remap(a).unwrap())
                        .collect();
                    assert!(got.windows(2).all(|w| w[0] == w[1]), "retire({a}) diverged: {got:?}");
                }
            }
            _ => {
                let m = tables[0].lookup(a);
                assert!(tables.iter().all(|t| t.lookup(a) == m), "lookup({a}) diverged");
                let x = tables[0].translate(a * tables[0].page_bytes() + 17);
                assert!(
                    tables.iter().all(|t| t.translate(a * t.page_bytes() + 17) == x),
                    "translate({a}) diverged"
                );
            }
        }
        if step % 512 == 0 {
            assert_surfaces_equal(tables);
        }
    }
    assert_surfaces_equal(tables);
}

fn tables_for(host_pages: u64, frames: &[u32], shard_counts: &[usize]) -> Vec<RedirectionTable> {
    shard_counts
        .iter()
        .map(|&n| RedirectionTable::new_with_shards(host_pages, frames, 4096, n))
        .collect()
}

#[test]
fn churn_battery_matches_monolithic_across_shard_counts() {
    // Shard 1 is the monolithic reference; 16 > stripes exercises
    // shards that own zero page stripes but still hold frame pools.
    let counts = [1usize, 2, 4, DEFAULT_SHARDS, 16];
    // (host_pages, tier frame stack): 2- and 3-tier, DRAM smaller than
    // the demand so placement overflows down the stack.
    let stacks: [(u64, &[u32]); 2] = [(512, &[96, 448]), (512, &[64, 128, 384])];
    for (host_pages, frames) in stacks {
        let mut tables = tables_for(host_pages, frames, &counts);
        churn(&mut tables, 0x5EED ^ host_pages ^ frames.len() as u64, 4_000);
    }
}

#[test]
fn identity_map_is_shard_invariant() {
    // 64 NVM frames stay free after the identity fill, so the
    // post-identity churn still exercises retirement remaps.
    let mut tables = tables_for(448, &[128, 384], &[1, 4, DEFAULT_SHARDS]);
    for t in tables.iter_mut() {
        t.identity_map();
    }
    assert_surfaces_equal(&tables);
    // Identity layout: page p sits on the p-th frame walking the stack.
    for t in &tables {
        assert_eq!(t.lookup(0), Some(Mapping { device: TierId::Dram, frame: 0 }));
        assert_eq!(t.lookup(127), Some(Mapping { device: TierId::Dram, frame: 127 }));
        assert_eq!(t.lookup(128), Some(Mapping { device: TierId::Nvm, frame: 0 }));
    }
    // Post-identity churn (swap/retire only — everything is mapped).
    churn(&mut tables, 0xFACE, 2_000);
}

#[test]
fn exhaustion_and_fallback_order_match_monolithic() {
    // host_pages == total frames: retiring frames shrinks capacity below
    // the page count, so both the "no free frames" place error and the
    // `Ok(None)` retire denial become reachable — and must agree.
    let mut tables = tables_for(128, &[64, 64], &[1, DEFAULT_SHARDS]);
    for page in 0..100u64 {
        let want = TierId((page % 2) as u8);
        let got: Vec<Mapping> =
            tables.iter_mut().map(|t| t.place(page, want).unwrap()).collect();
        assert_eq!(got[0], got[1], "fallback order diverged at page {page}");
    }
    for page in 0..28u64 {
        let got: Vec<Option<Mapping>> = tables
            .iter_mut()
            .map(|t| t.retire_and_remap(page).unwrap())
            .collect();
        assert_eq!(got[0], got[1], "retire remap diverged at page {page}");
        assert!(got[0].is_some(), "free frames remain, retire must remap");
    }
    assert_surfaces_equal(&tables);
    for t in &tables {
        assert_eq!(t.free_frames(TierId::Dram) + t.free_frames(TierId::Nvm), 0);
        assert_eq!(t.retired_frames(TierId::Dram) + t.retired_frames(TierId::Nvm), 28);
    }
    // No free frame anywhere: placement fails, retirement is denied
    // (the page survives on its degraded frame) — identically.
    for t in tables.iter_mut() {
        assert!(t.place(120, TierId::Dram).is_err(), "place on exhausted stack must fail");
        assert_eq!(t.retire_and_remap(50).unwrap(), None);
    }
    assert_surfaces_equal(&tables);
}

/// Rebuild the redirection table exactly as `Hmmu::new` does, but with
/// an explicit shard count — the monolithic reference for the
/// end-to-end runs below.
fn table_like_hmmu(cfg: &SystemConfig, nshards: usize) -> RedirectionTable {
    let page_bytes = cfg.hmmu.page_bytes;
    let frames: Vec<u32> = cfg
        .tier_specs()
        .iter()
        .map(|s| (s.size_bytes / page_bytes) as u32)
        .collect();
    let mut table =
        RedirectionTable::new_with_shards(cfg.total_pages(), &frames, page_bytes, nshards);
    if cfg.policy == PolicyKind::Static {
        table.identity_map();
    }
    table
}

/// Every surface the sweep fingerprints: platform time, the full
/// counter block, residency, retired-frame counts, mapped pages.
#[derive(PartialEq, Debug)]
struct Surface {
    time_ns: u64,
    counters: String,
    residency: Vec<u64>,
    retired: Vec<usize>,
    mapped: Vec<(u64, Mapping)>,
}

/// One full platform pass; `mono` swaps the HMMU's table for a 1-shard
/// build before the first access.
fn run_hmmu(cfg: &SystemConfig, wl_name: &str, ops: u64, mono: bool) -> Surface {
    let mut backend = HmmuBackend::new(cfg.clone(), None);
    if mono {
        backend.hmmu.table = table_like_hmmu(cfg, 1);
    }
    assert_eq!(backend.hmmu.table.shard_count(), if mono { 1 } else { DEFAULT_SHARDS });
    let mut core = CoreModel::new(cfg.cpu);
    let mut hier = CacheHierarchy::new(cfg);
    let wl = spec::by_name(wl_name).unwrap();
    let gen = TraceGenerator::new(wl, cfg.scale, cfg.seed).take_ops(ops);
    for op in gen {
        core.step(&op, &mut hier, &mut backend);
    }
    let t = core.finish();
    backend.drain(t);
    let table = &backend.hmmu.table;
    table.check_invariants().unwrap();
    Surface {
        time_ns: t,
        counters: format!("{:?}", backend.hmmu.counters),
        residency: table.residency().to_vec(),
        retired: (0..table.tiers())
            .map(|i| table.retired_frames(TierId(i as u8)))
            .collect(),
        mapped: table.iter_mapped().collect(),
    }
}

#[test]
fn hmmu_runs_bit_identical_mono_vs_sharded_under_fault_churn() {
    // 2- and 3-tier stacks × policies, with the fault layer hot enough
    // to retire frames mid-run: the sharded table must not move a single
    // counter, page, or nanosecond against the monolithic one.
    let base = SystemConfig::default_scaled(64);
    let three = base
        .clone()
        .with_tiers(&[MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D])
        .unwrap();
    let mut total_retired = 0usize;
    for stack in [&base, &three] {
        for policy in [PolicyKind::Static, PolicyKind::Hotness, PolicyKind::WearAware] {
            let mut cfg = stack.clone();
            cfg.policy = policy;
            cfg.hmmu.epoch_requests = 2_000;
            // Aggressive wear + error knobs so frames actually die
            // inside 12k ops (`tests/fault_props.rs` calibration).
            cfg.nvm.endurance = 16;
            cfg.fault.rber_base = 2e-2;
            cfg.fault.uncorrectable_frac = 0.2;
            let label = format!("{}/{policy:?}", cfg.topology_label());

            let sharded = run_hmmu(&cfg, "505.mcf", 12_000, false);
            let mono = run_hmmu(&cfg, "505.mcf", 12_000, true);
            assert_eq!(sharded, mono, "mono vs sharded diverged: {label}");
            total_retired += sharded.retired.iter().sum::<usize>();
        }
    }
    assert!(
        total_retired > 0,
        "fault churn never retired a frame — the battery is vacuous"
    );
}
