//! Fault-injection & graceful-degradation property battery.
//!
//! The fault layer's contract has three halves:
//!
//! 1. **Default-off is free and invisible.** With `FaultConfig` disabled
//!    (the default), every surface — platform time, the full counter
//!    Debug block, residency, sweep fingerprints — is byte-identical to
//!    a build without the layer, across policies, and invariant to the
//!    (inert) fault-stream seed.
//! 2. **Degradation is graceful and accounted.** Wear-exhausted frames
//!    retire into per-tier retired pools, their pages emergency-remap to
//!    healthy frames, effective capacity shrinks, and the run completes
//!    with the redirection invariants intact (retired frames never
//!    re-allocated, residency summing to mapped).
//! 3. **Faulted runs stay deterministic.** The dedicated fault RNG
//!    stream makes results a pure function of the scenario: identical
//!    across reruns, across sweep thread counts, and across
//!    checkpoint/fork vs cold replay (both fault RNGs ride the codec).

use hymem::config::{FaultConfig, PolicyKind, SystemConfig, MAX_TIERS};
use hymem::hmmu::{Hmmu, TierId};
use hymem::mem::AccessKind;
use hymem::platform::{Platform, RunOpts, WarmPlatform};
use hymem::sweep::{run_sweep, Scenario};
use hymem::workload::spec;

fn opts(ops: u64) -> RunOpts {
    RunOpts {
        ops,
        flush_at_end: false,
    }
}

/// A config whose fault layer injects heavily enough for every property
/// below to fire within a few thousand ops.
fn faulty_cfg(policy: PolicyKind) -> SystemConfig {
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = policy;
    cfg.hmmu.epoch_requests = 2_000;
    cfg.nvm.endurance = 64;
    cfg.fault.rber_base = 1e-2;
    cfg.fault.link_ber = 1e-2;
    cfg
}

#[test]
fn fault_off_is_invisible_and_seed_invariant_across_policies() {
    let wl = spec::by_name("505.mcf").unwrap();
    for policy in [PolicyKind::Static, PolicyKind::Hotness, PolicyKind::WearAware] {
        let mut base = SystemConfig::default_scaled(64);
        base.policy = policy;
        base.hmmu.epoch_requests = 2_000;
        assert!(!base.fault.enabled(), "fault layer must default off");

        // The fault-stream seed and curve knobs are inert while the layer
        // is off: changing them must not move a single byte of output.
        let mut reseeded = base.clone();
        reseeded.fault.seed = 0xDEAD_BEEF;
        reseeded.fault.rber_wear_slope = 99.0;
        reseeded.fault.ecc_latency_ns = 9_999;

        let a = Platform::new(base).run_opts_serial(&wl, opts(8_000)).unwrap();
        let b = Platform::new(reseeded).run_opts_serial(&wl, opts(8_000)).unwrap();
        assert_eq!(a.platform_time_ns, b.platform_time_ns, "{policy:?}");
        assert_eq!(a.native_time_ns, b.native_time_ns, "{policy:?}");
        assert_eq!(
            format!("{:#?}", a.counters),
            format!("{:#?}", b.counters),
            "{policy:?}"
        );
        assert_eq!(a.tier_residency, b.tier_residency, "{policy:?}");
        // And the counter block renders no fault fields at all, so the
        // golden Debug surface is byte-identical to pre-fault-layer runs.
        let debug = format!("{:#?}", a.counters);
        assert!(!debug.contains("ecc_corrected"), "{policy:?}: {debug}");
        assert!(!debug.contains("link_retries"), "{policy:?}: {debug}");
    }
}

#[test]
fn retirement_churn_keeps_residency_consistent_and_never_reallocates() {
    // Drive the HMMU directly through heavy wear-out churn, checking the
    // table invariants (retired frames absent from free pools and
    // mappings, residency counters exact) at every epoch-scale interval.
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::FirstTouch;
    cfg.hmmu.epoch_requests = 100_000;
    cfg.nvm.endurance = 16;
    cfg.fault.rber_base = 1e-6; // death comes from wear, not soft errors
    let mut h = Hmmu::new(cfg, None);
    let page_bytes = h.config().hmmu.page_bytes;
    let dram_pages = h.config().dram_pages();
    let mut t = 0;
    // Fill DRAM so subsequent pages land on the wear-limited rank.
    for p in 0..dram_pages {
        t = h.access(p * page_bytes, AccessKind::Read, 64, t + 50);
    }
    for round in 0..40u64 {
        for i in 0..60u64 {
            let p = dram_pages + (i % 12);
            t = h.access(p * page_bytes, AccessKind::Write, 64, t + 50);
        }
        h.drain(t + 10_000_000);
        assert_eq!(
            h.tier_residency().iter().sum::<u64>(),
            h.table.mapped_pages(),
            "round {round}: residency must sum to mapped pages"
        );
        h.table
            .check_invariants()
            .unwrap_or_else(|e| panic!("round {round}: {e:#}"));
    }
    assert!(h.counters.frames_retired > 0, "churn must retire frames");
    assert_eq!(h.counters.frames_retired, h.counters.remap_migrations);
    assert_eq!(h.counters.remap_bytes, h.counters.remap_migrations * page_bytes);
    assert!(h.table.retired_frames(TierId::Nvm) > 0);
    assert!(
        h.table.effective_frames(TierId::Nvm) < h.config().nvm.size_bytes / page_bytes,
        "retirement must shrink effective capacity"
    );
}

#[test]
fn degraded_platform_run_survives_to_completion() {
    // End to end: a platform run under aggressive wear + link corruption
    // retires frames, remaps pages, replays TLPs — and still produces a
    // complete, self-consistent report.
    let wl = spec::by_name("519.lbm").unwrap();
    let r = Platform::new(faulty_cfg(PolicyKind::FirstTouch))
        .run_opts_serial(&wl, opts(60_000))
        .unwrap();
    assert!(r.platform_time_ns > 0);
    assert!(r.counters.ecc_corrected > 0, "rber 1e-2 must correct errors");
    assert!(r.counters.frames_retired > 0, "endurance 64 must kill frames");
    assert_eq!(r.counters.frames_retired, r.counters.remap_migrations);
    assert!(r.counters.link_retries > 0, "link ber must force replays");
    // The faulted counters now render in Debug (and only now).
    let debug = format!("{:#?}", r.counters);
    assert!(debug.contains("ecc_corrected"), "{debug}");
    assert!(debug.contains("frames_retired"), "{debug}");
}

#[test]
fn faulted_sweep_is_deterministic_across_thread_counts() {
    let workloads = [
        spec::by_name("505.mcf").unwrap(),
        spec::by_name("557.xz").unwrap(),
    ];
    let base = faulty_cfg(PolicyKind::Hotness);
    let grid = Scenario::grid(
        &workloads,
        &[PolicyKind::Hotness, PolicyKind::WearAware],
        &base,
        6_000,
    );
    let grid = Scenario::fault_grid(&grid, &[0.0, 1e-2]);
    assert_eq!(grid.len(), 8);

    let fp1 = run_sweep(&grid, 1).unwrap().deterministic_fingerprint();
    for threads in [2usize, 4] {
        let fp = run_sweep(&grid, threads).unwrap().deterministic_fingerprint();
        assert_eq!(fp1, fp, "faulted sweep diverged at {threads} threads");
    }
    // The heavily-faulted rows (rber 1e-2 over thousands of accesses)
    // must carry the fault block in their fingerprint.
    let faulted: Vec<&str> = fp1.lines().filter(|l| l.contains("%0.01")).collect();
    assert_eq!(faulted.len(), 4);
    for line in faulted {
        assert!(line.contains("|eccC="), "{line}");
    }
}

#[test]
fn fault_free_fingerprint_carries_no_fault_block() {
    let wl = spec::by_name("541.leela").unwrap();
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::Hotness;
    cfg.hmmu.epoch_requests = 2_000;
    let grid = vec![Scenario::new("leela/hotness", wl, cfg, 4_000)];
    let fp = run_sweep(&grid, 1).unwrap().deterministic_fingerprint();
    assert!(
        !fp.contains("eccC=") && !fp.contains("linkRetry="),
        "healthy fingerprints must be byte-identical to pre-fault-layer builds: {fp}"
    );
}

#[test]
fn faulted_checkpoint_fork_is_bit_identical_to_cold_replay() {
    // Both fault RNG streams (HMMU wear/ECC draws, link corruption
    // draws) ride the checkpoint codec: a warmed, serialized, restored
    // run must replay the exact fault sequence a cold run draws.
    let wl = spec::by_name("505.mcf").unwrap();
    let cfg = faulty_cfg(PolicyKind::Hotness);
    let run_opts = opts(8_000);

    let cold = WarmPlatform::new(cfg.clone(), &wl, run_opts)
        .run_to_completion()
        .unwrap();
    assert!(
        cold.counters.ecc_corrected > 0 && cold.counters.link_retries > 0,
        "scenario must actually fault"
    );

    let mut warm = WarmPlatform::new(cfg.clone(), &wl, run_opts);
    warm.warm_up(4_000);
    let bytes = warm.save();
    let restored = WarmPlatform::load(&bytes, cfg, &wl, run_opts).unwrap();

    for (label, report) in [
        ("in-memory fork", warm.run_to_completion().unwrap()),
        ("serialized round trip", restored.run_to_completion().unwrap()),
    ] {
        assert_eq!(cold.platform_time_ns, report.platform_time_ns, "{label}");
        assert_eq!(
            format!("{:#?}", cold.counters),
            format!("{:#?}", report.counters),
            "{label}"
        );
        assert_eq!(cold.tier_residency, report.tier_residency, "{label}");
        assert_eq!(cold.tier_wear, report.tier_wear, "{label}");
    }
}

#[test]
fn explicit_boundary_budget_pins_legacy_behavior() {
    // `migrations_per_boundary` unset (all zeros) must behave exactly as
    // every boundary set to the global `migrations_per_epoch` cap — the
    // pre-config-knob behavior — and a tight budget must throttle.
    let wl = spec::by_name("520.omnetpp").unwrap();
    let mut legacy = SystemConfig::default_scaled(64);
    legacy.policy = PolicyKind::Hotness;
    legacy.hmmu.epoch_requests = 2_000;
    assert_eq!(legacy.hmmu.migrations_per_boundary, [0; MAX_TIERS - 1]);

    let mut pinned = legacy.clone();
    pinned.hmmu.migrations_per_boundary =
        [legacy.hmmu.migrations_per_epoch; MAX_TIERS - 1];

    let a = Platform::new(legacy.clone()).run_opts_serial(&wl, opts(30_000)).unwrap();
    let b = Platform::new(pinned).run_opts_serial(&wl, opts(30_000)).unwrap();
    assert_eq!(a.platform_time_ns, b.platform_time_ns);
    assert_eq!(format!("{:#?}", a.counters), format!("{:#?}", b.counters));
    assert!(a.counters.migrations > 0, "scenario must migrate");

    let mut tight = legacy;
    tight.hmmu.migrations_per_boundary = [1; MAX_TIERS - 1];
    let c = Platform::new(tight).run_opts_serial(&wl, opts(30_000)).unwrap();
    assert!(
        c.counters.migrations < a.counters.migrations,
        "budget 1/boundary must throttle migrations ({} vs {})",
        c.counters.migrations,
        a.counters.migrations
    );
}

#[test]
fn fault_config_constructor_matches_default() {
    assert_eq!(
        format!("{:?}", FaultConfig::disabled()),
        format!("{:?}", FaultConfig::default())
    );
    assert!(!FaultConfig::default().enabled());
}
