//! Middleware-path integration (paper Fig 4 + §III-G): driver frame pool
//! → jemalloc-like arenas → placement hints → HMMU placement; plus
//! allocator property sweeps and failure injection.

use hymem::alloc::{ArenaAllocator, GenPool, HintStore, Placement};
use hymem::config::{PolicyKind, SystemConfig};
use hymem::hmmu::{Device, Hmmu};
use hymem::mem::AccessKind;
use hymem::util::prop::run_prop;

#[test]
fn hints_flow_from_malloc_to_hmmu_placement() {
    // Allocate with hints through the middleware, then touch the memory
    // through the HMMU: placement must honor the hints (§III-G).
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::Hints;
    let page = cfg.hmmu.page_bytes;

    let pool = GenPool::new(0, cfg.total_mem_bytes(), page);
    let mut arena = ArenaAllocator::new(pool);

    // Cold bulk data -> NVM; latency-critical index -> pinned DRAM.
    let bulk = arena.malloc_hint(64 * page, Placement::PreferNvm).unwrap();
    let index = arena.malloc_hint(4 * page, Placement::PinDram).unwrap();
    let plain = arena.malloc(2 * page).unwrap();

    let mut hmmu = Hmmu::new(cfg, None);
    hmmu.set_hints(arena.hints().clone());

    let mut t = 0;
    for off in (0..64 * page).step_by(page as usize) {
        t = hmmu.access(bulk + off, AccessKind::Write, 64, t + 100);
    }
    for off in (0..4 * page).step_by(page as usize) {
        t = hmmu.access(index + off, AccessKind::Read, 64, t + 100);
    }
    hmmu.access(plain, AccessKind::Read, 64, t + 100);

    // Bulk pages must be NVM-resident; index pages DRAM-resident.
    for off in (0..64 * page).step_by(page as usize) {
        let (dev, _) = hmmu.table.translate(bulk + off).unwrap();
        assert_eq!(dev, Device::Nvm, "bulk page at +{off} not in NVM");
    }
    for off in (0..4 * page).step_by(page as usize) {
        let (dev, _) = hmmu.table.translate(index + off).unwrap();
        assert_eq!(dev, Device::Dram, "index page at +{off} not in DRAM");
    }
}

#[test]
fn prop_arena_alloc_free_never_overlaps() {
    run_prop("arena-no-overlap", |rng| {
        let mut arena = ArenaAllocator::new(GenPool::new(0x10_0000, 8 << 20, 4096));
        let mut live: Vec<(u64, u64)> = Vec::new();
        for _ in 0..200 {
            if live.is_empty() || rng.chance(0.6) {
                let size = 1 + rng.below(100_000);
                if let Ok(addr) = arena.malloc(size) {
                    // No overlap with any live allocation.
                    for &(a, s) in &live {
                        assert!(
                            addr + size <= a || a + s <= addr,
                            "overlap: new [{addr:#x},+{size}) vs live [{a:#x},+{s})"
                        );
                    }
                    live.push((addr, size));
                }
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                let (addr, _) = live.swap_remove(idx);
                arena.free(addr).unwrap();
            }
        }
    });
}

#[test]
fn prop_genpool_free_bytes_conserved() {
    run_prop("genpool-conservation", |rng| {
        let cap = 4 << 20;
        let mut pool = GenPool::new(0, cap, 4096);
        let mut live: Vec<(u64, u64)> = Vec::new();
        for _ in 0..100 {
            if live.is_empty() || rng.chance(0.55) {
                let bytes = 1 + rng.below(300_000);
                if let Ok(a) = pool.alloc(bytes) {
                    live.push((a, bytes));
                }
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                let (a, b) = live.swap_remove(idx);
                pool.free(a, b).unwrap();
            }
            let live_pages: u64 = live
                .iter()
                .map(|&(_, b)| b.div_ceil(4096) * 4096)
                .sum();
            assert_eq!(
                pool.free_bytes() + live_pages,
                cap,
                "leak or double-count with {} live allocations",
                live.len()
            );
        }
    });
}

#[test]
fn failure_injection_exhaustion_and_recovery() {
    // Drive the pool to exhaustion, verify clean failure, then recover.
    let mut pool = GenPool::new(0, 1 << 20, 4096);
    let a = pool.alloc(1 << 20).unwrap();
    assert!(pool.alloc(4096).is_err(), "exhausted pool must fail");
    assert_eq!(pool.fail_count, 1);
    pool.free(a, 1 << 20).unwrap();
    assert!(pool.alloc(4096).is_ok(), "pool must recover after free");
}

#[test]
fn hint_store_shadowing_is_exact() {
    let mut h = HintStore::new();
    h.insert(0x0000, 0x10000, Placement::PreferNvm);
    h.insert(0x4000, 0x1000, Placement::PinDram);
    h.insert(0x8000, 0x2000, Placement::PreferDram);
    // Boundaries are half-open.
    assert_eq!(h.lookup(0x3FFF), Placement::PreferNvm);
    assert_eq!(h.lookup(0x4000), Placement::PinDram);
    assert_eq!(h.lookup(0x4FFF), Placement::PinDram);
    assert_eq!(h.lookup(0x5000), Placement::PreferNvm);
    assert_eq!(h.lookup(0x9FFF), Placement::PreferDram);
    assert_eq!(h.lookup(0xA000), Placement::PreferNvm);
    assert_eq!(h.lookup(0x10000), Placement::Any);
}

#[test]
fn hybrid_exhaustion_is_a_model_error_not_ub() {
    // Touching more pages than DRAM+NVM frames must panic with a clear
    // message (the paper's platform would fault the same way).
    let mut cfg = SystemConfig::default_scaled(64);
    cfg.policy = PolicyKind::FirstTouch;
    let pages = cfg.total_pages();
    let page = cfg.hmmu.page_bytes;
    let mut hmmu = Hmmu::new(cfg, None);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut t = 0;
        for p in 0..pages + 1 {
            t = hmmu.access(p * page, AccessKind::Read, 64, t + 10);
        }
    }));
    assert!(result.is_err(), "over-commit must be detected");
}
