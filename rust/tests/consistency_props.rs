//! Property tests for the tag-matching consistency mechanism (§III-C).
//!
//! The paper's Fig 3 risk: requests split across the fast and slow
//! channels must not return out of order. We sweep randomized
//! issue/completion interleavings and check the invariants the RTL
//! designers "spent considerable time to verify".

use hymem::hmmu::TagMatcher;
use hymem::util::prop::run_prop;
use hymem::util::rng::Xoshiro256;

/// Simulate a random episode: issue a random number of requests with
/// random (device-dependent) latencies, completing them in random order.
/// Returns (tags in drain order, release times in drain order).
fn random_episode(rng: &mut Xoshiro256) -> (Vec<u16>, Vec<u64>, u64) {
    let depth = 1 + rng.below(63) as usize;
    let mut tm = TagMatcher::new(depth);
    let n = 1 + rng.below(depth as u64 * 4);
    let mut drained_tags = Vec::new();
    let mut drained_times = Vec::new();

    let mut outstanding: Vec<(u16, u64)> = Vec::new(); // (tag, media done)
    let mut now = 0u64;
    for _ in 0..n {
        // Random think time.
        now += rng.below(50);
        // Backpressure: completing a random (possibly non-head) request
        // may not free a FIFO slot until the head completes — keep
        // completing until a slot opens, as the hardware would.
        while !tm.can_issue() {
            let idx = rng.below(outstanding.len() as u64) as usize;
            let (tag, done) = outstanding.swap_remove(idx);
            for (t, r) in tm.complete(tag, done) {
                drained_tags.push(t);
                drained_times.push(r);
            }
        }
        let tag = tm.issue();
        // DRAM-ish (fast) or NVM-ish (slow) media completion.
        let latency = if rng.chance(0.5) {
            30 + rng.below(40)
        } else {
            80 + rng.below(400)
        };
        outstanding.push((tag, now + latency));
    }
    // Drain the rest in random order.
    while !outstanding.is_empty() {
        let idx = rng.below(outstanding.len() as u64) as usize;
        let (tag, done) = outstanding.swap_remove(idx);
        for (t, r) in tm.complete(tag, done) {
            drained_tags.push(t);
            drained_times.push(r);
        }
    }
    (drained_tags, drained_times, n)
}

#[test]
fn prop_responses_drain_in_request_order() {
    run_prop("drain-order", |rng| {
        let (tags, _, n) = random_episode(rng);
        assert_eq!(tags.len() as u64, n, "every request must drain exactly once");
        for w in tags.windows(2) {
            // Tags are allocated sequentially (wrapping); drains must
            // follow the same sequence.
            assert_eq!(w[1], w[0].wrapping_add(1), "out-of-order drain");
        }
    });
}

#[test]
fn prop_release_times_monotone() {
    run_prop("release-monotone", |rng| {
        let (_, times, _) = random_episode(rng);
        for w in times.windows(2) {
            assert!(w[0] <= w[1], "release times must be non-decreasing");
        }
    });
}

#[test]
fn prop_release_never_before_completion() {
    run_prop("release-after-media", |rng| {
        let depth = 2 + rng.below(30) as usize;
        let mut tm = TagMatcher::new(depth);
        let n = depth as u64;
        let mut media: Vec<(u16, u64)> = (0..n)
            .map(|_| {
                let tag = tm.issue();
                (tag, rng.below(1000))
            })
            .collect();
        let mut order: Vec<usize> = (0..media.len()).collect();
        rng.shuffle(&mut order);
        let mut releases = std::collections::HashMap::new();
        for &i in &order {
            let (tag, done) = media[i];
            for (t, r) in tm.complete(tag, done) {
                releases.insert(t, r);
            }
        }
        media.sort_by_key(|&(t, _)| t);
        for (tag, done) in media {
            let r = releases[&tag];
            assert!(r >= done, "tag {tag} released at {r} before media done {done}");
        }
    });
}

#[test]
fn prop_reorder_wait_only_when_inverted() {
    run_prop("reorder-accounting", |rng| {
        let mut tm = TagMatcher::new(16);
        let a = tm.issue();
        let b = tm.issue();
        let la = 50 + rng.below(500);
        let lb = 50 + rng.below(500);
        // Complete b first, then a.
        assert!(tm.complete(b, lb).is_empty());
        let rel = tm.complete(a, la);
        assert_eq!(rel.len(), 2);
        if lb >= la {
            // b was already later: it waited lb.max(la) - lb = 0 extra.
            assert_eq!(tm.reorder_wait_ns, 0);
        } else {
            assert_eq!(tm.reorder_wait_ns, la - lb);
        }
    });
}
