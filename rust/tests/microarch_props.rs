//! Microarchitecture property sweeps: cache LRU/inclusion behaviour,
//! redirection-table fuzzing under random migrations, DRAM timing
//! monotonicity, and core-model latency monotonicity.

use hymem::config::{CacheConfig, SystemConfig};
use hymem::cpu::cache::Cache;
use hymem::hmmu::redirection::{Device, RedirectionTable};
use hymem::mem::{AccessKind, DramDevice, MemDevice};
use hymem::util::prop::run_prop;
use hymem::util::rng::Xoshiro256;

#[test]
fn prop_cache_never_exceeds_capacity_and_lru_holds() {
    run_prop("cache-lru", |rng| {
        let ways = 1 + rng.below(8) as u32;
        let sets_pow = 2 + rng.below(5);
        let line = 64u32;
        let size = (1u64 << sets_pow) * ways as u64 * line as u64;
        let mut c = Cache::new(CacheConfig {
            size_bytes: size,
            ways,
            line_bytes: line,
            hit_cycles: 1,
        });
        // Working set exactly = capacity: after one pass, everything hits.
        let lines: Vec<u64> = (0..size / line as u64).map(|i| i * line as u64).collect();
        for &a in &lines {
            c.access(a, false);
        }
        let misses_before = c.misses;
        for &a in &lines {
            assert!(c.access(a, false).hit, "resident line missed");
        }
        assert_eq!(c.misses, misses_before);
        // Working set = capacity + one extra line per set: round-robin
        // thrash, LRU guarantees every access misses.
        let extra = size / line as u64; // one more full stride
        let mut c2 = Cache::new(CacheConfig {
            size_bytes: size,
            ways,
            line_bytes: line,
            hit_cycles: 1,
        });
        let wrap = (ways as u64 + 1) * (1 << sets_pow);
        for round in 0..3 {
            for i in 0..wrap {
                let a = (i % wrap) * line as u64;
                let out = c2.access(a, false);
                if round > 0 {
                    assert!(!out.hit, "LRU thrash must miss every access");
                }
            }
        }
        let _ = extra;
    });
}

#[test]
fn prop_redirection_translate_consistent_under_random_swaps() {
    run_prop("redirection-fuzz", |rng| {
        let host_pages = 16 + rng.below(200);
        let dram = 4 + rng.below(host_pages / 2) as u32;
        let nvm = host_pages as u32; // plenty
        let mut t = RedirectionTable::two_tier(host_pages, dram, nvm, 4096);
        t.identity_map();
        // Shadow model: page -> unique logical frame id.
        let ids: Vec<u64> = (0..host_pages).collect();
        let mut shadow = ids.clone();
        for _ in 0..100 {
            let a = rng.below(host_pages);
            let b = rng.below(host_pages);
            if a == b {
                continue;
            }
            t.swap(a, b).unwrap();
            shadow.swap(a as usize, b as usize);
            t.check_invariants().unwrap();
        }
        // Each page still maps to a unique (device, frame); the shadow
        // permutation tells us the mapping is a bijection.
        let mut seen = std::collections::HashSet::new();
        for p in 0..host_pages {
            let m = t.lookup(p).unwrap();
            assert!(seen.insert((m.device, m.frame)), "duplicate frame");
            // Offsets preserved.
            let (_, da) = t.translate(p * 4096 + 99).unwrap();
            assert_eq!(da % 4096, 99);
        }
        let _ = shadow;
    });
}

#[test]
fn prop_dram_completion_monotone_in_time() {
    run_prop("dram-monotone", |rng| {
        let cfg = SystemConfig::paper().dram;
        let mut d = DramDevice::new(cfg);
        let mut now = 0u64;
        let mut last_done = 0u64;
        for _ in 0..200 {
            now += rng.below(100);
            let addr = rng.below(cfg.size_bytes) & !63;
            let kind = if rng.chance(0.4) {
                AccessKind::Write
            } else {
                AccessKind::Read
            };
            let (done, _) = d.access(addr, kind, 64, now);
            assert!(done > now, "completion must be after issue");
            // Bus serialization: data completions never go backwards.
            assert!(done >= last_done.min(done), "sanity");
            last_done = done;
        }
    });
}

#[test]
fn prop_platform_time_monotone_in_nvm_stall() {
    // More NVM stall must never make the platform faster.
    run_prop("stall-monotonicity", |rng| {
        use hymem::platform::{Platform, RunOpts};
        use hymem::workload::spec;
        let wl = spec::by_name("557.xz").unwrap();
        let seed = rng.next_u64();
        let mut times = Vec::new();
        for stall in [0u64, 100, 400] {
            let mut cfg = SystemConfig::default_scaled(64);
            cfg.seed = seed;
            cfg.nvm.read_stall_ns = stall;
            cfg.nvm.write_stall_ns = stall * 2;
            let r = Platform::new(cfg)
                .run_opts(
                    &wl,
                    RunOpts {
                        ops: 4_000,
                        flush_at_end: false,
                    },
                )
                .unwrap();
            times.push(r.platform_time_ns);
        }
        assert!(
            times[0] <= times[1] && times[1] <= times[2],
            "platform time must be monotone in NVM stall: {times:?}"
        );
    });
}

#[test]
fn prop_first_touch_placement_deterministic_per_seed() {
    run_prop("placement-determinism", |rng| {
        use hymem::config::PolicyKind;
        use hymem::hmmu::Hmmu;
        let seed = rng.next_u64();
        let run = || {
            let mut cfg = SystemConfig::default_scaled(64);
            cfg.policy = PolicyKind::FirstTouch;
            cfg.seed = seed;
            let mut h = Hmmu::new(cfg, None);
            let mut local = Xoshiro256::new(seed);
            let mut t = 0;
            let mut placements = Vec::new();
            for _ in 0..200 {
                let page = local.below(1000);
                t = h.access(page * 4096, AccessKind::Read, 64, t + 50);
                placements.push(h.table.lookup(page).unwrap());
            }
            placements
        };
        assert_eq!(run(), run());
    });
}

#[test]
fn tier_ids_keep_legacy_device_names() {
    // The binary Device type generalized to TierId: the legacy two-tier
    // names survive as rank 0/1 constants with their old rendering.
    assert_ne!(Device::Dram, Device::Nvm);
    assert_eq!(Device::Dram.name(), "DRAM");
    assert_eq!(Device::Nvm.name(), "NVM");
    assert_eq!(Device::Dram.index(), 0);
    assert_eq!(Device::Nvm.index(), 1);
    assert!(Device::Dram < Device::Nvm, "ranks order fast-to-slow");
}
