//! The invariant rules.
//!
//! Each rule reports [`Finding`]s anchored to a file:line; exemption
//! comments (`// audit: allow(<rule>)`) are applied centrally by
//! [`super::audit_tree`]. Rule scope:
//!
//! - `codec-coverage`: every named field of a struct with an
//!   `impl CodecState` in the same file must be referenced in both the
//!   `encode_state` and `decode_state` bodies.
//! - `counter-surface`: every pub field of `HmmuCounters` must appear
//!   in the manual `Debug` impl, `ScenarioResult::to_json`, and
//!   `ScenarioResult::deterministic_key`.
//! - `wall-clock`: no `Instant::now` / `SystemTime` outside the
//!   allowlisted timing sites (`util/bench.rs`, `platform/`, `sweep/`).
//! - `unsorted-iter`: a `HashMap`/`HashSet` field of a codec-holding
//!   struct referenced in `encode_state` requires a sort in that body
//!   (the `mem/nvm.rs` pattern), or iteration order leaks into bytes.
//! - `float-bits`: float fields must cross `encode_state` via
//!   `put_f32`/`put_f64`/`to_bits`, never ad-hoc casts.
//! - `bench-pair`: every `/per-op` bench row name must be registered in
//!   `scripts/check_bench_gate.py` with a block-path partner row that
//!   exists in `benches/`.

use super::parse;
use super::{Finding, SourceFile};

pub const CODEC_COVERAGE: &str = "codec-coverage";
pub const COUNTER_SURFACE: &str = "counter-surface";
pub const WALL_CLOCK: &str = "wall-clock";
pub const UNSORTED_ITER: &str = "unsorted-iter";
pub const FLOAT_BITS: &str = "float-bits";
pub const BENCH_PAIR: &str = "bench-pair";

/// All per-file rules.
pub fn check_file(file: &SourceFile, out: &mut Vec<Finding>) {
    codec_rules(file, out);
    wall_clock(file, out);
}

fn push(out: &mut Vec<Finding>, file: &str, line: usize, rule: &'static str, message: String) {
    out.push(Finding {
        file: file.to_string(),
        line,
        rule,
        message,
    });
}

/// `codec-coverage`, `unsorted-iter` and `float-bits` share the same
/// scan: pair each `impl CodecState for T` with `struct T` definitions
/// in the same file and interrogate the encode/decode bodies.
fn codec_rules(file: &SourceFile, out: &mut Vec<Finding>) {
    let code = &file.stripped.code;
    let defs = parse::structs(code);
    for ib in parse::impls(code) {
        if ib.trait_name.as_deref() != Some("CodecState") {
            continue;
        }
        let enc = parse::find_fn(code, ib.body.clone(), "encode_state");
        let dec = parse::find_fn(code, ib.body.clone(), "decode_state");
        let enc_body = enc.clone().map(|r| &code[r]);
        let dec_body = dec.map(|r| &code[r]);
        for def in defs.iter().filter(|d| d.name == ib.type_name) {
            for f in &def.fields {
                let mut missing = Vec::new();
                if let Some(body) = enc_body {
                    if !parse::word_in(body, &f.name) {
                        missing.push("encode_state");
                    }
                }
                if let Some(body) = dec_body {
                    if !parse::word_in(body, &f.name) {
                        missing.push("decode_state");
                    }
                }
                if !missing.is_empty() {
                    let msg = format!(
                        "field `{}.{}` is not referenced in {}",
                        def.name,
                        f.name,
                        missing.join(" or "),
                    );
                    push(out, &file.display, f.line, CODEC_COVERAGE, msg);
                }
                let hashed = parse::word_in(&f.ty, "HashMap") || parse::word_in(&f.ty, "HashSet");
                if let Some(body) = enc_body {
                    if hashed && parse::word_in(body, &f.name) && !body.contains("sort") {
                        let msg = format!(
                            "hash-ordered field `{}.{}` is encoded without a sort",
                            def.name,
                            f.name,
                        );
                        push(out, &file.display, f.line, UNSORTED_ITER, msg);
                    }
                }
                let floaty = parse::word_in(&f.ty, "f32") || parse::word_in(&f.ty, "f64");
                if floaty {
                    if let Some(r) = enc.clone() {
                        float_bits_lines(file, def.name.as_str(), f, code, r, out);
                    }
                }
            }
        }
    }
}

/// Flag encode lines that touch a float field without `put_f*`/`to_bits`.
fn float_bits_lines(
    file: &SourceFile,
    struct_name: &str,
    f: &parse::Field,
    code: &str,
    body: std::ops::Range<usize>,
    out: &mut Vec<Finding>,
) {
    let start_line = parse::line_of(code, body.start);
    for (k, line_text) in code[body].split('\n').enumerate() {
        if !parse::word_in(line_text, &f.name) {
            continue;
        }
        if line_text.contains("put_f") || line_text.contains("to_bits") {
            continue;
        }
        let msg = format!(
            "float field `{}.{}` is encoded without put_f32/put_f64/to_bits",
            struct_name,
            f.name,
        );
        push(out, &file.display, start_line + k, FLOAT_BITS, msg);
    }
}

/// Wall-clock sites allowed wholesale: the bench harness and the
/// run/sweep drivers, which *report* host wall time rather than feed it
/// into the model.
fn wall_clock_allowlisted(rel: &str) -> bool {
    rel == "util/bench.rs" || rel.starts_with("platform/") || rel.starts_with("sweep/")
}

fn wall_clock(file: &SourceFile, out: &mut Vec<Finding>) {
    if wall_clock_allowlisted(&file.rel) {
        return;
    }
    let code = &file.stripped.code;
    for pat in ["Instant::now", "SystemTime"] {
        let mut at = 0;
        while let Some(p) = parse::find_word(code, pat, at) {
            at = p + pat.len();
            let msg = format!("`{pat}` outside the allowlisted timing sites");
            push(out, &file.display, parse::line_of(code, p), WALL_CLOCK, msg);
        }
    }
}

/// `counter-surface`: needs both `hmmu/counters.rs` (the struct and its
/// manual Debug impl) and `sweep/report.rs` (`to_json` and the
/// fingerprint). Skipped when either file is absent from the tree.
pub fn counter_surface(files: &[SourceFile], out: &mut Vec<Finding>) {
    let counters = files.iter().find(|f| f.rel.ends_with("hmmu/counters.rs"));
    let report = files.iter().find(|f| f.rel.ends_with("sweep/report.rs"));
    let (Some(counters), Some(report)) = (counters, report) else {
        return;
    };
    let ccode = &counters.stripped.code;
    let rcode = &report.stripped.code;
    let defs = parse::structs(ccode);
    let Some(def) = defs.iter().find(|d| d.name == "HmmuCounters") else {
        return;
    };
    let mut debug_body = None;
    for ib in parse::impls(ccode) {
        let is_debug = ib.trait_name.as_deref() == Some("Debug");
        if is_debug && ib.type_name == "HmmuCounters" {
            debug_body = parse::find_fn(ccode, ib.body, "fmt").map(|r| &ccode[r]);
        }
    }
    let mut to_json = None;
    let mut det_key = None;
    for ib in parse::impls(rcode) {
        if ib.trait_name.is_none() && ib.type_name == "ScenarioResult" {
            if let Some(r) = parse::find_fn(rcode, ib.body.clone(), "to_json") {
                to_json = Some(&rcode[r]);
            }
            if let Some(r) = parse::find_fn(rcode, ib.body, "deterministic_key") {
                det_key = Some(&rcode[r]);
            }
        }
    }
    for f in def.fields.iter().filter(|f| f.is_pub) {
        let mut missing = Vec::new();
        if !debug_body.is_some_and(|b| parse::word_in(b, &f.name)) {
            missing.push("the Debug impl");
        }
        if !to_json.is_some_and(|b| parse::word_in(b, &f.name)) {
            missing.push("ScenarioResult::to_json");
        }
        if !det_key.is_some_and(|b| parse::word_in(b, &f.name)) {
            missing.push("the fingerprint (deterministic_key)");
        }
        if !missing.is_empty() {
            let msg = format!("counter `{}` missing from {}", f.name, missing.join(", "));
            push(out, &counters.display, f.line, COUNTER_SURFACE, msg);
        }
    }
}

/// `bench-pair`: every `/per-op` row name in `benches/` must be the
/// baseline of a registered gate pair whose partner is a block row that
/// also exists in `benches/`.
pub fn bench_pair(
    bench_files: &[SourceFile],
    pairs: &[(String, String)],
    out: &mut Vec<Finding>,
) {
    let mut all_names = Vec::new();
    for f in bench_files {
        for (_, lit) in &f.stripped.strings {
            all_names.push(lit.as_str());
        }
    }
    for f in bench_files {
        for (line, lit) in &f.stripped.strings {
            if !lit.contains("/per-op") {
                continue;
            }
            let Some((_, fast)) = pairs.iter().find(|(base, _)| base == lit) else {
                let msg = format!(
                    "bench row `{lit}` has no pair registered in scripts/check_bench_gate.py",
                );
                push(out, &f.display, *line, BENCH_PAIR, msg);
                continue;
            };
            if !fast.contains("block") {
                let msg = format!(
                    "bench row `{lit}` is paired with `{fast}`, which is not a block row",
                );
                push(out, &f.display, *line, BENCH_PAIR, msg);
            } else if !all_names.contains(&fast.as_str()) {
                let msg = format!(
                    "bench row `{lit}` is paired with `{fast}`, which no bench registers",
                );
                push(out, &f.display, *line, BENCH_PAIR, msg);
            }
        }
    }
}

/// Fallback pair source when `python3` is unavailable: pull the quoted
/// strings out of the script's `PAIRS = [...]` literal, two per tuple.
pub fn parse_pairs_literal(script_src: &str) -> Vec<(String, String)> {
    let stripped = strip_python(script_src);
    let Some(start) = stripped.find("PAIRS") else {
        return Vec::new();
    };
    let Some(open) = stripped[start..].find('[') else {
        return Vec::new();
    };
    let from = start + open;
    let tail = stripped[from..].find("\n]");
    let end = tail.map_or(stripped.len(), |p| from + p);
    // Scan quote positions in the stripped text (comments blanked, so a
    // quote in a comment cannot desynchronize the scan), but slice the
    // contents out of the original source.
    let mut strings = Vec::new();
    let b = stripped.as_bytes();
    let mut i = from;
    while i < end {
        if b[i] == b'"' {
            let mut j = i + 1;
            while j < end && b[j] != b'"' {
                j += 1;
            }
            strings.push(script_src[i + 1..j].to_string());
            i = j + 1;
        } else {
            i += 1;
        }
    }
    let mut pairs = Vec::new();
    for pair in strings.chunks(2) {
        if let [base, fast] = pair {
            pairs.push((base.clone(), fast.clone()));
        }
    }
    pairs
}

/// Blank `#` comments and string contents out of Python source so the
/// `PAIRS` region scan cannot be fooled by either (offsets preserved).
fn strip_python(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = b.to_vec();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'#' => {
                while i < b.len() && b[i] != b'\n' {
                    out[i] = b' ';
                    i += 1;
                }
            }
            b'"' | b'\'' => {
                let q = b[i];
                let mut j = i + 1;
                while j < b.len() && b[j] != q && b[j] != b'\n' {
                    out[j] = b' ';
                    if b[j] == b'\\' && j + 1 < b.len() {
                        out[j + 1] = b' ';
                        j += 1;
                    }
                    j += 1;
                }
                i = j + 1;
            }
            _ => i += 1,
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}
