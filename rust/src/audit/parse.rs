//! Structure scanners over stripped source ([`super::lexer::strip`]).
//!
//! Hand-rolled (the build is dependency-free, so no `syn`): brace
//! matching plus word-boundary search is enough to extract named-field
//! struct definitions, `impl` blocks (inherent and trait, generic or
//! not), and named `fn` bodies — the shapes the rules interrogate.

use std::ops::Range;

/// One named field of a struct definition.
#[derive(Debug)]
pub struct Field {
    pub name: String,
    /// Declared type, as source text.
    pub ty: String,
    /// 1-based line of the field name.
    pub line: usize,
    pub is_pub: bool,
}

/// One `struct Name { ... }` definition (tuple and unit structs carry
/// no named fields and are not reported).
#[derive(Debug)]
pub struct StructDef {
    pub name: String,
    pub line: usize,
    pub fields: Vec<Field>,
}

/// One `impl` block header plus the byte range of its body.
#[derive(Debug)]
pub struct ImplBlock {
    /// Base trait name (`Debug` for `impl std::fmt::Debug for X`), or
    /// `None` for an inherent impl.
    pub trait_name: Option<String>,
    /// Base type name (`MemoryController` for `MemoryController<D>`).
    pub type_name: String,
    pub line: usize,
    pub body: Range<usize>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// 1-based line number of byte `idx`.
pub fn line_of(code: &str, idx: usize) -> usize {
    let upto = &code.as_bytes()[..idx.min(code.len())];
    let newlines = upto.iter().filter(|&&b| b == b'\n').count();
    newlines + 1
}

/// Next occurrence of `word` at identifier boundaries, from `from`.
pub fn find_word(code: &str, word: &str, from: usize) -> Option<usize> {
    let b = code.as_bytes();
    let mut at = from;
    while let Some(p) = code[at..].find(word) {
        let start = at + p;
        let end = start + word.len();
        let lb = start == 0 || !is_ident(b[start - 1]);
        let rb = end >= b.len() || !is_ident(b[end]);
        if lb && rb {
            return Some(start);
        }
        at = start + 1;
    }
    None
}

/// True when `word` occurs anywhere in `hay` at identifier boundaries.
pub fn word_in(hay: &str, word: &str) -> bool {
    find_word(hay, word, 0).is_some()
}

/// Byte index of the `}` matching the `{` at `open`.
pub fn match_brace(code: &str, open: usize) -> usize {
    let b = code.as_bytes();
    let mut depth = 0i32;
    for (off, &c) in b[open..].iter().enumerate() {
        if c == b'{' {
            depth += 1;
        } else if c == b'}' {
            depth -= 1;
            if depth == 0 {
                return open + off;
            }
        }
    }
    code.len()
}

/// Skip a balanced `<...>` group starting at `open` (which must be
/// `<`); returns the index past the closing `>`. `->` arrows inside do
/// not close the group.
fn skip_generics(code: &str, open: usize) -> usize {
    let b = code.as_bytes();
    let mut depth = 0i32;
    let mut i = open;
    while i < b.len() {
        match b[i] {
            b'<' => depth += 1,
            b'>' if i > 0 && b[i - 1] == b'-' => {}
            b'>' => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    b.len()
}

fn read_ident(code: &str, from: usize) -> (usize, usize) {
    let b = code.as_bytes();
    let mut s = from;
    while s < b.len() && (b[s] == b' ' || b[s] == b'\t' || b[s] == b'\n') {
        s += 1;
    }
    let mut e = s;
    while e < b.len() && is_ident(b[e]) {
        e += 1;
    }
    (s, e)
}

/// Every named-field struct definition in `code`.
pub fn structs(code: &str) -> Vec<StructDef> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut at = 0;
    while let Some(kw) = find_word(code, "struct", at) {
        at = kw + "struct".len();
        let (ns, ne) = read_ident(code, at);
        if ns == ne {
            continue;
        }
        let name = &code[ns..ne];
        // Skip generics, then find which delimiter opens the body: `{`
        // is a named-field struct, `(`/`;` are tuple/unit (skipped).
        let mut i = ne;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if i < b.len() && b[i] == b'<' {
            i = skip_generics(code, i);
        }
        while i < b.len() && !matches!(b[i], b'{' | b'(' | b';') {
            i += 1;
        }
        if i >= b.len() || b[i] != b'{' {
            continue;
        }
        let close = match_brace(code, i);
        out.push(StructDef {
            name: name.to_string(),
            line: line_of(code, kw),
            fields: fields_of(code, i + 1, close),
        });
        at = close;
    }
    out
}

/// Parse the named fields between body bytes `from..to`.
fn fields_of(code: &str, from: usize, to: usize) -> Vec<Field> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut seg_start = from;
    let mut depth = 0i32;
    let mut i = from;
    while i <= to {
        let at_end = i == to;
        let c = if at_end { b',' } else { b[i] };
        match c {
            b'<' | b'(' | b'[' | b'{' => depth += 1,
            b'>' if i > from && b[i - 1] == b'-' => {}
            b'>' | b')' | b']' | b'}' => depth -= 1,
            b',' if depth == 0 => {
                if let Some(f) = field_of(code, seg_start, i.min(to)) {
                    out.push(f);
                }
                seg_start = i + 1;
            }
            _ => {}
        }
        i += 1;
    }
    out
}

/// Parse one `pub name: Type` segment, tolerating leading attributes.
fn field_of(code: &str, from: usize, to: usize) -> Option<Field> {
    let b = code.as_bytes();
    let mut i = from;
    loop {
        while i < to && b[i].is_ascii_whitespace() {
            i += 1;
        }
        // Attribute: skip the balanced `#[...]` group.
        if i < to && b[i] == b'#' {
            while i < to && b[i] != b'[' {
                i += 1;
            }
            let mut depth = 0i32;
            while i < to {
                if b[i] == b'[' {
                    depth += 1;
                } else if b[i] == b']' {
                    depth -= 1;
                    if depth == 0 {
                        i += 1;
                        break;
                    }
                }
                i += 1;
            }
            continue;
        }
        break;
    }
    let mut is_pub = false;
    let (s, e) = read_ident(code, i);
    let mut ns = s;
    let mut ne = e;
    if &code[s..e] == "pub" {
        is_pub = true;
        let mut j = e;
        while j < to && b[j].is_ascii_whitespace() {
            j += 1;
        }
        if j < to && b[j] == b'(' {
            // pub(crate) and friends.
            while j < to && b[j] != b')' {
                j += 1;
            }
            j += 1;
        }
        let (s2, e2) = read_ident(code, j);
        ns = s2;
        ne = e2;
    }
    if ns == ne || ne >= to {
        return None;
    }
    let mut j = ne;
    while j < to && b[j].is_ascii_whitespace() {
        j += 1;
    }
    if j >= to || b[j] != b':' {
        return None;
    }
    Some(Field {
        name: code[ns..ne].to_string(),
        ty: code[j + 1..to].trim().to_string(),
        line: line_of(code, ns),
        is_pub,
    })
}

/// Every top-level-ish `impl` block in `code`. Occurrences of the
/// `impl` keyword in type position (`-> impl Trait`, `x: impl Trait`)
/// are filtered by requiring the previous non-whitespace byte to end an
/// item (`}` `;` `]` `{` or start of file).
pub fn impls(code: &str) -> Vec<ImplBlock> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut at = 0;
    while let Some(kw) = find_word(code, "impl", at) {
        at = kw + "impl".len();
        let prev = code[..kw].bytes().rev().find(|b| !b.is_ascii_whitespace());
        if !matches!(prev, None | Some(b'}') | Some(b';') | Some(b']') | Some(b'{')) {
            continue;
        }
        let mut i = at;
        if let Some(p) = code[i..].find(['<', '{']) {
            if b[i + p] == b'<' && code[i..i + p].trim().is_empty() {
                i = skip_generics(code, i + p);
            }
        }
        let Some(brace) = code[i..].find('{').map(|p| i + p) else {
            continue;
        };
        let header = &code[i..brace];
        let mut trait_name = None;
        let mut type_part = header;
        if let Some(f) = find_word(header, "for", 0) {
            trait_name = Some(base_name(&header[..f]));
            type_part = &header[f + "for".len()..];
        }
        let type_name = base_name(type_part);
        if type_name.is_empty() {
            continue;
        }
        let close = match_brace(code, brace);
        out.push(ImplBlock {
            trait_name,
            type_name,
            line: line_of(code, kw),
            body: brace + 1..close,
        });
        at = close;
    }
    out
}

/// Base identifier of a possibly-qualified, possibly-generic path:
/// `std::fmt::Debug` → `Debug`, `MemoryController<D>` → `MemoryController`.
fn base_name(path: &str) -> String {
    let p = path.trim();
    let p = p.split('<').next().unwrap_or(p).trim();
    let p = p.rsplit("::").next().unwrap_or(p).trim();
    p.trim_start_matches('&').trim().to_string()
}

/// Byte range of the body of `fn name` inside `within` (a body range
/// from [`impls`]), if present with a body.
pub fn find_fn(code: &str, within: Range<usize>, name: &str) -> Option<Range<usize>> {
    let b = code.as_bytes();
    let mut at = within.start;
    while let Some(kw) = find_word(code, "fn", at) {
        if kw >= within.end {
            return None;
        }
        at = kw + "fn".len();
        let (s, e) = read_ident(code, at);
        if &code[s..e] != name {
            continue;
        }
        let mut i = e;
        while i < within.end && !matches!(b[i], b'{' | b';') {
            if b[i] == b'<' {
                i = skip_generics(code, i);
            } else {
                i += 1;
            }
        }
        if i < within.end && b[i] == b'{' {
            let close = match_brace(code, i);
            return Some(i + 1..close.min(within.end));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::lexer::strip;

    const SRC: &str = "
/// Doc.
pub struct Gen<D: Clone> {
    /// Geometry.
    pub cfg: Config,
    #[allow(dead_code)]
    pub(crate) table: Vec<(u64, u64)>,
    inner: D,
}

struct Unit;
struct Tuple(u64, u64);

impl<D: Clone> util::codec::CodecState for Gen<D> {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_u64(self.table.len() as u64);
    }
    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.table.clear();
        Ok(())
    }
}

impl Gen<u8> {
    fn helper(&self) -> u64 {
        self.table.len() as u64
    }
}
";

    #[test]
    fn finds_structs_and_fields() {
        let s = strip(SRC);
        let defs = structs(&s.code);
        assert_eq!(defs.len(), 1, "tuple/unit structs are skipped");
        let g = &defs[0];
        assert_eq!(g.name, "Gen");
        let names: Vec<&str> = g.fields.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["cfg", "table", "inner"]);
        assert_eq!(g.fields[0].ty, "Config");
        assert!(g.fields[0].is_pub);
        assert!(g.fields[1].is_pub, "pub(crate) counts as pub");
        assert!(!g.fields[2].is_pub);
        assert_eq!(g.fields[0].line, 5);
    }

    #[test]
    fn finds_generic_and_inherent_impls() {
        let s = strip(SRC);
        let blocks = impls(&s.code);
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].trait_name.as_deref(), Some("CodecState"));
        assert_eq!(blocks[0].type_name, "Gen");
        assert_eq!(blocks[1].trait_name, None);
        assert_eq!(blocks[1].type_name, "Gen");
        let enc = find_fn(&s.code, blocks[0].body.clone(), "encode_state").unwrap();
        assert!(word_in(&s.code[enc], "table"));
        let dec = find_fn(&s.code, blocks[0].body.clone(), "decode_state").unwrap();
        assert!(word_in(&s.code[dec.clone()], "table"));
        assert!(!word_in(&s.code[dec], "cfg"));
        assert!(find_fn(&s.code, blocks[0].body.clone(), "helper").is_none());
    }

    #[test]
    fn word_boundaries() {
        assert!(word_in("self.host_reads + x", "host_reads"));
        assert!(!word_in("self.host_read_bytes", "host_reads"));
        assert!(!word_in("hosted_reads_total", "host_reads"));
    }
}
