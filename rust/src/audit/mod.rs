//! `hymem-audit` — source-level invariant checker.
//!
//! The repo's load-bearing property is bit-identical determinism:
//! forked warm-ups replay cold runs exactly, sweeps are
//! thread-count-invariant, goldens are byte-stable. The dynamic tests
//! enforce those properties but cannot see the bug class that threatens
//! them — a field added to a [`crate::util::codec::CodecState`] holder
//! without encode/decode coverage, a counter added to `HmmuCounters`
//! but missed on a report surface, or a stray wall-clock read landing
//! in model code. This module enforces them *statically*: a
//! dependency-free lexer/parser walks `rust/src` and applies the rules
//! in [`rules`]; `cargo run --bin hymem-audit -- rust/src` runs it and
//! CI fails on any unexempted finding.
//!
//! A finding is silenced with a justification comment on its line, or
//! alone on the line above:
//!
//! ```text
//! pub cfg: CacheConfig, // audit: allow(codec-coverage) — geometry
//! ```

pub mod lexer;
pub mod parse;
pub mod rules;

use std::fmt;
use std::io;
use std::path::{Path, PathBuf};

/// One rule violation, anchored to `file:line`.
#[derive(Debug)]
pub struct Finding {
    /// Path as displayed to the user (root argument + relative path).
    pub file: String,
    pub line: usize,
    /// Rule id, e.g. `codec-coverage`.
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// One stripped source file, addressed both ways the rules need it.
pub struct SourceFile {
    /// Display path (root argument joined with the relative path).
    pub display: String,
    /// Path relative to the scanned root, `/`-separated — what the
    /// wall-clock allowlist and the counter-surface lookups match on.
    pub rel: String,
    pub stripped: lexer::Stripped,
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries = Vec::new();
    for e in std::fs::read_dir(dir)? {
        entries.push(e?);
    }
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn load_tree(root: &Path) -> io::Result<Vec<SourceFile>> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    let mut files = Vec::new();
    for p in paths {
        let text = std::fs::read_to_string(&p)?;
        let rel = p.strip_prefix(root).unwrap_or(&p);
        let rel = rel.to_string_lossy().replace('\\', "/");
        files.push(SourceFile {
            display: p.display().to_string(),
            rel,
            stripped: lexer::strip(&text),
        });
    }
    Ok(files)
}

/// The gate pairs, preferably from the script's own `--list-pairs` mode
/// (one `base<TAB>fast` per line), falling back to a textual parse of
/// its `PAIRS` literal when `python3` is unavailable.
fn gate_pairs(script: &Path) -> Vec<(String, String)> {
    let run = std::process::Command::new("python3")
        .arg(script)
        .arg("--list-pairs")
        .output();
    if let Ok(out) = run {
        if out.status.success() {
            let text = String::from_utf8_lossy(&out.stdout);
            let mut pairs = Vec::new();
            for line in text.lines() {
                let mut cols = line.split('\t');
                if let (Some(base), Some(fast)) = (cols.next(), cols.next()) {
                    pairs.push((base.to_string(), fast.to_string()));
                }
            }
            if !pairs.is_empty() {
                return pairs;
            }
        }
    }
    match std::fs::read_to_string(script) {
        Ok(src) => rules::parse_pairs_literal(&src),
        Err(_) => Vec::new(),
    }
}

/// Walk `src_root`, apply every rule, filter exemptions, and return the
/// surviving findings sorted by file/line/rule. The bench-pair rule
/// additionally scans `../benches` and `../scripts/check_bench_gate.py`
/// relative to the root (skipped when absent, e.g. in rule fixtures).
pub fn audit_tree(src_root: &Path) -> io::Result<Vec<Finding>> {
    let files = load_tree(src_root)?;
    let mut findings = Vec::new();
    for f in &files {
        rules::check_file(f, &mut findings);
    }
    rules::counter_surface(&files, &mut findings);

    let mut bench_files = Vec::new();
    if let Some(crate_root) = src_root.parent() {
        let bench_dir = crate_root.join("benches");
        if bench_dir.is_dir() {
            bench_files = load_tree(&bench_dir)?;
            let pairs = gate_pairs(&crate_root.join("scripts/check_bench_gate.py"));
            rules::bench_pair(&bench_files, &pairs, &mut findings);
        }
    }

    let exempted = |f: &Finding| {
        let mut lookup = files.iter().chain(bench_files.iter());
        let Some(src) = lookup.find(|s| s.display == f.file) else {
            return false;
        };
        lexer::exempted(&src.stripped.allows, f.line, f.rule)
    };
    findings.retain(|f| !exempted(f));
    findings.sort_by(|a, b| {
        let ka = (&a.file, a.line, a.rule);
        ka.cmp(&(&b.file, b.line, b.rule))
    });
    Ok(findings)
}
