//! Comment/string-stripping lexer.
//!
//! Blanks comments and string/char literals out of Rust source while
//! preserving byte offsets and line structure, so the downstream
//! scanners ([`super::parse`]) can brace-match and word-search without
//! tripping over text inside literals. Along the way it collects the
//! `// audit: allow(<rule>)` exemption comments and the string literals
//! themselves (the bench-pair rule matches bench row names).

/// One `audit: allow(<rule>)` exemption found in a line comment. A
/// single comment may carry several `allow(...)` clauses; each becomes
/// its own `Allow`.
#[derive(Debug, Clone)]
pub struct Allow {
    /// Line the comment starts on (1-based).
    pub line: usize,
    /// Rule id inside the parentheses, e.g. `codec-coverage`.
    pub rule: String,
    /// True when nothing but whitespace precedes the comment on its
    /// line: the exemption then also covers the following line.
    pub standalone: bool,
}

/// Result of stripping one source file.
pub struct Stripped {
    /// Source with comments and literals blanked to spaces. Newlines
    /// are kept, so line numbers and byte offsets match the original.
    pub code: String,
    /// Exemption comments, in file order.
    pub allows: Vec<Allow>,
    /// `(line, contents)` of every ordinary string literal.
    pub strings: Vec<(usize, String)>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn blank(out: &mut [u8], from: usize, to: usize) {
    for b in out.iter_mut().take(to).skip(from) {
        if *b != b'\n' {
            *b = b' ';
        }
    }
}

fn is_rule_char(b: u8) -> bool {
    b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'-'
}

/// Pull every `allow(<rule>)` clause out of a comment that mentions
/// `audit:`.
fn collect_allows(comment: &str, line: usize, standalone: bool, allows: &mut Vec<Allow>) {
    if !comment.contains("audit:") {
        return;
    }
    let mut rest = comment;
    while let Some(pos) = rest.find("allow(") {
        rest = &rest[pos + "allow(".len()..];
        if let Some(end) = rest.find(')') {
            let rule = &rest[..end];
            if !rule.is_empty() && rule.bytes().all(is_rule_char) {
                allows.push(Allow {
                    line,
                    rule: rule.to_string(),
                    standalone,
                });
            }
            rest = &rest[end..];
        } else {
            break;
        }
    }
}

/// True when only whitespace precedes byte `i` on its line. Earlier
/// literals on the line were already blanked in `out`, so a comment
/// trailing real code is never "standalone".
fn only_ws_before(out: &[u8], i: usize) -> bool {
    let nl = out[..i].iter().rposition(|&b| b == b'\n');
    let start = nl.map_or(0, |p| p + 1);
    out[start..i].iter().all(|&b| b == b' ' || b == b'\t')
}

/// Strip `src`, collecting exemptions and string literals.
pub fn strip(src: &str) -> Stripped {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut allows = Vec::new();
    let mut strings = Vec::new();
    let mut i = 0;
    let mut line = 1usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // Line comment (also covers `///` and `//!` doc comments).
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let end = src[i..].find('\n').map_or(n, |p| i + p);
            let standalone = only_ws_before(&out, i);
            collect_allows(&src[i..end], line, standalone, &mut allows);
            blank(&mut out, i, end);
            i = end;
            continue;
        }
        // Block comment, possibly nested.
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // Raw string literal r"..." / r#"..."#.
        if c == b'r' && (i == 0 || !is_ident(b[i - 1])) && i + 1 < n {
            let mut j = i + 1;
            let mut hashes = 0;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                j += 1;
                let close = format!("\"{}", "#".repeat(hashes));
                let end = src[j..].find(&close).map_or(n, |p| j + p);
                line += src[j..end].matches('\n').count();
                blank(&mut out, i, (end + close.len()).min(n));
                i = (end + close.len()).min(n);
                continue;
            }
        }
        // Ordinary string literal (and b"..." via the plain `"` byte).
        if c == b'"' {
            let start_line = line;
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    break;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            let end = j.min(n);
            strings.push((start_line, src[i + 1..end].to_string()));
            blank(&mut out, i, (end + 1).min(n));
            i = end + 1;
            continue;
        }
        // Char literal vs lifetime: '\n' / 'x' / non-ASCII are literals;
        // 'a in `&'a str` is a lifetime and only the quote is skipped.
        if c == b'\'' {
            let next = if i + 1 < n { b[i + 1] } else { 0 };
            let after = if i + 2 < n { b[i + 2] } else { 0 };
            let is_char = next == b'\\' || next >= 0x80 || after == b'\'';
            if is_char {
                let mut j = i + 1;
                if b[j] == b'\\' {
                    j += 2;
                }
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                blank(&mut out, i, (j + 1).min(n));
                i = j + 1;
                continue;
            }
            i += 1;
            continue;
        }
        i += 1;
    }
    Stripped {
        code: String::from_utf8_lossy(&out).into_owned(),
        allows,
        strings,
    }
}

/// True when an allow for `rule` covers `line`: the comment sits on the
/// line itself, or alone on the line directly above.
pub fn exempted(allows: &[Allow], line: usize, rule: &str) -> bool {
    allows
        .iter()
        .any(|a| a.rule == rule && (a.line == line || (a.standalone && a.line + 1 == line)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanks_comments_and_strings_preserving_lines() {
        let src = "let a = \"hi // not a comment\"; // real\nlet b = 2; /* multi\nline */ let c = 3;\n";
        let s = strip(src);
        assert_eq!(s.code.len(), src.len());
        assert_eq!(s.code.matches('\n').count(), src.matches('\n').count());
        assert!(!s.code.contains("not a comment"));
        assert!(!s.code.contains("real"));
        assert!(!s.code.contains("multi"));
        assert!(s.code.contains("let c = 3;"));
        assert_eq!(s.strings.len(), 1);
        assert_eq!(s.strings[0], (1, "hi // not a comment".to_string()));
    }

    #[test]
    fn collects_allows_with_standalone_flag() {
        let src = "// audit: allow(wall-clock) timing is reported, not modeled\nlet t = now();\nlet u = now(); // audit: allow(wall-clock) allow(codec-coverage)\n";
        let s = strip(src);
        assert_eq!(s.allows.len(), 3);
        assert!(s.allows[0].standalone);
        assert_eq!(s.allows[0].line, 1);
        assert!(!s.allows[1].standalone);
        assert_eq!(s.allows[1].line, 3);
        assert_eq!(s.allows[2].rule, "codec-coverage");
        assert!(exempted(&s.allows, 2, "wall-clock"), "standalone covers next line");
        assert!(exempted(&s.allows, 3, "codec-coverage"));
        assert!(!exempted(&s.allows, 2, "codec-coverage"));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let src = "let p = r#\"raw \"quoted\" text\"#;\nfn f<'a>(x: &'a str, c: char) -> char { if c == '\\'' { 'x' } else { c } }\n";
        let s = strip(src);
        assert!(!s.code.contains("quoted"));
        assert!(s.code.contains("fn f<'a>(x: &'a str"));
        assert!(!s.code.contains("'x'"));
        assert_eq!(s.code.len(), src.len());
    }
}
