//! Warm-state checkpoint/fork engine (§Perf).
//!
//! Sweeps spend most of their simulated ops re-warming identical state:
//! every scenario that shares a (workload, cores, topology, sizing) base
//! replays the same warm-up prefix before the policies diverge. A
//! [`WarmPlatform`] captures **all** mutable platform state at a trace
//! block boundary — cache/TLB arrays, redirection table + frame pools,
//! policy hotness/wear counters, memory-controller queues, DMA in-flight
//! swaps, PCIe credit state, trace-generator RNG cursors, and both
//! clocks — so the warm-up is paid **once** and then forked (cheap
//! in-memory clone, or serialized bytes cached across CI runs) across the
//! whole policy × stall grid.
//!
//! Correctness leans on the block-boundary independence the repo already
//! pins: `step_block` results are block-size independent
//! (`tests/batch_equivalence.rs`), so splitting a run into a warm phase
//! and a measured phase at *any* op boundary is bit-identical to one cold
//! run — `warm_up(0)` literally *is* today's `run_opts_serial` path, and
//! `tests/checkpoint_fork.rs` pins fork-vs-cold-replay equality on time,
//! counters, residency and fingerprint.

use super::native::NativeBackend;
use super::{HmmuBackend, RunOpts, RunReport};
use crate::config::SystemConfig;
use crate::cpu::{CacheHierarchy, CoreModel};
use crate::util::codec::{fingerprint64, CodecState, Decoder, Encoder};
use crate::util::error::Result;
use crate::workload::{TraceBlock, TraceGenerator, Workload, TRACE_BLOCK_OPS};

/// Serialized-checkpoint magic ("HYMW" little-endian) + format version.
/// Version history: v2 = monolithic redirection table; v3 = sharded
/// redirection table payload + checkpoint-kind byte (old checkpoints fail
/// to load and the sweep degrades to re-warming, never to wrong results).
pub(crate) const CHECKPOINT_MAGIC: u32 = 0x574d_5948;
pub(crate) const CHECKPOINT_VERSION: u32 = 3;
/// Checkpoint kind discriminant, right after the version: a single-core
/// [`WarmPlatform`] or a multicore `WarmMulticore` snapshot.
pub(crate) const CHECKPOINT_KIND_SINGLE: u8 = 0;
pub(crate) const CHECKPOINT_KIND_MULTI: u8 = 1;

/// One run (platform pass + native reference pass) paused at a trace
/// block boundary, ready to be forked across scenario variants or
/// resumed to completion.
#[derive(Clone)]
pub struct WarmPlatform {
    cfg: SystemConfig,
    wl: Workload,
    opts: RunOpts,
    /// Ops already executed (the warm prefix length).
    warmed: u64,
    // --- platform pass ---
    backend: HmmuBackend,
    core: CoreModel,
    hier: CacheHierarchy,
    gen: TraceGenerator,
    // --- native reference pass ---
    nat_backend: NativeBackend,
    nat_core: CoreModel,
    nat_hier: CacheHierarchy,
    nat_gen: TraceGenerator,
}

impl WarmPlatform {
    /// A cold platform: identical state to the top of
    /// `Platform::run_opts_serial`'s two passes.
    pub fn new(cfg: SystemConfig, wl: &Workload, opts: RunOpts) -> Self {
        let seed = cfg.seed;
        let backend = HmmuBackend::new(cfg.clone(), None);
        let core = CoreModel::new(cfg.cpu);
        let hier = CacheHierarchy::new(&cfg);
        let gen = TraceGenerator::new(*wl, cfg.scale, seed).take_ops(opts.ops);
        let nat_backend = NativeBackend::new(&cfg);
        let nat_core = CoreModel::new(cfg.cpu);
        let nat_hier = CacheHierarchy::new(&cfg);
        let nat_gen = TraceGenerator::new(*wl, cfg.scale, seed).take_ops(opts.ops);
        WarmPlatform {
            cfg,
            wl: *wl,
            opts,
            warmed: 0,
            backend,
            core,
            hier,
            gen,
            nat_backend,
            nat_core,
            nat_hier,
            nat_gen,
        }
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    pub fn workload(&self) -> &Workload {
        &self.wl
    }

    /// Ops executed so far (warm prefix length).
    pub fn warmed_ops(&self) -> u64 {
        self.warmed
    }

    /// Advance both passes by up to `n` ops (bounded by the run's total),
    /// stopping at a block boundary with the deferred accounting flushed —
    /// the exact point a checkpoint may be taken.
    pub fn warm_up(&mut self, n: u64) {
        let n = n.min(self.opts.ops.saturating_sub(self.warmed));
        // Blocks of the default size, shrunk for the tail so the pause
        // lands exactly on op `warmed + n`. Block sizing does not affect
        // results (`tests/batch_equivalence.rs`), only where we may pause.
        let mut left = n;
        let mut block = TraceBlock::new();
        let mut nat_block = TraceBlock::new();
        while left > 0 {
            if (left as usize) < block.capacity() {
                block = TraceBlock::with_capacity(left as usize);
                nat_block = TraceBlock::with_capacity(left as usize);
            }
            let got = self.gen.fill_block(&mut block);
            if got == 0 {
                break;
            }
            self.core.step_block(&block, &mut self.hier, &mut self.backend);
            self.nat_gen.fill_block(&mut nat_block);
            self.nat_core.step_block(&nat_block, &mut self.nat_hier, &mut self.nat_backend);
            self.warmed += got as u64;
            left -= got as u64;
        }
    }

    /// Fork this warm state at scenario `cfg`, which may differ from the
    /// warm config only on the fork axes (policy kind, rank-1 stalls).
    /// O(state size) clone; no simulation happens here.
    pub fn fork(&self, cfg: &SystemConfig) -> WarmPlatform {
        let mut wp = self.clone();
        wp.backend.hmmu.morph_for_fork(cfg);
        wp.cfg = cfg.clone();
        wp
    }

    /// Run the remaining ops on both passes and produce the same
    /// [`RunReport`] a cold `Platform::run_opts_serial` of the full run
    /// would. `host_wall_ns`/`native_wall_ns` cover only the measured
    /// (post-fork) phase — that saved warm-up is the point of forking.
    pub fn run_to_completion(mut self) -> Result<RunReport> {
        let wall0 = std::time::Instant::now();
        let mut block = TraceBlock::with_capacity(TRACE_BLOCK_OPS);
        while self.gen.fill_block(&mut block) > 0 {
            self.core.step_block(&block, &mut self.hier, &mut self.backend);
        }
        if self.opts.flush_at_end {
            let now = self.core.now();
            self.hier.flush(now, &mut self.backend);
        }
        let platform_time_ns = self.core.finish();
        self.backend.drain(platform_time_ns);
        let host_wall_ns = wall0.elapsed().as_nanos() as u64;

        let wall1 = std::time::Instant::now();
        while self.nat_gen.fill_block(&mut block) > 0 {
            self.nat_core.step_block(&block, &mut self.nat_hier, &mut self.nat_backend);
        }
        let native_time_ns = self.nat_core.finish();
        let native_wall_ns = wall1.elapsed().as_nanos() as u64;

        let mut backend = self.backend;
        // Same link_retries / row-counter mirrors as
        // `Platform::run_opts_mode` — the forked report must be
        // byte-identical to a cold run's.
        backend.hmmu.counters.link_retries = backend.link.link_retries;
        backend.hmmu.sync_row_counters();
        let specs = backend.hmmu.tier_specs().to_vec();
        let energy_inputs: Vec<_> = specs
            .iter()
            .enumerate()
            .map(|(t, s)| {
                (
                    backend.hmmu.tier_stats(crate::hmmu::TierId(t as u8)),
                    s.energy,
                    s.size_bytes,
                )
            })
            .collect();
        let energy = crate::mem::estimate_tier_energy(&energy_inputs, platform_time_ns);

        Ok(RunReport {
            workload: self.wl.name.to_string(),
            policy: backend.hmmu.policy_name().to_string(),
            scale: self.cfg.scale,
            instructions: self.core.stats.instructions,
            mem_ops: self.core.stats.mem_ops,
            memory_accesses: self.core.stats.memory_accesses,
            l1d_miss_rate: self.hier.l1d.miss_rate(),
            l2_miss_rate: self.hier.l2.miss_rate(),
            native_time_ns,
            platform_time_ns,
            mem_stall_ns: self.core.stats.mem_stall_ns,
            counters: backend.hmmu.counters.clone(),
            dram_stats: backend.hmmu.dram_stats().clone(),
            nvm_stats: backend.hmmu.nvm_stats().clone(),
            topology: self.cfg.topology_label(),
            nvm_max_wear: backend.hmmu.nvm_max_wear(),
            tier_wear: backend.hmmu.tier_wear(),
            tier_residency: backend.hmmu.tier_residency(),
            dram_residency: backend.hmmu.dram_residency(),
            pcie_tx_bytes: backend.link.tx_bytes(),
            pcie_rx_bytes: backend.link.rx_bytes(),
            pcie_credit_stalls: backend.link.credit_stalls,
            energy,
            host_wall_ns,
            native_wall_ns,
        })
    }

    /// Cache key for a serialized checkpoint: everything that determines
    /// the warm state. Fork-axis fields are part of the config Debug
    /// surface, so two warm groups never collide on a key.
    pub fn cache_key(cfg: &SystemConfig, wl: &Workload, opts: RunOpts, warm_ops: u64) -> u64 {
        fingerprint64(&format!(
            "{:?}|{}|{}|{}|{warm_ops}",
            cfg, wl.name, opts.ops, opts.flush_at_end
        ))
    }

    /// Serialize the warm state into the compact binary checkpoint form
    /// (versioned header + every member's [`CodecState`] payload).
    pub fn save(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        e.put_u32(CHECKPOINT_MAGIC);
        e.put_u32(CHECKPOINT_VERSION);
        e.put_u8(CHECKPOINT_KIND_SINGLE);
        e.put_u64(fingerprint64(&format!("{:?}", self.cfg)));
        e.put_str(self.wl.name);
        e.put_u64(self.cfg.scale);
        e.put_u64(self.cfg.seed);
        e.put_u64(self.opts.ops);
        e.put_bool(self.opts.flush_at_end);
        e.put_u64(self.warmed);
        self.backend.encode_state(&mut e);
        self.core.encode_state(&mut e);
        self.hier.encode_state(&mut e);
        self.gen.encode_state(&mut e);
        self.nat_backend.encode_state(&mut e);
        self.nat_core.encode_state(&mut e);
        self.nat_hier.encode_state(&mut e);
        self.nat_gen.encode_state(&mut e);
        e.into_bytes()
    }

    /// Rebuild a warm platform from checkpoint `bytes`. The geometry
    /// (config, workload, run sizing) comes from the arguments — the
    /// header only *validates* that the bytes belong to this scenario;
    /// structural mismatches deeper in the payload fail loudly via each
    /// member's decode validation.
    pub fn load(bytes: &[u8], cfg: SystemConfig, wl: &Workload, opts: RunOpts) -> Result<Self> {
        let mut d = Decoder::new(bytes);
        let magic = d.u32()?;
        if magic != CHECKPOINT_MAGIC {
            crate::bail!("not a checkpoint: bad magic {magic:#x}");
        }
        let version = d.u32()?;
        if version != CHECKPOINT_VERSION {
            crate::bail!("checkpoint version {version} != {CHECKPOINT_VERSION}");
        }
        let kind = d.u8()?;
        if kind != CHECKPOINT_KIND_SINGLE {
            crate::bail!("checkpoint kind {kind} is not a single-core checkpoint");
        }
        let fp = d.u64()?;
        let want_fp = fingerprint64(&format!("{:?}", cfg));
        if fp != want_fp {
            crate::bail!("checkpoint config fingerprint {fp:#x} != {want_fp:#x}");
        }
        let name = d.str()?;
        if name != wl.name {
            crate::bail!("checkpoint workload {name:?} != {:?}", wl.name);
        }
        let scale = d.u64()?;
        let seed = d.u64()?;
        if scale != cfg.scale || seed != cfg.seed {
            crate::bail!("checkpoint scale/seed {scale}/{seed} != {}/{}", cfg.scale, cfg.seed);
        }
        let ops = d.u64()?;
        let flush = d.bool()?;
        if ops != opts.ops || flush != opts.flush_at_end {
            crate::bail!(
                "checkpoint run sizing {ops}/{flush} != {}/{}",
                opts.ops,
                opts.flush_at_end
            );
        }
        let warmed = d.u64()?;
        let mut wp = WarmPlatform::new(cfg, wl, opts);
        wp.warmed = warmed;
        wp.backend.decode_state(&mut d)?;
        wp.core.decode_state(&mut d)?;
        wp.hier.decode_state(&mut d)?;
        wp.gen.decode_state(&mut d)?;
        wp.nat_backend.decode_state(&mut d)?;
        wp.nat_core.decode_state(&mut d)?;
        wp.nat_hier.decode_state(&mut d)?;
        wp.nat_gen.decode_state(&mut d)?;
        if !d.is_done() {
            crate::bail!("checkpoint has {} trailing bytes", d.remaining());
        }
        Ok(wp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::workload::spec;

    fn opts() -> RunOpts {
        RunOpts {
            ops: 12_000,
            flush_at_end: false,
        }
    }

    fn cfg() -> SystemConfig {
        let mut c = SystemConfig::default_scaled(64);
        c.policy = PolicyKind::Hotness;
        c.hmmu.epoch_requests = 2_000;
        c
    }

    #[test]
    fn warm_then_run_matches_cold_run() {
        let wl = spec::by_name("505.mcf").unwrap();
        let cold = WarmPlatform::new(cfg(), &wl, opts())
            .run_to_completion()
            .unwrap();
        let mut warm = WarmPlatform::new(cfg(), &wl, opts());
        warm.warm_up(5_000);
        assert_eq!(warm.warmed_ops(), 5_000);
        let split = warm.run_to_completion().unwrap();
        assert_eq!(cold.platform_time_ns, split.platform_time_ns);
        assert_eq!(cold.native_time_ns, split.native_time_ns);
        assert_eq!(
            format!("{:#?}", cold.counters),
            format!("{:#?}", split.counters)
        );
        assert_eq!(cold.tier_residency, split.tier_residency);
    }

    #[test]
    fn matches_platform_run_opts_serial() {
        let wl = spec::by_name("557.xz").unwrap();
        let classic = super::super::Platform::new(cfg())
            .run_opts_serial(&wl, opts())
            .unwrap();
        let mut warm = WarmPlatform::new(cfg(), &wl, opts());
        warm.warm_up(4_000);
        let forked = warm.run_to_completion().unwrap();
        assert_eq!(classic.platform_time_ns, forked.platform_time_ns);
        assert_eq!(classic.native_time_ns, forked.native_time_ns);
        assert_eq!(
            format!("{:#?}", classic.counters),
            format!("{:#?}", forked.counters)
        );
    }

    #[test]
    fn serialized_round_trip_resumes_identically() {
        let wl = spec::by_name("505.mcf").unwrap();
        let mut warm = WarmPlatform::new(cfg(), &wl, opts());
        warm.warm_up(6_000);
        let bytes = warm.save();
        let restored = WarmPlatform::load(&bytes, cfg(), &wl, opts()).unwrap();
        assert_eq!(restored.warmed_ops(), 6_000);
        let a = warm.run_to_completion().unwrap();
        let b = restored.run_to_completion().unwrap();
        assert_eq!(a.platform_time_ns, b.platform_time_ns);
        assert_eq!(format!("{:#?}", a.counters), format!("{:#?}", b.counters));
        assert_eq!(a.tier_residency, b.tier_residency);
    }

    #[test]
    fn load_rejects_wrong_scenario() {
        let wl = spec::by_name("505.mcf").unwrap();
        let mut warm = WarmPlatform::new(cfg(), &wl, opts());
        warm.warm_up(2_000);
        let bytes = warm.save();
        // Different config → fingerprint mismatch.
        let mut other = cfg();
        other.policy = PolicyKind::Static;
        assert!(WarmPlatform::load(&bytes, other, &wl, opts()).is_err());
        // Different workload → name mismatch (same cfg, so only the
        // workload field differs).
        let xz = spec::by_name("557.xz").unwrap();
        assert!(WarmPlatform::load(&bytes, cfg(), &xz, opts()).is_err());
        // Truncated payload → positioned decode error.
        assert!(WarmPlatform::load(&bytes[..bytes.len() / 2], cfg(), &wl, opts()).is_err());
    }

    #[test]
    fn fork_morphs_policy_and_stalls() {
        let wl = spec::by_name("505.mcf").unwrap();
        let mut warm = WarmPlatform::new(cfg(), &wl, opts());
        warm.warm_up(4_000);
        let mut static_cfg = cfg();
        static_cfg.policy = PolicyKind::Static;
        static_cfg.nvm.read_stall_ns = 900;
        static_cfg.nvm.write_stall_ns = 2_000;
        let fork = warm.fork(&static_cfg);
        let r = fork.run_to_completion().unwrap();
        assert_eq!(r.policy, "static");
        // Warm platform unaffected by the fork.
        let r0 = warm.run_to_completion().unwrap();
        assert_eq!(r0.policy, "hotness");
        assert!(r.platform_time_ns != r0.platform_time_ns);
    }
}
