//! Run reports: the numbers Fig 7 / Fig 8 are built from.

use crate::hmmu::HmmuCounters;
use crate::mem::DeviceStats;
use crate::util::units::{fmt_bytes, fmt_ns};

/// Everything measured in one platform run (plus its native reference).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub workload: String,
    pub policy: String,
    pub scale: u64,
    pub instructions: u64,
    pub mem_ops: u64,
    /// Post-cache accesses (line fills) that reached main memory.
    pub memory_accesses: u64,
    pub l1d_miss_rate: f64,
    pub l2_miss_rate: f64,
    /// Modeled native execution time (on-board DRAM).
    pub native_time_ns: u64,
    /// Modeled execution time on the PCIe-attached hybrid platform.
    pub platform_time_ns: u64,
    /// Core-visible memory stall time on the platform.
    pub mem_stall_ns: u64,
    pub counters: HmmuCounters,
    pub dram_stats: DeviceStats,
    pub nvm_stats: DeviceStats,
    /// Tier-stack topology label (e.g. `dram+xpoint`).
    pub topology: String,
    /// Worst per-page wear across the wear-limited tiers (= rank-1 wear
    /// on a two-tier stack).
    pub nvm_max_wear: u64,
    /// Per-tier max wear, rank order.
    pub tier_wear: Vec<u64>,
    /// Per-tier resident page counts at end of run, rank order.
    pub tier_residency: Vec<u64>,
    pub dram_residency: f64,
    pub pcie_tx_bytes: u64,
    pub pcie_rx_bytes: u64,
    pub pcie_credit_stalls: u64,
    /// Static + dynamic energy breakdown (paper §II-B counters use case).
    pub energy: crate::mem::EnergyReport,
    /// Wall-clock cost of simulating the platform pass (host ns).
    pub host_wall_ns: u64,
    /// Wall-clock cost of simulating the native pass.
    pub native_wall_ns: u64,
}

impl RunReport {
    /// Fig 7 metric for the platform: target-time / native-time.
    pub fn slowdown(&self) -> f64 {
        self.platform_time_ns as f64 / self.native_time_ns.max(1) as f64
    }

    /// Fig 8 row: bytes of memory requests seen by the HMMU, scaled back
    /// up to paper-size footprints (×scale) for comparability.
    pub fn fig8_scaled(&self) -> (u64, u64) {
        let (r, w) = self.counters.fig8_row();
        (r * self.scale, w * self.scale)
    }

    /// Simulated-time throughput of the emulator itself (modeled ns per
    /// host wall ns — the emulator's own efficiency, §Perf).
    pub fn emulation_efficiency(&self) -> f64 {
        self.platform_time_ns as f64 / self.host_wall_ns.max(1) as f64
    }

    /// Modeled MIPS of the platform run.
    pub fn platform_mips(&self) -> f64 {
        self.instructions as f64 / (self.platform_time_ns as f64 / 1000.0)
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<16} policy={:<11} slowdown={:>6.2}x  native={:>10}  platform={:>10}  \
             memAcc={:<9} L2miss={:>5.1}%  dramResid={:>5.1}%  migrations={}",
            self.workload,
            self.policy,
            self.slowdown(),
            fmt_ns(self.native_time_ns),
            fmt_ns(self.platform_time_ns),
            self.memory_accesses,
            self.l2_miss_rate * 100.0,
            self.dram_residency * 100.0,
            self.counters.migrations,
        )
    }

    /// Multi-line detail block.
    pub fn detail(&self) -> String {
        let (rb, wb) = self.counters.fig8_row();
        // Row-buffer outcome line (per tier): rendered only when the
        // mirror ran and the devices saw traffic, so legacy hand-built
        // reports are unchanged.
        let mut rowbuf = String::new();
        let row_total: u64 = self.counters.tier_row_hits.iter().sum::<u64>()
            + self.counters.tier_row_misses.iter().sum::<u64>();
        if row_total > 0 {
            rowbuf.push_str("\nrow buffer     ");
            for t in 0..self.counters.tier_row_hits.len() {
                rowbuf.push_str(&format!(
                    " tier{t} {:.1}% hit ({}h/{}m)",
                    self.counters.tier_row_hit_rate(t) * 100.0,
                    self.counters.tier_row_hits.get(t).copied().unwrap_or(0),
                    self.counters.tier_row_misses.get(t).copied().unwrap_or(0),
                ));
            }
        }
        let mut tiers = String::new();
        if self.counters.tiers() > 2 {
            tiers.push_str(&format!("\ntiers           {}", self.topology));
            for t in 0..self.counters.tiers() {
                tiers.push_str(&format!(
                    "\n  tier{t}         {}r+{}w, {} pages resident, max wear {}",
                    self.counters.tier_reads.get(t).copied().unwrap_or(0),
                    self.counters.tier_writes.get(t).copied().unwrap_or(0),
                    self.tier_residency.get(t).copied().unwrap_or(0),
                    self.tier_wear.get(t).copied().unwrap_or(0),
                ));
            }
        }
        format!(
            "workload        {}\n\
             policy          {} (scale 1/{})\n\
             instructions    {}\n\
             mem ops         {} ({} to memory, L1D miss {:.2}%, L2 miss {:.2}%)\n\
             native time     {}\n\
             platform time   {}  (slowdown {:.2}x, mem stalls {})\n\
             HMMU traffic    R {} / W {}  (DRAM {}r+{}w, NVM {}r+{}w)\n\
             placement       {:.1}% DRAM-resident, {} migrations ({} moved)\n\
             consistency     reorder wait {}, fifo stalls {}, dma conflicts {}\n\
             PCIe            TX {} RX {} creditStalls {} (dma {} / {} stalls)\n\
             NVM wear        max {} writes/page\n\
             energy est.     {:.2} mJ dynamic; {}\n\
             latency         mean {:.0}ns p50 {}ns p99 {}ns max {}ns\n\
             emulator        {} wall, {:.2} modeled-ns/wall-ns{rowbuf}{tiers}",
            self.workload,
            self.policy,
            self.scale,
            self.instructions,
            self.mem_ops,
            self.memory_accesses,
            self.l1d_miss_rate * 100.0,
            self.l2_miss_rate * 100.0,
            fmt_ns(self.native_time_ns),
            fmt_ns(self.platform_time_ns),
            self.slowdown(),
            fmt_ns(self.mem_stall_ns),
            fmt_bytes(rb),
            fmt_bytes(wb),
            self.counters.dram_reads(),
            self.counters.dram_writes(),
            self.counters.nvm_reads(),
            self.counters.nvm_writes(),
            self.dram_residency * 100.0,
            self.counters.migrations,
            fmt_bytes(self.counters.migration_bytes),
            fmt_ns(self.counters.reorder_wait_ns),
            self.counters.fifo_full_stalls,
            self.counters.dma_conflict_stalls,
            fmt_bytes(self.pcie_tx_bytes),
            fmt_bytes(self.pcie_rx_bytes),
            self.pcie_credit_stalls,
            fmt_bytes(self.counters.pcie_dma_bytes),
            self.counters.dma_link_stalls,
            self.nvm_max_wear,
            self.counters.energy_estimate_mj(),
            self.energy.summary(),
            self.counters.latency.mean(),
            self.counters.latency.percentile(50.0),
            self.counters.latency.percentile(99.0),
            self.counters.latency.max(),
            fmt_ns(self.host_wall_ns),
            self.emulation_efficiency(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> RunReport {
        RunReport {
            workload: "505.mcf".into(),
            policy: "hotness".into(),
            scale: 16,
            instructions: 1_000_000,
            mem_ops: 300_000,
            memory_accesses: 50_000,
            l1d_miss_rate: 0.3,
            l2_miss_rate: 0.6,
            native_time_ns: 1_000_000,
            platform_time_ns: 15_360_000,
            mem_stall_ns: 14_000_000,
            counters: HmmuCounters::default(),
            dram_stats: DeviceStats::default(),
            nvm_stats: DeviceStats::default(),
            topology: "dram+xpoint".into(),
            nvm_max_wear: 3,
            tier_wear: vec![0, 3],
            tier_residency: vec![100, 150],
            dram_residency: 0.4,
            pcie_tx_bytes: 1000,
            pcie_rx_bytes: 2000,
            pcie_credit_stalls: 0,
            energy: crate::mem::EnergyReport::default(),
            host_wall_ns: 5_000_000,
            native_wall_ns: 3_000_000,
        }
    }

    #[test]
    fn slowdown_matches_paper_math() {
        let r = report();
        assert!((r.slowdown() - 15.36).abs() < 0.01);
    }

    #[test]
    fn fig8_scaling() {
        let mut r = report();
        r.counters.host_read_bytes = 100;
        r.counters.host_write_bytes = 50;
        assert_eq!(r.fig8_scaled(), (1600, 800));
    }

    #[test]
    fn summary_contains_key_fields() {
        let s = report().summary();
        assert!(s.contains("505.mcf"));
        assert!(s.contains("15.36"));
        let d = report().detail();
        assert!(d.contains("PCIe"));
        assert!(d.contains("NVM wear"));
        assert!(!d.contains("row buffer"), "no outcomes, no row line: {d}");
    }

    #[test]
    fn detail_renders_row_buffer_rates_when_present() {
        let mut r = report();
        r.counters.tier_row_hits = vec![30, 5];
        r.counters.tier_row_misses = vec![10, 15];
        let d = r.detail();
        assert!(d.contains("row buffer"), "{d}");
        assert!(d.contains("tier0 75.0% hit (30h/10m)"), "{d}");
        assert!(d.contains("tier1 25.0% hit (5h/15m)"), "{d}");
    }
}
