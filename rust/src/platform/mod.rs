//! The emulation platform (Fig 1b) and the native-execution reference.
//!
//! - [`Platform`] — host CPU model whose post-cache memory traffic crosses
//!   the PCIe link into the HMMU and its tier stack. Running a workload
//!   yields the **platform time** (what a stopwatch would show on the
//!   paper's LS2085A+FPGA rig).
//! - [`native`] — the same CPU model with local on-board DDR4 (the paper's
//!   16 GB native configuration); yields the **native time** that Fig 7
//!   normalizes against.
//!
//! `slowdown = platform_time / native_time` is the paper's headline
//! "merely 3.17×" metric; per-workload values range 1.17× (imagick) to
//! 15.36× (mcf) with memory intensity.

pub mod checkpoint;
pub mod multicore;
pub mod native;
pub mod report;

pub use checkpoint::WarmPlatform;
pub use multicore::{run_multicore, MulticoreReport, WarmMulticore};
pub use report::RunReport;

use crate::config::SystemConfig;
use crate::cpu::{BlockOutcomes, CacheHierarchy, CoreModel, MemBackend};
use crate::hmmu::{Hmmu, HotnessEngine};
use crate::mem::AccessKind;
use crate::pcie::{PcieLink, TlpColumn, TlpKind};
use crate::sim::Time;
use crate::util::codec::{CodecState, Decoder, Encoder};
use crate::util::error::Result;
use crate::workload::{TraceBlock, TraceGenerator, Workload};

/// Run-size options.
#[derive(Clone, Copy, Debug)]
pub struct RunOpts {
    /// Memory operations to simulate (trace length).
    pub ops: u64,
    /// Flush caches at the end (adds write-back traffic to counters).
    pub flush_at_end: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            ops: 2_000_000,
            flush_at_end: false,
        }
    }
}

/// Memory backend that sends requests over PCIe to the HMMU (Fig 1b path).
#[derive(Clone)]
pub struct HmmuBackend {
    pub link: PcieLink,
    pub hmmu: Hmmu,
    // audit: allow(codec-coverage) — geometry, re-derived from config
    line_bytes: u32,
    /// Recorded per-op traffic column for the block-batched link crossing
    /// (§Perf) — recycled across ops; steady state allocates nothing.
    // audit: allow(codec-coverage) — scratch, refilled every block
    col: TlpColumn,
    /// Per-entry completion scratch for the block crossing (recycled).
    // audit: allow(codec-coverage) — scratch, refilled every block
    completions: Vec<Time>,
}

impl HmmuBackend {
    pub fn new(cfg: SystemConfig, engine: Option<Box<dyn HotnessEngine>>) -> Self {
        let mut link = PcieLink::new(cfg.pcie);
        link.set_fault(&cfg.fault, cfg.seed);
        HmmuBackend {
            link,
            line_bytes: cfg.l1d.line_bytes,
            hmmu: Hmmu::new(cfg, engine),
            col: TlpColumn::new(),
            completions: Vec::new(),
        }
    }
}

impl MemBackend for HmmuBackend {
    fn access(&mut self, addr: u64, kind: AccessKind, bytes: u64, now: Time) -> Time {
        match kind {
            AccessKind::Read => {
                // MRd TLP: header only out, completion-with-data back.
                let arrive = self.link.send_to_device(0, now);
                let release =
                    self.hmmu.access_linked(addr, kind, bytes, arrive, Some(&mut self.link));
                let back = self.link.send_to_host(bytes.min(u32::MAX as u64) as u32, release);
                self.link.hold_credit_until(back);
                back
            }
            AccessKind::Write => {
                // Posted MWr: data out; host does not wait for the device
                // commit, but the link and HMMU do the work.
                let arrive = self
                    .link
                    .send_to_device(bytes.min(self.line_bytes as u64 * 8) as u32, now);
                let commit =
                    self.hmmu.access_linked(addr, kind, bytes, arrive, Some(&mut self.link));
                self.link.hold_credit_until(commit);
                commit
            }
        }
    }

    /// Block-path link crossing (§Perf): op `i`'s recorded traffic —
    /// posted victim write-backs, then the demand fill, all issued at the
    /// op's core time — forms one [`TlpColumn`] crossed in a single
    /// [`PcieLink::send_block_to_device`] pass, with the HMMU as the
    /// device-side service. Bit-identical to the per-op [`Self::access`]
    /// sequence when write coalescing is off (`tests/batch_equivalence.rs`
    /// and `tests/pcie_props.rs` pin it); with coalescing on, adjacent
    /// same-page write-backs share a wire TLP.
    fn issue_block_op(
        &mut self,
        out: &BlockOutcomes,
        i: usize,
        wr: &mut usize,
        rd: &mut usize,
        now: Time,
    ) -> Option<Time> {
        self.col.clear();
        let bytes = out.line_bytes();
        let wr_payload = bytes.min(self.line_bytes as u64 * 8) as u32;
        while out.has_writes_for(i, *wr) {
            self.col.push(TlpKind::MWr, out.writes()[*wr].1, wr_payload, now);
            *wr += 1;
        }
        let has_fill = out.is_mem_access(i);
        if has_fill {
            let fill = out.fills()[*rd];
            *rd += 1;
            self.col.push(TlpKind::MRd, fill, bytes.min(u32::MAX as u64) as u32, now);
        }
        if self.col.is_empty() {
            return None;
        }
        let (link, hmmu, col) = (&mut self.link, &mut self.hmmu, &self.col);
        link.send_block_to_device(
            col,
            &mut |link, j, arrive| {
                let kind = if col.kind(j) == TlpKind::MRd {
                    AccessKind::Read
                } else {
                    AccessKind::Write
                };
                hmmu.access_linked(col.addr(j), kind, bytes, arrive, Some(link))
            },
            &mut self.completions,
        );
        if has_fill {
            Some(*self.completions.last().unwrap())
        } else {
            None
        }
    }

    /// Block-batched accounting (§Perf): while a block is in flight the
    /// HMMU defers policy hotness counting and per-tier counters into a
    /// queue drained once at `end_block` — one tight accounting loop per
    /// block instead of a policy-dispatch + counter update per op.
    /// Bit-identical to immediate accounting (every reader sits behind a
    /// flush point; `tests/batch_equivalence.rs` pins it).
    fn begin_block(&mut self) {
        self.hmmu.begin_block();
    }

    fn end_block(&mut self) {
        self.hmmu.end_block();
    }

    fn drain(&mut self, now: Time) {
        self.hmmu.drain(now);
    }
}

impl CodecState for HmmuBackend {
    fn encode_state(&self, e: &mut Encoder) {
        // `col`/`completions` are per-block scratch (empty between
        // blocks, where checkpoints are taken); `line_bytes` is config.
        self.link.encode_state(e);
        self.hmmu.encode_state(e);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.link.decode_state(d)?;
        self.hmmu.decode_state(d)?;
        Ok(())
    }
}

/// The full emulation platform.
pub struct Platform {
    cfg: SystemConfig,
    engine: Option<Box<dyn HotnessEngine>>,
}

impl Platform {
    pub fn new(cfg: SystemConfig) -> Self {
        Platform { cfg, engine: None }
    }

    /// Use a specific hotness engine (e.g. the XLA artifact engine).
    pub fn with_engine(mut self, engine: Box<dyn HotnessEngine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Run `wl` on the platform **and** on the native reference, with
    /// default sizing.
    pub fn run(self, wl: &Workload) -> Result<RunReport> {
        self.run_opts(wl, RunOpts::default())
    }

    /// Run with explicit sizing.
    ///
    /// The platform pass and the native reference pass are fully
    /// independent (separate cores, hierarchies and trace generators from
    /// the same seed), so they run **concurrently**: the native pass on a
    /// scoped helper thread, the platform pass on the calling thread
    /// (§Perf — they used to run back-to-back, paying both wall times).
    /// Results are bit-identical to the serial order because neither pass
    /// reads the other's state.
    pub fn run_opts(self, wl: &Workload, opts: RunOpts) -> Result<RunReport> {
        self.run_opts_mode(wl, opts, true)
    }

    /// Like [`Self::run_opts`] but with the two passes back-to-back on the
    /// calling thread. Use when the caller already saturates the machine
    /// with its own parallelism (the sweep engine does): it avoids CPU
    /// oversubscription and keeps the per-run wall-clock metrics
    /// (`host_wall_ns`, `emulation_efficiency`) uncontended and honest.
    pub fn run_opts_serial(self, wl: &Workload, opts: RunOpts) -> Result<RunReport> {
        self.run_opts_mode(wl, opts, false)
    }

    fn run_opts_mode(self, wl: &Workload, opts: RunOpts, concurrent: bool) -> Result<RunReport> {
        let cfg = self.cfg;
        let seed = cfg.seed;

        // --- native pass (same trace, local DRAM) ---
        // §Perf: both passes pull whole [`TraceBlock`]s through the core
        // (`fill_block` + `step_block`) instead of one op at a time, and
        // `step_block` runs the cache filter block-batched
        // (`CacheHierarchy::access_block`: one TLB pass, one L1
        // multi-probe, one L2 pass over the compacted misses, outcomes in
        // the core's recycled SoA buffer). The block is allocated once
        // per pass and recycled, so the steady-state loop performs no
        // heap allocation. Bit-identical to the per-op loop (pinned by
        // `tests/batch_equivalence.rs`).
        let native_cfg = cfg.clone();
        let native_wl = *wl;
        let native_pass = move || {
            let wall1 = std::time::Instant::now();
            let mut nat_backend = native::NativeBackend::new(&native_cfg);
            let mut nat_core = CoreModel::new(native_cfg.cpu);
            let mut nat_hier = CacheHierarchy::new(&native_cfg);
            let mut gen =
                TraceGenerator::new(native_wl, native_cfg.scale, seed).take_ops(opts.ops);
            let mut block = TraceBlock::new();
            while gen.fill_block(&mut block) > 0 {
                nat_core.step_block(&block, &mut nat_hier, &mut nat_backend);
            }
            let native_time_ns = nat_core.finish();
            (native_time_ns, wall1.elapsed().as_nanos() as u64)
        };

        // --- platform pass ---
        let engine = self.engine;
        let platform_pass = || {
            let wall0 = std::time::Instant::now();
            let mut backend = HmmuBackend::new(cfg.clone(), engine);
            let mut core = CoreModel::new(cfg.cpu);
            let mut hier = CacheHierarchy::new(&cfg);
            let mut gen = TraceGenerator::new(*wl, cfg.scale, seed).take_ops(opts.ops);
            let mut block = TraceBlock::new();
            while gen.fill_block(&mut block) > 0 {
                core.step_block(&block, &mut hier, &mut backend);
            }
            if opts.flush_at_end {
                let now = core.now();
                hier.flush(now, &mut backend);
            }
            let platform_time_ns = core.finish();
            backend.drain(platform_time_ns);
            (backend, core, hier, platform_time_ns, wall0.elapsed().as_nanos() as u64)
        };

        let ((mut backend, core, hier, platform_time_ns, host_wall_ns), (native_time_ns, native_wall_ns)) =
            if concurrent {
                std::thread::scope(|s| {
                    let native = s.spawn(native_pass);
                    let plat = platform_pass();
                    (plat, native.join().expect("native pass panicked"))
                })
            } else {
                (platform_pass(), native_pass())
            };

        // Per-tier energy: every rank contributes its own coefficients
        // (the two-tier default folds to the legacy DDR4/XPoint pair).
        let specs = backend.hmmu.tier_specs().to_vec();
        let energy_inputs: Vec<_> = specs
            .iter()
            .enumerate()
            .map(|(t, s)| {
                (
                    backend.hmmu.tier_stats(crate::hmmu::TierId(t as u8)),
                    s.energy,
                    s.size_bytes,
                )
            })
            .collect();
        let energy = crate::mem::estimate_tier_energy(&energy_inputs, platform_time_ns);

        // Link replays live on the PCIe side; mirror them into the HMMU
        // counter block so every report surface (Debug golden, sweep
        // fingerprint, checkpoint) sees one consolidated fault tally.
        backend.hmmu.counters.link_retries = backend.link.link_retries;
        // Same pattern for the per-tier row-buffer outcome counters,
        // which live on the tier devices.
        backend.hmmu.sync_row_counters();

        Ok(RunReport {
            workload: wl.name.to_string(),
            policy: backend.hmmu.policy_name().to_string(),
            scale: cfg.scale,
            instructions: core.stats.instructions,
            mem_ops: core.stats.mem_ops,
            memory_accesses: core.stats.memory_accesses,
            l1d_miss_rate: hier.l1d.miss_rate(),
            l2_miss_rate: hier.l2.miss_rate(),
            native_time_ns,
            platform_time_ns,
            mem_stall_ns: core.stats.mem_stall_ns,
            counters: backend.hmmu.counters.clone(),
            dram_stats: backend.hmmu.dram_stats().clone(),
            nvm_stats: backend.hmmu.nvm_stats().clone(),
            topology: cfg.topology_label(),
            nvm_max_wear: backend.hmmu.nvm_max_wear(),
            tier_wear: backend.hmmu.tier_wear(),
            tier_residency: backend.hmmu.tier_residency(),
            dram_residency: backend.hmmu.dram_residency(),
            pcie_tx_bytes: backend.link.tx_bytes(),
            pcie_rx_bytes: backend.link.rx_bytes(),
            pcie_credit_stalls: backend.link.credit_stalls,
            energy,
            host_wall_ns,
            native_wall_ns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::workload::spec;

    fn small_opts() -> RunOpts {
        RunOpts {
            ops: 20_000,
            flush_at_end: false,
        }
    }

    #[test]
    fn platform_slower_than_native() {
        let cfg = SystemConfig::default_scaled(64);
        let wl = spec::by_name("505.mcf").unwrap();
        let r = Platform::new(cfg).run_opts(&wl, small_opts()).unwrap();
        assert!(r.platform_time_ns > r.native_time_ns);
        assert!(r.slowdown() > 1.0);
    }

    #[test]
    fn mcf_suffers_more_than_imagick() {
        // Enough ops to get past cache warmup (imagick is only low-miss
        // in steady state, when its tile window is resident).
        let cfg = SystemConfig::default_scaled(64);
        let opts = RunOpts {
            ops: 150_000,
            flush_at_end: false,
        };
        let mcf = Platform::new(cfg.clone())
            .run_opts(&spec::by_name("505.mcf").unwrap(), opts)
            .unwrap();
        let img = Platform::new(cfg)
            .run_opts(&spec::by_name("538.imagick").unwrap(), opts)
            .unwrap();
        eprintln!(
            "slowdowns: mcf {:.2} imagick {:.2}",
            mcf.slowdown(),
            img.slowdown()
        );
        assert!(
            mcf.slowdown() > 2.0 * img.slowdown(),
            "mcf {} vs imagick {}",
            mcf.slowdown(),
            img.slowdown()
        );
        assert!(img.slowdown() < 3.5, "imagick should be near-native: {}", img.slowdown());
    }

    #[test]
    fn counters_see_all_post_cache_traffic() {
        let cfg = SystemConfig::default_scaled(64);
        let wl = spec::by_name("519.lbm").unwrap();
        let r = Platform::new(cfg).run_opts(&wl, small_opts()).unwrap();
        assert_eq!(
            r.counters.total_host_requests(),
            r.counters.host_reads + r.counters.host_writes
        );
        assert!(r.counters.host_reads > 0);
        assert!(r.counters.host_writes > 0); // lbm writes back dirty lines
        // Fills = memory_accesses; host reads == fills.
        assert_eq!(r.counters.host_reads, r.memory_accesses);
    }

    #[test]
    fn policies_execute_and_differ() {
        let wl = spec::by_name("520.omnetpp").unwrap();
        let mut static_cfg = SystemConfig::default_scaled(64);
        static_cfg.policy = PolicyKind::Static;
        let mut hot_cfg = SystemConfig::default_scaled(64);
        hot_cfg.policy = PolicyKind::Hotness;
        hot_cfg.hmmu.epoch_requests = 2000;
        let opts = RunOpts {
            ops: 60_000,
            flush_at_end: false,
        };
        let r_static = Platform::new(static_cfg).run_opts(&wl, opts).unwrap();
        let r_hot = Platform::new(hot_cfg).run_opts(&wl, opts).unwrap();
        assert_eq!(r_static.counters.migrations, 0);
        assert!(r_hot.counters.migrations > 0);
    }

    #[test]
    fn host_managed_dma_charges_migration_at_the_link() {
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 2_000;
        let wl = spec::by_name("520.omnetpp").unwrap();
        let opts = RunOpts {
            ops: 60_000,
            flush_at_end: false,
        };
        let device_side = Platform::new(cfg.clone()).run_opts_serial(&wl, opts).unwrap();
        cfg.hmmu.host_managed_dma = true;
        let host_managed = Platform::new(cfg).run_opts_serial(&wl, opts).unwrap();

        // The paper's device-side DMA never touches PCIe.
        assert!(device_side.counters.migrations > 0);
        assert_eq!(device_side.counters.pcie_dma_bytes, 0);
        assert_eq!(device_side.counters.dma_link_stalls, 0);

        // Host-managed: every relocated byte crosses the link twice
        // (block read back to the host, block write out to the device),
        // and migration_bytes counts both pages of each swap — so link
        // DMA payload is exactly 2× migration_bytes.
        assert!(host_managed.counters.migrations > 0);
        assert_eq!(
            host_managed.counters.pcie_dma_bytes,
            2 * host_managed.counters.migration_bytes,
            "each migrated byte crosses the link once per direction"
        );
        // And the link sees strictly more traffic than the device-side
        // design on the same workload.
        assert!(
            host_managed.pcie_tx_bytes + host_managed.pcie_rx_bytes
                > device_side.pcie_tx_bytes + device_side.pcie_rx_bytes
        );
    }

    #[test]
    fn deterministic_runs() {
        let cfg = SystemConfig::default_scaled(64);
        let wl = spec::by_name("557.xz").unwrap();
        let a = Platform::new(cfg.clone()).run_opts(&wl, small_opts()).unwrap();
        let b = Platform::new(cfg).run_opts(&wl, small_opts()).unwrap();
        assert_eq!(a.platform_time_ns, b.platform_time_ns);
        assert_eq!(a.counters.host_read_bytes, b.counters.host_read_bytes);
    }
}
