//! Multi-programmed multicore runs (the LS2085A has 8 A57 cores; the
//! paper's platform serves them all through one PCIe link and one HMMU).
//!
//! Each core runs its own workload trace through a private L1/L2
//! hierarchy (A57 clusters share L2 pairwise; we give each core a
//! half-sized L2 slice, which bounds the same capacity), with all
//! post-cache traffic contending for the shared link + HMMU + devices.
//! Address spaces are striped per core so working sets do not overlap
//! (rate-style SPEC runs).
//!
//! Cores are interleaved on the shared timeline in lockstep-by-time:
//! the core with the smallest local clock steps next, so cross-core
//! contention at the link and memory controllers is ordered correctly.

use std::sync::mpsc;

use super::{HmmuBackend, RunOpts};
use crate::config::SystemConfig;
use crate::cpu::{CacheHierarchy, CoreModel, MemBackend};
use crate::hmmu::{HmmuCounters, HotnessEngine};
use crate::mem::AccessKind;
use crate::sim::Time;
use crate::workload::{TraceBlock, TraceGenerator, Workload};
use crate::bail;
use crate::util::error::Result;

/// Report for one core of a multicore run.
#[derive(Clone, Debug)]
pub struct CoreReport {
    pub core: usize,
    pub workload: String,
    pub instructions: u64,
    pub mem_ops: u64,
    pub memory_accesses: u64,
    pub time_ns: u64,
}

/// Aggregate multicore report.
#[derive(Clone, Debug)]
pub struct MulticoreReport {
    pub cores: Vec<CoreReport>,
    /// Makespan: time when the last core finished.
    pub makespan_ns: u64,
    /// Total post-cache requests served by the HMMU.
    pub hmmu_requests: u64,
    pub pcie_credit_stalls: u64,
    pub fifo_full_stalls: u64,
    /// Aggregate modeled MIPS across cores.
    pub aggregate_mips: f64,
    /// Full HMMU counter block (one HMMU shared by all cores) — lets the
    /// sweep engine report multicore scenarios with the same columns as
    /// single-core runs.
    pub counters: HmmuCounters,
    /// DRAM residency of mapped pages at end of run.
    pub dram_residency: f64,
    pub nvm_max_wear: u64,
    /// Tier-stack topology label (e.g. `dram+xpoint`).
    pub topology: String,
    /// Per-tier max wear, rank order.
    pub tier_wear: Vec<u64>,
    /// Per-tier resident page counts at end of run, rank order.
    pub tier_residency: Vec<u64>,
}

impl MulticoreReport {
    pub fn summary(&self) -> String {
        use crate::util::units::fmt_ns;
        let mut s = format!(
            "{} cores, makespan {}, {} HMMU requests, {:.1} aggregate MIPS\n",
            self.cores.len(),
            fmt_ns(self.makespan_ns),
            self.hmmu_requests,
            self.aggregate_mips,
        );
        for c in &self.cores {
            s.push_str(&format!(
                "  core{} {:<16} {:>10} instr  {:>8} memAcc  {}\n",
                c.core,
                c.workload,
                c.instructions,
                c.memory_accesses,
                fmt_ns(c.time_ns)
            ));
        }
        s
    }
}

/// Offset added to each core's addresses so rate-style copies do not
/// share pages (stripes the flat space per core).
fn core_stripe(cfg: &SystemConfig, core: usize, n_cores: usize) -> u64 {
    let stripe = cfg.total_mem_bytes() / n_cores as u64;
    (stripe & !(cfg.hmmu.page_bytes - 1)) * core as u64
}

/// Run `workloads` (one per core) against a single shared HMMU.
pub fn run_multicore(
    cfg: SystemConfig,
    workloads: &[Workload],
    opts: RunOpts,
    engine: Option<Box<dyn HotnessEngine>>,
) -> Result<MulticoreReport> {
    let n = workloads.len();
    if n == 0 || n > cfg.cpu.cores as usize {
        bail!(
            "need 1..={} workloads for {} cores, got {n}",
            cfg.cpu.cores,
            cfg.cpu.cores
        );
    }
    // Shrink per-core footprints so the striped spaces fit the hybrid.
    let mut wl_cfg = cfg.clone();
    wl_cfg.scale = cfg.scale * n as u64;

    // Per-core L2 slice (A57: 1MB per 2-core cluster).
    let mut core_cfg = cfg.clone();
    core_cfg.l2.size_bytes = (cfg.l2.size_bytes / 2).max(64 * 1024);

    let mut backend = HmmuBackend::new(cfg.clone(), engine);

    struct CoreState {
        core: CoreModel,
        hier: CacheHierarchy,
        /// Current trace block (§Perf: a dedicated producer thread
        /// refills blocks for this core; the scheduler consumes the
        /// current one through `cursor`). Two blocks per core circulate
        /// through the channels — no steady-state allocation.
        block: TraceBlock,
        cursor: usize,
        /// Filled blocks arriving from this core's producer thread.
        rx: mpsc::Receiver<TraceBlock>,
        /// Drained blocks returned to the producer for refilling.
        recycle: mpsc::Sender<TraceBlock>,
        stripe: u64,
        workload: String,
    }

    impl CoreState {
        /// Next op for this core, swapping in the next produced block
        /// when the current one is drained. The op sequence is
        /// bit-identical to pulling the generator directly (per-core
        /// seeds and streams are untouched by where the generator runs),
        /// so the time-ordered interleaving — and therefore all
        /// shared-resource contention — is unchanged by the parallel
        /// generation.
        #[inline]
        fn next_op(&mut self) -> Option<crate::workload::TraceOp> {
            if self.cursor == self.block.len() {
                // Producer hung up == trace exhausted. Leaving the
                // drained block in place keeps `cursor == len()`, so a
                // further call re-lands here and returns None again.
                let next = match self.rx.recv() {
                    Ok(b) => b,
                    Err(_) => return None,
                };
                let drained = std::mem::replace(&mut self.block, next);
                // The producer may already have exited; then the drained
                // block is simply dropped.
                let _ = self.recycle.send(drained);
                self.cursor = 0;
            }
            let op = self.block.get(self.cursor);
            self.cursor += 1;
            Some(op)
        }
    }

    /// Shim that offsets addresses into the core's stripe.
    struct StripedBackend<'a> {
        inner: &'a mut HmmuBackend,
        stripe: u64,
    }
    impl MemBackend for StripedBackend<'_> {
        fn access(&mut self, addr: u64, kind: AccessKind, bytes: u64, now: Time) -> Time {
            self.inner.access(addr + self.stripe, kind, bytes, now)
        }
    }

    // §Perf: per-core trace generation runs on scoped producer threads,
    // overlapping block refills with the (serial, time-ordered)
    // scheduling loop. Each producer owns its core's generator — same
    // per-core seed as before — and trades blocks with the scheduler
    // over a bounded channel pair: one block being consumed, one in
    // flight, recycled in both directions, so the steady state allocates
    // nothing and each core's op stream is bit-identical to serial
    // generation.
    std::thread::scope(|s| {
        let mut cores: Vec<CoreState> = workloads
            .iter()
            .enumerate()
            .map(|(i, wl)| {
                let (block_tx, block_rx) = mpsc::sync_channel::<TraceBlock>(1);
                let (recycle_tx, recycle_rx) = mpsc::channel::<TraceBlock>();
                // Two full-capacity blocks circulate per core: one seeded
                // on the producer's side, one starting (empty) as the
                // scheduler's current block below.
                recycle_tx.send(TraceBlock::new()).expect("fresh channel");
                let mut gen = TraceGenerator::new(*wl, wl_cfg.scale, cfg.seed ^ (i as u64) << 32)
                    .take_ops(opts.ops);
                s.spawn(move || {
                    while let Ok(mut block) = recycle_rx.recv() {
                        if gen.fill_block(&mut block) == 0 {
                            // Dropping `block_tx` signals exhaustion.
                            break;
                        }
                        if block_tx.send(block).is_err() {
                            break;
                        }
                    }
                });
                CoreState {
                    core: CoreModel::new(cfg.cpu),
                    hier: CacheHierarchy::new(&core_cfg),
                    // Starts empty: `cursor == len() == 0`, so the first
                    // `next_op()` receives the first filled block and
                    // hands this one to the producer for refilling.
                    block: TraceBlock::new(),
                    cursor: 0,
                    rx: block_rx,
                    recycle: recycle_tx,
                    stripe: core_stripe(&cfg, i, n),
                    workload: wl.name.to_string(),
                }
            })
            .collect();

        // Time-ordered round-robin: always step the core with the earliest
        // local clock so shared-resource contention is causally ordered.
        // §Perf: an indexed min-heap replaces the old O(cores) min-scan per
        // step; ties break on core index (lexicographic `(time, idx)`),
        // matching the old first-minimum selection exactly, so timelines are
        // bit-identical. Each live core has exactly one heap entry; a core's
        // clock only changes when it is stepped, so entries are never stale.
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mut ready: BinaryHeap<Reverse<(Time, usize)>> = cores
            .iter()
            .enumerate()
            .map(|(i, c)| Reverse((c.core.now(), i)))
            .collect();
        while let Some(Reverse((_, idx))) = ready.pop() {
            let c = &mut cores[idx];
            match c.next_op() {
                Some(op) => {
                    let mut shim = StripedBackend {
                        inner: &mut backend,
                        stripe: c.stripe,
                    };
                    c.core.step(&op, &mut c.hier, &mut shim);
                    ready.push(Reverse((c.core.now(), idx)));
                }
                None => {
                    c.core.finish();
                }
            }
        }

        let makespan = cores.iter().map(|c| c.core.stats.time_ns).max().unwrap_or(0);
        backend.drain(makespan);
        // Mirror link replays and device row-buffer outcomes into the
        // shared counter block (same as the single-core report path).
        backend.hmmu.counters.link_retries = backend.link.link_retries;
        backend.hmmu.sync_row_counters();

        let reports: Vec<CoreReport> = cores
            .iter()
            .enumerate()
            .map(|(i, c)| CoreReport {
                core: i,
                workload: c.workload.clone(),
                instructions: c.core.stats.instructions,
                mem_ops: c.core.stats.mem_ops,
                memory_accesses: c.core.stats.memory_accesses,
                time_ns: c.core.stats.time_ns,
            })
            .collect();
        let total_instr: u64 = reports.iter().map(|r| r.instructions).sum();
        Ok(MulticoreReport {
            aggregate_mips: total_instr as f64 / (makespan.max(1) as f64 / 1000.0),
            hmmu_requests: backend.hmmu.counters.total_host_requests(),
            pcie_credit_stalls: backend.link.credit_stalls,
            fifo_full_stalls: backend.hmmu.counters.fifo_full_stalls,
            dram_residency: backend.hmmu.dram_residency(),
            nvm_max_wear: backend.hmmu.nvm_max_wear(),
            topology: cfg.topology_label(),
            tier_wear: backend.hmmu.tier_wear(),
            tier_residency: backend.hmmu.tier_residency(),
            counters: backend.hmmu.counters.clone(),
            cores: reports,
            makespan_ns: makespan,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec;

    fn opts(ops: u64) -> RunOpts {
        RunOpts {
            ops,
            flush_at_end: false,
        }
    }

    #[test]
    fn two_cores_run_to_completion() {
        let cfg = SystemConfig::default_scaled(64);
        let wls = vec![
            spec::by_name("505.mcf").unwrap(),
            spec::by_name("538.imagick").unwrap(),
        ];
        let r = run_multicore(cfg, &wls, opts(10_000), None).unwrap();
        assert_eq!(r.cores.len(), 2);
        assert_eq!(r.cores[0].mem_ops, 10_000);
        assert_eq!(r.cores[1].mem_ops, 10_000);
        assert!(r.makespan_ns > 0);
        // mcf (memory bound) takes longer than imagick on-core.
        assert!(r.cores[0].time_ns > r.cores[1].time_ns);
    }

    #[test]
    fn contention_slows_vs_solo() {
        let cfg = SystemConfig::default_scaled(64);
        let mcf = spec::by_name("505.mcf").unwrap();
        let solo = run_multicore(cfg.clone(), &[mcf], opts(15_000), None).unwrap();
        let four = run_multicore(cfg, &[mcf, mcf, mcf, mcf], opts(15_000), None).unwrap();
        // Sharing the link/HMMU/devices must not speed a copy up.
        assert!(
            four.cores[0].time_ns >= solo.cores[0].time_ns,
            "contended {} < solo {}",
            four.cores[0].time_ns,
            solo.cores[0].time_ns
        );
    }

    #[test]
    fn stripes_do_not_overlap() {
        let cfg = SystemConfig::default_scaled(64);
        let n = 4;
        let stripe_bytes = cfg.total_mem_bytes() / n as u64;
        for i in 0..n {
            let s = core_stripe(&cfg, i, n);
            assert_eq!(s % cfg.hmmu.page_bytes, 0);
            assert!(s + stripe_bytes <= cfg.total_mem_bytes() + stripe_bytes);
        }
    }

    #[test]
    fn host_managed_dma_works_under_multicore_sharing() {
        // The shared HmmuBackend threads its link into every HMMU access,
        // so host-managed migration charging composes with multicore
        // interleaving: DMA link bytes appear (2× migration_bytes — see
        // the platform test) and the run stays reproducible.
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = crate::config::PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 2_000;
        cfg.hmmu.host_managed_dma = true;
        let wls = vec![
            spec::by_name("505.mcf").unwrap(),
            spec::by_name("520.omnetpp").unwrap(),
        ];
        let a = run_multicore(cfg.clone(), &wls, opts(40_000), None).unwrap();
        assert!(a.counters.migrations > 0, "scenario must migrate");
        assert_eq!(
            a.counters.pcie_dma_bytes,
            2 * a.counters.migration_bytes,
            "host-managed DMA must charge the shared link"
        );
        let b = run_multicore(cfg, &wls, opts(40_000), None).unwrap();
        assert_eq!(format!("{:?}", a.counters), format!("{:?}", b.counters));
        assert_eq!(a.makespan_ns, b.makespan_ns);
    }

    #[test]
    fn too_many_cores_rejected() {
        let cfg = SystemConfig::default_scaled(64);
        let wl = spec::by_name("541.leela").unwrap();
        let wls = vec![wl; cfg.cpu.cores as usize + 1];
        assert!(run_multicore(cfg, &wls, opts(100), None).is_err());
    }

    #[test]
    fn aggregate_mips_positive() {
        let cfg = SystemConfig::default_scaled(64);
        let wls = vec![
            spec::by_name("541.leela").unwrap(),
            spec::by_name("544.nab").unwrap(),
        ];
        let r = run_multicore(cfg, &wls, opts(5_000), None).unwrap();
        assert!(r.aggregate_mips > 0.0);
        assert!(r.hmmu_requests > 0);
    }
}
