//! Multi-programmed multicore runs (the LS2085A has 8 A57 cores; the
//! paper's platform serves them all through one PCIe link and one HMMU).
//!
//! Each core runs its own workload trace through a private L1/L2
//! hierarchy (A57 clusters share L2 pairwise; we give each core a
//! half-sized L2 slice, which bounds the same capacity), with all
//! post-cache traffic contending for the shared link + HMMU + devices.
//! Address spaces are striped per core so working sets do not overlap
//! (rate-style SPEC runs).
//!
//! Cores are interleaved on the shared timeline in lockstep-by-time:
//! the core with the smallest local clock steps next, so cross-core
//! contention at the link and memory controllers is ordered correctly.

use std::sync::mpsc;

use super::checkpoint::{CHECKPOINT_KIND_MULTI, CHECKPOINT_MAGIC, CHECKPOINT_VERSION};
use super::{HmmuBackend, RunOpts};
use crate::config::SystemConfig;
use crate::cpu::{CacheHierarchy, CoreModel, MemBackend};
use crate::hmmu::{HmmuCounters, HotnessEngine};
use crate::mem::AccessKind;
use crate::sim::Time;
use crate::util::codec::{fingerprint64, CodecState, Decoder, Encoder};
use crate::workload::{TraceBlock, TraceGenerator, Workload};
use crate::bail;
use crate::util::error::Result;

/// Report for one core of a multicore run.
#[derive(Clone, Debug)]
pub struct CoreReport {
    pub core: usize,
    pub workload: String,
    pub instructions: u64,
    pub mem_ops: u64,
    pub memory_accesses: u64,
    pub time_ns: u64,
}

/// Aggregate multicore report.
#[derive(Clone, Debug)]
pub struct MulticoreReport {
    pub cores: Vec<CoreReport>,
    /// Makespan: time when the last core finished.
    pub makespan_ns: u64,
    /// Total post-cache requests served by the HMMU.
    pub hmmu_requests: u64,
    pub pcie_credit_stalls: u64,
    pub fifo_full_stalls: u64,
    /// Aggregate modeled MIPS across cores.
    pub aggregate_mips: f64,
    /// Full HMMU counter block (one HMMU shared by all cores) — lets the
    /// sweep engine report multicore scenarios with the same columns as
    /// single-core runs.
    pub counters: HmmuCounters,
    /// DRAM residency of mapped pages at end of run.
    pub dram_residency: f64,
    pub nvm_max_wear: u64,
    /// Tier-stack topology label (e.g. `dram+xpoint`).
    pub topology: String,
    /// Per-tier max wear, rank order.
    pub tier_wear: Vec<u64>,
    /// Per-tier resident page counts at end of run, rank order.
    pub tier_residency: Vec<u64>,
}

impl MulticoreReport {
    pub fn summary(&self) -> String {
        use crate::util::units::fmt_ns;
        let mut s = format!(
            "{} cores, makespan {}, {} HMMU requests, {:.1} aggregate MIPS\n",
            self.cores.len(),
            fmt_ns(self.makespan_ns),
            self.hmmu_requests,
            self.aggregate_mips,
        );
        for c in &self.cores {
            s.push_str(&format!(
                "  core{} {:<16} {:>10} instr  {:>8} memAcc  {}\n",
                c.core,
                c.workload,
                c.instructions,
                c.memory_accesses,
                fmt_ns(c.time_ns)
            ));
        }
        s
    }
}

/// Offset added to each core's addresses so rate-style copies do not
/// share pages (stripes the flat space per core).
fn core_stripe(cfg: &SystemConfig, core: usize, n_cores: usize) -> u64 {
    let stripe = cfg.total_mem_bytes() / n_cores as u64;
    (stripe & !(cfg.hmmu.page_bytes - 1)) * core as u64
}

/// Shim that offsets addresses into the core's stripe. Shared by the
/// cold scheduler loop and the warm checkpoint engine below so both
/// charge the identical addresses to the shared backend.
struct StripedBackend<'a> {
    inner: &'a mut HmmuBackend,
    stripe: u64,
}
impl MemBackend for StripedBackend<'_> {
    fn access(&mut self, addr: u64, kind: AccessKind, bytes: u64, now: Time) -> Time {
        self.inner.access(addr + self.stripe, kind, bytes, now)
    }
}

/// Run `workloads` (one per core) against a single shared HMMU.
pub fn run_multicore(
    cfg: SystemConfig,
    workloads: &[Workload],
    opts: RunOpts,
    engine: Option<Box<dyn HotnessEngine>>,
) -> Result<MulticoreReport> {
    let n = workloads.len();
    if n == 0 || n > cfg.cpu.cores as usize {
        bail!(
            "need 1..={} workloads for {} cores, got {n}",
            cfg.cpu.cores,
            cfg.cpu.cores
        );
    }
    // Shrink per-core footprints so the striped spaces fit the hybrid.
    let mut wl_cfg = cfg.clone();
    wl_cfg.scale = cfg.scale * n as u64;

    // Per-core L2 slice (A57: 1MB per 2-core cluster).
    let mut core_cfg = cfg.clone();
    core_cfg.l2.size_bytes = (cfg.l2.size_bytes / 2).max(64 * 1024);

    let mut backend = HmmuBackend::new(cfg.clone(), engine);

    struct CoreState {
        core: CoreModel,
        hier: CacheHierarchy,
        /// Current trace block (§Perf: a dedicated producer thread
        /// refills blocks for this core; the scheduler consumes the
        /// current one through `cursor`). Two blocks per core circulate
        /// through the channels — no steady-state allocation.
        block: TraceBlock,
        cursor: usize,
        /// Filled blocks arriving from this core's producer thread.
        rx: mpsc::Receiver<TraceBlock>,
        /// Drained blocks returned to the producer for refilling.
        recycle: mpsc::Sender<TraceBlock>,
        stripe: u64,
        workload: String,
    }

    impl CoreState {
        /// Next op for this core, swapping in the next produced block
        /// when the current one is drained. The op sequence is
        /// bit-identical to pulling the generator directly (per-core
        /// seeds and streams are untouched by where the generator runs),
        /// so the time-ordered interleaving — and therefore all
        /// shared-resource contention — is unchanged by the parallel
        /// generation.
        #[inline]
        fn next_op(&mut self) -> Option<crate::workload::TraceOp> {
            if self.cursor == self.block.len() {
                // Producer hung up == trace exhausted. Leaving the
                // drained block in place keeps `cursor == len()`, so a
                // further call re-lands here and returns None again.
                let next = match self.rx.recv() {
                    Ok(b) => b,
                    Err(_) => return None,
                };
                let drained = std::mem::replace(&mut self.block, next);
                // The producer may already have exited; then the drained
                // block is simply dropped.
                let _ = self.recycle.send(drained);
                self.cursor = 0;
            }
            let op = self.block.get(self.cursor);
            self.cursor += 1;
            Some(op)
        }
    }

    // §Perf: per-core trace generation runs on scoped producer threads,
    // overlapping block refills with the (serial, time-ordered)
    // scheduling loop. Each producer owns its core's generator — same
    // per-core seed as before — and trades blocks with the scheduler
    // over a bounded channel pair: one block being consumed, one in
    // flight, recycled in both directions, so the steady state allocates
    // nothing and each core's op stream is bit-identical to serial
    // generation.
    std::thread::scope(|s| {
        let mut cores: Vec<CoreState> = workloads
            .iter()
            .enumerate()
            .map(|(i, wl)| {
                let (block_tx, block_rx) = mpsc::sync_channel::<TraceBlock>(1);
                let (recycle_tx, recycle_rx) = mpsc::channel::<TraceBlock>();
                // Two full-capacity blocks circulate per core: one seeded
                // on the producer's side, one starting (empty) as the
                // scheduler's current block below.
                recycle_tx.send(TraceBlock::new()).expect("fresh channel");
                let mut gen = TraceGenerator::new(*wl, wl_cfg.scale, cfg.seed ^ (i as u64) << 32)
                    .take_ops(opts.ops);
                s.spawn(move || {
                    while let Ok(mut block) = recycle_rx.recv() {
                        if gen.fill_block(&mut block) == 0 {
                            // Dropping `block_tx` signals exhaustion.
                            break;
                        }
                        if block_tx.send(block).is_err() {
                            break;
                        }
                    }
                });
                CoreState {
                    core: CoreModel::new(cfg.cpu),
                    hier: CacheHierarchy::new(&core_cfg),
                    // Starts empty: `cursor == len() == 0`, so the first
                    // `next_op()` receives the first filled block and
                    // hands this one to the producer for refilling.
                    block: TraceBlock::new(),
                    cursor: 0,
                    rx: block_rx,
                    recycle: recycle_tx,
                    stripe: core_stripe(&cfg, i, n),
                    workload: wl.name.to_string(),
                }
            })
            .collect();

        // Time-ordered round-robin: always step the core with the earliest
        // local clock so shared-resource contention is causally ordered.
        // §Perf: an indexed min-heap replaces the old O(cores) min-scan per
        // step; ties break on core index (lexicographic `(time, idx)`),
        // matching the old first-minimum selection exactly, so timelines are
        // bit-identical. Each live core has exactly one heap entry; a core's
        // clock only changes when it is stepped, so entries are never stale.
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mut ready: BinaryHeap<Reverse<(Time, usize)>> = cores
            .iter()
            .enumerate()
            .map(|(i, c)| Reverse((c.core.now(), i)))
            .collect();
        while let Some(Reverse((_, idx))) = ready.pop() {
            let c = &mut cores[idx];
            match c.next_op() {
                Some(op) => {
                    let mut shim = StripedBackend {
                        inner: &mut backend,
                        stripe: c.stripe,
                    };
                    c.core.step(&op, &mut c.hier, &mut shim);
                    ready.push(Reverse((c.core.now(), idx)));
                }
                None => {
                    c.core.finish();
                }
            }
        }

        let makespan = cores.iter().map(|c| c.core.stats.time_ns).max().unwrap_or(0);
        backend.drain(makespan);
        // Mirror link replays and device row-buffer outcomes into the
        // shared counter block (same as the single-core report path).
        backend.hmmu.counters.link_retries = backend.link.link_retries;
        backend.hmmu.sync_row_counters();

        let reports: Vec<CoreReport> = cores
            .iter()
            .enumerate()
            .map(|(i, c)| CoreReport {
                core: i,
                workload: c.workload.clone(),
                instructions: c.core.stats.instructions,
                mem_ops: c.core.stats.mem_ops,
                memory_accesses: c.core.stats.memory_accesses,
                time_ns: c.core.stats.time_ns,
            })
            .collect();
        let total_instr: u64 = reports.iter().map(|r| r.instructions).sum();
        Ok(MulticoreReport {
            aggregate_mips: total_instr as f64 / (makespan.max(1) as f64 / 1000.0),
            hmmu_requests: backend.hmmu.counters.total_host_requests(),
            pcie_credit_stalls: backend.link.credit_stalls,
            fifo_full_stalls: backend.hmmu.counters.fifo_full_stalls,
            dram_residency: backend.hmmu.dram_residency(),
            nvm_max_wear: backend.hmmu.nvm_max_wear(),
            topology: cfg.topology_label(),
            tier_wear: backend.hmmu.tier_wear(),
            tier_residency: backend.hmmu.tier_residency(),
            counters: backend.hmmu.counters.clone(),
            cores: reports,
            makespan_ns: makespan,
        })
    })
}

/// One core's warm state inside a [`WarmMulticore`] snapshot: the core
/// model, its private cache hierarchy, and its trace-generator cursor.
#[derive(Clone)]
struct WarmCore {
    core: CoreModel,
    hier: CacheHierarchy,
    gen: TraceGenerator,
    /// Trace exhausted and `core.finish()` already charged.
    done: bool,
    stripe: u64,
    workload: String,
}

/// A multicore run paused mid-interleaving, ready to be forked across
/// scenario variants or resumed to completion — the `cores > 1`
/// counterpart of [`super::WarmPlatform`].
///
/// The warm engine pulls each core's [`TraceGenerator`] directly instead
/// of through `run_multicore`'s producer threads; the op streams are
/// bit-identical either way (`fill_block` shares `gen_op` with the
/// `Iterator` impl, pinned by `fill_block_bit_identical_to_iterator`),
/// so the time-ordered interleaving — and every shared-resource
/// contention outcome — matches the cold path exactly. Unlike the
/// single-core engine there is no native reference pass (multicore
/// reports carry no native columns), and `flush_at_end` is ignored just
/// as `run_multicore` ignores it.
#[derive(Clone)]
pub struct WarmMulticore {
    cfg: SystemConfig,
    opts: RunOpts,
    /// Ops already executed across all cores (the warm prefix length).
    warmed: u64,
    backend: HmmuBackend,
    cores: Vec<WarmCore>,
}

impl WarmMulticore {
    /// A cold multicore platform: identical initial state to the top of
    /// `run_multicore`'s scheduling loop (same per-core seeds, scale
    /// inflation, L2 halving, and stripe offsets).
    pub fn new(cfg: SystemConfig, workloads: &[Workload], opts: RunOpts) -> Result<Self> {
        let n = workloads.len();
        if n == 0 || n > cfg.cpu.cores as usize {
            bail!(
                "need 1..={} workloads for {} cores, got {n}",
                cfg.cpu.cores,
                cfg.cpu.cores
            );
        }
        let mut wl_cfg = cfg.clone();
        wl_cfg.scale = cfg.scale * n as u64;
        let mut core_cfg = cfg.clone();
        core_cfg.l2.size_bytes = (cfg.l2.size_bytes / 2).max(64 * 1024);
        let backend = HmmuBackend::new(cfg.clone(), None);
        let cores = workloads
            .iter()
            .enumerate()
            .map(|(i, wl)| WarmCore {
                core: CoreModel::new(cfg.cpu),
                hier: CacheHierarchy::new(&core_cfg),
                gen: TraceGenerator::new(*wl, wl_cfg.scale, cfg.seed ^ (i as u64) << 32)
                    .take_ops(opts.ops),
                done: false,
                stripe: core_stripe(&cfg, i, n),
                workload: wl.name.to_string(),
            })
            .collect();
        Ok(WarmMulticore {
            cfg,
            opts,
            warmed: 0,
            backend,
            cores,
        })
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Total ops executed so far across all cores (warm prefix length).
    pub fn warmed_ops(&self) -> u64 {
        self.warmed
    }

    /// Step the time-ordered interleaving for up to `budget` ops (summed
    /// across cores), then pause. The heap is rebuilt from each live
    /// core's current clock on every call — each live core has exactly
    /// one entry either way, so pause/resume is bit-identical to one
    /// continuous scheduling loop. Returns the ops actually stepped.
    fn advance(&mut self, budget: u64) -> u64 {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mut ready: BinaryHeap<Reverse<(Time, usize)>> = self
            .cores
            .iter()
            .enumerate()
            .filter(|(_, c)| !c.done)
            .map(|(i, c)| Reverse((c.core.now(), i)))
            .collect();
        let mut stepped = 0u64;
        while stepped < budget {
            let Some(Reverse((_, idx))) = ready.pop() else {
                break;
            };
            let c = &mut self.cores[idx];
            match c.gen.next() {
                Some(op) => {
                    let mut shim = StripedBackend {
                        inner: &mut self.backend,
                        stripe: c.stripe,
                    };
                    c.core.step(&op, &mut c.hier, &mut shim);
                    ready.push(Reverse((c.core.now(), idx)));
                    stepped += 1;
                }
                None => {
                    c.core.finish();
                    c.done = true;
                }
            }
        }
        self.warmed += stepped;
        stepped
    }

    /// Advance the interleaved run by up to `n` ops total across cores
    /// (the multicore warm budget is per-run, not per-core: cores that
    /// stall on shared resources naturally warm fewer ops, exactly as
    /// they would in the cold run's prefix).
    pub fn warm_up(&mut self, n: u64) {
        self.advance(n);
    }

    /// Fork this warm state at scenario `cfg`, which may differ from the
    /// warm config only on the fork axes (policy kind, rank-1 stalls).
    /// O(state size) clone; no simulation happens here.
    pub fn fork(&self, cfg: &SystemConfig) -> WarmMulticore {
        let mut wm = self.clone();
        wm.backend.hmmu.morph_for_fork(cfg);
        wm.cfg = cfg.clone();
        wm
    }

    /// Run the remaining interleaving and produce the same
    /// [`MulticoreReport`] a cold `run_multicore` of the full run would.
    pub fn run_to_completion(mut self) -> Result<MulticoreReport> {
        self.advance(u64::MAX);
        let makespan = self
            .cores
            .iter()
            .map(|c| c.core.stats.time_ns)
            .max()
            .unwrap_or(0);
        self.backend.drain(makespan);
        // Same link_retries / row-counter mirrors as `run_multicore` —
        // the forked report must be byte-identical to a cold run's.
        self.backend.hmmu.counters.link_retries = self.backend.link.link_retries;
        self.backend.hmmu.sync_row_counters();

        let reports: Vec<CoreReport> = self
            .cores
            .iter()
            .enumerate()
            .map(|(i, c)| CoreReport {
                core: i,
                workload: c.workload.clone(),
                instructions: c.core.stats.instructions,
                mem_ops: c.core.stats.mem_ops,
                memory_accesses: c.core.stats.memory_accesses,
                time_ns: c.core.stats.time_ns,
            })
            .collect();
        let total_instr: u64 = reports.iter().map(|r| r.instructions).sum();
        let backend = self.backend;
        Ok(MulticoreReport {
            aggregate_mips: total_instr as f64 / (makespan.max(1) as f64 / 1000.0),
            hmmu_requests: backend.hmmu.counters.total_host_requests(),
            pcie_credit_stalls: backend.link.credit_stalls,
            fifo_full_stalls: backend.hmmu.counters.fifo_full_stalls,
            dram_residency: backend.hmmu.dram_residency(),
            nvm_max_wear: backend.hmmu.nvm_max_wear(),
            topology: self.cfg.topology_label(),
            tier_wear: backend.hmmu.tier_wear(),
            tier_residency: backend.hmmu.tier_residency(),
            counters: backend.hmmu.counters.clone(),
            cores: reports,
            makespan_ns: makespan,
        })
    }

    /// Cache key for a serialized multicore checkpoint. The `mc{n}|`
    /// prefix keeps multicore keys disjoint from single-core ones (core
    /// count is a scenario axis, not part of the config Debug surface).
    pub fn cache_key(
        cfg: &SystemConfig,
        workloads: &[Workload],
        opts: RunOpts,
        warm_ops: u64,
    ) -> u64 {
        let names: Vec<&str> = workloads.iter().map(|w| w.name).collect();
        fingerprint64(&format!(
            "mc{}|{:?}|{}|{}|{}|{warm_ops}",
            workloads.len(),
            cfg,
            names.join("+"),
            opts.ops,
            opts.flush_at_end
        ))
    }

    /// Serialize the warm state (versioned header + shared backend +
    /// every core's [`CodecState`] payload).
    pub fn save(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        e.put_u32(CHECKPOINT_MAGIC);
        e.put_u32(CHECKPOINT_VERSION);
        e.put_u8(CHECKPOINT_KIND_MULTI);
        e.put_u64(fingerprint64(&format!("{:?}", self.cfg)));
        e.put_len(self.cores.len());
        for c in &self.cores {
            e.put_str(&c.workload);
        }
        e.put_u64(self.cfg.scale);
        e.put_u64(self.cfg.seed);
        e.put_u64(self.opts.ops);
        e.put_bool(self.opts.flush_at_end);
        e.put_u64(self.warmed);
        self.backend.encode_state(&mut e);
        for c in &self.cores {
            c.core.encode_state(&mut e);
            c.hier.encode_state(&mut e);
            c.gen.encode_state(&mut e);
            e.put_bool(c.done);
        }
        e.into_bytes()
    }

    /// Rebuild a warm multicore platform from checkpoint `bytes`. The
    /// geometry comes from the arguments — the header only *validates*
    /// that the bytes belong to this scenario (config fingerprint, core
    /// count, per-core workload names, run sizing).
    pub fn load(
        bytes: &[u8],
        cfg: SystemConfig,
        workloads: &[Workload],
        opts: RunOpts,
    ) -> Result<Self> {
        let mut d = Decoder::new(bytes);
        let magic = d.u32()?;
        if magic != CHECKPOINT_MAGIC {
            bail!("not a checkpoint: bad magic {magic:#x}");
        }
        let version = d.u32()?;
        if version != CHECKPOINT_VERSION {
            bail!("checkpoint version {version} != {CHECKPOINT_VERSION}");
        }
        let kind = d.u8()?;
        if kind != CHECKPOINT_KIND_MULTI {
            bail!("checkpoint kind {kind} is not a multicore checkpoint");
        }
        let fp = d.u64()?;
        let want_fp = fingerprint64(&format!("{:?}", cfg));
        if fp != want_fp {
            bail!("checkpoint config fingerprint {fp:#x} != {want_fp:#x}");
        }
        let n = d.len()?;
        if n != workloads.len() {
            bail!("checkpoint core count {n} != {}", workloads.len());
        }
        for wl in workloads {
            let name = d.str()?;
            if name != wl.name {
                bail!("checkpoint workload {name:?} != {:?}", wl.name);
            }
        }
        let scale = d.u64()?;
        let seed = d.u64()?;
        if scale != cfg.scale || seed != cfg.seed {
            bail!(
                "checkpoint scale/seed {scale}/{seed} != {}/{}",
                cfg.scale,
                cfg.seed
            );
        }
        let ops = d.u64()?;
        let flush = d.bool()?;
        if ops != opts.ops || flush != opts.flush_at_end {
            bail!(
                "checkpoint run sizing {ops}/{flush} != {}/{}",
                opts.ops,
                opts.flush_at_end
            );
        }
        let warmed = d.u64()?;
        let mut wm = WarmMulticore::new(cfg, workloads, opts)?;
        wm.warmed = warmed;
        wm.backend.decode_state(&mut d)?;
        for c in &mut wm.cores {
            c.core.decode_state(&mut d)?;
            c.hier.decode_state(&mut d)?;
            c.gen.decode_state(&mut d)?;
            c.done = d.bool()?;
        }
        if !d.is_done() {
            bail!("checkpoint has {} trailing bytes", d.remaining());
        }
        Ok(wm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec;

    fn opts(ops: u64) -> RunOpts {
        RunOpts {
            ops,
            flush_at_end: false,
        }
    }

    #[test]
    fn two_cores_run_to_completion() {
        let cfg = SystemConfig::default_scaled(64);
        let wls = vec![
            spec::by_name("505.mcf").unwrap(),
            spec::by_name("538.imagick").unwrap(),
        ];
        let r = run_multicore(cfg, &wls, opts(10_000), None).unwrap();
        assert_eq!(r.cores.len(), 2);
        assert_eq!(r.cores[0].mem_ops, 10_000);
        assert_eq!(r.cores[1].mem_ops, 10_000);
        assert!(r.makespan_ns > 0);
        // mcf (memory bound) takes longer than imagick on-core.
        assert!(r.cores[0].time_ns > r.cores[1].time_ns);
    }

    #[test]
    fn contention_slows_vs_solo() {
        let cfg = SystemConfig::default_scaled(64);
        let mcf = spec::by_name("505.mcf").unwrap();
        let solo = run_multicore(cfg.clone(), &[mcf], opts(15_000), None).unwrap();
        let four = run_multicore(cfg, &[mcf, mcf, mcf, mcf], opts(15_000), None).unwrap();
        // Sharing the link/HMMU/devices must not speed a copy up.
        assert!(
            four.cores[0].time_ns >= solo.cores[0].time_ns,
            "contended {} < solo {}",
            four.cores[0].time_ns,
            solo.cores[0].time_ns
        );
    }

    #[test]
    fn stripes_do_not_overlap() {
        let cfg = SystemConfig::default_scaled(64);
        let n = 4;
        let stripe_bytes = cfg.total_mem_bytes() / n as u64;
        for i in 0..n {
            let s = core_stripe(&cfg, i, n);
            assert_eq!(s % cfg.hmmu.page_bytes, 0);
            assert!(s + stripe_bytes <= cfg.total_mem_bytes() + stripe_bytes);
        }
    }

    #[test]
    fn host_managed_dma_works_under_multicore_sharing() {
        // The shared HmmuBackend threads its link into every HMMU access,
        // so host-managed migration charging composes with multicore
        // interleaving: DMA link bytes appear (2× migration_bytes — see
        // the platform test) and the run stays reproducible.
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = crate::config::PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 2_000;
        cfg.hmmu.host_managed_dma = true;
        let wls = vec![
            spec::by_name("505.mcf").unwrap(),
            spec::by_name("520.omnetpp").unwrap(),
        ];
        let a = run_multicore(cfg.clone(), &wls, opts(40_000), None).unwrap();
        assert!(a.counters.migrations > 0, "scenario must migrate");
        assert_eq!(
            a.counters.pcie_dma_bytes,
            2 * a.counters.migration_bytes,
            "host-managed DMA must charge the shared link"
        );
        let b = run_multicore(cfg, &wls, opts(40_000), None).unwrap();
        assert_eq!(format!("{:?}", a.counters), format!("{:?}", b.counters));
        assert_eq!(a.makespan_ns, b.makespan_ns);
    }

    #[test]
    fn too_many_cores_rejected() {
        let cfg = SystemConfig::default_scaled(64);
        let wl = spec::by_name("541.leela").unwrap();
        let wls = vec![wl; cfg.cpu.cores as usize + 1];
        assert!(run_multicore(cfg, &wls, opts(100), None).is_err());
    }

    /// Full-fidelity comparison of two multicore reports.
    fn assert_reports_match(a: &MulticoreReport, b: &MulticoreReport, label: &str) {
        assert_eq!(a.makespan_ns, b.makespan_ns, "{label}");
        assert_eq!(
            format!("{:?}", a.counters),
            format!("{:?}", b.counters),
            "{label}"
        );
        assert_eq!(a.tier_residency, b.tier_residency, "{label}");
        assert_eq!(a.tier_wear, b.tier_wear, "{label}");
        assert_eq!(a.nvm_max_wear, b.nvm_max_wear, "{label}");
        for (ca, cb) in a.cores.iter().zip(&b.cores) {
            assert_eq!(ca.time_ns, cb.time_ns, "{label}/core{}", ca.core);
            assert_eq!(ca.instructions, cb.instructions, "{label}/core{}", ca.core);
            assert_eq!(ca.mem_ops, cb.mem_ops, "{label}/core{}", ca.core);
        }
    }

    #[test]
    fn warm_then_run_matches_cold_multicore() {
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = crate::config::PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 2_000;
        let wls = vec![
            spec::by_name("505.mcf").unwrap(),
            spec::by_name("520.omnetpp").unwrap(),
        ];
        let cold = run_multicore(cfg.clone(), &wls, opts(12_000), None).unwrap();
        for warm_ops in [0u64, 5_000] {
            let mut warm = WarmMulticore::new(cfg.clone(), &wls, opts(12_000)).unwrap();
            warm.warm_up(warm_ops);
            let split = warm.run_to_completion().unwrap();
            assert_reports_match(&cold, &split, &format!("warm={warm_ops}"));
        }
    }

    #[test]
    fn serialized_round_trip_resumes_identically() {
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = crate::config::PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 2_000;
        let wls = vec![
            spec::by_name("505.mcf").unwrap(),
            spec::by_name("538.imagick").unwrap(),
        ];
        let mut warm = WarmMulticore::new(cfg.clone(), &wls, opts(10_000)).unwrap();
        warm.warm_up(6_000);
        let bytes = warm.save();
        let restored = WarmMulticore::load(&bytes, cfg, &wls, opts(10_000)).unwrap();
        assert_eq!(restored.warmed_ops(), warm.warmed_ops());
        let a = warm.run_to_completion().unwrap();
        let b = restored.run_to_completion().unwrap();
        assert_reports_match(&a, &b, "roundtrip");
    }

    #[test]
    fn load_rejects_wrong_scenario() {
        let cfg = SystemConfig::default_scaled(64);
        let wls = vec![
            spec::by_name("505.mcf").unwrap(),
            spec::by_name("538.imagick").unwrap(),
        ];
        let mut warm = WarmMulticore::new(cfg.clone(), &wls, opts(4_000)).unwrap();
        warm.warm_up(1_000);
        let bytes = warm.save();
        // Different config → fingerprint mismatch.
        let mut other = cfg.clone();
        other.policy = crate::config::PolicyKind::Hotness;
        assert!(WarmMulticore::load(&bytes, other, &wls, opts(4_000)).is_err());
        // Different core count → count mismatch.
        assert!(WarmMulticore::load(&bytes, cfg.clone(), &wls[..1], opts(4_000)).is_err());
        // Different workload order → name mismatch.
        let swapped = vec![wls[1], wls[0]];
        assert!(WarmMulticore::load(&bytes, cfg.clone(), &swapped, opts(4_000)).is_err());
        // Truncated payload → positioned decode error.
        let truncated = &bytes[..bytes.len() / 2];
        assert!(WarmMulticore::load(truncated, cfg.clone(), &wls, opts(4_000)).is_err());
        // A single-core checkpoint must be rejected by kind.
        let wl = spec::by_name("505.mcf").unwrap();
        let single = super::super::WarmPlatform::new(
            cfg.clone(),
            &wl,
            RunOpts {
                ops: 4_000,
                flush_at_end: false,
            },
        )
        .save();
        assert!(WarmMulticore::load(&single, cfg, &wls, opts(4_000)).is_err());
    }

    #[test]
    fn fork_morphs_policy() {
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = crate::config::PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 2_000;
        let wls = vec![
            spec::by_name("505.mcf").unwrap(),
            spec::by_name("520.omnetpp").unwrap(),
        ];
        let mut warm = WarmMulticore::new(cfg.clone(), &wls, opts(40_000)).unwrap();
        warm.warm_up(2_000);
        let mut static_cfg = cfg.clone();
        static_cfg.policy = crate::config::PolicyKind::Static;
        let forked = warm.fork(&static_cfg).run_to_completion().unwrap();
        let hot = warm.run_to_completion().unwrap();
        // The hotness run migrates; the statically-placed fork does not
        // migrate after the fork point, so it must see strictly fewer.
        assert!(hot.counters.migrations > forked.counters.migrations);
    }

    #[test]
    fn aggregate_mips_positive() {
        let cfg = SystemConfig::default_scaled(64);
        let wls = vec![
            spec::by_name("541.leela").unwrap(),
            spec::by_name("544.nab").unwrap(),
        ];
        let r = run_multicore(cfg, &wls, opts(5_000), None).unwrap();
        assert!(r.aggregate_mips > 0.0);
        assert!(r.hmmu_requests > 0);
    }
}
