//! Native-execution reference: the workload running from the LS2085A's
//! on-board DDR4 (16 GB), no PCIe, no HMMU. Fig 7 normalizes everything
//! against this.

use crate::config::SystemConfig;
use crate::cpu::MemBackend;
use crate::mem::{AccessKind, DramDevice, MemoryController};
use crate::sim::{Clock, Time};
use crate::util::codec::{CodecState, Decoder, Encoder};
use crate::util::error::Result;

/// SoC interconnect latency between LLC miss and the DRAM controller
/// (CCN-504-class fabric on the LS2085A): a fixed cost per access.
const SOC_FABRIC_NS: u64 = 45;

/// Local-DRAM backend.
#[derive(Clone)]
pub struct NativeBackend {
    mc: MemoryController<DramDevice>,
    pub accesses: u64,
}

impl NativeBackend {
    pub fn new(cfg: &SystemConfig) -> Self {
        // On-board DRAM: same DDR4 timing but board-sized (the paper's
        // native runs use the 16 GB on-board memory; capacity is not the
        // bottleneck for any Table III footprint).
        let mut dram = cfg.dram;
        dram.size_bytes = 16 << 30;
        NativeBackend {
            mc: MemoryController::new(
                DramDevice::new(dram),
                Clock::from_mhz(1200.0),
                4,
                cfg.dram.queue_depth,
            ),
            accesses: 0,
        }
    }
}

impl MemBackend for NativeBackend {
    fn access(&mut self, addr: u64, kind: AccessKind, bytes: u64, now: Time) -> Time {
        self.accesses += 1;
        self.mc.issue(addr, kind, bytes, now + SOC_FABRIC_NS)
    }
}

impl CodecState for NativeBackend {
    fn encode_state(&self, e: &mut Encoder) {
        self.mc.encode_state(e);
        e.put_u64(self.accesses);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.mc.decode_state(d)?;
        self.accesses = d.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    #[test]
    fn native_latency_is_dram_class() {
        let cfg = SystemConfig::paper();
        let mut b = NativeBackend::new(&cfg);
        let done = b.access(0, AccessKind::Read, 64, 0);
        // ~45 fabric + ~36 device = ~81ns: an LLC-miss-to-DRAM figure.
        assert!(done > 60 && done < 120, "native latency {done}");
    }

    #[test]
    fn native_faster_than_pcie_roundtrip() {
        let cfg = SystemConfig::paper();
        let mut b = NativeBackend::new(&cfg);
        let native = b.access(0, AccessKind::Read, 64, 0);
        let link = crate::pcie::PcieLink::new(cfg.pcie);
        assert!(link.unloaded_rtt_ns(64) > 3 * native);
    }
}
