//! Trace file I/O — dump synthetic traces to disk and replay them, in
//! the spirit of ChampSim's trace-driven workflow. Useful for (a)
//! regression-pinning a workload's exact request stream, (b) feeding the
//! same trace to external tools, (c) skipping generation cost in
//! repeated experiments.
//!
//! Format (little-endian, 18 bytes/record after a 16-byte header):
//!
//! ```text
//! header:  magic "HYMT" | u16 version | u16 flags | u64 record count
//! record:  u32 gap | u64 addr | u8 flags(bit0=write, bit1=dependent) | u8 pattern | u32 pad? no
//! ```
//! Record layout: gap u32, addr u64, flags u8, pattern u8 → 14 bytes.

use super::trace::TraceOp;
use crate::bail;
use crate::util::error::{Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"HYMT";
const VERSION: u16 = 1;
const RECORD_BYTES: usize = 14;

/// Write `ops` to `path`. Returns the record count.
pub fn dump<I: IntoIterator<Item = TraceOp>>(path: &Path, ops: I) -> Result<u64> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating trace file {path:?}"))?;
    let mut w = BufWriter::new(file);
    // Header with a placeholder count; rewritten at the end.
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&0u16.to_le_bytes())?;
    w.write_all(&0u64.to_le_bytes())?;
    let mut count = 0u64;
    for op in ops {
        w.write_all(&op.gap.to_le_bytes())?;
        w.write_all(&op.addr.to_le_bytes())?;
        let flags = op.is_write as u8 | (op.dependent as u8) << 1;
        w.write_all(&[flags, op.pattern])?;
        count += 1;
    }
    w.flush()?;
    drop(w);
    // Patch the count.
    use std::io::{Seek, SeekFrom};
    let mut f = std::fs::OpenOptions::new().write(true).open(path)?;
    f.seek(SeekFrom::Start(8))?;
    f.write_all(&count.to_le_bytes())?;
    Ok(count)
}

/// Streaming trace-file reader.
pub struct TraceReader {
    r: BufReader<std::fs::File>,
    remaining: u64,
    pub count: u64,
}

impl TraceReader {
    pub fn open(path: &Path) -> Result<Self> {
        let file =
            std::fs::File::open(path).with_context(|| format!("opening trace {path:?}"))?;
        let mut r = BufReader::new(file);
        let mut header = [0u8; 16];
        r.read_exact(&mut header).context("reading trace header")?;
        if &header[0..4] != MAGIC {
            bail!("not a hymem trace file (bad magic)");
        }
        let version = u16::from_le_bytes([header[4], header[5]]);
        if version != VERSION {
            bail!("unsupported trace version {version}");
        }
        let count = u64::from_le_bytes(header[8..16].try_into().unwrap());
        Ok(TraceReader {
            r,
            remaining: count,
            count,
        })
    }
}

impl Iterator for TraceReader {
    type Item = TraceOp;

    fn next(&mut self) -> Option<TraceOp> {
        if self.remaining == 0 {
            return None;
        }
        let mut buf = [0u8; RECORD_BYTES];
        if self.r.read_exact(&mut buf).is_err() {
            self.remaining = 0;
            return None; // truncated file: stop cleanly
        }
        self.remaining -= 1;
        Some(TraceOp {
            gap: u32::from_le_bytes(buf[0..4].try_into().unwrap()),
            addr: u64::from_le_bytes(buf[4..12].try_into().unwrap()),
            is_write: buf[12] & 1 != 0,
            dependent: buf[12] & 2 != 0,
            pattern: buf[13],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{spec, TraceGenerator};

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hymem_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip_preserves_ops() {
        let path = tmp("roundtrip.trace");
        let ops: Vec<TraceOp> = TraceGenerator::new(spec::by_name("505.mcf").unwrap(), 64, 9)
            .take_ops(5000)
            .collect();
        let n = dump(&path, ops.iter().copied()).unwrap();
        assert_eq!(n, 5000);
        let back: Vec<TraceOp> = TraceReader::open(&path).unwrap().collect();
        assert_eq!(back, ops);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad.trace");
        std::fs::write(&path, b"NOPE0123456789ab").unwrap();
        assert!(TraceReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_stops_cleanly() {
        let path = tmp("trunc.trace");
        let ops: Vec<TraceOp> = TraceGenerator::new(spec::by_name("541.leela").unwrap(), 64, 9)
            .take_ops(100)
            .collect();
        dump(&path, ops).unwrap();
        // Chop the file mid-record.
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() - 7]).unwrap();
        let back: Vec<TraceOp> = TraceReader::open(&path).unwrap().collect();
        assert_eq!(back.len(), 99);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn count_header_accurate() {
        let path = tmp("count.trace");
        let gen = TraceGenerator::new(spec::by_name("557.xz").unwrap(), 64, 3).take_ops(321);
        dump(&path, gen).unwrap();
        let r = TraceReader::open(&path).unwrap();
        assert_eq!(r.count, 321);
        std::fs::remove_file(&path).ok();
    }
}
