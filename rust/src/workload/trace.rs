//! Trace record types: the per-op [`TraceOp`] record and the batched
//! struct-of-arrays [`TraceBlock`] the §Perf pipeline moves ops in.

/// One memory operation in a workload trace, with the number of
/// non-memory instructions preceding it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceOp {
    /// Non-memory instructions executed since the previous memory op.
    pub gap: u32,
    /// Virtual address accessed.
    pub addr: u64,
    /// Store (true) or load (false).
    pub is_write: bool,
    /// True if this access depends on the previous one (pointer chase):
    /// its issue cannot overlap the previous miss.
    pub dependent: bool,
    /// Which generator pattern produced this op (PAT_*). Baselines use it
    /// as a stable synthetic instruction pointer so IP-indexed structures
    /// (stride prefetchers) can train, as they would on a real loop body.
    pub pattern: u8,
}

impl TraceOp {
    pub const PAT_STREAM: u8 = 0;
    pub const PAT_STRIDE: u8 = 1;
    pub const PAT_CHASE: u8 = 2;
    pub const PAT_RANDOM: u8 = 3;

    pub fn load(gap: u32, addr: u64) -> Self {
        TraceOp {
            gap,
            addr,
            is_write: false,
            dependent: false,
            pattern: Self::PAT_RANDOM,
        }
    }

    pub fn store(gap: u32, addr: u64) -> Self {
        TraceOp {
            gap,
            addr,
            is_write: true,
            dependent: false,
            pattern: Self::PAT_RANDOM,
        }
    }

    pub fn chained_load(gap: u32, addr: u64) -> Self {
        TraceOp {
            gap,
            addr,
            is_write: false,
            dependent: true,
            pattern: Self::PAT_CHASE,
        }
    }

    /// Total instructions this op accounts for (gap + the op itself).
    pub fn instructions(&self) -> u64 {
        self.gap as u64 + 1
    }
}

/// Default capacity (in ops) of a [`TraceBlock`]: big enough to amortize
/// per-op call overhead across the pipeline, small enough that the three
/// arrays (4096 × (4 + 8 + 1) B ≈ 52 KiB) stay cache-resident while a
/// block is in flight.
pub const TRACE_BLOCK_OPS: usize = 4096;

/// A chunk of trace in struct-of-arrays layout — the unit the batched
/// pipeline moves between the generator, the core model and the cache
/// hierarchy (§Perf). The three parallel arrays (`gaps`, `addrs`, packed
/// `flags`) are fixed-capacity buffers reused across refills, so the
/// steady-state inner loop performs **zero heap allocation**: one block
/// is allocated per run and recycled by [`clear`](Self::clear) /
/// `TraceGenerator::fill_block`.
#[derive(Clone, Debug)]
pub struct TraceBlock {
    gaps: Vec<u32>,
    addrs: Vec<u64>,
    /// Packed per-op flags: [`Self::FLAG_WRITE`] | [`Self::FLAG_DEPENDENT`]
    /// | (pattern << [`Self::PATTERN_SHIFT`]).
    flags: Vec<u8>,
    capacity: usize,
}

impl Default for TraceBlock {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceBlock {
    /// `is_write` bit in the packed flags byte.
    pub const FLAG_WRITE: u8 = 1 << 0;
    /// `dependent` bit in the packed flags byte.
    pub const FLAG_DEPENDENT: u8 = 1 << 1;
    /// Pattern (`TraceOp::PAT_*`) field shift in the packed flags byte.
    pub const PATTERN_SHIFT: u8 = 2;

    /// A block with the default [`TRACE_BLOCK_OPS`] capacity.
    pub fn new() -> Self {
        Self::with_capacity(TRACE_BLOCK_OPS)
    }

    /// A block holding up to `capacity` ops. The arrays are allocated
    /// once, here; refills reuse them.
    pub fn with_capacity(capacity: usize) -> Self {
        assert!(capacity > 0);
        TraceBlock {
            gaps: Vec::with_capacity(capacity),
            addrs: Vec::with_capacity(capacity),
            flags: Vec::with_capacity(capacity),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.len() == self.capacity
    }

    /// Drop all ops, keeping the allocations for the next refill.
    pub fn clear(&mut self) {
        self.gaps.clear();
        self.addrs.clear();
        self.flags.clear();
    }

    /// Pack one flags byte.
    #[inline]
    pub fn pack_flags(is_write: bool, dependent: bool, pattern: u8) -> u8 {
        (is_write as u8) | ((dependent as u8) << 1) | (pattern << Self::PATTERN_SHIFT)
    }

    /// Append one op. Panics when the block is already full: the block
    /// is a fixed-size buffer, not a growable vec — silently growing the
    /// arrays in release builds would break the zero-alloc/fixed-capacity
    /// contract the batched pipeline is built on.
    #[inline]
    pub fn push(&mut self, op: TraceOp) {
        assert!(!self.is_full(), "TraceBlock overflow");
        self.gaps.push(op.gap);
        self.addrs.push(op.addr);
        self.flags
            .push(Self::pack_flags(op.is_write, op.dependent, op.pattern));
    }

    /// Reconstruct op `i` (bit-identical to the op that was pushed).
    #[inline]
    pub fn get(&self, i: usize) -> TraceOp {
        let f = self.flags[i];
        TraceOp {
            gap: self.gaps[i],
            addr: self.addrs[i],
            is_write: f & Self::FLAG_WRITE != 0,
            dependent: f & Self::FLAG_DEPENDENT != 0,
            pattern: f >> Self::PATTERN_SHIFT,
        }
    }

    /// The gap column (len() entries).
    pub fn gaps(&self) -> &[u32] {
        &self.gaps
    }

    /// The address column.
    pub fn addrs(&self) -> &[u64] {
        &self.addrs
    }

    /// The packed-flags column.
    pub fn flags(&self) -> &[u8] {
        &self.flags
    }

    /// Iterate the block as [`TraceOp`]s (reconstructed; for tests and
    /// non-hot-path consumers — the hot path reads the columns directly).
    pub fn iter(&self) -> impl Iterator<Item = TraceOp> + '_ {
        (0..self.len()).map(|i| self.get(i))
    }

    /// Total instructions the block accounts for (gaps + ops).
    pub fn instructions(&self) -> u64 {
        self.gaps.iter().map(|&g| g as u64).sum::<u64>() + self.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        assert!(!TraceOp::load(3, 0x10).is_write);
        assert!(TraceOp::store(3, 0x10).is_write);
        assert!(TraceOp::chained_load(0, 0x10).dependent);
        assert_eq!(TraceOp::load(3, 0x10).instructions(), 4);
    }

    #[test]
    fn block_round_trips_every_field() {
        let ops = [
            TraceOp::load(3, 0x40),
            TraceOp::store(0, 0x1000),
            TraceOp::chained_load(7, 0xdead_c0),
            TraceOp {
                gap: 11,
                addr: 0xffff_ffff_ffc0,
                is_write: true,
                dependent: true,
                pattern: TraceOp::PAT_STRIDE,
            },
        ];
        let mut b = TraceBlock::with_capacity(8);
        for op in &ops {
            b.push(*op);
        }
        assert_eq!(b.len(), 4);
        assert!(!b.is_full());
        for (i, op) in ops.iter().enumerate() {
            assert_eq!(b.get(i), *op, "op {i} must round-trip bit-identically");
        }
        let collected: Vec<TraceOp> = b.iter().collect();
        assert_eq!(collected, ops);
        assert_eq!(
            b.instructions(),
            ops.iter().map(|o| o.instructions()).sum::<u64>()
        );
    }

    #[test]
    fn block_clear_keeps_capacity() {
        let mut b = TraceBlock::with_capacity(2);
        b.push(TraceOp::load(0, 0));
        b.push(TraceOp::load(0, 64));
        assert!(b.is_full());
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.capacity(), 2);
    }

    #[test]
    fn default_block_capacity() {
        assert_eq!(TraceBlock::new().capacity(), TRACE_BLOCK_OPS);
    }

    #[test]
    #[should_panic(expected = "TraceBlock overflow")]
    fn push_past_capacity_panics_in_release_too() {
        // A hard assert, not debug_assert: release builds must not let an
        // over-filled block silently grow its arrays.
        let mut b = TraceBlock::with_capacity(2);
        b.push(TraceOp::load(0, 0));
        b.push(TraceOp::load(0, 64));
        b.push(TraceOp::load(0, 128));
    }

    #[test]
    fn columns_expose_packed_layout() {
        let mut b = TraceBlock::new();
        b.push(TraceOp::store(5, 0x80));
        assert_eq!(b.gaps(), &[5]);
        assert_eq!(b.addrs(), &[0x80]);
        assert_eq!(
            b.flags(),
            &[TraceBlock::FLAG_WRITE | (TraceOp::PAT_RANDOM << TraceBlock::PATTERN_SHIFT)]
        );
    }
}
