//! Trace record types.

/// One memory operation in a workload trace, with the number of
/// non-memory instructions preceding it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceOp {
    /// Non-memory instructions executed since the previous memory op.
    pub gap: u32,
    /// Virtual address accessed.
    pub addr: u64,
    /// Store (true) or load (false).
    pub is_write: bool,
    /// True if this access depends on the previous one (pointer chase):
    /// its issue cannot overlap the previous miss.
    pub dependent: bool,
    /// Which generator pattern produced this op (PAT_*). Baselines use it
    /// as a stable synthetic instruction pointer so IP-indexed structures
    /// (stride prefetchers) can train, as they would on a real loop body.
    pub pattern: u8,
}

impl TraceOp {
    pub const PAT_STREAM: u8 = 0;
    pub const PAT_STRIDE: u8 = 1;
    pub const PAT_CHASE: u8 = 2;
    pub const PAT_RANDOM: u8 = 3;

    pub fn load(gap: u32, addr: u64) -> Self {
        TraceOp {
            gap,
            addr,
            is_write: false,
            dependent: false,
            pattern: Self::PAT_RANDOM,
        }
    }

    pub fn store(gap: u32, addr: u64) -> Self {
        TraceOp {
            gap,
            addr,
            is_write: true,
            dependent: false,
            pattern: Self::PAT_RANDOM,
        }
    }

    pub fn chained_load(gap: u32, addr: u64) -> Self {
        TraceOp {
            gap,
            addr,
            is_write: false,
            dependent: true,
            pattern: Self::PAT_CHASE,
        }
    }

    /// Total instructions this op accounts for (gap + the op itself).
    pub fn instructions(&self) -> u64 {
        self.gap as u64 + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        assert!(!TraceOp::load(3, 0x10).is_write);
        assert!(TraceOp::store(3, 0x10).is_write);
        assert!(TraceOp::chained_load(0, 0x10).dependent);
        assert_eq!(TraceOp::load(3, 0x10).instructions(), 4);
    }
}
