//! Synthetic SPEC CPU 2017 workloads (Table III).
//!
//! We cannot run the real SPEC binaries on the modeled platform, so each
//! benchmark is replaced by a calibrated synthetic trace generator that
//! reproduces the properties the platform actually responds to:
//!
//! - **memory footprint** (Table III, scaled by the platform scale factor),
//! - **memory intensity** (accesses per kilo-instruction — calibrated so
//!   the Fig 8 request-volume *ordering* holds: 505.mcf max, 538.imagick
//!   min, consistent with the SPEC2017 characterization study [24]),
//! - **read/write mix**,
//! - **access pattern**: streaming / strided / pointer-chasing /
//!   zipf-random region mixes per benchmark class,
//! - **dependence**: pointer-chase loads are latency-bound (no MLP);
//!   streaming loads overlap.

pub mod generator;
pub mod spec;
pub mod trace;
pub mod tracefile;

pub use generator::TraceGenerator;
pub use spec::{by_name, proportional_ops, Workload, WORKLOADS};
pub use trace::{TraceBlock, TraceOp, TRACE_BLOCK_OPS};
pub use tracefile::{dump as dump_trace, TraceReader};
