//! Synthetic trace generation from a [`Workload`] descriptor.
//!
//! The generator lays the (scaled) footprint out as three regions —
//! streaming, pointer-chase and random — and emits [`TraceOp`]s whose
//! pattern follows the descriptor's mix weights. All state is derived
//! from an explicit seed; traces are reproducible.

use super::spec::Workload;
use super::trace::{TraceBlock, TraceOp};
use crate::util::codec::{CodecState, Decoder, Encoder};
use crate::util::error::Result;
use crate::util::rng::Xoshiro256;

const LINE: u64 = 64;

/// Streaming trace generator (an `Iterator<Item = TraceOp>`).
#[derive(Clone)]
pub struct TraceGenerator {
    rng: Xoshiro256,
    // audit: allow(codec-coverage) — workload spec, supplied at restore time
    wl: Workload,
    /// Scaled footprint in bytes.
    // audit: allow(codec-coverage) — derived from the workload spec
    footprint: u64,
    /// Region base offsets and sizes (bytes).
    // audit: allow(codec-coverage) — derived from the workload spec
    stream_base: u64,
    // audit: allow(codec-coverage) — derived from the workload spec
    stream_size: u64,
    // audit: allow(codec-coverage) — derived from the workload spec
    chase_base: u64,
    // audit: allow(codec-coverage) — derived from the workload spec
    random_base: u64,
    // audit: allow(codec-coverage) — derived from the workload spec
    random_size: u64,
    /// Streaming cursor.
    stream_pos: u64,
    /// Streaming working window (tiled reuse); `stream_size` when the
    /// workload streams its whole region.
    // audit: allow(codec-coverage) — derived from the workload spec
    stream_window: u64,
    /// Base offset of the current window within the stream region (the
    /// window slides occasionally, modeling tile-to-tile progress).
    window_base: u64,
    /// Stride-walk state.
    stride_pos: u64,
    // audit: allow(codec-coverage) — derived from the workload spec
    stride: u64,
    /// Pointer-chase permutation over chase-region lines (index = line).
    // audit: allow(codec-coverage) — re-derived from the seed on restore
    chase_perm: Vec<u32>,
    chase_cur: u32,
    /// Cumulative mix thresholds.
    // audit: allow(codec-coverage) — derived from the workload spec
    thresholds: [f64; 4],
    /// Remaining ops (None = unbounded).
    remaining: Option<u64>,
    /// Instructions represented so far (gaps + ops).
    pub instructions: u64,
    /// Ops emitted.
    pub ops: u64,
}

impl TraceGenerator {
    /// Build a generator for `wl` with the footprint divided by `scale`.
    pub fn new(wl: Workload, scale: u64, seed: u64) -> Self {
        let footprint = (wl.footprint_bytes / scale.max(1)).max(1 << 20);
        // Region split: chase and random regions sized by their mix share
        // (minimum 4KiB each so tiny mixes still work).
        let total_mix = wl.mix.total();
        let chase_share = wl.mix.chase / total_mix;
        let random_share = wl.mix.random / total_mix;
        let chase_size = ((footprint as f64 * chase_share) as u64).max(4096) & !(LINE - 1);
        let random_size = ((footprint as f64 * random_share) as u64).max(4096) & !(LINE - 1);
        let stream_size = footprint
            .saturating_sub(chase_size + random_size)
            .max(4096)
            & !(LINE - 1);

        let stream_base = 0u64;
        let chase_base = stream_size;
        let random_base = stream_size + chase_size;

        let mut rng = Xoshiro256::new(seed ^ fxhash(wl.name));

        // Pointer-chase permutation: a single Sattolo cycle over the chase
        // region's lines guarantees every load depends on the previous and
        // the cycle covers the whole region (worst case for caches).
        let chase_lines = (chase_size / LINE).min(u32::MAX as u64) as u32;
        let mut chase_perm: Vec<u32> = (0..chase_lines).collect();
        // Sattolo's algorithm: cyclic permutation.
        for i in (1..chase_perm.len()).rev() {
            let j = rng.below(i as u64) as usize;
            chase_perm.swap(i, j);
        }

        let m = &wl.mix;
        let t1 = m.stream / total_mix;
        let t2 = t1 + m.stride / total_mix;
        let t3 = t2 + m.chase / total_mix;

        let stream_window = if wl.stream_window == 0 {
            stream_size
        } else {
            wl.stream_window.min(stream_size) & !(LINE - 1)
        };

        TraceGenerator {
            rng,
            wl,
            footprint,
            stream_base,
            stream_size,
            chase_base,
            random_base,
            random_size,
            stream_window,
            window_base: 0,
            stream_pos: 0,
            stride_pos: 0,
            stride: 256, // 4-line stride: misses every line with prefetch-unfriendly step
            chase_perm,
            chase_cur: 0,
            thresholds: [t1, t2, t3, 1.0],
            remaining: None,
            instructions: 0,
            ops: 0,
        }
    }

    /// Bound the generator to `n` memory operations.
    pub fn take_ops(mut self, n: u64) -> Self {
        self.remaining = Some(n);
        self
    }

    pub fn footprint(&self) -> u64 {
        self.footprint
    }

    pub fn workload(&self) -> &Workload {
        &self.wl
    }

    /// Generate one op, honoring the `take_ops` bound. Shared by the
    /// per-op [`Iterator`] impl and [`Self::fill_block`], so the two
    /// paths emit bit-identical sequences by construction.
    #[inline]
    fn gen_op(&mut self) -> Option<TraceOp> {
        if let Some(rem) = &mut self.remaining {
            if *rem == 0 {
                return None;
            }
            *rem -= 1;
        }
        // Geometric gap with the workload's mean.
        let gap = self.rng.burst(self.wl.mean_gap, 4096).saturating_sub(1) as u32;
        let (addr, dependent, writeable, pattern) = self.next_addr();
        let is_write = writeable && self.rng.chance(self.wl.write_frac);
        self.instructions += gap as u64 + 1;
        self.ops += 1;
        Some(TraceOp {
            gap,
            addr,
            is_write,
            dependent,
            pattern,
        })
    }

    /// Batched generation (§Perf): clear `block` and refill it up to its
    /// capacity (or until the `take_ops` bound runs out), returning the
    /// number of ops produced. The block's buffers are reused across
    /// calls — steady-state generation allocates nothing — and the op
    /// sequence is bit-identical to draining the same generator through
    /// `Iterator::next`.
    pub fn fill_block(&mut self, block: &mut TraceBlock) -> usize {
        block.clear();
        while !block.is_full() {
            match self.gen_op() {
                Some(op) => block.push(op),
                None => break,
            }
        }
        block.len()
    }

    #[inline]
    fn next_addr(&mut self) -> (u64, bool /*dependent*/, bool /*writeable*/, u8 /*pattern*/) {
        let u = self.rng.f64();
        if u < self.thresholds[0] {
            // Streaming with tiled reuse: loop within the current window;
            // slide the window occasionally (~once per 4 window passes) to
            // model tile-to-tile progress through the region.
            let addr = self.stream_base + self.window_base + self.stream_pos;
            self.stream_pos += LINE;
            if self.stream_pos >= self.stream_window {
                self.stream_pos = 0;
                // Tile-to-tile progress: slide rarely — blocked kernels
                // re-traverse each tile many times (this is what produces
                // imagick's near-zero steady-state miss rate [24]).
                if self.stream_window < self.stream_size && self.rng.chance(0.02) {
                    self.window_base =
                        (self.window_base + self.stream_window) % (self.stream_size - self.stream_window + LINE);
                    self.window_base &= !(LINE - 1);
                }
            }
            (addr, false, true, TraceOp::PAT_STREAM)
        } else if u < self.thresholds[1] {
            // Strided walk (within the same working window as streaming —
            // blocked kernels stride within their tile).
            let addr = self.stream_base + self.window_base + self.stride_pos;
            self.stride_pos = (self.stride_pos + self.stride) % self.stream_window;
            (addr, false, true, TraceOp::PAT_STRIDE)
        } else if u < self.thresholds[2] && !self.chase_perm.is_empty() {
            // Pointer chase: follow the permutation cycle.
            self.chase_cur = self.chase_perm[self.chase_cur as usize];
            let addr = self.chase_base + self.chase_cur as u64 * LINE;
            (addr, true, false, TraceOp::PAT_CHASE)
        } else {
            // Zipf-random over the random region's lines.
            let lines = (self.random_size / LINE).max(1);
            let line = self.rng.zipf(lines, self.wl.zipf_s);
            // Bit-reverse-ish scatter so hot zipf lines spread across pages.
            let scattered = scatter(line, lines);
            let addr = self.random_base + scattered * LINE;
            (addr, false, true, TraceOp::PAT_RANDOM)
        }
    }
}

impl CodecState for TraceGenerator {
    fn encode_state(&self, e: &mut Encoder) {
        // The region layout, chase permutation and mix thresholds are all
        // deterministic functions of (workload, scale, seed) — the decode
        // target is constructed with the same triple, so only the stream
        // cursors cross the wire.
        for s in self.rng.state() {
            e.put_u64(s);
        }
        e.put_u64(self.stream_pos);
        e.put_u64(self.window_base);
        e.put_u64(self.stride_pos);
        e.put_u32(self.chase_cur);
        e.put_bool(self.remaining.is_some());
        e.put_u64(self.remaining.unwrap_or(0));
        e.put_u64(self.instructions);
        e.put_u64(self.ops);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let s = [d.u64()?, d.u64()?, d.u64()?, d.u64()?];
        self.rng = Xoshiro256::from_state(s);
        self.stream_pos = d.u64()?;
        self.window_base = d.u64()?;
        self.stride_pos = d.u64()?;
        self.chase_cur = d.u32()?;
        if !self.chase_perm.is_empty() && self.chase_cur as usize >= self.chase_perm.len() {
            crate::bail!(
                "checkpoint geometry mismatch: chase cursor {} outside permutation of {}",
                self.chase_cur,
                self.chase_perm.len()
            );
        }
        let has_rem = d.bool()?;
        let rem = d.u64()?;
        self.remaining = has_rem.then_some(rem);
        self.instructions = d.u64()?;
        self.ops = d.u64()?;
        Ok(())
    }
}

/// Deterministically scatter index `i` within `[0, n)` (golden-ratio hash).
#[inline]
fn scatter(i: u64, n: u64) -> u64 {
    (i.wrapping_mul(0x9E3779B97F4A7C15)) % n
}

/// Tiny FNV-style hash for workload-name seeding.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Iterator for TraceGenerator {
    type Item = TraceOp;

    fn next(&mut self) -> Option<TraceOp> {
        self.gen_op()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::by_name;

    fn gen(name: &str, ops: u64) -> Vec<TraceOp> {
        TraceGenerator::new(by_name(name).unwrap(), 16, 42)
            .take_ops(ops)
            .collect()
    }

    #[test]
    fn bounded_and_reproducible() {
        let a = gen("505.mcf", 1000);
        let b = gen("505.mcf", 1000);
        assert_eq!(a.len(), 1000);
        assert_eq!(a, b);
    }

    #[test]
    fn different_workloads_differ() {
        let a = gen("505.mcf", 100);
        let b = gen("538.imagick", 100);
        assert_ne!(a, b);
    }

    #[test]
    fn addresses_within_footprint() {
        let g = TraceGenerator::new(by_name("557.xz").unwrap(), 16, 7);
        let fp = g.footprint();
        for op in g.take_ops(10_000) {
            assert!(op.addr < fp, "addr {} >= footprint {}", op.addr, fp);
        }
    }

    #[test]
    fn footprint_scales() {
        let g1 = TraceGenerator::new(by_name("505.mcf").unwrap(), 1, 7);
        let g16 = TraceGenerator::new(by_name("505.mcf").unwrap(), 16, 7);
        assert_eq!(g1.footprint(), 602 << 20);
        assert_eq!(g16.footprint(), (602 << 20) / 16);
    }

    #[test]
    fn mcf_has_dependent_chains() {
        let ops = gen("505.mcf", 10_000);
        let dep = ops.iter().filter(|o| o.dependent).count();
        assert!(dep > 2000, "mcf should chase pointers, dep={dep}");
    }

    #[test]
    fn lbm_is_streaming_no_chase() {
        let ops = gen("519.lbm", 10_000);
        assert_eq!(ops.iter().filter(|o| o.dependent).count(), 0);
        // Write-heavy stencil:
        let writes = ops.iter().filter(|o| o.is_write).count();
        assert!(writes > 3000);
    }

    #[test]
    fn imagick_sparser_than_mcf() {
        let mcf: u64 = gen("505.mcf", 5000).iter().map(|o| o.instructions()).sum();
        let img: u64 = gen("538.imagick", 5000).iter().map(|o| o.instructions()).sum();
        // Same op count, imagick represents far more instructions.
        assert!(img > 2 * mcf, "img instr {img} vs mcf {mcf}");
    }

    #[test]
    fn chase_cycle_covers_region() {
        let g = TraceGenerator::new(by_name("505.mcf").unwrap(), 64, 3);
        let lines = g.chase_perm.len();
        // Sattolo gives a single cycle: following `lines` steps from 0
        // returns to 0 and visits every element once.
        let mut seen = vec![false; lines];
        let mut cur = 0u32;
        for _ in 0..lines {
            cur = g.chase_perm[cur as usize];
            assert!(!seen[cur as usize], "revisited before cycle end");
            seen[cur as usize] = true;
        }
        assert_eq!(cur, 0);
    }

    #[test]
    fn fill_block_bit_identical_to_iterator() {
        // Same seed, two drain styles: the block path must reproduce the
        // per-op stream exactly, including the take_ops tail.
        for name in ["505.mcf", "538.imagick", "519.lbm"] {
            let per_op: Vec<TraceOp> = TraceGenerator::new(by_name(name).unwrap(), 16, 42)
                .take_ops(10_000)
                .collect();
            let mut gen = TraceGenerator::new(by_name(name).unwrap(), 16, 42).take_ops(10_000);
            let mut block = TraceBlock::with_capacity(4096);
            let mut batched = Vec::new();
            while gen.fill_block(&mut block) > 0 {
                batched.extend(block.iter());
            }
            assert_eq!(per_op, batched, "{name}: block path diverged");
            // 10_000 is not a multiple of 4096: the tail block is short.
            assert_eq!(batched.len(), 10_000);
        }
    }

    #[test]
    fn fill_block_counts_ops_and_instructions() {
        let mut a = TraceGenerator::new(by_name("557.xz").unwrap(), 16, 7).take_ops(5000);
        let mut block = TraceBlock::new();
        let mut total = 0;
        while a.fill_block(&mut block) > 0 {
            total += block.len();
        }
        assert_eq!(total, 5000);
        assert_eq!(a.ops, 5000);
        let b: Vec<TraceOp> = TraceGenerator::new(by_name("557.xz").unwrap(), 16, 7)
            .take_ops(5000)
            .collect();
        assert_eq!(
            a.instructions,
            b.iter().map(|o| o.instructions()).sum::<u64>()
        );
        // Exhausted generator: fill_block returns 0 and leaves the block
        // empty (not stale data from the previous refill).
        assert_eq!(a.fill_block(&mut block), 0);
        assert!(block.is_empty());
    }

    #[test]
    fn codec_round_trip_continues_stream() {
        // Run a generator mid-way, snapshot, overlay onto a fresh
        // generator built from the same (workload, scale, seed), and check
        // the two produce identical tails.
        let mut warm = TraceGenerator::new(by_name("505.mcf").unwrap(), 16, 42).take_ops(6_000);
        for _ in 0..2_500 {
            warm.next().unwrap();
        }
        let mut e = Encoder::new();
        warm.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = TraceGenerator::new(by_name("505.mcf").unwrap(), 16, 42);
        restored.decode_state(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(restored.ops, warm.ops);
        let tail_a: Vec<TraceOp> = warm.collect();
        let tail_b: Vec<TraceOp> = restored.collect();
        assert_eq!(tail_a.len(), 3_500);
        assert_eq!(tail_a, tail_b, "restored generator diverged");
    }

    #[test]
    fn writes_respect_frac() {
        let ops = gen("500.perlbench", 20_000);
        let wf = ops.iter().filter(|o| o.is_write).count() as f64 / ops.len() as f64;
        let expect = by_name("500.perlbench").unwrap().write_frac;
        // chase ops never write, so observed rate is <= configured.
        assert!(wf < expect + 0.05, "wf={wf}");
        assert!(wf > 0.1);
    }
}
