//! Table III workload descriptors.
//!
//! Footprints are the paper's Table III values. Behavioural parameters
//! (gap, pattern mix, locality) are calibrated to the SPEC CPU 2017
//! characterization literature ([24] in the paper: mcf highest cache miss
//! rate, imagick lowest) so that the Fig 7 / Fig 8 *orderings* reproduce.

/// Memory access pattern weights (normalized at use).
#[derive(Clone, Copy, Debug)]
pub struct PatternMix {
    /// Sequential streaming over a large region.
    pub stream: f64,
    /// Fixed-stride (> line) walks.
    pub stride: f64,
    /// Dependent pointer chasing (latency-bound, defeats caches and MLP).
    pub chase: f64,
    /// Zipf-random over the footprint.
    pub random: f64,
}

impl PatternMix {
    pub fn total(&self) -> f64 {
        self.stream + self.stride + self.chase + self.random
    }
}

/// A synthetic SPEC-2017-like workload descriptor.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// SPEC-style name ("505.mcf").
    pub name: &'static str,
    pub desc: &'static str,
    /// Table III memory footprint in bytes (unscaled).
    pub footprint_bytes: u64,
    /// Mean non-memory instructions between memory ops (compute density).
    pub mean_gap: f64,
    /// Fraction of memory ops that are stores.
    pub write_frac: f64,
    pub mix: PatternMix,
    /// Zipf skew for the random region (higher = more locality).
    pub zipf_s: f64,
    /// Streaming working window in bytes (0 = stream the whole region
    /// with no reuse, like lbm's stencil sweep). Blocked/tiled kernels
    /// (imagick convolutions, x264 reference frames) loop within a window
    /// that fits in cache — this is what gives them their low miss rates
    /// in [24].
    pub stream_window: u64,
    /// Default instruction budget (modeled instructions, unscaled).
    pub default_instructions: u64,
    pub is_float: bool,
}

/// The twelve Table III workloads.
pub static WORKLOADS: [Workload; 12] = [
    Workload {
        name: "500.perlbench",
        desc: "Perl interpreter",
        footprint_bytes: 202 << 20,
        mean_gap: 4.0,
        write_frac: 0.38,
        mix: PatternMix { stream: 0.25, stride: 0.10, chase: 0.10, random: 0.55 },
        zipf_s: 1.20, // interpreters have strong locality on hot structures
        stream_window: 2 << 20,
        default_instructions: 900_000_000,
        is_float: false,
    },
    Workload {
        name: "505.mcf",
        desc: "Vehicle route scheduling",
        footprint_bytes: 602 << 20,
        mean_gap: 3.0, // extremely memory-bound
        write_frac: 0.47,
        mix: PatternMix { stream: 0.05, stride: 0.05, chase: 0.30, random: 0.60 },
        zipf_s: 0.60, // nearly uniform over the huge network
        stream_window: 0,
        // mcf has the longest ref runtime of the suite -> largest total
        // request volume in Fig 8 even at similar MPKI.
        default_instructions: 2_400_000_000,
        is_float: false,
    },
    Workload {
        name: "508.namd",
        desc: "Molecular dynamics",
        footprint_bytes: 172 << 20,
        mean_gap: 7.0, // FP compute heavy
        write_frac: 0.30,
        mix: PatternMix { stream: 0.55, stride: 0.25, chase: 0.00, random: 0.20 },
        zipf_s: 1.30, // blocked neighbor lists reuse well
        stream_window: 512 << 10,
        default_instructions: 1_100_000_000,
        is_float: true,
    },
    Workload {
        name: "520.omnetpp",
        desc: "Discrete event simulation - computer network",
        footprint_bytes: 241 << 20,
        mean_gap: 3.0,
        write_frac: 0.42,
        mix: PatternMix { stream: 0.05, stride: 0.05, chase: 0.28, random: 0.62 },
        zipf_s: 0.80, // event-heap churn: poor locality
        stream_window: 3 << 20,
        default_instructions: 900_000_000,
        is_float: false,
    },
    Workload {
        name: "523.xalancbmk",
        desc: "XML to HTML conversion via XSLT",
        footprint_bytes: 481 << 20,
        mean_gap: 3.5,
        write_frac: 0.35,
        mix: PatternMix { stream: 0.15, stride: 0.10, chase: 0.18, random: 0.57 },
        zipf_s: 0.90,
        stream_window: 4 << 20,
        default_instructions: 900_000_000,
        is_float: false,
    },
    Workload {
        name: "525.x264",
        desc: "Video compressing",
        footprint_bytes: 165 << 20,
        mean_gap: 6.0, // SIMD compute on frames
        write_frac: 0.33,
        mix: PatternMix { stream: 0.60, stride: 0.25, chase: 0.00, random: 0.15 },
        zipf_s: 1.40, // reference frames reuse heavily
        stream_window: 640 << 10,
        default_instructions: 1_000_000_000,
        is_float: false,
    },
    Workload {
        name: "531.deepsjeng",
        desc: "AI: alpha-beta tree search (Chess)",
        footprint_bytes: 700 << 20, // SPEC ref size (blank in Table III)
        mean_gap: 5.0,
        write_frac: 0.40,
        mix: PatternMix { stream: 0.05, stride: 0.05, chase: 0.10, random: 0.80 },
        zipf_s: 0.70, // transposition-table lookups are near-uniform
        stream_window: 1 << 20,
        default_instructions: 900_000_000,
        is_float: false,
    },
    Workload {
        name: "541.leela",
        desc: "AI: Monte Carlo tree search (Go)",
        footprint_bytes: 22 << 20,
        mean_gap: 5.5,
        write_frac: 0.35,
        mix: PatternMix { stream: 0.15, stride: 0.10, chase: 0.15, random: 0.60 },
        zipf_s: 1.12, // tiny footprint: mostly cache-resident, but MPKI above imagick [24]
        stream_window: 256 << 10,
        default_instructions: 1_000_000_000,
        is_float: false,
    },
    Workload {
        name: "557.xz",
        desc: "General data compression",
        footprint_bytes: 727 << 20,
        mean_gap: 3.0,
        write_frac: 0.45,
        mix: PatternMix { stream: 0.40, stride: 0.10, chase: 0.10, random: 0.40 },
        zipf_s: 0.75, // dictionary matches scatter widely
        stream_window: 0,
        default_instructions: 1_000_000_000,
        is_float: false,
    },
    Workload {
        name: "519.lbm",
        desc: "Fluid dynamics",
        footprint_bytes: 410 << 20,
        mean_gap: 3.5,
        write_frac: 0.48, // stencil updates write nearly every cell read
        mix: PatternMix { stream: 0.85, stride: 0.15, chase: 0.00, random: 0.00 },
        zipf_s: 1.0,
        stream_window: 0,
        default_instructions: 1_000_000_000,
        is_float: true,
    },
    Workload {
        name: "538.imagick",
        desc: "Image manipulation",
        footprint_bytes: 287 << 20,
        mean_gap: 18.0, // convolution kernels: heaviest compute per pixel of the suite
        write_frac: 0.27,
        mix: PatternMix { stream: 0.70, stride: 0.20, chase: 0.00, random: 0.10 },
        zipf_s: 2.10, // extreme tile reuse: lowest miss rate of the suite [24]
        stream_window: 448 << 10,
        default_instructions: 1_200_000_000,
        is_float: true,
    },
    Workload {
        name: "544.nab",
        desc: "Molecular dynamics",
        footprint_bytes: 147 << 20,
        mean_gap: 8.0,
        write_frac: 0.32,
        mix: PatternMix { stream: 0.50, stride: 0.25, chase: 0.00, random: 0.25 },
        zipf_s: 1.05, // moderate locality: [24] places nab above imagick on MPKI
        stream_window: 384 << 10,
        default_instructions: 1_000_000_000,
        is_float: true,
    },
];

impl Workload {
    /// Proxy for the workload's *full-run* memory-op count: instruction
    /// budget scaled by memory intensity. Fig 8 totals are proportional
    /// to this (the paper runs complete benchmarks, whose lengths differ).
    pub fn mem_op_weight(&self) -> f64 {
        self.default_instructions as f64 / (1.0 + self.mean_gap)
    }
}

/// Per-workload trace-op budgets for full-run-proportional experiments
/// (Fig 8): the heaviest workload gets `budget` ops, the rest
/// proportionally fewer (min 1/50th so light workloads still warm up).
pub fn proportional_ops(budget: u64) -> Vec<(Workload, u64)> {
    let wmax = WORKLOADS
        .iter()
        .map(|w| w.mem_op_weight())
        .fold(0.0f64, f64::max);
    WORKLOADS
        .iter()
        .map(|w| {
            let frac = (w.mem_op_weight() / wmax).max(0.02);
            (*w, ((budget as f64) * frac) as u64)
        })
        .collect()
}

/// Look up a workload by exact name or numeric prefix ("505" or "mcf").
pub fn by_name(name: &str) -> Option<Workload> {
    let lower = name.to_ascii_lowercase();
    WORKLOADS
        .iter()
        .find(|w| {
            w.name == lower
                || w.name.split('.').any(|part| part == lower)
                || w.name.starts_with(&lower)
        })
        .copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_workloads_match_table3() {
        assert_eq!(WORKLOADS.len(), 12);
        assert_eq!(by_name("505.mcf").unwrap().footprint_bytes, 602 << 20);
        assert_eq!(by_name("541.leela").unwrap().footprint_bytes, 22 << 20);
        assert_eq!(by_name("557.xz").unwrap().footprint_bytes, 727 << 20);
    }

    #[test]
    fn lookup_variants() {
        assert!(by_name("mcf").is_some());
        assert!(by_name("505").is_some());
        assert!(by_name("538.imagick").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn mixes_are_positive() {
        for w in &WORKLOADS {
            assert!(w.mix.total() > 0.0, "{}", w.name);
            assert!(w.write_frac > 0.0 && w.write_frac < 1.0);
            assert!(w.mean_gap >= 1.0);
        }
    }

    #[test]
    fn mcf_is_most_memory_intensive() {
        // Intensity ∝ 1/(1+gap); mcf must lead, imagick must trail — the
        // calibration target from Fig 8 / [24].
        let mcf = by_name("mcf").unwrap();
        let imagick = by_name("imagick").unwrap();
        for w in &WORKLOADS {
            assert!(mcf.mean_gap <= w.mean_gap, "{} denser than mcf", w.name);
            assert!(imagick.mean_gap >= w.mean_gap, "{} sparser than imagick", w.name);
        }
    }

    #[test]
    fn float_flags() {
        assert!(by_name("519.lbm").unwrap().is_float);
        assert!(!by_name("505.mcf").unwrap().is_float);
        assert_eq!(WORKLOADS.iter().filter(|w| w.is_float).count(), 4);
    }
}
