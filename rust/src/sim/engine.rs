//! Generic discrete-event component engine, plus a DES cross-validation
//! of the analytic request path.
//!
//! The platform's hot path (`Hmmu::access`) computes completion times
//! analytically per request — fast, but each component's occupancy
//! bookkeeping is hand-derived. This module provides the ground truth:
//! a classic DES where the PCIe link, HMMU pipeline and memory device
//! are explicit stations with explicit busy intervals, driven through
//! [`EventQueue`]. The `des_cross_check` integration test replays the
//! same request stream through both and bounds the divergence.

use super::event::EventQueue;
use super::Time;

/// A request flowing through the station pipeline.
#[derive(Clone, Copy, Debug)]
pub struct DesRequest {
    pub id: u64,
    /// Arrival time at the first station.
    pub arrival: Time,
    /// Fixed service demand per station (ns), set by the caller.
    pub demand: [u64; 3],
}

/// Event payload: request `idx` finishing station `stage`.
#[derive(Clone, Copy, Debug)]
struct StageDone {
    idx: usize,
    stage: usize,
}

/// A three-station tandem queue (link → pipeline → device), each station
/// serving one request at a time in FIFO order. This is exactly the
/// structural model behind the analytic path's `wire_free` /
/// `pipeline_ns` / bank `next_free` bookkeeping.
pub struct TandemDes {
    queue: EventQueue<StageDone>,
    /// Next-free time per station.
    station_free: [Time; 3],
    /// Completion time per request (filled as they exit station 2).
    pub completions: Vec<Time>,
}

impl Default for TandemDes {
    fn default() -> Self {
        Self::new()
    }
}

impl TandemDes {
    pub fn new() -> Self {
        TandemDes {
            queue: EventQueue::new(),
            station_free: [0; 3],
            completions: Vec::new(),
        }
    }

    /// Run all `requests` (must be sorted by arrival); returns per-request
    /// completion times.
    pub fn run(&mut self, requests: &[DesRequest]) -> &[Time] {
        self.completions = vec![0; requests.len()];
        // Seed: every request enters station 0 at its arrival.
        let mut entry_time: Vec<Time> = requests.iter().map(|r| r.arrival).collect();

        // Process stage by stage using the event queue for ordering.
        for (idx, r) in requests.iter().enumerate() {
            self.queue.schedule_at(r.arrival, StageDone { idx, stage: 0 });
        }
        while let Some((t, ev)) = self.queue.pop() {
            let r = &requests[ev.idx];
            // Service at this station starts when both the request has
            // arrived here and the station is free.
            let start = t.max(self.station_free[ev.stage]).max(entry_time[ev.idx]);
            let done = start + r.demand[ev.stage];
            self.station_free[ev.stage] = done;
            if ev.stage + 1 < 3 {
                entry_time[ev.idx] = done;
                self.queue.schedule_at(done, StageDone {
                    idx: ev.idx,
                    stage: ev.stage + 1,
                });
            } else {
                self.completions[ev.idx] = done;
            }
        }
        &self.completions
    }
}

/// Analytic reference for the same tandem queue (the closed-form used on
/// the hot path): per station, `done = max(arrival_here, station_free) +
/// demand`.
pub fn tandem_analytic(requests: &[DesRequest]) -> Vec<Time> {
    let mut free = [0u64; 3];
    let mut out = Vec::with_capacity(requests.len());
    for r in requests {
        let mut t = r.arrival;
        for s in 0..3 {
            let start = t.max(free[s]);
            let done = start + r.demand[s];
            free[s] = done;
            t = done;
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_stream(n: usize, seed: u64) -> Vec<DesRequest> {
        let mut rng = Xoshiro256::new(seed);
        let mut t = 0;
        (0..n)
            .map(|i| {
                t += rng.below(100);
                DesRequest {
                    id: i as u64,
                    arrival: t,
                    demand: [2 + rng.below(8), 4 + rng.below(12), 20 + rng.below(200)],
                }
            })
            .collect()
    }

    #[test]
    fn des_matches_analytic_exactly_for_fifo_tandem() {
        // The analytic hot path and the event-driven engine must agree
        // exactly for in-order arrivals — this pins the analytic
        // shortcuts used throughout the platform.
        for seed in [1u64, 7, 42, 1234] {
            let reqs = random_stream(500, seed);
            let mut des = TandemDes::new();
            let des_out = des.run(&reqs).to_vec();
            let ana_out = tandem_analytic(&reqs);
            assert_eq!(des_out, ana_out, "divergence at seed {seed}");
        }
    }

    #[test]
    fn empty_and_single() {
        let mut des = TandemDes::new();
        assert!(des.run(&[]).is_empty());
        let one = [DesRequest {
            id: 0,
            arrival: 10,
            demand: [1, 2, 3],
        }];
        assert_eq!(des.run(&one), &[16]);
    }

    #[test]
    fn queueing_emerges_under_load() {
        // Back-to-back arrivals at t=0: completions must be spaced by the
        // bottleneck station's demand.
        let reqs: Vec<DesRequest> = (0..10)
            .map(|i| DesRequest {
                id: i,
                arrival: 0,
                demand: [1, 1, 50],
            })
            .collect();
        let mut des = TandemDes::new();
        let out = des.run(&reqs).to_vec();
        for w in out.windows(2) {
            assert_eq!(w[1] - w[0], 50, "bottleneck spacing");
        }
    }
}
