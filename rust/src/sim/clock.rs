//! Clock-domain conversion.
//!
//! The emulation platform spans four clock domains (CPU 2 GHz, FPGA fabric
//! 250 MHz, PCIe SerDes, DDR4 controller). All timing converges on the
//! shared nanosecond timeline; `Clock` converts cycle counts of a domain
//! to/from nanoseconds with integer-safe rounding (always rounding
//! *up* to whole cycles, like real synchronizers do).

/// A fixed-frequency clock domain.
#[derive(Clone, Copy, Debug)]
pub struct Clock {
    /// Frequency in MHz (u64 picosecond period derived from it).
    period_ps: u64,
    freq_mhz: f64,
}

impl Clock {
    pub fn from_mhz(freq_mhz: f64) -> Self {
        assert!(freq_mhz > 0.0);
        Clock {
            period_ps: (1_000_000.0 / freq_mhz).round() as u64,
            freq_mhz,
        }
    }

    pub fn from_ghz(freq_ghz: f64) -> Self {
        Self::from_mhz(freq_ghz * 1000.0)
    }

    pub fn freq_mhz(&self) -> f64 {
        self.freq_mhz
    }

    /// Clock period in picoseconds.
    pub fn period_ps(&self) -> u64 {
        self.period_ps
    }

    /// Convert a cycle count to nanoseconds (rounded up).
    #[inline]
    pub fn cycles_to_ns(&self, cycles: u64) -> u64 {
        (cycles.saturating_mul(self.period_ps)).div_ceil(1000)
    }

    /// Convert nanoseconds to whole cycles (rounded up — crossing into a
    /// domain costs at least the partial cycle).
    #[inline]
    pub fn ns_to_cycles(&self, ns: u64) -> u64 {
        ns.saturating_mul(1000).div_ceil(self.period_ps)
    }

    /// Next domain edge at or after time `ns` (models synchronizer align).
    #[inline]
    pub fn align_up_ns(&self, ns: u64) -> u64 {
        let ps = ns * 1000;
        let edges = ps.div_ceil(self.period_ps);
        (edges * self.period_ps).div_ceil(1000)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_2ghz() {
        let c = Clock::from_ghz(2.0);
        assert_eq!(c.period_ps(), 500);
        assert_eq!(c.cycles_to_ns(2), 1);
        assert_eq!(c.cycles_to_ns(3), 2); // 1.5ns rounds up
        assert_eq!(c.ns_to_cycles(1), 2);
    }

    #[test]
    fn fpga_250mhz() {
        let c = Clock::from_mhz(250.0);
        assert_eq!(c.period_ps(), 4000);
        assert_eq!(c.cycles_to_ns(1), 4);
        assert_eq!(c.ns_to_cycles(10), 3); // 10ns -> 2.5 cycles -> 3
    }

    #[test]
    fn roundtrip_is_monotone() {
        let c = Clock::from_mhz(333.0);
        for cycles in [1u64, 7, 100, 12345] {
            let ns = c.cycles_to_ns(cycles);
            // ns->cycles of that may round up by at most one cycle
            let back = c.ns_to_cycles(ns);
            assert!(back >= cycles && back <= cycles + 1, "{cycles} -> {ns} -> {back}");
        }
    }

    #[test]
    fn align_up() {
        let c = Clock::from_mhz(250.0); // 4ns period
        assert_eq!(c.align_up_ns(0), 0);
        assert_eq!(c.align_up_ns(1), 4);
        assert_eq!(c.align_up_ns(4), 4);
        assert_eq!(c.align_up_ns(5), 8);
    }

    #[test]
    #[should_panic]
    fn zero_freq_panics() {
        let _ = Clock::from_mhz(0.0);
    }
}
