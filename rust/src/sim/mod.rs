//! Discrete-event simulation substrate.
//!
//! Everything timed in the platform — PCIe link, HMMU pipeline, memory
//! controllers, DMA engine — advances on a shared nanosecond timeline
//! driven by [`event::EventQueue`]. [`clock::Clock`] converts between the
//! several clock domains involved (CPU 2 GHz, FPGA fabric 250 MHz, PCIe,
//! memory controller) and the wall timeline.

pub mod clock;
pub mod engine;
pub mod event;

pub use clock::Clock;
pub use engine::{tandem_analytic, DesRequest, TandemDes};
pub use event::{EventQueue, Scheduled};

/// Simulation timestamp in nanoseconds.
pub type Time = u64;
