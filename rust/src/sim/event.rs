//! Time-ordered event queue.
//!
//! A binary heap of `(time, seq, payload)` with a monotonic sequence number
//! to make same-timestamp ordering deterministic (FIFO among equals) —
//! essential for reproducible runs and for the tag-matching property tests
//! that explore interleavings.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::Time;

/// An event scheduled at `time`; `seq` breaks ties FIFO.
#[derive(Clone, Debug)]
pub struct Scheduled<E> {
    pub time: Time,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour inside BinaryHeap (max-heap).
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-heap event queue.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: Time,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
            processed: 0,
        }
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` at absolute time `at` (clamped to now if in the past).
    #[inline]
    pub fn schedule_at(&mut self, at: Time, event: E) {
        let t = at.max(self.now);
        self.heap.push(Scheduled {
            time: t,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` `delay` ns from now.
    #[inline]
    pub fn schedule_in(&mut self, delay: Time, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing `now`.
    #[inline]
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let s = self.heap.pop()?;
        debug_assert!(s.time >= self.now, "time went backwards");
        self.now = s.time;
        self.processed += 1;
        Some((s.time, s.event))
    }

    /// Time of the next pending event.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|s| s.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.schedule_at(100, ());
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 100);
        // past-time schedules clamp to now
        q.schedule_at(50, ());
        assert_eq!(q.pop(), Some((100, ())));
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(100, 1);
        q.pop();
        q.schedule_in(25, 2);
        assert_eq!(q.pop(), Some((125, 2)));
    }

    #[test]
    fn interleaved_schedule_pop() {
        let mut q = EventQueue::new();
        q.schedule_at(10, 1);
        q.schedule_at(30, 3);
        assert_eq!(q.pop(), Some((10, 1)));
        q.schedule_in(10, 2); // at 20
        assert_eq!(q.pop(), Some((20, 2)));
        assert_eq!(q.pop(), Some((30, 3)));
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule_at(1, ());
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek_time(), Some(1));
    }
}
