//! Table I technology presets.
//!
//! The paper's §III-F emulates an NVM by measuring the DRAM round trip and
//! scaling stall cycles by the Table I latency ratio. These presets encode
//! Table I — extended with the PCM and memristor (ReRAM) classes that the
//! "Modeling and Simulating Emerging Memory Technologies" tutorial treats
//! as first-order design points — so any technology can be swapped in
//! (`--tech stt-ram`, `--tiers dram+pcm+xpoint`, …), which the Table I
//! experiments and the tier-topology sweeps exercise.

/// Memory technology classes: Table I rows plus the tutorial-class PCM
/// and memristor points used by the tier-topology axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemTech {
    Flash,
    Xpoint3D,
    Dram,
    SttRam,
    Mram,
    /// Phase-change memory (tutorial-class: reads near DRAM, writes
    /// 5-20x slower, endurance ~10^8-10^9).
    Pcm,
    /// Memristor / ReRAM class (fast reads, moderate writes, high
    /// endurance relative to PCM).
    Memristor,
}

impl MemTech {
    pub const ALL: [MemTech; 7] = [
        MemTech::Flash,
        MemTech::Xpoint3D,
        MemTech::Dram,
        MemTech::SttRam,
        MemTech::Mram,
        MemTech::Pcm,
        MemTech::Memristor,
    ];

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "flash" => Some(Self::Flash),
            "3dxpoint" | "xpoint" | "xpoint3d" | "optane" => Some(Self::Xpoint3D),
            "dram" | "ddr4" => Some(Self::Dram),
            "sttram" | "stt" => Some(Self::SttRam),
            "mram" => Some(Self::Mram),
            "pcm" | "pcram" | "phasechange" => Some(Self::Pcm),
            "memristor" | "reram" | "rram" => Some(Self::Memristor),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Flash => "FLASH",
            Self::Xpoint3D => "3D XPoint",
            Self::Dram => "DRAM",
            Self::SttRam => "STT-RAM",
            Self::Mram => "MRAM",
            Self::Pcm => "PCM",
            Self::Memristor => "Memristor",
        }
    }

    /// Short lower-case label used in tier-topology strings
    /// (`dram+pcm+xpoint`) and scenario fingerprints.
    pub fn label(&self) -> &'static str {
        match self {
            Self::Flash => "flash",
            Self::Xpoint3D => "xpoint",
            Self::Dram => "dram",
            Self::SttRam => "stt-ram",
            Self::Mram => "mram",
            Self::Pcm => "pcm",
            Self::Memristor => "memristor",
        }
    }
}

/// One row of Table I (latencies in ns; endurance in cycles).
#[derive(Clone, Copy, Debug)]
pub struct TechPreset {
    pub tech: MemTech,
    pub read_ns: u64,
    pub write_ns: u64,
    pub endurance: u64,
    /// $/GB midpoint (Table I), used only for report output.
    pub dollars_per_gb: f64,
}

impl TechPreset {
    /// Table I values (midpoints of the published ranges); PCM and
    /// memristor rows use the tutorial-class midpoints.
    pub fn of(tech: MemTech) -> Self {
        match tech {
            MemTech::Flash => TechPreset {
                tech,
                read_ns: 100_000,
                write_ns: 100_000,
                endurance: 10_000,
                dollars_per_gb: 0.54,
            },
            MemTech::Xpoint3D => TechPreset {
                tech,
                read_ns: 100,  // 50-150ns midpoint
                write_ns: 275, // 50-500ns midpoint
                endurance: 1_000_000_000,
                dollars_per_gb: 6.5,
            },
            MemTech::Dram => TechPreset {
                tech,
                read_ns: 50,
                write_ns: 50,
                endurance: u64::MAX, // >10^16, effectively unlimited
                dollars_per_gb: 6.65,
            },
            MemTech::SttRam => TechPreset {
                tech,
                read_ns: 20,
                write_ns: 20,
                endurance: u64::MAX,
                dollars_per_gb: f64::NAN,
            },
            MemTech::Mram => TechPreset {
                tech,
                read_ns: 20,
                write_ns: 20,
                endurance: 1_000_000_000_000_000,
                dollars_per_gb: f64::NAN,
            },
            MemTech::Pcm => TechPreset {
                tech,
                read_ns: 75,   // 50-100ns class midpoint
                write_ns: 500, // 150-1000ns class midpoint
                endurance: 100_000_000, // ~10^8 writes/cell
                dollars_per_gb: 3.0,
            },
            MemTech::Memristor => TechPreset {
                tech,
                read_ns: 30,
                write_ns: 60,
                endurance: 100_000_000_000, // ~10^11 class
                dollars_per_gb: f64::NAN,
            },
        }
    }

    /// §III-F: extra read stall over the measured DRAM round trip.
    /// `dram_rt_ns` is the DRAM device round trip being scaled against.
    pub fn read_stall_ns(&self, dram_rt_ns: u64) -> u64 {
        let dram = TechPreset::of(MemTech::Dram);
        let ratio = self.read_ns as f64 / dram.read_ns as f64;
        ((ratio - 1.0).max(0.0) * dram_rt_ns as f64) as u64
    }

    /// §III-F: extra write stall over the measured DRAM round trip.
    pub fn write_stall_ns(&self, dram_rt_ns: u64) -> u64 {
        let dram = TechPreset::of(MemTech::Dram);
        let ratio = self.write_ns as f64 / dram.write_ns as f64;
        ((ratio - 1.0).max(0.0) * dram_rt_ns as f64) as u64
    }

    /// Row-buffer-aware stall on an open-row *hit*. Yoon et al.
    /// (arXiv 1804.11040): a row-buffer hit is served from the sense
    /// amps / row buffer, which costs roughly the same in DRAM and the
    /// NVM classes — so the hit stall is zero for every class.
    pub fn row_hit_stall_ns(&self) -> u64 {
        0
    }

    /// Row-buffer-aware stall on a row *miss*: the array access is where
    /// the NVM penalty lives (activation reads the slow cells, and the
    /// restore/write-back into the array is write-dominated), so the
    /// miss stall reuses the class's §III-F write-latency scaling.
    pub fn row_miss_stall_ns(&self, dram_rt_ns: u64) -> u64 {
        self.write_stall_ns(dram_rt_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_aliases() {
        assert_eq!(MemTech::parse("3d-xpoint"), Some(MemTech::Xpoint3D));
        assert_eq!(MemTech::parse("optane"), Some(MemTech::Xpoint3D));
        assert_eq!(MemTech::parse("STT_RAM"), Some(MemTech::SttRam));
        assert_eq!(MemTech::parse("pcm"), Some(MemTech::Pcm));
        assert_eq!(MemTech::parse("ReRAM"), Some(MemTech::Memristor));
        assert_eq!(MemTech::parse("ddr4"), Some(MemTech::Dram));
        assert_eq!(MemTech::parse("nope"), None);
    }

    #[test]
    fn dram_has_zero_stall() {
        let p = TechPreset::of(MemTech::Dram);
        assert_eq!(p.read_stall_ns(28), 0);
        assert_eq!(p.write_stall_ns(28), 0);
    }

    #[test]
    fn xpoint_write_slower_than_read() {
        let p = TechPreset::of(MemTech::Xpoint3D);
        assert!(p.write_stall_ns(28) > p.read_stall_ns(28));
    }

    #[test]
    fn stt_ram_faster_than_dram_no_negative_stall() {
        let p = TechPreset::of(MemTech::SttRam);
        assert_eq!(p.read_stall_ns(28), 0); // clamped at 0, not negative
    }

    #[test]
    fn flash_stall_is_huge() {
        let p = TechPreset::of(MemTech::Flash);
        assert!(p.read_stall_ns(28) > 10_000);
    }

    #[test]
    fn all_contains_every_class() {
        assert_eq!(MemTech::ALL.len(), 7);
        for t in MemTech::ALL {
            assert_eq!(MemTech::parse(t.label()), Some(t), "{t:?} label round-trips");
        }
    }

    #[test]
    fn pcm_writes_dominate_reads() {
        let p = TechPreset::of(MemTech::Pcm);
        assert!(p.write_stall_ns(28) > 3 * p.read_stall_ns(28));
        // PCM wears out before XPoint.
        assert!(p.endurance < TechPreset::of(MemTech::Xpoint3D).endurance);
    }

    #[test]
    fn row_buffer_presets_follow_yoon() {
        // Hits are class-independent (zero stall); misses pay the
        // write-scaled array penalty, ordered DDR4 < memristor < xpoint
        // < pcm like the flat write stalls they derive from.
        for t in MemTech::ALL {
            assert_eq!(TechPreset::of(t).row_hit_stall_ns(), 0, "{t:?}");
        }
        let miss = |t: MemTech| TechPreset::of(t).row_miss_stall_ns(28);
        assert_eq!(miss(MemTech::Dram), 0);
        assert!(miss(MemTech::Dram) < miss(MemTech::Memristor));
        assert!(miss(MemTech::Memristor) < miss(MemTech::Xpoint3D));
        assert!(miss(MemTech::Xpoint3D) < miss(MemTech::Pcm));
        assert_eq!(miss(MemTech::Xpoint3D), 126); // (275/50 - 1) * 28
    }

    #[test]
    fn memristor_between_dram_and_pcm() {
        let m = TechPreset::of(MemTech::Memristor);
        let pcm = TechPreset::of(MemTech::Pcm);
        assert!(m.read_stall_ns(28) < pcm.read_stall_ns(28));
        assert!(m.write_stall_ns(28) < pcm.write_stall_ns(28));
        assert!(m.endurance > pcm.endurance);
    }
}
