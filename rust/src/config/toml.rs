//! Minimal TOML-subset parser for experiment config files.
//!
//! Supports: `[section]` headers, `key = value` with string / integer /
//! float / bool values, `#` comments, and byte-suffixed strings ("128MB").
//! Nested tables, arrays and datetimes are intentionally out of scope —
//! experiment configs are flat.

use std::collections::BTreeMap;

use crate::bail;
use crate::util::error::Result;

/// A parsed flat TOML document: `section.key -> raw value string`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, Value>,
}

/// TOML scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl TomlDoc {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                section = name.trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if key.ends_with('.') || key.starts_with('.') || k.trim().is_empty() {
                bail!("line {}: bad key", lineno + 1);
            }
            doc.values.insert(key, parse_value(v.trim(), lineno + 1)?);
        }
        Ok(doc)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn get_i64(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.as_i64())
            .map(|v| v.max(0) as u64)
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// "128MB"-style byte strings, or raw integers.
    pub fn get_bytes(&self, key: &str, default: u64) -> u64 {
        match self.get(key) {
            Some(Value::Str(s)) => crate::util::units::parse_bytes(s).unwrap_or(default),
            Some(Value::Int(i)) => (*i).max(0) as u64,
            _ => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if let Some(stripped) = s.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string");
        };
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("line {lineno}: cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# experiment config
scale = 16
seed = 0

[dram]
size = "128MB"
banks = 16

[nvm]
read_stall_ns = 50
ratio = 2.5
enabled = true
name = "3D XPoint # not a comment"
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.get_i64("scale", 0), 16);
        assert_eq!(d.get_bytes("dram.size", 0), 128 << 20);
        assert_eq!(d.get_i64("dram.banks", 0), 16);
        assert_eq!(d.get_f64("nvm.ratio", 0.0), 2.5);
        assert!(d.get_bool("nvm.enabled", false));
        assert_eq!(d.get_str("nvm.name", ""), "3D XPoint # not a comment");
    }

    #[test]
    fn defaults_for_missing() {
        let d = TomlDoc::parse("").unwrap();
        assert!(d.is_empty());
        assert_eq!(d.get_u64("nope", 9), 9);
    }

    #[test]
    fn underscored_ints() {
        let d = TomlDoc::parse("n = 1_000_000").unwrap();
        assert_eq!(d.get_i64("n", 0), 1_000_000);
    }

    #[test]
    fn errors_on_garbage() {
        assert!(TomlDoc::parse("key").is_err());
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        assert!(TomlDoc::parse("k = @@").is_err());
    }

    #[test]
    fn int_vs_float() {
        let d = TomlDoc::parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(d.get("a"), Some(&Value::Int(3)));
        assert_eq!(d.get("b"), Some(&Value::Float(3.5)));
        assert_eq!(d.get_f64("a", 0.0), 3.0); // int coerces to f64
    }
}
