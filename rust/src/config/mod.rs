//! System configuration: Table II defaults, Table I technology presets,
//! the tier-stack topology (N-tier memory substrate), and a minimal
//! TOML-subset loader for experiment configs.

pub mod presets;
pub mod toml;

pub use presets::{MemTech, TechPreset};

use crate::bail;
use crate::mem::energy::EnergyCoeffs;
use crate::util::error::Result;

/// Maximum tiers a stack may hold: the redirection table packs the tier
/// rank into 3 bits of its 32-bit entries.
pub const MAX_TIERS: usize = 8;

/// Full specification of one memory tier: technology class, capacity,
/// emulation timings (§III-F stall injection over the DRAM round trip),
/// wear budget and energy coefficients. Tiers are **data**, not types —
/// the whole stack is a rank-ordered `Vec<TierSpec>` (rank 0 = fastest).
#[derive(Clone, Copy, Debug)]
pub struct TierSpec {
    pub tech: MemTech,
    pub size_bytes: u64,
    /// Extra read stall (ns) injected on top of the DRAM timing model.
    pub read_stall_ns: u64,
    /// Extra write stall (ns) injected on top of the DRAM timing model.
    pub write_stall_ns: u64,
    /// Charge stalls by the row-buffer outcome (`row_hit_stall_ns` /
    /// `row_miss_stall_ns`) instead of the flat per-kind stalls. Off by
    /// default: legacy flat charging stays bit-identical, and the row
    /// fields below are inert until this is set.
    pub row_aware: bool,
    /// Row-aware mode: extra stall (ns) on an open-row hit (Yoon et al.,
    /// arXiv 1804.11040 — ~0 for every class; hits are served from the
    /// row buffer at DRAM speed).
    pub row_hit_stall_ns: u64,
    /// Row-aware mode: extra stall (ns) on a row miss (the array access
    /// pays the NVM penalty; preset uses the class's write scaling).
    pub row_miss_stall_ns: u64,
    /// Write endurance budget per page (wear counters).
    pub endurance: u64,
    /// Energy coefficients for this tier's technology class.
    pub energy: EnergyCoeffs,
}

impl TierSpec {
    /// Build a tier from a technology-class preset: stalls scaled from
    /// the measured DRAM round trip `dram_rt_ns` (§III-F), endurance and
    /// energy coefficients from the class tables. Flat charging by
    /// default; the row-aware stall point is precomputed but inert until
    /// [`Self::with_row_buffer`] enables it.
    pub fn of(tech: MemTech, size_bytes: u64, dram_rt_ns: u64) -> Self {
        let p = TechPreset::of(tech);
        TierSpec {
            tech,
            size_bytes,
            read_stall_ns: p.read_stall_ns(dram_rt_ns),
            write_stall_ns: p.write_stall_ns(dram_rt_ns),
            row_aware: false,
            row_hit_stall_ns: p.row_hit_stall_ns(),
            row_miss_stall_ns: p.row_miss_stall_ns(dram_rt_ns),
            endurance: p.endurance,
            energy: EnergyCoeffs::of(tech),
        }
    }

    /// Switch the tier to row-buffer-aware stall charging (open-row hits
    /// pay `row_hit_stall_ns`, misses `row_miss_stall_ns`).
    pub fn with_row_buffer(mut self) -> Self {
        self.row_aware = true;
        self
    }

    /// Does this tier inject any stall over the DRAM substrate under its
    /// active charging mode? (The build gate: a DRAM-class tier with no
    /// effective stalls gets the bare timing model.)
    pub fn has_stalls(&self) -> bool {
        if self.row_aware {
            self.row_hit_stall_ns > 0 || self.row_miss_stall_ns > 0
        } else {
            self.read_stall_ns > 0 || self.write_stall_ns > 0
        }
    }

    /// Is this tier wear-limited (finite endurance)?
    pub fn wear_limited(&self) -> bool {
        self.endurance != u64::MAX
    }
}

/// Fault-injection and graceful-degradation knobs. All-off by default:
/// with `rber_base == 0.0` and `link_ber == 0.0` every fault hook is
/// dead code and the platform is bit-identical to a build without the
/// subsystem (pinned like `coalesce_writes`). Fault draws come from a
/// dedicated `Xoshiro256` stream seeded from `SystemConfig::seed` mixed
/// with `FaultConfig::seed`, owned per-HMMU / per-link, so sweeps stay
/// deterministic at any thread count.
#[derive(Clone, Copy, Debug)]
pub struct FaultConfig {
    /// Fault-stream seed, mixed (splitmix64) with the platform seed so
    /// the fault draws decorrelate from trace generation.
    pub seed: u64,
    /// Raw bit-error probability per memory access at zero wear.
    /// `0.0` disables the memory-side fault model entirely.
    pub rber_base: f64,
    /// Linear RBER growth with wear: the per-access error probability is
    /// `rber_base * (1 + rber_wear_slope * wear/endurance)`, clamped to 1.
    /// Tiers with unlimited endurance stay at `rber_base`.
    pub rber_wear_slope: f64,
    /// Fraction of raw errors the ECC cannot correct (those retire the
    /// frame); the rest are corrected at `ecc_latency_ns` cost.
    pub uncorrectable_frac: f64,
    /// Latency penalty (ns) charged on the access for an ECC correction.
    pub ecc_latency_ns: u64,
    /// Per-TLP corruption probability on the PCIe link. `0.0` disables
    /// the link fault model entirely.
    pub link_ber: f64,
    /// Max replay attempts per corrupted TLP (ack/nak replay buffer);
    /// after the limit the TLP is delivered as-is (modeled link gives up
    /// rather than hanging the emulation).
    pub link_retry_limit: u32,
    /// Replay-timeout charged per retry (nak detection + replay fetch),
    /// on top of re-serializing the TLP on the wire.
    pub replay_timeout_ns: u64,
}

impl FaultConfig {
    /// All fault injection off — the bit-identity default.
    pub fn disabled() -> Self {
        FaultConfig {
            seed: 0xFA57,
            rber_base: 0.0,
            rber_wear_slope: 8.0,
            uncorrectable_frac: 0.05,
            ecc_latency_ns: 40,
            link_ber: 0.0,
            link_retry_limit: 3,
            replay_timeout_ns: 100,
        }
    }

    /// Is the memory-side (RBER/ECC/retirement) model active?
    pub fn mem_enabled(&self) -> bool {
        self.rber_base > 0.0
    }

    /// Is the link-side (TLP corruption/replay) model active?
    pub fn link_enabled(&self) -> bool {
        self.link_ber > 0.0
    }

    /// Any fault model active?
    pub fn enabled(&self) -> bool {
        self.mem_enabled() || self.link_enabled()
    }

    /// The wear-driven RBER curve: per-access raw error probability for a
    /// frame at `wear` writes against a tier `endurance` budget.
    pub fn rber(&self, wear: u64, endurance: u64) -> f64 {
        if self.rber_base <= 0.0 {
            return 0.0;
        }
        let frac = if endurance == u64::MAX {
            0.0
        } else {
            wear as f64 / endurance as f64
        };
        (self.rber_base * (1.0 + self.rber_wear_slope * frac)).min(1.0)
    }
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self::disabled()
    }
}

/// Parse a tier-topology string like `dram+pcm+xpoint` into its class
/// list (used by `hymem sweep --tiers` and `hymem run --tiers`).
pub fn parse_topology(s: &str) -> Option<Vec<MemTech>> {
    let classes: Option<Vec<MemTech>> = s.split('+').map(|t| MemTech::parse(t.trim())).collect();
    classes.filter(|c| c.len() >= 2 && c.len() <= MAX_TIERS)
}

/// Cache geometry (one level).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheConfig {
    pub size_bytes: u64,
    pub ways: u32,
    pub line_bytes: u32,
    /// Hit latency in CPU cycles.
    pub hit_cycles: u32,
}

impl CacheConfig {
    pub fn sets(&self) -> u64 {
        self.size_bytes / (self.ways as u64 * self.line_bytes as u64)
    }
}

/// CPU core model parameters (ARM Cortex-A57-like, Table II).
#[derive(Clone, Copy, Debug)]
pub struct CpuConfig {
    pub freq_ghz: f64,
    pub cores: u32,
    /// Base IPC for non-memory instructions (A57 is a 3-wide OoO; SPEC
    /// achieves ~1.0-1.3 IPC on it).
    pub base_ipc: f64,
    /// Maximum outstanding misses the core tolerates before stalling
    /// (models the MSHR/LSQ capacity that lets OoO hide some latency).
    pub max_outstanding_misses: u32,
}

/// PCIe link parameters (Gen3 defaults per Table II).
#[derive(Clone, Copy, Debug)]
pub struct PcieConfig {
    /// Per-lane raw rate in GT/s (Gen3 = 8.0).
    pub gts_per_lane: f64,
    pub lanes: u32,
    /// 128b/130b encoding efficiency.
    pub encoding: f64,
    /// One-way propagation + PHY latency in ns (host->FPGA).
    pub propagation_ns: u64,
    /// TLP header bytes (3DW header + framing for memory requests).
    pub tlp_header_bytes: u32,
    /// Max TLP payload bytes.
    pub max_payload_bytes: u32,
    /// Flow-control credit count (outstanding TLPs each direction).
    pub credits: u32,
    /// Write-combining on the block-batched link crossing: adjacent posted
    /// MWr TLPs issued at the same time into the same 4 KiB-aligned window
    /// are merged into one TLP of up to `max_payload_bytes` payload. Off
    /// (the default) keeps the block path bit-identical to the per-op
    /// path; on changes only wire time / TLP counts, never redirection or
    /// residency state (`tests/pcie_props.rs` pins both).
    pub coalesce_writes: bool,
}

impl PcieConfig {
    /// Effective unidirectional bandwidth in bytes/ns (= GB/s).
    pub fn bandwidth_bytes_per_ns(&self) -> f64 {
        self.gts_per_lane * self.lanes as f64 * self.encoding / 8.0
    }
}

/// DRAM device timing (DDR4-like).
#[derive(Clone, Copy, Debug)]
pub struct DramConfig {
    pub size_bytes: u64,
    pub banks: u32,
    pub row_bytes: u32,
    /// Activate (tRCD) in ns.
    pub t_rcd_ns: u64,
    /// CAS latency in ns.
    pub t_cas_ns: u64,
    /// Precharge (tRP) in ns.
    pub t_rp_ns: u64,
    /// Data burst transfer time for one 64B line in ns.
    pub t_burst_ns: u64,
    /// Memory controller queue depth per channel.
    pub queue_depth: u32,
}

/// NVM emulation parameters (§III-F: DRAM with injected stall cycles).
#[derive(Clone, Copy, Debug)]
pub struct NvmConfig {
    pub size_bytes: u64,
    /// Extra read stall (ns) added on top of DRAM timing.
    pub read_stall_ns: u64,
    /// Extra write stall (ns) added on top of DRAM timing.
    pub write_stall_ns: u64,
    /// Charge stalls by row-buffer outcome instead of flat per-kind
    /// stalls (see [`TierSpec::row_aware`]). Off = legacy bit-identical.
    pub row_aware: bool,
    /// Row-aware mode: extra stall (ns) on an open-row hit.
    pub row_hit_stall_ns: u64,
    /// Row-aware mode: extra stall (ns) on a row miss.
    pub row_miss_stall_ns: u64,
    /// Write endurance budget per 4K page (for wear counters; 3D XPoint ~1e9).
    pub endurance: u64,
}

/// HMMU / FPGA fabric parameters.
#[derive(Clone, Copy, Debug)]
pub struct HmmuConfig {
    /// FPGA fabric clock (the paper's RTL runs at a few hundred MHz).
    pub fpga_freq_mhz: f64,
    /// Control pipeline depth (Fig 2: decode + policy + route stages).
    pub pipeline_stages: u32,
    /// HDR FIFO capacity (outstanding requests tracked for tag matching).
    pub hdr_fifo_depth: u32,
    /// DMA sub-block size in bytes (paper: 512B).
    pub dma_block_bytes: u32,
    /// DMA internal buffer size in bytes.
    pub dma_buffer_bytes: u32,
    /// Page size managed by the redirection table.
    pub page_bytes: u64,
    /// Epoch length (in processed requests) between policy invocations.
    pub epoch_requests: u64,
    /// Max migrations enacted per epoch (top-k from the policy step).
    /// Applies **per boundary** unless overridden below.
    pub migrations_per_epoch: u32,
    /// Per-boundary migration budgets: entry `b` caps the epoch's
    /// migrations across the rank-`b` / rank-`b+1` boundary. An entry of
    /// `0` means "unset" and falls back to `migrations_per_epoch`, so the
    /// all-zero default is bit-identical to the legacy global budget.
    pub migrations_per_boundary: [u32; MAX_TIERS - 1],
    /// Fidelity: DMA migration block transfers occupy HDR FIFO slots
    /// (and stall when it is full) like demand requests do in hardware —
    /// the engine shares the same DDR interfaces and header FIFO. `false`
    /// restores the pre-PR-2 model where migration traffic bypassed the
    /// occupancy model entirely.
    pub dma_hdr_occupancy: bool,
    /// Fidelity scenario: a *host-managed* HMMU design, where migration
    /// DMA is performed by the host and every migrated block crosses the
    /// PCIe link (contending with demand traffic for wire time and flow
    /// control credits; `pcie_dma_bytes` / `dma_link_stalls` count it).
    /// Off by default — the paper's HMMU owns both memory controllers, so
    /// its device-side DMA never touches PCIe.
    pub host_managed_dma: bool,
}

/// Placement/migration policy selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Fixed address split: low addresses in DRAM.
    Static,
    /// Allocate DRAM until full, overflow to NVM; no migration.
    FirstTouch,
    /// Epoch-based hotness migration (the XLA policy step).
    Hotness,
    /// First-touch + allocation hints from the middleware (§III-G).
    Hints,
    /// Hotness migration with NVM-endurance write bias (extension
    /// motivated by Table I's endurance column).
    WearAware,
    /// Row-buffer-locality migration: promote the pages whose accesses
    /// keep missing the NVM row buffer (Yoon et al., arXiv 1804.11040 —
    /// row hits run at DRAM speed wherever they live).
    Rbl,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(Self::Static),
            "first-touch" | "firsttouch" | "first_touch" => Some(Self::FirstTouch),
            "hotness" | "migration" => Some(Self::Hotness),
            "hints" => Some(Self::Hints),
            "wear-aware" | "wearaware" | "wear" => Some(Self::WearAware),
            "rbl" | "row-buffer" | "rowbuffer" => Some(Self::Rbl),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Self::Static => "static",
            Self::FirstTouch => "first-touch",
            Self::Hotness => "hotness",
            Self::Hints => "hints",
            Self::WearAware => "wear-aware",
            Self::Rbl => "rbl",
        }
    }
}

/// Complete system configuration (Fig 1b / Table II).
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub cpu: CpuConfig,
    pub l1i: CacheConfig,
    pub l1d: CacheConfig,
    pub l2: CacheConfig,
    pub pcie: PcieConfig,
    pub dram: DramConfig,
    pub nvm: NvmConfig,
    pub hmmu: HmmuConfig,
    pub policy: PolicyKind,
    /// Footprint/memory scale divisor (1 = paper-size, 16 = default).
    pub scale: u64,
    /// RNG seed for the whole platform.
    pub seed: u64,
    /// Technology class of the rank-1 tier (the `nvm` config's class);
    /// selects its energy coefficients and the topology label.
    pub nvm_tech: MemTech,
    /// Tiers beyond the base DRAM/NVM pair (rank 2 and deeper). Empty =
    /// the paper's two-tier topology; [`Self::with_tiers`] populates it.
    pub extra_tiers: Vec<TierSpec>,
    /// Optional non-DRAM rank-0 tier (e.g. an all-NVM stack like
    /// `pcm+xpoint`). `None` (the default) keeps the legacy DRAM rank 0
    /// built from the `dram` config, bit-identically; `Some` overrides
    /// its class/stalls/endurance/energy while the capacity still comes
    /// from `dram.size_bytes` (the emulation substrate is DRAM either
    /// way — §III-F injects the class's stalls on top).
    pub rank0: Option<TierSpec>,
    /// Fault-injection knobs (RBER/ECC/frame retirement + link replay).
    /// Disabled by default — bit-identical to a fault-free build.
    pub fault: FaultConfig,
}

impl SystemConfig {
    /// Paper Table II configuration at full size.
    pub fn paper() -> Self {
        SystemConfig {
            cpu: CpuConfig {
                freq_ghz: 2.0,
                cores: 8,
                base_ipc: 1.2,
                max_outstanding_misses: 6,
            },
            l1i: CacheConfig {
                size_bytes: 48 << 10,
                ways: 3,
                line_bytes: 64,
                hit_cycles: 1,
            },
            l1d: CacheConfig {
                size_bytes: 32 << 10,
                ways: 2,
                line_bytes: 64,
                hit_cycles: 2,
            },
            // Table II says "64KB cache line size" — the obvious typo for
            // 64B lines (A57 L2 has 64B lines).
            l2: CacheConfig {
                size_bytes: 1 << 20,
                ways: 16,
                line_bytes: 64,
                hit_cycles: 12,
            },
            pcie: PcieConfig {
                gts_per_lane: 8.0,
                lanes: 8,
                encoding: 128.0 / 130.0,
                propagation_ns: 400,
                tlp_header_bytes: 16,
                max_payload_bytes: 256,
                credits: 64,
                coalesce_writes: false,
            },
            dram: DramConfig {
                size_bytes: 128 << 20,
                banks: 16,
                row_bytes: 2048,
                t_rcd_ns: 14,
                t_cas_ns: 14,
                t_rp_ns: 14,
                t_burst_ns: 4,
                queue_depth: 32,
            },
            nvm: NvmConfig {
                size_bytes: 1 << 30,
                // §III-F scaling from Table I: 3D XPoint read 50-150ns vs
                // DRAM 50ns -> +50ns; write 50-500ns -> +225ns.
                read_stall_ns: 50,
                write_stall_ns: 225,
                // Row-aware point (inert until `row_aware`): hits free,
                // misses pay the write-scaled array penalty.
                row_aware: false,
                row_hit_stall_ns: 0,
                row_miss_stall_ns: 225,
                endurance: 1_000_000_000,
            },
            hmmu: HmmuConfig {
                fpga_freq_mhz: 250.0,
                pipeline_stages: 4,
                hdr_fifo_depth: 64,
                dma_block_bytes: 512,
                dma_buffer_bytes: 8192,
                page_bytes: 4096,
                epoch_requests: 100_000,
                migrations_per_epoch: 32,
                migrations_per_boundary: [0; MAX_TIERS - 1],
                dma_hdr_occupancy: true,
                host_managed_dma: false,
            },
            policy: PolicyKind::Hotness,
            scale: 1,
            seed: 0x5EED,
            nvm_tech: MemTech::Xpoint3D,
            extra_tiers: Vec::new(),
            rank0: None,
            fault: FaultConfig::disabled(),
        }
    }

    /// Table II scaled down by `scale` (memory sizes and footprints shrink
    /// together so the DRAM:NVM ratio and pressure stay faithful).
    pub fn default_scaled(scale: u64) -> Self {
        let mut c = Self::paper();
        assert!(scale >= 1);
        c.scale = scale;
        c.dram.size_bytes = (c.dram.size_bytes / scale).max(1 << 20);
        c.nvm.size_bytes = (c.nvm.size_bytes / scale).max(8 << 20);
        // Epochs scale so migration cadence per unique page stays similar.
        c.hmmu.epoch_requests = (c.hmmu.epoch_requests / scale).max(4096);
        c
    }

    /// Total hybrid capacity across every tier of the stack.
    pub fn total_mem_bytes(&self) -> u64 {
        self.dram.size_bytes
            + self.nvm.size_bytes
            + self.extra_tiers.iter().map(|t| t.size_bytes).sum::<u64>()
    }

    /// Number of managed pages in the hybrid space.
    pub fn total_pages(&self) -> u64 {
        self.total_mem_bytes() / self.hmmu.page_bytes
    }

    pub fn dram_pages(&self) -> u64 {
        self.dram.size_bytes / self.hmmu.page_bytes
    }

    /// Number of tiers in the stack (≥ 2: the DRAM/NVM pair is the base).
    pub fn tier_count(&self) -> usize {
        2 + self.extra_tiers.len()
    }

    /// Materialize the full tier stack, rank order: rank 0 from the
    /// `dram` config (DDR4 class, unless `rank0` overrides it), rank 1
    /// from the `nvm` config (class `nvm_tech`, so the legacy
    /// stall/endurance knobs keep acting on it), then `extra_tiers`.
    pub fn tier_specs(&self) -> Vec<TierSpec> {
        let mut v = Vec::with_capacity(self.tier_count());
        v.push(self.rank0.unwrap_or(TierSpec {
            tech: MemTech::Dram,
            size_bytes: self.dram.size_bytes,
            read_stall_ns: 0,
            write_stall_ns: 0,
            row_aware: false,
            row_hit_stall_ns: 0,
            row_miss_stall_ns: 0,
            endurance: u64::MAX,
            energy: EnergyCoeffs::ddr4(),
        }));
        v.push(TierSpec {
            tech: self.nvm_tech,
            size_bytes: self.nvm.size_bytes,
            read_stall_ns: self.nvm.read_stall_ns,
            write_stall_ns: self.nvm.write_stall_ns,
            row_aware: self.nvm.row_aware,
            row_hit_stall_ns: self.nvm.row_hit_stall_ns,
            row_miss_stall_ns: self.nvm.row_miss_stall_ns,
            endurance: self.nvm.endurance,
            energy: EnergyCoeffs::of(self.nvm_tech),
        });
        v.extend(self.extra_tiers.iter().copied());
        v
    }

    /// Page frames per tier, rank order.
    pub fn tier_pages(&self) -> Vec<u64> {
        self.tier_specs()
            .iter()
            .map(|t| t.size_bytes / self.hmmu.page_bytes)
            .collect()
    }

    /// The stack's topology label, e.g. `dram+xpoint` (default) or
    /// `dram+pcm+xpoint` — the tier axis of scenario fingerprints.
    pub fn topology_label(&self) -> String {
        self.tier_specs()
            .iter()
            .map(|t| t.tech.label())
            .collect::<Vec<_>>()
            .join("+")
    }

    /// Reconfigure the tier stack from a topology of technology classes
    /// (e.g. `[Dram, Pcm, Xpoint3D]` or `[Pcm, Xpoint3D]`). The only
    /// ordering constraint is `deeper → slower`: Table I read latency
    /// must be non-decreasing with rank (the DMA engine and the cascade
    /// policies promote *up* the stack). Rank 0 may be any class — a
    /// non-DRAM rank 0 lands in [`Self::rank0`] (capacity still
    /// `dram.size_bytes`, stalls scaled per §III-F); rank 1 reconfigures
    /// the `nvm` config from its class preset **only when the class
    /// changes**, so the default `dram+xpoint` topology keeps the
    /// paper-calibrated stall point bit-identical; ranks 2+ become
    /// `extra_tiers`, each twice the capacity of the previous NVM rank
    /// (capacity grows down the stack).
    pub fn with_tiers(mut self, classes: &[MemTech]) -> Result<Self> {
        if classes.len() < 2 || classes.len() > MAX_TIERS {
            bail!("tier topology needs 2..={MAX_TIERS} classes, got {}", classes.len());
        }
        let rt = self.dram.t_cas_ns + self.dram.t_rcd_ns;
        // Deeper → slower, in *emulated* terms: the injected §III-F read
        // stall over the DRAM substrate must be non-decreasing with rank
        // (classes faster than DRAM clamp to 0, so e.g. dram+stt-ram
        // remains a valid stack — both emulate at substrate speed).
        for w in classes.windows(2) {
            let (a, b) = (TechPreset::of(w[0]), TechPreset::of(w[1]));
            if a.read_stall_ns(rt) > b.read_stall_ns(rt) {
                bail!(
                    "tier topology must order deeper->slower: {} ({}ns read) sits above {} ({}ns read)",
                    w[0].label(),
                    a.read_ns,
                    w[1].label(),
                    b.read_ns
                );
            }
        }
        self.rank0 = (classes[0] != MemTech::Dram)
            .then(|| TierSpec::of(classes[0], self.dram.size_bytes, rt));
        if classes[1] != self.nvm_tech {
            let p = TechPreset::of(classes[1]);
            self.nvm.read_stall_ns = p.read_stall_ns(rt);
            self.nvm.write_stall_ns = p.write_stall_ns(rt);
            self.nvm.row_hit_stall_ns = p.row_hit_stall_ns();
            self.nvm.row_miss_stall_ns = p.row_miss_stall_ns(rt);
            self.nvm.endurance = p.endurance;
            self.nvm_tech = classes[1];
        }
        self.extra_tiers = classes[2..]
            .iter()
            .enumerate()
            .map(|(k, &c)| TierSpec::of(c, self.nvm.size_bytes << (k + 1), rt))
            .collect();
        Ok(self)
    }

    /// Apply a Table I technology preset to the NVM emulation parameters.
    pub fn with_tech(mut self, tech: MemTech) -> Self {
        let p = TechPreset::of(tech);
        let rt = self.dram.t_cas_ns + self.dram.t_rcd_ns;
        self.nvm.read_stall_ns = p.read_stall_ns(rt);
        self.nvm.write_stall_ns = p.write_stall_ns(rt);
        self.nvm.row_hit_stall_ns = p.row_hit_stall_ns();
        self.nvm.row_miss_stall_ns = p.row_miss_stall_ns(rt);
        self.nvm.endurance = p.endurance;
        self.nvm_tech = tech;
        self
    }

    /// Switch every stalled tier to row-buffer-aware charging (`hymem
    /// --row-aware`): open-row hits run at substrate (DRAM) speed, row
    /// misses pay the class's array penalty. Flat-charging configs are
    /// untouched by default — this is the explicit opt-in.
    pub fn with_row_buffer(mut self) -> Self {
        self.nvm.row_aware = true;
        self.rank0 = self.rank0.map(TierSpec::with_row_buffer);
        for t in &mut self.extra_tiers {
            t.row_aware = true;
        }
        self
    }

    /// Render the Table II block (used by `hymem config --show`).
    pub fn show(&self) -> String {
        use crate::util::units::fmt_bytes;
        let mut extra = String::new();
        if !self.extra_tiers.is_empty() {
            extra.push_str(&format!("\nTopology       {}", self.topology_label()));
            for (k, t) in self.extra_tiers.iter().enumerate() {
                extra.push_str(&format!(
                    "\nTier {}         {} {} (+{}ns rd / +{}ns wr stalls)",
                    k + 2,
                    fmt_bytes(t.size_bytes),
                    t.tech.name(),
                    t.read_stall_ns,
                    t.write_stall_ns,
                ));
            }
        }
        format!(
            "CPU            ARM Cortex-A57-like @ {:.1}GHz, {} cores (modeled)\n\
             L1 I-Cache     {} {}‑way\n\
             L1 D-Cache     {} {}‑way\n\
             L2 Cache       {} {}‑way, {}B lines\n\
             Interconnect   PCIe Gen3 x{} ({:.1} GT/s, {:.2} GB/s eff.)\n\
             DRAM           {} (scale 1/{})\n\
             NVM            {} (DRAM + {}ns rd / {}ns wr stalls)\n\
             HMMU           {} MHz fabric, {}‑deep HDR FIFO, {}B DMA blocks\n\
             Policy         {}{extra}",
            self.cpu.freq_ghz,
            self.cpu.cores,
            fmt_bytes(self.l1i.size_bytes),
            self.l1i.ways,
            fmt_bytes(self.l1d.size_bytes),
            self.l1d.ways,
            fmt_bytes(self.l2.size_bytes),
            self.l2.ways,
            self.l2.line_bytes,
            self.pcie.lanes,
            self.pcie.gts_per_lane,
            self.pcie.bandwidth_bytes_per_ns(),
            fmt_bytes(self.dram.size_bytes),
            self.scale,
            fmt_bytes(self.nvm.size_bytes),
            self.nvm.read_stall_ns,
            self.nvm.write_stall_ns,
            self.hmmu.fpga_freq_mhz,
            self.hmmu.hdr_fifo_depth,
            self.hmmu.dma_block_bytes,
            self.policy.name(),
        )
    }
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self::default_scaled(16)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_matches_table2() {
        let c = SystemConfig::paper();
        assert_eq!(c.cpu.cores, 8);
        assert_eq!(c.l1d.size_bytes, 32 << 10);
        assert_eq!(c.l1d.ways, 2);
        assert_eq!(c.l2.size_bytes, 1 << 20);
        assert_eq!(c.l2.ways, 16);
        assert_eq!(c.dram.size_bytes, 128 << 20);
        assert_eq!(c.nvm.size_bytes, 1 << 30);
    }

    #[test]
    fn scaling_preserves_ratio() {
        let c = SystemConfig::default_scaled(16);
        let p = SystemConfig::paper();
        assert_eq!(
            p.nvm.size_bytes / p.dram.size_bytes,
            c.nvm.size_bytes / c.dram.size_bytes
        );
        assert_eq!(c.dram.size_bytes, 8 << 20);
    }

    #[test]
    fn cache_sets() {
        let c = SystemConfig::paper();
        assert_eq!(c.l1d.sets(), 256); // 32K / (2 * 64)
        assert_eq!(c.l2.sets(), 1024); // 1M / (16 * 64)
    }

    #[test]
    fn pcie_bandwidth_gen3_x8() {
        let c = SystemConfig::paper();
        let bw = c.pcie.bandwidth_bytes_per_ns();
        assert!((bw - 7.88).abs() < 0.1, "bw={bw}");
    }

    #[test]
    fn page_counts() {
        let c = SystemConfig::default_scaled(16);
        assert_eq!(c.total_pages(), (8 + 64) * 256); // (8MiB+64MiB)/4KiB
        assert_eq!(c.dram_pages(), 2048);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(PolicyKind::parse("hotness"), Some(PolicyKind::Hotness));
        assert_eq!(PolicyKind::parse("STATIC"), Some(PolicyKind::Static));
        assert_eq!(PolicyKind::parse("first-touch"), Some(PolicyKind::FirstTouch));
        assert_eq!(PolicyKind::parse("rbl"), Some(PolicyKind::Rbl));
        assert_eq!(PolicyKind::parse("row-buffer"), Some(PolicyKind::Rbl));
        assert_eq!(PolicyKind::parse("bogus"), None);
    }

    #[test]
    fn tech_preset_changes_stalls() {
        let base = SystemConfig::paper();
        let stt = base.clone().with_tech(MemTech::SttRam);
        assert!(stt.nvm.read_stall_ns < base.nvm.read_stall_ns);
        assert_eq!(stt.nvm_tech, MemTech::SttRam);
    }

    #[test]
    fn default_stack_is_the_paper_pair() {
        let c = SystemConfig::paper();
        assert_eq!(c.tier_count(), 2);
        let specs = c.tier_specs();
        assert_eq!(specs[0].tech, MemTech::Dram);
        assert_eq!(specs[0].size_bytes, c.dram.size_bytes);
        assert_eq!(specs[0].read_stall_ns, 0);
        assert_eq!(specs[1].tech, MemTech::Xpoint3D);
        assert_eq!(specs[1].read_stall_ns, c.nvm.read_stall_ns);
        assert_eq!(specs[1].endurance, c.nvm.endurance);
        assert_eq!(c.topology_label(), "dram+xpoint");
        assert_eq!(c.tier_pages().len(), 2);
    }

    #[test]
    fn with_tiers_default_pair_is_identity() {
        // `dram+xpoint` must not perturb the paper-calibrated stall point
        // (bit-identity contract of the two-tier default).
        let base = SystemConfig::default_scaled(64);
        let explicit = base
            .clone()
            .with_tiers(&[MemTech::Dram, MemTech::Xpoint3D])
            .unwrap();
        assert_eq!(explicit.nvm.read_stall_ns, base.nvm.read_stall_ns);
        assert_eq!(explicit.nvm.write_stall_ns, base.nvm.write_stall_ns);
        assert_eq!(explicit.nvm.endurance, base.nvm.endurance);
        assert!(explicit.extra_tiers.is_empty());
        assert_eq!(explicit.total_mem_bytes(), base.total_mem_bytes());
    }

    #[test]
    fn three_tier_topology_extends_the_stack() {
        let c = SystemConfig::default_scaled(64)
            .with_tiers(&[MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D])
            .unwrap();
        assert_eq!(c.tier_count(), 3);
        assert_eq!(c.nvm_tech, MemTech::Pcm);
        assert_eq!(c.topology_label(), "dram+pcm+xpoint");
        let specs = c.tier_specs();
        // Rank-2 capacity doubles the rank-1 capacity.
        assert_eq!(specs[2].size_bytes, 2 * c.nvm.size_bytes);
        assert_eq!(specs[2].tech, MemTech::Xpoint3D);
        // Total capacity and page count include every tier.
        assert_eq!(
            c.total_mem_bytes(),
            c.dram.size_bytes + c.nvm.size_bytes + specs[2].size_bytes
        );
        assert_eq!(c.total_pages(), c.tier_pages().iter().sum::<u64>());
        // PCM rank is wear-limited; its writes stall more than its reads.
        assert!(specs[1].wear_limited());
        assert!(specs[1].write_stall_ns > specs[1].read_stall_ns);
    }

    #[test]
    fn topology_parsing() {
        assert_eq!(
            parse_topology("dram+pcm+xpoint"),
            Some(vec![MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D])
        );
        assert_eq!(
            parse_topology("dram+memristor"),
            Some(vec![MemTech::Dram, MemTech::Memristor])
        );
        assert_eq!(parse_topology("dram"), None, "one tier is not a stack");
        assert_eq!(parse_topology("dram+bogus"), None);
    }

    #[test]
    fn with_tiers_rejects_bad_topologies() {
        let c = SystemConfig::default_scaled(64);
        assert!(c.clone().with_tiers(&[MemTech::Dram]).is_err());
        let inverted = c.clone().with_tiers(&[MemTech::Xpoint3D, MemTech::SttRam]);
        assert!(inverted.is_err(), "slower class above faster must be rejected");
        assert!(c.with_tiers(&[MemTech::Dram; 9]).is_err());
    }

    #[test]
    fn non_dram_rank0_stack_accepted() {
        // The old restriction ("rank 0 must be dram-class") is lifted: an
        // all-NVM stack orders deeper->slower and is a valid topology.
        let base = SystemConfig::default_scaled(64);
        let c = base
            .clone()
            .with_tiers(&[MemTech::Pcm, MemTech::Xpoint3D])
            .unwrap();
        assert_eq!(c.tier_count(), 2);
        assert_eq!(c.topology_label(), "pcm+xpoint");
        let specs = c.tier_specs();
        assert_eq!(specs[0].tech, MemTech::Pcm);
        // Capacity still comes from the DRAM substrate config; the class
        // override injects the PCM stall/endurance/energy point.
        assert_eq!(specs[0].size_bytes, base.dram.size_bytes);
        assert!(specs[0].write_stall_ns > specs[0].read_stall_ns);
        assert!(specs[0].wear_limited());
        // Rank order stays emulated-slower-downward.
        assert!(specs[1].read_stall_ns >= specs[0].read_stall_ns);
        // A DRAM-rank-0 topology keeps the legacy (override-free) path.
        let d = base.with_tiers(&[MemTech::Dram, MemTech::Xpoint3D]).unwrap();
        assert!(d.rank0.is_none());
    }

    #[test]
    fn fault_config_defaults_disabled() {
        let c = SystemConfig::paper();
        assert!(!c.fault.enabled());
        assert!(!c.fault.mem_enabled());
        assert!(!c.fault.link_enabled());
        assert_eq!(c.fault.rber(u64::MAX - 1, 100), 0.0, "disabled curve is flat zero");
        let mut f = c.fault;
        f.rber_base = 1e-4;
        assert!(f.mem_enabled() && f.enabled() && !f.link_enabled());
        // The RBER curve grows with wear fraction and clamps at 1.
        assert!(f.rber(0, 1000) < f.rber(500, 1000));
        assert!(f.rber(500, 1000) < f.rber(1000, 1000));
        assert_eq!(f.rber(10, u64::MAX), f.rber(0, u64::MAX), "unlimited endurance never wears");
        f.rber_base = 1.0;
        assert_eq!(f.rber(u64::MAX / 2, 1), 1.0, "clamped at certainty");
    }

    #[test]
    fn row_buffer_mode_is_opt_in() {
        let base = SystemConfig::paper();
        assert!(!base.nvm.row_aware);
        let specs = base.tier_specs();
        assert!(!specs[0].row_aware && !specs[1].row_aware);
        let rb = base.clone().with_row_buffer().tier_specs();
        assert!(rb[1].row_aware);
        assert_eq!(rb[1].row_hit_stall_ns, 0, "hits run at substrate speed");
        assert_eq!(rb[1].row_miss_stall_ns, 225, "misses pay the array penalty");
        // `has_stalls` follows the active charging mode.
        assert!(rb[1].has_stalls());
        let mut dram_rb = rb[0];
        dram_rb.row_aware = true;
        assert!(!dram_rb.has_stalls(), "row-aware DDR4 still injects nothing");
        // Deeper stacks propagate the flag to extra tiers.
        let three = SystemConfig::default_scaled(64)
            .with_tiers(&[MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D])
            .unwrap()
            .with_row_buffer();
        let spec2 = three.tier_specs()[2];
        assert!(spec2.row_aware);
        assert!(spec2.row_miss_stall_ns > 0);
    }

    #[test]
    fn boundary_budgets_default_unset() {
        let c = SystemConfig::paper();
        assert_eq!(c.hmmu.migrations_per_boundary, [0; MAX_TIERS - 1]);
    }
}
