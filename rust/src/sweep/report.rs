//! Structured sweep results: per-scenario modeled metrics, aggregates,
//! the determinism fingerprint, and JSON emission.

use crate::platform::RunReport;
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::stats::geomean;
use crate::util::units::fmt_ns;
use std::fmt::Write as _;
use std::path::Path;

use super::Scenario;

/// Modeled outcome of one scenario. Every field except `wall_ns` is a
/// deterministic function of the scenario and its derived seed.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub name: String,
    pub workload: String,
    pub policy: String,
    pub seed: u64,
    pub ops: u64,
    /// Core count (1 = single-core platform run; >1 = rate-style
    /// multicore run, where `platform_time_ns` is the makespan and the
    /// native/slowdown columns are 0 — no native reference exists).
    pub cores: usize,
    /// Tier-stack topology label (`dram+xpoint`, `dram+pcm+xpoint`, …) —
    /// the tier axis of the scenario fingerprint.
    pub topology: String,
    pub platform_time_ns: u64,
    pub native_time_ns: u64,
    pub slowdown: f64,
    pub l2_miss_rate: f64,
    pub dram_service_ratio: f64,
    pub dram_residency: f64,
    pub migrations: u64,
    pub migration_bytes: u64,
    pub epochs: u64,
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub nvm_reads: u64,
    pub nvm_writes: u64,
    /// Per-tier demand reads/writes, rank order (the two-tier columns
    /// above are ranks 0/1 of these).
    pub tier_reads: Vec<u64>,
    pub tier_writes: Vec<u64>,
    /// Per-tier first-touch placement decisions, rank order.
    pub tier_pages_placed: Vec<u64>,
    /// Per-tier device row-buffer outcomes, rank order (mirrored from
    /// the tier devices; the RBL observability surface).
    pub tier_row_hits: Vec<u64>,
    pub tier_row_misses: Vec<u64>,
    /// Derived per-tier row-buffer hit rate (0 for a traffic-free tier).
    pub tier_row_hit_rate: Vec<f64>,
    /// Per-tier resident page counts at end of run.
    pub tier_residency: Vec<u64>,
    /// Per-tier max page wear.
    pub tier_wear: Vec<u64>,
    /// Per-tier (static + dynamic) energy, mJ (empty for multicore rows,
    /// which carry no full energy report).
    pub tier_energy_mj: Vec<f64>,
    /// Host requests seen by the HMMU (post cache filter), by kind.
    pub host_reads: u64,
    pub host_writes: u64,
    pub host_read_bytes: u64,
    pub host_write_bytes: u64,
    pub fifo_full_stalls: u64,
    pub reorder_wait_ns: u64,
    pub dma_conflict_stalls: u64,
    /// HDR FIFO slots consumed / stalls incurred by migration DMA (only
    /// under `HmmuConfig::dma_hdr_occupancy`).
    pub dma_hdr_slots: u64,
    pub dma_hdr_stalls: u64,
    /// Migration payload bytes that crossed the PCIe link (host-managed
    /// DMA scenarios; 0 under the paper's device-side DMA).
    pub pcie_dma_bytes: u64,
    /// PCIe credit stalls attributed to host-managed DMA transfers.
    pub dma_link_stalls: u64,
    /// Fault-layer tallies (all 0 with faults off — see
    /// [`crate::config::FaultConfig`]): ECC events, frames retired into
    /// the per-tier retired pools, emergency remap migrations/bytes, and
    /// PCIe replay retries.
    pub ecc_corrected: u64,
    pub ecc_uncorrectable: u64,
    pub frames_retired: u64,
    pub remap_migrations: u64,
    pub remap_bytes: u64,
    pub link_retries: u64,
    pub nvm_max_wear: u64,
    pub energy_mj: f64,
    pub latency_mean_ns: f64,
    pub latency_p50_ns: u64,
    pub latency_p99_ns: u64,
    pub latency_max_ns: u64,
    /// Host wall clock of this scenario's run (nondeterministic; excluded
    /// from the fingerprint).
    pub wall_ns: u64,
}

impl ScenarioResult {
    pub fn new(sc: &Scenario, seed: u64, r: &RunReport, wall_ns: u64) -> Self {
        ScenarioResult {
            name: sc.name.clone(),
            workload: r.workload.clone(),
            policy: r.policy.clone(),
            seed,
            ops: sc.ops,
            cores: sc.cores,
            topology: r.topology.clone(),
            platform_time_ns: r.platform_time_ns,
            native_time_ns: r.native_time_ns,
            slowdown: r.slowdown(),
            l2_miss_rate: r.l2_miss_rate,
            dram_service_ratio: r.counters.dram_service_ratio(),
            dram_residency: r.dram_residency,
            migrations: r.counters.migrations,
            migration_bytes: r.counters.migration_bytes,
            epochs: r.counters.epochs,
            dram_reads: r.counters.dram_reads(),
            dram_writes: r.counters.dram_writes(),
            nvm_reads: r.counters.nvm_reads(),
            nvm_writes: r.counters.nvm_writes(),
            tier_reads: r.counters.tier_reads.clone(),
            tier_writes: r.counters.tier_writes.clone(),
            tier_pages_placed: r.counters.tier_pages_placed.clone(),
            tier_row_hits: r.counters.tier_row_hits.clone(),
            tier_row_misses: r.counters.tier_row_misses.clone(),
            tier_row_hit_rate: (0..r.counters.tier_row_hits.len())
                .map(|t| r.counters.tier_row_hit_rate(t))
                .collect(),
            tier_residency: r.tier_residency.clone(),
            tier_wear: r.tier_wear.clone(),
            tier_energy_mj: r.energy.tiers.iter().map(|&(s, d)| s + d).collect(),
            host_reads: r.counters.host_reads,
            host_writes: r.counters.host_writes,
            host_read_bytes: r.counters.host_read_bytes,
            host_write_bytes: r.counters.host_write_bytes,
            fifo_full_stalls: r.counters.fifo_full_stalls,
            reorder_wait_ns: r.counters.reorder_wait_ns,
            dma_conflict_stalls: r.counters.dma_conflict_stalls,
            dma_hdr_slots: r.counters.dma_hdr_slots,
            dma_hdr_stalls: r.counters.dma_hdr_stalls,
            pcie_dma_bytes: r.counters.pcie_dma_bytes,
            dma_link_stalls: r.counters.dma_link_stalls,
            ecc_corrected: r.counters.ecc_corrected,
            ecc_uncorrectable: r.counters.ecc_uncorrectable,
            frames_retired: r.counters.frames_retired,
            remap_migrations: r.counters.remap_migrations,
            remap_bytes: r.counters.remap_bytes,
            link_retries: r.counters.link_retries,
            nvm_max_wear: r.nvm_max_wear,
            energy_mj: r.counters.energy_estimate_mj(),
            latency_mean_ns: r.counters.latency.mean(),
            latency_p50_ns: r.counters.latency.percentile(50.0),
            latency_p99_ns: r.counters.latency.percentile(99.0),
            latency_max_ns: r.counters.latency.max(),
            wall_ns,
        }
    }

    /// A multicore scenario result (`Scenario::cores > 1`): the shared
    /// HMMU's counters fill the same columns as a single-core run; the
    /// native-reference columns (`native_time_ns`, `slowdown`) and the
    /// per-hierarchy `l2_miss_rate` have no multicore equivalent and
    /// report 0.
    pub fn from_multicore(
        sc: &Scenario,
        seed: u64,
        r: &crate::platform::MulticoreReport,
        wall_ns: u64,
    ) -> Self {
        ScenarioResult {
            name: sc.name.clone(),
            workload: sc.workload.name.to_string(),
            policy: sc.cfg.policy.name().to_string(),
            seed,
            ops: sc.ops,
            cores: sc.cores,
            topology: r.topology.clone(),
            platform_time_ns: r.makespan_ns,
            native_time_ns: 0,
            slowdown: 0.0,
            l2_miss_rate: 0.0,
            dram_service_ratio: r.counters.dram_service_ratio(),
            dram_residency: r.dram_residency,
            migrations: r.counters.migrations,
            migration_bytes: r.counters.migration_bytes,
            epochs: r.counters.epochs,
            dram_reads: r.counters.dram_reads(),
            dram_writes: r.counters.dram_writes(),
            nvm_reads: r.counters.nvm_reads(),
            nvm_writes: r.counters.nvm_writes(),
            tier_reads: r.counters.tier_reads.clone(),
            tier_writes: r.counters.tier_writes.clone(),
            tier_pages_placed: r.counters.tier_pages_placed.clone(),
            tier_row_hits: r.counters.tier_row_hits.clone(),
            tier_row_misses: r.counters.tier_row_misses.clone(),
            tier_row_hit_rate: (0..r.counters.tier_row_hits.len())
                .map(|t| r.counters.tier_row_hit_rate(t))
                .collect(),
            tier_residency: r.tier_residency.clone(),
            tier_wear: r.tier_wear.clone(),
            tier_energy_mj: Vec::new(),
            host_reads: r.counters.host_reads,
            host_writes: r.counters.host_writes,
            host_read_bytes: r.counters.host_read_bytes,
            host_write_bytes: r.counters.host_write_bytes,
            fifo_full_stalls: r.counters.fifo_full_stalls,
            reorder_wait_ns: r.counters.reorder_wait_ns,
            dma_conflict_stalls: r.counters.dma_conflict_stalls,
            dma_hdr_slots: r.counters.dma_hdr_slots,
            dma_hdr_stalls: r.counters.dma_hdr_stalls,
            pcie_dma_bytes: r.counters.pcie_dma_bytes,
            dma_link_stalls: r.counters.dma_link_stalls,
            ecc_corrected: r.counters.ecc_corrected,
            ecc_uncorrectable: r.counters.ecc_uncorrectable,
            frames_retired: r.counters.frames_retired,
            remap_migrations: r.counters.remap_migrations,
            remap_bytes: r.counters.remap_bytes,
            link_retries: r.counters.link_retries,
            nvm_max_wear: r.nvm_max_wear,
            energy_mj: r.counters.energy_estimate_mj(),
            latency_mean_ns: r.counters.latency.mean(),
            latency_p50_ns: r.counters.latency.percentile(50.0),
            latency_p99_ns: r.counters.latency.percentile(99.0),
            latency_max_ns: r.counters.latency.max(),
            wall_ns,
        }
    }

    /// One summary line (RunReport::summary-style).
    pub fn summary(&self) -> String {
        format!(
            "{:<26} slowdown={:>6.2}x  dramServ={:>5.1}%  dramResid={:>5.1}%  \
             migrations={:<6} p99={:>7}ns  wall={}",
            self.name,
            self.slowdown,
            self.dram_service_ratio * 100.0,
            self.dram_residency * 100.0,
            self.migrations,
            self.latency_p99_ns,
            fmt_ns(self.wall_ns),
        )
    }

    /// Every modeled field, rendered canonically. Two runs of the same
    /// scenario must produce byte-identical lines regardless of thread
    /// count — this is what the determinism tests compare.
    pub fn deterministic_key(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{}|{}|{}|seed={:#x}|ops={}|cores={}|tiers={}|plat={}|native={}|slow={:?}|l2={:?}|serv={:?}|resid={:?}\
             |mig={}|migB={}|epochs={}|dr={}|dw={}|nr={}|nw={}|tr={:?}|tw={:?}|tpp={:?}|trh={:?}|trm={:?}|trr={:?}|tres={:?}|twear={:?}|tmj={:?}\
             |hr={}|hw={}|hrb={}|hwb={}|fifo={}|reorder={}|dma={}|hdrSlots={}|hdrStalls={}\
             |dmaPcieB={}|dmaLinkStalls={}|wear={}|mj={:?}|lat=({:?},{},{},{})",
            self.name,
            self.workload,
            self.policy,
            self.seed,
            self.ops,
            self.cores,
            self.topology,
            self.platform_time_ns,
            self.native_time_ns,
            self.slowdown,
            self.l2_miss_rate,
            self.dram_service_ratio,
            self.dram_residency,
            self.migrations,
            self.migration_bytes,
            self.epochs,
            self.dram_reads,
            self.dram_writes,
            self.nvm_reads,
            self.nvm_writes,
            self.tier_reads,
            self.tier_writes,
            self.tier_pages_placed,
            self.tier_row_hits,
            self.tier_row_misses,
            self.tier_row_hit_rate,
            self.tier_residency,
            self.tier_wear,
            self.tier_energy_mj,
            self.host_reads,
            self.host_writes,
            self.host_read_bytes,
            self.host_write_bytes,
            self.fifo_full_stalls,
            self.reorder_wait_ns,
            self.dma_conflict_stalls,
            self.dma_hdr_slots,
            self.dma_hdr_stalls,
            self.pcie_dma_bytes,
            self.dma_link_stalls,
            self.nvm_max_wear,
            self.energy_mj,
            self.latency_mean_ns,
            self.latency_p50_ns,
            self.latency_p99_ns,
            self.latency_max_ns,
        );
        // Fault block: appended only when any fault event fired, so
        // fault-off fingerprints stay byte-identical to pre-fault-layer
        // builds (the same gating as `HmmuCounters`'s Debug rendering).
        let fault_events = self.ecc_corrected
            + self.ecc_uncorrectable
            + self.frames_retired
            + self.remap_migrations
            + self.remap_bytes
            + self.link_retries;
        if fault_events > 0 {
            let _ = write!(
                s,
                "|eccC={}|eccU={}|retired={}|remap={}|remapB={}|linkRetry={}",
                self.ecc_corrected,
                self.ecc_uncorrectable,
                self.frames_retired,
                self.remap_migrations,
                self.remap_bytes,
                self.link_retries,
            );
        }
        s
    }

    fn to_json(&self) -> Json {
        let arr_u64 = |v: &[u64]| Json::Arr(v.iter().map(|&x| Json::U64(x)).collect());
        let arr_f64 = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::F64(x)).collect());
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("workload", self.workload.as_str())
            .set("policy", self.policy.as_str())
            .set("seed", self.seed)
            .set("ops", self.ops)
            .set("cores", self.cores as u64)
            .set("topology", self.topology.as_str())
            .set("tier_reads", arr_u64(&self.tier_reads))
            .set("tier_writes", arr_u64(&self.tier_writes))
            .set("tier_pages_placed", arr_u64(&self.tier_pages_placed))
            .set("tier_row_hits", arr_u64(&self.tier_row_hits))
            .set("tier_row_misses", arr_u64(&self.tier_row_misses))
            .set("tier_row_hit_rate", arr_f64(&self.tier_row_hit_rate))
            .set("tier_residency", arr_u64(&self.tier_residency))
            .set("tier_wear", arr_u64(&self.tier_wear))
            .set("tier_energy_mj", arr_f64(&self.tier_energy_mj))
            .set("platform_time_ns", self.platform_time_ns)
            .set("native_time_ns", self.native_time_ns)
            .set("slowdown", self.slowdown)
            .set("l2_miss_rate", self.l2_miss_rate)
            .set("dram_service_ratio", self.dram_service_ratio)
            .set("dram_residency", self.dram_residency)
            .set("migrations", self.migrations)
            .set("migration_bytes", self.migration_bytes)
            .set("epochs", self.epochs)
            .set("dram_reads", self.dram_reads)
            .set("dram_writes", self.dram_writes)
            .set("nvm_reads", self.nvm_reads)
            .set("nvm_writes", self.nvm_writes)
            .set("host_reads", self.host_reads)
            .set("host_writes", self.host_writes)
            .set("host_read_bytes", self.host_read_bytes)
            .set("host_write_bytes", self.host_write_bytes)
            .set("fifo_full_stalls", self.fifo_full_stalls)
            .set("reorder_wait_ns", self.reorder_wait_ns)
            .set("dma_conflict_stalls", self.dma_conflict_stalls)
            .set("dma_hdr_slots", self.dma_hdr_slots)
            .set("dma_hdr_stalls", self.dma_hdr_stalls)
            .set("pcie_dma_bytes", self.pcie_dma_bytes)
            .set("dma_link_stalls", self.dma_link_stalls)
            .set("ecc_corrected", self.ecc_corrected)
            .set("ecc_uncorrectable", self.ecc_uncorrectable)
            .set("frames_retired", self.frames_retired)
            .set("remap_migrations", self.remap_migrations)
            .set("remap_bytes", self.remap_bytes)
            .set("link_retries", self.link_retries)
            .set("nvm_max_wear", self.nvm_max_wear)
            .set("energy_mj", self.energy_mj)
            .set("latency_mean_ns", self.latency_mean_ns)
            .set("latency_p50_ns", self.latency_p50_ns)
            .set("latency_p99_ns", self.latency_p99_ns)
            .set("latency_max_ns", self.latency_max_ns)
            .set("wall_ns", self.wall_ns);
        o
    }
}

/// Aggregate of one sweep invocation.
#[derive(Clone, Debug)]
pub struct SweepReport {
    /// Worker threads actually used.
    pub threads: usize,
    /// Parallel wall clock of the whole sweep.
    pub wall_ns: u64,
    /// Sum of per-scenario walls. Each pass runs serially inside its
    /// scenario, so this estimates the serial-equivalent cost;
    /// `serial_wall_ns / wall_ns` is the sweep-level speedup. Under a
    /// parallel sweep the per-scenario walls still share caches/memory
    /// bandwidth with sibling scenarios, so treat the estimate as a lower
    /// bound on true serial cost — for an uncontended baseline run the
    /// same scenarios with `threads = 1` and compare `wall_ns` directly.
    pub serial_wall_ns: u64,
    pub geomean_slowdown: f64,
    /// Results in scenario order (independent of execution order).
    pub scenarios: Vec<ScenarioResult>,
}

impl SweepReport {
    pub fn new(threads: usize, wall_ns: u64, scenarios: Vec<ScenarioResult>) -> Self {
        // Multicore scenarios carry no native reference (slowdown 0);
        // keep them out of the geomean instead of cratering it.
        let slowdowns: Vec<f64> = scenarios
            .iter()
            .map(|s| s.slowdown)
            .filter(|&x| x > 0.0)
            .collect();
        SweepReport {
            threads,
            wall_ns,
            serial_wall_ns: scenarios.iter().map(|s| s.wall_ns).sum(),
            geomean_slowdown: geomean(&slowdowns),
            scenarios,
        }
    }

    /// Sweep-level parallel speedup vs running the same scenarios
    /// back-to-back.
    pub fn parallel_speedup(&self) -> f64 {
        self.serial_wall_ns as f64 / self.wall_ns.max(1) as f64
    }

    /// Multi-line human summary.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for r in &self.scenarios {
            s.push_str(&r.summary());
            s.push('\n');
        }
        let _ = write!(
            s,
            "{} scenarios on {} threads: wall {} (serial-equivalent {}, {:.2}x speedup), \
             geomean slowdown {:.2}x",
            self.scenarios.len(),
            self.threads,
            fmt_ns(self.wall_ns),
            fmt_ns(self.serial_wall_ns),
            self.parallel_speedup(),
            self.geomean_slowdown,
        );
        s
    }

    /// Canonical rendering of every modeled field of every scenario.
    /// Byte-identical across thread counts (walls and thread counts are
    /// excluded); the determinism tests compare exactly this.
    pub fn deterministic_fingerprint(&self) -> String {
        let mut s = String::new();
        for r in &self.scenarios {
            s.push_str(&r.deterministic_key());
            s.push('\n');
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("schema", "hymem/sweep/v1")
            .set("threads", self.threads)
            .set("wall_ns", self.wall_ns)
            .set("serial_wall_ns", self.serial_wall_ns)
            .set("parallel_speedup", self.parallel_speedup())
            .set("geomean_slowdown", self.geomean_slowdown)
            .set(
                "scenarios",
                Json::Arr(self.scenarios.iter().map(|r| r.to_json()).collect()),
            );
        o
    }

    /// Write the machine-readable report (e.g. `BENCH_sweep.json`).
    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sweep::{run_sweep, Scenario};
    use crate::workload::spec;

    fn tiny_sweep() -> SweepReport {
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = crate::config::PolicyKind::Static;
        let wl = spec::by_name("541.leela").unwrap();
        let scenarios = vec![
            Scenario::new("a", wl, cfg.clone(), 3_000),
            Scenario::new("b", wl, cfg, 3_000),
        ];
        run_sweep(&scenarios, 2).unwrap()
    }

    #[test]
    fn aggregates_and_fingerprint() {
        let r = tiny_sweep();
        assert_eq!(r.scenarios.len(), 2);
        assert!(r.geomean_slowdown > 0.0);
        assert!(r.serial_wall_ns >= r.scenarios[0].wall_ns);
        let fp = r.deterministic_fingerprint();
        assert_eq!(fp.lines().count(), 2);
        // Same scenario list, same seeds -> same fingerprint lines except
        // the differing names/seeds.
        assert!(fp.contains("a|"));
        assert!(fp.contains("b|"));
        assert!(!fp.contains("wall"), "fingerprint must exclude wall time");
    }

    #[test]
    fn json_has_schema_and_scenarios() {
        let r = tiny_sweep();
        let js = r.to_json().render();
        assert!(js.contains("\"schema\":\"hymem/sweep/v1\""));
        assert!(js.contains("\"scenarios\":["));
        assert!(js.contains("\"platform_time_ns\""));
        assert!(js.contains("\"tier_row_hits\":["));
        assert!(js.contains("\"tier_row_hit_rate\":["));
        let pretty = r.to_json().pretty();
        assert!(pretty.ends_with("}\n"));
    }

    #[test]
    fn summary_mentions_speedup() {
        let r = tiny_sweep();
        let s = r.summary();
        assert!(s.contains("scenarios on"));
        assert!(s.contains("geomean slowdown"));
    }
}
