//! Deterministic parallel scenario-sweep engine.
//!
//! The paper's pitch is *simulation throughput* (9280× over gem5); the
//! emulator must never be the experiment bottleneck. Design-space sweeps
//! — workload × policy × config × NVM-stall point — are embarrassingly
//! parallel: every [`Scenario`] is an independent platform run with its
//! own seed. This module fans a `Vec<Scenario>` across OS threads
//! (`std::thread::scope`, no dependencies) and aggregates a structured
//! [`SweepReport`] with machine-readable JSON emission
//! (`BENCH_sweep.json`) so the perf trajectory is tracked across PRs.
//!
//! **Determinism contract:** every run is a pure function of the
//! scenario's own data (config, seed, workload, ops) — never of thread
//! identity or completion order — and no state is shared between
//! scenarios, so a parallel sweep is bit-identical to running the same
//! scenarios serially — pinned by
//! [`SweepReport::deterministic_fingerprint`] and
//! `tests/sweep_determinism.rs` across thread counts.
//!
//! **Seeding:** [`Scenario::grid`] points deliberately share the base
//! seed, so compared points (policy A vs policy B on the same workload)
//! run the **identical trace** — deltas measure the design axis, not
//! trace randomness. Use [`Scenario::replicates`] when you want
//! decorrelated seeds (error bars) instead; it derives them from the
//! replicate index via [`derive_seed`].

pub mod report;

pub use report::{ScenarioResult, SweepReport};

use crate::config::{PolicyKind, SystemConfig};
use crate::platform::{run_multicore, Platform, RunOpts, WarmMulticore, WarmPlatform};
use crate::util::error::Result;
use crate::util::rng::splitmix64;
use crate::workload::Workload;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// One point of a design-space sweep: a workload on a full system
/// configuration (policy, scale, NVM stalls, epoch length… all live in
/// `cfg`).
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Unique label, e.g. `"505.mcf/hotness"` (used in reports and JSON).
    pub name: String,
    pub workload: Workload,
    pub cfg: SystemConfig,
    /// Memory operations to simulate (per core when `cores > 1`).
    pub ops: u64,
    /// Flush caches at the end (write-back volume, Fig 8 style).
    pub flush_at_end: bool,
    /// Core count axis: `1` runs the single-core platform (with its
    /// native reference pass); `> 1` runs a rate-style multicore scenario
    /// (`run_multicore`: that many copies of the workload, private
    /// L1/L2s, one shared link + HMMU) through the same batched pipeline.
    pub cores: usize,
}

impl Scenario {
    pub fn new(name: impl Into<String>, workload: Workload, cfg: SystemConfig, ops: u64) -> Self {
        Scenario {
            name: name.into(),
            workload,
            cfg,
            ops,
            flush_at_end: false,
            cores: 1,
        }
    }

    /// Run this scenario as a rate-style multicore run on `cores` cores.
    pub fn with_cores(mut self, cores: usize) -> Self {
        assert!(cores >= 1);
        self.cores = cores;
        self
    }

    /// Override the emulated NVM stall point (§III-F "arbitrary latency
    /// cycles") — the sweep axis the FPGA reconfigures per experiment.
    pub fn with_nvm_stalls(mut self, read_ns: u64, write_ns: u64) -> Self {
        self.cfg.nvm.read_stall_ns = read_ns;
        self.cfg.nvm.write_stall_ns = write_ns;
        self
    }

    /// Build the workload × policy grid from a base configuration.
    pub fn grid(
        workloads: &[Workload],
        policies: &[PolicyKind],
        base: &SystemConfig,
        ops: u64,
    ) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(workloads.len() * policies.len());
        for wl in workloads {
            for &policy in policies {
                let mut cfg = base.clone();
                cfg.policy = policy;
                out.push(Scenario::new(
                    format!("{}/{}", wl.name, policy.name()),
                    *wl,
                    cfg,
                    ops,
                ));
            }
        }
        out
    }

    /// Expand scenarios across NVM stall points, suffixing names with
    /// `@rd:wr`.
    pub fn stall_grid(scenarios: &[Scenario], stall_points: &[(u64, u64)]) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(scenarios.len() * stall_points.len());
        for sc in scenarios {
            for &(rd, wr) in stall_points {
                let mut s = sc.clone().with_nvm_stalls(rd, wr);
                s.name = format!("{}@{rd}:{wr}", sc.name);
                out.push(s);
            }
        }
        out
    }

    /// Expand scenarios across a tier-topology axis, suffixing names
    /// with `~<topology>` (e.g. `505.mcf/hotness~dram+pcm+xpoint`).
    /// Each topology rebuilds the scenario's tier stack via
    /// [`SystemConfig::with_tiers`]; the plain two-tier default keeps
    /// its unsuffixed name so existing series stay comparable.
    pub fn tier_grid(
        scenarios: &[Scenario],
        topologies: &[Vec<crate::config::MemTech>],
    ) -> Result<Vec<Scenario>> {
        let mut out = Vec::with_capacity(scenarios.len() * topologies.len());
        for sc in scenarios {
            for classes in topologies {
                let cfg = sc.cfg.clone().with_tiers(classes)?;
                let mut s = sc.clone();
                let label = cfg.topology_label();
                if label != sc.cfg.topology_label() {
                    s.name = format!("{}~{label}", sc.name);
                }
                s.cfg = cfg;
                out.push(s);
            }
        }
        Ok(out)
    }

    /// Expand scenarios across a fault-rate axis, suffixing names with
    /// `%<rber>` (e.g. `505.mcf/hotness%0.0001`). Each point sets the
    /// wear-driven raw bit error rate ([`crate::config::FaultConfig`]
    /// `rber_base`); `0.0` disables the fault layer and keeps the
    /// unsuffixed name, so healthy baselines stay comparable across
    /// series.
    pub fn fault_grid(scenarios: &[Scenario], rber_points: &[f64]) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(scenarios.len() * rber_points.len());
        for sc in scenarios {
            for &rber in rber_points {
                let mut s = sc.clone();
                s.cfg.fault.rber_base = rber;
                if rber > 0.0 {
                    s.name = format!("{}%{rber}", sc.name);
                }
                out.push(s);
            }
        }
        out
    }

    /// Expand scenarios across a PCIe link-fault axis, suffixing names
    /// with `%lber<rate>` (e.g. `505.mcf/hotness%lber1e-6`). Each point
    /// sets the TLP corruption rate ([`crate::config::FaultConfig`]
    /// `link_ber`); `0.0` keeps the healthy link and the unsuffixed
    /// name, mirroring [`Self::fault_grid`] so the two axes compose.
    pub fn link_fault_grid(scenarios: &[Scenario], ber_points: &[f64]) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(scenarios.len() * ber_points.len());
        for sc in scenarios {
            for &ber in ber_points {
                let mut s = sc.clone();
                s.cfg.fault.link_ber = ber;
                if ber > 0.0 {
                    s.name = format!("{}%lber{ber}", sc.name);
                }
                out.push(s);
            }
        }
        out
    }

    /// Expand scenarios across a core-count axis, suffixing names with
    /// `x<cores>` (e.g. `505.mcf/hotness x4` → `"505.mcf/hotnessx4"`).
    /// Entries with `1` keep the single-core platform path unsuffixed.
    pub fn cores_grid(scenarios: &[Scenario], cores: &[usize]) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(scenarios.len() * cores.len());
        for sc in scenarios {
            for &n in cores {
                let mut s = sc.clone().with_cores(n);
                if n > 1 {
                    s.name = format!("{}x{n}", sc.name);
                }
                out.push(s);
            }
        }
        out
    }

    /// Expand scenarios across a DRAM bank-count axis, suffixing names
    /// with `%bk<n>` (e.g. `505.mcf/hotness%bk8`). Each point sets
    /// [`crate::config::DramConfig`] `banks` — the banking-sensitivity
    /// frontier for row-buffer-aware stacks; `0` keeps the stack default
    /// and the unsuffixed name, mirroring [`Self::fault_grid`] so
    /// default-bank baselines stay comparable across series.
    pub fn banks_grid(scenarios: &[Scenario], bank_points: &[u32]) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(scenarios.len() * bank_points.len());
        for sc in scenarios {
            for &banks in bank_points {
                let mut s = sc.clone();
                if banks > 0 {
                    s.cfg.dram.banks = banks;
                    s.name = format!("{}%bk{banks}", sc.name);
                }
                out.push(s);
            }
        }
        out
    }

    /// `n` statistical replicates of each scenario, with distinct seeds
    /// derived from the replicate index (names suffixed `#k`). This is
    /// the opt-in path for decorrelated traces; plain grids share the
    /// base seed on purpose so compared points stay trace-identical.
    pub fn replicates(scenarios: &[Scenario], n: u64) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(scenarios.len() * n as usize);
        for sc in scenarios {
            for k in 0..n {
                let mut s = sc.clone();
                s.cfg.seed = derive_seed(sc.cfg.seed, k);
                s.name = format!("{}#{k}", sc.name);
                out.push(s);
            }
        }
        out
    }
}

/// Worker-thread count to use when the caller doesn't specify one: all
/// available cores (shared by the CLI, examples and benches).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Derive a decorrelated seed from a base seed and a replicate index
/// (pure function of `(base, index)`, so it is thread- and
/// order-independent). Used by [`Scenario::replicates`]; plain sweeps run
/// each scenario with the seed its config carries.
pub fn derive_seed(base: u64, index: u64) -> u64 {
    // Golden-ratio stride decorrelates neighbouring indices, then one
    // splitmix round scrambles; identical to seeding Xoshiro substreams.
    let mut s = base ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(index.wrapping_add(1));
    splitmix64(&mut s)
}

/// Run one scenario with the seed its config carries. The scenario is
/// the unit of parallelism, so the platform/native passes run serially
/// inside it — spawning the concurrent-pass helper here would
/// oversubscribe the CPU under a multi-threaded sweep and contaminate
/// the per-scenario wall clocks.
fn run_scenario(sc: &Scenario) -> Result<ScenarioResult> {
    let wall = Instant::now();
    let seed = sc.cfg.seed;
    let opts = RunOpts {
        ops: sc.ops,
        flush_at_end: sc.flush_at_end,
    };
    if sc.cores > 1 {
        // Rate-style multicore point: `cores` copies of the workload
        // sharing one HMMU. No native reference pass exists for this
        // shape, so the slowdown columns report 0 (the throughput metric
        // is the makespan / aggregate MIPS).
        let wls = vec![sc.workload; sc.cores];
        let report = run_multicore(sc.cfg.clone(), &wls, opts, None)?;
        return Ok(ScenarioResult::from_multicore(
            sc,
            seed,
            &report,
            wall.elapsed().as_nanos() as u64,
        ));
    }
    let report = Platform::new(sc.cfg.clone()).run_opts_serial(&sc.workload, opts)?;
    Ok(ScenarioResult::new(sc, seed, &report, wall.elapsed().as_nanos() as u64))
}

/// Fan `scenarios` across `threads` OS threads (clamped to the scenario
/// count; `1` = serial). Results come back in scenario order regardless
/// of which thread ran what, and are bit-identical across thread counts.
pub fn run_sweep(scenarios: &[Scenario], threads: usize) -> Result<SweepReport> {
    let n = scenarios.len();
    let threads = threads.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<ScenarioResult>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    let wall = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                // Dynamic work-stealing queue: one atomic fetch per
                // scenario, so long and short scenarios balance.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = run_scenario(&scenarios[i]);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    let wall_ns = wall.elapsed().as_nanos() as u64;

    collect_slots(scenarios, slots, threads, wall_ns)
}

/// Warm-state forked sweep options (`hymem sweep --warmup-ops N`).
#[derive(Clone, Debug, Default)]
pub struct ForkOpts {
    /// Warm-up prefix length in ops, paid **once per warm group** and
    /// forked across the group's scenarios. `0` = plain cold sweep.
    pub warmup_ops: u64,
    /// Directory for serialized warm checkpoints: hits skip the warm-up
    /// simulation entirely (the CI cache rides on this across runs).
    pub checkpoint_dir: Option<PathBuf>,
    /// Replay every scenario cold through the **same** warm+morph code
    /// path (fresh warm-up per scenario instead of a fork). The baseline
    /// the fork speedup and bit-identity pins are measured against.
    pub cold_replay: bool,
}

/// Group scenarios that can share one warm state: identical on every
/// axis **except** the fork axes (policy kind, emulated NVM stall
/// point). Seed, workload, topology, sizing and core count all stay in
/// the key, so only scenarios replaying the identical warm-up prefix
/// trace land together.
fn warm_group_key(sc: &Scenario) -> String {
    let mut cfg = sc.cfg.clone();
    cfg.policy = PolicyKind::Static;
    cfg.nvm.read_stall_ns = 0;
    cfg.nvm.write_stall_ns = 0;
    format!(
        "{:?}|{}|{}|{}|{}",
        cfg, sc.workload.name, sc.ops, sc.flush_at_end, sc.cores
    )
}

/// A group's warm state: the single-core platform engine or its
/// multicore counterpart, chosen by the leader's core count. Shared by
/// reference across the worker pool in phase B of [`run_sweep_forked`]
/// (both engines are plain data behind `Send + Sync` policy engines).
enum Warm {
    Single(WarmPlatform),
    Multi(WarmMulticore),
}

/// Sizing for a group leader's warm run.
fn leader_opts(leader: &Scenario) -> RunOpts {
    RunOpts {
        ops: leader.ops,
        flush_at_end: leader.flush_at_end,
    }
}

/// Simulate a fresh warm-up on the leader's config — no checkpoint
/// cache. This is both the cold-replay per-member path and the cache-miss
/// path of [`obtain_warm_group`], so the two modes share one
/// construction and stay bit-identical by construction.
///
/// The warm prefix runs under the **leader's** full config (its policy
/// included). A fork whose policy differs from the leader's inherits the
/// leader-warmed table layout; that is the checkpoint-fork methodology,
/// pinned as such by `tests/checkpoint_fork.rs`. Multicore groups warm
/// `warmup_ops × cores` interleaved ops (the same per-core average as the
/// single-core budget).
fn fresh_warm(leader: &Scenario, warmup_ops: u64) -> Result<Warm> {
    let opts = leader_opts(leader);
    if leader.cores > 1 {
        let wls = vec![leader.workload; leader.cores];
        let mut w = WarmMulticore::new(leader.cfg.clone(), &wls, opts)?;
        w.warm_up(warmup_ops.saturating_mul(leader.cores as u64));
        Ok(Warm::Multi(w))
    } else {
        let mut w = WarmPlatform::new(leader.cfg.clone(), &leader.workload, opts);
        w.warm_up(warmup_ops);
        Ok(Warm::Single(w))
    }
}

/// Produce a group's warm state, consulting the checkpoint cache when
/// one is configured.
fn obtain_warm_group(leader: &Scenario, fork: &ForkOpts) -> Result<Warm> {
    if leader.cores > 1 {
        obtain_warm_multicore(leader, leader_opts(leader), fork).map(Warm::Multi)
    } else {
        Ok(Warm::Single(obtain_warm(leader, leader_opts(leader), fork)))
    }
}

/// Fork `warm` at the member's config and run it to completion, shaping
/// the report into the member's [`ScenarioResult`] row. `wall` is the
/// member's wall-clock origin: the fork point in forked mode, the top of
/// the member's own warm-up in cold-replay mode.
fn run_forked_member(sc: &Scenario, warm: &Warm, wall: Instant) -> Result<ScenarioResult> {
    match warm {
        Warm::Single(w) => {
            let report = w.fork(&sc.cfg).run_to_completion()?;
            Ok(ScenarioResult::new(
                sc,
                sc.cfg.seed,
                &report,
                wall.elapsed().as_nanos() as u64,
            ))
        }
        Warm::Multi(w) => {
            let report = w.fork(&sc.cfg).run_to_completion()?;
            Ok(ScenarioResult::from_multicore(
                sc,
                sc.cfg.seed,
                &report,
                wall.elapsed().as_nanos() as u64,
            ))
        }
    }
}

/// Produce the group's warm platform: checkpoint-cache hit (deserialize,
/// skip the warm-up simulation), else simulate the warm-up and populate
/// the cache. Cache problems degrade to a fresh warm-up, never an error.
fn obtain_warm(leader: &Scenario, opts: RunOpts, fork: &ForkOpts) -> WarmPlatform {
    let path = fork.checkpoint_dir.as_ref().map(|dir| {
        let key = WarmPlatform::cache_key(&leader.cfg, &leader.workload, opts, fork.warmup_ops);
        dir.join(format!("warm-{key:016x}.ckpt"))
    });
    if let Some(p) = &path {
        if let Ok(bytes) = std::fs::read(p) {
            match WarmPlatform::load(&bytes, leader.cfg.clone(), &leader.workload, opts) {
                Ok(wp) => return wp,
                Err(e) => eprintln!("warning: stale checkpoint {}: {e}", p.display()),
            }
        }
    }
    let mut wp = WarmPlatform::new(leader.cfg.clone(), &leader.workload, opts);
    wp.warm_up(fork.warmup_ops);
    if let Some(p) = &path {
        let write = std::fs::create_dir_all(p.parent().unwrap_or(std::path::Path::new(".")))
            .and_then(|()| std::fs::write(p, wp.save()));
        if let Err(e) = write {
            eprintln!("warning: cannot cache checkpoint {}: {e}", p.display());
        }
    }
    wp
}

/// Multicore counterpart of [`obtain_warm`]: same cache discipline
/// (stale or unwritable checkpoints degrade to a fresh warm-up, never an
/// error), keyed with the core count so single- and multicore groups
/// never collide on a checkpoint file.
fn obtain_warm_multicore(
    leader: &Scenario,
    opts: RunOpts,
    fork: &ForkOpts,
) -> Result<WarmMulticore> {
    let wls = vec![leader.workload; leader.cores];
    let path = fork.checkpoint_dir.as_ref().map(|dir| {
        let key = WarmMulticore::cache_key(&leader.cfg, &wls, opts, fork.warmup_ops);
        dir.join(format!("warm-{key:016x}.ckpt"))
    });
    if let Some(p) = &path {
        if let Ok(bytes) = std::fs::read(p) {
            match WarmMulticore::load(&bytes, leader.cfg.clone(), &wls, opts) {
                Ok(wm) => return Ok(wm),
                Err(e) => eprintln!("warning: stale checkpoint {}: {e}", p.display()),
            }
        }
    }
    let mut wm = WarmMulticore::new(leader.cfg.clone(), &wls, opts)?;
    wm.warm_up(fork.warmup_ops.saturating_mul(leader.cores as u64));
    if let Some(p) = &path {
        let write = std::fs::create_dir_all(p.parent().unwrap_or(std::path::Path::new(".")))
            .and_then(|()| std::fs::write(p, wm.save()));
        if let Err(e) = write {
            eprintln!("warning: cannot cache checkpoint {}: {e}", p.display());
        }
    }
    Ok(wm)
}

/// Warm-state forked sweep, in two phases: **phase A** groups scenarios
/// by [`warm_group_key`] and fans the group warm-ups across `threads`
/// workers (each group's warm-up runs once); **phase B** fans *every
/// scenario* across the workers, forking from its group's shared warm
/// state — so a sweep of 2 groups × 16 members keeps all N threads
/// busy instead of 2. Results come back in scenario order and are
/// bit-identical across thread counts — and bit-identical to
/// `cold_replay` mode, which replays the identical warm+morph path per
/// scenario (`tests/checkpoint_fork.rs` pins both). Multicore rows warm
/// and fork through [`WarmMulticore`]; `warmup_ops == 0` degrades to the
/// classic cold sweep with a per-row stderr warning.
pub fn run_sweep_forked(
    scenarios: &[Scenario],
    threads: usize,
    fork: &ForkOpts,
) -> Result<SweepReport> {
    let n = scenarios.len();
    if fork.warmup_ops == 0 {
        // Satellite contract: never silently degrade a row to the cold
        // path — name the row and the reason on stderr.
        for sc in scenarios {
            eprintln!(
                "warning: scenario {:?} falls back to the classic cold path: --warmup-ops is 0",
                sc.name
            );
        }
        return run_sweep(scenarios, threads);
    }

    let mut groups: Vec<(String, Vec<usize>)> = Vec::new();
    let mut group_of = vec![0usize; n];
    for (i, sc) in scenarios.iter().enumerate() {
        let key = warm_group_key(sc);
        let gi = match groups.iter().position(|(k, _)| *k == key) {
            Some(gi) => {
                groups[gi].1.push(i);
                gi
            }
            None => {
                groups.push((key, vec![i]));
                groups.len() - 1
            }
        };
        group_of[i] = gi;
    }
    let g = groups.len();
    let workers = threads.max(1).min(n.max(1));
    let slots: Vec<Mutex<Option<Result<ScenarioResult>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let wall = Instant::now();

    if fork.cold_replay {
        // Baseline mode: every member replays its own warm-up through
        // the identical warm+morph construction, fanned member-wise.
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let leader = &scenarios[groups[group_of[i]].1[0]];
                    let member_wall = Instant::now();
                    let result = fresh_warm(leader, fork.warmup_ops)
                        .and_then(|w| run_forked_member(&scenarios[i], &w, member_wall));
                    *slots[i].lock().unwrap() = Some(result);
                });
            }
        });
        let wall_ns = wall.elapsed().as_nanos() as u64;
        return collect_slots(scenarios, slots, workers, wall_ns);
    }

    // Phase A: one warm state per group, fanned across the workers.
    // Errors are carried as strings so every member of a failed group
    // can report the same cause.
    let warm_slots: Vec<Mutex<Option<std::result::Result<Warm, String>>>> =
        (0..g).map(|_| Mutex::new(None)).collect();
    {
        let next = AtomicUsize::new(0);
        let warm_workers = threads.max(1).min(g.max(1));
        std::thread::scope(|s| {
            for _ in 0..warm_workers {
                s.spawn(|| loop {
                    let gi = next.fetch_add(1, Ordering::Relaxed);
                    if gi >= g {
                        break;
                    }
                    let leader = &scenarios[groups[gi].1[0]];
                    let warm = obtain_warm_group(leader, fork).map_err(|e| e.to_string());
                    *warm_slots[gi].lock().unwrap() = Some(warm);
                });
            }
        });
    }
    let warms: Vec<std::result::Result<Warm, String>> = warm_slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("phase A fills every group"))
        .collect();

    // Phase B: fork every member from its group's shared warm state,
    // fanned member-wise across the workers.
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = match &warms[group_of[i]] {
                    Ok(w) => run_forked_member(&scenarios[i], w, Instant::now()),
                    Err(e) => Err(crate::anyhow!("warm-up failed: {e}")),
                };
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    let wall_ns = wall.elapsed().as_nanos() as u64;

    collect_slots(scenarios, slots, workers, wall_ns)
}

fn collect_slots(
    scenarios: &[Scenario],
    slots: Vec<Mutex<Option<Result<ScenarioResult>>>>,
    threads: usize,
    wall_ns: u64,
) -> Result<SweepReport> {
    let mut results = Vec::with_capacity(scenarios.len());
    for (i, slot) in slots.into_iter().enumerate() {
        match slot.into_inner().unwrap() {
            Some(Ok(r)) => results.push(r),
            Some(Err(e)) => return Err(e.context(format!("scenario {:?}", scenarios[i].name))),
            None => crate::bail!("scenario {:?} never ran (worker died?)", scenarios[i].name),
        }
    }
    Ok(SweepReport::new(threads, wall_ns, results))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec;

    fn small_cfg() -> SystemConfig {
        SystemConfig::default_scaled(64)
    }

    #[test]
    fn grid_names_are_unique() {
        let wls = [
            spec::by_name("505.mcf").unwrap(),
            spec::by_name("557.xz").unwrap(),
        ];
        let scenarios = Scenario::grid(
            &wls,
            &[PolicyKind::Static, PolicyKind::Hotness],
            &small_cfg(),
            1000,
        );
        assert_eq!(scenarios.len(), 4);
        let mut names: Vec<&str> = scenarios.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 4);
        assert!(scenarios.iter().any(|s| s.name == "505.mcf/hotness"));
    }

    #[test]
    fn stall_grid_expands_and_overrides() {
        let wl = spec::by_name("505.mcf").unwrap();
        let base = vec![Scenario::new("mcf/static", wl, small_cfg(), 1000)];
        let grid = Scenario::stall_grid(&base, &[(50, 225), (200, 900)]);
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[0].name, "mcf/static@50:225");
        assert_eq!(grid[1].cfg.nvm.read_stall_ns, 200);
        assert_eq!(grid[1].cfg.nvm.write_stall_ns, 900);
    }

    #[test]
    fn tier_grid_expands_and_fingerprints_topology() {
        use crate::config::MemTech;
        let wl = spec::by_name("505.mcf").unwrap();
        let base = vec![Scenario::new("mcf/static", wl, small_cfg(), 1000)];
        let grid = Scenario::tier_grid(
            &base,
            &[
                vec![MemTech::Dram, MemTech::Xpoint3D],
                vec![MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D],
            ],
        )
        .unwrap();
        assert_eq!(grid.len(), 2);
        // The default pair keeps its unsuffixed name; the deep stack is
        // labeled.
        assert_eq!(grid[0].name, "mcf/static");
        assert_eq!(grid[1].name, "mcf/static~dram+pcm+xpoint");
        assert_eq!(grid[1].cfg.tier_count(), 3);

        // A three-tier scenario runs end to end through the sweep, with
        // the topology in the fingerprint and per-tier columns populated.
        let r = run_sweep(&grid[1..], 1).unwrap();
        let fp = r.deterministic_fingerprint();
        assert!(fp.contains("tiers=dram+pcm+xpoint"), "{fp}");
        assert_eq!(r.scenarios[0].tier_reads.len(), 3);
        assert_eq!(r.scenarios[0].tier_residency.len(), 3);
        assert_eq!(r.scenarios[0].tier_energy_mj.len(), 3);
        let js = r.to_json().render();
        assert!(js.contains("\"topology\":\"dram+pcm+xpoint\""), "{js}");
        assert!(js.contains("\"tier_wear\":["), "{js}");
    }

    #[test]
    fn fault_grid_expands_and_suffixes() {
        let wl = spec::by_name("505.mcf").unwrap();
        let base = vec![Scenario::new("mcf/static", wl, small_cfg(), 1000)];
        let grid = Scenario::fault_grid(&base, &[0.0, 1e-4]);
        assert_eq!(grid.len(), 2);
        // The healthy point keeps its unsuffixed name and a disabled
        // fault layer; the faulted point is labeled with its rate.
        assert_eq!(grid[0].name, "mcf/static");
        assert!(!grid[0].cfg.fault.enabled());
        assert_eq!(grid[1].name, "mcf/static%0.0001");
        assert_eq!(grid[1].cfg.fault.rber_base, 1e-4);
        assert!(grid[1].cfg.fault.mem_enabled());
    }

    #[test]
    fn link_fault_grid_expands_and_suffixes() {
        let wl = spec::by_name("505.mcf").unwrap();
        let base = vec![Scenario::new("mcf/static", wl, small_cfg(), 1000)];
        let grid = Scenario::link_fault_grid(&base, &[0.0, 1e-6]);
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[0].name, "mcf/static");
        assert!(!grid[0].cfg.fault.enabled());
        assert_eq!(grid[1].name, "mcf/static%lber0.000001");
        assert_eq!(grid[1].cfg.fault.link_ber, 1e-6);
        assert!(grid[1].cfg.fault.link_enabled());
        // The two fault axes compose: rber × link-ber.
        let both = Scenario::fault_grid(&grid, &[0.0, 1e-4]);
        assert_eq!(both.len(), 4);
        assert_eq!(both[3].name, "mcf/static%lber0.000001%0.0001");
    }

    #[test]
    fn cores_grid_expands_and_suffixes() {
        let wl = spec::by_name("505.mcf").unwrap();
        let base = vec![Scenario::new("mcf/static", wl, small_cfg(), 1000)];
        let grid = Scenario::cores_grid(&base, &[1, 4]);
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[0].name, "mcf/static");
        assert_eq!(grid[0].cores, 1);
        assert_eq!(grid[1].name, "mcf/staticx4");
        assert_eq!(grid[1].cores, 4);
    }

    #[test]
    fn banks_grid_expands_and_suffixes() {
        let wl = spec::by_name("505.mcf").unwrap();
        let base = vec![Scenario::new("mcf/static", wl, small_cfg(), 1000)];
        let default_banks = base[0].cfg.dram.banks;
        let grid = Scenario::banks_grid(&base, &[0, 8, 32]);
        assert_eq!(grid.len(), 3);
        // The 0 point keeps the stack default and the unsuffixed name.
        assert_eq!(grid[0].name, "mcf/static");
        assert_eq!(grid[0].cfg.dram.banks, default_banks);
        assert_eq!(grid[1].name, "mcf/static%bk8");
        assert_eq!(grid[1].cfg.dram.banks, 8);
        assert_eq!(grid[2].name, "mcf/static%bk32");
        assert_eq!(grid[2].cfg.dram.banks, 32);
        // The axis composes with the others (suffix order is stable).
        let both = Scenario::fault_grid(&grid[1..2], &[1e-4]);
        assert_eq!(both[0].name, "mcf/static%bk8%0.0001");
    }

    #[test]
    fn multicore_scenario_runs_through_sweep() {
        let wl = spec::by_name("541.leela").unwrap();
        let scenarios = vec![
            Scenario::new("leela", wl, small_cfg(), 3_000),
            Scenario::new("leelax2", wl, small_cfg(), 3_000).with_cores(2),
        ];
        let r = run_sweep(&scenarios, 2).unwrap();
        assert_eq!(r.scenarios.len(), 2);
        // Single-core row has a native reference; the multicore row
        // reports makespan with zeroed native columns.
        assert!(r.scenarios[0].slowdown > 1.0);
        assert_eq!(r.scenarios[1].cores, 2);
        assert_eq!(r.scenarios[1].slowdown, 0.0);
        assert!(r.scenarios[1].platform_time_ns > 0);
        assert!(r.scenarios[1].host_read_bytes > 0);
        // Geomean skips the slowdown-less multicore rows.
        assert!((r.geomean_slowdown - r.scenarios[0].slowdown).abs() < 1e-9);
    }

    #[test]
    fn derived_seeds_decorrelate() {
        let a = derive_seed(0x5EED, 0);
        let b = derive_seed(0x5EED, 1);
        let c = derive_seed(0x5EED + 1, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        // And are pure functions of (base, index).
        assert_eq!(a, derive_seed(0x5EED, 0));
    }

    #[test]
    fn grid_shares_seed_replicates_derive() {
        // Controlled comparison: grid points share the base seed so the
        // compared policies see the identical trace.
        let wl = spec::by_name("505.mcf").unwrap();
        let grid = Scenario::grid(
            &[wl],
            &[PolicyKind::Static, PolicyKind::Hotness],
            &small_cfg(),
            1000,
        );
        assert_eq!(grid[0].cfg.seed, grid[1].cfg.seed);
        // Error bars: replicates get distinct derived seeds and names.
        let reps = Scenario::replicates(&grid[..1], 3);
        assert_eq!(reps.len(), 3);
        assert_eq!(reps[0].name, "505.mcf/static#0");
        assert_ne!(reps[0].cfg.seed, reps[1].cfg.seed);
        assert_ne!(reps[1].cfg.seed, reps[2].cfg.seed);
        assert_eq!(reps[2].cfg.seed, derive_seed(grid[0].cfg.seed, 2));
    }

    #[test]
    fn single_scenario_sweep_runs() {
        let wl = spec::by_name("557.xz").unwrap();
        let scenarios = vec![Scenario::new("557.xz/static", wl, small_cfg(), 5_000)];
        let r = run_sweep(&scenarios, 4).unwrap();
        assert_eq!(r.scenarios.len(), 1);
        assert_eq!(r.threads, 1, "threads clamp to scenario count");
        assert!(r.scenarios[0].platform_time_ns > 0);
        assert!(r.scenarios[0].slowdown > 1.0);
    }
}
