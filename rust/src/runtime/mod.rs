//! PJRT runtime — loads the AOT-compiled policy artifacts (HLO text
//! emitted by `python/compile/aot.py`) and exposes them to the HMMU as a
//! [`HotnessEngine`].
//!
//! Python runs only at build time (`make artifacts`); at run time this
//! module compiles the HLO once on the PJRT CPU client and executes it
//! from the epoch path. When no artifacts are present, callers fall back
//! to the bit-compatible [`NativeHotnessEngine`]
//! (`hmmu::policy::NativeHotnessEngine`); an integration test cross-checks
//! the two engines.
//!
//! The PJRT path requires the vendored `xla` crate and is compiled only
//! under the **`xla` feature**. The default (offline, dependency-free)
//! build ships API-compatible stubs whose loaders fail cleanly, so every
//! call site — CLI, examples, integration tests — degrades to the native
//! engine without `cfg` noise of its own.

use crate::hmmu::policy::HotnessEngine;
use std::path::{Path, PathBuf};

/// Page-count variants emitted by `aot.py` (padded executions pick the
/// smallest variant that fits).
pub const ARTIFACT_SIZES: [usize; 4] = [4096, 16384, 65536, 262144];

/// Default artifacts directory (relative to the repo root / CWD).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("HYMEM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Path of the hotness policy-step artifact for `pages`.
pub fn hotness_artifact_path(dir: &Path, pages: usize) -> PathBuf {
    dir.join(format!("hotness_step_{pages}.hlo.txt"))
}

/// Path of the latency-model artifact (batch size fixed at AOT time).
pub fn latency_artifact_path(dir: &Path, batch: usize) -> PathBuf {
    dir.join(format!("latency_model_{batch}.hlo.txt"))
}

#[cfg(feature = "xla")]
mod pjrt {
    use super::{default_artifact_dir, hotness_artifact_path, latency_artifact_path, ARTIFACT_SIZES};
    // The offline image ships no vendored `xla` crate; the stub mirrors
    // its API surface with loaders that fail cleanly, so this whole
    // module compiles, lints and runs (degrading to the native engine)
    // under `--features xla`. Once the crate is vendored, delete this
    // alias (and `src/xla_stub.rs`) to bind the real thing.
    use crate::xla_stub as xla;
    use crate::hmmu::policy::{HotnessEngine, PolicyStepOutput};
    use crate::util::error::{Context, Result};
    use crate::{anyhow, bail};
    use std::path::{Path, PathBuf};

    /// A compiled HLO module on the PJRT CPU client.
    pub struct HloExecutable {
        exe: xla::PjRtLoadedExecutable,
        pub path: PathBuf,
    }

    impl HloExecutable {
        /// Load HLO **text** (see aot_recipe: text, not serialized proto)
        /// and compile it.
        pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path:?}: {e}"))?;
            Ok(HloExecutable {
                exe,
                path: path.to_path_buf(),
            })
        }

        /// Execute with f32 vector inputs; returns the output tuple's
        /// members as f32 vectors.
        pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> =
                inputs.iter().map(|v| xla::Literal::vec1(v)).collect();
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {:?}: {e}", self.path))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result: {e}"))?;
            let parts = out
                .to_tuple()
                .map_err(|e| anyhow!("untupling result: {e}"))?;
            parts
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}")))
                .collect()
        }
    }

    /// The XLA-backed hotness engine (drop-in for `NativeHotnessEngine`).
    pub struct XlaHotnessEngine {
        _client: xla::PjRtClient,
        /// (pages, executable), ascending by pages.
        variants: Vec<(usize, HloExecutable)>,
        /// Executions performed (for reports).
        pub invocations: u64,
    }

    impl XlaHotnessEngine {
        /// Load every available size variant from `dir`. Errors if none
        /// exist.
        pub fn load(dir: &Path) -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
            let mut variants = Vec::new();
            for &n in &ARTIFACT_SIZES {
                let path = hotness_artifact_path(dir, n);
                if path.exists() {
                    variants.push((
                        n,
                        HloExecutable::load(&client, &path)
                            .with_context(|| format!("loading variant {n}"))?,
                    ));
                }
            }
            if variants.is_empty() {
                bail!("no hotness_step_*.hlo.txt artifacts in {dir:?}; run `make artifacts`");
            }
            Ok(XlaHotnessEngine {
                _client: client,
                variants,
                invocations: 0,
            })
        }

        /// Load from the default directory.
        pub fn load_default() -> Result<Self> {
            Self::load(&default_artifact_dir())
        }

        fn pick_variant(&self, n: usize) -> Option<&(usize, HloExecutable)> {
            self.variants.iter().find(|(size, _)| *size >= n)
        }

        pub fn variant_sizes(&self) -> Vec<usize> {
            self.variants.iter().map(|(n, _)| *n).collect()
        }
    }

    impl HotnessEngine for XlaHotnessEngine {
        fn step(
            &mut self,
            reads: &[f32],
            writes: &[f32],
            prev: &[f32],
            in_dram: &[f32],
        ) -> PolicyStepOutput {
            let n = reads.len();
            let (size, exe) = self
                .pick_variant(n)
                .unwrap_or_else(|| self.variants.last().unwrap());
            let size = *size;
            assert!(
                n <= size,
                "page count {n} exceeds largest artifact variant {size}; \
                 re-run aot.py with a larger size"
            );
            // Pad to the variant size with zero counters and in_dram=1;
            // padding never escapes because outputs truncate back to `n`.
            let mut r = reads.to_vec();
            let mut w = writes.to_vec();
            let mut p = prev.to_vec();
            let mut d = in_dram.to_vec();
            r.resize(size, 0.0);
            w.resize(size, 0.0);
            p.resize(size, 0.0);
            d.resize(size, 1.0);

            let outs = exe
                .run_f32(&[&r, &w, &p, &d])
                .expect("policy-step execution failed");
            assert_eq!(outs.len(), 3, "policy step must return 3 arrays");
            self.invocations += 1;
            let mut hotness = outs[0].clone();
            let mut promote = outs[1].clone();
            let mut demote = outs[2].clone();
            hotness.truncate(n);
            promote.truncate(n);
            demote.truncate(n);
            PolicyStepOutput {
                hotness,
                promote_score: promote,
                demote_score: demote,
            }
        }

        fn label(&self) -> &'static str {
            "xla-aot"
        }
    }

    /// Batched latency-model runner (second artifact; used by the
    /// `calibrate` CLI path to estimate request latencies for Table I
    /// technologies).
    pub struct XlaLatencyModel {
        _client: xla::PjRtClient,
        exe: HloExecutable,
        pub batch: usize,
    }

    impl XlaLatencyModel {
        pub fn load(dir: &Path, batch: usize) -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
            let path = latency_artifact_path(dir, batch);
            let exe = HloExecutable::load(&client, &path)?;
            Ok(XlaLatencyModel {
                _client: client,
                exe,
                batch,
            })
        }

        /// Estimate per-request latencies.
        ///
        /// Inputs (each `batch`-long): `is_nvm` (0/1), `is_write` (0/1),
        /// `queue_depth` (requests ahead). Scalars are broadcast at trace
        /// time; the base latencies are baked into the artifact from the
        /// DRAM calibration (§III-F).
        pub fn estimate(
            &mut self,
            is_nvm: &[f32],
            is_write: &[f32],
            queue_depth: &[f32],
        ) -> Result<Vec<f32>> {
            assert_eq!(is_nvm.len(), self.batch);
            let outs = self.exe.run_f32(&[is_nvm, is_write, queue_depth])?;
            Ok(outs.into_iter().next().unwrap())
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{HloExecutable, XlaHotnessEngine, XlaLatencyModel};

#[cfg(not(feature = "xla"))]
mod stub {
    use super::default_artifact_dir;
    use crate::bail;
    use crate::hmmu::policy::{HotnessEngine, NativeHotnessEngine, PolicyStepOutput};
    use crate::util::error::Result;
    use std::path::Path;

    /// Stub for the PJRT hotness engine: the loaders fail with the same
    /// actionable message as a missing-artifact error, so callers fall
    /// back to the native engine exactly as they would offline.
    pub struct XlaHotnessEngine {
        pub invocations: u64,
    }

    impl XlaHotnessEngine {
        pub fn load(dir: &Path) -> Result<Self> {
            bail!(
                "PJRT runtime disabled (built without the `xla` feature); \
                 cannot load artifacts from {dir:?} — rebuild with \
                 `--features xla` and run `make artifacts`"
            )
        }

        pub fn load_default() -> Result<Self> {
            Self::load(&default_artifact_dir())
        }

        pub fn variant_sizes(&self) -> Vec<usize> {
            Vec::new()
        }
    }

    impl HotnessEngine for XlaHotnessEngine {
        fn step(
            &mut self,
            reads: &[f32],
            writes: &[f32],
            prev: &[f32],
            in_dram: &[f32],
        ) -> PolicyStepOutput {
            // Unreachable in practice (`load` never succeeds); delegate to
            // the bit-compatible native math for safety.
            self.invocations += 1;
            NativeHotnessEngine.step(reads, writes, prev, in_dram)
        }

        fn label(&self) -> &'static str {
            "xla-aot"
        }
    }

    /// Stub for the PJRT latency model (see [`XlaHotnessEngine`]).
    pub struct XlaLatencyModel {
        pub batch: usize,
    }

    impl XlaLatencyModel {
        pub fn load(_dir: &Path, _batch: usize) -> Result<Self> {
            bail!(
                "PJRT runtime disabled (built without the `xla` feature); \
                 rebuild with `--features xla` and run `make artifacts`"
            )
        }

        pub fn estimate(
            &mut self,
            _is_nvm: &[f32],
            _is_write: &[f32],
            _queue_depth: &[f32],
        ) -> Result<Vec<f32>> {
            bail!("PJRT runtime disabled (built without the `xla` feature)")
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{XlaHotnessEngine, XlaLatencyModel};

/// Convenience: build the best available engine — XLA artifacts when
/// present, native fallback otherwise. Returns the engine and its label.
pub fn best_engine() -> (Box<dyn HotnessEngine>, &'static str) {
    match XlaHotnessEngine::load_default() {
        Ok(e) => (Box::new(e), "xla-aot"),
        Err(_) => (
            Box::new(crate::hmmu::policy::NativeHotnessEngine),
            "native",
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_paths() {
        let d = Path::new("artifacts");
        assert_eq!(
            hotness_artifact_path(d, 4096).to_str().unwrap(),
            "artifacts/hotness_step_4096.hlo.txt"
        );
        assert_eq!(
            latency_artifact_path(d, 1024).to_str().unwrap(),
            "artifacts/latency_model_1024.hlo.txt"
        );
    }

    #[test]
    fn missing_artifacts_error_is_clean() {
        match XlaHotnessEngine::load(Path::new("/nonexistent-dir")) {
            Ok(_) => panic!("expected error"),
            Err(err) => assert!(err.to_string().contains("make artifacts")),
        }
    }

    #[test]
    fn best_engine_always_returns_something() {
        let (_e, label) = best_engine();
        assert!(label == "xla-aot" || label == "native");
    }
}
