//! `hymem-audit` — walk a source tree and enforce the repo invariants
//! (see [`hymem::audit`] for the rule set and exemption syntax).
//!
//! Usage: `cargo run --bin hymem-audit -- rust/src` (from the repo
//! root) or `cargo run --bin hymem-audit -- src` (from `rust/`). Exit
//! codes: 0 clean, 1 findings, 2 usage/IO error.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let Some(arg) = std::env::args().nth(1) else {
        eprintln!("usage: hymem-audit <src-root>");
        return ExitCode::from(2);
    };
    let mut root = PathBuf::from(&arg);
    if !root.is_dir() {
        // Tolerate a repo-root-relative `rust/src` argument when the
        // working directory is already the crate (e.g. under CI's
        // `working-directory: rust`).
        if let Some(tail) = arg.strip_prefix("rust/") {
            let alt = Path::new(env!("CARGO_MANIFEST_DIR")).join(tail);
            if alt.is_dir() {
                root = alt;
            }
        }
    }
    if !root.is_dir() {
        eprintln!("hymem-audit: {arg}: not a directory");
        return ExitCode::from(2);
    }
    match hymem::audit::audit_tree(&root) {
        Ok(findings) if findings.is_empty() => {
            println!("hymem-audit: clean ({arg})");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!("hymem-audit: {} finding(s)", findings.len());
            ExitCode::from(1)
        }
        Err(e) => {
            eprintln!("hymem-audit: {arg}: {e}");
            ExitCode::from(2)
        }
    }
}
