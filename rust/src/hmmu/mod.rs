//! The Hybrid Memory Management Unit — the paper's contribution (Fig 2).
//!
//! Request flow, mirroring the paper's workflow:
//!
//! ```text
//! PCIe RX → HDR FIFO → control pipeline (decode → policy → route)
//!        → { tier-0 MC | tier-1 MC | … | DMA-conflict redirect }
//!        → tag-matching in-order completion → PCIe TX
//! ```
//!
//! plus the DMA engine migrating pages between any two tiers under the
//! control of the epoch policy, and performance counters on everything.
//! The memory substrate is an N-tier stack ([`crate::config::TierSpec`]
//! rank order, one `MemoryController<TierDevice>` per rank); the paper's
//! DRAM/NVM pair is the two-tier default and stays bit-identical.
//!
//! The HMMU is deliberately independent of the PCIe link for **demand
//! traffic**: it consumes requests with arrival timestamps and produces
//! completion timestamps, and the platform wraps it with the link model.
//! The one exception is the *host-managed* fidelity scenario
//! (`HmmuConfig::host_managed_dma`): there, migration DMA is performed by
//! the host, so [`Hmmu::access_linked`] threads an optional [`PcieLink`]
//! handle down to the epoch path and every migrated block crosses the
//! link — contending with demand traffic for wire time and credits
//! (`pcie_dma_bytes` / `dma_link_stalls` count it). The paper's
//! device-side DMA (the default) never touches the link.

pub mod counters;
pub mod dma;
pub mod policy;
pub mod redirection;
pub mod tags;

pub use counters::HmmuCounters;
pub use dma::{DmaEngine, DmaRoute};
pub use policy::{build_policy, HotnessEngine, PlacementPolicy, PolicyImpl, PolicyView};
pub use redirection::{Device, Mapping, RedirectionTable, TierId};
pub use tags::TagMatcher;

use crate::alloc::HintStore;
use crate::config::{SystemConfig, TierSpec};
use crate::mem::{AccessKind, MemoryController, TierDevice};
use crate::pcie::PcieLink;
use crate::sim::{Clock, Time};
use crate::util::codec::{check_len, CodecState, Decoder, Encoder};
use crate::util::error::Result;
use crate::util::rng::{splitmix64, Xoshiro256};

/// Fixed-capacity ring of outstanding-response release times — the HDR
/// FIFO occupancy model. §Perf: replaces a per-request `VecDeque` (which
/// reallocated and bounds-checked on the hot path) with one boxed slice
/// allocated at construction; push/pop are two or three arithmetic ops.
/// Entries drain in push order (hardware FIFO): [`Self::push_back`]
/// clamps each release to be ≥ the previously pushed one, so the front is
/// always the earliest. For the demand path the clamp is a no-op (the tag
/// matcher's in-order drain already makes release times monotone); it
/// matters when DMA migration traffic — whose completions are computed at
/// the epoch boundary, ahead of later demand requests — shares the FIFO
/// under `HmmuConfig::dma_hdr_occupancy`.
#[derive(Clone, Debug)]
struct ReleaseRing {
    buf: Box<[Time]>,
    head: usize,
    len: usize,
    /// Most recently pushed (clamped) release.
    last: Time,
}

impl ReleaseRing {
    fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ReleaseRing {
            buf: vec![0; capacity].into_boxed_slice(),
            head: 0,
            len: 0,
            last: 0,
        }
    }

    #[inline]
    fn is_full(&self) -> bool {
        self.len == self.buf.len()
    }

    #[inline]
    fn front(&self) -> Option<Time> {
        if self.len == 0 {
            None
        } else {
            Some(self.buf[self.head])
        }
    }

    #[inline]
    fn pop_front(&mut self) {
        debug_assert!(self.len > 0);
        self.head += 1;
        if self.head == self.buf.len() {
            self.head = 0;
        }
        self.len -= 1;
    }

    #[inline]
    fn push_back(&mut self, t: Time) {
        debug_assert!(!self.is_full(), "HDR occupancy ring overflow");
        let t = t.max(self.last);
        self.last = t;
        let mut i = self.head + self.len;
        if i >= self.buf.len() {
            i -= self.buf.len();
        }
        self.buf[i] = t;
        self.len += 1;
    }
}

/// Scratch columns for the host-managed DMA completion stream (one
/// migrated block's max_payload chunks crossed device→host as a single
/// [`PcieLink::send_block_to_host`] column). Recycled across transfers —
/// steady state allocates nothing.
#[derive(Clone, Default)]
struct CplScratch {
    payloads: Vec<u32>,
    times: Vec<Time>,
    arrivals: Vec<Time>,
}

/// Deferred hotness/tier-access accounting for one trace block (§Perf).
/// While a block drains, the per-request `policy.record_access` +
/// `counters.record_tier_access` calls — pure counter additions that no
/// reader consults until the next epoch boundary — are queued here and
/// flushed in one pass at block end (or just before an epoch fires
/// mid-block). Entry order is preserved, so the flush is bit-identical to
/// immediate recording; per-op callers (no block active) still record
/// immediately.
#[derive(Clone, Default)]
struct PendingAccesses {
    pages: Vec<u64>,
    /// Tier rank in bits 0..5, row-miss flag in bit 6, write flag in
    /// bit 7.
    meta: Vec<u8>,
    /// True between `begin_block` and `end_block`.
    active: bool,
}

const PENDING_WRITE_BIT: u8 = 0x80;
/// The request's device access missed the row buffer (recorded only
/// when the policy consumes the RBL signal).
const PENDING_ROW_MISS_BIT: u8 = 0x40;

/// The HMMU model.
#[derive(Clone)]
pub struct Hmmu {
    // audit: allow(codec-coverage) — configuration, supplied at restore time
    cfg: SystemConfig,
    pub table: RedirectionTable,
    tags: TagMatcher,
    pub dma: DmaEngine,
    /// Enum-dispatched placement policy (§Perf: de-virtualized hot path;
    /// `dyn` survives only at the `HotnessEngine` boundary).
    policy: PolicyImpl,
    /// The tier stack: one memory controller per rank (0 = fastest).
    tiers: Vec<MemoryController<TierDevice>>,
    /// The specs the stack was built from (energy/report surface).
    // audit: allow(codec-coverage) — configuration, rebuilt from cfg
    specs: Vec<TierSpec>,
    pub counters: HmmuCounters,
    hints: HintStore,
    /// Pipeline latency (decode + policy + route stages) in ns.
    // audit: allow(codec-coverage) — derived from cfg on construction
    pipeline_ns: u64,
    /// Release times of outstanding HDR FIFO entries (occupancy model).
    hdr_occupancy: ReleaseRing,
    /// Host-managed DMA completion-column scratch (see [`CplScratch`]).
    // audit: allow(codec-coverage) — scratch, rebuilt per batch
    dma_cpl: CplScratch,
    /// Block-batched hotness/tier-access accounting (see
    /// [`PendingAccesses`]).
    pending: PendingAccesses,
    requests_since_epoch: u64,
    /// Simulated time of the last processed request (drives epoch DMA).
    last_now: Time,
    /// Dedicated fault-injection stream ([`crate::config::FaultConfig`]):
    /// decoupled from every workload/policy RNG so fault draws are
    /// deterministic at any thread count, and never consumed when the
    /// fault layer is off (default-off runs stay bit-identical).
    fault_rng: Xoshiro256,
}

impl Hmmu {
    pub fn new(cfg: SystemConfig, engine: Option<Box<dyn HotnessEngine>>) -> Self {
        let fpga = Clock::from_mhz(cfg.hmmu.fpga_freq_mhz);
        let page_bytes = cfg.hmmu.page_bytes;
        let specs = cfg.tier_specs();
        let frames: Vec<u32> = specs
            .iter()
            .map(|s| (s.size_bytes / page_bytes) as u32)
            .collect();
        let host_pages = cfg.total_pages();

        let mut table = RedirectionTable::new(host_pages, &frames, page_bytes);
        if cfg.policy == crate::config::PolicyKind::Static {
            table.identity_map();
        }

        // Memory-controller clock: DDR4-1600-class command rate; every
        // tier runs a Table II-class controller in front of its device.
        let mc_clock = Clock::from_mhz(1200.0);
        let tiers: Vec<MemoryController<TierDevice>> = specs
            .iter()
            .map(|s| {
                MemoryController::new(
                    TierDevice::build(s, cfg.dram, page_bytes),
                    mc_clock,
                    4,
                    cfg.dram.queue_depth,
                )
            })
            .collect();

        let policy = build_policy(&cfg, engine);
        let pipeline_ns = fpga.cycles_to_ns(cfg.hmmu.pipeline_stages as u64);
        let mut counters = HmmuCounters::with_tiers(specs.len());
        counters.energy_nj = specs
            .iter()
            .map(|s| (s.energy.read_nj, s.energy.write_nj))
            .collect();

        Hmmu {
            table,
            tags: TagMatcher::new(cfg.hmmu.hdr_fifo_depth as usize),
            dma: DmaEngine::new(
                cfg.hmmu.dma_block_bytes as u64,
                page_bytes,
                cfg.hmmu.dma_buffer_bytes as u64 >= 2 * cfg.hmmu.dma_block_bytes as u64,
            ),
            policy,
            tiers,
            specs,
            counters,
            hints: HintStore::new(),
            pipeline_ns,
            hdr_occupancy: ReleaseRing::new(cfg.hmmu.hdr_fifo_depth as usize),
            dma_cpl: CplScratch::default(),
            pending: PendingAccesses::default(),
            requests_since_epoch: 0,
            last_now: 0,
            fault_rng: {
                let mut mix = cfg.seed ^ cfg.fault.seed;
                Xoshiro256::new(splitmix64(&mut mix))
            },
            cfg,
        }
    }

    /// Install middleware hints (paper §III-G) for hint-aware placement.
    pub fn set_hints(&mut self, hints: HintStore) {
        self.hints = hints;
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Dynamic-stall reconfiguration of the rank-1 tier (Table I sweep:
    /// §III-F "arbitrary latency cycles").
    pub fn set_nvm_stalls(&mut self, read_ns: u64, write_ns: u64) {
        self.set_tier_stalls(TierId::Nvm, read_ns, write_ns);
    }

    /// Dynamic-stall reconfiguration of any tier (a no-op on bare DRAM
    /// ranks).
    pub fn set_tier_stalls(&mut self, tier: TierId, read_ns: u64, write_ns: u64) {
        self.tiers[tier.index()].device_mut().set_stalls(read_ns, write_ns);
    }

    /// Number of tiers in the stack.
    pub fn tier_count(&self) -> usize {
        self.tiers.len()
    }

    /// The tier specs the stack was built from, rank order.
    pub fn tier_specs(&self) -> &[TierSpec] {
        &self.specs
    }

    /// Device counter snapshot of one tier.
    pub fn tier_stats(&self, tier: TierId) -> &crate::mem::DeviceStats {
        self.tiers[tier.index()].device().stats()
    }

    pub fn dram_stats(&self) -> &crate::mem::DeviceStats {
        self.tier_stats(TierId::Dram)
    }

    /// Mirror every tier's device-level row-buffer outcome counters into
    /// the HMMU counter block (rank order). Called by the platform just
    /// before the counters are cloned into a report — the same pattern
    /// as the `link_retries` mirror — so the row-hit-rate columns always
    /// reflect the devices' cumulative truth.
    pub fn sync_row_counters(&mut self) {
        let n = self.tiers.len();
        self.counters.tier_row_hits.resize(n, 0);
        self.counters.tier_row_misses.resize(n, 0);
        for (i, t) in self.tiers.iter().enumerate() {
            let s = t.device().stats();
            self.counters.tier_row_hits[i] = s.row_hits;
            self.counters.tier_row_misses[i] = s.row_misses;
        }
    }

    pub fn nvm_stats(&self) -> &crate::mem::DeviceStats {
        self.tier_stats(TierId::Nvm)
    }

    /// Highest per-page write count observed on one tier (0 for bare
    /// DRAM ranks).
    pub fn tier_max_wear(&self, tier: TierId) -> u64 {
        self.tiers[tier.index()].device().max_wear()
    }

    /// Per-tier max wear, rank order.
    pub fn tier_wear(&self) -> Vec<u64> {
        self.tiers.iter().map(|t| t.device().max_wear()).collect()
    }

    /// Worst per-page wear across the wear-limited (rank ≥ 1) tiers —
    /// the legacy `nvm_max_wear` report column (= rank-1 wear on a
    /// two-tier stack).
    pub fn nvm_max_wear(&self) -> u64 {
        self.tiers[1..]
            .iter()
            .map(|t| t.device().max_wear())
            .max()
            .unwrap_or(0)
    }

    /// Per-tier resident page counts, rank order (sums to the mapped
    /// page count).
    pub fn tier_residency(&self) -> Vec<u64> {
        self.table.residency().to_vec()
    }

    /// Process one memory request arriving at `now`. Returns the time the
    /// response leaves the HMMU (for reads: data ready for the TX TLP;
    /// for writes: commit time at the device — posted, the host does not
    /// wait for it).
    pub fn access(&mut self, addr: u64, kind: AccessKind, bytes: u64, now: Time) -> Time {
        self.access_linked(addr, kind, bytes, now, None)
    }

    /// [`Self::access`] with a PCIe link handle for the epoch path: under
    /// `HmmuConfig::host_managed_dma` any migration launched at this
    /// request's epoch boundary charges its block transfers at the link.
    /// With the flag off (the default) the handle is ignored and this is
    /// exactly [`Self::access`].
    pub fn access_linked(
        &mut self,
        addr: u64,
        kind: AccessKind,
        bytes: u64,
        now: Time,
        mut link: Option<&mut PcieLink>,
    ) -> Time {
        self.last_now = now;
        // --- counters: host side ---
        match kind {
            AccessKind::Read => {
                self.counters.host_reads += 1;
                self.counters.host_read_bytes += bytes;
            }
            AccessKind::Write => {
                self.counters.host_writes += 1;
                self.counters.host_write_bytes += bytes;
            }
        }

        // --- commit any DMA swaps that finished before this request ---
        self.commit_dma(now);

        // --- HDR FIFO occupancy / backpressure ---
        let mut t = now;
        // Responses that left by `t` free their slots.
        while let Some(front) = self.hdr_occupancy.front() {
            if front <= t {
                self.hdr_occupancy.pop_front();
            } else {
                break;
            }
        }
        if self.hdr_occupancy.is_full() {
            // FIFO full: stall the pipeline until the head drains (and
            // free anything else that drains while we wait).
            self.counters.fifo_full_stalls += 1;
            t = self.hdr_occupancy.front().unwrap();
            self.hdr_occupancy.pop_front();
            while let Some(front) = self.hdr_occupancy.front() {
                if front <= t {
                    self.hdr_occupancy.pop_front();
                } else {
                    break;
                }
            }
        }

        // --- control pipeline (decode + policy + route stages) ---
        t += self.pipeline_ns;

        // --- placement on first touch ---
        let page = addr / self.cfg.hmmu.page_bytes;
        let offset = addr % self.cfg.hmmu.page_bytes;
        if self.table.lookup(page).is_none() {
            let hint = self.hints.lookup(addr);
            let preferred = self.policy.place(page, hint);
            let m = self
                .table
                .place(page, preferred)
                .expect("hybrid memory exhausted: host space exceeds frames");
            self.counters.record_placement(m.device.index());
        }

        // --- DMA conflict routing (§III-D) ---
        let (device, dev_addr) = {
            let (route, swap) = self.dma.route(page, offset, t);
            match route {
                DmaRoute::NotInvolved => self.table.translate(addr).unwrap(),
                DmaRoute::UseOriginal => {
                    let m = swap.unwrap().original(page);
                    (m.device, m.frame as u64 * self.cfg.hmmu.page_bytes + offset)
                }
                DmaRoute::UseDestination => {
                    let m = swap.unwrap().destination(page);
                    (m.device, m.frame as u64 * self.cfg.hmmu.page_bytes + offset)
                }
                DmaRoute::Stall(until) => {
                    self.counters.dma_conflict_stalls += 1;
                    let m = swap.unwrap().destination(page);
                    t = until;
                    (m.device, m.frame as u64 * self.cfg.hmmu.page_bytes + offset)
                }
            }
        };

        // --- tag issue + media access ---
        let tag = if self.tags.can_issue() {
            self.tags.issue()
        } else {
            // No free HDR tag (the occupancy model normally gates this):
            // block until the earliest outstanding response drains and
            // count the stall, instead of issuing into a full FIFO. The
            // occupancy ring front is that earliest completion; the tag
            // matcher uses it for its unstamped head.
            self.counters.fifo_full_stalls += 1;
            let hint = self.hdr_occupancy.front().unwrap_or(t);
            let (tag, freed_at) = self.tags.issue_blocking(t, hint);
            t = freed_at;
            tag
        };
        // --- policy + per-tier accounting ---
        // §Perf: inside a trace block the two recorder calls (pure
        // counter additions no reader consults until the next epoch
        // boundary) are queued and flushed in one batch at block end —
        // see [`PendingAccesses`]. Per-op callers record immediately.
        if self.pending.active {
            self.pending.pages.push(page);
            let write = if kind.is_write() { PENDING_WRITE_BIT } else { 0 };
            self.pending.meta.push(device.rank() | write);
        } else {
            self.policy.record_access(page, kind.is_write());
            self.counters.record_tier_access(device.index(), kind.is_write());
        }
        let (mut done, row_hit) = self.tiers[device.index()].issue_hit(dev_addr, kind, bytes, t);
        // RBL sampling: the device's row-buffer outcome feeds the
        // per-page miss-intensity counters — only when the policy
        // actually consumes the signal, so every other policy's hot
        // path (and its block meta encoding) is untouched.
        if !row_hit && self.policy.wants_row_misses() {
            if self.pending.active {
                // The meta byte for *this* request was pushed just above.
                *self.pending.meta.last_mut().unwrap() |= PENDING_ROW_MISS_BIT;
            } else {
                self.policy.record_row_miss(page);
            }
        }

        // --- fault layer: wear-driven errors, ECC, frame retirement ---
        if self.cfg.fault.mem_enabled() {
            done = self.mem_fault(page, device, dev_addr, done, &mut link);
        }

        // --- in-order completion drain (§III-C) ---
        let release = self.tags.complete_inline(tag, done);
        self.counters.reorder_wait_ns = self.tags.reorder_wait_ns;
        self.hdr_occupancy.push_back(release);

        self.counters.latency.record(release.saturating_sub(now));

        // --- epoch boundary ---
        self.requests_since_epoch += 1;
        if self.requests_since_epoch >= self.cfg.hmmu.epoch_requests {
            self.requests_since_epoch = 0;
            // The epoch step reads the policy counters: drain any
            // block-batched accounting first so deferral is invisible.
            self.flush_pending();
            self.run_epoch(release, link);
        }

        release
    }

    /// Start deferring hotness/tier-access accounting for a trace block
    /// (the [`crate::cpu::MemBackend::begin_block`] hook).
    pub fn begin_block(&mut self) {
        self.pending.active = true;
    }

    /// End the block: flush the deferred accounting in arrival order.
    pub fn end_block(&mut self) {
        self.pending.active = false;
        self.flush_pending();
    }

    /// Drain the deferred accounting queue into the policy and counters,
    /// in arrival order — bit-identical to immediate recording because
    /// both recorders are pure additions and every reader (epoch step,
    /// reports) runs behind a flush point.
    fn flush_pending(&mut self) {
        if self.pending.pages.is_empty() {
            return;
        }
        // Take the buffers to split the borrow; hand them back afterwards
        // so steady state allocates nothing.
        let pages = std::mem::take(&mut self.pending.pages);
        let meta = std::mem::take(&mut self.pending.meta);
        for (&page, &m) in pages.iter().zip(meta.iter()) {
            let is_write = m & PENDING_WRITE_BIT != 0;
            self.policy.record_access(page, is_write);
            if m & PENDING_ROW_MISS_BIT != 0 {
                self.policy.record_row_miss(page);
            }
            self.counters.record_tier_access(
                (m & !(PENDING_WRITE_BIT | PENDING_ROW_MISS_BIT)) as usize,
                is_write,
            );
        }
        self.pending.pages = pages;
        self.pending.meta = meta;
        self.pending.pages.clear();
        self.pending.meta.clear();
    }

    /// Commit DMA swaps completed by `now` into the redirection table.
    fn commit_dma(&mut self, now: Time) {
        for (a, b) in self.dma.drain_committed(now) {
            self.table
                .swap(a, b)
                .expect("committed swap of unmapped pages");
        }
    }

    /// Run the policy step and launch the selected migrations on the DMA
    /// engine. The policy math itself executes off the request path (the
    /// paper's control logic is pipelined in fabric); we account its host
    /// wall time in the counters for the §Perf report.
    fn run_epoch(&mut self, now: Time, mut link: Option<&mut PcieLink>) {
        self.counters.epochs += 1;
        // The one sanctioned wall-clock read in model code: it feeds only
        // `policy_wall_ns`, which every deterministic surface excludes.
        // audit: allow(wall-clock) — policy_wall_ns measurement site
        let wall = std::time::Instant::now();
        let dma_ref = &self.dma;
        let migrating = |page: u64| dma_ref.is_active(page);
        let pairs = {
            let view = PolicyView {
                table: &self.table,
                migrating: &migrating,
                max_migrations: self.cfg.hmmu.migrations_per_epoch,
                boundary_budgets: &self.cfg.hmmu.migrations_per_boundary,
            };
            // Borrows the policy's recycled pair buffer (§Perf: no
            // per-epoch allocation).
            self.policy.epoch(&view)
        };
        self.counters.policy_wall_ns += wall.elapsed().as_nanos() as u64;

        // Fidelity (ROADMAP): migration block transfers share the HDR
        // FIFO with demand traffic — each DMA device access claims a slot
        // (stalling its issue when the FIFO is full) and holds it until
        // the access completes. `dma_hdr_occupancy = false` restores the
        // old bypass model.
        let occupy = self.cfg.hmmu.dma_hdr_occupancy;
        // Fidelity (ROADMAP): under a *host-managed* design the migration
        // engine lives on the host side of the link, so every block
        // transfer crosses PCIe — reads come back as completion data,
        // writes go out as posted-payload TLPs, both split at the link's
        // max payload — and contends with demand traffic for wire time
        // and credits. Requires a link handle (the platform backends pass
        // one); a bare `Hmmu::access` keeps device-side DMA.
        let host_managed = self.cfg.hmmu.host_managed_dma;
        let max_payload = self.cfg.pcie.max_payload_bytes as u64;
        for &(deep_page, fast_page) in pairs {
            let (Some(ma), Some(mb)) = (self.table.lookup(deep_page), self.table.lookup(fast_page))
            else {
                continue;
            };
            // Policies see a consistent snapshot, but double-check
            // directions: promote from a deeper rank to a faster one
            // only (any tier pair is allowed; for the two-tier stack
            // this is exactly the old NVM→DRAM check).
            if ma.device <= mb.device {
                continue;
            }
            // Belt-and-braces: pairs launched earlier *this epoch* are
            // already active on the DMA engine (the policy's `migrating`
            // snapshot predates them; policies also dedupe, so this
            // never fires on a two-tier stack).
            if self.dma.is_active(deep_page) || self.dma.is_active(fast_page) {
                continue;
            }
            let tiers = &mut self.tiers;
            let hdr = &mut self.hdr_occupancy;
            let counters = &mut self.counters;
            let link_ref = &mut link;
            let cpl = &mut self.dma_cpl;
            let mut issue = |dev: Device, a: u64, k: AccessKind, b: u64, at: Time| {
                let l = if host_managed { link_ref.as_deref_mut() } else { None };
                Self::dma_issue(tiers, hdr, counters, cpl, l, occupy, max_payload, dev, a, k, b, at)
            };
            self.dma
                .start_swap(deep_page, ma, fast_page, mb, now, &mut issue);
            self.counters.migrations += 1;
            self.counters.migration_bytes += 2 * self.cfg.hmmu.page_bytes;
        }
    }

    /// Issue one DMA block access against the tier stack, modeling HDR
    /// FIFO occupancy (when `occupy`) and the host-managed PCIe crossing
    /// (when a `link` handle is given). An associated function over split
    /// field borrows so the epoch migration closure and the fault layer's
    /// emergency remap charge the **identical** machinery.
    ///
    /// The argument count is deliberate (audited PR 8): the first four
    /// are *disjoint field borrows* of `self` — they cannot collapse
    /// into a params struct without re-borrowing `self`, which the
    /// epoch-migration closure (holding its own `self` splits) forbids —
    /// and the remaining six are the per-access description. Bundling
    /// the latter into a struct would only move the same six values one
    /// level down at both call sites.
    #[allow(clippy::too_many_arguments)]
    fn dma_issue(
        tiers: &mut [MemoryController<TierDevice>],
        hdr: &mut ReleaseRing,
        counters: &mut HmmuCounters,
        cpl: &mut CplScratch,
        link: Option<&mut PcieLink>,
        occupy: bool,
        max_payload: u64,
        dev: Device,
        a: u64,
        k: AccessKind,
        b: u64,
        at: Time,
    ) -> Time {
        let mut at = at;
        if occupy {
            // Free slots whose responses left by `at`; stall the
            // transfer on a full FIFO until the head drains.
            // Time-base note: every ring entry's stored release is
            // ≤ the epoch time `now` (demand releases are monotone
            // and the epoch fires at the newest one; earlier DMA
            // pushes were clamped monotone) or is a DMA completion
            // from this epoch, and `at >= now` — so these pops
            // never free a slot before its modeled drain time.
            while let Some(front) = hdr.front() {
                if front <= at {
                    hdr.pop_front();
                } else {
                    break;
                }
            }
            if hdr.is_full() {
                counters.dma_hdr_stalls += 1;
                at = hdr.front().unwrap();
                hdr.pop_front();
                while let Some(front) = hdr.front() {
                    if front <= at {
                        hdr.pop_front();
                    } else {
                        break;
                    }
                }
            }
        }
        let done = match link {
            Some(l) => {
                let stalls_before = l.credit_stalls;
                let done = match k {
                    AccessKind::Read => {
                        // Host reads the block: MRd request out
                        // (header only), device access, then the
                        // data rides completion TLPs back —
                        // split at the link's max payload and
                        // serialized back-to-back on the RX wire
                        // as one column.
                        let arrive = l.send_to_device(0, at);
                        let ready = tiers[dev.index()].issue(a, k, b, arrive);
                        cpl.payloads.clear();
                        cpl.times.clear();
                        let mut remaining = b;
                        while remaining > 0 {
                            let chunk = remaining.min(max_payload);
                            cpl.payloads.push(chunk as u32);
                            cpl.times.push(ready);
                            remaining -= chunk;
                        }
                        l.send_block_to_host(&cpl.payloads, &cpl.times, &mut cpl.arrivals);
                        let done = *cpl.arrivals.last().unwrap();
                        l.hold_credit_until(done);
                        done
                    }
                    AccessKind::Write => {
                        // Host writes the block: posted MWr TLPs
                        // carry the payload out in max_payload
                        // chunks. Each chunk's flow-control
                        // credit is recorded as it is sent
                        // (posted writes free their credit once
                        // the device RX buffer accepts them), so
                        // the pool never exceeds `cfg.credits`
                        // mid-burst; the device commit happens
                        // once the last chunk has arrived.
                        let mut arrive = at;
                        let mut remaining = b;
                        while remaining > 0 {
                            let chunk = remaining.min(max_payload);
                            arrive = l.send_to_device(chunk as u32, at);
                            l.hold_credit_until(arrive);
                            remaining -= chunk;
                        }
                        tiers[dev.index()].issue(a, k, b, arrive)
                    }
                };
                counters.pcie_dma_bytes += b;
                counters.dma_link_stalls += l.credit_stalls - stalls_before;
                done
            }
            None => tiers[dev.index()].issue(a, k, b, at),
        };
        if occupy {
            counters.dma_hdr_slots += 1;
            hdr.push_back(done);
        }
        done
    }

    /// Fault layer (called per demand access when
    /// [`crate::config::FaultConfig::mem_enabled`]): draw a wear-driven
    /// bit error against the frame that served this access. Corrected
    /// events cost the ECC latency penalty; uncorrectable events — and
    /// frames whose wear has exhausted the endurance budget — retire the
    /// frame into the tier's retired pool and emergency-remigrate the
    /// page to a healthy frame, charging the copy through the same
    /// DMA/HDR/PCIe machinery as an epoch migration. Returns the
    /// fault-adjusted completion time.
    fn mem_fault(
        &mut self,
        page: u64,
        device: Device,
        dev_addr: u64,
        done: Time,
        link: &mut Option<&mut PcieLink>,
    ) -> Time {
        let page_bytes = self.cfg.hmmu.page_bytes;
        let frame = dev_addr / page_bytes;
        let dev = self.tiers[device.index()].device();
        let wear = dev.wear_of(frame);
        let endurance = dev.endurance();
        let dead = endurance != u64::MAX && wear >= endurance;
        if !dead {
            // One Bernoulli draw per access against the frame's
            // wear-scaled raw bit error rate.
            let rber = self.cfg.fault.rber(wear, endurance);
            if !self.fault_rng.chance(rber) {
                return done;
            }
            if !self.fault_rng.chance(self.cfg.fault.uncorrectable_frac) {
                // Within ECC correction strength: latency penalty only.
                self.counters.ecc_corrected += 1;
                return done + self.cfg.fault.ecc_latency_ns;
            }
        }
        // Uncorrectable error (or hard frame death at endurance
        // exhaustion): the ECC pipeline still spends its detection
        // latency before the rescue starts.
        self.counters.ecc_uncorrectable += 1;
        let done = done + self.cfg.fault.ecc_latency_ns;
        // A page mid-DMA owns its frames until the swap commits — defer
        // the retirement; a later access to the degraded frame retries.
        if self.dma.is_active(page) {
            return done;
        }
        let Some(old) = self.table.lookup(page) else {
            return done;
        };
        let new = match self.table.retire_and_remap(page) {
            // No healthy frame anywhere in the stack: the page limps on
            // its degraded frame (survival over retirement).
            Ok(None) | Err(_) => return done,
            Ok(Some(m)) => m,
        };
        self.counters.frames_retired += 1;
        self.counters.remap_migrations += 1;
        self.counters.remap_bytes += page_bytes;
        // One-way rescue copy, block by block: read the old frame, write
        // the healthy one — HDR occupancy and (under host-managed DMA)
        // the PCIe link charged exactly like an epoch migration block.
        let occupy = self.cfg.hmmu.dma_hdr_occupancy;
        let host_managed = self.cfg.hmmu.host_managed_dma;
        let max_payload = self.cfg.pcie.max_payload_bytes as u64;
        let block = (self.cfg.hmmu.dma_block_bytes as u64).clamp(1, page_bytes);
        let src = old.frame as u64 * page_bytes;
        let dst = new.frame as u64 * page_bytes;
        let mut at = done;
        let mut off = 0;
        while off < page_bytes {
            let b = block.min(page_bytes - off);
            let l = if host_managed { link.as_deref_mut() } else { None };
            let ready = Self::dma_issue(
                &mut self.tiers,
                &mut self.hdr_occupancy,
                &mut self.counters,
                &mut self.dma_cpl,
                l,
                occupy,
                max_payload,
                old.device,
                src + off,
                AccessKind::Read,
                b,
                at,
            );
            let l = if host_managed { link.as_deref_mut() } else { None };
            at = Self::dma_issue(
                &mut self.tiers,
                &mut self.hdr_occupancy,
                &mut self.counters,
                &mut self.dma_cpl,
                l,
                occupy,
                max_payload,
                new.device,
                dst + off,
                AccessKind::Write,
                b,
                ready,
            );
            off += b;
        }
        // The demand response waits for the rescue: the data is only
        // guaranteed good once it lands on the healthy frame.
        at
    }

    /// Finish outstanding work at end-of-run (commit in-flight swaps).
    pub fn drain(&mut self, now: Time) {
        self.flush_pending();
        while self.dma.active_count() > 0 {
            let horizon = self.dma.next_commit().unwrap().max(now);
            self.commit_dma(horizon);
        }
    }

    /// DRAM residency ratio of mapped pages (placement quality metric).
    /// O(1): both terms are counters maintained by the redirection table
    /// (§Perf — this used to walk every table entry per report).
    pub fn dram_residency(&self) -> f64 {
        let mapped = self.table.mapped_pages() as f64;
        if mapped == 0.0 {
            return 0.0;
        }
        self.table.dram_resident_pages() as f64 / mapped
    }

    /// Re-target a forked (cloned or restored) warm HMMU at scenario
    /// `cfg`, which may differ from the warm-up config only on the fork
    /// axes: policy kind and rank-1 injected stalls.
    ///
    /// - Policy **kind** change: the warm policy state belongs to another
    ///   algorithm, so the new policy starts fresh (`build_policy`) — the
    ///   redirection table, caches, devices and clocks stay warm. Note
    ///   a fork to Static keeps the warm table layout (identity mapping
    ///   happens only at construction): inherent to checkpoint-fork
    ///   methodology, and pinned as such by the fork-vs-cold tests, which
    ///   replay the same morph path cold.
    /// - Same kind: the warm policy state (hotness, wear) carries over.
    /// - Stall change: reconfigures the rank-1 device in place (§III-F
    ///   "arbitrary latency cycles" — same mechanism as `--nvm-stalls`).
    pub fn morph_for_fork(&mut self, cfg: &SystemConfig) {
        if cfg.policy != self.cfg.policy {
            self.policy = build_policy(cfg, None);
            self.cfg.policy = cfg.policy;
        }
        if cfg.nvm.read_stall_ns != self.cfg.nvm.read_stall_ns
            || cfg.nvm.write_stall_ns != self.cfg.nvm.write_stall_ns
        {
            self.set_nvm_stalls(cfg.nvm.read_stall_ns, cfg.nvm.write_stall_ns);
            self.cfg.nvm = cfg.nvm;
        }
    }
}

impl CodecState for ReleaseRing {
    fn encode_state(&self, e: &mut Encoder) {
        // Entries in drain order; the restored ring re-bases at index 0
        // (head position is representation, not state).
        e.put_len(self.len);
        for k in 0..self.len {
            let mut i = self.head + k;
            if i >= self.buf.len() {
                i -= self.buf.len();
            }
            e.put_u64(self.buf[i]);
        }
        e.put_u64(self.last);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let n = d.len()?;
        if n > self.buf.len() {
            crate::bail!(
                "checkpoint geometry mismatch: {n} HDR occupancy entries exceed capacity {}",
                self.buf.len()
            );
        }
        self.head = 0;
        self.len = n;
        for k in 0..n {
            self.buf[k] = d.u64()?;
        }
        self.last = d.u64()?;
        Ok(())
    }
}

impl CodecState for Hmmu {
    fn encode_state(&self, e: &mut Encoder) {
        // Checkpoints are taken at trace-block boundaries, where the
        // deferred accounting queue is empty (`end_block` flushed it) and
        // the DMA completion scratch is idle — so neither is serialized.
        // `cfg`/`specs`/`pipeline_ns` are configuration, rebuilt by
        // `Hmmu::new` and validated structurally by each member decode.
        debug_assert!(
            self.pending.pages.is_empty() && !self.pending.active,
            "checkpoint mid-block: deferred accounting not flushed"
        );
        self.table.encode_state(e);
        self.tags.encode_state(e);
        self.dma.encode_state(e);
        self.policy.encode_state(e);
        e.put_len(self.tiers.len());
        for t in &self.tiers {
            t.encode_state(e);
        }
        self.counters.encode_state(e);
        self.hints.encode_state(e);
        self.hdr_occupancy.encode_state(e);
        e.put_u64(self.requests_since_epoch);
        e.put_u64(self.last_now);
        // Fault stream position: a restored faulted run must draw the
        // exact sequence a continuous run would have drawn.
        e.put_u64_slice(&self.fault_rng.state());
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.table.decode_state(d)?;
        self.tags.decode_state(d)?;
        self.dma.decode_state(d)?;
        self.policy.decode_state(d)?;
        let n = d.len()?;
        check_len("hmmu tiers", self.tiers.len(), n)?;
        for t in &mut self.tiers {
            t.decode_state(d)?;
        }
        self.counters.decode_state(d)?;
        self.hints.decode_state(d)?;
        self.hdr_occupancy.decode_state(d)?;
        self.requests_since_epoch = d.u64()?;
        self.last_now = d.u64()?;
        let s = d.u64_vec()?;
        check_len("fault rng words", 4, s.len())?;
        self.fault_rng = Xoshiro256::from_state([s[0], s[1], s[2], s[3]]);
        self.pending = PendingAccesses::default();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;

    fn hmmu(policy: PolicyKind) -> Hmmu {
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = policy;
        cfg.hmmu.epoch_requests = 1000;
        Hmmu::new(cfg, None)
    }

    #[test]
    fn read_and_write_complete() {
        let mut h = hmmu(PolicyKind::Static);
        let t_r = h.access(0, AccessKind::Read, 64, 0);
        assert!(t_r > 0);
        let t_w = h.access(4096, AccessKind::Write, 64, t_r);
        assert!(t_w > t_r);
        assert_eq!(h.counters.host_reads, 1);
        assert_eq!(h.counters.host_writes, 1);
    }

    #[test]
    fn static_policy_routes_by_address() {
        let mut h = hmmu(PolicyKind::Static);
        let dram_bytes = h.config().dram.size_bytes;
        h.access(0, AccessKind::Read, 64, 0);
        assert_eq!(h.counters.dram_reads(), 1);
        h.access(dram_bytes + 64, AccessKind::Read, 64, 1000);
        assert_eq!(h.counters.nvm_reads(), 1);
    }

    #[test]
    fn nvm_read_slower_than_dram_read() {
        let mut h = hmmu(PolicyKind::Static);
        let dram_bytes = h.config().dram.size_bytes;
        let t0 = h.access(0, AccessKind::Read, 64, 0);
        let dram_latency = t0;
        let t1 = h.access(dram_bytes + 4096, AccessKind::Read, 64, 100_000);
        let nvm_latency = t1 - 100_000;
        assert!(
            nvm_latency > dram_latency + h.config().nvm.read_stall_ns / 2,
            "nvm {nvm_latency} vs dram {dram_latency}"
        );
    }

    #[test]
    fn first_touch_fills_dram_then_nvm() {
        let mut h = hmmu(PolicyKind::FirstTouch);
        let page_bytes = h.config().hmmu.page_bytes;
        let dram_pages = h.config().dram_pages();
        let mut t = 0;
        // Touch more pages than DRAM holds.
        for p in 0..(dram_pages + 10) {
            t = h.access(p * page_bytes, AccessKind::Write, 64, t + 100);
        }
        assert_eq!(h.counters.pages_placed_dram(), dram_pages);
        assert_eq!(h.counters.pages_placed_nvm(), 10);
    }

    #[test]
    fn hotness_policy_migrates_hot_nvm_pages() {
        let mut h = hmmu(PolicyKind::Hotness);
        let page_bytes = h.config().hmmu.page_bytes;
        let dram_pages = h.config().dram_pages();
        let mut t = 0;
        // Fill DRAM with one-touch pages.
        for p in 0..dram_pages {
            t = h.access(p * page_bytes, AccessKind::Read, 64, t + 50);
        }
        // Overflow page lands in NVM, then becomes scorching hot.
        let hot = dram_pages + 1;
        for _ in 0..2000 {
            t = h.access(hot * page_bytes, AccessKind::Read, 64, t + 50);
        }
        h.drain(t + 1_000_000);
        assert!(h.counters.migrations > 0, "hot page should migrate");
        // After drain, the hot page must be DRAM-resident.
        let m = h.table.lookup(hot).unwrap();
        assert_eq!(m.device, Device::Dram);
    }

    #[test]
    fn migration_preserves_table_invariants() {
        let mut h = hmmu(PolicyKind::Hotness);
        let page_bytes = h.config().hmmu.page_bytes;
        let total = h.config().total_pages();
        let mut t = 0;
        let mut rng = crate::util::rng::Xoshiro256::new(1);
        for _ in 0..5000 {
            let p = rng.below(total.min(4096));
            let w = rng.chance(0.3);
            let kind = if w { AccessKind::Write } else { AccessKind::Read };
            t = h.access(p * page_bytes + rng.below(page_bytes), kind, 64, t + 20);
        }
        h.drain(t + 10_000_000);
        h.table.check_invariants().unwrap();
    }

    #[test]
    fn counters_fig8_totals() {
        let mut h = hmmu(PolicyKind::Static);
        let mut t = 0;
        for i in 0..100u64 {
            t = h.access(i * 64, AccessKind::Read, 64, t + 10);
        }
        for i in 0..50u64 {
            t = h.access(i * 64, AccessKind::Write, 64, t + 10);
        }
        let (rb, wb) = h.counters.fig8_row();
        assert_eq!(rb, 6400);
        assert_eq!(wb, 3200);
    }

    #[test]
    fn latency_histogram_populated() {
        let mut h = hmmu(PolicyKind::Static);
        let mut t = 0;
        for i in 0..100u64 {
            t = h.access(i * 4096, AccessKind::Read, 64, t + 100);
        }
        assert_eq!(h.counters.latency.count(), 100);
        assert!(h.counters.latency.mean() > 0.0);
    }

    #[test]
    fn resident_counters_match_recount_after_migrations() {
        // Pins the O(1) residency counters against a full-table recount
        // after a run with placements, migrations and DMA commits.
        let mut h = hmmu(PolicyKind::Hotness);
        let page_bytes = h.config().hmmu.page_bytes;
        let total = h.config().total_pages();
        let mut t = 0;
        let mut rng = crate::util::rng::Xoshiro256::new(7);
        for _ in 0..8000 {
            let p = rng.below(total.min(4096));
            let kind = if rng.chance(0.3) {
                AccessKind::Write
            } else {
                AccessKind::Read
            };
            t = h.access(p * page_bytes, kind, 64, t + 20);
        }
        h.drain(t + 10_000_000);
        assert_eq!(
            h.table.dram_resident_pages(),
            h.table.recount_dram_resident(),
            "resident counter drifted from recount"
        );
        assert_eq!(
            h.table.mapped_pages(),
            h.table.iter_mapped().count() as u64,
            "mapped counter drifted from recount"
        );
        let mapped = h.table.mapped_pages();
        assert!(mapped > 0);
        let expect = h.table.dram_resident_pages() as f64 / mapped as f64;
        assert!((h.dram_residency() - expect).abs() < 1e-12);
    }

    #[test]
    fn dma_traffic_consumes_hdr_fifo_slots() {
        // Default fidelity model: every migrated 512B block costs exactly
        // 4 HDR slots (2 reads + 2 cross-writes) — pinned against the DMA
        // engine's own block counter.
        let mut h = hmmu(PolicyKind::Hotness);
        let page_bytes = h.config().hmmu.page_bytes;
        let dram_pages = h.config().dram_pages();
        let mut t = 0;
        for p in 0..(dram_pages + 50) {
            for _ in 0..30 {
                t = h.access(p * page_bytes, AccessKind::Read, 64, t + 20);
            }
        }
        h.drain(t + 100_000_000);
        assert!(h.counters.migrations > 0, "scenario must migrate");
        assert!(h.dma.blocks_moved > 0);
        assert_eq!(
            h.counters.dma_hdr_slots,
            4 * h.dma.blocks_moved,
            "each DMA block claims 4 HDR slots"
        );
        h.table.check_invariants().unwrap();
    }

    #[test]
    fn dma_hdr_occupancy_flag_off_restores_bypass() {
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 1000;
        cfg.hmmu.dma_hdr_occupancy = false;
        let mut h = Hmmu::new(cfg, None);
        let page_bytes = h.config().hmmu.page_bytes;
        let dram_pages = h.config().dram_pages();
        let mut t = 0;
        for p in 0..(dram_pages + 50) {
            for _ in 0..30 {
                t = h.access(p * page_bytes, AccessKind::Read, 64, t + 20);
            }
        }
        h.drain(t + 100_000_000);
        assert!(h.counters.migrations > 0);
        assert_eq!(
            h.counters.dma_hdr_slots, 0,
            "bypass mode must not touch the occupancy model"
        );
        assert_eq!(h.counters.dma_hdr_stalls, 0);
    }

    #[test]
    fn host_managed_dma_respects_link_credit_pool() {
        // Regression: the chunked posted-write burst used to defer every
        // chunk's credit hold past the burst, so the pool could exceed
        // `cfg.credits`. Drive a migrating scenario through a tight pool
        // and assert the invariant after every request (only DMA charges
        // this link — demand traffic here bypasses it, which isolates
        // the burst accounting).
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 1000;
        cfg.hmmu.host_managed_dma = true;
        cfg.pcie.credits = 4;
        let mut h = Hmmu::new(cfg.clone(), None);
        let mut link = crate::pcie::PcieLink::new(cfg.pcie);
        let page_bytes = cfg.hmmu.page_bytes;
        let dram_pages = cfg.dram.size_bytes / page_bytes;
        let mut t = 0;
        for p in 0..(dram_pages + 50) {
            for _ in 0..30 {
                t = h.access_linked(p * page_bytes, AccessKind::Read, 64, t + 20, Some(&mut link));
                assert!(
                    link.outstanding_credits() <= cfg.pcie.credits as usize,
                    "credit pool exceeded {} after request",
                    cfg.pcie.credits
                );
            }
        }
        h.drain(t + 100_000_000);
        assert!(h.counters.migrations > 0, "scenario must migrate");
        assert!(h.counters.pcie_dma_bytes > 0, "DMA must charge the link");
        assert_eq!(
            h.counters.pcie_dma_bytes,
            2 * h.counters.migration_bytes,
            "each migrated byte crosses the link once per direction"
        );
    }

    #[test]
    fn three_tier_stack_runs_and_accounts_per_tier() {
        use crate::config::MemTech;
        let mut cfg = SystemConfig::default_scaled(64)
            .with_tiers(&[MemTech::Dram, MemTech::Pcm, MemTech::Xpoint3D])
            .unwrap();
        cfg.policy = PolicyKind::Hotness;
        cfg.hmmu.epoch_requests = 1000;
        let mut h = Hmmu::new(cfg, None);
        assert_eq!(h.tier_count(), 3);
        let page_bytes = h.config().hmmu.page_bytes;
        let total = h.config().total_pages();
        let mut rng = crate::util::rng::Xoshiro256::new(11);
        let mut t = 0;
        for _ in 0..8000 {
            let p = rng.below(total.min(6000));
            let kind = if rng.chance(0.3) {
                AccessKind::Write
            } else {
                AccessKind::Read
            };
            t = h.access(p * page_bytes, kind, 64, t + 20);
        }
        h.drain(t + 10_000_000);
        h.table.check_invariants().unwrap();
        // Residency counters sum to mapped pages across all tiers.
        assert_eq!(
            h.tier_residency().iter().sum::<u64>(),
            h.table.mapped_pages()
        );
        // Demand requests partition across the three tiers' counters.
        assert_eq!(h.counters.tier_reads.len(), 3);
        let device: u64 = h.counters.tier_reads.iter().sum::<u64>()
            + h.counters.tier_writes.iter().sum::<u64>();
        assert_eq!(h.counters.total_host_requests(), device);
        // The footprint overflows ranks 0 and 1, so the deep tier serves
        // traffic and holds pages.
        assert!(h.tier_residency()[2] > 0, "deep tier must hold pages");
        assert!(
            h.counters.tier_reads[2] + h.counters.tier_writes[2] > 0,
            "deep tier must serve traffic"
        );
        // Wear is tracked per wear-limited tier.
        assert_eq!(h.tier_wear().len(), 3);
        assert_eq!(h.tier_wear()[0], 0, "bare DRAM rank tracks no wear");
        assert!(h.nvm_max_wear() >= h.tier_wear()[2]);
    }

    #[test]
    fn fault_off_records_no_events() {
        let mut h = hmmu(PolicyKind::Hotness);
        let page_bytes = h.config().hmmu.page_bytes;
        let total = h.config().total_pages();
        let mut rng = crate::util::rng::Xoshiro256::new(3);
        let mut t = 0;
        for _ in 0..5000 {
            let p = rng.below(total.min(4096));
            let kind = if rng.chance(0.3) { AccessKind::Write } else { AccessKind::Read };
            t = h.access(p * page_bytes, kind, 64, t + 20);
        }
        h.drain(t + 10_000_000);
        assert_eq!(h.counters.fault_events(), 0, "default-off layer must be silent");
    }

    #[test]
    fn ecc_corrected_events_add_latency_only() {
        // Every injected error falls within correction strength: the run
        // pays latency but never retires a frame or moves a page.
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = PolicyKind::Static;
        cfg.hmmu.epoch_requests = 100_000;
        cfg.fault.rber_base = 0.5;
        cfg.fault.uncorrectable_frac = 0.0;
        let mut h = Hmmu::new(cfg, None);
        let mut t = 0;
        for i in 0..500u64 {
            t = h.access(i * 4096, AccessKind::Read, 64, t + 100);
        }
        assert!(h.counters.ecc_corrected > 100, "rber 0.5 must fire often");
        assert_eq!(h.counters.ecc_uncorrectable, 0);
        assert_eq!(h.counters.frames_retired, 0);
        assert_eq!(h.counters.remap_migrations, 0);
        h.table.check_invariants().unwrap();
    }

    #[test]
    fn wear_exhaustion_retires_frames_and_remaps() {
        // Hammer writes at a handful of wear-limited pages with a tiny
        // endurance budget: their frames die, retire into the tier's
        // retired pool, and the pages emergency-remap to healthy frames
        // — shrinking effective capacity while the run survives.
        let mut cfg = SystemConfig::default_scaled(64);
        cfg.policy = PolicyKind::FirstTouch;
        cfg.hmmu.epoch_requests = 100_000;
        cfg.nvm.endurance = 8;
        cfg.fault.rber_base = 1e-9; // enables the layer; death comes from wear
        let mut h = Hmmu::new(cfg, None);
        let page_bytes = h.config().hmmu.page_bytes;
        let dram_pages = h.config().dram_pages();
        let mut t = 0;
        // Fill DRAM so the next pages land on the wear-limited rank.
        for p in 0..dram_pages {
            t = h.access(p * page_bytes, AccessKind::Read, 64, t + 50);
        }
        for i in 0..400u64 {
            let p = dram_pages + (i % 4);
            t = h.access(p * page_bytes, AccessKind::Write, 64, t + 50);
        }
        h.drain(t + 10_000_000);
        assert!(h.counters.frames_retired > 0, "worn frames must retire");
        assert_eq!(h.counters.frames_retired, h.counters.remap_migrations);
        assert_eq!(h.counters.remap_bytes, h.counters.remap_migrations * page_bytes);
        assert!(h.counters.ecc_uncorrectable >= h.counters.frames_retired);
        assert!(
            h.table.retired_frames(TierId::Nvm) > 0,
            "retired pool must hold the dead frames"
        );
        assert!(
            h.table.effective_frames(TierId::Nvm)
                < h.config().nvm.size_bytes / page_bytes,
            "retirement must shrink effective capacity"
        );
        // Residency still sums to mapped pages; invariants hold.
        assert_eq!(h.tier_residency().iter().sum::<u64>(), h.table.mapped_pages());
        h.table.check_invariants().unwrap();
    }

    #[test]
    fn faulted_runs_are_deterministic() {
        let run = || {
            let mut cfg = SystemConfig::default_scaled(64);
            cfg.policy = PolicyKind::Hotness;
            cfg.hmmu.epoch_requests = 1000;
            cfg.nvm.endurance = 50;
            cfg.fault.rber_base = 1e-3;
            let mut h = Hmmu::new(cfg, None);
            let page_bytes = h.config().hmmu.page_bytes;
            let total = h.config().total_pages();
            let mut rng = crate::util::rng::Xoshiro256::new(5);
            let mut t = 0;
            for _ in 0..8000 {
                let p = rng.below(total.min(4096));
                let kind = if rng.chance(0.5) { AccessKind::Write } else { AccessKind::Read };
                t = h.access(p * page_bytes, kind, 64, t + 20);
            }
            h.drain(t + 10_000_000);
            h.table.check_invariants().unwrap();
            (format!("{:?}", h.counters), t)
        };
        assert_eq!(run(), run(), "same seed must replay the same faults");
    }

    #[test]
    fn drain_commits_everything() {
        let mut h = hmmu(PolicyKind::Hotness);
        let page_bytes = h.config().hmmu.page_bytes;
        let dram_pages = h.config().dram_pages();
        let mut t = 0;
        for p in 0..(dram_pages + 50) {
            for _ in 0..30 {
                t = h.access(p * page_bytes, AccessKind::Read, 64, t + 20);
            }
        }
        h.drain(t + 100_000_000);
        assert_eq!(h.dma.active_count(), 0);
        h.table.check_invariants().unwrap();
    }
}
