//! HMMU performance counters (paper §II-B: "users can easily add a
//! variety of performance counters of their choice. For example, we
//! implemented counters for read/write transactions to each memory device
//! respectively, and obtained a fairly accurate estimate of the dynamic
//! power consumption").
//!
//! These counters regenerate Fig 8 (memory request bytes per workload)
//! and feed the energy estimate. Device counters are **per tier** (rank
//! order vectors); the legacy two-tier scalar names (`dram_reads`,
//! `nvm_writes`, `pages_placed_dram`, …) survive as accessors reading
//! ranks 0/1, so the golden counter snapshots and every report column
//! stay stable for two-tier configs.

use crate::util::codec::{CodecState, Decoder, Encoder};
use crate::util::error::Result;
use crate::util::stats::LatencyHistogram;

/// Aggregated HMMU counters for one run.
///
/// `Debug` is implemented manually (not derived) so it renders **only
/// deterministic, simulated-time fields**: the equivalence tests and the
/// golden counter snapshots compare the Debug rendering verbatim, and the
/// host-wall-clock `policy_wall_ns` field would make byte-identical runs
/// render differently. For two-tier stacks the rendering is byte-for-byte
/// the legacy scalar layout; deeper stacks additionally render the
/// per-tier vectors.
#[derive(Clone, Default)]
pub struct HmmuCounters {
    /// Requests received from the host (post cache filter).
    pub host_reads: u64,
    pub host_writes: u64,
    pub host_read_bytes: u64,
    pub host_write_bytes: u64,
    /// Requests forwarded per tier (rank order; empty ≡ all-zero
    /// two-tier for a default-constructed counter block).
    pub tier_reads: Vec<u64>,
    pub tier_writes: Vec<u64>,
    /// First-touch placement decisions per tier.
    pub tier_pages_placed: Vec<u64>,
    /// Device-level row-buffer outcomes per tier (rank order), mirrored
    /// from the tier devices' [`crate::mem::DeviceStats`] by
    /// [`crate::hmmu::Hmmu::sync_row_counters`] just before reports
    /// clone the block — the RBL observability surface.
    pub tier_row_hits: Vec<u64>,
    pub tier_row_misses: Vec<u64>,
    /// Migration activity.
    pub migrations: u64,
    pub migration_bytes: u64,
    /// Policy epochs executed.
    pub epochs: u64,
    /// Time spent in the policy step (ns of host wall clock, for the
    /// §Perf report; not simulated time, so it is excluded from the
    /// codec, Debug, JSON and fingerprint surfaces by design).
    // audit: allow(codec-coverage) allow(counter-surface) — host wall clock
    pub policy_wall_ns: u64,
    /// End-to-end request latency distribution (simulated ns). Surfaced
    /// through the latency_mean/p50/p99/max scalar columns, not as-is.
    // audit: allow(counter-surface) — surfaced via latency_* scalars
    pub latency: LatencyHistogram,
    /// Consistency mechanism cost.
    pub reorder_wait_ns: u64,
    pub fifo_full_stalls: u64,
    /// DMA conflict redirects/stalls.
    pub dma_conflict_stalls: u64,
    /// HDR FIFO slots consumed by DMA migration block transfers (only
    /// counted when `HmmuConfig::dma_hdr_occupancy` is on; exactly 4 per
    /// migrated block — two reads + two cross-writes).
    pub dma_hdr_slots: u64,
    /// DMA block transfers that stalled on a full HDR FIFO before
    /// issuing (kept separate from `fifo_full_stalls`, which counts only
    /// demand-pipeline stalls, so that series stays comparable across
    /// configurations and PRs).
    pub dma_hdr_stalls: u64,
    /// Payload bytes of migration traffic that crossed the PCIe link
    /// (only under `HmmuConfig::host_managed_dma`; the paper's
    /// device-side DMA never touches the link and keeps this 0).
    pub pcie_dma_bytes: u64,
    /// PCIe credit stalls incurred by host-managed DMA transfers (a
    /// subset of the link's total `credit_stalls`, attributed so demand
    /// vs migration link pressure can be separated).
    pub dma_link_stalls: u64,
    /// Fault-injection counters (all zero when the fault layer is off;
    /// they render in Debug only when nonzero, so the fault-free Debug
    /// surface — and every golden snapshot — is byte-identical to the
    /// pre-fault layout). ECC events corrected in place (latency penalty
    /// only).
    pub ecc_corrected: u64,
    /// ECC events beyond correction strength: the frame is retired and
    /// its page emergency-remapped.
    pub ecc_uncorrectable: u64,
    /// Frames permanently removed from circulation (uncorrectable error
    /// or endurance exhaustion).
    pub frames_retired: u64,
    /// Emergency page remaps triggered by frame retirement.
    pub remap_migrations: u64,
    /// Bytes copied by emergency remaps (one page per remap).
    pub remap_bytes: u64,
    /// PCIe TLP replays triggered by injected link corruption.
    pub link_retries: u64,
    /// Per-tier (read_nj, write_nj) dynamic-energy coefficients, set by
    /// the HMMU from the tier specs. **Not a counter**: excluded from
    /// Debug (like `policy_wall_ns`); empty falls back to the legacy
    /// DDR4/3D XPoint constants.
    // audit: allow(codec-coverage) allow(counter-surface) — config, not a counter
    pub energy_nj: Vec<(f64, f64)>,
}

impl std::fmt::Debug for HmmuCounters {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Every simulated-time field; `policy_wall_ns` (host wall clock,
        // nondeterministic) and `energy_nj` (configuration, not a
        // counter) are deliberately excluded from the equality surface.
        // The exhaustive destructure makes adding a counter without
        // deciding its Debug fate a compile error — a silently-missing
        // field here would be invisible to every Debug-equality test and
        // golden snapshot. Two-tier stacks render the legacy scalar
        // layout byte-identically; deeper stacks append the per-tier
        // vectors after the legacy scalars.
        let HmmuCounters {
            host_reads,
            host_writes,
            host_read_bytes,
            host_write_bytes,
            tier_reads,
            tier_writes,
            tier_pages_placed,
            tier_row_hits,
            tier_row_misses,
            migrations,
            migration_bytes,
            epochs,
            policy_wall_ns: _,
            latency,
            reorder_wait_ns,
            fifo_full_stalls,
            dma_conflict_stalls,
            dma_hdr_slots,
            dma_hdr_stalls,
            pcie_dma_bytes,
            dma_link_stalls,
            ecc_corrected,
            ecc_uncorrectable,
            frames_retired,
            remap_migrations,
            remap_bytes,
            link_retries,
            energy_nj: _,
        } = self;
        let mut s = f.debug_struct("HmmuCounters");
        s.field("host_reads", host_reads)
            .field("host_writes", host_writes)
            .field("host_read_bytes", host_read_bytes)
            .field("host_write_bytes", host_write_bytes)
            .field("dram_reads", &self.dram_reads())
            .field("dram_writes", &self.dram_writes())
            .field("nvm_reads", &self.nvm_reads())
            .field("nvm_writes", &self.nvm_writes())
            .field("pages_placed_dram", &self.pages_placed_dram())
            .field("pages_placed_nvm", &self.pages_placed_nvm())
            .field("migrations", migrations)
            .field("migration_bytes", migration_bytes)
            .field("epochs", epochs)
            .field("latency", latency)
            .field("reorder_wait_ns", reorder_wait_ns)
            .field("fifo_full_stalls", fifo_full_stalls)
            .field("dma_conflict_stalls", dma_conflict_stalls)
            .field("dma_hdr_slots", dma_hdr_slots)
            .field("dma_hdr_stalls", dma_hdr_stalls)
            .field("pcie_dma_bytes", pcie_dma_bytes)
            .field("dma_link_stalls", dma_link_stalls);
        // Fault counters render only when a fault run produced events:
        // the fault-free rendering stays byte-identical to the pre-fault
        // layout (golden snapshots, equivalence batteries).
        if self.fault_events() > 0 {
            s.field("ecc_corrected", ecc_corrected)
                .field("ecc_uncorrectable", ecc_uncorrectable)
                .field("frames_retired", frames_retired)
                .field("remap_migrations", remap_migrations)
                .field("remap_bytes", remap_bytes)
                .field("link_retries", link_retries);
        }
        if self.tiers() > 2 {
            s.field("tier_reads", tier_reads)
                .field("tier_writes", tier_writes)
                .field("tier_pages_placed", tier_pages_placed)
                .field("tier_row_hits", tier_row_hits)
                .field("tier_row_misses", tier_row_misses);
        }
        s.finish_non_exhaustive()
    }
}

impl CodecState for HmmuCounters {
    fn encode_state(&self, e: &mut Encoder) {
        // Same exclusions as Debug: `policy_wall_ns` is host wall clock
        // (would make byte-identical warm-ups serialize differently) and
        // `energy_nj` is configuration, re-derived from the tier specs on
        // construction. Everything else round-trips.
        e.put_u64(self.host_reads);
        e.put_u64(self.host_writes);
        e.put_u64(self.host_read_bytes);
        e.put_u64(self.host_write_bytes);
        e.put_u64_slice(&self.tier_reads);
        e.put_u64_slice(&self.tier_writes);
        e.put_u64_slice(&self.tier_pages_placed);
        e.put_u64_slice(&self.tier_row_hits);
        e.put_u64_slice(&self.tier_row_misses);
        e.put_u64(self.migrations);
        e.put_u64(self.migration_bytes);
        e.put_u64(self.epochs);
        self.latency.encode_state(e);
        e.put_u64(self.reorder_wait_ns);
        e.put_u64(self.fifo_full_stalls);
        e.put_u64(self.dma_conflict_stalls);
        e.put_u64(self.dma_hdr_slots);
        e.put_u64(self.dma_hdr_stalls);
        e.put_u64(self.pcie_dma_bytes);
        e.put_u64(self.dma_link_stalls);
        e.put_u64(self.ecc_corrected);
        e.put_u64(self.ecc_uncorrectable);
        e.put_u64(self.frames_retired);
        e.put_u64(self.remap_migrations);
        e.put_u64(self.remap_bytes);
        e.put_u64(self.link_retries);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.host_reads = d.u64()?;
        self.host_writes = d.u64()?;
        self.host_read_bytes = d.u64()?;
        self.host_write_bytes = d.u64()?;
        // The per-tier vectors grow on demand, so their encoded lengths
        // are state, not geometry — adopt them as-is.
        self.tier_reads = d.u64_vec()?;
        self.tier_writes = d.u64_vec()?;
        self.tier_pages_placed = d.u64_vec()?;
        self.tier_row_hits = d.u64_vec()?;
        self.tier_row_misses = d.u64_vec()?;
        self.migrations = d.u64()?;
        self.migration_bytes = d.u64()?;
        self.epochs = d.u64()?;
        self.latency.decode_state(d)?;
        self.reorder_wait_ns = d.u64()?;
        self.fifo_full_stalls = d.u64()?;
        self.dma_conflict_stalls = d.u64()?;
        self.dma_hdr_slots = d.u64()?;
        self.dma_hdr_stalls = d.u64()?;
        self.pcie_dma_bytes = d.u64()?;
        self.dma_link_stalls = d.u64()?;
        self.ecc_corrected = d.u64()?;
        self.ecc_uncorrectable = d.u64()?;
        self.frames_retired = d.u64()?;
        self.remap_migrations = d.u64()?;
        self.remap_bytes = d.u64()?;
        self.link_retries = d.u64()?;
        // Host wall clock restarts at the restore point.
        self.policy_wall_ns = 0;
        Ok(())
    }
}

impl HmmuCounters {
    /// Counter block sized for an `n`-tier stack.
    pub fn with_tiers(n: usize) -> Self {
        HmmuCounters {
            tier_reads: vec![0; n],
            tier_writes: vec![0; n],
            tier_pages_placed: vec![0; n],
            tier_row_hits: vec![0; n],
            tier_row_misses: vec![0; n],
            ..Default::default()
        }
    }

    /// Row-buffer hit rate of tier `t` (0 when the tier saw no traffic).
    pub fn tier_row_hit_rate(&self, t: usize) -> f64 {
        let hits = Self::tier(&self.tier_row_hits, t);
        let total = hits + Self::tier(&self.tier_row_misses, t);
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Number of tiers this counter block covers (a default-constructed
    /// block reads as the two-tier legacy shape). Takes the max over all
    /// per-tier vectors: the grow-on-demand recorders extend only the
    /// vector they touch, and a write-only deep tier must still be
    /// visible to the energy estimate and the Debug surface.
    pub fn tiers(&self) -> usize {
        self.tier_reads
            .len()
            .max(self.tier_writes.len())
            .max(self.tier_pages_placed.len())
            .max(2)
    }

    #[inline]
    fn tier(v: &[u64], t: usize) -> u64 {
        v.get(t).copied().unwrap_or(0)
    }

    /// Rank-0 demand reads — legacy accessor.
    pub fn dram_reads(&self) -> u64 {
        Self::tier(&self.tier_reads, 0)
    }

    pub fn dram_writes(&self) -> u64 {
        Self::tier(&self.tier_writes, 0)
    }

    /// Rank-1 demand reads — legacy accessor; deeper ranks via
    /// `tier_reads`.
    pub fn nvm_reads(&self) -> u64 {
        Self::tier(&self.tier_reads, 1)
    }

    pub fn nvm_writes(&self) -> u64 {
        Self::tier(&self.tier_writes, 1)
    }

    pub fn pages_placed_dram(&self) -> u64 {
        Self::tier(&self.tier_pages_placed, 0)
    }

    pub fn pages_placed_nvm(&self) -> u64 {
        Self::tier(&self.tier_pages_placed, 1)
    }

    /// Record one demand access routed to tier `t` (the vectors grow on
    /// demand so hand-built counter blocks in tests keep working).
    #[inline]
    pub fn record_tier_access(&mut self, t: usize, is_write: bool) {
        let v = if is_write {
            &mut self.tier_writes
        } else {
            &mut self.tier_reads
        };
        if v.len() <= t {
            v.resize(t + 1, 0);
        }
        v[t] += 1;
    }

    /// Record one first-touch placement on tier `t`.
    #[inline]
    pub fn record_placement(&mut self, t: usize) {
        if self.tier_pages_placed.len() <= t {
            self.tier_pages_placed.resize(t + 1, 0);
        }
        self.tier_pages_placed[t] += 1;
    }

    /// Total fault-layer events recorded (0 ⇔ the fault counters are
    /// absent from the Debug surface).
    pub fn fault_events(&self) -> u64 {
        self.ecc_corrected
            + self.ecc_uncorrectable
            + self.frames_retired
            + self.remap_migrations
            + self.remap_bytes
            + self.link_retries
    }

    pub fn total_host_requests(&self) -> u64 {
        self.host_reads + self.host_writes
    }

    pub fn total_host_bytes(&self) -> u64 {
        self.host_read_bytes + self.host_write_bytes
    }

    /// Fraction of device traffic served by the rank-0 tier (placement
    /// quality).
    pub fn dram_service_ratio(&self) -> f64 {
        let dram = self.dram_reads() + self.dram_writes();
        let total: u64 =
            self.tier_reads.iter().sum::<u64>() + self.tier_writes.iter().sum::<u64>();
        // A default-constructed block has empty vectors: total == 0.
        if total == 0 {
            0.0
        } else {
            dram as f64 / total as f64
        }
    }

    /// Dynamic energy estimate in millijoules, folded over the per-tier
    /// coefficients (`energy_nj`, set from the tier specs; the legacy
    /// DDR4/3D XPoint constants when unset). What matters is the
    /// *relative* comparison across policies and topologies, as in the
    /// paper.
    ///
    /// This is the legacy **counter-based approximation**: demand
    /// traffic is folded per tier, but migration bytes are charged at
    /// the fixed rank-0-read + rank-1-write midpoint (the two-tier
    /// formula, kept bit-identical), with no per-boundary attribution.
    /// For deep stacks the accurate per-tier energy is the
    /// device-stats-based [`crate::mem::estimate_tiers`] report (DMA
    /// block transfers land in each tier's own read/write counters
    /// there), surfaced as `tier_energy_mj` in the sweep JSON.
    pub fn energy_estimate_mj(&self) -> f64 {
        // Legacy nJ per 64B access (DDR4 rank 0, 3D XPoint rank 1).
        const LEGACY: [(f64, f64); 2] = [(15.0, 18.0), (28.0, 94.0)];
        let coeff = |t: usize| -> (f64, f64) {
            if self.energy_nj.is_empty() {
                LEGACY.get(t).copied().unwrap_or(LEGACY[1])
            } else {
                self.energy_nj
                    .get(t)
                    .copied()
                    .unwrap_or(*self.energy_nj.last().unwrap())
            }
        };
        let mut nj = 0.0f64;
        for t in 0..self.tiers() {
            let (rd, wr) = coeff(t);
            nj += Self::tier(&self.tier_reads, t) as f64 * rd;
            nj += Self::tier(&self.tier_writes, t) as f64 * wr;
        }
        // Migration traffic: a block leaves one tier and lands in
        // another; charge the rank-0 read + rank-1 write midpoint, as the
        // two-tier model always has.
        nj += (self.migration_bytes as f64 / 64.0) * (coeff(0).0 + coeff(1).1) * 0.5;
        nj * 1e-6
    }

    /// One Fig 8 row: `(read_bytes, write_bytes)` seen by the HMMU.
    pub fn fig8_row(&self) -> (u64, u64) {
        (self.host_read_bytes, self.host_write_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios() {
        let mut c = HmmuCounters::with_tiers(2);
        c.tier_reads[0] = 30;
        c.tier_writes[0] = 10;
        c.tier_reads[1] = 40;
        c.tier_writes[1] = 20;
        assert!((c.dram_service_ratio() - 0.4).abs() < 1e-9);
        assert_eq!(c.dram_reads(), 30);
        assert_eq!(c.nvm_writes(), 20);
    }

    #[test]
    fn energy_nvm_writes_dominate() {
        let mut a = HmmuCounters::with_tiers(2);
        a.tier_writes[1] = 1000;
        let mut b = HmmuCounters::with_tiers(2);
        b.tier_writes[0] = 1000;
        assert!(a.energy_estimate_mj() > 4.0 * b.energy_estimate_mj());
    }

    #[test]
    fn fig8_row_sums() {
        let mut c = HmmuCounters::default();
        c.host_read_bytes = 100;
        c.host_write_bytes = 50;
        assert_eq!(c.fig8_row(), (100, 50));
        assert_eq!(c.total_host_bytes(), 150);
    }

    #[test]
    fn empty_ratio_zero() {
        assert_eq!(HmmuCounters::default().dram_service_ratio(), 0.0);
    }

    #[test]
    fn default_block_renders_like_two_tier_block() {
        // A default-constructed block (empty vectors) and an explicit
        // all-zero two-tier block must be indistinguishable on the Debug
        // equality surface.
        assert_eq!(
            format!("{:?}", HmmuCounters::default()),
            format!("{:?}", HmmuCounters::with_tiers(2)),
        );
    }

    #[test]
    fn two_tier_debug_keeps_legacy_field_names() {
        let mut c = HmmuCounters::with_tiers(2);
        c.record_tier_access(0, false);
        c.record_tier_access(1, true);
        c.record_placement(1);
        let s = format!("{c:?}");
        assert!(s.contains("dram_reads: 1"), "{s}");
        assert!(s.contains("nvm_writes: 1"), "{s}");
        assert!(s.contains("pages_placed_nvm: 1"), "{s}");
        assert!(!s.contains("tier_reads"), "two-tier must not render vectors: {s}");
    }

    #[test]
    fn deep_stack_debug_adds_tier_vectors() {
        let mut c = HmmuCounters::with_tiers(3);
        c.record_tier_access(2, false);
        let s = format!("{c:?}");
        assert!(s.contains("tier_reads: [0, 0, 1]"), "{s}");
        assert!(s.contains("dram_reads: 0"), "legacy scalars still render: {s}");
    }

    #[test]
    fn write_only_deep_tier_is_visible() {
        // Grow-on-demand recording extends only the touched vector; the
        // tier count (and so the energy fold and Debug surface) must
        // still see the deep rank.
        let mut c = HmmuCounters::default();
        c.record_tier_access(2, true);
        assert_eq!(c.tiers(), 3);
        assert!(c.energy_estimate_mj() > 0.0, "deep write must carry energy");
        let s = format!("{c:?}");
        assert!(s.contains("tier_writes: [0, 0, 1]"), "{s}");
    }

    #[test]
    fn energy_uses_per_tier_coefficients_when_set() {
        let mut cheap = HmmuCounters::with_tiers(3);
        cheap.tier_writes[2] = 1000;
        cheap.energy_nj = vec![(15.0, 18.0), (28.0, 94.0), (1.0, 1.0)];
        let mut dear = HmmuCounters::with_tiers(3);
        dear.tier_writes[2] = 1000;
        dear.energy_nj = vec![(15.0, 18.0), (28.0, 94.0), (20.0, 120.0)];
        assert!(dear.energy_estimate_mj() > 50.0 * cheap.energy_estimate_mj());
    }

    #[test]
    fn codec_round_trip_matches_debug_surface() {
        let mut c = HmmuCounters::with_tiers(3);
        c.host_reads = 11;
        c.host_writes = 7;
        c.host_read_bytes = 704;
        c.host_write_bytes = 448;
        c.record_tier_access(0, false);
        c.record_tier_access(2, true);
        c.record_placement(1);
        c.migrations = 3;
        c.migration_bytes = 3 * 8192;
        c.epochs = 2;
        c.latency.record(120);
        c.latency.record(950);
        c.reorder_wait_ns = 42;
        c.policy_wall_ns = 987_654; // excluded from the codec surface

        let mut e = Encoder::new();
        c.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = HmmuCounters::with_tiers(3);
        let mut d = Decoder::new(&bytes);
        restored.decode_state(&mut d).unwrap();
        assert!(d.is_done());

        assert_eq!(format!("{restored:?}"), format!("{c:?}"));
        assert_eq!(restored.policy_wall_ns, 0, "wall clock restarts on restore");
    }

    #[test]
    fn fault_counters_hidden_when_zero_and_round_trip() {
        // Zero fault counters must be invisible on the Debug surface
        // (golden snapshots pre-date the fault layer) ...
        let mut c = HmmuCounters::with_tiers(2);
        c.record_tier_access(0, false);
        let s = format!("{c:?}");
        assert!(!s.contains("ecc_corrected"), "{s}");
        assert!(!s.contains("link_retries"), "{s}");
        // ... and nonzero ones must render and survive the codec.
        c.ecc_corrected = 9;
        c.ecc_uncorrectable = 2;
        c.frames_retired = 2;
        c.remap_migrations = 2;
        c.remap_bytes = 2 * 4096;
        c.link_retries = 5;
        let s = format!("{c:?}");
        assert!(s.contains("ecc_corrected: 9"), "{s}");
        assert!(s.contains("frames_retired: 2"), "{s}");
        assert!(s.contains("link_retries: 5"), "{s}");

        let mut e = Encoder::new();
        c.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = HmmuCounters::with_tiers(2);
        let mut d = Decoder::new(&bytes);
        restored.decode_state(&mut d).unwrap();
        assert!(d.is_done());
        assert_eq!(format!("{restored:?}"), format!("{c:?}"));
    }

    #[test]
    fn row_hit_rate_derives_from_vectors_and_round_trips() {
        let mut c = HmmuCounters::with_tiers(2);
        c.tier_row_hits[1] = 30;
        c.tier_row_misses[1] = 10;
        assert!((c.tier_row_hit_rate(1) - 0.75).abs() < 1e-12);
        assert_eq!(c.tier_row_hit_rate(0), 0.0, "no traffic, no rate");
        // Two-tier Debug keeps the legacy layout (row vectors are a
        // deep-stack / JSON / fingerprint surface).
        let s = format!("{c:?}");
        assert!(!s.contains("tier_row_hits"), "{s}");

        let mut e = Encoder::new();
        c.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut r = HmmuCounters::with_tiers(2);
        let mut d = Decoder::new(&bytes);
        r.decode_state(&mut d).unwrap();
        assert!(d.is_done());
        assert_eq!(r.tier_row_hits, c.tier_row_hits);
        assert_eq!(r.tier_row_misses, c.tier_row_misses);
    }

    #[test]
    fn legacy_energy_constants_match_two_tier_default() {
        // Unset coefficients fall back to the pre-tier-refactor constants:
        // an explicit ddr4/xpoint pair computes the identical estimate.
        let mut a = HmmuCounters::with_tiers(2);
        a.tier_reads[0] = 123;
        a.tier_writes[0] = 45;
        a.tier_reads[1] = 67;
        a.tier_writes[1] = 89;
        a.migration_bytes = 8192;
        let mut b = a.clone();
        b.energy_nj = vec![(15.0, 18.0), (28.0, 94.0)];
        assert_eq!(a.energy_estimate_mj().to_bits(), b.energy_estimate_mj().to_bits());
    }
}
