//! HMMU performance counters (paper §II-B: "users can easily add a
//! variety of performance counters of their choice. For example, we
//! implemented counters for read/write transactions to each memory device
//! respectively, and obtained a fairly accurate estimate of the dynamic
//! power consumption").
//!
//! These counters regenerate Fig 8 (memory request bytes per workload)
//! and feed the energy estimate.

use crate::util::stats::LatencyHistogram;

/// Aggregated HMMU counters for one run.
///
/// `Debug` is implemented manually (not derived) so it renders **only
/// deterministic, simulated-time fields**: the equivalence tests and the
/// golden counter snapshots compare the Debug rendering verbatim, and the
/// host-wall-clock `policy_wall_ns` field would make byte-identical runs
/// render differently.
#[derive(Clone, Default)]
pub struct HmmuCounters {
    /// Requests received from the host (post cache filter).
    pub host_reads: u64,
    pub host_writes: u64,
    pub host_read_bytes: u64,
    pub host_write_bytes: u64,
    /// Requests forwarded per device.
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub nvm_reads: u64,
    pub nvm_writes: u64,
    /// Placement decisions.
    pub pages_placed_dram: u64,
    pub pages_placed_nvm: u64,
    /// Migration activity.
    pub migrations: u64,
    pub migration_bytes: u64,
    /// Policy epochs executed.
    pub epochs: u64,
    /// Time spent in the policy step (ns of host wall clock, for the
    /// §Perf report; not simulated time).
    pub policy_wall_ns: u64,
    /// End-to-end request latency distribution (simulated ns).
    pub latency: LatencyHistogram,
    /// Consistency mechanism cost.
    pub reorder_wait_ns: u64,
    pub fifo_full_stalls: u64,
    /// DMA conflict redirects/stalls.
    pub dma_conflict_stalls: u64,
    /// HDR FIFO slots consumed by DMA migration block transfers (only
    /// counted when `HmmuConfig::dma_hdr_occupancy` is on; exactly 4 per
    /// migrated block — two reads + two cross-writes).
    pub dma_hdr_slots: u64,
    /// DMA block transfers that stalled on a full HDR FIFO before
    /// issuing (kept separate from `fifo_full_stalls`, which counts only
    /// demand-pipeline stalls, so that series stays comparable across
    /// configurations and PRs).
    pub dma_hdr_stalls: u64,
    /// Payload bytes of migration traffic that crossed the PCIe link
    /// (only under `HmmuConfig::host_managed_dma`; the paper's
    /// device-side DMA never touches the link and keeps this 0).
    pub pcie_dma_bytes: u64,
    /// PCIe credit stalls incurred by host-managed DMA transfers (a
    /// subset of the link's total `credit_stalls`, attributed so demand
    /// vs migration link pressure can be separated).
    pub dma_link_stalls: u64,
}

impl std::fmt::Debug for HmmuCounters {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Every simulated-time field, in declaration order;
        // `policy_wall_ns` (host wall clock, nondeterministic) is
        // deliberately excluded from the equality surface. The exhaustive
        // destructure makes adding a counter without deciding its Debug
        // fate a compile error — a silently-missing field here would be
        // invisible to every Debug-equality test and golden snapshot.
        let HmmuCounters {
            host_reads,
            host_writes,
            host_read_bytes,
            host_write_bytes,
            dram_reads,
            dram_writes,
            nvm_reads,
            nvm_writes,
            pages_placed_dram,
            pages_placed_nvm,
            migrations,
            migration_bytes,
            epochs,
            policy_wall_ns: _,
            latency,
            reorder_wait_ns,
            fifo_full_stalls,
            dma_conflict_stalls,
            dma_hdr_slots,
            dma_hdr_stalls,
            pcie_dma_bytes,
            dma_link_stalls,
        } = self;
        f.debug_struct("HmmuCounters")
            .field("host_reads", host_reads)
            .field("host_writes", host_writes)
            .field("host_read_bytes", host_read_bytes)
            .field("host_write_bytes", host_write_bytes)
            .field("dram_reads", dram_reads)
            .field("dram_writes", dram_writes)
            .field("nvm_reads", nvm_reads)
            .field("nvm_writes", nvm_writes)
            .field("pages_placed_dram", pages_placed_dram)
            .field("pages_placed_nvm", pages_placed_nvm)
            .field("migrations", migrations)
            .field("migration_bytes", migration_bytes)
            .field("epochs", epochs)
            .field("latency", latency)
            .field("reorder_wait_ns", reorder_wait_ns)
            .field("fifo_full_stalls", fifo_full_stalls)
            .field("dma_conflict_stalls", dma_conflict_stalls)
            .field("dma_hdr_slots", dma_hdr_slots)
            .field("dma_hdr_stalls", dma_hdr_stalls)
            .field("pcie_dma_bytes", pcie_dma_bytes)
            .field("dma_link_stalls", dma_link_stalls)
            .finish_non_exhaustive()
    }
}

impl HmmuCounters {
    pub fn total_host_requests(&self) -> u64 {
        self.host_reads + self.host_writes
    }

    pub fn total_host_bytes(&self) -> u64 {
        self.host_read_bytes + self.host_write_bytes
    }

    /// Fraction of device traffic served by DRAM (placement quality).
    pub fn dram_service_ratio(&self) -> f64 {
        let dram = self.dram_reads + self.dram_writes;
        let total = dram + self.nvm_reads + self.nvm_writes;
        if total == 0 {
            0.0
        } else {
            dram as f64 / total as f64
        }
    }

    /// Dynamic energy estimate in millijoules. Per-access energies are
    /// DDR4 vs 3D XPoint class constants (pJ/bit ballpark): what matters
    /// is the *relative* comparison across policies, as in the paper.
    pub fn energy_estimate_mj(&self) -> f64 {
        // nJ per 64B access.
        const DRAM_RD: f64 = 15.0;
        const DRAM_WR: f64 = 18.0;
        const NVM_RD: f64 = 28.0;
        const NVM_WR: f64 = 94.0; // PCM-class write energy dominates
        let nj = self.dram_reads as f64 * DRAM_RD
            + self.dram_writes as f64 * DRAM_WR
            + self.nvm_reads as f64 * NVM_RD
            + self.nvm_writes as f64 * NVM_WR
            + (self.migration_bytes as f64 / 64.0) * (DRAM_RD + NVM_WR) * 0.5;
        nj * 1e-6
    }

    /// One Fig 8 row: `(read_bytes, write_bytes)` seen by the HMMU.
    pub fn fig8_row(&self) -> (u64, u64) {
        (self.host_read_bytes, self.host_write_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios() {
        let mut c = HmmuCounters::default();
        c.dram_reads = 30;
        c.dram_writes = 10;
        c.nvm_reads = 40;
        c.nvm_writes = 20;
        assert!((c.dram_service_ratio() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn energy_nvm_writes_dominate() {
        let mut a = HmmuCounters::default();
        a.nvm_writes = 1000;
        let mut b = HmmuCounters::default();
        b.dram_writes = 1000;
        assert!(a.energy_estimate_mj() > 4.0 * b.energy_estimate_mj());
    }

    #[test]
    fn fig8_row_sums() {
        let mut c = HmmuCounters::default();
        c.host_read_bytes = 100;
        c.host_write_bytes = 50;
        assert_eq!(c.fig8_row(), (100, 50));
        assert_eq!(c.total_host_bytes(), 150);
    }

    #[test]
    fn empty_ratio_zero() {
        assert_eq!(HmmuCounters::default().dram_service_ratio(), 0.0);
    }
}
