//! Tag-matching memory-consistency mechanism (paper §III-C, Fig 3).
//!
//! Requests split across the DRAM and NVM channels can complete out of
//! order (a later DRAM read returns before an earlier NVM read). The OS
//! sees one memory, so completions must return **in request order**. The
//! paper stores each request's header in the HDR FIFO and uses it as the
//! tag: media access proceeds out of order, but responses drain through
//! the FIFO head.
//!
//! The model: `issue()` allocates a FIFO slot (stalling when the FIFO is
//! full — backpressure), `complete()` records the media completion time,
//! and the release time of each response is
//! `max(own completion, previous release)` — i.e. in-order drain.

use crate::sim::Time;
use crate::util::codec::{CodecState, Decoder, Encoder};
use crate::util::error::Result;
use std::collections::VecDeque;

/// One in-flight request tracked by the HDR FIFO.
#[derive(Clone, Copy, Debug)]
struct HdrEntry {
    tag: u16,
    /// Media completion time (None until `complete`).
    done: Option<Time>,
}

/// The HDR-FIFO tag matcher.
#[derive(Clone, Debug)]
pub struct TagMatcher {
    fifo: VecDeque<HdrEntry>,
    // audit: allow(codec-coverage) — geometry, validated not restored
    depth: usize,
    next_tag: u16,
    /// Release time of the most recently drained response.
    last_release: Time,
    /// Total responses drained.
    pub completed: u64,
    /// Extra ns responses spent waiting for FIFO-order drain (the cost of
    /// consistency vs raw out-of-order return).
    pub reorder_wait_ns: u64,
    /// Issue stalls due to a full FIFO.
    pub fifo_full_stalls: u64,
}

impl TagMatcher {
    pub fn new(depth: usize) -> Self {
        assert!(depth > 0);
        TagMatcher {
            fifo: VecDeque::with_capacity(depth),
            depth,
            next_tag: 0,
            last_release: 0,
            completed: 0,
            reorder_wait_ns: 0,
            fifo_full_stalls: 0,
        }
    }

    /// True if a new request can issue (FIFO has a slot).
    pub fn can_issue(&self) -> bool {
        self.fifo.len() < self.depth
    }

    /// Allocate a tag for a new request. Returns the tag. Caller must
    /// check [`Self::can_issue`]; issuing into a full FIFO is a model bug.
    pub fn issue(&mut self) -> u16 {
        assert!(self.can_issue(), "HDR FIFO overflow");
        let tag = self.next_tag;
        self.next_tag = self.next_tag.wrapping_add(1);
        self.fifo.push_back(HdrEntry { tag, done: None });
        tag
    }

    /// If the FIFO is full, the time until a slot frees given that the
    /// head's media access completes at `head_done_hint`. Used by the
    /// pipeline to compute backpressure stalls.
    pub fn note_full_stall(&mut self) {
        self.fifo_full_stalls += 1;
    }

    /// Record the media completion of `tag` at `done`; returns the
    /// response release times of every entry that can now drain (in
    /// order). The caller forwards them to TX.
    pub fn complete(&mut self, tag: u16, done: Time) -> Vec<(u16, Time)> {
        // Find and stamp the entry (it is somewhere in the FIFO).
        let Some(e) = self.fifo.iter_mut().find(|e| e.tag == tag) else {
            panic!("completion for unknown tag {tag}");
        };
        debug_assert!(e.done.is_none(), "double completion for tag {tag}");
        e.done = Some(done);

        // Drain from the head while completed.
        let mut released = Vec::new();
        while let Some(head) = self.fifo.front() {
            let Some(head_done) = head.done else { break };
            let release = head_done.max(self.last_release);
            self.reorder_wait_ns += release - head_done;
            self.last_release = release;
            self.completed += 1;
            released.push((head.tag, release));
            self.fifo.pop_front();
        }
        released
    }

    /// Issue a tag at `now`, **blocking** (advancing simulated time)
    /// until a slot frees when the FIFO is full — instead of tripping the
    /// overflow assert as a bare `issue()` after `note_full_stall()` did.
    ///
    /// The slot frees when the head response drains. If the head is
    /// already stamped, its own completion time is used; if not, the
    /// caller's occupancy model — which knows every outstanding
    /// completion — supplies `head_done_hint`. Entries stamped behind the
    /// head drain with it (in-order semantics, matching [`Self::complete`]).
    /// Counts the stall in `fifo_full_stalls`. Returns `(tag, issue_time)`
    /// with `issue_time == now` when no stall occurred.
    pub fn issue_blocking(&mut self, now: Time, head_done_hint: Time) -> (u16, Time) {
        let mut t = now;
        if !self.can_issue() {
            self.note_full_stall();
            let head_done = self
                .fifo
                .front()
                .expect("full FIFO must have a head")
                .done
                .unwrap_or(head_done_hint);
            let release = head_done.max(self.last_release);
            self.reorder_wait_ns += release - head_done;
            self.last_release = release;
            self.completed += 1;
            self.fifo.pop_front();
            t = t.max(release);
            // Anything stamped right behind the head drains with it.
            while let Some(head) = self.fifo.front() {
                let Some(done) = head.done else { break };
                let release = done.max(self.last_release);
                self.reorder_wait_ns += release - done;
                self.last_release = release;
                self.completed += 1;
                self.fifo.pop_front();
            }
        }
        (self.issue(), t)
    }

    /// Allocation-free fast path for the synchronous pipeline (§Perf):
    /// when `tag` is the FIFO head and nothing else is pending, complete
    /// and drain it in one step, returning its release time. Falls back
    /// to the general path otherwise.
    #[inline]
    pub fn complete_inline(&mut self, tag: u16, done: Time) -> Time {
        if self.fifo.len() == 1 && self.fifo.front().map(|e| e.tag) == Some(tag) {
            self.fifo.pop_front();
            let release = done.max(self.last_release);
            self.reorder_wait_ns += release - done;
            self.last_release = release;
            self.completed += 1;
            release
        } else {
            let released = self.complete(tag, done);
            released
                .iter()
                .find(|(t, _)| *t == tag)
                .map(|(_, r)| *r)
                .unwrap_or_else(|| released.last().map(|(_, r)| *r).unwrap_or(done))
        }
    }

    /// Outstanding (issued, not yet drained) requests.
    pub fn outstanding(&self) -> usize {
        self.fifo.len()
    }

    /// Release time of the head if it completed now (for stall estimates).
    pub fn last_release(&self) -> Time {
        self.last_release
    }
}

impl CodecState for TagMatcher {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_len(self.fifo.len());
        for entry in &self.fifo {
            e.put_u16(entry.tag);
            e.put_bool(entry.done.is_some());
            e.put_u64(entry.done.unwrap_or(0));
        }
        e.put_u16(self.next_tag);
        e.put_u64(self.last_release);
        e.put_u64(self.completed);
        e.put_u64(self.reorder_wait_ns);
        e.put_u64(self.fifo_full_stalls);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let n = d.len()?;
        if n > self.depth {
            crate::bail!(
                "checkpoint geometry mismatch: {n} HDR FIFO entries exceed depth {}",
                self.depth
            );
        }
        self.fifo.clear();
        for _ in 0..n {
            let tag = d.u16()?;
            let stamped = d.bool()?;
            let done = d.u64()?;
            self.fifo.push_back(HdrEntry {
                tag,
                done: stamped.then_some(done),
            });
        }
        self.next_tag = d.u16()?;
        self.last_release = d.u64()?;
        self.completed = d.u64()?;
        self.reorder_wait_ns = d.u64()?;
        self.fifo_full_stalls = d.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_order_completions_drain_immediately() {
        let mut tm = TagMatcher::new(8);
        let t0 = tm.issue();
        let t1 = tm.issue();
        let r0 = tm.complete(t0, 100);
        assert_eq!(r0, vec![(t0, 100)]);
        let r1 = tm.complete(t1, 200);
        assert_eq!(r1, vec![(t1, 200)]);
        assert_eq!(tm.reorder_wait_ns, 0);
    }

    #[test]
    fn fig3_out_of_order_is_held() {
        // Fig 3 scenario: req0 -> NVM (slow), req1 -> DRAM (fast).
        let mut tm = TagMatcher::new(8);
        let t0 = tm.issue(); // NVM
        let t1 = tm.issue(); // DRAM
        // DRAM completes first: nothing drains (head t0 incomplete).
        assert_eq!(tm.complete(t1, 50), vec![]);
        // NVM completes: both drain, t1 held until after t0's release.
        let r = tm.complete(t0, 300);
        assert_eq!(r, vec![(t0, 300), (t1, 300)]);
        assert_eq!(tm.reorder_wait_ns, 250); // t1 waited 300-50
        assert_eq!(tm.completed, 2);
    }

    #[test]
    fn release_times_monotone() {
        let mut tm = TagMatcher::new(16);
        let tags: Vec<u16> = (0..10).map(|_| tm.issue()).collect();
        // Complete in reverse order with decreasing times.
        let mut all = Vec::new();
        for (i, &tag) in tags.iter().enumerate().rev() {
            all.extend(tm.complete(tag, 1000 - i as u64 * 50));
        }
        // Everything drains at the end, in tag order, non-decreasing time.
        assert_eq!(all.len(), 10);
        for w in all.windows(2) {
            assert!(w[0].1 <= w[1].1, "release times must be monotone");
            assert!(w[0].0 < w[1].0, "tags must drain in order");
        }
    }

    #[test]
    fn fifo_capacity_enforced() {
        let mut tm = TagMatcher::new(2);
        tm.issue();
        tm.issue();
        assert!(!tm.can_issue());
        assert_eq!(tm.outstanding(), 2);
    }

    #[test]
    #[should_panic]
    fn overflow_panics() {
        let mut tm = TagMatcher::new(1);
        tm.issue();
        tm.issue();
    }

    #[test]
    #[should_panic]
    fn unknown_tag_panics() {
        let mut tm = TagMatcher::new(2);
        tm.complete(99, 10);
    }

    #[test]
    fn issue_blocking_fast_path_no_stall() {
        let mut tm = TagMatcher::new(2);
        let (_, t) = tm.issue_blocking(42, 999);
        assert_eq!(t, 42);
        assert_eq!(tm.fifo_full_stalls, 0);
        assert_eq!(tm.outstanding(), 1);
    }

    #[test]
    fn issue_blocking_waits_for_unstamped_head() {
        // Regression: a full FIFO used to panic via the bare `issue()`
        // fallback; now the issue blocks until the earliest outstanding
        // completion (the occupancy model's hint for the unstamped head).
        let mut tm = TagMatcher::new(2);
        tm.issue();
        tm.issue();
        assert!(!tm.can_issue());
        let (_, t) = tm.issue_blocking(100, 500);
        assert_eq!(t, 500, "must block until the head drains");
        assert_eq!(tm.fifo_full_stalls, 1);
        assert_eq!(tm.completed, 1);
        assert_eq!(tm.last_release(), 500);
        assert_eq!(tm.outstanding(), 2); // drained head + new issue
    }

    #[test]
    fn issue_blocking_drains_stamped_followers_in_order() {
        let mut tm = TagMatcher::new(3);
        let _a = tm.issue();
        let b = tm.issue();
        let c = tm.issue();
        // b and c completed early but are held behind the unstamped head.
        assert_eq!(tm.complete(b, 50), vec![]);
        assert_eq!(tm.complete(c, 60), vec![]);
        assert!(!tm.can_issue());
        let (_, t) = tm.issue_blocking(10, 200);
        // Slot freed when the head drained at 200; b and c drain behind
        // it at the same release (in-order hold).
        assert_eq!(t, 200);
        assert_eq!(tm.completed, 3);
        assert_eq!(tm.last_release(), 200);
        assert_eq!(tm.outstanding(), 1); // only the new issue remains
        assert_eq!(tm.fifo_full_stalls, 1);
    }

    #[test]
    fn codec_round_trip_preserves_drain_order() {
        // Snapshot mid-flight with a stamped entry held behind an
        // unstamped head; the restored matcher must drain identically.
        let mut tm = TagMatcher::new(8);
        let a = tm.issue();
        let b = tm.issue();
        assert_eq!(tm.complete(b, 50), vec![]);

        let mut e = Encoder::new();
        tm.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = TagMatcher::new(8);
        let mut d = Decoder::new(&bytes);
        restored.decode_state(&mut d).unwrap();
        assert!(d.is_done());

        let want = tm.complete(a, 300);
        let got = restored.complete(a, 300);
        assert_eq!(got, want);
        assert_eq!(restored.reorder_wait_ns, tm.reorder_wait_ns);
        assert_eq!(restored.completed, tm.completed);
    }

    #[test]
    fn codec_rejects_overdeep_fifo() {
        let mut tm = TagMatcher::new(4);
        tm.issue();
        tm.issue();
        tm.issue();
        let mut e = Encoder::new();
        tm.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut small = TagMatcher::new(2);
        assert!(small.decode_state(&mut Decoder::new(&bytes)).is_err());
    }

    #[test]
    fn tag_wraparound() {
        let mut tm = TagMatcher::new(4);
        tm.next_tag = u16::MAX - 1;
        let a = tm.issue();
        let b = tm.issue();
        assert_eq!(a, u16::MAX - 1);
        assert_eq!(b, u16::MAX);
        let c = tm.issue();
        assert_eq!(c, 0);
        tm.complete(a, 10);
        tm.complete(b, 20);
        let r = tm.complete(c, 30);
        assert_eq!(r, vec![(0, 30)]);
    }
}
