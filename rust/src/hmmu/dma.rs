//! DMA engine for page migration (paper §III-D).
//!
//! Swaps pages between **any two tiers** of the stack in 512-byte
//! sub-blocks (the engine is tier-agnostic: the mappings carry the tier,
//! and the HMMU's `issue` callback routes each block access to the right
//! memory controller), tracking the precise swap progress so that memory
//! requests hitting an in-flight page are redirected correctly:
//!
//! - request behind the progress pointer (block already copied) → go to
//!   the **destination** device;
//! - request ahead of the progress pointer (block not yet copied) → go to
//!   the **original** device (writes land there and are migrated with the
//!   block later);
//! - request inside the block currently being transferred → **stall**
//!   until that block commits, then go to the destination.
//!
//! The paper: "We spent considerable time to design and verify the logic
//! design to ensure all possible cases are covered" — the property tests
//! in `rust/tests/` sweep the interleavings.
//!
//! The engine itself is transport-agnostic: per-block timing comes from
//! the HMMU's `issue` callback, which charges each access at the memory
//! controllers (the paper's device-side DMA) — or, under
//! `HmmuConfig::host_managed_dma`, additionally at the PCIe link, so
//! migration bandwidth contends with demand traffic
//! (`HmmuCounters::pcie_dma_bytes` / `dma_link_stalls`). Nothing here
//! changes between the two modes; only the callback's cost model does.

use super::redirection::{Device, Mapping, TierId};
use crate::mem::AccessKind;
use crate::sim::Time;
use crate::util::codec::{check_len, CodecState, Decoder, Encoder};
use crate::util::error::Result;

/// Routing decision for a request touching an in-flight swap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DmaRoute {
    /// Page not involved in any active swap.
    NotInvolved,
    /// Use the page's original mapping.
    UseOriginal,
    /// Use the swap-partner's frame (block already moved).
    UseDestination,
    /// Wait until `0` (block mid-transfer), then use the destination.
    Stall(Time),
}

/// An in-flight (or completed-but-uncommitted) page swap.
#[derive(Clone, Debug)]
pub struct ActiveSwap {
    pub page_a: u64,
    pub page_b: u64,
    /// Original mappings at swap start (table still holds these until
    /// commit).
    pub map_a: Mapping,
    pub map_b: Mapping,
    /// Per-block transfer windows: block i is "in flight" during
    /// `[start[i], done[i])` and committed at `done[i]`.
    start: Vec<Time>,
    done: Vec<Time>,
    /// Completion of the whole swap.
    pub finished: Time,
}

impl ActiveSwap {
    fn involves(&self, page: u64) -> bool {
        page == self.page_a || page == self.page_b
    }

    /// Route a request at byte `offset` within the page at time `now`.
    fn route(&self, offset: u64, block_bytes: u64, now: Time) -> DmaRoute {
        let b = (offset / block_bytes) as usize;
        if now >= self.done[b] {
            DmaRoute::UseDestination
        } else if now >= self.start[b] {
            DmaRoute::Stall(self.done[b])
        } else {
            DmaRoute::UseOriginal
        }
    }

    /// The frame a request for `page` should use once the block has moved.
    pub fn destination(&self, page: u64) -> Mapping {
        if page == self.page_a {
            self.map_b
        } else {
            self.map_a
        }
    }

    /// The original frame for `page`.
    pub fn original(&self, page: u64) -> Mapping {
        if page == self.page_a {
            self.map_a
        } else {
            self.map_b
        }
    }
}

/// Cap on the recycled-buffer free list: enough for every swap a default
/// epoch can launch (`migrations_per_epoch` = 32) with headroom; beyond
/// this, returned buffers are simply dropped.
const FREE_BUF_CAP: usize = 64;

/// The DMA engine: at most `max_inflight` concurrent swaps; per-block
/// timing is produced by the HMMU's memory controllers via the `issue`
/// callback so DMA traffic contends with demand traffic at the devices
/// (as in hardware — a shared DDR interface).
#[derive(Clone)]
pub struct DmaEngine {
    // audit: allow(codec-coverage) — geometry, re-derived from config
    block_bytes: u64,
    // audit: allow(codec-coverage) — geometry, re-derived from config
    page_bytes: u64,
    /// Double-buffering: overlap block N's writes with block N+1's reads
    /// (requires 2× block buffer, which the paper's 8 KiB buffer allows).
    // audit: allow(codec-coverage) — configuration, re-derived from config
    pub pipelined: bool,
    active: Vec<ActiveSwap>,
    /// Arena of recycled per-swap block-window buffers (§Perf): committed
    /// swaps return their `start`/`done` vectors here instead of dropping
    /// them, so steady-state migration launches allocate nothing.
    // audit: allow(codec-coverage) — allocation cache, contents never observable
    free_bufs: Vec<(Vec<Time>, Vec<Time>)>,
    pub swaps_started: u64,
    pub swaps_committed: u64,
    pub blocks_moved: u64,
    pub bytes_moved: u64,
    pub busy_ns: u64,
    pub conflict_stalls: u64,
    /// Swap launches served from the free list (no allocation).
    pub bufs_recycled: u64,
}

impl DmaEngine {
    pub fn new(block_bytes: u64, page_bytes: u64, pipelined: bool) -> Self {
        assert!(block_bytes > 0 && page_bytes % block_bytes == 0);
        DmaEngine {
            block_bytes,
            page_bytes,
            pipelined,
            active: Vec::new(),
            free_bufs: Vec::new(),
            swaps_started: 0,
            swaps_committed: 0,
            blocks_moved: 0,
            bytes_moved: 0,
            busy_ns: 0,
            conflict_stalls: 0,
            bufs_recycled: 0,
        }
    }

    pub fn blocks_per_page(&self) -> u64 {
        self.page_bytes / self.block_bytes
    }

    /// Start swapping host pages `page_a` (mapped `map_a`) and `page_b`
    /// (`map_b`) at `now`. `issue(device, dev_addr, kind, bytes, at)`
    /// returns the completion time of one device access.
    ///
    /// Returns the swap completion time.
    pub fn start_swap<F>(
        &mut self,
        page_a: u64,
        map_a: Mapping,
        page_b: u64,
        map_b: Mapping,
        now: Time,
        issue: &mut F,
    ) -> Time
    where
        F: FnMut(Device, u64, AccessKind, u64, Time) -> Time,
    {
        assert!(page_a != page_b);
        debug_assert!(
            !self.is_active(page_a) && !self.is_active(page_b),
            "page already migrating"
        );
        let nblocks = self.blocks_per_page() as usize;
        // Reuse a committed swap's buffers when available (zero-alloc
        // steady state); first launches allocate the arena entries.
        let (mut start, mut done) = match self.free_bufs.pop() {
            Some(bufs) => {
                self.bufs_recycled += 1;
                bufs
            }
            None => (Vec::with_capacity(nblocks), Vec::with_capacity(nblocks)),
        };
        start.clear();
        done.clear();
        let base_a = map_a.frame as u64 * self.page_bytes;
        let base_b = map_b.frame as u64 * self.page_bytes;

        let mut t = now;
        let mut prev_reads_done = now;
        for i in 0..nblocks {
            let off = i as u64 * self.block_bytes;
            let block_start = t;
            // Read both sides into the internal buffer.
            let ra = issue(map_a.device, base_a + off, AccessKind::Read, self.block_bytes, block_start);
            let rb = issue(map_b.device, base_b + off, AccessKind::Read, self.block_bytes, block_start);
            let reads_done = ra.max(rb);
            // Cross-write from the buffer.
            let wa = issue(map_b.device, base_b + off, AccessKind::Write, self.block_bytes, reads_done);
            let wb = issue(map_a.device, base_a + off, AccessKind::Write, self.block_bytes, reads_done);
            let block_done = wa.max(wb);
            start.push(block_start);
            done.push(block_done);
            self.blocks_moved += 1;
            self.bytes_moved += 2 * self.block_bytes;
            // Next block: pipelined mode overlaps its reads with our
            // writes (reads of i+1 start when reads of i finished);
            // sequential mode waits for the full block.
            t = if self.pipelined {
                reads_done.max(prev_reads_done)
            } else {
                block_done
            };
            prev_reads_done = reads_done;
        }
        let finished = *done.last().unwrap();
        self.busy_ns += finished - now;
        self.swaps_started += 1;
        self.active.push(ActiveSwap {
            page_a,
            page_b,
            map_a,
            map_b,
            start,
            done,
            finished,
        });
        finished
    }

    /// Is `page` part of an uncommitted swap?
    pub fn is_active(&self, page: u64) -> bool {
        self.active.iter().any(|s| s.involves(page))
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Route a request for host `page` at byte `offset` at time `now`.
    /// Returns the routing decision plus the swap's index for mapping
    /// resolution.
    pub fn route(&mut self, page: u64, offset: u64, now: Time) -> (DmaRoute, Option<&ActiveSwap>) {
        // Rev: the newest swap involving the page governs (re-migration
        // cannot start while active, but after commit an old record may
        // briefly coexist before drain).
        if let Some(s) = self.active.iter().rev().find(|s| s.involves(page)) {
            let r = s.route(offset, self.block_bytes, now);
            if matches!(r, DmaRoute::Stall(_)) {
                self.conflict_stalls += 1;
            }
            (r, Some(s))
        } else {
            (DmaRoute::NotInvolved, None)
        }
    }

    /// Remove swaps fully committed by `now`, returning their page pairs
    /// so the caller can swap the redirection-table entries. Committed
    /// swaps' block-window buffers go back to the free list. Called per
    /// request: the no-active fast path returns an unallocated `Vec`.
    pub fn drain_committed(&mut self, now: Time) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        if self.active.is_empty() {
            return out;
        }
        // Index walk instead of `retain`: we need ownership of removed
        // entries to recycle their buffers, and `remove` (not
        // `swap_remove`) preserves the newest-swap-last order `route`
        // relies on.
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished <= now {
                let s = self.active.remove(i);
                out.push((s.page_a, s.page_b));
                if self.free_bufs.len() < FREE_BUF_CAP {
                    let ActiveSwap { start, done, .. } = s;
                    self.free_bufs.push((start, done));
                }
            } else {
                i += 1;
            }
        }
        self.swaps_committed += out.len() as u64;
        out
    }

    /// Earliest completion among active swaps.
    pub fn next_commit(&self) -> Option<Time> {
        self.active.iter().map(|s| s.finished).min()
    }
}

fn encode_mapping(e: &mut Encoder, m: Mapping) {
    e.put_u8(m.device.rank());
    e.put_u32(m.frame);
}

fn decode_mapping(d: &mut Decoder) -> Result<Mapping> {
    let rank = d.u8()?;
    let frame = d.u32()?;
    Ok(Mapping {
        device: TierId(rank),
        frame,
    })
}

impl CodecState for DmaEngine {
    fn encode_state(&self, e: &mut Encoder) {
        // `block_bytes`/`page_bytes`/`pipelined` are configuration; the
        // `free_bufs` arena is a pure allocation-recycling optimization
        // (restored engines refill it as swaps commit) — neither is
        // serialized. Active swaps and counters are the state.
        e.put_len(self.active.len());
        for s in &self.active {
            e.put_u64(s.page_a);
            e.put_u64(s.page_b);
            encode_mapping(e, s.map_a);
            encode_mapping(e, s.map_b);
            e.put_u64_slice(&s.start);
            e.put_u64_slice(&s.done);
            e.put_u64(s.finished);
        }
        e.put_u64(self.swaps_started);
        e.put_u64(self.swaps_committed);
        e.put_u64(self.blocks_moved);
        e.put_u64(self.bytes_moved);
        e.put_u64(self.busy_ns);
        e.put_u64(self.conflict_stalls);
        e.put_u64(self.bufs_recycled);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let n = d.len()?;
        let nblocks = self.blocks_per_page() as usize;
        let mut active = Vec::with_capacity(n);
        for _ in 0..n {
            let page_a = d.u64()?;
            let page_b = d.u64()?;
            let map_a = decode_mapping(d)?;
            let map_b = decode_mapping(d)?;
            let start = d.u64_vec()?;
            let done = d.u64_vec()?;
            check_len("dma swap block windows", nblocks, start.len())?;
            check_len("dma swap block windows", nblocks, done.len())?;
            let finished = d.u64()?;
            active.push(ActiveSwap {
                page_a,
                page_b,
                map_a,
                map_b,
                start,
                done,
                finished,
            });
        }
        self.active = active;
        self.swaps_started = d.u64()?;
        self.swaps_committed = d.u64()?;
        self.blocks_moved = d.u64()?;
        self.bytes_moved = d.u64()?;
        self.busy_ns = d.u64()?;
        self.conflict_stalls = d.u64()?;
        self.bufs_recycled = d.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn maps() -> (Mapping, Mapping) {
        (
            Mapping {
                device: Device::Nvm,
                frame: 3,
            },
            Mapping {
                device: Device::Dram,
                frame: 1,
            },
        )
    }

    /// Fixed-latency issue fn: reads 30ns, writes 40ns, no contention.
    fn fixed_issue(_d: Device, _a: u64, k: AccessKind, _b: u64, at: Time) -> Time {
        at + if k.is_write() { 40 } else { 30 }
    }

    #[test]
    fn swap_timing_sequential() {
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        let done = dma.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);
        // 8 blocks × (30 read + 40 write) = 560
        assert_eq!(done, 560);
        assert_eq!(dma.blocks_moved, 8);
        assert_eq!(dma.bytes_moved, 2 * 4096);
    }

    #[test]
    fn pipelined_faster_than_sequential() {
        let (ma, mb) = maps();
        let mut seq = DmaEngine::new(512, 4096, false);
        let t_seq = seq.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);
        let mut pipe = DmaEngine::new(512, 4096, true);
        let t_pipe = pipe.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);
        assert!(t_pipe < t_seq, "pipelined {t_pipe} vs sequential {t_seq}");
    }

    #[test]
    fn route_before_during_after() {
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        dma.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);
        // Block 0 is in flight during [0, 70).
        let (r, _) = dma.route(10, 0, 0);
        assert_eq!(r, DmaRoute::Stall(70));
        // Block 7 has not started at t=0 (starts at 490).
        let (r, _) = dma.route(10, 7 * 512, 0);
        assert_eq!(r, DmaRoute::UseOriginal);
        // Block 0 committed by t=100.
        let (r, s) = dma.route(10, 0, 100);
        assert_eq!(r, DmaRoute::UseDestination);
        assert_eq!(s.unwrap().destination(10), mb);
        // Unrelated page.
        let (r, _) = dma.route(99, 0, 50);
        assert_eq!(r, DmaRoute::NotInvolved);
    }

    #[test]
    fn partner_page_routes_symmetrically() {
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        dma.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);
        let (r, s) = dma.route(20, 0, 100);
        assert_eq!(r, DmaRoute::UseDestination);
        assert_eq!(s.unwrap().destination(20), ma); // b's data now in a's frame
        assert_eq!(s.unwrap().original(20), mb);
    }

    #[test]
    fn drain_commits_after_finish() {
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        let done = dma.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);
        assert!(dma.drain_committed(done - 1).is_empty());
        let committed = dma.drain_committed(done);
        assert_eq!(committed, vec![(10, 20)]);
        assert!(!dma.is_active(10));
        assert_eq!(dma.swaps_committed, 1);
        // Idempotent.
        assert!(dma.drain_committed(done + 100).is_empty());
    }

    #[test]
    fn stall_counter_increments() {
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        dma.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);
        let before = dma.conflict_stalls;
        dma.route(10, 0, 0); // in-flight block
        assert_eq!(dma.conflict_stalls, before + 1);
    }

    #[test]
    fn contention_visible_to_issue_fn() {
        // The issue closure sees DMA traffic: count accesses.
        let mut count = 0u64;
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        dma.start_swap(1, ma, 2, mb, 0, &mut |_d, _a, _k, _b, at| {
            count += 1;
            at + 10
        });
        assert_eq!(count, 8 * 4); // 8 blocks × (2 reads + 2 writes)
    }

    #[test]
    fn swap_buffers_recycle_after_commit() {
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        // First swap allocates; after its commit, subsequent swaps are
        // served from the free list (steady state allocates nothing).
        let done = dma.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);
        assert_eq!(dma.bufs_recycled, 0);
        dma.drain_committed(done);
        for k in 0..5u64 {
            let t0 = (k + 1) * 10_000;
            let d = dma.start_swap(30 + 2 * k, ma, 31 + 2 * k, mb, t0, &mut fixed_issue);
            assert_eq!(dma.bufs_recycled, k + 1, "swap {k} must reuse a buffer");
            dma.drain_committed(d);
        }
        // Recycled buffers carry full per-block windows for the new swap.
        let d = dma.start_swap(50, ma, 60, mb, 100_000, &mut fixed_issue);
        let (r, _) = dma.route(50, 7 * 512, d);
        assert_eq!(r, DmaRoute::UseDestination);
    }

    #[test]
    fn codec_round_trip_preserves_inflight_routing() {
        // Snapshot with a swap mid-flight; the restored engine must make
        // identical routing decisions and commit at the same time.
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        let done = dma.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);

        let mut e = Encoder::new();
        dma.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = DmaEngine::new(512, 4096, false);
        let mut d = Decoder::new(&bytes);
        restored.decode_state(&mut d).unwrap();
        assert!(d.is_done());

        for &(off, t) in &[(0u64, 0u64), (7 * 512, 0), (0, 100), (7 * 512, done)] {
            let (want, _) = dma.route(10, off, t);
            let (got, _) = restored.route(10, off, t);
            assert_eq!(got, want, "offset {off} at t={t}");
        }
        assert_eq!(restored.next_commit(), dma.next_commit());
        assert_eq!(restored.drain_committed(done), vec![(10, 20)]);
        assert_eq!(restored.swaps_committed, dma.swaps_committed + 1);
    }

    #[test]
    fn codec_rejects_block_count_mismatch() {
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        dma.start_swap(10, ma, 20, mb, 0, &mut fixed_issue);
        let mut e = Encoder::new();
        dma.encode_state(&mut e);
        let bytes = e.into_bytes();
        // An engine with a different blocks-per-page geometry refuses.
        let mut wrong = DmaEngine::new(1024, 4096, false);
        assert!(wrong.decode_state(&mut Decoder::new(&bytes)).is_err());
    }

    #[test]
    fn progress_is_monotone() {
        let mut dma = DmaEngine::new(512, 4096, false);
        let (ma, mb) = maps();
        dma.start_swap(10, ma, 20, mb, 5, &mut fixed_issue);
        let s = &dma.active[0];
        for i in 1..s.done.len() {
            assert!(s.start[i] >= s.start[i - 1]);
            assert!(s.done[i] > s.done[i - 1]);
            assert!(s.start[i] >= s.done[i - 1]); // sequential mode
        }
    }
}
