//! Hint-aware first-touch policy (paper §III-G): the extended malloc API
//! populates device preferences "through the stack to the hardware hybrid
//! memory controller". Pages with a hint honor it; unhinted pages behave
//! like first-touch.

use super::{Device, PlacementPolicy, PolicyView};
use crate::alloc::Placement;
use crate::util::codec::{CodecState, Decoder, Encoder};
use crate::util::error::Result;
use std::collections::HashSet;

#[derive(Clone, Default)]
pub struct HintsPolicy {
    /// Pages pinned to DRAM (never offered as demotion victims).
    pinned: HashSet<u64>,
}

impl HintsPolicy {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_pinned(&self, page: u64) -> bool {
        self.pinned.contains(&page)
    }
}

impl PlacementPolicy for HintsPolicy {
    fn name(&self) -> &'static str {
        "hints"
    }

    fn place(&mut self, page: u64, hint: Placement) -> Device {
        match hint {
            Placement::PreferNvm => Device::Nvm,
            Placement::PinDram => {
                self.pinned.insert(page);
                Device::Dram
            }
            Placement::PreferDram | Placement::Any => Device::Dram,
        }
    }

    fn record_access(&mut self, _page: u64, _is_write: bool) {}

    fn epoch(&mut self, _view: &PolicyView) -> &[(u64, u64)] {
        &[]
    }
}

impl CodecState for HintsPolicy {
    fn encode_state(&self, e: &mut Encoder) {
        // Pinned set sorted: same state ⇒ same bytes regardless of
        // HashSet iteration order.
        let mut pinned: Vec<u64> = self.pinned.iter().copied().collect();
        pinned.sort_unstable();
        e.put_u64_slice(&pinned);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.pinned = d.u64_vec()?.into_iter().collect();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn honors_hints() {
        let mut p = HintsPolicy::new();
        assert_eq!(p.place(1, Placement::PreferNvm), Device::Nvm);
        assert_eq!(p.place(2, Placement::PreferDram), Device::Dram);
        assert_eq!(p.place(3, Placement::PinDram), Device::Dram);
        assert_eq!(p.place(4, Placement::Any), Device::Dram);
        assert!(p.is_pinned(3));
        assert!(!p.is_pinned(2));
    }
}
