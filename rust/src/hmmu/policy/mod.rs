//! Data placement / migration policies — the axis the paper's platform
//! exists to explore ("users can implement their data placement/migration
//! policies with the FPGA logic elements").
//!
//! A policy decides (1) where a first-touch page lands and (2) which page
//! pairs to swap at each epoch boundary. Request routing, DMA mechanics,
//! consistency and counters are the HMMU's job, not the policy's.

mod first_touch;
mod hints_policy;
mod hotness;
mod rbl;
mod static_split;
mod wear_aware;

pub use first_touch::FirstTouchPolicy;
pub use hints_policy::HintsPolicy;
pub use hotness::{
    select_boundary_into, BoundaryBias, HotnessEngine, HotnessPolicy, NativeHotnessEngine,
    PolicyStepOutput, SelectParams, HOTNESS_DECAY, HOTNESS_TILE, NEG_INF, WRITE_WEIGHT,
};
pub use rbl::RblPolicy;
pub use static_split::StaticPolicy;
pub use wear_aware::{WearAwarePolicy, WEAR_BIAS};

use super::redirection::{Device, RedirectionTable};
use crate::alloc::Placement;
use crate::config::{PolicyKind, SystemConfig};
use crate::util::codec::{CodecState, Decoder, Encoder};
use crate::util::error::Result;

/// Read-only state a policy may consult at an epoch boundary.
pub struct PolicyView<'a> {
    pub table: &'a RedirectionTable,
    /// Pages currently involved in in-flight DMA swaps (cannot re-migrate).
    pub migrating: &'a dyn Fn(u64) -> bool,
    /// Cap on migrations this epoch (per boundary, unless overridden by
    /// `boundary_budgets`).
    pub max_migrations: u32,
    /// Per-boundary overrides (`HmmuConfig::migrations_per_boundary`):
    /// entry `b` caps the rank-`b`/rank-`b+1` boundary; `0` = unset,
    /// falling back to `max_migrations`. Policies read it through
    /// [`Self::budget`].
    pub boundary_budgets: &'a [u32],
}

impl PolicyView<'_> {
    /// Migration budget for tier boundary `b` (rank `b` ↔ rank `b+1`).
    #[inline]
    pub fn budget(&self, boundary: usize) -> u32 {
        match self.boundary_budgets.get(boundary) {
            Some(&n) if n > 0 => n,
            _ => self.max_migrations,
        }
    }
}

/// A placement/migration policy.
pub trait PlacementPolicy {
    fn name(&self) -> &'static str;

    /// Choose the device for a first-touch page.
    fn place(&mut self, page: u64, hint: Placement) -> Device;

    /// Account one (post-cache-filter) request to `page`.
    fn record_access(&mut self, page: u64, is_write: bool);

    /// Epoch boundary: select up to `view.max_migrations` page pairs
    /// `(nvm_page, dram_page)` to swap (promote the first, demote the
    /// second). The returned slice borrows a policy-owned buffer that is
    /// **recycled across epochs** (§Perf, ROADMAP item: the per-epoch
    /// migration pair vectors used to be freshly allocated every epoch;
    /// steady state now allocates nothing — pinned by capacity-snapshot
    /// tests in `hotness.rs`/`wear_aware.rs`).
    fn epoch(&mut self, view: &PolicyView) -> &[(u64, u64)];
}

/// Enum-dispatched policy — the HMMU's request hot path calls
/// [`PolicyImpl::record_access`] once per request, so §Perf replaces the
/// old `Box<dyn PlacementPolicy>` vtable indirection with a match that
/// the compiler can inline (and often hoist out of the request loop
/// entirely for the stateless policies). Dynamic dispatch survives only
/// at the [`HotnessEngine`] boundary, where it is needed to swap the
/// native math for the AOT-XLA executable.
#[derive(Clone)]
pub enum PolicyImpl {
    Static(StaticPolicy),
    FirstTouch(FirstTouchPolicy),
    Hints(HintsPolicy),
    Hotness(HotnessPolicy),
    WearAware(WearAwarePolicy),
    Rbl(RblPolicy),
}

impl PolicyImpl {
    #[inline]
    pub fn name(&self) -> &'static str {
        match self {
            PolicyImpl::Static(p) => p.name(),
            PolicyImpl::FirstTouch(p) => p.name(),
            PolicyImpl::Hints(p) => p.name(),
            PolicyImpl::Hotness(p) => p.name(),
            PolicyImpl::WearAware(p) => p.name(),
            PolicyImpl::Rbl(p) => p.name(),
        }
    }

    /// Choose the device for a first-touch page.
    #[inline]
    pub fn place(&mut self, page: u64, hint: Placement) -> Device {
        match self {
            PolicyImpl::Static(p) => p.place(page, hint),
            PolicyImpl::FirstTouch(p) => p.place(page, hint),
            PolicyImpl::Hints(p) => p.place(page, hint),
            PolicyImpl::Hotness(p) => p.place(page, hint),
            PolicyImpl::WearAware(p) => p.place(page, hint),
            PolicyImpl::Rbl(p) => p.place(page, hint),
        }
    }

    /// Account one (post-cache-filter) request to `page` — the per-request
    /// call on the HMMU hot path.
    #[inline]
    pub fn record_access(&mut self, page: u64, is_write: bool) {
        match self {
            PolicyImpl::Static(p) => p.record_access(page, is_write),
            PolicyImpl::FirstTouch(p) => p.record_access(page, is_write),
            PolicyImpl::Hints(p) => p.record_access(page, is_write),
            PolicyImpl::Hotness(p) => p.record_access(page, is_write),
            PolicyImpl::WearAware(p) => p.record_access(page, is_write),
            PolicyImpl::Rbl(p) => p.record_access(page, is_write),
        }
    }

    /// Account one row-buffer *miss* on `page` — the RBL sampling hook.
    /// Only the RBL policy consumes the signal; for every other policy
    /// this is a no-op the compiler folds away, so the existing hot
    /// paths (and their timing/counter surfaces) are untouched.
    #[inline]
    pub fn record_row_miss(&mut self, page: u64) {
        if let PolicyImpl::Rbl(p) = self {
            p.record_row_miss(page);
        }
    }

    /// Whether this policy consumes the row-buffer-outcome signal (the
    /// HMMU samples misses only when true, keeping the block-mode meta
    /// encoding and the per-request branch off the common path).
    #[inline]
    pub fn wants_row_misses(&self) -> bool {
        matches!(self, PolicyImpl::Rbl(_))
    }

    /// Epoch boundary: migration pair selection (off the request path).
    /// Returns a slice of the policy's recycled pair buffer.
    pub fn epoch(&mut self, view: &PolicyView) -> &[(u64, u64)] {
        match self {
            PolicyImpl::Static(p) => p.epoch(view),
            PolicyImpl::FirstTouch(p) => p.epoch(view),
            PolicyImpl::Hints(p) => p.epoch(view),
            PolicyImpl::Hotness(p) => p.epoch(view),
            PolicyImpl::WearAware(p) => p.epoch(view),
            PolicyImpl::Rbl(p) => p.epoch(view),
        }
    }
}

impl PolicyImpl {
    fn variant_tag(&self) -> u8 {
        match self {
            PolicyImpl::Static(_) => 0,
            PolicyImpl::FirstTouch(_) => 1,
            PolicyImpl::Hints(_) => 2,
            PolicyImpl::Hotness(_) => 3,
            PolicyImpl::WearAware(_) => 4,
            PolicyImpl::Rbl(_) => 5,
        }
    }
}

impl CodecState for PolicyImpl {
    fn encode_state(&self, e: &mut Encoder) {
        // The variant is config-derived (`build_policy`); tag it so a
        // snapshot restored into the wrong policy kind fails loudly.
        e.put_u8(self.variant_tag());
        match self {
            // Static split and first-touch are stateless (geometry lives
            // in the config); hints/hotness/wear-aware carry state.
            PolicyImpl::Static(_) | PolicyImpl::FirstTouch(_) => {}
            PolicyImpl::Hints(p) => p.encode_state(e),
            PolicyImpl::Hotness(p) => p.encode_state(e),
            PolicyImpl::WearAware(p) => p.encode_state(e),
            PolicyImpl::Rbl(p) => p.encode_state(e),
        }
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let tag = d.u8()?;
        if tag != self.variant_tag() {
            crate::bail!(
                "checkpoint geometry mismatch: policy variant tag {tag}, expected {} ({})",
                self.variant_tag(),
                self.name()
            );
        }
        match self {
            PolicyImpl::Static(_) | PolicyImpl::FirstTouch(_) => Ok(()),
            PolicyImpl::Hints(p) => p.decode_state(d),
            PolicyImpl::Hotness(p) => p.decode_state(d),
            PolicyImpl::WearAware(p) => p.decode_state(d),
            PolicyImpl::Rbl(p) => p.decode_state(d),
        }
    }
}

/// Build the configured policy for the config's tier stack. `engine`
/// supplies the hotness math (native or AOT-XLA); ignored by the
/// stateless policies.
pub fn build_policy(cfg: &SystemConfig, engine: Option<Box<dyn HotnessEngine>>) -> PolicyImpl {
    let pages = cfg.total_pages();
    let tiers = cfg.tier_count();
    match cfg.policy {
        PolicyKind::Static => PolicyImpl::Static(StaticPolicy::new_tiered(&cfg.tier_pages())),
        PolicyKind::FirstTouch => PolicyImpl::FirstTouch(FirstTouchPolicy::new()),
        PolicyKind::Hints => PolicyImpl::Hints(HintsPolicy::new()),
        PolicyKind::Hotness => PolicyImpl::Hotness(HotnessPolicy::new_tiered(
            pages,
            tiers,
            engine.unwrap_or_else(|| Box::new(NativeHotnessEngine)),
        )),
        PolicyKind::WearAware => PolicyImpl::WearAware(WearAwarePolicy::new_tiered(pages, tiers)),
        PolicyKind::Rbl => PolicyImpl::Rbl(RblPolicy::new_tiered(pages, tiers)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_all_kinds() {
        for kind in [
            PolicyKind::Static,
            PolicyKind::FirstTouch,
            PolicyKind::Hotness,
            PolicyKind::Hints,
            PolicyKind::WearAware,
            PolicyKind::Rbl,
        ] {
            let mut cfg = SystemConfig::default_scaled(16);
            cfg.policy = kind;
            let p = build_policy(&cfg, None);
            assert_eq!(p.name(), kind.name());
        }
    }
}
