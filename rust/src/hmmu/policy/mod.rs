//! Data placement / migration policies — the axis the paper's platform
//! exists to explore ("users can implement their data placement/migration
//! policies with the FPGA logic elements").
//!
//! A policy decides (1) where a first-touch page lands and (2) which page
//! pairs to swap at each epoch boundary. Request routing, DMA mechanics,
//! consistency and counters are the HMMU's job, not the policy's.

mod first_touch;
mod hints_policy;
mod hotness;
mod static_split;
mod wear_aware;

pub use first_touch::FirstTouchPolicy;
pub use hints_policy::HintsPolicy;
pub use hotness::{
    HotnessEngine, HotnessPolicy, NativeHotnessEngine, PolicyStepOutput, HOTNESS_DECAY,
    NEG_INF, WRITE_WEIGHT,
};
pub use static_split::StaticPolicy;
pub use wear_aware::{WearAwarePolicy, WEAR_BIAS};

use super::redirection::{Device, RedirectionTable};
use crate::alloc::Placement;
use crate::config::{PolicyKind, SystemConfig};

/// Read-only state a policy may consult at an epoch boundary.
pub struct PolicyView<'a> {
    pub table: &'a RedirectionTable,
    /// Pages currently involved in in-flight DMA swaps (cannot re-migrate).
    pub migrating: &'a dyn Fn(u64) -> bool,
    /// Cap on migrations this epoch.
    pub max_migrations: u32,
}

/// A placement/migration policy.
pub trait PlacementPolicy {
    fn name(&self) -> &'static str;

    /// Choose the device for a first-touch page.
    fn place(&mut self, page: u64, hint: Placement) -> Device;

    /// Account one (post-cache-filter) request to `page`.
    fn record_access(&mut self, page: u64, is_write: bool);

    /// Epoch boundary: return up to `view.max_migrations` page pairs
    /// `(nvm_page, dram_page)` to swap (promote the first, demote the
    /// second).
    fn epoch(&mut self, view: &PolicyView) -> Vec<(u64, u64)>;
}

/// Build the configured policy. `engine` supplies the hotness math
/// (native or AOT-XLA); ignored by the stateless policies.
pub fn build_policy(
    cfg: &SystemConfig,
    engine: Option<Box<dyn HotnessEngine>>,
) -> Box<dyn PlacementPolicy> {
    let pages = cfg.total_pages();
    match cfg.policy {
        PolicyKind::Static => Box::new(StaticPolicy::new(cfg.dram_pages())),
        PolicyKind::FirstTouch => Box::new(FirstTouchPolicy::new()),
        PolicyKind::Hints => Box::new(HintsPolicy::new()),
        PolicyKind::Hotness => Box::new(HotnessPolicy::new(
            pages,
            engine.unwrap_or_else(|| Box::new(NativeHotnessEngine::default())),
        )),
        PolicyKind::WearAware => Box::new(WearAwarePolicy::new(pages)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_all_kinds() {
        for kind in [
            PolicyKind::Static,
            PolicyKind::FirstTouch,
            PolicyKind::Hotness,
            PolicyKind::Hints,
            PolicyKind::WearAware,
        ] {
            let mut cfg = SystemConfig::default_scaled(16);
            cfg.policy = kind;
            let p = build_policy(&cfg, None);
            assert_eq!(p.name(), kind.name());
        }
    }
}
