//! Wear-aware hotness policy — an extension the paper's Table I
//! motivates: 3D XPoint endures ~10⁹ writes/cell (PCM an order of
//! magnitude less), so a migration policy should keep *write-hot* pages
//! out of the wear-limited tiers even when their total hotness is
//! moderate, and prefer *read-mostly* pages as demotion victims.
//!
//! Scoring (on top of the base hotness math):
//!
//! ```text
//! promote_score += WEAR_BIAS * write_rate        (write-hot pages climb first)
//! demote_score  -= WEAR_BIAS * lifetime_writes   (never demote write-hot pages)
//! ```
//!
//! On a deep stack the same biases drive every tier boundary
//! ([`select_boundary_into`]): write-hot pages are pulled up out of
//! *all* wear-limited ranks, spreading write pressure toward rank 0,
//! and historically write-hot upper-tier pages are never pushed down.
//! The ablation bench compares NVM max-wear under hotness vs wear-aware.

use super::hotness::{
    select_boundary_into, BoundaryBias, HotnessEngine, NativeHotnessEngine, SelectParams, NEG_INF,
    TIER_UNMAPPED,
};
use super::{Device, PlacementPolicy, PolicyView};
use crate::alloc::Placement;
use crate::hmmu::policy::HotnessPolicy;
use crate::hmmu::redirection::TierId;
use crate::util::codec::{check_len, CodecState, Decoder, Encoder};
use crate::util::error::Result;

/// Weight of write activity in the wear-adjusted scores.
pub const WEAR_BIAS: f32 = 4.0;

/// Wear-aware epoch-migration policy.
pub struct WearAwarePolicy {
    // audit: allow(codec-coverage) — geometry, validated not restored
    pages: usize,
    /// Number of tiers in the stack (2 = the classic pair).
    // audit: allow(codec-coverage) — geometry, re-derived from config
    tiers: usize,
    reads: Vec<f32>,
    writes: Vec<f32>,
    /// Lifetime write counts (never reset — proxies frame wear).
    lifetime_writes: Vec<f32>,
    hotness: Vec<f32>,
    /// Residency bitmap scratch, reused across epochs (§Perf).
    // audit: allow(codec-coverage) — scratch, rebuilt every epoch
    in_dram: Vec<f32>,
    /// Per-page tier rank scratch, reused across epochs (drives the
    /// deeper-boundary cascade).
    // audit: allow(codec-coverage) — scratch, rebuilt every epoch
    tier_of: Vec<u8>,
    /// Selected migration pairs, reused across epochs (§Perf, ROADMAP
    /// item — see [`HotnessPolicy`]).
    // audit: allow(codec-coverage) — scratch, refilled every epoch
    pairs: Vec<(u64, u64)>,
    // audit: allow(codec-coverage) — engine is stateless, re-bound at restore
    engine: Box<dyn HotnessEngine>,
    pub epochs: u64,
}

impl Clone for WearAwarePolicy {
    fn clone(&self) -> Self {
        WearAwarePolicy {
            pages: self.pages,
            tiers: self.tiers,
            reads: self.reads.clone(),
            writes: self.writes.clone(),
            lifetime_writes: self.lifetime_writes.clone(),
            hotness: self.hotness.clone(),
            in_dram: self.in_dram.clone(),
            tier_of: self.tier_of.clone(),
            pairs: self.pairs.clone(),
            engine: self.engine.clone_box(),
            epochs: self.epochs,
        }
    }
}

impl CodecState for WearAwarePolicy {
    fn encode_state(&self, e: &mut Encoder) {
        // Scratch buffers (`in_dram`/`tier_of`/`pairs`) are rebuilt each
        // epoch; persistent state adds `lifetime_writes` (the wear proxy,
        // never reset) to the hotness-policy set.
        e.put_f32_slice(&self.reads);
        e.put_f32_slice(&self.writes);
        e.put_f32_slice(&self.lifetime_writes);
        e.put_f32_slice(&self.hotness);
        e.put_u64(self.epochs);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let reads = d.f32_vec()?;
        check_len("wear-aware pages", self.pages, reads.len())?;
        self.reads = reads;
        let writes = d.f32_vec()?;
        check_len("wear-aware pages", self.pages, writes.len())?;
        self.writes = writes;
        let lifetime = d.f32_vec()?;
        check_len("wear-aware pages", self.pages, lifetime.len())?;
        self.lifetime_writes = lifetime;
        let hotness = d.f32_vec()?;
        check_len("wear-aware pages", self.pages, hotness.len())?;
        self.hotness = hotness;
        self.epochs = d.u64()?;
        Ok(())
    }
}

impl WearAwarePolicy {
    pub fn new(pages: u64) -> Self {
        Self::new_tiered(pages, 2)
    }

    /// Policy for a `tiers`-deep stack.
    pub fn new_tiered(pages: u64, tiers: usize) -> Self {
        let pages = pages as usize;
        WearAwarePolicy {
            pages,
            tiers: tiers.max(2),
            reads: vec![0.0; pages],
            writes: vec![0.0; pages],
            lifetime_writes: vec![0.0; pages],
            hotness: vec![0.0; pages],
            in_dram: vec![0.0; pages],
            tier_of: vec![TIER_UNMAPPED; pages],
            pairs: Vec::new(),
            engine: Box::new(NativeHotnessEngine),
            epochs: 0,
        }
    }

    /// Capacity of the recycled migration-pair buffer (tests pin that it
    /// stops growing once warm).
    pub fn pairs_capacity(&self) -> usize {
        self.pairs.capacity()
    }
}

impl PlacementPolicy for WearAwarePolicy {
    fn name(&self) -> &'static str {
        "wear-aware"
    }

    fn place(&mut self, _page: u64, hint: Placement) -> Device {
        match hint {
            Placement::PreferNvm => TierId::Nvm,
            _ => TierId::Dram,
        }
    }

    fn record_access(&mut self, page: u64, is_write: bool) {
        let i = page as usize;
        if is_write {
            self.writes[i] += 1.0;
            self.lifetime_writes[i] += 1.0;
        } else {
            self.reads[i] += 1.0;
        }
    }

    fn epoch(&mut self, view: &PolicyView) -> &[(u64, u64)] {
        self.epochs += 1;
        self.in_dram.fill(0.0);
        self.tier_of.fill(TIER_UNMAPPED);
        for (page, m) in view.table.iter_mapped() {
            self.tier_of[page as usize] = m.device.rank();
            if m.device == Device::Dram {
                self.in_dram[page as usize] = 1.0;
            }
        }
        let mut out = self
            .engine
            .step(&self.reads, &self.writes, &self.hotness, &self.in_dram);

        // Wear adjustment on top of the base scores.
        for i in 0..self.pages {
            if out.promote_score[i] > NEG_INF / 2.0 {
                out.promote_score[i] += WEAR_BIAS * self.writes[i];
            }
            if out.demote_score[i] > NEG_INF / 2.0 {
                // High-lifetime-write DRAM pages are bad demotion victims.
                out.demote_score[i] -= WEAR_BIAS * self.lifetime_writes[i];
            }
        }

        // Rank-0 boundary: exactly the two-tier wear-aware selection.
        HotnessPolicy::select_migrations_into(
            &out,
            view.budget(0) as usize,
            super::hotness::HYSTERESIS,
            view.migrating,
            &mut self.pairs,
        );
        // Deeper boundaries (no-op for two tiers): the same wear biases
        // pull write-hot pages up out of every wear-limited rank and
        // protect historically write-hot upper-tier pages from demotion.
        for upper in 1..(self.tiers as u8 - 1) {
            let budget = view.budget(upper as usize) as usize;
            let bias = BoundaryBias {
                promote: Some(&self.writes),
                demote: Some(&self.lifetime_writes),
                weight: WEAR_BIAS,
            };
            select_boundary_into(
                &out.hotness,
                &self.tier_of,
                upper,
                SelectParams::new(budget, super::hotness::HYSTERESIS),
                bias,
                view.migrating,
                &mut self.pairs,
            );
        }

        self.reads.iter_mut().for_each(|x| *x = 0.0);
        self.writes.iter_mut().for_each(|x| *x = 0.0);
        self.hotness = out.hotness; // move, not clone (§Perf)
        &self.pairs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmmu::redirection::RedirectionTable;

    fn view(t: &RedirectionTable) -> PolicyView<'_> {
        PolicyView {
            table: t,
            migrating: &|_| false,
            max_migrations: 4,
            boundary_budgets: &[],
        }
    }

    #[test]
    fn write_hot_nvm_page_promoted_over_read_hot() {
        let mut t = RedirectionTable::two_tier(8, 4, 8, 4096);
        t.identity_map(); // 0-3 DRAM, 4-7 NVM
        let mut p = WearAwarePolicy::new(8);
        // Page 4: 30 reads. Page 5: 20 writes (less raw hotness than 40
        // but wear-biased above page 4's 30).
        for _ in 0..30 {
            p.record_access(4, false);
        }
        for _ in 0..20 {
            p.record_access(5, true);
        }
        // Warm one DRAM page a little so hysteresis passes.
        for _ in 0..2 {
            p.record_access(0, false);
        }
        let pairs = p.epoch(&view(&t));
        assert!(!pairs.is_empty());
        assert_eq!(pairs[0].0, 5, "write-hot page must promote first: {pairs:?}");
    }

    #[test]
    fn write_hot_dram_page_never_demoted() {
        let mut t = RedirectionTable::two_tier(8, 4, 8, 4096);
        t.identity_map();
        let mut p = WearAwarePolicy::new(8);
        // DRAM page 0 is write-hot historically; pages 1-3 idle.
        for _ in 0..50 {
            p.record_access(0, true);
        }
        // NVM page 6 is hot enough to promote.
        for _ in 0..200 {
            p.record_access(6, false);
        }
        let pairs = p.epoch(&view(&t));
        assert!(!pairs.is_empty());
        for &(_, victim) in pairs {
            assert_ne!(victim, 0, "write-hot DRAM page demoted: {pairs:?}");
        }
    }

    #[test]
    fn epoch_pair_buffer_reaches_steady_state() {
        // Same zero-steady-state-growth contract as HotnessPolicy: the
        // recycled pair buffer caps at k and never grows after warmup.
        let mut t = RedirectionTable::two_tier(64, 32, 32, 4096);
        t.identity_map();
        let mut p = WearAwarePolicy::new(64);
        let v = PolicyView {
            table: &t,
            migrating: &|_| false,
            max_migrations: 4,
            boundary_budgets: &[],
        };
        let mut warm = 0usize;
        for epoch in 0..20 {
            for page in 32..64u64 {
                for _ in 0..50 {
                    p.record_access(page, false);
                }
            }
            assert_eq!(p.epoch(&v).len(), 4, "epoch {epoch}");
            if epoch == 0 {
                warm = p.pairs_capacity();
            } else {
                assert_eq!(p.pairs_capacity(), warm, "epoch {epoch}: buffer grew");
            }
        }
        assert!(warm <= 4, "capacity bounded by k: {warm}");
    }

    #[test]
    fn lifetime_writes_persist_across_epochs() {
        let mut t = RedirectionTable::two_tier(4, 2, 4, 4096);
        t.identity_map();
        let mut p = WearAwarePolicy::new(4);
        for _ in 0..10 {
            p.record_access(0, true);
        }
        p.epoch(&view(&t));
        // Epoch counters reset, lifetime persists.
        assert_eq!(p.writes[0], 0.0);
        assert_eq!(p.lifetime_writes[0], 10.0);
    }

    #[test]
    fn deep_stack_cascade_pulls_write_hot_pages_up() {
        // 2+2+4 stack: tier-2 page 5 is write-hot, page 4 read-warm with
        // slightly higher raw hotness; tier-1 victims idle. The wear bias
        // must rank the write-hot page first at the boundary-1 cascade.
        let mut t = RedirectionTable::new(8, &[2, 2, 4], 4096);
        t.identity_map(); // 0-1 tier0, 2-3 tier1, 4-7 tier2
        let mut p = WearAwarePolicy::new_tiered(8, 3);
        // Keep DRAM hot so the rank-0 boundary stays closed.
        for d in 0..2u64 {
            for _ in 0..200 {
                p.record_access(d, false);
            }
        }
        for _ in 0..30 {
            p.record_access(4, false); // read-warm: hotness 30
        }
        for _ in 0..12 {
            p.record_access(5, true); // write-hot: hotness 24, bias +48
        }
        let pairs = p.epoch(&view(&t)).to_vec();
        assert!(!pairs.is_empty(), "cascade must fire");
        assert_eq!(
            pairs[0].0, 5,
            "write-hot tier-2 page must climb first: {pairs:?}"
        );
        assert!(
            pairs[0].1 == 2 || pairs[0].1 == 3,
            "victim must come from tier 1: {pairs:?}"
        );
    }
}
