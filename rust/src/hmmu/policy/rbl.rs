//! Row-buffer-locality (RBL) migration policy — the Yoon et al.
//! (arXiv 1804.11040) observation turned into a placement signal: a
//! row-buffer *hit* costs roughly the same in DRAM and NVM, so the
//! pages worth promoting are not the merely-hot ones but the ones whose
//! accesses keep *missing* the NVM row buffer and paying the slow array
//! access. The HMMU samples each request's row-buffer outcome (the
//! `issue_hit` bit) into per-page miss counts; at the epoch boundary
//! this policy decays them into a running **miss intensity** and ranks
//! promotion candidates by it:
//!
//! ```text
//! intensity' = DECAY * intensity + epoch_row_misses
//! ```
//!
//! Promotion/demotion selection reuses the shared boundary machinery
//! ([`select_boundary_into`]) over the intensity array at every tier
//! boundary, so the cascade, hysteresis gate and tie-breaks are
//! identical to the hotness/wear-aware policies — only the metric
//! differs. Pages with high row-buffer locality (hot but mostly
//! hitting) stay put: they already run at near-DRAM speed where they
//! are.

use super::hotness::{
    select_boundary_into, BoundaryBias, SelectParams, HOTNESS_DECAY, HYSTERESIS, TIER_UNMAPPED,
};
use super::{Device, PlacementPolicy, PolicyView};
use crate::alloc::Placement;
use crate::hmmu::redirection::TierId;
use crate::util::codec::{check_len, CodecState, Decoder, Encoder};
use crate::util::error::Result;

/// Row-buffer-locality epoch-migration policy.
#[derive(Clone)]
pub struct RblPolicy {
    // audit: allow(codec-coverage) — geometry, validated not restored
    pages: usize,
    /// Number of tiers in the stack (2 = the classic pair).
    // audit: allow(codec-coverage) — geometry, re-derived from config
    tiers: usize,
    /// Row misses observed this epoch, per page.
    misses: Vec<f32>,
    /// Decayed running miss intensity (the ranking metric).
    intensity: Vec<f32>,
    /// Per-page tier rank scratch, reused across epochs (drives the
    /// boundary cascade).
    // audit: allow(codec-coverage) — scratch, rebuilt every epoch
    tier_of: Vec<u8>,
    /// Selected migration pairs, reused across epochs (§Perf — same
    /// zero-steady-state-growth contract as the other policies).
    // audit: allow(codec-coverage) — scratch, refilled every epoch
    pairs: Vec<(u64, u64)>,
    pub epochs: u64,
}

impl CodecState for RblPolicy {
    fn encode_state(&self, e: &mut Encoder) {
        // Persistent state only: `tier_of`/`pairs` are rebuilt each
        // epoch. Both miss arrays ride the checkpoint so a forked run
        // replays migrations exactly like a cold one (fork == cold).
        e.put_f32_slice(&self.misses);
        e.put_f32_slice(&self.intensity);
        e.put_u64(self.epochs);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let misses = d.f32_vec()?;
        check_len("rbl pages", self.pages, misses.len())?;
        self.misses = misses;
        let intensity = d.f32_vec()?;
        check_len("rbl pages", self.pages, intensity.len())?;
        self.intensity = intensity;
        self.epochs = d.u64()?;
        Ok(())
    }
}

impl RblPolicy {
    pub fn new(pages: u64) -> Self {
        Self::new_tiered(pages, 2)
    }

    /// Policy for a `tiers`-deep stack.
    pub fn new_tiered(pages: u64, tiers: usize) -> Self {
        let pages = pages as usize;
        RblPolicy {
            pages,
            tiers: tiers.max(2),
            misses: vec![0.0; pages],
            intensity: vec![0.0; pages],
            tier_of: vec![TIER_UNMAPPED; pages],
            pairs: Vec::new(),
            epochs: 0,
        }
    }

    /// Account one row-buffer miss against `page` — the per-request
    /// sampling call (the HMMU invokes it only for this policy, so the
    /// other policies' hot path is untouched).
    #[inline]
    pub fn record_row_miss(&mut self, page: u64) {
        self.misses[page as usize] += 1.0;
    }

    /// Capacity of the recycled migration-pair buffer (tests pin that it
    /// stops growing once warm).
    pub fn pairs_capacity(&self) -> usize {
        self.pairs.capacity()
    }
}

impl PlacementPolicy for RblPolicy {
    fn name(&self) -> &'static str {
        "rbl"
    }

    fn place(&mut self, _page: u64, hint: Placement) -> Device {
        match hint {
            Placement::PreferNvm => TierId::Nvm,
            _ => TierId::Dram,
        }
    }

    fn record_access(&mut self, _page: u64, _is_write: bool) {
        // Intentionally a no-op: RBL ranks purely by row-miss intensity.
        // A page hammering an open row is fast wherever it lives.
    }

    fn epoch(&mut self, view: &PolicyView) -> &[(u64, u64)] {
        self.epochs += 1;
        self.tier_of.fill(TIER_UNMAPPED);
        for (page, m) in view.table.iter_mapped() {
            self.tier_of[page as usize] = m.device.rank();
        }
        // Same decay shape as the hotness step: fma per page.
        for i in 0..self.pages {
            self.intensity[i] = HOTNESS_DECAY * self.intensity[i] + self.misses[i];
        }
        // Every boundary runs the shared selection over the intensity
        // array: promote the miss-heaviest pages of the lower rank,
        // demote the miss-lightest pages of the upper rank (they hit
        // their rows — or are idle — and lose least by moving down).
        self.pairs.clear();
        for upper in 0..(self.tiers as u8 - 1) {
            select_boundary_into(
                &self.intensity,
                &self.tier_of,
                upper,
                SelectParams::new(view.budget(upper as usize) as usize, HYSTERESIS),
                BoundaryBias::default(),
                view.migrating,
                &mut self.pairs,
            );
        }
        self.misses.iter_mut().for_each(|x| *x = 0.0);
        &self.pairs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmmu::redirection::RedirectionTable;
    use crate::util::codec::{Decoder, Encoder};

    fn view(t: &RedirectionTable) -> PolicyView<'_> {
        PolicyView {
            table: t,
            migrating: &|_| false,
            max_migrations: 4,
            boundary_budgets: &[],
        }
    }

    #[test]
    fn miss_heavy_page_promoted_over_hit_heavy() {
        let mut t = RedirectionTable::two_tier(8, 4, 8, 4096);
        t.identity_map(); // 0-3 DRAM, 4-7 NVM
        let mut p = RblPolicy::new(8);
        // Page 4: many accesses, all row hits (no misses recorded).
        // Page 5: fewer accesses but every one misses the row buffer.
        for _ in 0..100 {
            p.record_access(4, false);
        }
        for _ in 0..10 {
            p.record_row_miss(5);
        }
        let pairs = p.epoch(&view(&t));
        assert!(!pairs.is_empty());
        assert_eq!(pairs[0].0, 5, "miss-heavy page must promote: {pairs:?}");
        assert!(
            !pairs.iter().any(|&(promo, _)| promo == 4),
            "hit-heavy page stays in NVM: {pairs:?}"
        );
    }

    #[test]
    fn intensity_decays_across_epochs() {
        let mut t = RedirectionTable::two_tier(4, 2, 4, 4096);
        t.identity_map();
        let mut p = RblPolicy::new(4);
        for _ in 0..8 {
            p.record_row_miss(2);
        }
        p.epoch(&view(&t));
        assert_eq!(p.misses[2], 0.0, "epoch counts reset");
        assert_eq!(p.intensity[2], 8.0);
        p.epoch(&view(&t));
        assert_eq!(p.intensity[2], 4.0, "decay halves a quiet epoch");
    }

    #[test]
    fn deep_stack_cascade_promotes_one_rank_per_epoch() {
        let mut t = RedirectionTable::new(8, &[2, 2, 4], 4096);
        t.identity_map(); // 0-1 tier0, 2-3 tier1, 4-7 tier2
        let mut p = RblPolicy::new_tiered(8, 3);
        // Keep tier-0 pages miss-hot so the rank-0 boundary stays closed;
        // tier-2 page 6 is the only deep miss generator.
        for d in 0..2u64 {
            for _ in 0..50 {
                p.record_row_miss(d);
            }
        }
        for _ in 0..20 {
            p.record_row_miss(6);
        }
        let pairs = p.epoch(&view(&t)).to_vec();
        assert!(!pairs.is_empty(), "cascade must fire");
        assert_eq!(pairs[0].0, 6, "deep miss-heavy page climbs: {pairs:?}");
        assert!(pairs[0].1 == 2 || pairs[0].1 == 3, "victim comes from tier 1: {pairs:?}");
    }

    #[test]
    fn epoch_pair_buffer_reaches_steady_state() {
        let mut t = RedirectionTable::two_tier(64, 32, 32, 4096);
        t.identity_map();
        let mut p = RblPolicy::new(64);
        let mut warm = 0usize;
        for epoch in 0..20 {
            for page in 32..64u64 {
                for _ in 0..50 {
                    p.record_row_miss(page);
                }
            }
            assert_eq!(p.epoch(&view(&t)).len(), 4, "epoch {epoch}");
            if epoch == 0 {
                warm = p.pairs_capacity();
            } else {
                assert_eq!(p.pairs_capacity(), warm, "epoch {epoch}: buffer grew");
            }
        }
        assert!(warm <= 4, "capacity bounded by k: {warm}");
    }

    #[test]
    fn codec_round_trip_preserves_intensity() {
        let mut t = RedirectionTable::two_tier(8, 4, 8, 4096);
        t.identity_map();
        let mut p = RblPolicy::new(8);
        for _ in 0..6 {
            p.record_row_miss(5);
        }
        p.epoch(&view(&t));
        for _ in 0..3 {
            p.record_row_miss(6); // un-flushed epoch counts must ride too
        }
        let mut e = Encoder::new();
        p.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut q = RblPolicy::new(8);
        let mut d = Decoder::new(&bytes);
        q.decode_state(&mut d).unwrap();
        assert!(d.is_done());
        assert_eq!(q.intensity, p.intensity);
        assert_eq!(q.misses, p.misses);
        assert_eq!(q.epochs, p.epochs);
        // And the forked policy selects the same pairs as the original.
        assert_eq!(p.epoch(&view(&t)).to_vec(), q.epoch(&view(&t)).to_vec());
    }

    #[test]
    fn geometry_mismatch_fails_loudly() {
        let p = RblPolicy::new(8);
        let mut e = Encoder::new();
        p.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut q = RblPolicy::new(16);
        let mut d = Decoder::new(&bytes);
        assert!(q.decode_state(&mut d).is_err());
    }
}
