//! First-touch policy: every new page prefers DRAM; once DRAM frames run
//! out the redirection table falls back to NVM. No migration — whatever
//! touched memory first keeps the fast frames. The classic baseline for
//! migration studies.

use super::{Device, PlacementPolicy, PolicyView};
use crate::alloc::Placement;

#[derive(Clone, Default)]
pub struct FirstTouchPolicy;

impl FirstTouchPolicy {
    pub fn new() -> Self {
        FirstTouchPolicy
    }
}

impl PlacementPolicy for FirstTouchPolicy {
    fn name(&self) -> &'static str {
        "first-touch"
    }

    fn place(&mut self, _page: u64, _hint: Placement) -> Device {
        Device::Dram // table falls back to NVM when DRAM is full
    }

    fn record_access(&mut self, _page: u64, _is_write: bool) {}

    fn epoch(&mut self, _view: &PolicyView) -> &[(u64, u64)] {
        &[]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_prefers_dram() {
        let mut p = FirstTouchPolicy::new();
        for page in [0u64, 5, 1000, 1 << 40] {
            assert_eq!(p.place(page, Placement::Any), Device::Dram);
        }
    }
}
