//! Epoch-based hotness migration policy — the flagship policy, and the
//! piece of the stack that runs through the AOT-compiled XLA artifact.
//!
//! Per-page read/write counters accumulate during an epoch (in HMMU SRAM
//! in the paper; plain arrays here). At the epoch boundary a **policy
//! step** computes, for every page:
//!
//! ```text
//! hotness'      = DECAY * hotness + reads + WRITE_WEIGHT * writes
//! promote_score = in_nvm  ? hotness' : -inf     (hot NVM pages move up)
//! demote_score  = in_dram ? -hotness' : -inf    (cold DRAM pages move down)
//! ```
//!
//! `WRITE_WEIGHT > 1` encodes NVM's write asymmetry (Table I: 3D XPoint
//! writes are 2-10× its reads): write-hot pages benefit doubly from DRAM.
//!
//! The step is a dense elementwise pass over the page arrays — exactly
//! the shape the Pallas kernel implements (`python/compile/kernels/
//! hotness.py`). [`HotnessEngine`] abstracts the math so the HMMU can run
//! either the [`NativeHotnessEngine`] (pure Rust, bit-compatible) or the
//! AOT XLA executable loaded by `runtime::XlaHotnessEngine`. An
//! integration test cross-checks the two.

use super::{Device, PlacementPolicy, PolicyView};
use crate::alloc::Placement;
use crate::util::codec::{check_len, CodecState, Decoder, Encoder};
use crate::util::error::Result;

/// Tier marker for unmapped pages in the per-page tier scratch.
pub(crate) const TIER_UNMAPPED: u8 = u8::MAX;

/// Exponential decay applied to hotness each epoch.
pub const HOTNESS_DECAY: f32 = 0.5;
/// Weight of a write relative to a read (NVM write asymmetry).
pub const WRITE_WEIGHT: f32 = 2.0;
/// A promoted NVM page must be this much hotter than the DRAM victim it
/// replaces (hysteresis against thrashing).
pub const HYSTERESIS: f32 = 1.25;

/// Output of one policy step.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyStepOutput {
    pub hotness: Vec<f32>,
    pub promote_score: Vec<f32>,
    pub demote_score: Vec<f32>,
}

/// (score, idx) ordered by score asc then idx desc, so a bounded
/// min-heap's minimum is the *worst* retained candidate and ties keep
/// the smaller index (drop larger-index equals first). Shared by the
/// rank-0 selection and the deeper-boundary cascade so every tier
/// boundary ranks candidates identically.
#[derive(PartialEq)]
struct Cand(f32, u32);
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(other.1.cmp(&self.1))
    }
}

/// Knobs shared by every boundary selection (see [`select_pairs_core`]).
#[derive(Clone, Copy)]
pub struct SelectParams {
    /// Maximum pairs to select.
    pub k: usize,
    /// Hysteresis gate factor on raw hotness.
    pub hysteresis: f32,
    /// Candidate order is monotone in the gate's metric, so the gate
    /// may stop at the first failing pair. Must be `false` whenever
    /// candidates are ranked by a *biased* score — a biased ranking is
    /// not hotness-monotone, so the gate has to examine every pair.
    pub strict_order: bool,
}

impl SelectParams {
    /// Strict-order selection — the legacy two-tier contract (unbiased
    /// scores, gate breaks at the first failing pair).
    pub fn new(k: usize, hysteresis: f32) -> Self {
        SelectParams {
            k,
            hysteresis,
            strict_order: true,
        }
    }
}

/// Optional per-page score biases for a boundary selection, scaled by
/// `weight`: added to promote scores, subtracted from demote scores.
/// The default is unbiased (pure hotness ranking).
#[derive(Clone, Copy, Default)]
pub struct BoundaryBias<'a> {
    pub promote: Option<&'a [f32]>,
    pub demote: Option<&'a [f32]>,
    pub weight: f32,
}

/// The **single** bounded-heap pair-selection core shared by the rank-0
/// boundary ([`HotnessPolicy::select_migrations_into`]) and the deeper
/// boundaries ([`select_boundary_into`]): one pass over the pages keeps
/// the top-`k` promote/demote candidates (score desc, index-asc
/// tie-break), then zips them through the hysteresis gate on raw
/// hotness. `promote_score`/`demote_score` return `None` for ineligible
/// pages.
fn select_pairs_core(
    pages: u32,
    promote_score: &dyn Fn(u32) -> Option<f32>,
    demote_score: &dyn Fn(u32) -> Option<f32>,
    hotness: &[f32],
    params: SelectParams,
    skip: &dyn Fn(u64) -> bool,
    pairs: &mut Vec<(u64, u64)>,
) {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let k = params.k;
    if k == 0 {
        return;
    }
    let mut promote: BinaryHeap<Reverse<Cand>> = BinaryHeap::with_capacity(k + 1);
    let mut demote: BinaryHeap<Reverse<Cand>> = BinaryHeap::with_capacity(k + 1);
    for i in 0..pages {
        if let Some(ps) = promote_score(i) {
            let better = promote.len() < k
                || promote.peek().map(|Reverse(c)| Cand(ps, i) > *c).unwrap();
            if better && !skip(i as u64) {
                promote.push(Reverse(Cand(ps, i)));
                if promote.len() > k {
                    promote.pop();
                }
            }
        }
        if let Some(ds) = demote_score(i) {
            let better =
                demote.len() < k || demote.peek().map(|Reverse(c)| Cand(ds, i) > *c).unwrap();
            if better && !skip(i as u64) {
                demote.push(Reverse(Cand(ds, i)));
                if demote.len() > k {
                    demote.pop();
                }
            }
        }
    }
    // `into_sorted_vec` sorts ascending in `Reverse<Cand>`, i.e.
    // descending in `Cand`: best candidates first.
    let promote: Vec<u32> = promote.into_sorted_vec().into_iter().map(|Reverse(c)| c.1).collect();
    let demote: Vec<u32> = demote.into_sorted_vec().into_iter().map(|Reverse(c)| c.1).collect();
    for (p, d) in promote.iter().zip(demote.iter()).take(k) {
        let hot_p = hotness[*p as usize];
        let hot_d = hotness[*d as usize];
        // Hysteresis: only swap if the promoted page is decisively hotter.
        if hot_p > hot_d * params.hysteresis + 1.0 {
            pairs.push((*p as u64, *d as u64));
        } else if params.strict_order {
            break; // candidates sorted by the gate metric; later pairs are worse
        }
    }
}

/// Select up to `k` swap pairs `(deep_page, upper_page)` across the tier
/// boundary directly below rank `upper`: promote candidates are the
/// hottest pages on rank `upper + 1` (strictly adjacent — the cascade
/// climbs one rank per epoch; only the rank-0 boundary, which runs the
/// engine's scores, promotes from any depth), demotion victims the
/// coldest pages on rank `upper` — the same bounded-heap selection,
/// tie-breaks and hysteresis rule as the rank-0 boundary (shared
/// [`select_pairs_core`]). Optional per-page biases ([`BoundaryBias`];
/// the wear-aware policy passes its epoch write counts / lifetime
/// writes with [`super::WEAR_BIAS`]) are added to promote scores /
/// subtracted from demote scores. `params.strict_order` is derived
/// here from the bias (a biased ranking is never gate-monotone), so
/// callers just use [`SelectParams::new`]. Pages for which `skip`
/// returns true, or that are already in `pairs` from an earlier
/// boundary this epoch, are excluded; selected pairs are **appended**
/// to `pairs`.
pub fn select_boundary_into(
    hotness: &[f32],
    tier_of: &[u8],
    upper: u8,
    params: SelectParams,
    bias: BoundaryBias<'_>,
    skip: &dyn Fn(u64) -> bool,
    pairs: &mut Vec<(u64, u64)>,
) {
    let taken: Vec<u64> = pairs.iter().flat_map(|&(a, b)| [a, b]).collect();
    let skip_all = |p: u64| skip(p) || taken.contains(&p);
    let promote = |i: u32| {
        if tier_of[i as usize] != upper + 1 {
            return None;
        }
        let ps = hotness[i as usize]
            + bias.promote.map_or(0.0, |b| bias.weight * b[i as usize]);
        if ps > 0.0 {
            Some(ps)
        } else {
            None
        }
    };
    let demote = |i: u32| {
        if tier_of[i as usize] != upper {
            return None;
        }
        Some(-hotness[i as usize] - bias.demote.map_or(0.0, |b| bias.weight * b[i as usize]))
    };
    // A biased ranking is not monotone in raw hotness: the gate must
    // examine every pair instead of breaking at the first failure.
    let params = SelectParams {
        strict_order: bias.promote.is_none() && bias.demote.is_none(),
        ..params
    };
    select_pairs_core(
        hotness.len() as u32,
        &promote,
        &demote,
        hotness,
        params,
        &skip_all,
        pairs,
    );
}

/// The hotness math, swappable between native Rust and the XLA artifact.
///
/// `Send + Sync` so warm platform state (which boxes an engine) can be
/// shared by reference across the sweep worker pool when group members
/// fork in parallel.
pub trait HotnessEngine: Send + Sync {
    /// `reads`/`writes`: epoch counters; `prev`: hotness from last epoch;
    /// `in_dram`: 1.0 where the page is DRAM-resident, 0.0 NVM-resident
    /// (unmapped pages have 0 counters and are never candidates).
    fn step(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        prev: &[f32],
        in_dram: &[f32],
    ) -> PolicyStepOutput;

    /// Implementation label for reports.
    fn label(&self) -> &'static str;

    /// Clone the engine for a checkpoint fork. The default returns the
    /// native engine: every engine is stateless and bit-compatible with
    /// it (the XLA engine is cross-checked against native by integration
    /// test), and the sweep fork path always runs native — so forks
    /// degrade gracefully instead of requiring every engine to be
    /// clonable.
    fn clone_box(&self) -> Box<dyn HotnessEngine> {
        Box::new(NativeHotnessEngine)
    }
}

/// Pure-Rust engine, bit-compatible with the Pallas kernel under
/// `interpret=True` (same operation order: fma, mask by select).
#[derive(Default)]
pub struct NativeHotnessEngine;

/// Mask value for non-candidates (matches `ref.py` / the kernel).
pub const NEG_INF: f32 = -1.0e30;

/// Tile width (f32 elements) for the hotness step and the epoch array
/// passes. 256 × 4 B = 1 KiB per stream; the step touches six streams
/// (~6 KiB per tile), so a whole tile stays L1-resident while its FMA +
/// select lanes retire — and 256 is a multiple of every SIMD width LLVM
/// targets here (4/8/16 lanes), so the branch-light inner loop
/// auto-vectorizes with no scalar prologue inside a tile. Mirrors the
/// Pallas kernel's block shape over the same arrays.
pub const HOTNESS_TILE: usize = 256;

impl HotnessEngine for NativeHotnessEngine {
    fn step(
        &mut self,
        reads: &[f32],
        writes: &[f32],
        prev: &[f32],
        in_dram: &[f32],
    ) -> PolicyStepOutput {
        let n = reads.len();
        let mut hotness = vec![0f32; n];
        let mut promote = vec![0f32; n];
        let mut demote = vec![0f32; n];
        // §Perf: tiled pass — fixed-width contiguous chunks over all six
        // arrays. The inner loop is a zipped (bounds-check-free),
        // branch-light elementwise body LLVM auto-vectorizes; the math is
        // purely elementwise, so tiling cannot change any result bit.
        for tile in (0..n).step_by(HOTNESS_TILE) {
            let end = (tile + HOTNESS_TILE).min(n);
            let (r, w) = (&reads[tile..end], &writes[tile..end]);
            let (pv, dr) = (&prev[tile..end], &in_dram[tile..end]);
            let h = &mut hotness[tile..end];
            let p = &mut promote[tile..end];
            let d = &mut demote[tile..end];
            for (((((h, p), d), &r), &w), (&pv, &dram)) in h
                .iter_mut()
                .zip(p.iter_mut())
                .zip(d.iter_mut())
                .zip(r)
                .zip(w)
                .zip(pv.iter().zip(dr))
            {
                let hv = HOTNESS_DECAY * pv + (r + WRITE_WEIGHT * w);
                *h = hv;
                let is_dram = dram != 0.0;
                *p = if is_dram { NEG_INF } else { hv };
                *d = if is_dram { -hv } else { NEG_INF };
            }
        }
        PolicyStepOutput {
            hotness,
            promote_score: promote,
            demote_score: demote,
        }
    }

    fn label(&self) -> &'static str {
        "native"
    }
}

/// The migration policy driving an engine: hotness promotes toward rank
/// 0. The rank-0 boundary runs the engine's promote/demote scores
/// (bit-identical to the two-tier policy); for deeper stacks every lower
/// boundary additionally cascades — warm pages climb one rank per epoch
/// ([`select_boundary_into`]) — so a three-tier demotion scenario
/// (hot→DRAM, warm→PCM, cold→3D XPoint) emerges from the same hotness
/// state.
pub struct HotnessPolicy {
    // audit: allow(codec-coverage) — geometry, validated not restored
    pages: usize,
    /// Number of tiers in the stack (2 = the classic pair).
    // audit: allow(codec-coverage) — geometry, re-derived from config
    tiers: usize,
    reads: Vec<f32>,
    writes: Vec<f32>,
    hotness: Vec<f32>,
    /// Residency bitmap scratch, reused across epochs (§Perf: avoids a
    /// page-count allocation per epoch).
    // audit: allow(codec-coverage) — scratch, rebuilt every epoch
    in_dram: Vec<f32>,
    /// Per-page tier rank scratch ([`TIER_UNMAPPED`] = unplaced), reused
    /// across epochs; drives the deeper-boundary cascade.
    // audit: allow(codec-coverage) — scratch, rebuilt every epoch
    tier_of: Vec<u8>,
    /// Selected migration pairs, reused across epochs (§Perf, ROADMAP
    /// item: `epoch` used to allocate a fresh `Vec` per epoch; the buffer
    /// now reaches steady-state capacity — at most `max_migrations`
    /// entries per tier boundary — and never grows again).
    // audit: allow(codec-coverage) — scratch, refilled every epoch
    pairs: Vec<(u64, u64)>,
    // audit: allow(codec-coverage) — engine is stateless, re-bound at restore
    engine: Box<dyn HotnessEngine>,
    /// Epochs run (for reports).
    pub epochs: u64,
}

impl Clone for HotnessPolicy {
    fn clone(&self) -> Self {
        HotnessPolicy {
            pages: self.pages,
            tiers: self.tiers,
            reads: self.reads.clone(),
            writes: self.writes.clone(),
            hotness: self.hotness.clone(),
            in_dram: self.in_dram.clone(),
            tier_of: self.tier_of.clone(),
            pairs: self.pairs.clone(),
            engine: self.engine.clone_box(),
            epochs: self.epochs,
        }
    }
}

impl CodecState for HotnessPolicy {
    fn encode_state(&self, e: &mut Encoder) {
        // `in_dram`/`tier_of`/`pairs` are per-epoch scratch, rebuilt from
        // the table at the next epoch boundary; the persistent state is
        // the epoch counters, the decayed hotness, and the epoch count.
        e.put_f32_slice(&self.reads);
        e.put_f32_slice(&self.writes);
        e.put_f32_slice(&self.hotness);
        e.put_u64(self.epochs);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let reads = d.f32_vec()?;
        check_len("hotness pages", self.pages, reads.len())?;
        self.reads = reads;
        let writes = d.f32_vec()?;
        check_len("hotness pages", self.pages, writes.len())?;
        self.writes = writes;
        let hotness = d.f32_vec()?;
        check_len("hotness pages", self.pages, hotness.len())?;
        self.hotness = hotness;
        self.epochs = d.u64()?;
        Ok(())
    }
}

impl HotnessPolicy {
    pub fn new(pages: u64, engine: Box<dyn HotnessEngine>) -> Self {
        Self::new_tiered(pages, 2, engine)
    }

    /// Policy for an `tiers`-deep stack.
    pub fn new_tiered(pages: u64, tiers: usize, engine: Box<dyn HotnessEngine>) -> Self {
        let pages = pages as usize;
        HotnessPolicy {
            pages,
            tiers: tiers.max(2),
            reads: vec![0.0; pages],
            writes: vec![0.0; pages],
            hotness: vec![0.0; pages],
            in_dram: vec![0.0; pages],
            tier_of: vec![TIER_UNMAPPED; pages],
            pairs: Vec::new(),
            engine,
            epochs: 0,
        }
    }

    /// Capacity of the recycled migration-pair buffer (tests pin that it
    /// stops growing once warm).
    pub fn pairs_capacity(&self) -> usize {
        self.pairs.capacity()
    }

    pub fn engine_label(&self) -> &'static str {
        self.engine.label()
    }

    /// Select up to `k` (nvm_page, dram_page) swap pairs from the step
    /// output, ranked by promote score desc / demote score desc with
    /// index ascending as the tie-break (matches `jnp.argsort` stability
    /// in the L2 model).
    ///
    /// §Perf: single pass with two bounded min-heaps (O(P log k)) instead
    /// of materializing + sorting every candidate (O(P log P)) — the
    /// epoch step used to dominate the hotness-policy hot path.
    pub fn select_migrations(
        out: &PolicyStepOutput,
        k: usize,
        hysteresis: f32,
        skip: &dyn Fn(u64) -> bool,
    ) -> Vec<(u64, u64)> {
        let mut pairs = Vec::new();
        Self::select_migrations_into(out, k, hysteresis, skip, &mut pairs);
        pairs
    }

    /// [`Self::select_migrations`] into a caller-provided buffer
    /// (cleared first) — the allocation-free epoch path, riding the
    /// shared [`select_pairs_core`].
    pub fn select_migrations_into(
        out: &PolicyStepOutput,
        k: usize,
        hysteresis: f32,
        skip: &dyn Fn(u64) -> bool,
        pairs: &mut Vec<(u64, u64)>,
    ) {
        pairs.clear();
        let promote = |i: u32| {
            let ps = out.promote_score[i as usize];
            if ps > 0.0 {
                Some(ps)
            } else {
                None
            }
        };
        let demote = |i: u32| {
            let ds = out.demote_score[i as usize];
            if ds > NEG_INF / 2.0 {
                Some(ds)
            } else {
                None
            }
        };
        select_pairs_core(
            out.promote_score.len() as u32,
            &promote,
            &demote,
            &out.hotness,
            // Legacy two-tier contract (pinned by the equivalence
            // batteries): the gate stops at the first failing pair.
            SelectParams::new(k, hysteresis),
            skip,
            pairs,
        );
    }
}

impl PlacementPolicy for HotnessPolicy {
    fn name(&self) -> &'static str {
        "hotness"
    }

    fn place(&mut self, _page: u64, hint: Placement) -> Device {
        match hint {
            Placement::PreferNvm => Device::Nvm,
            _ => Device::Dram, // first-touch DRAM; migration fixes mistakes
        }
    }

    fn record_access(&mut self, page: u64, is_write: bool) {
        let i = page as usize;
        debug_assert!(i < self.pages);
        if is_write {
            self.writes[i] += 1.0;
        } else {
            self.reads[i] += 1.0;
        }
    }

    fn epoch(&mut self, view: &PolicyView) -> &[(u64, u64)] {
        self.epochs += 1;
        // Residency bitmap + per-page tier ranks from the table (scratch
        // buffers reused; the clears compile to tile-width memsets —
        // same contiguous-chunk discipline as the engine step).
        self.in_dram.fill(0.0);
        self.tier_of.fill(TIER_UNMAPPED);
        for (page, m) in view.table.iter_mapped() {
            self.tier_of[page as usize] = m.device.rank();
            if m.device == Device::Dram {
                self.in_dram[page as usize] = 1.0;
            }
        }
        let out = self
            .engine
            .step(&self.reads, &self.writes, &self.hotness, &self.in_dram);
        // Reset epoch counters.
        self.reads.fill(0.0);
        self.writes.fill(0.0);

        // Rank-0 boundary: the engine's promote/demote scores — exactly
        // the two-tier policy (hot pages anywhere below rank 0 swap with
        // the coldest rank-0 victims).
        Self::select_migrations_into(
            &out,
            view.budget(0) as usize,
            HYSTERESIS,
            view.migrating,
            &mut self.pairs,
        );
        // Deeper boundaries (no-op for the two-tier stack): warm pages
        // cascade one rank upward per epoch, each boundary with its own
        // migration budget.
        for upper in 1..(self.tiers as u8 - 1) {
            let budget = view.budget(upper as usize) as usize;
            select_boundary_into(
                &out.hotness,
                &self.tier_of,
                upper,
                SelectParams::new(budget, HYSTERESIS),
                BoundaryBias::default(),
                view.migrating,
                &mut self.pairs,
            );
        }
        self.hotness = out.hotness; // move, not clone (§Perf)
        &self.pairs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmmu::redirection::RedirectionTable;

    fn policy(pages: u64) -> HotnessPolicy {
        HotnessPolicy::new(pages, Box::new(NativeHotnessEngine))
    }

    fn view(t: &RedirectionTable) -> PolicyView<'_> {
        PolicyView {
            table: t,
            migrating: &|_| false,
            max_migrations: 8,
            boundary_budgets: &[],
        }
    }

    #[test]
    fn native_engine_math() {
        let mut e = NativeHotnessEngine;
        let out = e.step(&[3.0, 0.0], &[1.0, 0.0], &[4.0, 8.0], &[0.0, 1.0]);
        // page0: 0.5*4 + 3 + 2*1 = 7, in NVM -> promote 7
        assert_eq!(out.hotness, vec![7.0, 4.0]);
        assert_eq!(out.promote_score[0], 7.0);
        assert_eq!(out.demote_score[0], NEG_INF);
        // page1: 0.5*8 = 4, in DRAM -> demote -4
        assert_eq!(out.promote_score[1], NEG_INF);
        assert_eq!(out.demote_score[1], -4.0);
    }

    #[test]
    fn tiled_step_matches_scalar_reference() {
        // Sizes straddling tile boundaries, including a non-multiple tail.
        let mut rng = crate::util::rng::Xoshiro256::new(99);
        for n in [1usize, HOTNESS_TILE - 1, HOTNESS_TILE, 3 * HOTNESS_TILE + 17] {
            let reads: Vec<f32> = (0..n).map(|_| rng.below(50) as f32).collect();
            let writes: Vec<f32> = (0..n).map(|_| rng.below(20) as f32).collect();
            let prev: Vec<f32> = (0..n).map(|_| rng.below(1000) as f32 / 8.0).collect();
            let in_dram: Vec<f32> = (0..n).map(|_| (rng.below(2)) as f32).collect();

            let mut e = NativeHotnessEngine;
            let out = e.step(&reads, &writes, &prev, &in_dram);

            // Straight-line scalar reference (the pre-tiling definition).
            for i in 0..n {
                let hv = HOTNESS_DECAY * prev[i] + (reads[i] + WRITE_WEIGHT * writes[i]);
                assert_eq!(out.hotness[i], hv, "hotness[{i}] n={n}");
                let is_dram = in_dram[i] != 0.0;
                assert_eq!(out.promote_score[i], if is_dram { NEG_INF } else { hv });
                assert_eq!(out.demote_score[i], if is_dram { -hv } else { NEG_INF });
            }
        }
    }

    #[test]
    fn hot_nvm_page_promoted_over_cold_dram_page() {
        let mut t = RedirectionTable::two_tier(8, 4, 8, 4096);
        t.identity_map(); // pages 0-3 DRAM, 4-7 NVM
        let mut p = policy(8);
        // Page 5 (NVM) is hot; page 2 (DRAM) is cold (untouched).
        for _ in 0..100 {
            p.record_access(5, false);
        }
        // Give other DRAM pages some heat so page 2 is the victim.
        for d in [0u64, 1, 3] {
            for _ in 0..50 {
                p.record_access(d, false);
            }
        }
        let pairs = p.epoch(&view(&t));
        assert_eq!(pairs, vec![(5, 2)]);
    }

    #[test]
    fn hysteresis_blocks_marginal_swaps() {
        let mut t = RedirectionTable::two_tier(4, 2, 4, 4096);
        t.identity_map();
        let mut p = policy(4);
        // NVM page 2 barely warmer than DRAM page 0.
        for _ in 0..10 {
            p.record_access(2, false);
        }
        for _ in 0..9 {
            p.record_access(0, false);
        }
        for _ in 0..20 {
            p.record_access(1, false);
        }
        let pairs = p.epoch(&view(&t));
        assert!(pairs.is_empty(), "10 vs 9 is within hysteresis: {pairs:?}");
    }

    #[test]
    fn counters_reset_and_decay() {
        let mut t = RedirectionTable::two_tier(4, 2, 4, 4096);
        t.identity_map();
        let mut p = policy(4);
        for _ in 0..64 {
            p.record_access(3, false);
        }
        p.epoch(&view(&t));
        assert_eq!(p.hotness[3], 64.0);
        // Next epoch without accesses: decays.
        p.epoch(&view(&t));
        assert_eq!(p.hotness[3], 32.0);
    }

    #[test]
    fn migrating_pages_skipped() {
        let mut t = RedirectionTable::two_tier(8, 4, 8, 4096);
        t.identity_map();
        let mut p = policy(8);
        for _ in 0..100 {
            p.record_access(5, false);
        }
        let busy = |page: u64| page == 5;
        let v = PolicyView {
            table: &t,
            migrating: &busy,
            max_migrations: 8,
            boundary_budgets: &[],
        };
        let pairs = p.epoch(&v);
        assert!(pairs.iter().all(|&(a, b)| a != 5 && b != 5));
    }

    #[test]
    fn writes_weighted_heavier() {
        let mut e = NativeHotnessEngine;
        let out = e.step(&[10.0, 0.0], &[0.0, 6.0], &[0.0, 0.0], &[0.0, 0.0]);
        // 6 writes (×2) > 10 reads? No: 12 > 10 — write-hot page wins.
        assert!(out.promote_score[1] > out.promote_score[0]);
    }

    #[test]
    fn select_into_recycles_buffer_with_identical_decisions() {
        // A dirty, reused buffer must yield exactly what a fresh
        // allocation yields, every epoch, and must stop growing once it
        // has seen a full-k selection.
        let mut rng = crate::util::rng::Xoshiro256::new(2024);
        let mut e = NativeHotnessEngine;
        let mut buf: Vec<(u64, u64)> = vec![(999, 999); 3]; // pre-polluted
        let mut warm_cap = 0usize;
        for iter in 0..50 {
            let n = 512usize;
            let reads: Vec<f32> = (0..n).map(|_| rng.below(100) as f32).collect();
            let writes: Vec<f32> = (0..n).map(|_| rng.below(30) as f32).collect();
            let prev: Vec<f32> = (0..n).map(|_| rng.below(200) as f32).collect();
            let in_dram: Vec<f32> = (0..n).map(|_| rng.below(2) as f32).collect();
            let out = e.step(&reads, &writes, &prev, &in_dram);
            let reference = HotnessPolicy::select_migrations(&out, 8, HYSTERESIS, &|_| false);
            HotnessPolicy::select_migrations_into(&out, 8, HYSTERESIS, &|_| false, &mut buf);
            assert_eq!(buf, reference, "iter {iter}: decisions diverged");
            if iter == 4 {
                warm_cap = buf.capacity();
            } else if iter > 4 {
                assert!(
                    buf.capacity() <= warm_cap.max(8),
                    "iter {iter}: steady-state buffer growth ({} > {warm_cap})",
                    buf.capacity()
                );
            }
        }
        assert!(warm_cap <= 8, "capacity bounded by k: {warm_cap}");
    }

    #[test]
    fn epoch_pair_buffer_reaches_steady_state() {
        // Hammer the policy so every epoch selects the full migration cap:
        // the recycled pair buffer must reach k capacity once and never
        // grow again (zero steady-state allocation, ROADMAP item).
        let mut t = RedirectionTable::two_tier(64, 32, 32, 4096);
        t.identity_map(); // 0-31 DRAM, 32-63 NVM
        let mut p = policy(64);
        let mut warm = 0usize;
        for epoch in 0..30 {
            for page in 32..64u64 {
                for _ in 0..50 {
                    p.record_access(page, false);
                }
            }
            let n_pairs = p.epoch(&view(&t)).len();
            assert_eq!(n_pairs, 8, "epoch {epoch}: full-k selection expected");
            if epoch == 0 {
                warm = p.pairs_capacity();
            } else {
                assert_eq!(
                    p.pairs_capacity(),
                    warm,
                    "epoch {epoch}: pair buffer grew after warmup"
                );
            }
        }
        assert!(warm <= 8, "capacity bounded by k: {warm}");
    }

    #[test]
    fn three_tier_cascade_promotes_warm_pages_one_rank() {
        // 4 DRAM + 4 tier-1 + 8 tier-2 frames, identity mapped. DRAM is
        // scorching (no rank-0 swap clears hysteresis); a warm tier-2
        // page must still climb into tier 1 via the boundary-1 cascade.
        let mut t = RedirectionTable::new(16, &[4, 4, 8], 4096);
        t.identity_map();
        let mut p = HotnessPolicy::new_tiered(16, 3, Box::new(NativeHotnessEngine));
        for d in 0..4u64 {
            for _ in 0..100 {
                p.record_access(d, false);
            }
        }
        for _ in 0..20 {
            p.record_access(8, false); // warm page deep in tier 2
        }
        let pairs = p.epoch(&view(&t));
        assert_eq!(
            pairs,
            vec![(8, 4)],
            "warm tier-2 page swaps with the coldest tier-1 page"
        );
    }

    #[test]
    fn cascade_never_selects_a_page_twice() {
        // A scorching tier-2 page wins the rank-0 boundary; the deeper
        // boundary must skip it (already paired) and promote the next
        // warm page instead.
        let mut t = RedirectionTable::new(16, &[4, 4, 8], 4096);
        t.identity_map();
        let mut p = HotnessPolicy::new_tiered(16, 3, Box::new(NativeHotnessEngine));
        for d in 0..4u64 {
            for _ in 0..100 {
                p.record_access(d, false); // DRAM warm: hysteresis bar is high
            }
        }
        for _ in 0..300 {
            p.record_access(8, false); // hot: clears the rank-0 bar
        }
        for _ in 0..50 {
            p.record_access(9, false); // warm: blocked at rank 0, cascades
        }
        let pairs = p.epoch(&view(&t)).to_vec();
        let mut seen = std::collections::HashSet::new();
        for &(a, b) in &pairs {
            assert!(seen.insert(a), "page {a} selected twice: {pairs:?}");
            assert!(seen.insert(b), "page {b} selected twice: {pairs:?}");
        }
        assert!(pairs.contains(&(8, 0)), "hot page promotes to rank 0: {pairs:?}");
        assert!(pairs.contains(&(9, 4)), "warm page cascades to rank 1: {pairs:?}");
    }

    #[test]
    fn two_tier_stack_runs_no_cascade() {
        // With two tiers the cascade loop is empty: `new` and
        // `new_tiered(.., 2, ..)` make identical decisions.
        let mut t = RedirectionTable::new(8, &[4, 8], 4096);
        t.identity_map();
        let mut a = policy(8);
        let mut b = HotnessPolicy::new_tiered(8, 2, Box::new(NativeHotnessEngine));
        for pg in [5u64, 5, 5, 6, 0] {
            a.record_access(pg, false);
            b.record_access(pg, false);
        }
        assert_eq!(a.epoch(&view(&t)), b.epoch(&view(&t)));
    }

    #[test]
    fn respects_migration_cap() {
        let mut t = RedirectionTable::two_tier(64, 32, 32, 4096);
        t.identity_map();
        let mut p = policy(64);
        for page in 32..64 {
            for _ in 0..100 {
                p.record_access(page, false);
            }
        }
        let v = PolicyView {
            table: &t,
            migrating: &|_| false,
            max_migrations: 4,
            boundary_budgets: &[],
        };
        assert_eq!(p.epoch(&v).len(), 4);
    }

    #[test]
    fn boundary_budget_overrides_rank0_cap() {
        // Same hammered table as `respects_migration_cap`, but with a
        // per-boundary override for boundary 0: the override wins, and a
        // zero entry falls back to the legacy epoch-wide cap.
        let mut t = RedirectionTable::two_tier(64, 32, 32, 4096);
        t.identity_map();
        let hammer = |p: &mut HotnessPolicy| {
            for page in 32..64 {
                for _ in 0..100 {
                    p.record_access(page, false);
                }
            }
        };
        let mut p = policy(64);
        hammer(&mut p);
        let v = PolicyView {
            table: &t,
            migrating: &|_| false,
            max_migrations: 8,
            boundary_budgets: &[2],
        };
        assert_eq!(p.epoch(&v).len(), 2, "override caps boundary 0");

        let mut p = policy(64);
        hammer(&mut p);
        let v = PolicyView {
            table: &t,
            migrating: &|_| false,
            max_migrations: 8,
            boundary_budgets: &[0, 0, 0],
        };
        assert_eq!(p.epoch(&v).len(), 8, "zero entries fall back to the cap");
    }
}
