//! Static address-split policy: host pages below the DRAM capacity live
//! in DRAM, the rest in NVM; no migration ever. The trivial baseline —
//! equivalent to the redirection table's identity mapping.

use super::{Device, PlacementPolicy, PolicyView};
use crate::alloc::Placement;

pub struct StaticPolicy {
    dram_pages: u64,
}

impl StaticPolicy {
    pub fn new(dram_pages: u64) -> Self {
        StaticPolicy { dram_pages }
    }
}

impl PlacementPolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn place(&mut self, page: u64, _hint: Placement) -> Device {
        if page < self.dram_pages {
            Device::Dram
        } else {
            Device::Nvm
        }
    }

    fn record_access(&mut self, _page: u64, _is_write: bool) {}

    fn epoch(&mut self, _view: &PolicyView) -> &[(u64, u64)] {
        &[]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmmu::redirection::RedirectionTable;

    #[test]
    fn splits_at_capacity() {
        let mut p = StaticPolicy::new(100);
        assert_eq!(p.place(0, Placement::Any), Device::Dram);
        assert_eq!(p.place(99, Placement::Any), Device::Dram);
        assert_eq!(p.place(100, Placement::Any), Device::Nvm);
        // Hints ignored by design.
        assert_eq!(p.place(500, Placement::PreferDram), Device::Nvm);
    }

    #[test]
    fn never_migrates() {
        let mut p = StaticPolicy::new(10);
        for page in 0..1000 {
            p.record_access(page % 20, true);
        }
        let t = RedirectionTable::new(20, 10, 10, 4096);
        let not_migrating = |_: u64| false;
        let v = PolicyView {
            table: &t,
            migrating: &not_migrating,
            max_migrations: 8,
        };
        assert!(p.epoch(&v).is_empty());
    }
}
