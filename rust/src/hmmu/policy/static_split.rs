//! Static address-split policy: the flat host space is carved across the
//! tier stack in rank order by capacity — host pages below the rank-0
//! capacity live there, the next span on rank 1, and so on; no migration
//! ever. The trivial baseline — equivalent to the redirection table's
//! identity mapping.

use super::{Device, PlacementPolicy, PolicyView};
use crate::alloc::Placement;
use crate::hmmu::redirection::TierId;

#[derive(Clone)]
pub struct StaticPolicy {
    /// Cumulative page-count boundaries, rank order: a page below
    /// `bounds[t]` (and not below `bounds[t-1]`) lives on tier `t`.
    bounds: Vec<u64>,
}

impl StaticPolicy {
    /// Two-tier constructor (the legacy call shape): everything below
    /// `dram_pages` is rank 0, the rest rank 1.
    pub fn new(dram_pages: u64) -> Self {
        StaticPolicy {
            bounds: vec![dram_pages, u64::MAX],
        }
    }

    /// Stack-generic constructor from per-tier page counts, rank order.
    pub fn new_tiered(tier_pages: &[u64]) -> Self {
        let mut bounds = Vec::with_capacity(tier_pages.len());
        let mut cum = 0u64;
        for &p in tier_pages {
            cum += p;
            bounds.push(cum);
        }
        if let Some(last) = bounds.last_mut() {
            *last = u64::MAX; // the table falls back when the last tier fills
        }
        StaticPolicy { bounds }
    }
}

impl PlacementPolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn place(&mut self, page: u64, _hint: Placement) -> Device {
        let rank = self
            .bounds
            .iter()
            .position(|&b| page < b)
            .unwrap_or(self.bounds.len() - 1);
        TierId(rank as u8)
    }

    fn record_access(&mut self, _page: u64, _is_write: bool) {}

    fn epoch(&mut self, _view: &PolicyView) -> &[(u64, u64)] {
        &[]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hmmu::redirection::RedirectionTable;

    #[test]
    fn splits_at_capacity() {
        let mut p = StaticPolicy::new(100);
        assert_eq!(p.place(0, Placement::Any), TierId::Dram);
        assert_eq!(p.place(99, Placement::Any), TierId::Dram);
        assert_eq!(p.place(100, Placement::Any), TierId::Nvm);
        // Hints ignored by design.
        assert_eq!(p.place(500, Placement::PreferDram), TierId::Nvm);
    }

    #[test]
    fn tiered_split_matches_cumulative_capacities() {
        let mut p = StaticPolicy::new_tiered(&[4, 4, 8]);
        assert_eq!(p.place(3, Placement::Any), TierId(0));
        assert_eq!(p.place(4, Placement::Any), TierId(1));
        assert_eq!(p.place(7, Placement::Any), TierId(1));
        assert_eq!(p.place(8, Placement::Any), TierId(2));
        assert_eq!(p.place(15, Placement::Any), TierId(2));
        // Beyond the stack: stays on the last rank (table falls back).
        assert_eq!(p.place(99, Placement::Any), TierId(2));
    }

    #[test]
    fn two_tier_constructors_agree() {
        let mut legacy = StaticPolicy::new(10);
        let mut tiered = StaticPolicy::new_tiered(&[10, 90]);
        for page in [0u64, 5, 9, 10, 50, 99, 1000] {
            assert_eq!(
                legacy.place(page, Placement::Any),
                tiered.place(page, Placement::Any),
                "page {page}"
            );
        }
    }

    #[test]
    fn never_migrates() {
        let mut p = StaticPolicy::new(10);
        for page in 0..1000 {
            p.record_access(page % 20, true);
        }
        let t = RedirectionTable::two_tier(20, 10, 10, 4096);
        let not_migrating = |_: u64| false;
        let v = PolicyView {
            table: &t,
            migrating: &not_migrating,
            max_migrations: 8,
            boundary_budgets: &[],
        };
        assert!(p.epoch(&v).is_empty());
    }
}
