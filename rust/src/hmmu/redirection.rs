//! Address redirection table — the paper's §III-B "heterogeneity
//! transparency" mechanism.
//!
//! The OS sees one flat physical space (the BAR window); the HMMU
//! translates each host page to a *device frame* (DRAM or NVM). The
//! mapping is the mutable core of every placement policy, and page
//! migration is a frame swap in this table.

use crate::bail;
use crate::util::error::Result;

/// Which memory device backs a frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Device {
    Dram,
    Nvm,
}

impl Device {
    pub fn name(&self) -> &'static str {
        match self {
            Device::Dram => "DRAM",
            Device::Nvm => "NVM",
        }
    }
}

/// Packed table entry: device bit + frame index (u32 capped: 16 TiB of 4K
/// pages is far beyond the platform).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mapping {
    pub device: Device,
    pub frame: u32,
}

const UNMAPPED: u32 = u32::MAX;

/// Host-page → device-frame redirection table with frame free lists.
#[derive(Clone, Debug)]
pub struct RedirectionTable {
    page_bytes: u64,
    /// Packed entries: high bit = device (1 = NVM), low 31 bits = frame;
    /// `UNMAPPED` = not yet placed.
    entries: Vec<u32>,
    free_dram: Vec<u32>,
    free_nvm: Vec<u32>,
    dram_frames: u32,
    nvm_frames: u32,
    /// Mapped-page count, maintained on place (§Perf: keeps
    /// `dram_residency()` O(1) instead of a full-table walk per report).
    mapped: u64,
    /// Mapped pages currently backed by DRAM, maintained on place/swap.
    dram_resident: u64,
}

impl RedirectionTable {
    /// `host_pages` = size of the flat space; frames per device from the
    /// device capacities. Pages start **unmapped** (policies place them on
    /// first touch) unless [`Self::identity_map`] is called.
    pub fn new(host_pages: u64, dram_frames: u32, nvm_frames: u32, page_bytes: u64) -> Self {
        assert!(host_pages <= (dram_frames as u64 + nvm_frames as u64));
        // Free lists popped from the back → allocate low frames first.
        let free_dram: Vec<u32> = (0..dram_frames).rev().collect();
        let free_nvm: Vec<u32> = (0..nvm_frames).rev().collect();
        RedirectionTable {
            page_bytes,
            entries: vec![UNMAPPED; host_pages as usize],
            free_dram,
            free_nvm,
            dram_frames,
            nvm_frames,
            mapped: 0,
            dram_resident: 0,
        }
    }

    #[inline]
    fn pack(m: Mapping) -> u32 {
        debug_assert!(m.frame < (1 << 31));
        match m.device {
            Device::Dram => m.frame,
            Device::Nvm => m.frame | 0x8000_0000,
        }
    }

    #[inline]
    fn unpack(e: u32) -> Mapping {
        if e & 0x8000_0000 != 0 {
            Mapping {
                device: Device::Nvm,
                frame: e & 0x7FFF_FFFF,
            }
        } else {
            Mapping {
                device: Device::Dram,
                frame: e,
            }
        }
    }

    pub fn host_pages(&self) -> u64 {
        self.entries.len() as u64
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    /// Identity mapping: host pages below the DRAM capacity map to DRAM
    /// frames 1:1, the rest to NVM frames (the paper's "straightforward
    /// approach" / the static policy's starting point).
    pub fn identity_map(&mut self) {
        for page in 0..self.entries.len() as u64 {
            let m = if page < self.dram_frames as u64 {
                Mapping {
                    device: Device::Dram,
                    frame: page as u32,
                }
            } else {
                Mapping {
                    device: Device::Nvm,
                    frame: (page - self.dram_frames as u64) as u32,
                }
            };
            self.entries[page as usize] = Self::pack(m);
        }
        self.free_dram.clear();
        self.free_nvm.clear();
        // Leftover NVM frames stay free.
        let used_nvm = self.entries.len() as u64 - self.dram_frames as u64;
        self.free_nvm = ((used_nvm as u32)..self.nvm_frames).rev().collect();
        self.mapped = self.entries.len() as u64;
        self.dram_resident = self.mapped.min(self.dram_frames as u64);
    }

    /// Look up a host page; `None` if unmapped.
    #[inline]
    pub fn lookup(&self, page: u64) -> Option<Mapping> {
        let e = self.entries[page as usize];
        if e == UNMAPPED {
            None
        } else {
            Some(Self::unpack(e))
        }
    }

    /// Translate a host address to (device, device address).
    #[inline]
    pub fn translate(&self, addr: u64) -> Option<(Device, u64)> {
        let page = addr / self.page_bytes;
        let off = addr % self.page_bytes;
        self.lookup(page)
            .map(|m| (m.device, m.frame as u64 * self.page_bytes + off))
    }

    /// Place an unmapped page on `device`; falls back to the other device
    /// when full. Returns the final mapping.
    pub fn place(&mut self, page: u64, device: Device) -> Result<Mapping> {
        if self.entries[page as usize] != UNMAPPED {
            bail!("page {page} already mapped");
        }
        let m = match device {
            Device::Dram => {
                if let Some(f) = self.free_dram.pop() {
                    Mapping {
                        device: Device::Dram,
                        frame: f,
                    }
                } else if let Some(f) = self.free_nvm.pop() {
                    Mapping {
                        device: Device::Nvm,
                        frame: f,
                    }
                } else {
                    bail!("no free frames");
                }
            }
            Device::Nvm => {
                if let Some(f) = self.free_nvm.pop() {
                    Mapping {
                        device: Device::Nvm,
                        frame: f,
                    }
                } else if let Some(f) = self.free_dram.pop() {
                    Mapping {
                        device: Device::Dram,
                        frame: f,
                    }
                } else {
                    bail!("no free frames");
                }
            }
        };
        self.entries[page as usize] = Self::pack(m);
        self.mapped += 1;
        if m.device == Device::Dram {
            self.dram_resident += 1;
        }
        Ok(m)
    }

    /// Swap the frames of two host pages (post-DMA commit of a migration).
    /// Residency counters are conserved: the two entries trade places, so
    /// the multiset of mapped frames is unchanged.
    pub fn swap(&mut self, page_a: u64, page_b: u64) -> Result<()> {
        let (a, b) = (self.entries[page_a as usize], self.entries[page_b as usize]);
        if a == UNMAPPED || b == UNMAPPED {
            bail!("swap of unmapped page");
        }
        self.entries[page_a as usize] = b;
        self.entries[page_b as usize] = a;
        Ok(())
    }

    pub fn free_dram_frames(&self) -> usize {
        self.free_dram.len()
    }

    pub fn free_nvm_frames(&self) -> usize {
        self.free_nvm.len()
    }

    /// Count of mapped pages — O(1), maintained on place.
    pub fn mapped_pages(&self) -> u64 {
        self.mapped
    }

    /// Count of mapped pages currently backed by DRAM — O(1), maintained
    /// on place/swap (§Perf: was a full-table scan per call).
    pub fn dram_resident_pages(&self) -> u64 {
        self.dram_resident
    }

    /// Full-table recount of DRAM-resident pages; tests pin the O(1)
    /// counter against this.
    pub fn recount_dram_resident(&self) -> u64 {
        self.entries
            .iter()
            .filter(|&&e| e != UNMAPPED && e & 0x8000_0000 == 0)
            .count() as u64
    }

    /// Iterate mapped (page, mapping) pairs.
    pub fn iter_mapped(&self) -> impl Iterator<Item = (u64, Mapping)> + '_ {
        self.entries.iter().enumerate().filter_map(|(p, &e)| {
            if e == UNMAPPED {
                None
            } else {
                Some((p as u64, Self::unpack(e)))
            }
        })
    }

    /// Invariant check (used by property tests): every mapped frame is
    /// unique per device and no mapped frame is also on a free list.
    pub fn check_invariants(&self) -> Result<()> {
        let mut dram_seen = vec![false; self.dram_frames as usize];
        let mut nvm_seen = vec![false; self.nvm_frames as usize];
        for &e in &self.entries {
            if e == UNMAPPED {
                continue;
            }
            let m = Self::unpack(e);
            let seen = match m.device {
                Device::Dram => &mut dram_seen[m.frame as usize],
                Device::Nvm => &mut nvm_seen[m.frame as usize],
            };
            if *seen {
                bail!("frame {:?}:{} double-mapped", m.device, m.frame);
            }
            *seen = true;
        }
        for &f in &self.free_dram {
            if dram_seen[f as usize] {
                bail!("DRAM frame {f} both mapped and free");
            }
        }
        for &f in &self.free_nvm {
            if nvm_seen[f as usize] {
                bail!("NVM frame {f} both mapped and free");
            }
        }
        let mapped_recount = self.entries.iter().filter(|&&e| e != UNMAPPED).count() as u64;
        if self.mapped != mapped_recount {
            bail!("mapped counter {} != recount {mapped_recount}", self.mapped);
        }
        let dram_recount = self.recount_dram_resident();
        if self.dram_resident != dram_recount {
            bail!(
                "dram_resident counter {} != recount {dram_recount}",
                self.dram_resident
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> RedirectionTable {
        // 8 host pages, 4 DRAM + 8 NVM frames, 4K pages.
        RedirectionTable::new(8, 4, 8, 4096)
    }

    #[test]
    fn starts_unmapped() {
        let t = table();
        assert_eq!(t.lookup(0), None);
        assert_eq!(t.translate(100), None);
    }

    #[test]
    fn identity_map_splits_by_capacity() {
        let mut t = table();
        t.identity_map();
        assert_eq!(
            t.lookup(0),
            Some(Mapping {
                device: Device::Dram,
                frame: 0
            })
        );
        assert_eq!(
            t.lookup(4),
            Some(Mapping {
                device: Device::Nvm,
                frame: 0
            })
        );
        assert_eq!(t.free_nvm_frames(), 4); // 8 - 4 used
        t.check_invariants().unwrap();
    }

    #[test]
    fn translate_preserves_offset() {
        let mut t = table();
        t.identity_map();
        let (dev, da) = t.translate(5 * 4096 + 123).unwrap();
        assert_eq!(dev, Device::Nvm);
        assert_eq!(da, 4096 + 123); // nvm frame 1, offset 123
    }

    #[test]
    fn place_prefers_then_falls_back() {
        let mut t = table();
        for p in 0..4 {
            let m = t.place(p, Device::Dram).unwrap();
            assert_eq!(m.device, Device::Dram);
        }
        // DRAM exhausted → falls over to NVM.
        let m = t.place(4, Device::Dram).unwrap();
        assert_eq!(m.device, Device::Nvm);
        t.check_invariants().unwrap();
    }

    #[test]
    fn double_place_rejected() {
        let mut t = table();
        t.place(0, Device::Dram).unwrap();
        assert!(t.place(0, Device::Dram).is_err());
    }

    #[test]
    fn swap_moves_frames() {
        let mut t = table();
        t.identity_map();
        let before_a = t.lookup(0).unwrap();
        let before_b = t.lookup(7).unwrap();
        t.swap(0, 7).unwrap();
        assert_eq!(t.lookup(0), Some(before_b));
        assert_eq!(t.lookup(7), Some(before_a));
        t.check_invariants().unwrap();
    }

    #[test]
    fn swap_unmapped_fails() {
        let mut t = table();
        t.place(0, Device::Dram).unwrap();
        assert!(t.swap(0, 1).is_err());
    }

    #[test]
    fn exhaustion_errors() {
        let mut t = RedirectionTable::new(3, 1, 2, 4096);
        t.place(0, Device::Dram).unwrap();
        t.place(1, Device::Dram).unwrap();
        t.place(2, Device::Dram).unwrap();
        let mut t2 = RedirectionTable::new(2, 1, 1, 4096);
        t2.place(0, Device::Nvm).unwrap();
        t2.place(1, Device::Nvm).unwrap();
        // Everything mapped; placing again impossible (all pages mapped).
        assert_eq!(t2.free_dram_frames() + t2.free_nvm_frames(), 0);
    }

    #[test]
    fn dram_resident_count() {
        let mut t = table();
        t.identity_map();
        assert_eq!(t.dram_resident_pages(), 4);
        t.swap(0, 7).unwrap();
        assert_eq!(t.dram_resident_pages(), 4); // swap conserves
    }

    #[test]
    fn resident_counters_track_recount() {
        // Random place/swap churn: the O(1) counters must stay pinned to
        // the full-table recount the whole way.
        let mut t = RedirectionTable::new(64, 16, 64, 4096);
        let mut rng = crate::util::rng::Xoshiro256::new(99);
        let mut placed: Vec<u64> = Vec::new();
        for page in 0..48u64 {
            let dev = if rng.chance(0.5) {
                Device::Dram
            } else {
                Device::Nvm
            };
            t.place(page, dev).unwrap();
            placed.push(page);
            assert_eq!(t.dram_resident_pages(), t.recount_dram_resident());
            assert_eq!(t.mapped_pages(), page + 1);
        }
        for _ in 0..200 {
            let a = placed[rng.below(placed.len() as u64) as usize];
            let b = placed[rng.below(placed.len() as u64) as usize];
            if a != b {
                t.swap(a, b).unwrap();
            }
            assert_eq!(t.dram_resident_pages(), t.recount_dram_resident());
        }
        t.check_invariants().unwrap();
    }

    #[test]
    fn identity_map_sets_counters() {
        let mut t = table();
        t.identity_map();
        assert_eq!(t.mapped_pages(), 8);
        assert_eq!(t.dram_resident_pages(), t.recount_dram_resident());
        t.check_invariants().unwrap();
    }
}
