//! Address redirection table — the paper's §III-B "heterogeneity
//! transparency" mechanism, generalized to an N-tier stack and sharded
//! into power-of-two page-range stripes.
//!
//! The OS sees one flat physical space (the BAR window); the HMMU
//! translates each host page to a *device frame* in one of the stack's
//! tiers (rank 0 = fastest). The mapping is the mutable core of every
//! placement policy, and page migration is a frame swap in this table.
//! Frame pools and residency counters are **per tier** — the binary
//! `dram`/`nvm` pair is just the two-tier special case.
//!
//! # Shard layout
//!
//! The flat page space is striped across [`DEFAULT_SHARDS`] shards in
//! 64-page regions: stripe `t` (pages `t*64 .. t*64+64`) belongs to
//! shard `t % nshards`. Each shard owns its stripe entries plus
//! per-tier frame pools (frames `f` with `f % nshards == shard`),
//! retired pools, and O(1) mapped/residency counters that sum to the
//! global view — so future per-shard locking partitions *all* mutable
//! state, not just the entry array. The single-threaded fast path stays
//! lock-free, and allocation is **bit-identical** to the monolithic
//! table: pools are pop-only (frames are consumed by `place`, and
//! retirement moves frames to the retired pool, never back to a free
//! list), so the monolithic allocator always hands out the globally
//! lowest free frame of a tier — which the sharded table reproduces
//! exactly by popping the minimum across shard pool heads.

use crate::bail;
use crate::util::codec::{check_len, CodecState, Decoder, Encoder};
use crate::util::error::Result;

/// A tier of the memory stack, by rank (0 = fastest). The legacy
/// two-tier names survive as associated constants: `TierId::Dram` is
/// rank 0, `TierId::Nvm` rank 1 — so `Device::Dram`-style call sites
/// keep compiling against the [`Device`] alias.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TierId(pub u8);

/// Legacy alias: the binary device type, generalized to N tiers.
pub type Device = TierId;

#[allow(non_upper_case_globals)]
impl TierId {
    /// Rank-0 (DRAM-class) tier — the legacy two-tier name.
    pub const Dram: TierId = TierId(0);
    /// Rank-1 tier — the legacy two-tier "NVM" name.
    pub const Nvm: TierId = TierId(1);

    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }

    #[inline]
    pub fn rank(self) -> u8 {
        self.0
    }

    pub fn name(&self) -> &'static str {
        const NAMES: [&str; 8] = [
            "DRAM", "NVM", "TIER2", "TIER3", "TIER4", "TIER5", "TIER6", "TIER7",
        ];
        NAMES[self.0 as usize]
    }
}

impl std::fmt::Debug for TierId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Keep the legacy enum-style rendering for the two-tier names.
        match self.0 {
            0 => f.write_str("Dram"),
            1 => f.write_str("Nvm"),
            n => write!(f, "Tier{n}"),
        }
    }
}

/// Packed table entry: tier rank + frame index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mapping {
    pub device: TierId,
    pub frame: u32,
}

const UNMAPPED: u32 = u32::MAX;
/// Bits of a packed entry that hold the frame index; the top 3 bits hold
/// the tier rank (`config::MAX_TIERS` = 8). 2^28 4K frames = 1 TiB per
/// tier — far beyond the platform.
const FRAME_BITS: u32 = 28;
const FRAME_MASK: u32 = (1 << FRAME_BITS) - 1;

/// Pages per shard stripe: 2^6 = 64 pages (256 KiB of 4 KiB pages), so
/// spatially-local traffic stays inside one shard while distinct
/// workload regions spread across all of them.
const STRIPE_SHIFT: u32 = 6;
const STRIPE_LEN: u64 = 1 << STRIPE_SHIFT;
const STRIPE_MASK: u64 = STRIPE_LEN - 1;

/// Default shard count (power of two). One shard per plausible worker
/// core keeps future per-shard locking uncontended; a count of 1 is the
/// monolithic table (the shard-property tests pin 1 vs N bit-identity).
pub const DEFAULT_SHARDS: usize = 8;

/// One page-range shard: stripe entries plus the shard's slice of every
/// tier's frame pool, retired pool, and counters.
#[derive(Clone, Debug)]
struct Shard {
    /// Packed entries for this shard's stripes, stripe-major: local
    /// index `(k << STRIPE_SHIFT) | offset` is the k-th stripe owned by
    /// the shard. Tail padding past `host_pages` stays `UNMAPPED`.
    entries: Vec<u32>,
    /// Per-tier free pools over frames `f` with `f % nshards == shard`,
    /// descending (popped from the back → the shard's lowest frame
    /// first).
    free: Vec<Vec<u32>>,
    /// Per-tier retired frames owned by this shard.
    retired: Vec<Vec<u32>>,
    /// Mapped pages owned by this shard.
    mapped: u64,
    /// Per-tier residency of this shard's pages; sums to `mapped`.
    resident: Vec<u64>,
}

/// Host-page → tier-frame redirection table with per-tier frame free
/// lists and residency counters, sharded by page range.
#[derive(Clone, Debug)]
pub struct RedirectionTable {
    // audit: allow(codec-coverage) — geometry, re-derived from config
    page_bytes: u64,
    /// Size of the flat host space. Shard entry arrays are padded to
    /// whole stripes, so the true page count is stored explicitly (and
    /// validated on decode).
    host_pages: u64,
    // audit: allow(codec-coverage) — geometry, re-derived from shard count
    shard_bits: u32,
    // audit: allow(codec-coverage) — geometry, re-derived from shard count
    shard_mask: usize,
    /// Frame capacity per tier.
    // audit: allow(codec-coverage) — geometry, validated not restored
    frames: Vec<u32>,
    /// Page-range shards; every mutable field below is the sum of its
    /// per-shard counterparts.
    shards: Vec<Shard>,
    /// Mapped-page count, maintained on place (§Perf: keeps residency
    /// reporting O(1) instead of a full-table walk).
    mapped: u64,
    /// Mapped pages currently backed by each tier, maintained on
    /// place/swap; sums to `mapped`.
    resident: Vec<u64>,
}

impl RedirectionTable {
    /// `host_pages` = size of the flat space; `tier_frames` = frame
    /// capacity per tier, rank order. Pages start **unmapped** (policies
    /// place them on first touch) unless [`Self::identity_map`] is
    /// called. Uses [`DEFAULT_SHARDS`] page-range shards.
    pub fn new(host_pages: u64, tier_frames: &[u32], page_bytes: u64) -> Self {
        Self::new_with_shards(host_pages, tier_frames, page_bytes, DEFAULT_SHARDS)
    }

    /// [`Self::new`] with an explicit shard count (power of two).
    /// `nshards == 1` is the monolithic table; the shard property tests
    /// pin every count bit-identical to it.
    pub fn new_with_shards(
        host_pages: u64,
        tier_frames: &[u32],
        page_bytes: u64,
        nshards: usize,
    ) -> Self {
        assert!(
            (2..=crate::config::MAX_TIERS).contains(&tier_frames.len()),
            "tier stack must hold 2..=8 tiers"
        );
        assert!(
            tier_frames.iter().all(|&f| f < FRAME_MASK),
            "tier frame count exceeds the packed-entry range"
        );
        assert!(host_pages <= tier_frames.iter().map(|&f| f as u64).sum());
        assert!(
            nshards.is_power_of_two(),
            "shard count must be a power of two"
        );
        let tiers = tier_frames.len();
        let stripes = host_pages.div_ceil(STRIPE_LEN);
        let mut shards: Vec<Shard> = (0..nshards)
            .map(|s| {
                // Stripes are dealt round-robin: shard s owns stripe t
                // iff t % nshards == s.
                let own = stripes / nshards as u64
                    + u64::from((s as u64) < stripes % nshards as u64);
                Shard {
                    entries: vec![UNMAPPED; (own * STRIPE_LEN) as usize],
                    free: vec![Vec::new(); tiers],
                    retired: vec![Vec::new(); tiers],
                    mapped: 0,
                    resident: vec![0; tiers],
                }
            })
            .collect();
        // Pools popped from the back → each shard allocates its lowest
        // frame first; `pop_lowest` takes the minimum across shards.
        let mask = nshards - 1;
        for (t, &f) in tier_frames.iter().enumerate() {
            for frame in (0..f).rev() {
                shards[frame as usize & mask].free[t].push(frame);
            }
        }
        RedirectionTable {
            page_bytes,
            host_pages,
            shard_bits: nshards.trailing_zeros(),
            shard_mask: mask,
            frames: tier_frames.to_vec(),
            shards,
            mapped: 0,
            resident: vec![0; tiers],
        }
    }

    /// Two-tier convenience constructor (the legacy call shape).
    pub fn two_tier(host_pages: u64, dram_frames: u32, nvm_frames: u32, page_bytes: u64) -> Self {
        Self::new(host_pages, &[dram_frames, nvm_frames], page_bytes)
    }

    #[inline]
    fn pack(m: Mapping) -> u32 {
        debug_assert!(m.frame < FRAME_MASK);
        ((m.device.0 as u32) << FRAME_BITS) | m.frame
    }

    #[inline]
    fn unpack(e: u32) -> Mapping {
        Mapping {
            device: TierId((e >> FRAME_BITS) as u8),
            frame: e & FRAME_MASK,
        }
    }

    /// (shard, local entry index) of a host page.
    #[inline]
    fn locate(&self, page: u64) -> (usize, usize) {
        assert!(page < self.host_pages, "page {page} out of range");
        let stripe = page >> STRIPE_SHIFT;
        let shard = stripe as usize & self.shard_mask;
        let local = ((stripe >> self.shard_bits) << STRIPE_SHIFT) | (page & STRIPE_MASK);
        (shard, local as usize)
    }

    #[inline]
    fn slot(&self, page: u64) -> u32 {
        let (s, l) = self.locate(page);
        self.shards[s].entries[l]
    }

    pub fn host_pages(&self) -> u64 {
        self.host_pages
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    /// Number of tiers in the stack.
    pub fn tiers(&self) -> usize {
        self.frames.len()
    }

    /// Number of page-range shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Pop the globally lowest free frame of tier `t` — the monolithic
    /// allocation order, recovered as the min across shard pool heads
    /// (each head is its shard's minimum; pools are pop-only, so the
    /// partition never loses the global order).
    fn pop_lowest(&mut self, t: usize) -> Option<u32> {
        let mut best_shard = usize::MAX;
        let mut best_frame = u32::MAX;
        for (s, sh) in self.shards.iter().enumerate() {
            if let Some(&head) = sh.free[t].last() {
                if head < best_frame {
                    best_frame = head;
                    best_shard = s;
                }
            }
        }
        if best_shard == usize::MAX {
            return None;
        }
        self.shards[best_shard].free[t].pop()
    }

    /// Identity mapping: host pages fill the tiers in rank order 1:1
    /// (the paper's "straightforward approach" / the static policy's
    /// starting point).
    pub fn identity_map(&mut self) {
        debug_assert!(
            self.shards.iter().all(|s| s.retired.iter().all(Vec::is_empty)),
            "identity_map re-issues every frame; only valid on a fresh table"
        );
        for sh in &mut self.shards {
            sh.mapped = 0;
            sh.resident.fill(0);
            for pool in &mut sh.free {
                pool.clear();
            }
        }
        self.resident.fill(0);
        let mut tier = 0usize;
        let mut next_frame = 0u32;
        for page in 0..self.host_pages {
            while next_frame >= self.frames[tier] {
                tier += 1;
                next_frame = 0;
            }
            let (s, l) = self.locate(page);
            self.shards[s].entries[l] = Self::pack(Mapping {
                device: TierId(tier as u8),
                frame: next_frame,
            });
            self.shards[s].mapped += 1;
            self.shards[s].resident[tier] += 1;
            self.resident[tier] += 1;
            next_frame += 1;
        }
        // Remaining frames of the partially-filled tier and every deeper
        // tier stay free, dealt back to their owning shards.
        for t in 0..self.tiers() {
            let used = if t < tier {
                self.frames[t]
            } else if t == tier {
                next_frame
            } else {
                0
            };
            for frame in (used..self.frames[t]).rev() {
                self.shards[frame as usize & self.shard_mask].free[t].push(frame);
            }
        }
        self.mapped = self.host_pages;
    }

    /// Look up a host page; `None` if unmapped.
    #[inline]
    pub fn lookup(&self, page: u64) -> Option<Mapping> {
        let e = self.slot(page);
        if e == UNMAPPED {
            None
        } else {
            Some(Self::unpack(e))
        }
    }

    /// Translate a host address to (tier, device address).
    #[inline]
    pub fn translate(&self, addr: u64) -> Option<(TierId, u64)> {
        let page = addr / self.page_bytes;
        let off = addr % self.page_bytes;
        self.lookup(page)
            .map(|m| (m.device, m.frame as u64 * self.page_bytes + off))
    }

    /// Place an unmapped page on `tier`, falling back when it is full:
    /// first down the stack (slower ranks — overflow demotes rather than
    /// stealing faster frames), then up. For a two-tier stack this is
    /// exactly the legacy behavior (DRAM→NVM, NVM→DRAM). Returns the
    /// final mapping.
    pub fn place(&mut self, page: u64, tier: TierId) -> Result<Mapping> {
        let (ps, pl) = self.locate(page);
        if self.shards[ps].entries[pl] != UNMAPPED {
            bail!("page {page} already mapped");
        }
        let start = tier.index().min(self.tiers() - 1);
        let order = (start..self.tiers()).chain((0..start).rev());
        let mut found = None;
        for t in order {
            if let Some(f) = self.pop_lowest(t) {
                found = Some(Mapping {
                    device: TierId(t as u8),
                    frame: f,
                });
                break;
            }
        }
        let Some(m) = found else {
            bail!("no free frames");
        };
        self.shards[ps].entries[pl] = Self::pack(m);
        self.shards[ps].mapped += 1;
        self.shards[ps].resident[m.device.index()] += 1;
        self.mapped += 1;
        self.resident[m.device.index()] += 1;
        Ok(m)
    }

    /// Swap the frames of two host pages (post-DMA commit of a migration).
    /// Residency counters are conserved globally: the two entries trade
    /// places, so the multiset of mapped frames is unchanged — but when
    /// the pages live in different shards *and* different tiers, the
    /// per-shard residency moves with them.
    pub fn swap(&mut self, page_a: u64, page_b: u64) -> Result<()> {
        let (sa, la) = self.locate(page_a);
        let (sb, lb) = self.locate(page_b);
        let a = self.shards[sa].entries[la];
        let b = self.shards[sb].entries[lb];
        if a == UNMAPPED || b == UNMAPPED {
            bail!("swap of unmapped page");
        }
        self.shards[sa].entries[la] = b;
        self.shards[sb].entries[lb] = a;
        let (ta, tb) = (Self::unpack(a).device.index(), Self::unpack(b).device.index());
        if ta != tb {
            self.shards[sa].resident[ta] -= 1;
            self.shards[sa].resident[tb] += 1;
            self.shards[sb].resident[tb] -= 1;
            self.shards[sb].resident[ta] += 1;
        }
        Ok(())
    }

    /// Retire the frame backing `page` (uncorrectable error / endurance
    /// death) and remap the page onto a healthy frame, preferring the
    /// same tier then falling down-then-up the stack in [`Self::place`]
    /// order. The dead frame lands in the retired pool of the shard that
    /// owns it — it is **never** returned to a free list, so the tier's
    /// effective capacity shrinks. Returns the new mapping, or `None`
    /// when no free frame exists anywhere in the stack (fully mapped:
    /// the page must survive on its degraded frame rather than be lost,
    /// and the caller skips the retirement).
    pub fn retire_and_remap(&mut self, page: u64) -> Result<Option<Mapping>> {
        let (ps, pl) = self.locate(page);
        let e = self.shards[ps].entries[pl];
        if e == UNMAPPED {
            bail!("retire of unmapped page {page}");
        }
        let old = Self::unpack(e);
        let start = old.device.index();
        let order = (start..self.tiers()).chain((0..start).rev());
        let mut found = None;
        for t in order {
            if let Some(f) = self.pop_lowest(t) {
                found = Some(Mapping {
                    device: TierId(t as u8),
                    frame: f,
                });
                break;
            }
        }
        let Some(m) = found else {
            return Ok(None);
        };
        self.shards[ps].entries[pl] = Self::pack(m);
        self.shards[ps].resident[old.device.index()] -= 1;
        self.shards[ps].resident[m.device.index()] += 1;
        self.resident[old.device.index()] -= 1;
        self.resident[m.device.index()] += 1;
        let owner = old.frame as usize & self.shard_mask;
        self.shards[owner].retired[old.device.index()].push(old.frame);
        Ok(Some(m))
    }

    /// Frames permanently retired on `tier`, summed across shards.
    pub fn retired_frames(&self, tier: TierId) -> usize {
        self.shards
            .iter()
            .map(|s| s.retired[tier.index()].len())
            .sum()
    }

    /// Usable frame capacity of `tier` after retirements — the
    /// degradation sweep's "effective capacity" column.
    pub fn effective_frames(&self, tier: TierId) -> u64 {
        self.frames[tier.index()] as u64 - self.retired_frames(tier) as u64
    }

    /// Free frames currently available on `tier`, summed across shards.
    pub fn free_frames(&self, tier: TierId) -> usize {
        self.shards.iter().map(|s| s.free[tier.index()].len()).sum()
    }

    pub fn free_dram_frames(&self) -> usize {
        self.free_frames(TierId::Dram)
    }

    pub fn free_nvm_frames(&self) -> usize {
        self.free_frames(TierId::Nvm)
    }

    /// Count of mapped pages — O(1), maintained on place.
    pub fn mapped_pages(&self) -> u64 {
        self.mapped
    }

    /// Mapped pages currently backed by `tier` — O(1), maintained on
    /// place (swaps conserve the per-tier counts).
    pub fn resident_pages(&self, tier: TierId) -> u64 {
        self.resident[tier.index()]
    }

    /// Per-tier residency counts, rank order; sums to
    /// [`Self::mapped_pages`].
    pub fn residency(&self) -> &[u64] {
        &self.resident
    }

    /// Count of mapped pages currently backed by rank 0 — the legacy
    /// accessor.
    pub fn dram_resident_pages(&self) -> u64 {
        self.resident[0]
    }

    /// Full-table recount of pages resident on `tier`; tests pin the
    /// O(1) counters against this. Shard padding entries are `UNMAPPED`,
    /// so the raw scan over shard arrays is exact.
    pub fn recount_resident(&self, tier: TierId) -> u64 {
        self.shards
            .iter()
            .flat_map(|s| s.entries.iter())
            .filter(|&&e| e != UNMAPPED && Self::unpack(e).device == tier)
            .count() as u64
    }

    /// Legacy rank-0 recount.
    pub fn recount_dram_resident(&self) -> u64 {
        self.recount_resident(TierId::Dram)
    }

    /// Iterate mapped (page, mapping) pairs in ascending page order —
    /// the sorted merge across shards (page order interleaves stripe
    /// storage, so walking the flat space in order reads each shard's
    /// stripes in sequence). Codec and fingerprint consumers rely on
    /// this order being shard-count independent.
    pub fn iter_mapped(&self) -> impl Iterator<Item = (u64, Mapping)> + '_ {
        (0..self.host_pages).filter_map(|p| self.lookup(p).map(|m| (p, m)))
    }

    /// Invariant check (used by property tests): every mapped frame is
    /// unique per tier, no mapped frame is also on a free list, every
    /// shard holds only its own frames (in descending pool order) and
    /// its counters sum to the global O(1) view, retired frames are out
    /// of circulation, and per-tier accounting is conservative
    /// (resident + free + retired == capacity).
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen: Vec<Vec<bool>> =
            self.frames.iter().map(|&f| vec![false; f as usize]).collect();
        let mut mapped_recount = 0u64;
        let mut resident_recount = vec![0u64; self.tiers()];
        let mut shard_page_recount = vec![0u64; self.shards.len()];
        for page in 0..self.host_pages {
            let e = self.slot(page);
            if e == UNMAPPED {
                continue;
            }
            let m = Self::unpack(e);
            if m.device.index() >= self.tiers() || m.frame >= self.frames[m.device.index()] {
                bail!("entry {:?}:{} out of range", m.device, m.frame);
            }
            let s = &mut seen[m.device.index()][m.frame as usize];
            if *s {
                bail!("frame {:?}:{} double-mapped", m.device, m.frame);
            }
            *s = true;
            mapped_recount += 1;
            resident_recount[m.device.index()] += 1;
            shard_page_recount[self.locate(page).0] += 1;
        }
        // Stripe tail padding must stay unmapped: the raw entry count
        // across shards equals the per-page walk above.
        let raw_mapped = self
            .shards
            .iter()
            .flat_map(|s| s.entries.iter())
            .filter(|&&e| e != UNMAPPED)
            .count() as u64;
        if raw_mapped != mapped_recount {
            bail!("shard padding entries are mapped ({raw_mapped} != {mapped_recount})");
        }
        let mut dead: Vec<Vec<bool>> =
            self.frames.iter().map(|&f| vec![false; f as usize]).collect();
        for (snum, shard) in self.shards.iter().enumerate() {
            for (t, frees) in shard.free.iter().enumerate() {
                for (i, &f) in frees.iter().enumerate() {
                    if f >= self.frames[t] {
                        bail!("free frame {:?}:{f} out of range", TierId(t as u8));
                    }
                    if f as usize & self.shard_mask != snum {
                        bail!("shard {snum} pool holds foreign frame {:?}:{f}", TierId(t as u8));
                    }
                    if seen[t][f as usize] {
                        bail!("{:?} frame {f} both mapped and free", TierId(t as u8));
                    }
                    if i > 0 && frees[i - 1] <= f {
                        bail!("shard {snum} {:?} pool not descending", TierId(t as u8));
                    }
                }
            }
            // Retired frames are out of circulation: in range, owned by
            // this shard, not mapped, not free, never retired twice.
            for (t, retired) in shard.retired.iter().enumerate() {
                let tier = TierId(t as u8);
                for &f in retired {
                    if f >= self.frames[t] {
                        bail!("retired frame {tier:?}:{f} out of range");
                    }
                    if f as usize & self.shard_mask != snum {
                        bail!("shard {snum} retired pool holds foreign frame {tier:?}:{f}");
                    }
                    if seen[t][f as usize] {
                        bail!("{tier:?} frame {f} both mapped and retired");
                    }
                    if dead[t][f as usize] {
                        bail!("{tier:?} frame {f} retired twice");
                    }
                    dead[t][f as usize] = true;
                }
                for &f in &shard.free[t] {
                    if dead[t][f as usize] {
                        bail!("{tier:?} frame {f} both retired and free");
                    }
                }
            }
        }
        if self.mapped != mapped_recount {
            bail!("mapped counter {} != recount {mapped_recount}", self.mapped);
        }
        for t in 0..self.tiers() {
            let tier = TierId(t as u8);
            if self.resident[t] != resident_recount[t] {
                bail!(
                    "{tier:?} resident counter {} != recount {}",
                    self.resident[t],
                    resident_recount[t]
                );
            }
            // Conservation: every frame is mapped, free, or retired.
            let accounted = self.resident[t]
                + self.free_frames(tier) as u64
                + self.retired_frames(tier) as u64;
            if accounted != self.frames[t] as u64 {
                bail!(
                    "{tier:?} accounting {accounted} != capacity {}",
                    self.frames[t]
                );
            }
        }
        if self.resident.iter().sum::<u64>() != self.mapped {
            bail!("per-tier residency does not sum to the mapped count");
        }
        // Per-shard counters sum to the global view.
        let shard_mapped: u64 = self.shards.iter().map(|s| s.mapped).sum();
        if shard_mapped != self.mapped {
            bail!("shard mapped sum {shard_mapped} != global {}", self.mapped);
        }
        for (snum, shard) in self.shards.iter().enumerate() {
            if shard.mapped != shard_page_recount[snum] {
                bail!(
                    "shard {snum} mapped {} != recount {}",
                    shard.mapped,
                    shard_page_recount[snum]
                );
            }
            if shard.resident.iter().sum::<u64>() != shard.mapped {
                bail!("shard {snum} residency does not sum to its mapped count");
            }
        }
        for t in 0..self.tiers() {
            let sum: u64 = self.shards.iter().map(|s| s.resident[t]).sum();
            if sum != self.resident[t] {
                bail!(
                    "{:?} shard residency sum {sum} != global {}",
                    TierId(t as u8),
                    self.resident[t]
                );
            }
        }
        Ok(())
    }
}

impl CodecState for RedirectionTable {
    fn encode_state(&self, e: &mut Encoder) {
        // Geometry (page_bytes, frames, shard striping) is config-derived
        // and validated on decode rather than serialized; the mutable
        // state is each shard's entry array, free/retired pools, and
        // counters, plus the global O(1) counters.
        e.put_u64(self.host_pages);
        e.put_len(self.shards.len());
        e.put_len(self.frames.len());
        for sh in &self.shards {
            e.put_u32_slice(&sh.entries);
            for f in &sh.free {
                e.put_u32_slice(f);
            }
            e.put_u64(sh.mapped);
            e.put_u64_slice(&sh.resident);
            for r in &sh.retired {
                e.put_u32_slice(r);
            }
        }
        e.put_u64(self.mapped);
        e.put_u64_slice(&self.resident);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let host_pages = d.u64()?;
        check_len(
            "redirection host pages",
            self.host_pages as usize,
            host_pages as usize,
        )?;
        let nshards = d.len()?;
        check_len("redirection shards", self.shards.len(), nshards)?;
        let tiers = d.len()?;
        check_len("redirection tiers", self.frames.len(), tiers)?;
        let mut shards = Vec::with_capacity(nshards);
        for snum in 0..nshards {
            let entries = d.u32_vec()?;
            check_len(
                "redirection shard entries",
                self.shards[snum].entries.len(),
                entries.len(),
            )?;
            let mut free = Vec::with_capacity(tiers);
            for t in 0..tiers {
                let f = d.u32_vec()?;
                if f.len() > self.frames[t] as usize {
                    bail!(
                        "checkpoint geometry mismatch: tier {t} free list {} exceeds {} frames",
                        f.len(),
                        self.frames[t]
                    );
                }
                free.push(f);
            }
            let mapped = d.u64()?;
            let resident = d.u64_vec()?;
            check_len(
                "redirection shard residency",
                self.shards[snum].resident.len(),
                resident.len(),
            )?;
            let mut retired = Vec::with_capacity(tiers);
            for t in 0..tiers {
                let r = d.u32_vec()?;
                if r.len() > self.frames[t] as usize {
                    bail!(
                        "checkpoint geometry mismatch: tier {t} retired pool {} exceeds {} frames",
                        r.len(),
                        self.frames[t]
                    );
                }
                retired.push(r);
            }
            shards.push(Shard {
                entries,
                free,
                retired,
                mapped,
                resident,
            });
        }
        let mapped = d.u64()?;
        let resident = d.u64_vec()?;
        check_len("redirection residency", self.resident.len(), resident.len())?;
        self.shards = shards;
        self.mapped = mapped;
        self.resident = resident;
        // A decoded table must satisfy the same invariants a live one
        // does — catches corrupt/mismatched snapshots up front.
        self.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> RedirectionTable {
        // 8 host pages, 4 DRAM + 8 NVM frames, 4K pages.
        RedirectionTable::two_tier(8, 4, 8, 4096)
    }

    #[test]
    fn starts_unmapped() {
        let t = table();
        assert_eq!(t.lookup(0), None);
        assert_eq!(t.translate(100), None);
    }

    #[test]
    fn identity_map_splits_by_capacity() {
        let mut t = table();
        t.identity_map();
        assert_eq!(
            t.lookup(0),
            Some(Mapping {
                device: TierId::Dram,
                frame: 0
            })
        );
        assert_eq!(
            t.lookup(4),
            Some(Mapping {
                device: TierId::Nvm,
                frame: 0
            })
        );
        assert_eq!(t.free_nvm_frames(), 4); // 8 - 4 used
        t.check_invariants().unwrap();
    }

    #[test]
    fn translate_preserves_offset() {
        let mut t = table();
        t.identity_map();
        let (dev, da) = t.translate(5 * 4096 + 123).unwrap();
        assert_eq!(dev, TierId::Nvm);
        assert_eq!(da, 4096 + 123); // nvm frame 1, offset 123
    }

    #[test]
    fn place_prefers_then_falls_back() {
        let mut t = table();
        for p in 0..4 {
            let m = t.place(p, TierId::Dram).unwrap();
            assert_eq!(m.device, TierId::Dram);
        }
        // DRAM exhausted → falls over to NVM.
        let m = t.place(4, TierId::Dram).unwrap();
        assert_eq!(m.device, TierId::Nvm);
        t.check_invariants().unwrap();
    }

    #[test]
    fn double_place_rejected() {
        let mut t = table();
        t.place(0, TierId::Dram).unwrap();
        assert!(t.place(0, TierId::Dram).is_err());
    }

    #[test]
    fn swap_moves_frames() {
        let mut t = table();
        t.identity_map();
        let before_a = t.lookup(0).unwrap();
        let before_b = t.lookup(7).unwrap();
        t.swap(0, 7).unwrap();
        assert_eq!(t.lookup(0), Some(before_b));
        assert_eq!(t.lookup(7), Some(before_a));
        t.check_invariants().unwrap();
    }

    #[test]
    fn swap_unmapped_fails() {
        let mut t = table();
        t.place(0, TierId::Dram).unwrap();
        assert!(t.swap(0, 1).is_err());
    }

    #[test]
    fn exhaustion_errors() {
        let mut t = RedirectionTable::two_tier(3, 1, 2, 4096);
        t.place(0, TierId::Dram).unwrap();
        t.place(1, TierId::Dram).unwrap();
        t.place(2, TierId::Dram).unwrap();
        let mut t2 = RedirectionTable::two_tier(2, 1, 1, 4096);
        t2.place(0, TierId::Nvm).unwrap();
        t2.place(1, TierId::Nvm).unwrap();
        // Everything mapped; placing again impossible (all pages mapped).
        assert_eq!(t2.free_dram_frames() + t2.free_nvm_frames(), 0);
    }

    #[test]
    fn dram_resident_count() {
        let mut t = table();
        t.identity_map();
        assert_eq!(t.dram_resident_pages(), 4);
        t.swap(0, 7).unwrap();
        assert_eq!(t.dram_resident_pages(), 4); // swap conserves
    }

    #[test]
    fn resident_counters_track_recount() {
        // Random place/swap churn: the O(1) counters must stay pinned to
        // the full-table recount the whole way.
        let mut t = RedirectionTable::two_tier(64, 16, 64, 4096);
        let mut rng = crate::util::rng::Xoshiro256::new(99);
        let mut placed: Vec<u64> = Vec::new();
        for page in 0..48u64 {
            let dev = if rng.chance(0.5) {
                TierId::Dram
            } else {
                TierId::Nvm
            };
            t.place(page, dev).unwrap();
            placed.push(page);
            assert_eq!(t.dram_resident_pages(), t.recount_dram_resident());
            assert_eq!(t.mapped_pages(), page + 1);
        }
        for _ in 0..200 {
            let a = placed[rng.below(placed.len() as u64) as usize];
            let b = placed[rng.below(placed.len() as u64) as usize];
            if a != b {
                t.swap(a, b).unwrap();
            }
            assert_eq!(t.dram_resident_pages(), t.recount_dram_resident());
        }
        t.check_invariants().unwrap();
    }

    #[test]
    fn identity_map_sets_counters() {
        let mut t = table();
        t.identity_map();
        assert_eq!(t.mapped_pages(), 8);
        assert_eq!(t.dram_resident_pages(), t.recount_dram_resident());
        t.check_invariants().unwrap();
    }

    #[test]
    fn three_tier_identity_map_fills_rank_order() {
        // 10 host pages over a 4+4+8 stack: 4 in rank 0, 4 in rank 1,
        // 2 in rank 2, 6 rank-2 frames left free.
        let mut t = RedirectionTable::new(10, &[4, 4, 8], 4096);
        t.identity_map();
        assert_eq!(t.lookup(3).unwrap().device, TierId(0));
        assert_eq!(t.lookup(4).unwrap().device, TierId(1));
        assert_eq!(t.lookup(8), Some(Mapping { device: TierId(2), frame: 0 }));
        assert_eq!(t.free_frames(TierId(2)), 6);
        assert_eq!(t.residency(), &[4, 4, 2]);
        t.check_invariants().unwrap();
    }

    #[test]
    fn three_tier_place_falls_down_then_up() {
        let mut t = RedirectionTable::new(6, &[1, 1, 4], 4096);
        // Rank-1 request: fills rank 1, then falls DOWN to rank 2 (not up
        // to rank 0) until the deep tier is full, then up to rank 0.
        assert_eq!(t.place(0, TierId(1)).unwrap().device, TierId(1));
        for p in 1..5u64 {
            assert_eq!(t.place(p, TierId(1)).unwrap().device, TierId(2), "page {p}");
        }
        assert_eq!(t.place(5, TierId(1)).unwrap().device, TierId(0));
        t.check_invariants().unwrap();
    }

    #[test]
    fn three_tier_swap_any_pair_conserves_residency() {
        let mut t = RedirectionTable::new(16, &[4, 4, 8], 4096);
        t.identity_map();
        let before: Vec<u64> = t.residency().to_vec();
        // Swap across every tier pair: (0,1), (1,2), (0,2).
        t.swap(0, 4).unwrap();
        t.swap(5, 9).unwrap();
        t.swap(1, 10).unwrap();
        assert_eq!(t.residency(), before.as_slice());
        assert_eq!(t.lookup(0).unwrap().device, TierId(1));
        assert_eq!(t.lookup(10).unwrap().device, TierId(0));
        t.check_invariants().unwrap();
        // Residency sums to mapped across all tiers.
        assert_eq!(t.residency().iter().sum::<u64>(), t.mapped_pages());
    }

    #[test]
    fn retire_prefers_same_tier_then_falls_down_the_stack() {
        let mut t = table(); // 8 pages, 4 DRAM + 8 NVM frames
        t.identity_map();
        let old = t.lookup(0).unwrap();
        assert_eq!(old.device, TierId::Dram);
        // No free DRAM frames (identity map filled all 4): the victim
        // falls to the NVM pool; the dead DRAM frame is retired.
        let m = t.retire_and_remap(0).unwrap().unwrap();
        assert_eq!(m.device, TierId::Nvm);
        assert_eq!(t.lookup(0), Some(m));
        assert_eq!(t.retired_frames(TierId::Dram), 1);
        assert_eq!(t.effective_frames(TierId::Dram), 3);
        assert_eq!(t.residency(), &[3, 5]);
        assert_eq!(t.mapped_pages(), 8, "page survives the retirement");
        t.check_invariants().unwrap();
    }

    #[test]
    fn retired_frames_never_reallocated() {
        let mut t = RedirectionTable::two_tier(6, 2, 4, 4096);
        t.place(0, TierId::Dram).unwrap();
        let dead = t.lookup(0).unwrap();
        let m = t.retire_and_remap(0).unwrap().unwrap();
        assert_ne!((m.device, m.frame), (dead.device, dead.frame));
        // Exhaust every remaining frame: the retired one must never come
        // back out of a free list.
        for p in 1..5u64 {
            let got = t.place(p, TierId::Dram).unwrap();
            assert_ne!((got.device, got.frame), (dead.device, dead.frame), "page {p}");
        }
        // 6 frames - 1 retired - 5 mapped = 0 free anywhere.
        assert_eq!(t.free_frames(TierId::Dram) + t.free_frames(TierId::Nvm), 0);
        assert!(t.place(5, TierId::Dram).is_err(), "capacity shrank by the retirement");
        assert!(t.retire_and_remap(5).is_err(), "unmapped page rejected");
        t.check_invariants().unwrap();
    }

    #[test]
    fn retire_with_full_stack_returns_none() {
        let mut t = RedirectionTable::two_tier(3, 1, 2, 4096);
        for p in 0..3 {
            t.place(p, TierId::Dram).unwrap();
        }
        let before = t.lookup(1).unwrap();
        assert_eq!(t.retire_and_remap(1).unwrap(), None);
        assert_eq!(t.lookup(1), Some(before), "page survives on its degraded frame");
        assert_eq!(t.retired_frames(TierId::Dram) + t.retired_frames(TierId::Nvm), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn codec_round_trip_restores_retired_pools() {
        let mut t = RedirectionTable::new(16, &[4, 4, 8], 4096);
        t.identity_map();
        t.retire_and_remap(0).unwrap().unwrap();
        t.retire_and_remap(5).unwrap().unwrap();
        let mut e = Encoder::new();
        t.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = RedirectionTable::new(16, &[4, 4, 8], 4096);
        let mut d = Decoder::new(&bytes);
        restored.decode_state(&mut d).unwrap();
        assert!(d.is_done());
        for tier in 0..3u8 {
            assert_eq!(
                restored.retired_frames(TierId(tier)),
                t.retired_frames(TierId(tier))
            );
            assert_eq!(
                restored.effective_frames(TierId(tier)),
                t.effective_frames(TierId(tier))
            );
        }
        for p in 0..16 {
            assert_eq!(restored.lookup(p), t.lookup(p), "page {p}");
        }
        restored.check_invariants().unwrap();
    }

    #[test]
    fn codec_round_trip_restores_mappings_and_counters() {
        let mut t = RedirectionTable::new(16, &[4, 4, 8], 4096);
        t.identity_map();
        t.swap(0, 4).unwrap();
        t.swap(5, 9).unwrap();

        let mut e = Encoder::new();
        t.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = RedirectionTable::new(16, &[4, 4, 8], 4096);
        let mut d = Decoder::new(&bytes);
        restored.decode_state(&mut d).unwrap();
        assert!(d.is_done());

        for p in 0..16 {
            assert_eq!(restored.lookup(p), t.lookup(p), "page {p}");
        }
        assert_eq!(restored.residency(), t.residency());
        assert_eq!(restored.mapped_pages(), t.mapped_pages());
        for tier in 0..3 {
            assert_eq!(
                restored.free_frames(TierId(tier)),
                t.free_frames(TierId(tier))
            );
        }
        restored.check_invariants().unwrap();
    }

    #[test]
    fn codec_rejects_wrong_geometry() {
        let mut t = table();
        t.identity_map();
        let mut e = Encoder::new();
        t.encode_state(&mut e);
        let bytes = e.into_bytes();
        // Different host-page count refuses the overlay.
        let mut wrong = RedirectionTable::two_tier(16, 4, 16, 4096);
        assert!(wrong.decode_state(&mut Decoder::new(&bytes)).is_err());
        // Different tier count refuses too.
        let mut wrong3 = RedirectionTable::new(8, &[4, 4, 8], 4096);
        assert!(wrong3.decode_state(&mut Decoder::new(&bytes)).is_err());
        // Different shard count refuses: the stripe layout is geometry.
        let mut wrong_shards = RedirectionTable::new_with_shards(8, &[4, 8], 4096, 2);
        assert!(wrong_shards.decode_state(&mut Decoder::new(&bytes)).is_err());
    }

    #[test]
    fn tier_names_and_ordering() {
        assert_ne!(TierId::Dram, TierId::Nvm);
        assert_eq!(TierId::Dram.name(), "DRAM");
        assert_eq!(TierId::Nvm.name(), "NVM");
        assert_eq!(TierId(2).name(), "TIER2");
        assert!(TierId::Dram < TierId::Nvm);
        assert_eq!(format!("{:?}", TierId::Dram), "Dram");
        assert_eq!(format!("{:?}", TierId(3)), "Tier3");
    }

    // ---- shard-specific pins -------------------------------------------

    /// Every (shard, local) pair is distinct and stays in bounds, so the
    /// striped layout is a bijection over the host space.
    #[test]
    fn stripe_layout_is_a_bijection() {
        for nshards in [1usize, 2, 4, 8] {
            let pages = 5 * STRIPE_LEN + 7; // partial tail stripe
            let t = RedirectionTable::new_with_shards(pages, &[512, 512], 4096, nshards);
            let mut seen = std::collections::HashSet::new();
            for p in 0..pages {
                let (s, l) = t.locate(p);
                assert!(s < nshards);
                assert!(l < t.shards[s].entries.len(), "page {p} shard {s}");
                assert!(seen.insert((s, l)), "page {p} collides");
            }
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_shards_rejected() {
        RedirectionTable::new_with_shards(8, &[4, 8], 4096, 3);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_page_panics() {
        table().lookup(8);
    }

    /// The monolithic table (1 shard) and the sharded default allocate
    /// identical frames through a place/swap/retire churn — the
    /// bit-identity the pop-only/min-of-heads argument guarantees.
    #[test]
    fn sharded_allocation_matches_monolithic() {
        let mk = |n| RedirectionTable::new_with_shards(300, &[96, 128, 128], 4096, n);
        let mut mono = mk(1);
        let mut shrd = mk(8);
        let mut rng = crate::util::rng::Xoshiro256::new(7);
        let mut placed: Vec<u64> = Vec::new();
        for page in 0..260u64 {
            let tier = TierId(rng.below(3) as u8);
            let a = mono.place(page, tier).unwrap();
            let b = shrd.place(page, tier).unwrap();
            assert_eq!(a, b, "page {page}");
            placed.push(page);
        }
        for round in 0..400 {
            let a = placed[rng.below(placed.len() as u64) as usize];
            let b = placed[rng.below(placed.len() as u64) as usize];
            if a != b {
                mono.swap(a, b).unwrap();
                shrd.swap(a, b).unwrap();
            }
            if round % 13 == 0 {
                let victim = placed[rng.below(placed.len() as u64) as usize];
                assert_eq!(
                    mono.retire_and_remap(victim).unwrap(),
                    shrd.retire_and_remap(victim).unwrap(),
                    "round {round}"
                );
            }
        }
        for p in 0..300 {
            assert_eq!(mono.lookup(p), shrd.lookup(p), "page {p}");
        }
        assert_eq!(mono.residency(), shrd.residency());
        assert_eq!(mono.mapped_pages(), shrd.mapped_pages());
        for t in 0..3u8 {
            assert_eq!(
                mono.retired_frames(TierId(t)),
                shrd.retired_frames(TierId(t))
            );
            assert_eq!(mono.free_frames(TierId(t)), shrd.free_frames(TierId(t)));
        }
        mono.check_invariants().unwrap();
        shrd.check_invariants().unwrap();
    }

    /// identity_map on the sharded table matches the monolithic fill and
    /// leaves per-shard counters summing to the global view.
    #[test]
    fn sharded_identity_map_matches_monolithic() {
        let mut mono = RedirectionTable::new_with_shards(200, &[64, 96, 128], 4096, 1);
        let mut shrd = RedirectionTable::new_with_shards(200, &[64, 96, 128], 4096, 4);
        mono.identity_map();
        shrd.identity_map();
        for p in 0..200 {
            assert_eq!(mono.lookup(p), shrd.lookup(p), "page {p}");
        }
        assert_eq!(mono.residency(), shrd.residency());
        let i_mono: Vec<_> = mono.iter_mapped().collect();
        let i_shrd: Vec<_> = shrd.iter_mapped().collect();
        assert_eq!(i_mono, i_shrd, "iter_mapped order is shard-independent");
        shrd.check_invariants().unwrap();
    }

    /// Codec round-trip preserves shard structure (not just the merged
    /// view): a restored table passes the per-shard invariants.
    #[test]
    fn codec_round_trip_preserves_shards() {
        let mut t = RedirectionTable::new_with_shards(200, &[64, 96, 128], 4096, 4);
        t.identity_map();
        t.swap(0, 70).unwrap();
        t.retire_and_remap(5).unwrap().unwrap();
        let mut e = Encoder::new();
        t.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = RedirectionTable::new_with_shards(200, &[64, 96, 128], 4096, 4);
        restored.decode_state(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(restored.shard_count(), 4);
        for (a, b) in t.shards.iter().zip(&restored.shards) {
            assert_eq!(a.entries, b.entries);
            assert_eq!(a.free, b.free);
            assert_eq!(a.retired, b.retired);
            assert_eq!(a.mapped, b.mapped);
            assert_eq!(a.resident, b.resident);
        }
        restored.check_invariants().unwrap();
    }
}
