//! Middleware substrate (paper Fig 4, §III-G).
//!
//! The paper forces applications onto the PCIe-attached hybrid memory via
//! (1) a kernel driver managing physical frames of `/dev/mem` with the
//! genpool subsystem, and (2) a modified jemalloc whose `pages.c` mmaps
//! the device file. This module reproduces both layers:
//!
//! - [`genpool`] — the driver's physical frame pool over the BAR window.
//! - [`arena`] — a jemalloc-like size-class arena allocator on top.
//! - [`hints`] — the paper's extended-malloc placement hints, which flow
//!   through the allocator down to the HMMU placement policy.

pub mod arena;
pub mod genpool;
pub mod hints;

pub use arena::ArenaAllocator;
pub use genpool::GenPool;
pub use hints::{HintStore, Placement};
