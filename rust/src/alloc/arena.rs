//! jemalloc-like arena allocator over the genpool frame allocator.
//!
//! Mirrors the paper's modified jemalloc: small allocations are served
//! from size-class runs carved out of page-granular chunks obtained from
//! the device pool (`pages.c` → mmap of `/dev/mem_driver`); large
//! allocations go straight to the pool. Placement hints ride along and are
//! recorded in the [`HintStore`] for the HMMU.

use super::genpool::GenPool;
use super::hints::{HintStore, Placement};
use crate::util::error::Result;

/// jemalloc-style small size classes (bytes).
const SIZE_CLASSES: [u64; 12] = [16, 32, 48, 64, 96, 128, 192, 256, 384, 512, 1024, 2048];

/// Allocation granularity fetched from the pool per run.
const RUN_BYTES: u64 = 16 * 4096;

#[derive(Clone, Debug)]
struct Run {
    base: u64,
    class_bytes: u64,
    /// Free-slot bitmap (bit set = free).
    free_slots: Vec<u64>,
    free_count: u32,
}

impl Run {
    fn new(base: u64, class_bytes: u64) -> Self {
        let slots = (RUN_BYTES / class_bytes) as u32;
        let words = slots.div_ceil(64) as usize;
        let mut free_slots = vec![u64::MAX; words];
        // Clear bits beyond `slots`.
        let extra = (words as u32 * 64) - slots;
        if extra > 0 {
            let last = free_slots.last_mut().unwrap();
            *last >>= extra;
        }
        Run {
            base,
            class_bytes,
            free_slots,
            free_count: slots,
        }
    }

    fn alloc(&mut self) -> Option<u64> {
        if self.free_count == 0 {
            return None;
        }
        for (w, word) in self.free_slots.iter_mut().enumerate() {
            if *word != 0 {
                let bit = word.trailing_zeros();
                *word &= !(1u64 << bit);
                self.free_count -= 1;
                return Some(self.base + (w as u64 * 64 + bit as u64) * self.class_bytes);
            }
        }
        None
    }

    fn contains(&self, addr: u64) -> bool {
        addr >= self.base && addr < self.base + RUN_BYTES
    }

    fn free(&mut self, addr: u64) -> bool {
        debug_assert!(self.contains(addr));
        let slot = (addr - self.base) / self.class_bytes;
        let (w, bit) = ((slot / 64) as usize, slot % 64);
        if self.free_slots[w] & (1 << bit) != 0 {
            return false; // double free
        }
        self.free_slots[w] |= 1 << bit;
        self.free_count += 1;
        true
    }
}

/// Arena allocator with hint plumbing.
pub struct ArenaAllocator {
    pool: GenPool,
    runs: Vec<Run>,
    hints: HintStore,
    /// (addr, bytes) of large allocations for free().
    large: Vec<(u64, u64)>,
    pub small_allocs: u64,
    pub large_allocs: u64,
}

impl ArenaAllocator {
    pub fn new(pool: GenPool) -> Self {
        ArenaAllocator {
            pool,
            runs: Vec::new(),
            hints: HintStore::new(),
            large: Vec::new(),
            small_allocs: 0,
            large_allocs: 0,
        }
    }

    fn class_for(bytes: u64) -> Option<u64> {
        SIZE_CLASSES.iter().copied().find(|&c| c >= bytes)
    }

    /// `malloc(bytes)` with a placement hint (the paper's extended API).
    pub fn malloc_hint(&mut self, bytes: u64, hint: Placement) -> Result<u64> {
        let addr = if let Some(class) = Self::class_for(bytes) {
            self.small_allocs += 1;
            // Existing run with space?
            if let Some(run) = self
                .runs
                .iter_mut()
                .find(|r| r.class_bytes == class && r.free_count > 0)
            {
                run.alloc().unwrap()
            } else {
                let base = self.pool.alloc(RUN_BYTES)?;
                let mut run = Run::new(base, class);
                let a = run.alloc().unwrap();
                self.runs.push(run);
                a
            }
        } else {
            self.large_allocs += 1;
            let a = self.pool.alloc(bytes)?;
            self.large.push((a, bytes));
            a
        };
        if hint != Placement::Any {
            self.hints.insert(addr, bytes.max(16), hint);
        }
        Ok(addr)
    }

    /// Plain `malloc`.
    pub fn malloc(&mut self, bytes: u64) -> Result<u64> {
        self.malloc_hint(bytes, Placement::Any)
    }

    /// `free(addr)`.
    pub fn free(&mut self, addr: u64) -> Result<()> {
        if let Some(run) = self.runs.iter_mut().find(|r| r.contains(addr)) {
            if !run.free(addr) {
                crate::bail!("arena: double free at {addr:#x}");
            }
            self.hints.remove(addr, run.class_bytes);
            return Ok(());
        }
        if let Some(pos) = self.large.iter().position(|&(a, _)| a == addr) {
            let (a, b) = self.large.swap_remove(pos);
            self.hints.remove(a, b);
            return self.pool.free(a, b);
        }
        crate::bail!("arena: free of unknown address {addr:#x}")
    }

    pub fn hints(&self) -> &HintStore {
        &self.hints
    }

    pub fn pool(&self) -> &GenPool {
        &self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena() -> ArenaAllocator {
        ArenaAllocator::new(GenPool::new(0x1000_0000, 4 << 20, 4096))
    }

    #[test]
    fn small_allocations_share_a_run() {
        let mut a = arena();
        let p1 = a.malloc(40).unwrap();
        let p2 = a.malloc(40).unwrap();
        // Same 48-byte class, same run, adjacent slots.
        assert_eq!(p2 - p1, 48);
        assert_eq!(a.pool().alloc_count, 1); // one run fetched
    }

    #[test]
    fn distinct_classes_distinct_runs() {
        let mut a = arena();
        let p1 = a.malloc(40).unwrap();
        let p2 = a.malloc(400).unwrap();
        assert!(p2 >= p1 + RUN_BYTES || p1 >= p2 + RUN_BYTES);
    }

    #[test]
    fn large_goes_to_pool() {
        let mut a = arena();
        a.malloc(1 << 20).unwrap();
        assert_eq!(a.large_allocs, 1);
        assert_eq!(a.small_allocs, 0);
    }

    #[test]
    fn free_and_reuse_slot() {
        let mut a = arena();
        let p1 = a.malloc(100).unwrap();
        a.free(p1).unwrap();
        let p2 = a.malloc(100).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn double_free_detected() {
        let mut a = arena();
        let p = a.malloc(64).unwrap();
        a.free(p).unwrap();
        assert!(a.free(p).is_err());
        let l = a.malloc(1 << 20).unwrap();
        a.free(l).unwrap();
        assert!(a.free(l).is_err());
    }

    #[test]
    fn hints_recorded_and_cleared() {
        let mut a = arena();
        let p = a.malloc_hint(128, Placement::PinDram).unwrap();
        assert_eq!(a.hints().lookup(p), Placement::PinDram);
        a.free(p).unwrap();
        assert_eq!(a.hints().lookup(p), Placement::Any);
    }

    #[test]
    fn run_exhaustion_fetches_new_run() {
        let mut a = arena();
        let slots = RUN_BYTES / 16;
        for _ in 0..=slots {
            a.malloc(16).unwrap();
        }
        assert_eq!(a.pool().alloc_count, 2);
    }

    #[test]
    fn pool_exhaustion_propagates() {
        let mut a = ArenaAllocator::new(GenPool::new(0, 64 << 10, 4096));
        assert!(a.malloc(128 << 10).is_err());
    }
}
