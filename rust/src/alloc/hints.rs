//! Placement hints (§III-G: "we extended the malloc API, to accept users'
//! hints of memory device preference regarding data placement, and
//! populate these information through the stack to the hardware hybrid
//! memory controller").
//!
//! Hints are recorded per allocated range; the HMMU's hint-aware policy
//! queries them by page.

use crate::util::codec::{CodecState, Decoder, Encoder};
use crate::util::error::Result;

/// Device preference attached to an allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// No preference (policy decides).
    Any,
    /// Latency-sensitive: prefer DRAM.
    PreferDram,
    /// Cold/bulk data: prefer NVM.
    PreferNvm,
    /// Pin to DRAM (never migrate out).
    PinDram,
}

/// Range → hint store, queried by page address.
#[derive(Clone, Debug, Default)]
pub struct HintStore {
    /// Sorted, non-overlapping (start, end, hint) ranges.
    ranges: Vec<(u64, u64, Placement)>,
}

impl HintStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a hint for `[start, start+len)`. Later inserts shadow
    /// earlier ones (allocator reuse of freed ranges).
    pub fn insert(&mut self, start: u64, len: u64, hint: Placement) {
        if len == 0 {
            return;
        }
        let end = start + len;
        // Remove/trim any overlapped older ranges.
        let mut next: Vec<(u64, u64, Placement)> = Vec::with_capacity(self.ranges.len() + 2);
        for &(s, e, h) in &self.ranges {
            if e <= start || s >= end {
                next.push((s, e, h));
            } else {
                if s < start {
                    next.push((s, start, h));
                }
                if e > end {
                    next.push((end, e, h));
                }
            }
        }
        next.push((start, end, hint));
        next.sort_by_key(|r| r.0);
        self.ranges = next;
    }

    /// Remove hints covering `[start, start+len)` (on free).
    pub fn remove(&mut self, start: u64, len: u64) {
        self.insert(start, len, Placement::Any);
        self.ranges.retain(|&(_, _, h)| h != Placement::Any);
    }

    /// Query the hint governing `addr`.
    pub fn lookup(&self, addr: u64) -> Placement {
        match self
            .ranges
            .binary_search_by(|&(s, e, _)| {
                if addr < s {
                    std::cmp::Ordering::Greater
                } else if addr >= e {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Equal
                }
            }) {
            Ok(i) => self.ranges[i].2,
            Err(_) => Placement::Any,
        }
    }

    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }
}

impl Placement {
    fn tag(self) -> u8 {
        match self {
            Placement::Any => 0,
            Placement::PreferDram => 1,
            Placement::PreferNvm => 2,
            Placement::PinDram => 3,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => Placement::Any,
            1 => Placement::PreferDram,
            2 => Placement::PreferNvm,
            3 => Placement::PinDram,
            _ => crate::bail!("checkpoint corrupt: placement tag {t}"),
        })
    }
}

impl CodecState for HintStore {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_len(self.ranges.len());
        for &(s, end, h) in &self.ranges {
            e.put_u64(s);
            e.put_u64(end);
            e.put_u8(h.tag());
        }
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        let n = d.len()?;
        let mut ranges = Vec::with_capacity(n);
        for _ in 0..n {
            let s = d.u64()?;
            let end = d.u64()?;
            let h = Placement::from_tag(d.u8()?)?;
            ranges.push((s, end, h));
        }
        self.ranges = ranges;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_inside_and_outside() {
        let mut h = HintStore::new();
        h.insert(0x1000, 0x1000, Placement::PreferDram);
        assert_eq!(h.lookup(0x1000), Placement::PreferDram);
        assert_eq!(h.lookup(0x1FFF), Placement::PreferDram);
        assert_eq!(h.lookup(0x2000), Placement::Any);
        assert_eq!(h.lookup(0xFFF), Placement::Any);
    }

    #[test]
    fn later_insert_shadows() {
        let mut h = HintStore::new();
        h.insert(0, 0x3000, Placement::PreferNvm);
        h.insert(0x1000, 0x1000, Placement::PinDram);
        assert_eq!(h.lookup(0x500), Placement::PreferNvm);
        assert_eq!(h.lookup(0x1500), Placement::PinDram);
        assert_eq!(h.lookup(0x2500), Placement::PreferNvm);
        assert_eq!(h.len(), 3);
    }

    #[test]
    fn remove_clears() {
        let mut h = HintStore::new();
        h.insert(0, 0x2000, Placement::PreferDram);
        h.remove(0, 0x1000);
        assert_eq!(h.lookup(0x500), Placement::Any);
        assert_eq!(h.lookup(0x1800), Placement::PreferDram);
    }

    #[test]
    fn codec_round_trip_preserves_lookups() {
        let mut h = HintStore::new();
        h.insert(0, 0x3000, Placement::PreferNvm);
        h.insert(0x1000, 0x1000, Placement::PinDram);
        let mut e = Encoder::new();
        h.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = HintStore::new();
        let mut d = Decoder::new(&bytes);
        restored.decode_state(&mut d).unwrap();
        assert!(d.is_done());
        for addr in [0x500u64, 0x1500, 0x2500, 0x9000] {
            assert_eq!(restored.lookup(addr), h.lookup(addr), "addr {addr:#x}");
        }
    }

    #[test]
    fn zero_len_noop() {
        let mut h = HintStore::new();
        h.insert(0x1000, 0, Placement::PinDram);
        assert!(h.is_empty());
    }
}
