//! Physical frame pool — the `mem_driver.ko` + kernel genpool analog.
//!
//! Manages page-granular frames of the hybrid-memory BAR window. First-fit
//! over a free list kept sorted and coalesced, like the kernel's genpool
//! in its default configuration.

use crate::bail;
use crate::util::error::Result;

/// A page-granular physical frame allocator over `[base, base+size)`.
#[derive(Clone, Debug)]
pub struct GenPool {
    base: u64,
    size: u64,
    page: u64,
    /// Sorted, coalesced free ranges (offset, len) in bytes.
    free: Vec<(u64, u64)>,
    pub allocated_bytes: u64,
    pub alloc_count: u64,
    pub fail_count: u64,
}

impl GenPool {
    /// `base` is the BAR window base (the paper maps
    /// [0x1240000000, 0x1288000000)); `size` its length.
    pub fn new(base: u64, size: u64, page: u64) -> Self {
        assert!(page.is_power_of_two());
        assert_eq!(size % page, 0);
        GenPool {
            base,
            size,
            page,
            free: vec![(0, size)],
            allocated_bytes: 0,
            alloc_count: 0,
            fail_count: 0,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.size
    }

    pub fn free_bytes(&self) -> u64 {
        self.free.iter().map(|(_, l)| l).sum()
    }

    /// Allocate `bytes` (rounded up to pages); returns the physical address.
    pub fn alloc(&mut self, bytes: u64) -> Result<u64> {
        let len = bytes.div_ceil(self.page) * self.page;
        // First fit.
        for i in 0..self.free.len() {
            let (off, flen) = self.free[i];
            if flen >= len {
                if flen == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (off + len, flen - len);
                }
                self.allocated_bytes += len;
                self.alloc_count += 1;
                return Ok(self.base + off);
            }
        }
        self.fail_count += 1;
        bail!("genpool: out of memory allocating {bytes} bytes")
    }

    /// Free a previously allocated range.
    pub fn free(&mut self, addr: u64, bytes: u64) -> Result<()> {
        let len = bytes.div_ceil(self.page) * self.page;
        if addr < self.base || addr + len > self.base + self.size {
            bail!("genpool: free outside pool");
        }
        let off = addr - self.base;
        if off % self.page != 0 {
            bail!("genpool: unaligned free");
        }
        // Insert sorted; check overlap with neighbours; coalesce.
        let pos = self.free.partition_point(|&(o, _)| o < off);
        if pos > 0 {
            let (po, pl) = self.free[pos - 1];
            if po + pl > off {
                bail!("genpool: double free / overlap");
            }
        }
        if pos < self.free.len() && off + len > self.free[pos].0 {
            bail!("genpool: double free / overlap");
        }
        self.free.insert(pos, (off, len));
        self.allocated_bytes = self.allocated_bytes.saturating_sub(len);
        // Coalesce around pos.
        self.coalesce(pos);
        Ok(())
    }

    fn coalesce(&mut self, pos: usize) {
        // Merge with next.
        if pos + 1 < self.free.len() {
            let (o, l) = self.free[pos];
            if o + l == self.free[pos + 1].0 {
                self.free[pos].1 += self.free[pos + 1].1;
                self.free.remove(pos + 1);
            }
        }
        // Merge with previous.
        if pos > 0 {
            let (po, pl) = self.free[pos - 1];
            if po + pl == self.free[pos].0 {
                self.free[pos - 1].1 += self.free[pos].1;
                self.free.remove(pos);
            }
        }
    }

    /// Number of free fragments (fragmentation metric).
    pub fn fragments(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BAR: u64 = 0x12_4000_0000; // paper's BAR base

    fn pool() -> GenPool {
        GenPool::new(BAR, 1 << 20, 4096)
    }

    #[test]
    fn alloc_returns_bar_addresses() {
        let mut p = pool();
        let a = p.alloc(100).unwrap();
        assert_eq!(a, BAR);
        let b = p.alloc(4096).unwrap();
        assert_eq!(b, BAR + 4096);
    }

    #[test]
    fn rounds_to_pages() {
        let mut p = pool();
        p.alloc(1).unwrap();
        assert_eq!(p.allocated_bytes, 4096);
        assert_eq!(p.free_bytes(), (1 << 20) - 4096);
    }

    #[test]
    fn exhaustion_fails_cleanly() {
        let mut p = pool();
        p.alloc(1 << 20).unwrap();
        assert!(p.alloc(1).is_err());
        assert_eq!(p.fail_count, 1);
    }

    #[test]
    fn free_and_coalesce() {
        let mut p = pool();
        let a = p.alloc(4096).unwrap();
        let b = p.alloc(4096).unwrap();
        let c = p.alloc(4096).unwrap();
        p.free(b, 4096).unwrap();
        assert_eq!(p.fragments(), 2);
        p.free(a, 4096).unwrap();
        assert_eq!(p.fragments(), 2); // a+b coalesced, tail separate
        p.free(c, 4096).unwrap();
        assert_eq!(p.fragments(), 1); // fully coalesced
        assert_eq!(p.free_bytes(), 1 << 20);
    }

    #[test]
    fn double_free_detected() {
        let mut p = pool();
        let a = p.alloc(4096).unwrap();
        p.free(a, 4096).unwrap();
        assert!(p.free(a, 4096).is_err());
    }

    #[test]
    fn out_of_range_free_rejected() {
        let mut p = pool();
        assert!(p.free(0, 4096).is_err());
        assert!(p.free(BAR + (2 << 20), 4096).is_err());
    }

    #[test]
    fn reuse_after_free() {
        let mut p = pool();
        let a = p.alloc(64 * 4096).unwrap();
        p.free(a, 64 * 4096).unwrap();
        let b = p.alloc(64 * 4096).unwrap();
        assert_eq!(a, b); // first-fit reuses
    }
}
