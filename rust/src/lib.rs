//! # hymem — Hybrid Memory Emulation Platform
//!
//! A full-stack reproduction of *"FPGA-based Hybrid Memory Emulation
//! System"* (Wen, Qin, Gratz, Reddy — FPL 2021) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper builds an FPGA platform in which a **Hybrid Memory Management
//! Unit (HMMU)** sits between a real ARM host and two DRAM DIMMs (one
//! emulating NVM via injected stall cycles), attached over PCIe. This crate
//! rebuilds every hardware component as a calibrated model so the same
//! experiments run on a plain CPU:
//!
//! - [`sim`] — discrete-event simulation engine with multiple clock domains.
//! - [`cpu`] — ARM-A57-like core + L1/L2 cache hierarchy (the *host*).
//! - [`pcie`] — Gen3 TLP-level link model (the *interconnect*).
//! - [`hmmu`] — the paper's contribution: request pipeline, tag-matching
//!   consistency, address redirection, DMA page-swap engine, pluggable
//!   placement/migration policies, performance counters.
//! - [`mem`] — DDR4 timing model + stall-scaled NVM emulation (§III-F),
//!   composed into an N-tier device stack (`TierSpec` presets for DDR4,
//!   PCM, memristor and 3D XPoint classes; the paper's pair is the
//!   two-tier default).
//! - [`workload`] — synthetic SPEC CPU 2017 workload generators (Table III).
//! - [`alloc`] — driver/allocator middleware (Fig 4): genpool frame pool +
//!   jemalloc-like arenas + placement hints.
//! - [`baselines`] — gem5-like and ChampSim-like software simulators for
//!   the Fig 7 comparison.
//! - [`platform`] — composes everything into the emulation platform and the
//!   native-execution reference.
//! - [`sweep`] — deterministic parallel scenario-sweep engine: fans
//!   workload × policy × config grids across OS threads with bit-identical
//!   results and machine-readable `BENCH_sweep.json` reports.
//! - [`runtime`] — loads the AOT-compiled XLA policy step (L2/L1 artifacts)
//!   via PJRT and exposes it to the HMMU, with a bit-compatible native
//!   fallback.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hymem::config::SystemConfig;
//! use hymem::platform::Platform;
//! use hymem::workload::spec;
//!
//! let cfg = SystemConfig::default_scaled(16); // Table II at 1/16 scale
//! let wl = spec::by_name("505.mcf").unwrap();
//! let report = Platform::new(cfg).run(&wl).unwrap();
//! println!("{}", report.summary());
//! ```

pub mod alloc;
/// Source-level invariant checker behind the `hymem-audit` binary:
/// codec coverage, counter surfaces, determinism hygiene, bench-gate
/// pairing. Dependency-free lexer/parser, like everything else here.
pub mod audit;
pub mod baselines;
pub mod config;
pub mod cpu;
pub mod hmmu;
pub mod mem;
pub mod pcie;
pub mod platform;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod util;
pub mod workload;
/// Stand-in for the unvendored `xla` crate so the `xla` feature builds
/// (and its code paths stay compiled/tested) in the offline image; see
/// the module docs for the swap-out procedure once the crate is vendored.
#[cfg(feature = "xla")]
pub mod xla_stub;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
