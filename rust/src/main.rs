//! `hymem` — CLI for the hybrid memory emulation platform.
//!
//! Subcommands:
//! - `run`            run one workload on the platform (+ native ref)
//! - `sweep`          parallel scenario sweep over all Table III workloads
//!                    (× policies × NVM-stall points), deterministic
//!                    across thread counts, with `BENCH_sweep.json` output
//! - `fig7`           full Fig 7 comparison incl. gem5-like/champsim-like
//! - `fig8`           Fig 8 memory-request-bytes table
//! - `table1`         Table I technology sweep
//! - `calibrate`      §III-F stall-cycle calibration (uses the XLA
//!                    latency-model artifact when present)
//! - `config`         show the (scaled) Table II configuration
//! - `list-workloads` show the Table III workload set

use hymem::baselines::run_fig7_row;
use hymem::config::{MemTech, PolicyKind, SystemConfig, TechPreset};
use hymem::platform::{Platform, RunOpts};
use hymem::runtime;
use hymem::sweep::{default_threads, run_sweep, run_sweep_forked, ForkOpts, Scenario};
use hymem::util::cli::Args;
use hymem::util::stats::geomean;
use hymem::util::units::fmt_bytes;
use hymem::workload::{spec, WORKLOADS};

fn main() {
    let args = Args::parse();
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    let code = match cmd.as_str() {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "fig7" => cmd_fig7(&args),
        "fig8" => cmd_fig8(&args),
        "table1" => cmd_table1(&args),
        "calibrate" => cmd_calibrate(&args),
        "config" => cmd_config(&args),
        "list-workloads" => cmd_list(),
        "trace-dump" => cmd_trace_dump(&args),
        "multicore" => cmd_multicore(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn config_from(args: &Args) -> SystemConfig {
    let scale = args.get_u64("scale", 16);
    let mut cfg = SystemConfig::default_scaled(scale);
    if let Some(p) = args.get("policy").and_then(PolicyKind::parse) {
        cfg.policy = p;
    }
    if let Some(t) = args.get("tech").and_then(MemTech::parse) {
        cfg = cfg.with_tech(t);
    }
    // Tier-stack topology, e.g. `--tiers dram+pcm+xpoint` (for `sweep`,
    // `--tiers` may be a comma-separated *axis*, handled in cmd_sweep; a
    // single topology here configures every other command).
    if let Some(s) = args.get("tiers") {
        if s.contains(',') {
            if args.command.as_deref() != Some("sweep") {
                eprintln!(
                    "--tiers {s:?}: a comma-separated topology list is only a sweep axis; \
                     pass one topology (e.g. dram+pcm+xpoint) to this command"
                );
                std::process::exit(1);
            }
        } else {
            match hymem::config::parse_topology(s).map(|c| cfg.clone().with_tiers(&c)) {
                Some(Ok(c)) => cfg = c,
                _ => {
                    eprintln!("bad --tiers topology {s:?}; want e.g. dram+pcm+xpoint");
                    std::process::exit(1);
                }
            }
        }
    }
    // Row-buffer-aware stall charging (applies to the full stack, so it
    // must fold in after `--tech` / `--tiers` rebuilt the tier specs).
    if args.flag("row-aware") {
        cfg = cfg.with_row_buffer();
    }
    cfg.seed = args.get_u64("seed", cfg.seed);
    if let Some(e) = args.get("epoch") {
        cfg.hmmu.epoch_requests = e.parse().unwrap_or(cfg.hmmu.epoch_requests);
    }
    // Link-model axes: host-managed migration DMA (charges page moves at
    // the PCIe link) and MWr write-combining on the block crossing.
    if args.flag("host-managed-dma") {
        cfg.hmmu.host_managed_dma = true;
    }
    if args.flag("coalesce-writes") {
        cfg.pcie.coalesce_writes = true;
    }
    // Fault-injection axes (default off = bit-identical to a healthy
    // platform): wear-driven NVM bit errors, link-TLP corruption, and the
    // dedicated fault RNG stream seed. For `sweep`, `--rber` may be a
    // comma-separated axis, handled in cmd_sweep.
    cfg.fault.seed = args.get_u64("fault-seed", cfg.fault.seed);
    if let Some(s) = args.get("rber") {
        if !s.contains(',') {
            match s.parse::<f64>() {
                Ok(r) if r >= 0.0 => cfg.fault.rber_base = r,
                _ => {
                    eprintln!("bad --rber {s:?}; want a rate in [0,1], e.g. 1e-4");
                    std::process::exit(1);
                }
            }
        } else if args.command.as_deref() != Some("sweep") {
            eprintln!(
                "--rber {s:?}: a comma-separated rate list is only a sweep axis; \
                 pass one rate (e.g. 1e-4) to this command"
            );
            std::process::exit(1);
        }
    }
    if let Some(s) = args.get("link-ber") {
        if !s.contains(',') {
            match s.parse::<f64>() {
                Ok(r) if (0.0..=1.0).contains(&r) => cfg.fault.link_ber = r,
                _ => {
                    eprintln!("bad --link-ber {s:?}; want a rate in [0,1], e.g. 1e-6");
                    std::process::exit(1);
                }
            }
        } else if args.command.as_deref() != Some("sweep") {
            eprintln!(
                "--link-ber {s:?}: a comma-separated rate list is only a sweep axis; \
                 pass one rate (e.g. 1e-6) to this command"
            );
            std::process::exit(1);
        }
    }
    // DRAM bank count (row-buffer banking frontier). For `sweep`,
    // `--banks` may be a comma-separated axis, handled in cmd_sweep;
    // `0` keeps the stack default.
    if let Some(s) = args.get("banks") {
        if !s.contains(',') {
            match s.parse::<u32>() {
                Ok(0) => {}
                Ok(b) => cfg.dram.banks = b,
                _ => {
                    eprintln!("bad --banks {s:?}; want a bank count, e.g. 8 (0 = default)");
                    std::process::exit(1);
                }
            }
        } else if args.command.as_deref() != Some("sweep") {
            eprintln!(
                "--banks {s:?}: a comma-separated bank list is only a sweep axis; \
                 pass one count (e.g. 8) to this command"
            );
            std::process::exit(1);
        }
    }
    cfg
}

fn engine_for(args: &Args) -> (Option<Box<dyn hymem::hmmu::HotnessEngine>>, &'static str) {
    if args.flag("native-engine") {
        return (None, "native");
    }
    match runtime::XlaHotnessEngine::load_default() {
        Ok(e) => (Some(Box::new(e)), "xla-aot"),
        Err(_) => (None, "native (no artifacts)"),
    }
}

fn cmd_run(args: &Args) -> i32 {
    let name = args.get_or("workload", "505.mcf");
    let Some(wl) = spec::by_name(name) else {
        eprintln!("unknown workload {name:?}; try `hymem list-workloads`");
        return 1;
    };
    let cfg = config_from(args);
    let (engine, label) = engine_for(args);
    let opts = RunOpts {
        ops: args.get_u64("ops", 2_000_000),
        flush_at_end: args.flag("flush"),
    };
    let mut platform = Platform::new(cfg);
    if let Some(e) = engine {
        platform = platform.with_engine(e);
    }
    println!("# engine: {label}");
    match platform.run_opts(&wl, opts) {
        Ok(r) => {
            println!("{}", r.detail());
            0
        }
        Err(e) => {
            eprintln!("run failed: {e:#}");
            1
        }
    }
}

/// Parallel scenario sweep: Table III workloads × `--policies` ×
/// `--nvm-stalls` points, fanned across `--threads` OS threads with
/// bit-identical-to-serial results (per-scenario derived seeds).
fn cmd_sweep(args: &Args) -> i32 {
    let cfg = config_from(args);
    let ops = args.get_u64("ops", 1_000_000);
    let threads = args.get_usize("threads", default_threads());

    let policies: Vec<PolicyKind> = match args.get("policies") {
        None => vec![cfg.policy],
        Some(list) => {
            let mut out = Vec::new();
            for tok in list.split(',') {
                match PolicyKind::parse(tok.trim()) {
                    Some(p) => out.push(p),
                    None => {
                        eprintln!("unknown policy {tok:?}");
                        return 1;
                    }
                }
            }
            out
        }
    };

    let mut scenarios = Scenario::grid(&WORKLOADS, &policies, &cfg, ops);
    // Optional tier-topology axis:
    // `--tiers dram+pcm,dram+xpoint,dram+pcm+xpoint` — each entry
    // rebuilds the stack for every scenario and suffixes its name.
    if let Some(list) = args.get("tiers") {
        if list.contains(',') {
            let mut topologies = Vec::new();
            for tok in list.split(',') {
                match hymem::config::parse_topology(tok.trim()) {
                    Some(t) => topologies.push(t),
                    None => {
                        eprintln!("bad --tiers entry {tok:?}; want e.g. dram+pcm+xpoint");
                        return 1;
                    }
                }
            }
            scenarios = match Scenario::tier_grid(&scenarios, &topologies) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("--tiers: {e:#}");
                    return 1;
                }
            };
        }
        // A single topology was already folded into `cfg` by config_from.
    }
    // Optional NVM-stall axis: `--nvm-stalls 50:225,200:900` (read:write ns).
    if let Some(list) = args.get("nvm-stalls") {
        let mut points = Vec::new();
        for tok in list.split(',') {
            let Some((r, w)) = tok.trim().split_once(':') else {
                eprintln!("bad --nvm-stalls entry {tok:?}; want rd:wr in ns");
                return 1;
            };
            match (r.parse::<u64>(), w.parse::<u64>()) {
                (Ok(r), Ok(w)) => points.push((r, w)),
                _ => {
                    eprintln!("bad --nvm-stalls entry {tok:?}; want rd:wr in ns");
                    return 1;
                }
            }
        }
        scenarios = Scenario::stall_grid(&scenarios, &points);
    }
    // Optional core-count axis: `--cores 1,4` (rate-style multicore runs
    // share one HMMU; 1 keeps the single-core platform + native pass).
    if let Some(list) = args.get("cores") {
        let mut counts = Vec::new();
        for tok in list.split(',') {
            match tok.trim().parse::<usize>() {
                Ok(n) if (1..=cfg.cpu.cores as usize).contains(&n) => counts.push(n),
                _ => {
                    eprintln!(
                        "bad --cores entry {tok:?}; want 1..={} per point",
                        cfg.cpu.cores
                    );
                    return 1;
                }
            }
        }
        scenarios = Scenario::cores_grid(&scenarios, &counts);
    }
    // Optional fault-rate axis: `--rber 0,1e-5,1e-4` (wear-driven raw bit
    // error rate per point; 0 keeps the healthy baseline unsuffixed). A
    // single rate was already folded into `cfg` by config_from.
    if let Some(list) = args.get("rber") {
        if list.contains(',') {
            let mut points = Vec::new();
            for tok in list.split(',') {
                match tok.trim().parse::<f64>() {
                    Ok(r) if r >= 0.0 => points.push(r),
                    _ => {
                        eprintln!("bad --rber entry {tok:?}; want a rate in [0,1], e.g. 1e-4");
                        return 1;
                    }
                }
            }
            scenarios = Scenario::fault_grid(&scenarios, &points);
        }
    }
    // Optional link-fault axis, same shape: `--link-ber 0,1e-6` (PCIe
    // TLP corruption rate per point; 0 keeps the healthy baseline
    // unsuffixed). Composes with `--rber` into a full fault grid.
    if let Some(list) = args.get("link-ber") {
        if list.contains(',') {
            let mut points = Vec::new();
            for tok in list.split(',') {
                match tok.trim().parse::<f64>() {
                    Ok(r) if (0.0..=1.0).contains(&r) => points.push(r),
                    _ => {
                        eprintln!("bad --link-ber entry {tok:?}; want a rate in [0,1], e.g. 1e-6");
                        return 1;
                    }
                }
            }
            scenarios = Scenario::link_fault_grid(&scenarios, &points);
        }
    }
    // Optional DRAM bank-count axis: `--banks 4,8,16` (bank count per
    // point; 0 keeps the stack default unsuffixed). A single count was
    // already folded into `cfg` by config_from.
    if let Some(list) = args.get("banks") {
        if list.contains(',') {
            let mut points = Vec::new();
            for tok in list.split(',') {
                match tok.trim().parse::<u32>() {
                    Ok(b) => points.push(b),
                    _ => {
                        eprintln!(
                            "bad --banks entry {tok:?}; want a bank count, e.g. 8 (0 = default)"
                        );
                        return 1;
                    }
                }
            }
            scenarios = Scenario::banks_grid(&scenarios, &points);
        }
    }

    // Warm-state checkpoint/fork engine: `--warmup-ops N` pays the
    // warm-up once per (workload, base-config) group and forks it across
    // the policy × stall grid; `--checkpoint-dir D` caches serialized
    // warm states across invocations (CI rides on this); `--cold-replay`
    // re-warms every scenario through the same code path (baseline for
    // the fork speedup, bit-identical results).
    let fork = ForkOpts {
        warmup_ops: args.get_u64("warmup-ops", 0),
        checkpoint_dir: args.get("checkpoint-dir").map(std::path::PathBuf::from),
        cold_replay: args.flag("cold-replay"),
    };

    println!(
        "# sweep: {} scenarios ({} workloads x {} policies) scale=1/{} ops={ops} threads={threads}",
        scenarios.len(),
        WORKLOADS.len(),
        policies.len(),
        cfg.scale
    );
    if fork.warmup_ops > 0 {
        println!(
            "# warm-state fork: warmup-ops={} mode={}{}",
            fork.warmup_ops,
            if fork.cold_replay { "cold-replay" } else { "forked" },
            fork.checkpoint_dir
                .as_deref()
                .map(|d| format!(" checkpoint-dir={}", d.display()))
                .unwrap_or_default()
        );
    }
    // Sweep scenarios always use the native hotness engine (bit-compatible
    // with the XLA artifact); say so instead of silently ignoring the
    // engine selection that `run` honors.
    if runtime::XlaHotnessEngine::load_default().is_ok() {
        println!(
            "# note: sweep scenarios use the native engine (bit-identical to the XLA \
             artifact); use `hymem run` to exercise the artifact path"
        );
    } else if args.flag("native-engine") {
        println!("# note: --native-engine is implied for sweep (scenarios always run native)");
    }
    let result = if fork.warmup_ops > 0 {
        run_sweep_forked(&scenarios, threads, &fork)
    } else {
        run_sweep(&scenarios, threads)
    };
    match result {
        Ok(report) => {
            println!("{}", report.summary());
            println!("(paper geomean: 3.17x)");
            let path = args.get_or("json", "BENCH_sweep.json");
            match report.write_json(path) {
                Ok(()) => println!("wrote {path}"),
                Err(e) => {
                    eprintln!("writing {path}: {e:#}");
                    return 1;
                }
            }
            0
        }
        Err(e) => {
            eprintln!("sweep failed: {e:#}");
            1
        }
    }
}

fn cmd_fig7(args: &Args) -> i32 {
    let cfg = config_from(args);
    let ops = args.get_u64("ops", 500_000);
    let binstr = args.get_u64("baseline-instructions", 300_000);
    println!("# Fig 7: simulation time normalized against native execution");
    println!(
        "{:<16} {:>10} {:>14} {:>12}",
        "workload", "ours", "champsim-like", "gem5-like"
    );
    let (mut ours, mut champ, mut gem5) = (Vec::new(), Vec::new(), Vec::new());
    for wl in &WORKLOADS {
        match run_fig7_row(&cfg, wl, ops, binstr) {
            Ok(row) => {
                println!(
                    "{:<16} {:>9.2}x {:>13.0}x {:>11.0}x",
                    row.workload, row.ours, row.champsim, row.gem5
                );
                ours.push(row.ours);
                champ.push(row.champsim);
                gem5.push(row.gem5);
            }
            Err(e) => {
                eprintln!("{}: {e:#}", wl.name);
                return 1;
            }
        }
    }
    let (go, gc, gg) = (geomean(&ours), geomean(&champ), geomean(&gem5));
    println!(
        "{:<16} {:>9.2}x {:>13.0}x {:>11.0}x   (paper: 3.17x / 7241x / 29398x)",
        "geomean", go, gc, gg
    );
    println!(
        "speedup vs gem5-like: {:.0}x (paper 9280x), vs champsim-like: {:.0}x (paper 2286x)",
        gg / go,
        gc / go
    );
    0
}

fn cmd_fig8(args: &Args) -> i32 {
    let cfg = config_from(args);
    let ops = args.get_u64("ops", 1_000_000);
    println!(
        "# Fig 8: memory requests (bytes) seen by the HMMU, scaled x{}",
        cfg.scale
    );
    println!("# run lengths proportional to full-benchmark memory-op counts");
    println!("{:<16} {:>12} {:>12}", "workload", "read", "write");
    let mut rows: Vec<(String, u64, u64)> = Vec::new();
    for (wl, wl_ops) in hymem::workload::proportional_ops(ops) {
        let wl = &wl;
        let p = Platform::new(cfg.clone());
        match p.run_opts(
            wl,
            RunOpts {
                ops: wl_ops,
                // flush residual dirty lines so write-back volume is
                // counted, as a full-benchmark run would see (Fig 8 has
                // writes ~ reads).
                flush_at_end: true,
            },
        ) {
            Ok(r) => {
                let (rb, wb) = r.fig8_scaled();
                println!("{:<16} {:>12} {:>12}", wl.name, fmt_bytes(rb), fmt_bytes(wb));
                rows.push((wl.name.to_string(), rb, wb));
            }
            Err(e) => {
                eprintln!("{}: {e:#}", wl.name);
                return 1;
            }
        }
    }
    rows.sort_by_key(|r| std::cmp::Reverse(r.1 + r.2));
    println!(
        "\nmax: {} (paper: 505.mcf)  min: {} (paper: 538.imagick)",
        rows.first().map(|r| r.0.as_str()).unwrap_or("-"),
        rows.last().map(|r| r.0.as_str()).unwrap_or("-")
    );
    0
}

fn cmd_table1(args: &Args) -> i32 {
    let ops = args.get_u64("ops", 300_000);
    let wl_name = args.get_or("workload", "505.mcf");
    let Some(wl) = spec::by_name(wl_name) else {
        eprintln!("unknown workload {wl_name}");
        return 1;
    };
    println!("# Table I sweep: emulated NVM technology vs platform slowdown ({wl_name})");
    println!(
        "{:<12} {:>9} {:>9} {:>10} {:>10} {:>10}",
        "tech", "rd(ns)", "wr(ns)", "rd-stall", "wr-stall", "slowdown"
    );
    for tech in MemTech::ALL {
        let preset = TechPreset::of(tech);
        let cfg = config_from(args).with_tech(tech);
        let (rs, ws) = (cfg.nvm.read_stall_ns, cfg.nvm.write_stall_ns);
        let r = Platform::new(cfg)
            .run_opts(
                &wl,
                RunOpts {
                    ops,
                    flush_at_end: false,
                },
            )
            .unwrap();
        println!(
            "{:<12} {:>9} {:>9} {:>10} {:>10} {:>9.2}x",
            tech.name(),
            preset.read_ns,
            preset.write_ns,
            rs,
            ws,
            r.slowdown()
        );
    }
    0
}

fn cmd_calibrate(args: &Args) -> i32 {
    use hymem::mem::{DramDevice, MemDevice};
    let cfg = config_from(args);
    // §III-F step 1: measure the DRAM round trip.
    let mut dram = DramDevice::new(cfg.dram);
    let (rt, _) = dram.access(0, hymem::mem::AccessKind::Read, 64, 0);
    let fpga = hymem::sim::Clock::from_mhz(cfg.hmmu.fpga_freq_mhz);
    println!("# §III-F calibration");
    println!(
        "measured DRAM round trip: {rt} ns = {} FPGA cycles",
        fpga.ns_to_cycles(rt)
    );
    println!(
        "{:<12} {:>16} {:>16}",
        "tech", "rd-stall(cycles)", "wr-stall(cycles)"
    );
    for tech in MemTech::ALL {
        let p = TechPreset::of(tech);
        println!(
            "{:<12} {:>16} {:>16}",
            tech.name(),
            fpga.ns_to_cycles(p.read_stall_ns(rt)),
            fpga.ns_to_cycles(p.write_stall_ns(rt))
        );
    }
    // Optional: exercise the XLA latency-model artifact.
    match runtime::XlaLatencyModel::load(&runtime::default_artifact_dir(), 1024) {
        Ok(mut m) => {
            let nvm: Vec<f32> = (0..1024).map(|i| (i % 2) as f32).collect();
            let wr: Vec<f32> = (0..1024).map(|i| ((i / 2) % 2) as f32).collect();
            let qd = vec![0.0f32; 1024];
            match m.estimate(&nvm, &wr, &qd) {
                Ok(lat) => println!(
                    "xla latency model: dram-rd {:.0}ns nvm-rd {:.0}ns dram-wr {:.0}ns nvm-wr {:.0}ns",
                    lat[0], lat[1], lat[2], lat[3]
                ),
                Err(e) => eprintln!("latency model execution failed: {e:#}"),
            }
        }
        Err(_) => println!("(no latency-model artifact; run `make artifacts` for the XLA path)"),
    }
    0
}

fn cmd_trace_dump(args: &Args) -> i32 {
    use hymem::workload::{dump_trace, TraceGenerator};
    let name = args.get_or("workload", "505.mcf");
    let Some(wl) = spec::by_name(name) else {
        eprintln!("unknown workload {name}");
        return 1;
    };
    let cfg = config_from(args);
    let ops = args.get_u64("ops", 1_000_000);
    let out = args.get_or("out", "trace.hymt").to_string();
    let gen = TraceGenerator::new(wl, cfg.scale, cfg.seed).take_ops(ops);
    match dump_trace(std::path::Path::new(&out), gen) {
        Ok(n) => {
            println!("wrote {n} records to {out}");
            0
        }
        Err(e) => {
            eprintln!("trace dump failed: {e:#}");
            1
        }
    }
}

fn cmd_multicore(args: &Args) -> i32 {
    use hymem::platform::run_multicore;
    let cfg = config_from(args);
    let ops = args.get_u64("ops", 200_000);
    let names = args.get_or("workloads", "505.mcf,557.xz,538.imagick,525.x264");
    let mut wls = Vec::new();
    for n in names.split(',') {
        match spec::by_name(n.trim()) {
            Some(w) => wls.push(w),
            None => {
                eprintln!("unknown workload {n}");
                return 1;
            }
        }
    }
    match run_multicore(
        cfg,
        &wls,
        RunOpts {
            ops,
            flush_at_end: false,
        },
        None,
    ) {
        Ok(r) => {
            print!("{}", r.summary());
            0
        }
        Err(e) => {
            eprintln!("multicore run failed: {e:#}");
            1
        }
    }
}

fn cmd_config(args: &Args) -> i32 {
    let cfg = config_from(args);
    println!("# Table II (scaled 1/{})", cfg.scale);
    println!("{}", cfg.show());
    0
}

fn cmd_list() -> i32 {
    println!("# Table III workloads");
    println!(
        "{:<16} {:<42} {:>10} {:>6}",
        "name", "description", "footprint", "type"
    );
    for w in &WORKLOADS {
        println!(
            "{:<16} {:<42} {:>10} {:>6}",
            w.name,
            w.desc,
            fmt_bytes(w.footprint_bytes),
            if w.is_float { "fp" } else { "int" }
        );
    }
    0
}

fn print_help() {
    println!(
        "hymem {} — hybrid memory emulation platform (FPL'21 reproduction)

USAGE: hymem <command> [--options]

COMMANDS:
  run             --workload <name> [--policy static|first-touch|hotness|hints|wear-aware|rbl]
                  [--ops N] [--scale N] [--tech 3dxpoint|stt-ram|...] [--flush]
                  [--tiers dram+pcm+xpoint] [--row-aware] [--native-engine]
                  [--host-managed-dma] [--coalesce-writes]
                  [--rber R] wear-driven NVM bit-error rate (ECC + frame
                  retirement); [--link-ber R] PCIe TLP corruption/replay
                  rate; [--fault-seed N] fault RNG stream seed
  sweep           parallel scenario sweep: 12 workloads [x --policies a,b,..]
                  [x --nvm-stalls rd:wr,rd:wr,..] [x --cores 1,4,..]
                  [x --tiers dram+pcm,dram+xpoint,dram+pcm+xpoint]
                  [x --rber 0,1e-5,1e-4] [x --link-ber 0,1e-6]
                  [x --banks 4,8,16] (0 = stack default, unsuffixed) on
                  --threads N OS threads (default: all cores; bit-identical
                  to serial), writes --json <path> (default BENCH_sweep.json)
                  [--ops N] [--row-aware] row-buffer-outcome stall charging
                  (pair with --policies rbl for row-miss-guided migration)
                  [--host-managed-dma] [--coalesce-writes]
                  [--fault-seed N]
                  [--warmup-ops N] pay warm-up once per workload group and
                  fork it across the grid (single- and multicore rows,
                  members fanned across threads); [--checkpoint-dir D] cache warm
                  states on disk; [--cold-replay] re-warm per scenario
                  (fork-speedup baseline, bit-identical results)
  fig7            full comparison vs gem5-like and champsim-like
                  [--ops N] [--baseline-instructions N]
  fig8            memory request bytes per workload [--ops N]
  table1          NVM technology sweep [--workload <name>] [--ops N]
  calibrate       print §III-F stall-cycle calibration table
  config          show the scaled Table II configuration [--scale N]
  list-workloads  show the Table III workload set
  trace-dump      --workload <name> --ops N --out trace.hymt
  multicore       --workloads a,b,c --ops N   (shared-HMMU rate run)",
        hymem::version()
    );
}
