//! Analytical performance model — the paper's third evaluation category:
//! prior hybrid-memory works "use either software-based platform
//! simulation, with simulator runtime limiting the workloads that can be
//! examined, or they use **analytical modeling, which has a large impact
//! on accuracy**" (§II).
//!
//! This module is that strawman, built honestly: a closed-form
//! average-value model (no simulation) predicting platform execution
//! time from first-order workload parameters. The `accuracy` bench
//! compares its prediction against the platform's simulated time per
//! workload — reproducing the paper's claim that analytical models are
//! fast but inaccurate, because they miss queueing, burstiness, cache
//! dynamics, migration transients and consistency stalls.

use crate::config::SystemConfig;
use crate::pcie::PcieLink;
use crate::workload::Workload;

/// Closed-form prediction for one workload on the platform.
#[derive(Clone, Debug)]
pub struct AnalyticalPrediction {
    /// Predicted execution time for `instructions` instructions (ns).
    pub time_ns: u64,
    /// Predicted native time (ns).
    pub native_time_ns: u64,
    /// Predicted slowdown.
    pub slowdown: f64,
    /// Model-estimated L2 miss rate used.
    pub miss_rate: f64,
    /// Wall time of the prediction itself (ns) — the model's selling point.
    pub wall_ns: u64,
}

/// First-order analytical model.
///
/// Assumptions (all standard for such models, all sources of error):
/// - memory ops are `1/(1+gap)` of instructions;
/// - the L1+L2 hierarchy filters a *fixed* fraction of accesses derived
///   from footprint vs cache capacity (no temporal dynamics);
/// - every miss costs the *unloaded* memory latency (no queueing, no
///   banking, no bandwidth ceiling);
/// - a fixed MLP factor hides latency for non-dependent misses;
/// - migration, consistency reordering and DMA conflicts are free.
pub struct AnalyticalModel {
    cfg: SystemConfig,
}

impl AnalyticalModel {
    pub fn new(cfg: SystemConfig) -> Self {
        AnalyticalModel { cfg }
    }

    /// Estimate the post-cache miss rate from footprint vs cache size —
    /// the classic √-rule of thumb (Hartstein et al.): miss rate falls
    /// with the square root of cache over working set.
    fn est_miss_rate(&self, wl: &Workload) -> f64 {
        let footprint = (wl.footprint_bytes / self.cfg.scale) as f64;
        let cache = self.cfg.l2.size_bytes as f64;
        if footprint <= cache {
            return 0.002; // cache-resident: residual compulsory misses
        }
        // Locality classes shift the curve: chase/random-heavy workloads
        // approach the capacity bound, streaming reuses its window.
        let total = wl.mix.total();
        let hostile = (wl.mix.chase + wl.mix.random) / total;
        let base = (cache / footprint).sqrt().min(1.0);
        ((1.0 - base) * (0.15 + 0.85 * hostile)).clamp(0.002, 0.95)
    }

    /// Predict platform + native times for `instructions` instructions.
    pub fn predict(&self, wl: &Workload, instructions: u64) -> AnalyticalPrediction {
        // audit: allow(wall-clock) — baselines time themselves for Fig 7
        let wall = std::time::Instant::now();
        let cfg = &self.cfg;
        let mem_ops = instructions as f64 / (1.0 + wl.mean_gap);
        let miss_rate = self.est_miss_rate(wl);
        let misses = mem_ops * miss_rate;

        // Unloaded latencies.
        let link = PcieLink::new(cfg.pcie);
        let dram_ns = 32.0; // unloaded DDR4 round trip (cf. calibrate)
        let nvm_frac = 1.0
            - (cfg.dram.size_bytes as f64 / (wl.footprint_bytes / cfg.scale) as f64).min(1.0);
        let read_stall = cfg.nvm.read_stall_ns as f64;
        let device_ns = dram_ns + nvm_frac * read_stall;
        let platform_miss_ns = link.unloaded_rtt_ns(64) as f64 + device_ns;
        let native_miss_ns = 45.0 + dram_ns;

        // MLP: dependent misses serialize, the rest overlap by the MSHR
        // capacity.
        let dep_frac = wl.mix.chase / wl.mix.total();
        let mlp = cfg.cpu.max_outstanding_misses as f64 * 0.6;
        let eff = |lat: f64| dep_frac * lat + (1.0 - dep_frac) * lat / mlp;

        let base_ns = instructions as f64 / (cfg.cpu.freq_ghz * cfg.cpu.base_ipc);
        let time_ns = base_ns + misses * eff(platform_miss_ns);
        let native_time_ns = base_ns + misses * eff(native_miss_ns);

        AnalyticalPrediction {
            time_ns: time_ns as u64,
            native_time_ns: native_time_ns as u64,
            slowdown: time_ns / native_time_ns,
            miss_rate,
            wall_ns: wall.elapsed().as_nanos() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec;

    #[test]
    fn predicts_in_microseconds() {
        let m = AnalyticalModel::new(SystemConfig::default_scaled(16));
        let p = m.predict(&spec::by_name("505.mcf").unwrap(), 10_000_000);
        // The model's virtue: instant.
        assert!(p.wall_ns < 1_000_000, "prediction took {}ns", p.wall_ns);
        assert!(p.slowdown > 1.0);
    }

    #[test]
    fn ordering_roughly_sane() {
        let m = AnalyticalModel::new(SystemConfig::default_scaled(16));
        let mcf = m.predict(&spec::by_name("505.mcf").unwrap(), 1_000_000);
        let img = m.predict(&spec::by_name("538.imagick").unwrap(), 1_000_000);
        assert!(mcf.slowdown > img.slowdown);
        assert!(mcf.miss_rate > img.miss_rate);
    }

    #[test]
    fn cache_resident_near_native() {
        // leela's scaled footprint (1.4MB) slightly exceeds L2, and the
        // √-rule overestimates its misses — crude by design; just bound
        // it away from the memory-bound class.
        let m = AnalyticalModel::new(SystemConfig::default_scaled(16));
        let leela = m.predict(&spec::by_name("541.leela").unwrap(), 1_000_000);
        let mcf = m.predict(&spec::by_name("505.mcf").unwrap(), 1_000_000);
        assert!(leela.slowdown < mcf.slowdown);
        assert!(leela.slowdown < 8.0);
    }

    #[test]
    fn accuracy_vs_simulation_is_poor_for_complex_workloads() {
        // The paper's point: analytical models miss the dynamics. The
        // platform-vs-model error for at least one workload should be
        // large (>30%) — this test pins the *motivation*, not a virtue.
        use crate::platform::{Platform, RunOpts};
        let cfg = SystemConfig::default_scaled(64);
        let m = AnalyticalModel::new(cfg.clone());
        let mut worst = 0.0f64;
        for name in ["505.mcf", "520.omnetpp", "538.imagick"] {
            let wl = spec::by_name(name).unwrap();
            let r = Platform::new(cfg.clone())
                .run_opts(
                    &wl,
                    RunOpts {
                        ops: 60_000,
                        flush_at_end: false,
                    },
                )
                .unwrap();
            let p = m.predict(&wl, r.instructions);
            let err = (p.slowdown - r.slowdown()).abs() / r.slowdown();
            worst = worst.max(err);
        }
        assert!(
            worst > 0.3,
            "analytical model suspiciously accurate (worst err {worst:.2}) — \
             if this fails the model got *better*; update the paper-motivation notes"
        );
    }
}
