//! Software-simulator baselines for the Fig 7 comparison.
//!
//! The paper compares its platform against gem5 (SE mode) and ChampSim
//! running the same workloads on a Xeon workstation. Neither tool exists
//! in this offline environment, so we implement the two *cost regimes*
//! they represent and measure real wall-clock on this host:
//!
//! - [`gem5_like`] — cycle-level out-of-order microarchitecture simulation:
//!   every cycle ticks fetch/rename/issue/execute/commit structures, the
//!   full cache hierarchy and a banked DRAM model. This is the "detailed,
//!   slow" regime (real gem5: ~0.1 MIPS).
//! - [`champsim_like`] — trace-driven simulation: per-instruction branch
//!   predictor + cache hierarchy lookups with a simplified queue-based
//!   memory model. The "faster, less detailed" regime (real ChampSim:
//!   ~1-5 MIPS).
//!
//! Slowdowns are computed exactly as in the paper: simulator wall-clock
//! time normalized by the *native* execution time of the same instruction
//! count (from the platform's native reference model).

pub mod analytical;
pub mod champsim_like;
pub mod gem5_like;
pub mod harness;

pub use analytical::{AnalyticalModel, AnalyticalPrediction};
pub use harness::{run_fig7_row, BaselineResult, Fig7Row};
