//! Trace-driven, cycle-operated simulator (the ChampSim cost regime).
//!
//! Modern ChampSim is *cycle-driven*: every simulated cycle it calls
//! `operate()` on the O3 pipeline model and on each cache/DRAM queue; it
//! is only "trace-driven" in that instructions come from a trace instead
//! of functional execution. That per-cycle queue machinery is why it runs
//! at ~1-5 MIPS — an order of magnitude faster than gem5 (which adds
//! full-window wakeup scans and execute-in-execute), and thousands of
//! times slower than native.
//!
//! We model the same structure: a cycle loop with dispatch/retire stages,
//! a ROB of completion times, MSHRs, per-level request queues operated
//! every cycle, a bimodal branch predictor, an IP-stride prefetcher and
//! the banked DRAM model.

use crate::config::SystemConfig;
use crate::cpu::cache::Cache;
use crate::mem::{AccessKind, DramDevice, MemDevice};
use crate::util::rng::Xoshiro256;
use crate::workload::{TraceGenerator, Workload};
use std::collections::VecDeque;

const ROB_SIZE: usize = 128;
const DISPATCH_WIDTH: usize = 4;
const RETIRE_WIDTH: usize = 4;
const MSHRS: usize = 8;
const RQ_SIZE: usize = 32;
const PREFETCH_TABLE: usize = 64;

/// Result of a champsim-like run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub instructions: u64,
    pub modeled_ns: u64,
    pub wall_ns: u64,
    pub l2_misses: u64,
    pub prefetches_issued: u64,
}

#[derive(Clone, Copy, Default)]
struct StrideEntry {
    ip: u64,
    last_addr: u64,
    stride: i64,
    confidence: u8,
}

/// A pending instruction in the dispatch buffer.
#[derive(Clone, Copy)]
enum Slot {
    Plain,
    Branch,
    Mem {
        addr: u64,
        is_write: bool,
        dependent: bool,
        /// Synthetic loop-body IP (stable per pattern) for IP-indexed
        /// structures.
        ip: u64,
    },
}

pub struct ChampsimLike {
    cfg: SystemConfig,
}

impl ChampsimLike {
    pub fn new(cfg: SystemConfig) -> Self {
        ChampsimLike { cfg }
    }

    pub fn run(&self, wl: &Workload, instructions: u64) -> SimResult {
        // audit: allow(wall-clock) — baselines time themselves for Fig 7
        let wall0 = std::time::Instant::now();
        let cfg = &self.cfg;
        let mut l1i = Cache::new(cfg.l1i);
        let mut l1d = Cache::new(cfg.l1d);
        let mut l2 = Cache::new(cfg.l2);
        let mut dram = DramDevice::new(cfg.dram);
        let mut bp = vec![1u8; 8192];
        let mut stride_table: Vec<StrideEntry> = vec![StrideEntry::default(); PREFETCH_TABLE];
        let mut rng = Xoshiro256::new(cfg.seed ^ 0xC5);

        let mut gen = TraceGenerator::new(*wl, cfg.scale, cfg.seed);
        // Decode buffer of pending slots from the trace.
        let mut decode: VecDeque<Slot> = VecDeque::with_capacity(64);
        let mut refill = |decode: &mut VecDeque<Slot>, rng: &mut Xoshiro256| {
            if let Some(t) = gen.next() {
                for k in 0..t.gap {
                    decode.push_back(if (k + 1) % 7 == 0 && rng.chance(0.9) {
                        Slot::Branch
                    } else {
                        Slot::Plain
                    });
                }
                decode.push_back(Slot::Mem {
                    addr: t.addr,
                    is_write: t.is_write,
                    dependent: t.dependent,
                    ip: 0x40_0000 + t.pattern as u64 * 32,
                });
                true
            } else {
                false
            }
        };

        // Pipeline state.
        let mut rob: VecDeque<u64> = VecDeque::with_capacity(ROB_SIZE); // completion cycles
        let mut mshrs: Vec<u64> = Vec::with_capacity(MSHRS);
        // Per-level request queues (operated every cycle like ChampSim's
        // RQ): (ready_cycle, addr).
        let mut l1_rq: VecDeque<(u64, u64)> = VecDeque::with_capacity(RQ_SIZE);
        let mut l2_rq: VecDeque<(u64, u64)> = VecDeque::with_capacity(RQ_SIZE);
        let mut cycle: u64 = 0;
        let mut retired: u64 = 0;
        let mut dispatched: u64 = 0;
        let mut stall_until: u64 = 0; // front-end stall (mispredict / dep load)
        let mut l2_misses = 0u64;
        let mut prefetches = 0u64;
        let mut pc: u64 = 0x40_0000;

        while retired < instructions {
            cycle += 1;

            // --- operate() the cache queues: drain ready entries (the
            //     per-cycle queue machinery that costs ChampSim its MIPS) ---
            while let Some(&(r, _)) = l1_rq.front() {
                if r <= cycle {
                    l1_rq.pop_front();
                } else {
                    break;
                }
            }
            while let Some(&(r, _)) = l2_rq.front() {
                if r <= cycle {
                    l2_rq.pop_front();
                } else {
                    break;
                }
            }
            mshrs.retain(|&c| c > cycle);

            // --- retire: up to RETIRE_WIDTH completed from the ROB head ---
            for _ in 0..RETIRE_WIDTH {
                match rob.front() {
                    Some(&c) if c <= cycle => {
                        rob.pop_front();
                        retired += 1;
                    }
                    _ => break,
                }
            }
            if retired >= instructions {
                break;
            }

            // --- dispatch: up to DISPATCH_WIDTH from the decode buffer ---
            if cycle >= stall_until {
                for _ in 0..DISPATCH_WIDTH {
                    if rob.len() >= ROB_SIZE {
                        break;
                    }
                    if decode.is_empty() && !refill(&mut decode, &mut rng) {
                        break;
                    }
                    let Some(slot) = decode.pop_front() else { break };
                    // I-fetch one line probe per dispatch group.
                    pc = pc.wrapping_add(4);
                    if pc % 64 == 0 && !l1i.access(pc & !63, false).hit {
                        let _ = l2.access(pc & !63, false);
                        stall_until = cycle + cfg.l2.hit_cycles as u64;
                    }
                    match slot {
                        Slot::Plain => rob.push_back(cycle + 1),
                        Slot::Branch => {
                            let idx = (pc >> 2 & 8191) as usize;
                            let taken = rng.chance(0.4);
                            let pred = bp[idx] >= 2;
                            if taken {
                                bp[idx] = (bp[idx] + 1).min(3);
                            } else {
                                bp[idx] = bp[idx].saturating_sub(1);
                            }
                            rob.push_back(cycle + 1);
                            if pred != taken {
                                stall_until = cycle + 12;
                                break;
                            }
                        }
                        Slot::Mem {
                            addr,
                            is_write,
                            dependent,
                            ip,
                        } => {
                            let line = addr & !63;

                            // IP-stride prefetcher (train + issue into L2).
                            let sidx = ((ip >> 2) as usize) % PREFETCH_TABLE;
                            let e = &mut stride_table[sidx];
                            if e.ip == ip {
                                let s = line as i64 - e.last_addr as i64;
                                if s == e.stride && s != 0 {
                                    e.confidence = (e.confidence + 1).min(3);
                                } else {
                                    e.confidence = e.confidence.saturating_sub(1);
                                    e.stride = s;
                                }
                                e.last_addr = line;
                            } else {
                                *e = StrideEntry {
                                    ip,
                                    last_addr: line,
                                    stride: 0,
                                    confidence: 0,
                                };
                            }
                            if e.confidence >= 2 {
                                let paddr = (line as i64 + 2 * e.stride) as u64 & !63;
                                if !l2.access(paddr, false).hit {
                                    prefetches += 1;
                                    let now_ns = (cycle as f64 / cfg.cpu.freq_ghz) as u64;
                                    let _ = dram.access(paddr, AccessKind::Read, 64, now_ns);
                                }
                            }

                            // RQ occupancy: full queue blocks dispatch.
                            if l1_rq.len() >= RQ_SIZE {
                                decode.push_front(slot);
                                break;
                            }

                            let complete = if l1d.access(line, is_write).hit {
                                cycle + cfg.l1d.hit_cycles as u64
                            } else if {
                                l1_rq.push_back((cycle + cfg.l1d.hit_cycles as u64, line));
                                l2.access(line, is_write).hit
                            } {
                                cycle + (cfg.l1d.hit_cycles + cfg.l2.hit_cycles) as u64
                            } else {
                                l2_misses += 1;
                                if mshrs.len() >= MSHRS || l2_rq.len() >= RQ_SIZE {
                                    // Stall dispatch until an MSHR frees.
                                    let earliest =
                                        mshrs.iter().copied().min().unwrap_or(cycle + 1);
                                    stall_until = stall_until.max(earliest);
                                }
                                let now_ns = (cycle as f64 / cfg.cpu.freq_ghz) as u64;
                                let (done_ns, _) = dram.access(
                                    line,
                                    if is_write {
                                        AccessKind::Write
                                    } else {
                                        AccessKind::Read
                                    },
                                    64,
                                    now_ns,
                                );
                                let mem_cycles =
                                    ((done_ns - now_ns) as f64 * cfg.cpu.freq_ghz) as u64;
                                let c = cycle
                                    + (cfg.l1d.hit_cycles + cfg.l2.hit_cycles) as u64
                                    + mem_cycles;
                                mshrs.push(c);
                                l2_rq.push_back((c, line));
                                c
                            };
                            rob.push_back(complete);
                            if dependent && complete > cycle {
                                // Chained load: the next instruction's
                                // address depends on this data.
                                stall_until = stall_until.max(complete);
                                break;
                            }
                        }
                    }
                    dispatched += 1;
                }
            }

            // Safety valve.
            if cycle > instructions * 2000 {
                break;
            }
        }
        let _ = dispatched;

        SimResult {
            instructions: retired,
            modeled_ns: (cycle as f64 / cfg.cpu.freq_ghz) as u64,
            wall_ns: wall0.elapsed().as_nanos() as u64,
            l2_misses,
            prefetches_issued: prefetches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec;

    #[test]
    fn runs_and_counts() {
        let cfg = SystemConfig::default_scaled(64);
        let r = ChampsimLike::new(cfg).run(&spec::by_name("505.mcf").unwrap(), 50_000);
        assert!(r.instructions >= 50_000);
        assert!(r.modeled_ns > 0);
        assert!(r.l2_misses > 0);
    }

    #[test]
    fn faster_than_gem5_like_but_slow_regime() {
        let cfg = SystemConfig::default_scaled(64);
        let n = 40_000;
        let wl = spec::by_name("520.omnetpp").unwrap();
        let champ = ChampsimLike::new(cfg.clone()).run(&wl, n);
        let gem5 = super::super::gem5_like::Gem5Like::new(cfg).run(&wl, n);
        assert!(
            gem5.wall_ns > 2 * champ.wall_ns,
            "gem5-like {} vs champsim-like {}",
            gem5.wall_ns,
            champ.wall_ns
        );
        // Cycle-driven regime: well below 20 MIPS.
        let mips = champ.instructions as f64 / (champ.wall_ns as f64 / 1000.0);
        assert!(mips < 20.0, "champsim-like too fast: {mips} MIPS");
    }

    #[test]
    fn memory_bound_slower_modeled_time() {
        let cfg = SystemConfig::default_scaled(64);
        let n = 50_000;
        let mcf = ChampsimLike::new(cfg.clone()).run(&spec::by_name("505.mcf").unwrap(), n);
        let img = ChampsimLike::new(cfg).run(&spec::by_name("538.imagick").unwrap(), n);
        let cpi_mcf = mcf.modeled_ns as f64 / mcf.instructions as f64;
        let cpi_img = img.modeled_ns as f64 / img.instructions as f64;
        assert!(cpi_mcf > cpi_img);
    }

    #[test]
    fn prefetcher_trains_on_streams() {
        let cfg = SystemConfig::default_scaled(64);
        let r = ChampsimLike::new(cfg).run(&spec::by_name("519.lbm").unwrap(), 50_000);
        assert!(r.prefetches_issued > 0, "streaming should train the prefetcher");
    }
}
