//! Cycle-level out-of-order CPU simulator (the gem5-SE cost regime).
//!
//! Models, per simulated cycle:
//! - 3-wide fetch through an L1I model with a gshare branch predictor and
//!   squash-on-mispredict refetch;
//! - rename with a free-list and register scoreboard;
//! - a 48-entry issue queue woken by a full-window dependency scan each
//!   cycle (this O(window) scan every cycle is exactly what makes real
//!   cycle simulators slow — it is the honest cost of the regime, not an
//!   artificial sleep);
//! - execution ports (3 ALU, 1 branch, 2 LSU), an 8-entry MSHR file,
//!   the L1D/L2 hierarchy and a banked DRAM with row-buffer state;
//! - a 128-entry ROB with in-order commit.
//!
//! Instruction stream: synthesized from the workload trace — each
//! `TraceOp` expands to `gap` non-memory instructions (ALU/branch/FP mix)
//! followed by the memory op, with dependencies wired so pointer-chase
//! loads serialize as they would in the real binary.

use crate::config::SystemConfig;
use crate::cpu::cache::Cache;
use crate::mem::{AccessKind, DramDevice, MemDevice};
use crate::util::rng::Xoshiro256;
use crate::workload::{TraceGenerator, Workload};

const ROB_SIZE: usize = 128;
const IQ_SIZE: usize = 48;
const FETCH_WIDTH: usize = 3;
const COMMIT_WIDTH: usize = 3;
const NUM_ALU: usize = 3;
const NUM_LSU: usize = 2;
const MSHRS: usize = 8;

#[derive(Clone, Copy, Debug, PartialEq)]
enum Op {
    Alu,
    Fp,
    Branch,
    Load { addr: u64, dependent: bool },
    Store { addr: u64 },
}

#[derive(Clone, Copy, Debug)]
struct MicroOp {
    op: Op,
    /// Producer's *global* instruction id this op waits on, if any
    /// (global ids are stable across ROB head removal).
    src: Option<u64>,
    /// Cycle the op's result is ready (u64::MAX until executed).
    ready_at: u64,
    issued: bool,
    completed: bool,
}

/// Result of a gem5-like run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub instructions: u64,
    pub cycles: u64,
    pub modeled_ns: u64,
    pub wall_ns: u64,
    pub l1d_misses: u64,
    pub branch_mispredicts: u64,
}

impl SimResult {
    pub fn sim_mips(&self) -> f64 {
        self.instructions as f64 / (self.wall_ns as f64 / 1000.0)
    }
}

/// gshare branch predictor (4K entries, 2-bit counters).
struct Gshare {
    table: Vec<u8>,
    history: u64,
}

impl Gshare {
    fn new() -> Self {
        Gshare {
            table: vec![1; 4096],
            history: 0,
        }
    }

    #[inline]
    fn predict_and_update(&mut self, pc: u64, taken: bool) -> bool {
        let idx = ((pc ^ self.history) & 4095) as usize;
        let pred = self.table[idx] >= 2;
        let c = &mut self.table[idx];
        if taken {
            *c = (*c + 1).min(3);
        } else {
            *c = c.saturating_sub(1);
        }
        self.history = (self.history << 1) | taken as u64;
        pred == taken
    }
}

/// The simulator.
pub struct Gem5Like {
    cfg: SystemConfig,
}

impl Gem5Like {
    pub fn new(cfg: SystemConfig) -> Self {
        Gem5Like { cfg }
    }

    /// Run `instructions` of `wl`; returns modeled + wall time.
    pub fn run(&self, wl: &Workload, instructions: u64) -> SimResult {
        // audit: allow(wall-clock) — baselines time themselves for Fig 7
        let wall0 = std::time::Instant::now();
        let cfg = &self.cfg;
        let mut l1i = Cache::new(cfg.l1i);
        let mut l1d = Cache::new(cfg.l1d);
        let mut l2 = Cache::new(cfg.l2);
        let mut dram = DramDevice::new(cfg.dram);
        let mut bp = Gshare::new();
        let mut rng = Xoshiro256::new(cfg.seed ^ 0x6E);

        // Instruction feed from the trace generator.
        let mut gen = TraceGenerator::new(*wl, cfg.scale, cfg.seed);
        let mut pending: Vec<(Op, bool)> = Vec::new(); // (op, depends_on_prev_load)
        let mut feed = move |rng: &mut Xoshiro256, pending: &mut Vec<(Op, bool)>| {
            if pending.is_empty() {
                if let Some(t) = gen.next() {
                    // gap non-memory ops then the memory op (reverse push).
                    let mem = if t.is_write {
                        Op::Store { addr: t.addr }
                    } else {
                        Op::Load {
                            addr: t.addr,
                            dependent: t.dependent,
                        }
                    };
                    pending.push((mem, t.dependent));
                    for _ in 0..t.gap {
                        let r = rng.f64();
                        let op = if r < 0.15 {
                            Op::Branch
                        } else if r < 0.35 && gen.workload().is_float {
                            Op::Fp
                        } else {
                            Op::Alu
                        };
                        pending.push((op, false));
                    }
                }
            }
            pending.pop()
        };

        // Pipeline state.
        let mut rob: Vec<MicroOp> = Vec::with_capacity(ROB_SIZE);
        let mut rob_base: u64 = 0; // global index of rob[0]
        let mut cycle: u64 = 0;
        let mut committed: u64 = 0;
        let mut fetch_stall_until: u64 = 0;
        let mut mshrs: Vec<u64> = Vec::new(); // completion cycles
        let mut last_load_id: Option<u64> = None; // global id of last load
        let mut l1d_misses = 0u64;
        let mut mispredicts = 0u64;
        let mut pc: u64 = 0x40_0000;

        let cycle_ns = |c: u64| (c as f64 / (cfg.cpu.freq_ghz)) as u64;

        while committed < instructions {
            cycle += 1;

            // --- commit: up to COMMIT_WIDTH completed ops from ROB head ---
            let mut n_commit = 0;
            while n_commit < COMMIT_WIDTH && !rob.is_empty() {
                if rob[0].completed && rob[0].ready_at <= cycle {
                    rob.remove(0);
                    rob_base += 1;
                    committed += 1;
                    n_commit += 1;
                } else {
                    break;
                }
            }

            // --- wakeup/complete: scan the whole window every cycle (the
            //     honest O(window) cost of cycle-level simulation) ---
            for i in 0..rob.len() {
                if rob[i].issued && !rob[i].completed && rob[i].ready_at <= cycle {
                    rob[i].completed = true;
                }
            }
            mshrs.retain(|&c| c > cycle);

            // --- issue: scan IQ-eligible ops, respect ports + deps ---
            let mut alu_free = NUM_ALU;
            let mut lsu_free = NUM_LSU;
            let window = rob.len().min(IQ_SIZE);
            for i in 0..window {
                if rob[i].issued {
                    continue;
                }
                // Dependency ready? (committed producers — global id below
                // rob_base — are always ready.)
                if let Some(src_id) = rob[i].src {
                    if src_id >= rob_base {
                        let s = (src_id - rob_base) as usize;
                        if s < rob.len() && !(rob[s].completed && rob[s].ready_at <= cycle) {
                            continue;
                        }
                    }
                }
                match rob[i].op {
                    Op::Alu | Op::Branch | Op::Fp => {
                        if alu_free == 0 {
                            continue;
                        }
                        alu_free -= 1;
                        let lat = if rob[i].op == Op::Fp { 4 } else { 1 };
                        rob[i].issued = true;
                        rob[i].ready_at = cycle + lat;
                    }
                    Op::Load { addr, .. } | Op::Store { addr } => {
                        if lsu_free == 0 || mshrs.len() >= MSHRS {
                            continue;
                        }
                        lsu_free -= 1;
                        let is_store = matches!(rob[i].op, Op::Store { .. });
                        let line = addr & !63;
                        // Hierarchy walk.
                        let lat_cycles = if l1d.access(line, is_store).hit {
                            cfg.l1d.hit_cycles as u64
                        } else if l2.access(line, is_store).hit {
                            l1d_misses += 1;
                            (cfg.l1d.hit_cycles + cfg.l2.hit_cycles) as u64
                        } else {
                            l1d_misses += 1;
                            // DRAM access with bank/row state.
                            let now_ns = cycle_ns(cycle);
                            let (done_ns, _) =
                                dram.access(line, if is_store { AccessKind::Write } else { AccessKind::Read }, 64, now_ns);
                            let mem_cycles =
                                ((done_ns - now_ns) as f64 * cfg.cpu.freq_ghz) as u64;
                            mshrs.push(cycle + mem_cycles);
                            (cfg.l1d.hit_cycles + cfg.l2.hit_cycles) as u64 + mem_cycles
                        };
                        rob[i].issued = true;
                        rob[i].ready_at = cycle + lat_cycles;
                    }
                }
            }

            // --- fetch/rename: up to FETCH_WIDTH new ops into the ROB ---
            if cycle >= fetch_stall_until {
                for _ in 0..FETCH_WIDTH {
                    if rob.len() >= ROB_SIZE {
                        break;
                    }
                    // I-fetch (sequential PCs; 64B lines hit mostly).
                    pc += 4;
                    if !l1i.access(pc & !63, false).hit {
                        // I-miss: refill from L2 (charge a fetch bubble).
                        let _ = l2.access(pc & !63, false);
                        fetch_stall_until = cycle + cfg.l2.hit_cycles as u64;
                    }
                    let Some((op, dep)) = feed(&mut rng, &mut pending) else {
                        break;
                    };
                    // Branch prediction.
                    if matches!(op, Op::Branch) {
                        let taken = rng.chance(0.4);
                        if !bp.predict_and_update(pc, taken) {
                            mispredicts += 1;
                            fetch_stall_until = cycle + 12; // A57-ish penalty
                        }
                    }
                    let src = if dep { last_load_id } else { None };
                    let is_load = matches!(op, Op::Load { .. });
                    rob.push(MicroOp {
                        op,
                        src,
                        ready_at: u64::MAX,
                        issued: false,
                        completed: false,
                    });
                    if is_load {
                        last_load_id = Some(rob_base + rob.len() as u64 - 1);
                    }
                    if matches!(op, Op::Branch) && fetch_stall_until > cycle {
                        break; // squash: stop fetching this cycle
                    }
                }
            }

            // Deadlock guard (should not trigger; keeps tests safe).
            if cycle > instructions * 1000 {
                break;
            }
        }

        let modeled_ns = cycle_ns(cycle);
        SimResult {
            instructions: committed,
            cycles: cycle,
            modeled_ns,
            wall_ns: wall0.elapsed().as_nanos() as u64,
            l1d_misses,
            branch_mispredicts: mispredicts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec;

    #[test]
    fn runs_to_completion() {
        let cfg = SystemConfig::default_scaled(64);
        let r = Gem5Like::new(cfg).run(&spec::by_name("505.mcf").unwrap(), 20_000);
        assert!(r.instructions >= 20_000);
        assert!(r.cycles > 0);
        assert!(r.modeled_ns > 0);
        assert!(r.wall_ns > 0);
    }

    #[test]
    fn memory_bound_worse_ipc_than_compute_bound() {
        let cfg = SystemConfig::default_scaled(64);
        let mcf = Gem5Like::new(cfg.clone()).run(&spec::by_name("505.mcf").unwrap(), 30_000);
        let img = Gem5Like::new(cfg).run(&spec::by_name("538.imagick").unwrap(), 30_000);
        let ipc_mcf = mcf.instructions as f64 / mcf.cycles as f64;
        let ipc_img = img.instructions as f64 / img.cycles as f64;
        assert!(ipc_img > ipc_mcf, "imagick {ipc_img} vs mcf {ipc_mcf}");
    }

    #[test]
    fn simulation_is_slow_regime() {
        // The whole point: wall time per instruction is orders of
        // magnitude above native. Native at ~2.4 GIPS does 30K instr in
        // 12.5us; the cycle sim must be at least 100x slower.
        let cfg = SystemConfig::default_scaled(64);
        let r = Gem5Like::new(cfg).run(&spec::by_name("520.omnetpp").unwrap(), 30_000);
        let native_ns = 30_000.0 / 2.4;
        assert!(
            r.wall_ns as f64 > 100.0 * native_ns,
            "gem5-like wall {} vs native {}",
            r.wall_ns,
            native_ns
        );
    }

    #[test]
    fn counts_microarch_events() {
        let cfg = SystemConfig::default_scaled(64);
        let r = Gem5Like::new(cfg).run(&spec::by_name("557.xz").unwrap(), 50_000);
        assert!(r.l1d_misses > 0);
        assert!(r.branch_mispredicts > 0);
    }
}
