//! Fig 7 harness: run all three methods on a workload and compute
//! slowdown factors exactly as the paper does.
//!
//! - **native time** — modeled execution on local DRAM (the denominator).
//! - **ours** — modeled execution on the PCIe-attached hybrid platform
//!   (the paper's platform runs the *real* application; its slowdown is a
//!   hardware property, so we compare modeled-vs-modeled).
//! - **gem5-like / champsim-like** — measured simulator *wall-clock*,
//!   normalized by the native time of the same instruction count
//!   (rate-based: simulators run a sample of the trace; cost per
//!   instruction is constant, so the ratio is unbiased).

use super::champsim_like::ChampsimLike;
use super::gem5_like::Gem5Like;
use crate::config::SystemConfig;
use crate::platform::{Platform, RunOpts};
use crate::workload::Workload;
use crate::util::error::Result;

/// One simulator measurement.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    pub name: &'static str,
    pub instructions: u64,
    pub wall_ns: u64,
    pub slowdown: f64,
}

/// One row of Fig 7.
#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub workload: String,
    /// Our platform: modeled slowdown vs native.
    pub ours: f64,
    pub champsim: f64,
    pub gem5: f64,
    /// Native time per instruction (ns) used for normalization.
    pub native_ns_per_instr: f64,
}

impl Fig7Row {
    pub fn speedup_vs_gem5(&self) -> f64 {
        self.gem5 / self.ours
    }

    pub fn speedup_vs_champsim(&self) -> f64 {
        self.champsim / self.ours
    }
}

/// Produce one Fig 7 row. `platform_ops` sizes our platform run;
/// `baseline_instructions` sizes the (much slower) simulator samples.
pub fn run_fig7_row(
    cfg: &SystemConfig,
    wl: &Workload,
    platform_ops: u64,
    baseline_instructions: u64,
) -> Result<Fig7Row> {
    // Ours + the native normalization baseline.
    let report = Platform::new(cfg.clone()).run_opts(
        wl,
        RunOpts {
            ops: platform_ops,
            flush_at_end: false,
        },
    )?;
    let native_ns_per_instr = report.native_time_ns as f64 / report.instructions as f64;

    // gem5-like.
    let g = Gem5Like::new(cfg.clone()).run(wl, baseline_instructions);
    let g_native = native_ns_per_instr * g.instructions as f64;
    let gem5 = g.wall_ns as f64 / g_native;

    // champsim-like.
    let c = ChampsimLike::new(cfg.clone()).run(wl, baseline_instructions);
    let c_native = native_ns_per_instr * c.instructions as f64;
    let champsim = c.wall_ns as f64 / c_native;

    Ok(Fig7Row {
        workload: wl.name.to_string(),
        ours: report.slowdown(),
        champsim,
        gem5,
        native_ns_per_instr,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec;

    #[test]
    fn ordering_matches_paper() {
        let cfg = SystemConfig::default_scaled(64);
        let wl = spec::by_name("505.mcf").unwrap();
        let row = run_fig7_row(&cfg, &wl, 20_000, 20_000).unwrap();
        eprintln!(
            "fig7 mcf: ours={:.2} champsim={:.1} gem5={:.1} native_ns/instr={:.3}",
            row.ours, row.champsim, row.gem5, row.native_ns_per_instr
        );
        // The paper's regime ordering: gem5 >> champsim >> ours;
        // ours stays within ~20x of native even for mcf.
        assert!(row.gem5 > row.champsim, "gem5 {} champ {}", row.gem5, row.champsim);
        assert!(
            row.champsim > row.ours,
            "champ {} ours {}",
            row.champsim,
            row.ours
        );
        assert!(row.ours > 1.0 && row.ours < 40.0, "ours {}", row.ours);
        assert!(row.speedup_vs_gem5() > 10.0);
    }
}
