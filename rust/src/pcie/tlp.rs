//! PCIe Transaction Layer Packet types.
//!
//! The HMMU's RX module receives memory-request TLPs (MRd/MWr) and its TX
//! module returns completions-with-data (CplD) — Fig 2's entry and exit
//! points. The `tag` field is the consistency handle the paper's
//! tag-matching mechanism keys on (§III-C).

/// TLP kinds used by the platform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TlpKind {
    /// Memory read request.
    MRd,
    /// Memory write request (posted).
    MWr,
    /// Completion with data (read response).
    CplD,
}

/// A transaction-layer packet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tlp {
    pub kind: TlpKind,
    /// Host physical address (within the BAR window).
    pub addr: u64,
    /// Payload length in bytes (write data or completion data).
    pub bytes: u32,
    /// Transaction tag — matches completions to requests.
    pub tag: u16,
    /// Requester id (core index in our model).
    pub requester: u16,
}

impl Tlp {
    pub fn read(addr: u64, bytes: u32, tag: u16, requester: u16) -> Self {
        Tlp {
            kind: TlpKind::MRd,
            addr,
            bytes,
            tag,
            requester,
        }
    }

    pub fn write(addr: u64, bytes: u32, tag: u16, requester: u16) -> Self {
        Tlp {
            kind: TlpKind::MWr,
            addr,
            bytes,
            tag,
            requester,
        }
    }

    pub fn completion(&self) -> Self {
        debug_assert_eq!(self.kind, TlpKind::MRd);
        Tlp {
            kind: TlpKind::CplD,
            addr: self.addr,
            bytes: self.bytes,
            tag: self.tag,
            requester: self.requester,
        }
    }

    /// Payload carried on the wire (writes carry data out, reads carry
    /// data back in the completion).
    pub fn wire_payload(&self) -> u32 {
        match self.kind {
            TlpKind::MRd => 0,
            TlpKind::MWr | TlpKind::CplD => self.bytes,
        }
    }

    pub fn is_read(&self) -> bool {
        self.kind == TlpKind::MRd
    }

    pub fn is_write(&self) -> bool {
        self.kind == TlpKind::MWr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_preserves_tag() {
        let r = Tlp::read(0x1000, 64, 42, 1);
        let c = r.completion();
        assert_eq!(c.kind, TlpKind::CplD);
        assert_eq!(c.tag, 42);
        assert_eq!(c.addr, 0x1000);
    }

    #[test]
    fn wire_payload_by_kind() {
        assert_eq!(Tlp::read(0, 64, 0, 0).wire_payload(), 0);
        assert_eq!(Tlp::write(0, 64, 0, 0).wire_payload(), 64);
        assert_eq!(Tlp::read(0, 64, 0, 0).completion().wire_payload(), 64);
    }

    #[test]
    fn predicates() {
        assert!(Tlp::read(0, 64, 0, 0).is_read());
        assert!(Tlp::write(0, 64, 0, 0).is_write());
        assert!(!Tlp::write(0, 64, 0, 0).is_read());
    }
}
