//! PCIe Gen3 link model — the interconnect between the host CPU and the
//! HMMU (Fig 1b), and the paper's own explanation for the platform's
//! residual slowdown ("we presume the major impact comes from the latency
//! of the PCIe links").
//!
//! Modeled at TLP granularity: serialization time from payload size and
//! the 128b/130b-encoded lane rate, a fixed propagation/PHY latency each
//! way, and credit-based flow control bounding outstanding TLPs.

pub mod tlp;

pub use tlp::{Tlp, TlpKind};

use crate::config::PcieConfig;
use crate::sim::Time;

/// One direction of the link (host→device or device→host).
#[derive(Clone, Debug)]
pub struct LinkDirection {
    /// When the wire is next free.
    wire_free: Time,
    bytes_sent: u64,
    tlps_sent: u64,
}

/// Full-duplex PCIe link with credit flow control.
#[derive(Clone, Debug)]
pub struct PcieLink {
    cfg: PcieConfig,
    pub tx: LinkDirection, // host -> HMMU
    pub rx: LinkDirection, // HMMU -> host
    /// Completion times of TLPs holding a TX credit.
    credit_release: Vec<Time>,
    pub credit_stalls: u64,
    pub credit_wait_ns: u64,
}

impl PcieLink {
    pub fn new(cfg: PcieConfig) -> Self {
        PcieLink {
            cfg,
            tx: LinkDirection {
                wire_free: 0,
                bytes_sent: 0,
                tlps_sent: 0,
            },
            rx: LinkDirection {
                wire_free: 0,
                bytes_sent: 0,
                tlps_sent: 0,
            },
            credit_release: Vec::new(),
            credit_stalls: 0,
            credit_wait_ns: 0,
        }
    }

    pub fn config(&self) -> &PcieConfig {
        &self.cfg
    }

    /// Wire time for a TLP of `payload` bytes (header + payload over the
    /// encoded aggregate lane bandwidth), in ns (at least 1).
    #[inline]
    pub fn serialize_ns(&self, payload_bytes: u32) -> u64 {
        let total = (self.cfg.tlp_header_bytes + payload_bytes) as f64;
        (total / self.cfg.bandwidth_bytes_per_ns()).ceil().max(1.0) as u64
    }

    /// Transmit host→HMMU at `now`; returns arrival time at the HMMU RX.
    /// Acquires a flow-control credit; the credit is released when the
    /// transaction completes (`release` from [`Self::complete`]).
    pub fn send_to_device(&mut self, payload_bytes: u32, now: Time) -> Time {
        // Credit gate. §Perf: drain released credits lazily — only when
        // the pool looks exhausted (amortized O(1) per TLP).
        let mut start = now;
        if self.credit_release.len() >= self.cfg.credits as usize {
            self.credit_release.retain(|&t| t > now);
        }
        if self.credit_release.len() >= self.cfg.credits as usize {
            let earliest = self.credit_release.iter().copied().min().unwrap();
            self.credit_stalls += 1;
            self.credit_wait_ns += earliest.saturating_sub(now);
            start = earliest;
            let e = earliest;
            self.credit_release.retain(|&t| t > e);
        }
        let ser = self.serialize_ns(payload_bytes);
        let wire_start = start.max(self.tx.wire_free);
        self.tx.wire_free = wire_start + ser;
        self.tx.bytes_sent += (self.cfg.tlp_header_bytes + payload_bytes) as u64;
        self.tx.tlps_sent += 1;
        wire_start + ser + self.cfg.propagation_ns
    }

    /// Register the completion time of a transaction so its TX credit is
    /// released then.
    pub fn hold_credit_until(&mut self, release_at: Time) {
        self.credit_release.push(release_at);
    }

    /// Transmit HMMU→host (completion TLP) at `now`; returns arrival time
    /// at the host.
    pub fn send_to_host(&mut self, payload_bytes: u32, now: Time) -> Time {
        let ser = self.serialize_ns(payload_bytes);
        let wire_start = now.max(self.rx.wire_free);
        self.rx.wire_free = wire_start + ser;
        self.rx.bytes_sent += (self.cfg.tlp_header_bytes + payload_bytes) as u64;
        self.rx.tlps_sent += 1;
        wire_start + ser + self.cfg.propagation_ns
    }

    pub fn tx_bytes(&self) -> u64 {
        self.tx.bytes_sent
    }

    pub fn rx_bytes(&self) -> u64 {
        self.rx.bytes_sent
    }

    pub fn tlps(&self) -> u64 {
        self.tx.tlps_sent + self.rx.tlps_sent
    }

    /// Unloaded round-trip for a read of `bytes` (serialize request +
    /// 2×propagation + serialize completion); device service excluded.
    pub fn unloaded_rtt_ns(&self, bytes: u32) -> u64 {
        self.serialize_ns(0) + self.serialize_ns(bytes) + 2 * self.cfg.propagation_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn link() -> PcieLink {
        PcieLink::new(SystemConfig::paper().pcie)
    }

    #[test]
    fn serialization_scales_with_payload() {
        let l = link();
        assert!(l.serialize_ns(256) > l.serialize_ns(0));
        // 16B header at ~7.88GB/s ≈ 2-3ns
        assert!(l.serialize_ns(0) <= 3);
    }

    #[test]
    fn propagation_dominates_small_tlps() {
        let mut l = link();
        let arrival = l.send_to_device(0, 0);
        assert!(arrival >= 400, "arrival={arrival}");
        assert!(arrival < 450);
    }

    #[test]
    fn wire_occupancy_serializes_back_to_back() {
        let mut l = link();
        let a1 = l.send_to_device(256, 0);
        let a2 = l.send_to_device(256, 0);
        assert!(a2 > a1);
        assert_eq!(a2 - a1, l.serialize_ns(256));
    }

    #[test]
    fn credits_block_when_exhausted() {
        let mut l = link();
        let credits = l.config().credits;
        for _ in 0..credits {
            let arr = l.send_to_device(0, 0);
            l.hold_credit_until(arr + 10_000); // transactions outstanding for a long time
        }
        let before = l.credit_stalls;
        l.send_to_device(0, 0);
        assert_eq!(l.credit_stalls, before + 1);
        assert!(l.credit_wait_ns > 0);
    }

    #[test]
    fn duplex_directions_independent() {
        let mut l = link();
        let t_tx = l.send_to_device(256, 0);
        let t_rx = l.send_to_host(256, 0);
        // Both around serialize+prop, neither delayed by the other.
        assert!(t_tx < 500 && t_rx < 500);
    }

    #[test]
    fn rtt_sane() {
        let l = link();
        let rtt = l.unloaded_rtt_ns(64);
        assert!(rtt > 2 * 400);
        assert!(rtt < 900);
    }

    #[test]
    fn byte_accounting() {
        let mut l = link();
        l.send_to_device(64, 0);
        l.send_to_host(0, 0);
        assert_eq!(l.tx_bytes(), 16 + 64);
        assert_eq!(l.rx_bytes(), 16);
        assert_eq!(l.tlps(), 2);
    }
}
