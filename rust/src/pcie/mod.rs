//! PCIe Gen3 link model — the interconnect between the host CPU and the
//! HMMU (Fig 1b), and the paper's own explanation for the platform's
//! residual slowdown ("we presume the major impact comes from the latency
//! of the PCIe links").
//!
//! Modeled at TLP granularity: serialization time from payload size and
//! the 128b/130b-encoded lane rate, a fixed propagation/PHY latency each
//! way, and credit-based flow control bounding outstanding TLPs.
//!
//! Two ways to cross the link:
//!
//! - the **per-op** path ([`PcieLink::send_to_device`] /
//!   [`PcieLink::send_to_host`] / [`PcieLink::hold_credit_until`]), one
//!   call per TLP — the reference semantics;
//! - the **block** path ([`PcieLink::send_block_to_device`] /
//!   [`PcieLink::send_block_to_host`]), which takes a recorded traffic
//!   column ([`TlpColumn`]) and processes it in one pass: the credit gate
//!   drains against a sorted release horizon (a min-heap, shared with the
//!   per-op path), serialization times are memoized per payload size, and
//!   — when [`crate::config::PcieConfig::coalesce_writes`] is on —
//!   adjacent same-page posted MWr TLPs are write-combined up to
//!   `max_payload_bytes`. With coalescing off the block path is
//!   **bit-identical** to the per-op path (`tests/pcie_props.rs` pins it);
//!   with coalescing on only wire time and TLP counts change.

pub mod tlp;

pub use tlp::{Tlp, TlpKind};

use crate::config::{FaultConfig, PcieConfig};
use crate::sim::Time;
use crate::util::codec::{check_len, CodecState, Decoder, Encoder};
use crate::util::error::Result;
use crate::util::rng::{splitmix64, Xoshiro256};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// PCIe requests must not cross a 4 KiB boundary (PCIe Base Spec §2.2.7);
/// write-combining therefore never merges MWr TLPs from different
/// 4 KiB-aligned windows. This is the spec constant, independent of the
/// HMMU's managed page size.
const PCIE_PAGE_SHIFT: u64 = 12;

/// One direction of the link (host→device or device→host).
#[derive(Clone, Debug)]
pub struct LinkDirection {
    /// When the wire is next free.
    wire_free: Time,
    bytes_sent: u64,
    tlps_sent: u64,
}

/// Recorded host→device traffic for one block crossing, in issue order
/// (struct-of-arrays, recycled across crossings — steady state allocates
/// nothing). MWr entries carry their wire payload; MRd entries carry the
/// payload of the completion that will come back.
#[derive(Clone, Debug, Default)]
pub struct TlpColumn {
    kinds: Vec<TlpKind>,
    addrs: Vec<u64>,
    payloads: Vec<u32>,
    issue_at: Vec<Time>,
}

impl TlpColumn {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop all entries, keeping the allocations for the next crossing.
    pub fn clear(&mut self) {
        self.kinds.clear();
        self.addrs.clear();
        self.payloads.clear();
        self.issue_at.clear();
    }

    /// Append one request. `payload` is the data the transaction moves:
    /// outbound for MWr, inbound (completion) for MRd.
    ///
    /// Panics on CplD in release builds too: a completion silently
    /// crossing host→device would be modeled as a posted write (and even
    /// write-combined), corrupting wire accounting — same
    /// hard-error-over-silent-corruption stance as `TraceBlock::push`.
    #[inline]
    pub fn push(&mut self, kind: TlpKind, addr: u64, payload: u32, issue_at: Time) {
        assert_ne!(kind, TlpKind::CplD, "host→device column carries requests");
        self.kinds.push(kind);
        self.addrs.push(addr);
        self.payloads.push(payload);
        self.issue_at.push(issue_at);
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    #[inline]
    pub fn kind(&self, i: usize) -> TlpKind {
        self.kinds[i]
    }

    #[inline]
    pub fn addr(&self, i: usize) -> u64 {
        self.addrs[i]
    }

    #[inline]
    pub fn payload(&self, i: usize) -> u32 {
        self.payloads[i]
    }

    #[inline]
    pub fn issue_time(&self, i: usize) -> Time {
        self.issue_at[i]
    }
}

/// Link-fault injection state ([`FaultConfig::link_enabled`]): each TLP
/// put on a wire draws against the bit error rate; a corrupted TLP is
/// NAK'd by the receiver's LCRC check and retransmitted from the replay
/// buffer — re-occupying the wire for another serialization after the
/// replay timeout, bounded by the retry limit (PCIe's DLLP ack/nak
/// protocol, collapsed to its timing shape).
#[derive(Clone, Debug)]
struct LinkFaultState {
    rng: Xoshiro256,
    ber: f64,
    retry_limit: u32,
    replay_timeout_ns: u64,
}

/// Full-duplex PCIe link with credit flow control.
#[derive(Clone, Debug)]
pub struct PcieLink {
    // audit: allow(codec-coverage) — configuration, supplied at restore time
    cfg: PcieConfig,
    pub tx: LinkDirection, // host -> HMMU
    pub rx: LinkDirection, // HMMU -> host
    /// Completion times of TLPs holding a TX credit — the sorted release
    /// horizon. §Perf: a min-heap replaces the old unsorted `Vec` whose
    /// `retain` scans cost O(credits) per TLP under pressure; draining
    /// released credits is now O(log credits) per release, and the batch
    /// path pops the horizon once per gate instead of rescanning.
    credit_release: BinaryHeap<Reverse<Time>>,
    pub credit_stalls: u64,
    pub credit_wait_ns: u64,
    /// MWr TLPs merged away by write-combining (block path, coalescing
    /// on): `tlps_sent` counts wire TLPs, this counts the requests that
    /// rode along in a combined one.
    pub coalesced_writes: u64,
    /// Fault-injection state; `None` (the default) keeps every wire push
    /// on the exact pre-fault path.
    fault: Option<LinkFaultState>,
    /// TLP retransmissions triggered by injected corruption (both
    /// directions, per-op and block paths alike — the replay runs inside
    /// the shared wire-push choke points).
    pub link_retries: u64,
}

impl PcieLink {
    pub fn new(cfg: PcieConfig) -> Self {
        PcieLink {
            cfg,
            tx: LinkDirection {
                wire_free: 0,
                bytes_sent: 0,
                tlps_sent: 0,
            },
            rx: LinkDirection {
                wire_free: 0,
                bytes_sent: 0,
                tlps_sent: 0,
            },
            credit_release: BinaryHeap::new(),
            credit_stalls: 0,
            credit_wait_ns: 0,
            coalesced_writes: 0,
            fault: None,
            link_retries: 0,
        }
    }

    /// Arm the link-fault layer from `fault` (a no-op when
    /// [`FaultConfig::link_enabled`] is false). `seed` is the platform
    /// seed; the fault stream is mixed away from every workload RNG so
    /// arming it never perturbs anything else.
    pub fn set_fault(&mut self, fault: &FaultConfig, seed: u64) {
        if !fault.link_enabled() {
            self.fault = None;
            return;
        }
        let mut mix = seed ^ fault.seed.rotate_left(17);
        self.fault = Some(LinkFaultState {
            rng: Xoshiro256::new(splitmix64(&mut mix)),
            ber: fault.link_ber,
            retry_limit: fault.link_retry_limit,
            replay_timeout_ns: fault.replay_timeout_ns,
        });
    }

    pub fn config(&self) -> &PcieConfig {
        &self.cfg
    }

    /// Wire time for a TLP of `payload` bytes (header + payload over the
    /// encoded aggregate lane bandwidth), in ns (at least 1).
    #[inline]
    pub fn serialize_ns(&self, payload_bytes: u32) -> u64 {
        let total = (self.cfg.tlp_header_bytes + payload_bytes) as f64;
        (total / self.cfg.bandwidth_bytes_per_ns()).ceil().max(1.0) as u64
    }

    /// Credit gate: the time a TLP wanting to start at `now` may actually
    /// start, draining the release horizon and counting stalls. Released
    /// credits are drained lazily — only when the pool looks exhausted —
    /// exactly as the pre-heap `retain` gate did (same multiset, same
    /// decisions), so per-op and block crossings share one semantics.
    #[inline]
    fn credit_gate(&mut self, now: Time) -> Time {
        if self.credit_release.len() >= self.cfg.credits as usize {
            while let Some(&Reverse(t)) = self.credit_release.peek() {
                if t <= now {
                    self.credit_release.pop();
                } else {
                    break;
                }
            }
        }
        if self.credit_release.len() >= self.cfg.credits as usize {
            let Reverse(earliest) = *self.credit_release.peek().unwrap();
            self.credit_stalls += 1;
            self.credit_wait_ns += earliest.saturating_sub(now);
            while let Some(&Reverse(t)) = self.credit_release.peek() {
                if t <= earliest {
                    self.credit_release.pop();
                } else {
                    break;
                }
            }
            earliest
        } else {
            now
        }
    }

    /// Corruption draw + replay charging for one TLP whose clean
    /// transmission ends at `sent`. Each corrupted attempt costs the
    /// replay timeout (LCRC check + NAK DLLP round) plus a full
    /// reserialization; after `retry_limit` replays the transfer is
    /// delivered (the protocol escalates to link retrain — out of scope —
    /// so we cap the charged retries). Returns the fault-adjusted
    /// wire-occupied-until time; retries are tallied on `link_retries`.
    /// `bytes_sent`/`tlps_sent` stay goodput (one count per delivered
    /// TLP) so traffic accounting remains comparable across fault rates.
    #[inline]
    fn faulted_wire_end(&mut self, ser: u64, sent: Time) -> Time {
        let Some(f) = self.fault.as_mut() else {
            return sent;
        };
        let mut sent = sent;
        let mut tries = 0;
        while tries < f.retry_limit && f.rng.chance(f.ber) {
            tries += 1;
            sent += f.replay_timeout_ns + ser;
        }
        self.link_retries += tries as u64;
        sent
    }

    /// Put a pre-serialized TLP on the TX wire at `start`; returns its
    /// arrival at the device.
    #[inline]
    fn tx_push(&mut self, ser: u64, payload_bytes: u32, start: Time) -> Time {
        let wire_start = start.max(self.tx.wire_free);
        let mut wire_end = wire_start + ser;
        if self.fault.is_some() {
            wire_end = self.faulted_wire_end(ser, wire_end);
        }
        self.tx.wire_free = wire_end;
        self.tx.bytes_sent += (self.cfg.tlp_header_bytes + payload_bytes) as u64;
        self.tx.tlps_sent += 1;
        wire_end + self.cfg.propagation_ns
    }

    /// Put a pre-serialized TLP on the RX wire at `now`; returns its
    /// arrival at the host.
    #[inline]
    fn rx_push(&mut self, ser: u64, payload_bytes: u32, now: Time) -> Time {
        let wire_start = now.max(self.rx.wire_free);
        let mut wire_end = wire_start + ser;
        if self.fault.is_some() {
            wire_end = self.faulted_wire_end(ser, wire_end);
        }
        self.rx.wire_free = wire_end;
        self.rx.bytes_sent += (self.cfg.tlp_header_bytes + payload_bytes) as u64;
        self.rx.tlps_sent += 1;
        wire_end + self.cfg.propagation_ns
    }

    /// Transmit host→HMMU at `now`; returns arrival time at the HMMU RX.
    /// Acquires a flow-control credit; the credit is released when the
    /// transaction completes (`release` from [`Self::hold_credit_until`]).
    pub fn send_to_device(&mut self, payload_bytes: u32, now: Time) -> Time {
        let start = self.credit_gate(now);
        let ser = self.serialize_ns(payload_bytes);
        self.tx_push(ser, payload_bytes, start)
    }

    /// Register the completion time of a transaction so its TX credit is
    /// released then.
    pub fn hold_credit_until(&mut self, release_at: Time) {
        self.credit_release.push(Reverse(release_at));
    }

    /// Transmit HMMU→host (completion TLP) at `now`; returns arrival time
    /// at the host.
    pub fn send_to_host(&mut self, payload_bytes: u32, now: Time) -> Time {
        let ser = self.serialize_ns(payload_bytes);
        self.rx_push(ser, payload_bytes, now)
    }

    /// Cross a whole recorded traffic column host→device in one pass —
    /// the block-batched link crossing (§Perf: one call per column,
    /// serialization memoized per payload size, the credit horizon
    /// drained once per gate).
    ///
    /// For each entry, in column order: the credit gate runs at its issue
    /// time, the request TLP is serialized onto the TX wire, and
    /// `service(link, i, arrive)` performs the device-side work (the
    /// HMMU access), returning its completion. MWr entries hold their
    /// credit until that commit; MRd entries additionally serialize the
    /// completion-with-data back over RX and hold the credit until it
    /// arrives. Per-entry completions (MWr: device commit; MRd: data
    /// arrival at the host) are left in `completions`.
    ///
    /// `service` receives the link back as its first argument so
    /// device-side work may itself cross the link (host-managed DMA at an
    /// epoch boundary) at the correct sequence point — which is also why
    /// wire state is *not* cached across service calls: both paths must
    /// observe every interleaved send.
    ///
    /// With `coalesce_writes` off this is bit-identical to issuing the
    /// same column through the per-op calls. With it on, adjacent
    /// **address-contiguous** MWr entries issued at the same time inside
    /// one 4 KiB-aligned window (the PCIe request-boundary rule) merge
    /// into a single wire TLP of up to `max_payload_bytes` payload
    /// (one header, one credit, one serialization); each constituent
    /// write is still serviced individually at the combined TLP's arrival
    /// time, so device-side state (redirection, residency, per-device
    /// counters) is untouched — only wire time and TLP counts change.
    pub fn send_block_to_device<F>(
        &mut self,
        col: &TlpColumn,
        service: &mut F,
        completions: &mut Vec<Time>,
    ) where
        F: FnMut(&mut PcieLink, usize, Time) -> Time,
    {
        completions.clear();
        let n = col.len();
        let coalesce = self.cfg.coalesce_writes;
        let max_payload = self.cfg.max_payload_bytes;
        // Serialization memo: a column carries very few distinct payload
        // sizes (header-only reads + line-sized writes), so the f64
        // division in `serialize_ns` is paid per size, not per TLP.
        let ser_hdr = self.serialize_ns(0);
        let mut memo_payload = 0u32;
        let mut memo_ser = ser_hdr;
        let mut i = 0usize;
        while i < n {
            let at = col.issue_at[i];
            let payload = col.payloads[i];
            match col.kinds[i] {
                TlpKind::MRd => {
                    // Request out is header-only; the data rides the
                    // completion back.
                    let start = self.credit_gate(at);
                    let arrive = self.tx_push(ser_hdr, 0, start);
                    let release = service(self, i, arrive);
                    if payload != memo_payload {
                        memo_payload = payload;
                        memo_ser = self.serialize_ns(payload);
                    }
                    let back = self.rx_push(memo_ser, payload, release);
                    self.hold_credit_until(back);
                    completions.push(back);
                    i += 1;
                }
                TlpKind::CplD => unreachable!("TlpColumn::push rejects completions"),
                TlpKind::MWr => {
                    // Write-combining: extend the run while the next entry
                    // is another posted write at the same issue time whose
                    // data is **address-contiguous** with the run so far
                    // (an MWr TLP carries one address and one contiguous
                    // payload), the run stays inside one PCIe 4 KiB page
                    // (requests must not cross that boundary), and the
                    // merged payload still fits one TLP.
                    let mut end = i + 1;
                    let mut combined = payload;
                    if coalesce {
                        while end < n
                            && col.kinds[end] == TlpKind::MWr
                            && col.issue_at[end] == at
                            && col.addrs[end]
                                == col.addrs[end - 1] + col.payloads[end - 1] as u64
                            && col.addrs[end] >> PCIE_PAGE_SHIFT
                                == col.addrs[i] >> PCIE_PAGE_SHIFT
                            && combined.saturating_add(col.payloads[end]) <= max_payload
                        {
                            combined += col.payloads[end];
                            end += 1;
                        }
                    }
                    let start = self.credit_gate(at);
                    if combined != memo_payload {
                        memo_payload = combined;
                        memo_ser = self.serialize_ns(combined);
                    }
                    let arrive = self.tx_push(memo_ser, combined, start);
                    self.coalesced_writes += (end - i - 1) as u64;
                    // Every constituent write is serviced individually at
                    // the (shared) arrival time; the single credit is held
                    // until the last of them commits.
                    let mut release = 0;
                    for j in i..end {
                        let commit = service(self, j, arrive);
                        release = release.max(commit);
                        completions.push(commit);
                    }
                    self.hold_credit_until(release);
                    i = end;
                }
            }
        }
    }

    /// Cross a column of completion TLPs device→host in one pass with
    /// serialization memoized per payload size; arrival times land in
    /// `arrivals`. Used by the host-managed DMA path to ship a migrated
    /// block's completion chunks back-to-back on the RX wire. Each entry
    /// goes through the same [`Self::rx_push`] bookkeeping as
    /// [`Self::send_to_host`] (single source of truth), so the column is
    /// bit-identical to per-entry sends.
    pub fn send_block_to_host(
        &mut self,
        payloads: &[u32],
        issue_at: &[Time],
        arrivals: &mut Vec<Time>,
    ) {
        assert_eq!(payloads.len(), issue_at.len());
        arrivals.clear();
        let mut memo_payload = u32::MAX;
        let mut memo_ser = 0u64;
        for (&p, &t) in payloads.iter().zip(issue_at) {
            if p != memo_payload {
                memo_payload = p;
                memo_ser = self.serialize_ns(p);
            }
            arrivals.push(self.rx_push(memo_ser, p, t));
        }
    }

    pub fn tx_bytes(&self) -> u64 {
        self.tx.bytes_sent
    }

    pub fn rx_bytes(&self) -> u64 {
        self.rx.bytes_sent
    }

    pub fn tx_tlps(&self) -> u64 {
        self.tx.tlps_sent
    }

    pub fn rx_tlps(&self) -> u64 {
        self.rx.tlps_sent
    }

    pub fn tlps(&self) -> u64 {
        self.tx.tlps_sent + self.rx.tlps_sent
    }

    /// TX credits currently held by outstanding transactions (an upper
    /// bound: released credits are reclaimed lazily, at the gate).
    pub fn outstanding_credits(&self) -> usize {
        self.credit_release.len()
    }

    /// Unloaded round-trip for a read of `bytes` (serialize request +
    /// 2×propagation + serialize completion); device service excluded.
    pub fn unloaded_rtt_ns(&self, bytes: u32) -> u64 {
        self.serialize_ns(0) + self.serialize_ns(bytes) + 2 * self.cfg.propagation_ns
    }
}

impl CodecState for LinkDirection {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_u64(self.wire_free);
        e.put_u64(self.bytes_sent);
        e.put_u64(self.tlps_sent);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.wire_free = d.u64()?;
        self.bytes_sent = d.u64()?;
        self.tlps_sent = d.u64()?;
        Ok(())
    }
}

impl CodecState for PcieLink {
    fn encode_state(&self, e: &mut Encoder) {
        self.tx.encode_state(e);
        self.rx.encode_state(e);
        // Credit-release horizon, sorted so the encoding is independent of
        // the heap's insertion-dependent internal layout.
        let mut release: Vec<Time> = self.credit_release.iter().map(|&Reverse(t)| t).collect();
        release.sort_unstable();
        e.put_u64_slice(&release);
        e.put_u64(self.credit_stalls);
        e.put_u64(self.credit_wait_ns);
        e.put_u64(self.coalesced_writes);
        // Fault stream position (the ber/limits are config-derived): a
        // restored faulted link must replay the exact corruption draws a
        // continuous run would have made.
        match &self.fault {
            None => e.put_bool(false),
            Some(f) => {
                e.put_bool(true);
                e.put_u64_slice(&f.rng.state());
            }
        }
        e.put_u64(self.link_retries);
    }

    fn decode_state(&mut self, d: &mut Decoder) -> Result<()> {
        self.tx.decode_state(d)?;
        self.rx.decode_state(d)?;
        let release = d.u64_vec()?;
        if release.len() > self.cfg.credits as usize {
            crate::bail!(
                "checkpoint geometry mismatch: {} held credits exceed credit limit {}",
                release.len(),
                self.cfg.credits
            );
        }
        self.credit_release = release.into_iter().map(Reverse).collect();
        self.credit_stalls = d.u64()?;
        self.credit_wait_ns = d.u64()?;
        self.coalesced_writes = d.u64()?;
        let armed = d.bool()?;
        match (&mut self.fault, armed) {
            (None, false) => {}
            (Some(f), true) => {
                let s = d.u64_vec()?;
                check_len("link fault rng words", 4, s.len())?;
                f.rng = Xoshiro256::from_state([s[0], s[1], s[2], s[3]]);
            }
            (have, _) => crate::bail!(
                "checkpoint geometry mismatch: link fault layer {} in snapshot, {} in config",
                if armed { "armed" } else { "absent" },
                if have.is_some() { "armed" } else { "absent" },
            ),
        }
        self.link_retries = d.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn link() -> PcieLink {
        PcieLink::new(SystemConfig::paper().pcie)
    }

    #[test]
    fn serialization_scales_with_payload() {
        let l = link();
        assert!(l.serialize_ns(256) > l.serialize_ns(0));
        // 16B header at ~7.88GB/s ≈ 2-3ns
        assert!(l.serialize_ns(0) <= 3);
    }

    #[test]
    fn propagation_dominates_small_tlps() {
        let mut l = link();
        let arrival = l.send_to_device(0, 0);
        assert!(arrival >= 400, "arrival={arrival}");
        assert!(arrival < 450);
    }

    #[test]
    fn wire_occupancy_serializes_back_to_back() {
        let mut l = link();
        let a1 = l.send_to_device(256, 0);
        let a2 = l.send_to_device(256, 0);
        assert!(a2 > a1);
        assert_eq!(a2 - a1, l.serialize_ns(256));
    }

    #[test]
    fn credits_block_when_exhausted() {
        let mut l = link();
        let credits = l.config().credits;
        for _ in 0..credits {
            let arr = l.send_to_device(0, 0);
            l.hold_credit_until(arr + 10_000); // transactions outstanding for a long time
        }
        assert_eq!(l.outstanding_credits(), credits as usize);
        let before = l.credit_stalls;
        l.send_to_device(0, 0);
        assert_eq!(l.credit_stalls, before + 1);
        assert!(l.credit_wait_ns > 0);
    }

    #[test]
    fn duplex_directions_independent() {
        let mut l = link();
        let t_tx = l.send_to_device(256, 0);
        let t_rx = l.send_to_host(256, 0);
        // Both around serialize+prop, neither delayed by the other.
        assert!(t_tx < 500 && t_rx < 500);
    }

    #[test]
    fn rtt_sane() {
        let l = link();
        let rtt = l.unloaded_rtt_ns(64);
        assert!(rtt > 2 * 400);
        assert!(rtt < 900);
    }

    #[test]
    fn byte_accounting() {
        let mut l = link();
        l.send_to_device(64, 0);
        l.send_to_host(0, 0);
        assert_eq!(l.tx_bytes(), 16 + 64);
        assert_eq!(l.rx_bytes(), 16);
        assert_eq!(l.tlps(), 2);
        assert_eq!(l.tx_tlps(), 1);
        assert_eq!(l.rx_tlps(), 1);
    }

    #[test]
    fn block_crossing_matches_per_op_reads_and_writes() {
        // A hand-sized column through both paths; the full randomized
        // battery lives in tests/pcie_props.rs.
        fn latency(i: usize) -> Time {
            100 + 10 * i as Time
        }

        let mut per_op = link();
        let mut ref_completions = Vec::new();
        {
            // write @ t=0, read @ t=50, write @ t=50
            let a = per_op.send_to_device(64, 0);
            per_op.hold_credit_until(a + latency(0));
            ref_completions.push(a + latency(0));
            let a = per_op.send_to_device(0, 50);
            let b = per_op.send_to_host(64, a + latency(1));
            per_op.hold_credit_until(b);
            ref_completions.push(b);
            let a = per_op.send_to_device(64, 50);
            per_op.hold_credit_until(a + latency(2));
            ref_completions.push(a + latency(2));
        }

        let mut blocked = link();
        let mut col = TlpColumn::new();
        col.push(TlpKind::MWr, 0x1000, 64, 0);
        col.push(TlpKind::MRd, 0x2000, 64, 50);
        col.push(TlpKind::MWr, 0x3040, 64, 50);
        let mut completions = Vec::new();
        blocked.send_block_to_device(
            &col,
            &mut |_l: &mut PcieLink, i, arrive| arrive + latency(i),
            &mut completions,
        );

        assert_eq!(completions, ref_completions);
        assert_eq!(blocked.tx_bytes(), per_op.tx_bytes());
        assert_eq!(blocked.rx_bytes(), per_op.rx_bytes());
        assert_eq!(blocked.tlps(), per_op.tlps());
        assert_eq!(blocked.credit_stalls, per_op.credit_stalls);
    }

    #[test]
    fn write_combining_merges_same_page_runs() {
        let mut cfg = SystemConfig::paper().pcie;
        cfg.coalesce_writes = true;
        let mut l = PcieLink::new(cfg);
        let mut col = TlpColumn::new();
        // Three 64B writes in one 4K page at the same time: one TLP.
        col.push(TlpKind::MWr, 0x1000, 64, 0);
        col.push(TlpKind::MWr, 0x1040, 64, 0);
        col.push(TlpKind::MWr, 0x1080, 64, 0);
        // Different page: must not merge into the run.
        col.push(TlpKind::MWr, 0x2000, 64, 0);
        let mut serviced = 0u32;
        let mut completions = Vec::new();
        l.send_block_to_device(
            &col,
            &mut |_l, _i, arrive| {
                serviced += 1;
                arrive + 10
            },
            &mut completions,
        );
        assert_eq!(serviced, 4, "every constituent write is serviced");
        assert_eq!(l.tx_tlps(), 2, "3 same-page writes combine into 1 TLP");
        assert_eq!(l.coalesced_writes, 2);
        // One header saved per merged TLP.
        assert_eq!(l.tx_bytes(), 2 * 16 + 4 * 64);
        assert_eq!(completions.len(), 4);
    }

    #[test]
    fn write_combining_requires_contiguity() {
        // Same 4 KiB page and same issue time is not enough: an MWr TLP
        // carries one address and one contiguous payload, so an address
        // gap breaks the run even inside one page.
        let mut cfg = SystemConfig::paper().pcie;
        cfg.coalesce_writes = true;
        let mut l = PcieLink::new(cfg);
        let mut col = TlpColumn::new();
        col.push(TlpKind::MWr, 0x1000, 64, 0);
        col.push(TlpKind::MWr, 0x1fc0, 64, 0); // same page, 4032B away
        let mut completions = Vec::new();
        l.send_block_to_device(&col, &mut |_l, _i, a| a + 10, &mut completions);
        assert_eq!(l.tx_tlps(), 2, "non-contiguous writes must not merge");
        assert_eq!(l.coalesced_writes, 0);
    }

    #[test]
    fn write_combining_respects_max_payload() {
        let mut cfg = SystemConfig::paper().pcie;
        cfg.coalesce_writes = true;
        cfg.max_payload_bytes = 128;
        let mut l = PcieLink::new(cfg);
        let mut col = TlpColumn::new();
        for k in 0..4u64 {
            col.push(TlpKind::MWr, 0x1000 + k * 64, 64, 0);
        }
        let mut completions = Vec::new();
        l.send_block_to_device(&col, &mut |_l, _i, a| a + 10, &mut completions);
        // 4 × 64B at max_payload 128 → two 128B TLPs.
        assert_eq!(l.tx_tlps(), 2);
        assert_eq!(l.coalesced_writes, 2);
    }

    #[test]
    #[should_panic(expected = "host→device column carries requests")]
    fn column_rejects_completions_in_release_too() {
        // Hard assert, not debug_assert: a CplD in the host→device column
        // would silently be modeled as a posted MWr.
        let mut col = TlpColumn::new();
        col.push(TlpKind::CplD, 0x1000, 64, 0);
    }

    #[test]
    fn codec_round_trip_preserves_link_state() {
        let mut warm = link();
        for i in 0..40u64 {
            let a = warm.send_to_device(64, i * 3);
            warm.hold_credit_until(a + 5_000);
            warm.send_to_host(64, i * 3 + 1);
        }
        let mut e = Encoder::new();
        warm.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = link();
        restored.decode_state(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(restored.tx_bytes(), warm.tx_bytes());
        assert_eq!(restored.outstanding_credits(), warm.outstanding_credits());
        // Future behavior identical: same sends, same arrivals/stalls.
        for i in 0..30u64 {
            assert_eq!(
                restored.send_to_device(64, 100 + i),
                warm.send_to_device(64, 100 + i)
            );
        }
        assert_eq!(restored.credit_stalls, warm.credit_stalls);
        assert_eq!(restored.credit_wait_ns, warm.credit_wait_ns);
    }

    #[test]
    fn link_faults_replay_and_count_retries() {
        // ber = 1.0: every attempt corrupts, so every TLP burns exactly
        // `link_retry_limit` replays — each costing a reserialization
        // plus the replay timeout — before the capped delivery.
        let mut fault = FaultConfig::disabled();
        fault.link_ber = 1.0;
        let mut clean = link();
        let mut faulty = link();
        faulty.set_fault(&fault, 42);
        let a_clean = clean.send_to_device(64, 0);
        let a_faulty = faulty.send_to_device(64, 0);
        let ser = clean.serialize_ns(64);
        let expect = fault.link_retry_limit as u64 * (fault.replay_timeout_ns + ser);
        assert_eq!(a_faulty - a_clean, expect);
        assert_eq!(faulty.link_retries, fault.link_retry_limit as u64);
        // RX direction replays through the same choke point.
        let r_clean = clean.send_to_host(64, 10_000);
        let r_faulty = faulty.send_to_host(64, 10_000);
        assert_eq!(r_faulty - r_clean, expect);
        // Goodput accounting is unchanged by replays.
        assert_eq!(faulty.tx_bytes(), clean.tx_bytes());
        assert_eq!(faulty.tlps(), clean.tlps());
    }

    #[test]
    fn disarmed_fault_layer_is_bit_identical() {
        let mut fault = FaultConfig::disabled();
        fault.rber_base = 0.1; // memory faults on, link faults off
        let mut a = link();
        let mut b = link();
        b.set_fault(&fault, 42);
        for i in 0..50u64 {
            assert_eq!(a.send_to_device(64, i * 7), b.send_to_device(64, i * 7));
            assert_eq!(a.send_to_host(64, i * 7), b.send_to_host(64, i * 7));
        }
        assert_eq!(b.link_retries, 0);
    }

    #[test]
    fn faulted_link_codec_round_trip_replays_identically() {
        let mut fault = FaultConfig::disabled();
        fault.link_ber = 0.3;
        let mut warm = link();
        warm.set_fault(&fault, 7);
        for i in 0..60u64 {
            let a = warm.send_to_device(64, i * 11);
            warm.hold_credit_until(a + 2_000);
            warm.send_to_host(64, i * 11 + 3);
        }
        assert!(warm.link_retries > 0, "ber 0.3 over 120 TLPs must retry");
        let mut e = Encoder::new();
        warm.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut restored = link();
        restored.set_fault(&fault, 7);
        restored.decode_state(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(restored.link_retries, warm.link_retries);
        // Future corruption draws continue from the same stream position.
        for i in 0..40u64 {
            assert_eq!(
                restored.send_to_device(64, 5_000 + i * 9),
                warm.send_to_device(64, 5_000 + i * 9)
            );
        }
        assert_eq!(restored.link_retries, warm.link_retries);
        // Geometry mismatch fails loudly.
        let mut disarmed = link();
        assert!(disarmed.decode_state(&mut Decoder::new(&bytes)).is_err());
    }

    #[test]
    fn block_to_host_matches_per_entry() {
        let mut a = link();
        let mut b = link();
        let payloads = [64u32, 64, 0, 256, 64];
        let times = [10u64, 12, 400, 401, 900];
        let mut got = Vec::new();
        b.send_block_to_host(&payloads, &times, &mut got);
        let want: Vec<Time> = payloads
            .iter()
            .zip(&times)
            .map(|(&p, &t)| a.send_to_host(p, t))
            .collect();
        assert_eq!(got, want);
        assert_eq!(a.rx_bytes(), b.rx_bytes());
        assert_eq!(a.rx_tlps(), b.rx_tlps());
    }
}
