//! Host-CPU substrate: ARM Cortex-A57-like core timing, L1/L2 cache
//! hierarchy and TLB.
//!
//! In the paper the host is real silicon (LS2085A); its only observable
//! effect on the experiment is (a) the *cache-filtered* memory request
//! stream reaching the HMMU and (b) execution time as a function of
//! memory latency. Both are reproduced here: [`cache`] models the Table II
//! hierarchy, [`core_model`] converts per-access latencies into cycles.

pub mod cache;
pub mod core_model;
pub mod hierarchy;
pub mod tlb;

pub use cache::{BlockMiss, Cache, CacheOutcome};
pub use core_model::CoreModel;
pub use hierarchy::{BlockOutcomes, CacheHierarchy, HierarchyOutcome, MemBackend};
pub use tlb::Tlb;
