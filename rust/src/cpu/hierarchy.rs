//! Cache hierarchy: L1D + L2 (+ TLB), producing the post-cache-filter
//! request stream that reaches main memory.
//!
//! The paper's Fig 1: "receives the memory requests from the host CPU
//! *after cache filtering*". This module is that filter. A memory backend
//! (native DRAM or PCIe+HMMU) is abstracted behind [`MemBackend`] so the
//! same hierarchy drives both the emulation platform and the native
//! reference.

use super::cache::Cache;
use super::tlb::Tlb;
use crate::config::SystemConfig;
use crate::mem::AccessKind;
use crate::sim::Time;

/// Anything that can serve a line-sized memory access at a point in time.
pub trait MemBackend {
    /// Issue an access; returns its completion time.
    fn access(&mut self, addr: u64, kind: AccessKind, bytes: u64, now: Time) -> Time;

    /// Called at epoch boundaries / end-of-run to let the backend flush
    /// (e.g., HMMU migration bookkeeping). Default: nothing.
    fn drain(&mut self, _now: Time) {}
}

/// Outcome of one data access through the hierarchy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HierarchyOutcome {
    /// Latency in ns as seen by the core for this access.
    pub latency_ns: u64,
    /// Did the access go to main memory?
    pub memory_access: bool,
}

/// L1D + L2 + TLB in front of a [`MemBackend`].
pub struct CacheHierarchy {
    pub l1d: Cache,
    pub l2: Cache,
    pub tlb: Tlb,
    line_bytes: u64,
    l1_hit_ns: u64,
    l2_hit_ns: u64,
    /// TLB L2-hit / walk penalties in ns.
    tlb_l2_ns: u64,
    tlb_walk_ns: u64,
    /// Memory accesses (fills + writebacks) forwarded to the backend.
    pub mem_reads: u64,
    pub mem_writes: u64,
}

impl CacheHierarchy {
    pub fn new(cfg: &SystemConfig) -> Self {
        let cpu_cycle_ns = 1.0 / cfg.cpu.freq_ghz;
        CacheHierarchy {
            l1d: Cache::new(cfg.l1d),
            l2: Cache::new(cfg.l2),
            tlb: Tlb::a57(cfg.hmmu.page_bytes),
            line_bytes: cfg.l1d.line_bytes as u64,
            l1_hit_ns: (cfg.l1d.hit_cycles as f64 * cpu_cycle_ns).ceil() as u64,
            l2_hit_ns: (cfg.l2.hit_cycles as f64 * cpu_cycle_ns).ceil() as u64,
            tlb_l2_ns: (4.0 * cpu_cycle_ns).ceil() as u64,
            tlb_walk_ns: (20.0 * cpu_cycle_ns).ceil() as u64,
            mem_reads: 0,
            mem_writes: 0,
        }
    }

    /// One data access at time `now`; misses go to `backend`.
    /// `#[inline]`: monomorphized per backend and called from
    /// `CoreModel::step_block`'s tight loop — inlining it there lets the
    /// TLB/L1 hit path fold into the block loop without a call.
    #[inline]
    pub fn access<B: MemBackend>(
        &mut self,
        addr: u64,
        is_write: bool,
        now: Time,
        backend: &mut B,
    ) -> HierarchyOutcome {
        let line_addr = addr & !(self.line_bytes - 1);

        // TLB first.
        let tlb_ns = match self.tlb.access(addr) {
            0 => 0,
            1 => self.tlb_l2_ns,
            _ => self.tlb_walk_ns,
        };

        // L1D.
        let l1 = self.l1d.access(line_addr, is_write);
        if l1.hit {
            return HierarchyOutcome {
                latency_ns: tlb_ns + self.l1_hit_ns,
                memory_access: false,
            };
        }
        // L1 victim write-back goes to L2.
        if let Some(wb) = l1.writeback {
            let l2wb = self.l2.access(wb, true);
            if let Some(wb2) = l2wb.writeback {
                // L2 dirty victim → memory write (posted; doesn't stall core).
                self.mem_writes += 1;
                backend.access(wb2, AccessKind::Write, self.line_bytes, now);
            }
        }

        // L2.
        let l2 = self.l2.access(line_addr, is_write);
        if l2.hit {
            return HierarchyOutcome {
                latency_ns: tlb_ns + self.l1_hit_ns + self.l2_hit_ns,
                memory_access: false,
            };
        }
        if let Some(wb2) = l2.writeback {
            self.mem_writes += 1;
            backend.access(wb2, AccessKind::Write, self.line_bytes, now);
        }

        // Memory fill (read the line; write-allocate means even stores
        // fetch the line first).
        self.mem_reads += 1;
        let done = backend.access(line_addr, AccessKind::Read, self.line_bytes, now);
        HierarchyOutcome {
            latency_ns: tlb_ns + self.l1_hit_ns + self.l2_hit_ns + (done - now),
            memory_access: true,
        }
    }

    /// Flush both caches, returning dirty lines as memory writes.
    ///
    /// The hierarchy is inclusive and store-allocates mark both levels
    /// dirty, so the L2 dirty set covers (to within the rare
    /// store-hit-on-clean-L1-line case) everything that must reach
    /// memory; L1 dirty lines drain into L2, not past it.
    pub fn flush<B: MemBackend>(&mut self, now: Time, backend: &mut B) {
        let _d1 = self.l1d.flush();
        let d2 = self.l2.flush();
        // Charge the dirty write-backs to the backend (addresses are gone
        // after flush; we model the volume with sequential addresses —
        // only counters matter post-run).
        for i in 0..d2 {
            self.mem_writes += 1;
            backend.access(i * self.line_bytes, AccessKind::Write, self.line_bytes, now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fixed-latency test backend recording accesses.
    pub struct TestBackend {
        pub latency: u64,
        pub log: Vec<(u64, AccessKind)>,
    }

    impl MemBackend for TestBackend {
        fn access(&mut self, addr: u64, kind: AccessKind, _bytes: u64, now: Time) -> Time {
            self.log.push((addr, kind));
            now + self.latency
        }
    }

    fn setup() -> (CacheHierarchy, TestBackend) {
        let cfg = SystemConfig::default_scaled(16);
        (
            CacheHierarchy::new(&cfg),
            TestBackend {
                latency: 100,
                log: Vec::new(),
            },
        )
    }

    #[test]
    fn first_touch_misses_to_memory() {
        let (mut h, mut b) = setup();
        let out = h.access(0x10000, false, 0, &mut b);
        assert!(out.memory_access);
        assert!(out.latency_ns >= 100);
        assert_eq!(b.log.len(), 1);
        assert_eq!(b.log[0].1, AccessKind::Read);
    }

    #[test]
    fn second_touch_hits_l1() {
        let (mut h, mut b) = setup();
        h.access(0x10000, false, 0, &mut b);
        let out = h.access(0x10000, false, 200, &mut b);
        assert!(!out.memory_access);
        assert!(out.latency_ns < 100);
        assert_eq!(b.log.len(), 1); // no new memory access
    }

    #[test]
    fn l1_evict_hits_l2() {
        let (mut h, mut b) = setup();
        let cfg = SystemConfig::default_scaled(16);
        // Fill one L1 set (2 ways) then a third conflicting line.
        let stride = cfg.l1d.sets() * cfg.l1d.line_bytes as u64;
        h.access(0, false, 0, &mut b);
        h.access(stride, false, 0, &mut b);
        h.access(2 * stride, false, 0, &mut b); // evicts 0 from L1
        let out = h.access(0, false, 0, &mut b); // L2 hit
        assert!(!out.memory_access);
        assert_eq!(b.log.len(), 3);
    }

    #[test]
    fn writes_allocate_and_writeback_on_eviction() {
        let (mut h, mut b) = setup();
        let cfg = SystemConfig::default_scaled(16);
        // Dirty a line, then force it out of both L1 and L2. The L1
        // eviction of line 0 (at the second conflicting access) writes it
        // back into L2 and *refreshes* its L2 LRU position, so evicting
        // it from L2 takes ways+1 conflicting fills.
        h.access(0, true, 0, &mut b);
        let l2_stride = cfg.l2.sets() * cfg.l2.line_bytes as u64;
        for w in 1..=(cfg.l2.ways as u64 + 1) {
            h.access(w * l2_stride, false, 0, &mut b);
        }
        let writes: Vec<_> = b.log.iter().filter(|(_, k)| k.is_write()).collect();
        assert_eq!(writes.len(), 1, "dirty line written back once");
        assert_eq!(writes[0].0, 0);
        assert_eq!(h.mem_writes, 1);
    }

    #[test]
    fn flush_writes_dirty_lines() {
        let (mut h, mut b) = setup();
        h.access(0, true, 0, &mut b);
        h.access(4096, true, 0, &mut b);
        let before = b.log.len();
        h.flush(100, &mut b);
        let wbs = b.log[before..].iter().filter(|(_, k)| k.is_write()).count();
        assert_eq!(wbs, 2);
    }

    #[test]
    fn streaming_miss_rate_near_one() {
        let (mut h, mut b) = setup();
        for a in (0..(4 << 20)).step_by(64) {
            h.access(a, false, 0, &mut b);
        }
        // 4MiB stream through 1MiB L2: every line misses.
        assert!(h.mem_reads > 60_000);
    }
}
